"""Property tests: the bf16 storage policy's error envelope vs fp32.

Satellite of the precision-policy PR. The bf16 policy
(:mod:`repro.core.precision`) stores the scan-carried push-sum state —
including the *cumulative* relay counters sigma/rho — in bfloat16. With 8
mantissa bits, a per-round increment rounds to nothing once the counter
is ~2^8x its size, so the quantization error of every Theorem-1/2
quantity grows with the horizon T: bf16 is a bandwidth optimization for
the **short-window regime** (large N, bounded rounds per compiled
window), not a drop-in for long trajectories. These tests pin that down
with explicit envelopes:

* mass invariant drift  <= ``C_MASS * EPS_BF16 * T``   (linear in T);
* consensus-gap perturbation <= ``C_GAP * EPS_BF16`` of the input spread
  at T=32 — within Theorem 1's tolerance, whose gamma^t contraction
  floor at that horizon is far above the envelope;
* Theorem-2 worst-case log-ratio within ``C_LR * EPS_BF16`` of fp32
  (relative, +1 absolute floor) at T=16;
* and — so nobody widens the envelope by raising T — an explicit
  *horizon* test asserting the short-T envelope genuinely fails by
  T=200: the cliff is a property of the cumulative relay in bf16, and
  this suite documents it rather than hiding it.

No ``hypothesis`` in the image: scenarios are drawn over
(drop, Gamma, topology, seed) by a seeded ``numpy.random.Generator`` —
deterministic, but exercising the full grid the sweeps run.

Envelope constants are calibrated empirically (worst case over the
sampled scenarios, then doubled) — they are claims about THIS engine's
bf16 build, not generic bf16 folklore; a regression that loosens the
rounding behavior trips them.
"""
import numpy as np
import pytest

from repro.core.graphs import make_hierarchy
from repro.core.hps import HPSConfig, make_hps_runtime, run_hps
from repro.core.pushsum import sparse_mass_invariant
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning

EPS_BF16 = 2.0 ** -8          # bfloat16 unit roundoff (8 mantissa bits)
TOPOLOGIES = ("ring", "complete", "ring+")

# calibrated worst-case-x2 margins (see module docstring)
C_MASS = 2.0                  # mass drift slope: measured ~0.8*EPS*T @T=32
C_GAP = 32.0                  # gap diff / spread @T=32: measured ~14*EPS
C_LR = 1280.0                 # Thm-2 log-ratio rel diff @T=16: measured ~606*EPS
                              # (worst case is Gamma=16 on a ring — the
                              # slowest-mixing scenario, no fusion before
                              # t=15, where mass quantization bites hardest)


def _scenarios(k: int, seed: int):
    """k (drop, Gamma, topology, seed) draws from one seeded generator."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        out.append((
            float(rng.uniform(0.0, 0.6)),
            int(rng.choice([2, 4, 8, 16])),
            TOPOLOGIES[int(rng.integers(len(TOPOLOGIES)))],
            int(rng.integers(1000)),
        ))
    return out


def _hps_pair(drop, gamma, topology, seed, T):
    """(fp32 run, bf16 run, runtime, inputs) for one scenario."""
    topo = make_hierarchy([5, 5, 5], topology=topology, seed=seed)
    cfg = HPSConfig(topo=topo, gamma_period=gamma, B=4, drop_prob=drop)
    w = (np.random.default_rng(seed)
         .normal(size=(topo.N, 3)).astype(np.float32))
    rt = make_hps_runtime(cfg)
    r32 = run_hps(w, cfg, T=T, seed=seed, store="gap")
    r16 = run_hps(w, cfg, T=T, seed=seed, store="gap", policy="bf16")
    return r32, r16, rt, w


def _mass_rel_drift(res, rt, w):
    """Worst relative drift of sum_j z_j + in-flight from sum_j w_j."""
    mi = np.asarray(sparse_mass_invariant(res.final_state, rt.src, rt.valid))
    ref = np.asarray(w).sum(axis=0)
    tot = np.abs(np.asarray(w)).sum(axis=0)
    return float(np.max(np.abs(mi - ref) / np.maximum(tot, 1e-6)))


class TestTheorem1Envelope:
    T = 32

    def test_mass_invariant_drift_linear_in_T(self):
        """bf16 mass drift <= C_MASS * EPS * T; fp32 stays at roundoff.

        Theorem 1 rides the augmented-graph mass-preservation property;
        in bf16 the cumulative sigma/rho relay quantizes each round's
        delivery, so the telescoping identity drifts by O(EPS) per round
        — linear in T, NOT a fixed floor."""
        env = C_MASS * EPS_BF16 * self.T
        for drop, gamma, topology, seed in _scenarios(10, seed=7):
            r32, r16, rt, w = _hps_pair(drop, gamma, topology, seed, self.T)
            d32 = _mass_rel_drift(r32, rt, w)
            d16 = _mass_rel_drift(r16, rt, w)
            assert d32 <= 1e-5, (drop, gamma, topology, seed, d32)
            assert d16 <= env, (drop, gamma, topology, seed, d16, env)

    def test_consensus_gap_perturbation(self):
        """|gap_bf16 - gap_fp32| <= C_GAP * EPS * spread(w) at T=32.

        The consensus gap is Theorem 1's LHS; at this horizon the bf16
        perturbation sits well inside the theorem's tolerance (the
        gamma^t floor is still O(spread) here, ~10x the envelope)."""
        for drop, gamma, topology, seed in _scenarios(10, seed=11):
            r32, r16, _, w = _hps_pair(drop, gamma, topology, seed, self.T)
            spread = float(np.ptp(np.asarray(w)))
            diff = abs(float(r16.gap[-1]) - float(r32.gap[-1]))
            assert diff <= C_GAP * EPS_BF16 * spread, (
                drop, gamma, topology, seed, diff, spread)


class TestTheorem2Envelope:
    T = 16

    def _pair(self, drop, gamma, topology, seed, T):
        topo = make_hierarchy([5, 5, 5], topology=topology, seed=seed)
        model = make_confused_model(N=topo.N, m=3, truth=1,
                                    confusion=0.4, seed=seed)
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=4, drop_prob=drop)
        r32 = run_social_learning(model, cfg, T=T, seed=seed,
                                  store="log_ratio")
        r16 = run_social_learning(model, cfg, T=T, seed=seed,
                                  store="log_ratio", policy="bf16")
        return (np.asarray(r32.log_ratio), np.asarray(r16.log_ratio))

    def test_log_ratio_envelope(self):
        """Thm-2 worst-case log-ratio: bf16 within C_LR*EPS of fp32.

        Relative with a +1 absolute floor (the curve crosses zero). The
        log-belief magnitudes grow ~t, so the stored-state rounding is
        amplified through the exponential belief dynamics — hence the
        short T: this is the window where the envelope is meaningfully
        tight (measured worst ~2.4 vs the 5.0 bound — the ~2.4 scenario
        is Gamma=16 on a ring, see C_LR's comment)."""
        env = C_LR * EPS_BF16
        for drop, gamma, topology, seed in _scenarios(8, seed=13):
            lr32, lr16 = self._pair(drop, gamma, topology, seed, self.T)
            rel = float(np.max(np.abs(lr16 - lr32) / (np.abs(lr32) + 1.0)))
            assert rel <= env, (drop, gamma, topology, seed, rel, env)
            assert np.isfinite(lr16).all()


class TestHorizonCliff:
    """The envelopes above are horizon-limited BY CONSTRUCTION — assert
    the cliff exists so a future edit cannot quietly stretch the same
    constants over long trajectories."""

    def test_mass_envelope_fails_by_T200(self):
        """At T=200 at least one sampled scenario must blow through the
        T=32 mass envelope: once sigma_m is ~2^8x a round's mass
        increment, deliveries round to zero while senders keep halving
        their mass — the relay starves and z/m diverges. If this ever
        PASSES at T=200, the storage layout changed (e.g. the relay went
        back to fp32) and the budget models/statics contract must be
        revisited together with these constants."""
        env = C_MASS * EPS_BF16 * 32     # the short-horizon envelope
        worst = 0.0
        for drop, gamma, topology, seed in _scenarios(6, seed=7):
            _, r16, rt, w = _hps_pair(drop, gamma, topology, seed, T=200)
            worst = max(worst, _mass_rel_drift(r16, rt, w))
        assert worst > env, worst

    def test_fp32_policy_has_no_cliff(self):
        """The cliff is a bf16-storage property, not an engine property:
        the fp32 policy at T=200 keeps the invariant at roundoff."""
        drop, gamma, topology, seed = _scenarios(1, seed=7)[0]
        topo = make_hierarchy([5, 5, 5], topology=topology, seed=seed)
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=4, drop_prob=drop)
        w = (np.random.default_rng(seed)
             .normal(size=(topo.N, 3)).astype(np.float32))
        rt = make_hps_runtime(cfg)
        res = run_hps(w, cfg, T=200, seed=seed, store="gap", policy="fp32")
        assert _mass_rel_drift(res, rt, w) <= 1e-4
