"""Unified fault plane (repro.core.faults) tests.

Covers the tentpole contracts of the fault PR:

* the fault fold-in map is affine, host/traced-consistent, and provably
  disjoint from every engine's own PRNG streams (same base key);
* the degenerate FaultModel reproduces today's Bernoulli link draw
  bit-for-bit, and every engine's ``faults=None`` path is unchanged;
* churn conserves the push-sum mass invariant through leave/rejoin;
* PS crash at probability 1 is exactly the never-fuse engine;
* fault realizations are invariant to the graph-shard count;
* extreme faults (all edges dropped, all agents dead) keep z/m finite
  across (drop, topology) seeds — the satellite property tests;
* the sweep fault axis crosses scenarios fault-minor and degenerate
  fault rows match the no-fault sweep;
* the serving-tier retry policy (fake clock) and the bench ``# NEW``
  announcement — the infrastructure satellites.
"""
import random

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import attacks
from repro.core.byzantine import (
    ByzantineConfig,
    make_byzantine_scan,
    stream_fold as byz_stream_fold,
)
from repro.core.faults import (
    ENGINE_BYZANTINE,
    ENGINE_HPS,
    ENGINE_PUSHSUM,
    ENGINE_SOCIAL,
    FAULT_CHURN,
    FAULT_EDGE,
    FAULT_PS,
    N_ENGINES,
    N_FAULT_STREAMS,
    FaultState,
    edge_uniforms,
    fault_stream_fold,
    faulty_edge_mask,
    freeze,
    gilbert_elliott_model,
    init_fault_state,
    make_fault_model,
    step_faults,
)
from repro.core.graphs import (
    edge_list,
    make_hierarchy,
    partition_edge_list,
    random_strongly_connected,
)
from repro.core.hps import hps_stream_fold, run_hps
from repro.core.pushsum import (
    run_pushsum_sparse,
    sparse_mass_invariant,
    sparse_ratios,
    step_edge_mask,
)
from repro.core.signals import make_confused_model
from repro.core.social import (
    STREAM_LINK,
    STREAM_SIGNAL,
    make_social_runtime,
    run_social_runtime,
    social_stream_fold,
)
from repro.core.sweeps import run_pushsum_sweep
from repro.statics.streams import affine_disjoint, fit_affine

HPSConfig = pytest.importorskip("repro.core.hps").HPSConfig

HORIZON = 1 << 20


def _chaos(**kw):
    base = dict(p_gb=0.25, p_bg=0.5, drop_bad=0.9,
                leave_prob=0.05, join_prob=0.5, ps_crash_prob=0.3)
    base.update(kw)
    return make_fault_model(**base)


# ---------------------------------------------------------------------------
# Fold map: affine, host == traced, disjoint from every engine stream
# ---------------------------------------------------------------------------

class TestFoldMap:
    def test_host_matches_traced_mod_2_32(self):
        for e in range(N_ENGINES):
            for s in range(N_FAULT_STREAMS):
                host = np.uint32(np.int32(fault_stream_fold(17, e, s)))
                traced = jax.jit(
                    lambda t, _e=e, _s=s: fault_stream_fold(t, _e, _s)
                )(jnp.uint32(17))
                assert host == np.uint32(np.asarray(traced)), (e, s)

    def test_all_fault_streams_pairwise_disjoint(self):
        maps = [
            fit_affine(lambda t, _e=e, _s=s: fault_stream_fold(t, _e, _s),
                       f"fault[{e},{s}]")
            for e in range(N_ENGINES) for s in range(N_FAULT_STREAMS)
        ]
        for i, m1 in enumerate(maps):
            for m2 in maps[i + 1:]:
                ok, wit = affine_disjoint(m1, m2, HORIZON)
                assert ok, (m1.name, m2.name, wit)

    def test_disjoint_from_every_engine_stream(self):
        """The whole point of the negative 2^21-offset domain: fault draws
        never collide with pushsum t, social 2t+s, byzantine 3t+s, or the
        HPS ~t top-of-domain stream under one shared base key."""
        engine_maps = [
            fit_affine(lambda t: t, "pushsum.link"),
            fit_affine(lambda t: social_stream_fold(t, STREAM_LINK),
                       "social.link"),
            fit_affine(lambda t: social_stream_fold(t, STREAM_SIGNAL),
                       "social.signal"),
            fit_affine(lambda t: hps_stream_fold(t), "hps.link"),
        ] + [
            fit_affine(lambda t, _s=s: byz_stream_fold(t, _s), f"byz[{s}]")
            for s in range(3)
        ]
        fault_maps = [
            fit_affine(lambda t, _e=e, _s=s: fault_stream_fold(t, _e, _s),
                       f"fault[{e},{s}]")
            for e in range(N_ENGINES) for s in range(N_FAULT_STREAMS)
        ]
        for fm in fault_maps:
            for em in engine_maps:
                ok, wit = affine_disjoint(fm, em, HORIZON)
                assert ok, (fm.name, em.name, wit)

    def test_gilbert_elliott_parameterization(self):
        fm = gilbert_elliott_model(4.0, 0.2)
        assert np.isclose(float(fm.p_bg), 0.25)
        # stationary bad fraction p_gb / (p_gb + p_bg) == bad_frac
        p_gb, p_bg = float(fm.p_gb), float(fm.p_bg)
        assert np.isclose(p_gb / (p_gb + p_bg), 0.2)
        with pytest.raises(ValueError):
            gilbert_elliott_model(0.5, 0.2)
        with pytest.raises(ValueError):
            gilbert_elliott_model(4.0, 1.0)


# ---------------------------------------------------------------------------
# Degenerate model == today's Bernoulli draw, bit for bit
# ---------------------------------------------------------------------------

class TestDegenerateMask:
    def test_mask_bit_identical_to_step_edge_mask(self):
        key = jax.random.PRNGKey(7)
        E, N, B = 33, 9, 3
        rng = np.random.default_rng(0)
        src = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        fm0 = make_fault_model()
        fs0 = init_fault_state(N, E)
        for t in range(7):
            ref = step_edge_mask(key, jnp.uint32(t), E, 0.35, B)
            u = jax.random.uniform(
                jax.random.fold_in(key, jnp.uint32(t)), (E,))
            got = faulty_edge_mask(u, jnp.uint32(t), fm0, fs0, src, dst,
                                   0.35, B)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_bad_edges_exempt_from_forced_delivery(self):
        # a burst IS a B-window violation: at t % B == B-1 good edges are
        # forced up, bad edges still drop at drop_bad
        fm = make_fault_model(drop_bad=1.0)
        fs = FaultState(edge_bad=jnp.array([True, False]),
                        node_live=jnp.ones((2,), bool))
        u = jnp.array([0.5, 0.0])   # below any forced threshold
        src = jnp.array([0, 0], jnp.int32)
        dst = jnp.array([1, 1], jnp.int32)
        got = np.asarray(faulty_edge_mask(u, jnp.uint32(1), fm, fs, src,
                                          dst, 0.9, 2))
        assert not got[0]      # bad edge down despite the B-window
        assert got[1]          # good edge forced up

    def test_dead_endpoint_silences_edge(self):
        fm = make_fault_model()
        fs = FaultState(edge_bad=jnp.zeros((3,), bool),
                        node_live=jnp.array([True, False, True]))
        u = jnp.zeros((3,))
        src = jnp.array([0, 1, 2], jnp.int32)
        dst = jnp.array([2, 2, 1], jnp.int32)
        got = np.asarray(faulty_edge_mask(u, jnp.uint32(1), fm, fs, src,
                                          dst, 0.0, 2))
        np.testing.assert_array_equal(got, [True, False, False])


# ---------------------------------------------------------------------------
# Engine equivalences: faults=None untouched; degenerate model ~ no faults;
# ps_crash_prob=1 == never fuse
# ---------------------------------------------------------------------------

def _pushsum_setup(n=12, seed=0):
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, 0.3, rng)
    el = edge_list(adj)
    w = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    return el, w


class TestEngineDegenerate:
    def test_pushsum_degenerate_matches_no_faults(self):
        el, w = _pushsum_setup()
        kw = dict(T=25, drop_prob=0.3, B=3, key=jax.random.PRNGKey(1))
        st0, traj0 = run_pushsum_sparse(w, el.src, el.dst, **kw)
        st1, traj1 = run_pushsum_sparse(w, el.src, el.dst, **kw,
                                        faults=make_fault_model())
        np.testing.assert_allclose(np.asarray(traj0), np.asarray(traj1),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(st0.z), np.asarray(st1.z),
                                   atol=1e-5)

    def test_social_degenerate_matches_no_faults(self):
        topo = make_hierarchy([5, 5, 5], topology="ring", seed=1)
        model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.4,
                                    seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        rt = make_social_runtime(cfg)
        r0 = run_social_runtime(model, rt, M=3, T=40, store="log_ratio")
        r1 = run_social_runtime(model, rt, M=3, T=40, store="log_ratio",
                                faults=make_fault_model())
        np.testing.assert_allclose(np.asarray(r0.log_ratio),
                                   np.asarray(r1.log_ratio), atol=1e-4)
        np.testing.assert_allclose(np.asarray(r0.beliefs),
                                   np.asarray(r1.beliefs), atol=1e-5)

    def test_hps_degenerate_matches_no_faults(self):
        topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        w = np.random.default_rng(3).normal(size=(15, 2)).astype(np.float32)
        r0 = run_hps(w, cfg, T=30, seed=0, store="gap")
        r1 = run_hps(w, cfg, T=30, seed=0, store="gap",
                     faults=make_fault_model())
        np.testing.assert_allclose(np.asarray(r0.gap), np.asarray(r1.gap),
                                   atol=1e-5)

    def test_byzantine_degenerate_exact(self):
        topo = make_hierarchy([7] * 4, topology="complete", seed=0)
        model = make_confused_model(N=28, m=3, truth=0, confusion=0.3,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=4,
                              attack=attacks.large_value())
        key = jax.random.PRNGKey(3)
        r0 = make_byzantine_scan(model, cfg, T=12, store="final")(key)
        r1 = make_byzantine_scan(model, cfg, T=12, store="final",
                                 faults=make_fault_model())(key)
        np.testing.assert_array_equal(np.asarray(r0.r), np.asarray(r1.r))
        np.testing.assert_array_equal(np.asarray(r0.decisions),
                                      np.asarray(r1.decisions))

    def test_byzantine_dense_core_rejects_faults(self):
        topo = make_hierarchy([7] * 4, topology="complete", seed=0)
        model = make_confused_model(N=28, m=3, truth=0, confusion=0.3,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=4,
                              attack=attacks.large_value())
        with pytest.raises(ValueError, match="sparse"):
            make_byzantine_scan(model, cfg, T=4, core="dense",
                                faults=make_fault_model())

    def test_ps_crash_prob_one_is_never_fuse(self):
        """A permanently-dead PS degrades the hierarchy to pure local
        consensus — exactly the gamma_period -> infinity engine."""
        topo = make_hierarchy([5, 5, 5], topology="complete", seed=2)
        model = make_confused_model(N=15, m=3, truth=1, confusion=0.4,
                                    seed=0)
        crash = make_fault_model(ps_crash_prob=1.0)
        rt_g4 = make_social_runtime(
            HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3))
        rt_inf = make_social_runtime(
            HPSConfig(topo=topo, gamma_period=10 ** 6, B=2, drop_prob=0.3))
        r_crash = run_social_runtime(model, rt_g4, M=3, T=30,
                                     store="log_ratio", faults=crash)
        r_nofuse = run_social_runtime(model, rt_inf, M=3, T=30,
                                      store="log_ratio",
                                      faults=make_fault_model())
        np.testing.assert_array_equal(np.asarray(r_crash.log_ratio),
                                      np.asarray(r_nofuse.log_ratio))


# ---------------------------------------------------------------------------
# Churn: mass invariant through leave / rejoin; frozen state rejoins stale
# ---------------------------------------------------------------------------

class TestChurnMass:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_mass_invariant_under_churn(self, seed):
        el, w = _pushsum_setup(n=14, seed=seed)
        fm = _chaos(leave_prob=0.15, join_prob=0.4, ps_crash_prob=0.0)
        st, _ = run_pushsum_sparse(
            w, el.src, el.dst, T=40, drop_prob=0.2, B=2,
            key=jax.random.PRNGKey(seed), faults=fm)
        inv = np.asarray(sparse_mass_invariant(
            st, el.src, jnp.ones((el.E,), bool)))
        np.testing.assert_allclose(inv, np.asarray(w).sum(0),
                                   rtol=2e-3, atol=2e-3)

    def test_freeze_helper_shapes(self):
        live = jnp.array([True, False, True])
        new = jnp.arange(6.0).reshape(3, 2)
        old = -jnp.ones((3, 2))
        out = np.asarray(freeze(live, new, old))
        np.testing.assert_array_equal(out[1], [-1.0, -1.0])
        np.testing.assert_array_equal(out[0], [0.0, 1.0])
        out1 = np.asarray(freeze(live, jnp.arange(3.0), -jnp.ones((3,))))
        np.testing.assert_array_equal(out1, [0.0, -1.0, 2.0])

    def test_dead_agent_state_frozen_until_rejoin(self):
        """With leave_prob=1, join_prob=0 every agent dies after round 0;
        the state must stop evolving from round 1 on (stale, not zeroed)."""
        el, w = _pushsum_setup(n=10, seed=3)
        fm = make_fault_model(leave_prob=1.0, join_prob=0.0)
        kw = dict(drop_prob=0.0, B=1, key=jax.random.PRNGKey(0), faults=fm)
        st2, _ = run_pushsum_sparse(w, el.src, el.dst, T=2, **kw)
        st9, _ = run_pushsum_sparse(w, el.src, el.dst, T=9, **kw)
        np.testing.assert_array_equal(np.asarray(st2.z), np.asarray(st9.z))
        np.testing.assert_array_equal(np.asarray(st2.m), np.asarray(st9.m))


# ---------------------------------------------------------------------------
# Shard invariance: the fault realization is a function of (key, t) only
# ---------------------------------------------------------------------------

class TestShardInvariance:
    def test_edge_uniforms_windows_full_draw(self):
        key = jax.random.PRNGKey(11)
        e_shard, K = 16, 4
        full = np.asarray(edge_uniforms(key, 5, K * e_shard))

        def shard(_):
            return edge_uniforms(key, 5, e_shard, graph_axis="g",
                                 n_shards=K)

        windows = np.asarray(
            jax.vmap(shard, axis_name="g")(jnp.arange(K)))
        np.testing.assert_array_equal(windows.reshape(-1), full)

    def test_step_faults_shard_invariant(self):
        key = jax.random.PRNGKey(13)
        e_shard, K, N = 8, 3, 7
        fm = _chaos()
        fs_full = init_fault_state(N, K * e_shard)
        ref = step_faults(key, jnp.uint32(2), fm, fs_full,
                          engine=ENGINE_PUSHSUM)

        def shard(_):
            fs = init_fault_state(N, e_shard)
            return step_faults(key, jnp.uint32(2), fm, fs,
                               engine=ENGINE_PUSHSUM,
                               graph_axis="g", n_shards=K)

        got = jax.vmap(shard, axis_name="g")(jnp.arange(K))
        np.testing.assert_array_equal(
            np.asarray(got.edge_bad).reshape(-1), np.asarray(ref.edge_bad))
        # churn is replicated, never windowed
        for k in range(K):
            np.testing.assert_array_equal(np.asarray(got.node_live[k]),
                                          np.asarray(ref.node_live))

    def test_faulted_sweep_matches_on_padded_layout(self):
        """End to end: the 2-shard edge-partitioned faulted sweep equals
        the single-device sweep over the padded edge list exactly."""
        rng = np.random.default_rng(3)
        adj = random_strongly_connected(12, 0.3, rng)
        el = edge_list(adj)
        w = jnp.asarray(rng.normal(size=(12, 3)).astype(np.float32))
        fl = [gilbert_elliott_model(3.0, 0.3, leave_prob=0.05,
                                    join_prob=0.5)]
        sh = partition_edge_list(el, 2)
        pel = sh.padded_edge_list()
        r_plain = run_pushsum_sweep(w, pel, 20, drop_probs=0.2,
                                    seeds=[0, 1], B=3, faults=fl,
                                    dst_sorted=True)
        r_shard = run_pushsum_sweep(w, sh, 20, drop_probs=0.2,
                                    seeds=[0, 1], B=3, faults=fl)
        np.testing.assert_array_equal(np.asarray(r_plain.err),
                                      np.asarray(r_shard.err))


# ---------------------------------------------------------------------------
# Satellite: extreme-fault finiteness across (drop, topology) seeds
# ---------------------------------------------------------------------------

EXTREME_MODELS = {
    "all_edges_dropped": make_fault_model(p_gb=1.0, p_bg=0.0,
                                          drop_bad=1.0),
    "all_agents_dead": make_fault_model(leave_prob=1.0, join_prob=0.0),
}


class TestExtremeFaultsFinite:
    @pytest.mark.parametrize("fault_name", sorted(EXTREME_MODELS))
    @pytest.mark.parametrize("drop,seed", [(0.0, 0), (0.5, 1), (0.9, 2)])
    def test_pushsum_finite(self, fault_name, drop, seed):
        el, w = _pushsum_setup(n=11, seed=seed)
        st, traj = run_pushsum_sparse(
            w, el.src, el.dst, T=15, drop_prob=drop, B=2,
            key=jax.random.PRNGKey(seed), faults=EXTREME_MODELS[fault_name])
        for arr in (st.z, st.m, traj, sparse_ratios(st)):
            assert np.isfinite(np.asarray(arr)).all(), fault_name

    @pytest.mark.parametrize("fault_name", sorted(EXTREME_MODELS))
    @pytest.mark.parametrize("topology,seed", [("ring", 0),
                                               ("complete", 1)])
    def test_social_finite(self, fault_name, topology, seed):
        topo = make_hierarchy([5, 5, 5], topology=topology, seed=seed)
        model = make_confused_model(N=15, m=3, truth=0, confusion=0.5,
                                    seed=seed)
        cfg = HPSConfig(topo=topo, gamma_period=3, B=2, drop_prob=0.4)
        rt = make_social_runtime(cfg)
        res = run_social_runtime(model, rt, M=3, T=20, store="log_ratio",
                                 faults=EXTREME_MODELS[fault_name])
        assert np.isfinite(np.asarray(res.beliefs)).all(), fault_name
        assert np.isfinite(np.asarray(res.log_ratio)).all(), fault_name

    @pytest.mark.parametrize("fault_name", sorted(EXTREME_MODELS))
    def test_hps_finite(self, fault_name):
        topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        w = np.random.default_rng(1).normal(size=(15, 2)).astype(np.float32)
        res = run_hps(w, cfg, T=20, seed=0, store="gap",
                      faults=EXTREME_MODELS[fault_name])
        assert np.isfinite(np.asarray(res.ratio)).all(), fault_name
        assert np.isfinite(np.asarray(res.gap)).all(), fault_name

    @pytest.mark.parametrize("fault_name", sorted(EXTREME_MODELS))
    def test_byzantine_finite(self, fault_name):
        topo = make_hierarchy([7] * 4, topology="complete", seed=0)
        model = make_confused_model(N=28, m=3, truth=0, confusion=0.3,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=4,
                              attack=attacks.large_value())
        run = make_byzantine_scan(model, cfg, T=10, store="final",
                                  faults=EXTREME_MODELS[fault_name])
        res = run(jax.random.PRNGKey(0))
        assert np.isfinite(np.asarray(res.r)).all(), fault_name


# ---------------------------------------------------------------------------
# Sweep fault axis
# ---------------------------------------------------------------------------

class TestSweepFaultAxis:
    def test_fault_axis_crosses_fault_minor(self):
        el, w = _pushsum_setup(n=10, seed=5)
        fl = [make_fault_model(),
              gilbert_elliott_model(4.0, 0.4, leave_prob=0.1,
                                    join_prob=0.5)]
        base = run_pushsum_sweep(w, el, 15, drop_probs=[0.1, 0.5],
                                 seeds=[0, 1], B=2)
        res = run_pushsum_sweep(w, el, 15, drop_probs=[0.1, 0.5],
                                seeds=[0, 1], B=2, faults=fl)
        k = base.err.shape[0]
        assert res.err.shape[0] == k * 2
        np.testing.assert_array_equal(np.asarray(res.fault),
                                      np.tile([0, 1], k))
        # fault index 0 is the degenerate model: those rows ~ the base run
        np.testing.assert_allclose(np.asarray(res.err[0::2]),
                                   np.asarray(base.err), atol=1e-5)
        # the bursty model actually changes the outcome somewhere
        assert not np.allclose(np.asarray(res.err[1::2]),
                               np.asarray(base.err), atol=1e-6)
        assert np.isfinite(np.asarray(res.err)).all()

    def test_no_faults_result_has_none_fault_field(self):
        el, w = _pushsum_setup(n=10, seed=5)
        res = run_pushsum_sweep(w, el, 8, drop_probs=0.2, seeds=[0], B=2)
        assert res.fault is None


# ---------------------------------------------------------------------------
# Satellite: serving-tier retry policy under a fake clock
# ---------------------------------------------------------------------------

class TestRetryPolicy:
    def _fixture(self):
        from repro.distributed.server import (
            RequestTimeout,
            RetriesExhausted,
            RetryPolicy,
            call_with_retry,
        )
        return RequestTimeout, RetriesExhausted, RetryPolicy, call_with_retry

    def test_success_first_try_no_sleep(self):
        *_, call = self._fixture()
        sleeps = []
        out = call(lambda: 42, clock=lambda: 0.0, sleep=sleeps.append)
        assert out == 42 and sleeps == []

    def test_backoff_schedule_jittered_and_bounded(self):
        _, exhausted, policy_cls, call = self._fixture()
        pol = policy_cls(max_attempts=4, timeout=None, base_delay=0.1,
                         backoff=2.0, max_delay=0.3, jitter=0.5)
        sleeps = []
        with pytest.raises(exhausted):
            call(lambda: 1 / 0, pol, clock=lambda: 0.0,
                 sleep=sleeps.append, rng=random.Random(0))
        # 3 backoffs for 4 attempts; nominal 0.1, 0.2, min(0.4, cap=0.3)
        assert len(sleeps) == 3
        for s, nominal in zip(sleeps, [0.1, 0.2, 0.3]):
            assert 0.5 * nominal <= s <= 1.5 * nominal

    def test_exhausted_carries_cause(self):
        _, exhausted, policy_cls, call = self._fixture()
        with pytest.raises(exhausted) as ei:
            call(lambda: 1 / 0, policy_cls(max_attempts=2),
                 clock=lambda: 0.0, sleep=lambda _ : None)
        assert isinstance(ei.value.__cause__, ZeroDivisionError)

    def test_timeout_counts_as_failure_fake_clock(self):
        timeout_exc, exhausted, policy_cls, call = self._fixture()
        t = [0.0]

        def clock():
            return t[0]

        def slow_then_fast():
            # attempt 0 burns 5 fake seconds; attempt 1 is instant
            if not hasattr(slow_then_fast, "done"):
                slow_then_fast.done = True
                t[0] += 5.0
            return "ok"

        retries = []
        out = call(slow_then_fast,
                   policy_cls(max_attempts=2, timeout=1.0, base_delay=0.0),
                   clock=clock, sleep=lambda _: None,
                   on_retry=lambda a, e: retries.append((a, type(e))))
        assert out == "ok"
        assert retries == [(0, timeout_exc)]

    def test_eventually_succeeds(self):
        _, _, policy_cls, call = self._fixture()
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "done"

        assert call(flaky, policy_cls(max_attempts=3, timeout=None),
                    clock=lambda: 0.0, sleep=lambda _: None) == "done"

    def test_policy_validation(self):
        _, _, policy_cls, _ = self._fixture()
        with pytest.raises(ValueError):
            policy_cls(max_attempts=0)
        with pytest.raises(ValueError):
            policy_cls(jitter=1.5)


# ---------------------------------------------------------------------------
# Satellite: bench --check announces rows with no baseline as # NEW
# ---------------------------------------------------------------------------

class TestBenchCheckNewRows:
    def test_new_rows_announced_not_gated(self, capsys):
        import sys
        from pathlib import Path
        root = Path(__file__).resolve().parents[1]
        if str(root) not in sys.path:
            sys.path.insert(0, str(root))
        from benchmarks.run import _check_regressions

        bad = _check_regressions(
            "b.json", {"old": {"us_per_call": 1.0}},
            {"old": (1.1, ""), "burst_row": (9e9, "faults=ge")})
        assert bad == 0
        out = capsys.readouterr().out
        assert "# NEW burst_row" in out
        assert "no baseline row" in out
