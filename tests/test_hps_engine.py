"""Fused Algorithm 1 engine + shared PS fusion + batched HPS sweeps.

The contract under test: the fused scan core is bit-identical to a
pre-refactor-style sparse replay (same edge core, no invariant hoisting,
host-precomputed fusion schedule) and matches the kept dense (N, N)
reference to fp reduction order on the IDENTICAL in-scan mask stream;
``hps_fusion`` and ``byzantine._fusion`` reduce through one
``ps_trimmed_pool`` lowering (F=0 masked mean, F>0 trimmed rep pool);
``store="gap"|"final"`` materializes no (N, N) or (T, N, d) value (jaxpr
inspection); the HPS link-mask stream lives on the dedicated ``~t`` fold-in
domain, disjoint from the social and Byzantine stream domains (the seed
scheme would have aliased the HPS schedule with the social link masks at
equal seeds); the empirical Theorem-1 ``store="gap"`` curve is dominated by
the ``theorem1_bound`` envelope across a (Γ, drop, B) grid; a
(topology x M x Γ x drop x seed) grid of >= 48 scenarios — sub-network
count M traced per scenario — runs as ONE compiled program; and the
compiled-sweep cache is LRU-bounded.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.byzantine import N_STREAMS as BYZ_STREAMS, stream_fold
from repro.core.graphs import (
    hier_edge_list,
    is_strongly_connected,
    make_hierarchy,
)
from repro.core.hps import (
    HPS_STORES,
    HPSConfig,
    hps_fusion,
    hps_runtime_from_edge_list,
    hps_stream_fold,
    make_hps_runtime,
    ps_trimmed_pool,
    run_hps,
    run_hps_dense,
    run_hps_runtime,
    theorem1_bound,
)
from repro.core.pushsum import (
    init_sparse_state,
    sparse_pushsum_step,
    sparse_ratios,
    step_edge_mask,
)
from repro.core.social import (
    N_SOCIAL_STREAMS,
    STREAM_LINK,
    STREAM_SIGNAL,
    social_stream_fold,
)
from repro.core.sweeps import run_hps_grid, run_hps_sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(sizes=(5, 6, 4), seed=2, d=2, topology="complete"):
    topo = make_hierarchy(list(sizes), topology=topology, seed=seed)
    w = np.random.default_rng(1).normal(size=(topo.N, d)).astype(np.float32)
    return topo, w


# ---------------------------------------------------------------------------
# PS-side fusion: the shared masked-pool reduction
# ---------------------------------------------------------------------------

class TestPSTrimmedPool:
    @pytest.mark.parametrize("R,coord,F", [
        (7, (3,), 0), (7, (3,), 1), (9, (2, 2), 2), (5, (4,), 1),
    ])
    def test_matches_numpy_sort_trim(self, R, coord, F):
        rng = np.random.default_rng(R + F)
        pool = rng.normal(size=(R,) + coord).astype(np.float32)
        valid = rng.random(R) < 0.8
        valid[:max(2 * F + 1, 1)] = True            # keep the pool non-empty
        got = np.asarray(ps_trimmed_pool(
            jnp.asarray(pool), jnp.asarray(valid), F
        ))
        flat = pool.reshape(R, -1)
        want = np.empty(flat.shape[1], np.float32)
        for p in range(flat.shape[1]):
            vals = np.sort(flat[valid, p])
            kept = vals[F: len(vals) - F] if F > 0 else vals
            want[p] = kept.sum() / max(len(kept), 1)
        np.testing.assert_allclose(got.reshape(-1), want, rtol=1e-6,
                                   atol=1e-7)

    def test_traced_F_matches_static(self):
        """The sort-based lowering accepts a traced F — what lets batched
        grids put the trim count on a vmap scenario axis."""
        rng = np.random.default_rng(0)
        pool = jnp.asarray(rng.normal(size=(9, 4)).astype(np.float32))
        valid = jnp.ones(9, bool)
        static = ps_trimmed_pool(pool, valid, 2)
        traced = jax.jit(ps_trimmed_pool)(pool, valid, jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))

    def test_byzantine_fusion_reduces_through_it(self):
        """Regression for the rewire: Algorithm 2's PS rule (sort, drop F
        from each end, average the rest) must equal the seed lowering it
        replaced, bit for bit."""
        rng = np.random.default_rng(3)
        n_reps, F = 7, 2
        rep_vals = jnp.asarray(rng.normal(size=(n_reps, 3, 3))
                               .astype(np.float32))
        # the seed-era lowering, verbatim
        s = jnp.sort(rep_vals, axis=0)
        ar = jnp.arange(n_reps)
        keep = (ar >= F) & (ar < n_reps - F)
        want = (s * keep[:, None, None]).sum(0) / keep.sum()
        got = ps_trimmed_pool(rep_vals, jnp.ones(n_reps, bool), F)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestHPSFusion:
    def test_f0_is_doubly_stochastic(self):
        """Algorithm 1's fusion matrix preserves total mass and leaves
        non-representatives untouched."""
        topo, w = _setup()
        z = jnp.asarray(w)
        m = jnp.asarray(np.random.default_rng(0).uniform(
            0.5, 2.0, topo.N).astype(np.float32))
        rep = jnp.asarray(topo.rep_mask())
        z_f, m_f = hps_fusion(z, m, rep, topo.M)
        np.testing.assert_allclose(float(m_f.sum()), float(m.sum()),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(z_f.sum(0)),
                                   np.asarray(z.sum(0)), rtol=1e-5)
        nr = ~np.asarray(rep)
        np.testing.assert_array_equal(np.asarray(z_f)[nr],
                                      np.asarray(z)[nr])

    def test_f_positive_is_trimmed_rep_mean(self):
        """F>0 swaps the plain average for the trimmed rep-pool mean: the
        rep update must equal 0.5 z_rep + 0.5 * trimmed_mean(pool)."""
        topo, w = _setup(sizes=(3, 3, 3, 3, 3), seed=0, d=1)
        z = jnp.asarray(w)
        m = jnp.ones(topo.N, jnp.float32)
        rep = jnp.asarray(topo.rep_mask())
        z_f, m_f = hps_fusion(z, m, rep, topo.M, F=1)
        reps = np.nonzero(np.asarray(rep))[0]
        pool = np.sort(np.asarray(w)[reps, 0])
        tmean = pool[1:-1].mean()
        for r in reps:
            np.testing.assert_allclose(
                float(z_f[r, 0]), 0.5 * w[r, 0] + 0.5 * tmean, rtol=1e-5
            )
        # trimming the (identical) masses keeps them at 1
        np.testing.assert_allclose(np.asarray(m_f), 1.0, rtol=1e-6)

    def test_trimmed_engine_still_reaches_consensus(self):
        """The resilient rule trades the exact average for outlier
        rejection: agents must still AGREE (inter-agent spread -> 0) even
        though the common value may be biased away from mean(w)."""
        topo, w = _setup(sizes=(6, 6, 6), seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.1)
        res = run_hps(w, cfg, 2000, seed=1, store="gap", F=1)
        gap = np.asarray(res.gap)
        assert np.isfinite(gap).all()
        assert gap[-1] < 0.25 * gap[0]       # error vs mean(w) still shrinks
        final = np.asarray(res.ratio)        # (N, d) — and agents agree,
        spread = (final.max(axis=0) - final.min(axis=0)).max()
        assert spread < 0.005, spread        # though biased off mean(w)


# ---------------------------------------------------------------------------
# Engine equivalence: sparse oracle (bit-exact) + dense reference
# ---------------------------------------------------------------------------

def _sparse_oracle(w, cfg, T, seed):
    """The pre-refactor scan structure on the sparse core: per-step share
    recomputation (no invariant hoisting) and in-body fusion gating —
    modulo only the satellite-mandated PRNG-domain fix. The per-scenario
    scalars (drop, B, Γ, M) and the rep mask ride as traced jit ARGUMENTS,
    matching the engine's HPSRuntime calling convention: baking them in as
    Python constants lets XLA constant-fold the mask comparison and refuse
    different FMA contractions, which perturbs the trajectory at 1 ulp —
    with the argument structure aligned the fused engine must reproduce
    this oracle bit for bit."""
    el = cfg.edge_index()
    src, dst = jnp.asarray(el.src), jnp.asarray(el.dst)
    valid = jnp.asarray(el.valid)

    def run(key, w_in, drop, B, gamma, M, rep_mask):
        state0 = init_sparse_state(w_in, el.E)

        def body(state, t):
            mask = step_edge_mask(
                key, t, el.E, drop, B, fold_t=hps_stream_fold(t)
            )
            st = sparse_pushsum_step(state, mask, src, dst, valid, "xla")
            z_f, m_f = hps_fusion(st.z, st.m, rep_mask, M)
            do_fusion = (t + 1) % gamma == 0
            st = st._replace(
                z=jnp.where(do_fusion, z_f, st.z),
                m=jnp.where(do_fusion, m_f, st.m),
            )
            return st, sparse_ratios(st)

        _, traj = jax.lax.scan(body, state0, jnp.arange(T, dtype=jnp.int32))
        return traj

    return jax.jit(run)(
        jax.random.PRNGKey(seed), jnp.asarray(w),
        jnp.float32(cfg.drop_prob), jnp.int32(cfg.B),
        jnp.int32(cfg.gamma_period), jnp.int32(cfg.topo.M),
        cfg.rep_mask(),
    )


class TestEngineEquivalence:
    """Acceptance: fused engine == pre-refactor sparse oracle, bit for bit."""

    @pytest.mark.parametrize("drop,gamma,B", [(0.0, 4, 1), (0.3, 8, 2),
                                              (0.6, 3, 4)])
    def test_fused_engine_matches_sparse_oracle(self, drop, gamma, B):
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=B, drop_prob=drop)
        traj = _sparse_oracle(w, cfg, T=40, seed=3)
        res = run_hps(w, cfg, T=40, seed=3, backend="xla")
        np.testing.assert_array_equal(np.asarray(res.ratio),
                                      np.asarray(traj))

    def test_dense_reference_matches_runtime_core(self):
        """The kept (N, N) dense reference consumes the IDENTICAL in-scan
        (E,) mask stream at matched seeds; trajectories agree to fp
        reduction order — the dense axis-0 delivery reduce and the sparse
        segment-sum associate differently, so this is the established
        dense<->sparse tolerance (test_pushsum_sparse), not bit-identity;
        the bit-exact contract is the sparse-oracle test above."""
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        _, traj_d = run_hps_dense(w, cfg, T=120, seed=3)
        res = run_hps(w, cfg, T=120, seed=3, backend="xla")
        np.testing.assert_allclose(np.asarray(res.ratio),
                                   np.asarray(traj_d),
                                   rtol=1e-4, atol=1e-5)

    def test_pallas_backend_matches_xla(self):
        """interpret-mode fused consensus kernel == XLA lowering over a
        full run (same traced program that compiles on TPU)."""
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        x = run_hps(w, cfg, T=50, seed=0, backend="xla")
        p = run_hps(w, cfg, T=50, seed=0, backend="pallas")
        np.testing.assert_allclose(np.asarray(p.ratio),
                                   np.asarray(x.ratio),
                                   rtol=1e-4, atol=1e-5)

    def test_dense_free_runtime_matches_config_path(self):
        """hier_edge_list + run_hps_runtime (the N ~ 1e4 path that never
        builds an (N, N) adjacency) == the HPSConfig path, bit for bit."""
        topo, w = _setup(sizes=(6, 6, 6))
        el, rep_mask = hier_edge_list([6, 6, 6], topology="complete")
        rt = hps_runtime_from_edge_list(el, rep_mask, drop_prob=0.3,
                                        gamma_period=8, B=2)
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        a = run_hps_runtime(w, rt, T=40, seed=5)
        b = run_hps(w, cfg, T=40, seed=5)
        np.testing.assert_array_equal(np.asarray(a.ratio),
                                      np.asarray(b.ratio))

    def test_store_shapes_and_consistency(self):
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        N, d, T = topo.N, w.shape[1], 60
        traj = run_hps(w, cfg, T=T, seed=0)
        gapr = run_hps(w, cfg, T=T, seed=0, store="gap")
        fin = run_hps(w, cfg, T=T, seed=0, store="final")
        assert traj.ratio.shape == (T, N, d) and traj.gap.shape == (T,)
        assert gapr.ratio.shape == (N, d) and gapr.gap.shape == (T,)
        assert fin.ratio.shape == (N, d) and fin.gap.shape == ()
        r = np.asarray(traj.ratio)
        np.testing.assert_array_equal(np.asarray(gapr.ratio), r[-1])
        np.testing.assert_array_equal(np.asarray(fin.ratio), r[-1])
        # the three stores are distinct XLA programs; the ratio division
        # fuses into the error reduction differently, so the gap curves
        # agree to 1 ulp, not bitwise
        np.testing.assert_allclose(np.asarray(gapr.gap),
                                   np.asarray(traj.gap),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(fin.gap), float(traj.gap[-1]),
                                   rtol=1e-6, atol=1e-7)

    def test_invalid_store_rejected(self):
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        with pytest.raises(ValueError, match="store"):
            run_hps(w, cfg, T=5, store="everything")
        assert HPS_STORES == ("trajectory", "gap", "final")


# ---------------------------------------------------------------------------
# No dense / trajectory intermediates in the sparse path
# ---------------------------------------------------------------------------

# The jaxpr walker these tests introduced now lives in repro.statics.walk
# (PR 6); imported under the historical names so the assertions below stay
# bit-for-bit what they were when the helpers were local.
from repro.statics.walk import collect_avals as _collect_avals  # noqa: E402
from repro.statics.walk import subjaxprs as _subjaxprs  # noqa: E402,F401


class TestNoDenseIntermediates:
    """Acceptance: store="gap"|"final" holds no (N, N) or (T, N, d) value."""

    T = 37   # distinct from N=15, d=2, E=62 so the walker cannot confuse axes

    def _shapes(self, store):
        from repro.core.hps import _hps_scan_core

        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        rt = make_hps_runtime(cfg)

        def run(key):
            return _hps_scan_core(
                key, rt, jnp.asarray(w),
                T=self.T, store=store, backend="xla",
            )

        shapes = _collect_avals(
            jax.make_jaxpr(run)(jax.random.PRNGKey(0)).jaxpr, []
        )
        assert shapes, "jaxpr walker found no values"
        return shapes, topo.N

    @pytest.mark.parametrize("store", ["gap", "final"])
    def test_no_dense_or_trajectory_value(self, store):
        shapes, N = self._shapes(store)
        dense = [s for s in shapes
                 if len(s) >= 2 and s[0] == N and s[1] == N]
        assert not dense, f"(N, N, ...) intermediates: {dense}"
        traj = [s for s in shapes if len(s) >= 2 and s[0] == self.T]
        assert not traj, f"(T, ...) intermediates: {traj}"
        if store == "gap":
            assert (self.T,) in shapes      # the in-scan-reduced curve

    def test_detector_flags_trajectory_store(self):
        """Sanity: the same walker does find the (T, N, d) history in the
        trajectory store, so the assertions above have teeth."""
        shapes, N = self._shapes("trajectory")
        assert (self.T, N, 2) in shapes


# ---------------------------------------------------------------------------
# PRNG stream domains
# ---------------------------------------------------------------------------

class TestPRNGStreams:
    def test_hps_domain_disjoint_from_social_and_byzantine(self):
        """The HPS link-mask stream folds ``~t`` — the top of the uint32
        domain — so it can never collide with the social engine's
        ``2t + s`` or the Byzantine engine's ``3t + s`` streams at any
        realistic horizon, even with every base key rooted at one seed."""
        T = 20000
        t = np.arange(T, dtype=np.int32)
        hps = set(np.asarray(hps_stream_fold(t)).astype(np.uint32).tolist())
        social = set()
        for s in (STREAM_LINK, STREAM_SIGNAL):
            social |= set(np.asarray(
                social_stream_fold(t, s)).astype(np.uint32).tolist())
        byz = set()
        for s in range(BYZ_STREAMS):
            byz |= set(np.asarray(
                stream_fold(t, s)).astype(np.uint32).tolist())
        assert len(hps) == T                 # injective over the horizon
        assert not (hps & social)
        assert not (hps & byz)
        assert N_SOCIAL_STREAMS == 2 and BYZ_STREAMS == 3

    def test_seed_scheme_would_have_aliased(self):
        """The bug being regressed: the seed-era ``run_hps`` derived its
        schedule from ``seed`` alone (plain ``t`` domain), so at equal
        seeds the HPS mask key at t = 2k EQUALED the social link-mask key
        at iteration k. The dedicated domain breaks the collision."""
        k = jax.random.PRNGKey(7)
        t = 6
        old_hps = jax.random.fold_in(k, t)    # seed scheme: fold plain t
        social = jax.random.fold_in(
            k, social_stream_fold(t // 2, STREAM_LINK)
        )
        np.testing.assert_array_equal(np.asarray(old_hps),
                                      np.asarray(social))   # the alias
        fixed = jax.random.fold_in(k, hps_stream_fold(t))
        assert (np.asarray(fixed) != np.asarray(social)).any()

    def test_seed_drives_masks(self):
        topo, w = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.5)
        a = run_hps(w, cfg, T=60, seed=0, store="gap")
        b = run_hps(w, cfg, T=60, seed=1, store="gap")
        assert (np.asarray(a.gap) != np.asarray(b.gap)).any()
        assert np.isfinite(np.asarray(a.gap)).all()


# ---------------------------------------------------------------------------
# Dense-free hierarchical edge-list builder
# ---------------------------------------------------------------------------

class TestHierEdgeList:
    def test_complete_matches_make_hierarchy(self):
        topo = make_hierarchy([4, 5, 3], topology="complete")
        el, rep = hier_edge_list([4, 5, 3], topology="complete")
        np.testing.assert_array_equal(el.to_dense(), topo.adj)
        np.testing.assert_array_equal(rep, topo.rep_mask())

    @pytest.mark.parametrize("topology", ["ring", "complete", "ring+"])
    def test_blocks_are_strongly_connected_and_block_diagonal(self, topology):
        sizes = [6, 5, 7]
        el, rep = hier_edge_list(sizes, topology=topology, seed=3)
        adj = el.to_dense()
        assert not adj.diagonal().any()
        off = 0
        for sz in sizes:
            block = adj[off:off + sz, off:off + sz]
            assert is_strongly_connected(block)
            # no cross-network edges
            assert adj[off:off + sz].sum() == block.sum()
            off += sz
        assert rep.sum() == len(sizes)
        # dst-sorted layout (the Pallas consensus contract)
        assert (np.diff(el.dst) >= 0).all()

    def test_rep_choice_random_stays_in_block(self):
        sizes = [5, 5, 5]
        _, rep = hier_edge_list(sizes, topology="ring", seed=7,
                                rep_choice="random")
        reps = np.nonzero(rep)[0]
        assert len(reps) == 3
        assert all(5 * i <= r < 5 * (i + 1) for i, r in enumerate(reps))

    def test_unknown_topology_rejected(self):
        with pytest.raises(ValueError, match="topology"):
            hier_edge_list([4, 4], topology="torus")


# ---------------------------------------------------------------------------
# Theorem 1: empirical gap curves under the analytical envelope
# ---------------------------------------------------------------------------

class TestTheorem1Bound:
    def test_gap_curve_dominated_by_envelope_across_grid(self):
        """Property-style acceptance: over a (Γ, drop, B) grid, every
        scenario's in-scan ``store="gap"`` curve must sit below the
        Theorem-1 RHS at every iteration (the bound is loose by Remark 3,
        so domination is strict in practice)."""
        topo = make_hierarchy([4, 4], topology="complete", seed=5)
        w = np.random.default_rng(3).normal(size=(topo.N, 2)).astype(np.float32)
        cfgs = [
            HPSConfig(topo=topo, gamma_period=g, B=b, drop_prob=dp)
            for g in (2, 4) for dp in (0.0, 0.3) for b in (1, 2)
        ]
        res = run_hps_grid(w, cfgs, T=300, seeds=[0, 1], store="gap")
        assert res.K == len(cfgs) * 2
        for k in range(res.K):
            cfg = cfgs[int(res.cfg[k])]
            gap = np.asarray(res.gap[k])
            bound = np.asarray([theorem1_bound(cfg, w, t)
                                for t in range(300)])
            assert (gap <= bound + 1e-6).all(), (
                f"cfg={cfg.gamma_period, cfg.B, cfg.drop_prob} "
                f"seed={int(res.seed[k])}: worst excess "
                f"{(gap - bound).max():.2e}"
            )


# ---------------------------------------------------------------------------
# Batched (topology x M x Γ x drop) x seed sweeps
# ---------------------------------------------------------------------------

def _grid_fixture():
    """4 hierarchies over N=18 with DIFFERENT sub-network counts
    (M in {3, 2, 6}) x 2 Γ x 2 drop = 16 configs; x 3 seeds = 48."""
    topos = [
        make_hierarchy([6, 6, 6], topology="complete", seed=0),
        make_hierarchy([6, 6, 6], topology="ring+", extra_edge_prob=0.8,
                       seed=1),
        make_hierarchy([9, 9], topology="complete", seed=2),
        make_hierarchy([3] * 6, topology="complete", seed=3),
    ]
    cfgs = [
        HPSConfig(topo=t, gamma_period=g, B=2, drop_prob=d)
        for t in topos for g in (4, 8) for d in (0.0, 0.3)
    ]
    w = np.random.default_rng(0).normal(size=(18, 3)).astype(np.float32)
    return w, cfgs


class TestHPSSweep:
    def test_topo_M_gamma_drop_seed_grid_single_trace(self):
        """Acceptance: 4 topologies (M in {3, 2, 6}) x 2 Γ x 2 drop x 3
        seeds = 48 scenarios as ONE compiled program — one jit cache entry,
        no retrace on a second seed batch, M traced per scenario."""
        from repro.core.sweeps import _hps_sweep_fn, cache_registry

        w, cfgs = _grid_fixture()
        res = run_hps_grid(w, cfgs, T=25, seeds=list(range(3)))
        assert res.K == 48
        assert res.gap.shape == (48, 25)
        assert res.ratio.shape == (48, 18, 3)
        assert set(np.asarray(res.M).tolist()) == {2, 3, 6}
        fn = _hps_sweep_fn(None, "data", T=25, store="gap", backend="xla")
        assert fn._cache_size() == 1
        res2 = run_hps_grid(w, cfgs, T=25, seeds=list(range(3, 6)))
        assert fn._cache_size() == 1         # same shapes -> no retrace
        assert res2.K == 48
        info = cache_registry()["hps.compiled"].cache_info()
        assert info.currsize <= info.maxsize

    def test_uniform_E_grid_matches_single_runs_bit_identical(self):
        """Traced (drop, Γ, M) on the vmap axis must reproduce each
        config's single run bit for bit (single topology -> no edge
        padding -> identical link-mask streams)."""
        topo, w = _setup(sizes=(6, 6, 6))
        cfgs = [HPSConfig(topo=topo, gamma_period=g, B=2, drop_prob=d)
                for d in (0.0, 0.4, 0.8) for g in (3, 8)]
        res = run_hps_grid(w, cfgs, T=30, seeds=[0, 3])
        for k in range(res.K):
            ci, sd = int(res.cfg[k]), int(res.seed[k])
            single = run_hps(w, cfgs[ci], T=30, seed=sd, backend="xla",
                             store="gap")
            np.testing.assert_array_equal(np.asarray(res.gap[k]),
                                          np.asarray(single.gap))
            np.testing.assert_array_equal(np.asarray(res.ratio[k]),
                                          np.asarray(single.ratio))
            assert np.float32(res.drop_prob[k]) == np.float32(
                cfgs[ci].drop_prob)
            assert int(res.gamma[k]) == cfgs[ci].gamma_period
            assert int(res.M[k]) == cfgs[ci].topo.M

    def test_mixed_E_grid_matches_padded_runtimes(self):
        """Topology draws with different edge counts pad to a common E —
        which re-indexes the (E,) link-mask draw, so the contract is
        bit-identity against the single run on the SAME padded runtime."""
        w, cfgs = _grid_fixture()
        e_all = {int(np.count_nonzero(c.topo.adj)) for c in cfgs}
        assert len(e_all) > 1, "fixture must exercise heterogeneous E"
        e_max = max(e_all)
        res = run_hps_grid(w, cfgs, T=25, seeds=[1])
        for k in range(0, res.K, 5):
            ci, sd = int(res.cfg[k]), int(res.seed[k])
            rt = make_hps_runtime(cfgs[ci], e_max=e_max)
            single = run_hps_runtime(w, rt, T=25, seed=sd, backend="xla",
                                     store="gap")
            np.testing.assert_array_equal(np.asarray(res.gap[k]),
                                          np.asarray(single.gap))
            np.testing.assert_array_equal(np.asarray(res.ratio[k]),
                                          np.asarray(single.ratio))

    def test_sweep_cross_product_coordinates(self):
        topo, w = _setup(sizes=(6, 6, 6))
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.0)
        res = run_hps_sweep(w, cfg, T=10, drop_probs=[0.0, 0.5],
                            gammas=[2, 8], seeds=[0, 1, 2])
        assert res.K == 12
        coords = {(float(res.drop_prob[k]), int(res.gamma[k]),
                   int(res.seed[k])) for k in range(res.K)}
        assert coords == {(d, g, s) for d in (0.0, 0.5) for g in (2, 8)
                          for s in (0, 1, 2)}

    def test_trajectory_store_sweep(self):
        topo, w = _setup(sizes=(6, 6, 6))
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        res = run_hps_sweep(w, cfg, T=15, seeds=[0, 1], store="trajectory")
        assert res.ratio.shape == (2, 15, 18, 2)
        single = run_hps(w, cfg, T=15, seed=1)
        np.testing.assert_array_equal(np.asarray(res.ratio[1]),
                                      np.asarray(single.ratio))

    def test_incompatible_configs_rejected(self):
        w, cfgs = _grid_fixture()
        other = make_hierarchy([5, 5, 5], topology="complete")
        bad = HPSConfig(topo=other, gamma_period=4, B=2, drop_prob=0.0)
        with pytest.raises(ValueError, match="share"):
            run_hps_grid(w, [cfgs[0], bad], T=5, seeds=[0])
        with pytest.raises(ValueError, match="store"):
            run_hps_grid(w, [cfgs[0]], T=5, seeds=[0], store="bogus")
        with pytest.raises(ValueError, match="at least one"):
            run_hps_grid(w, [], T=5, seeds=[0])

    def test_compiled_cache_is_lru_bounded(self):
        from repro.core.sweeps import cache_registry

        reg = cache_registry()
        compiled = reg["hps.compiled"].cache_info()
        runtime = reg["hps.runtime"].cache_info()
        assert 0 < compiled.maxsize <= 64
        assert 0 < runtime.maxsize <= 64
        assert compiled.currsize <= compiled.maxsize

    def test_sharded_sweep_equals_single_device(self):
        """K=12 grid over a 4-device data mesh (subprocess, fake CPU
        devices): bit-identical to the single-device vmap."""
        prog = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json
            import numpy as np
            import jax
            from repro.core.graphs import make_hierarchy
            from repro.core.hps import HPSConfig
            from repro.core.sweeps import run_hps_sweep
            from repro.launch import compat

            topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
            w = np.random.default_rng(0).normal(size=(18, 3)).astype("float32")
            cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.0)
            kw = dict(drop_probs=[0.0, 0.4, 0.8], gammas=[4, 16],
                      seeds=[0, 1])
            r1 = run_hps_sweep(w, cfg, T=20, **kw)
            mesh = compat.make_mesh((4,), ("data",))
            r2 = run_hps_sweep(w, cfg, T=20, mesh=mesh, **kw)
            same = bool((np.asarray(r1.gap) == np.asarray(r2.gap)).all())
            err = float(np.abs(np.asarray(r1.ratio)
                               - np.asarray(r2.ratio)).max())
            print(json.dumps({"K": int(r2.K), "same": same, "err": err,
                              "devices": jax.device_count()}))
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        for _ in range(2):   # CPU collective rendezvous can flake; retry once
            out = subprocess.run([sys.executable, "-c", prog],
                                 capture_output=True, text=True,
                                 timeout=420, env=env, cwd=REPO)
            if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
                break
        assert out.returncode == 0, out.stderr[-3000:]
        res = json.loads(
            [l for l in out.stdout.splitlines() if l.startswith("{")][-1])
        assert res["devices"] == 4
        assert res["K"] == 12            # pad rows sliced off
        assert res["same"] and res["err"] == 0.0
