"""CLI launcher smoke tests: train.py and serve.py end to end (1 device)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m"] + args, capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_train_cli_mean(tmp_path):
    out = _run([
        "repro.launch.train", "--arch", "paper_sim", "--reduced",
        "--steps", "4", "--seq-len", "32", "--global-batch", "4",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ])
    assert "done" in out
    losses = [float(l.split("loss")[1].split()[0])
              for l in out.splitlines() if "loss" in l]
    assert len(losses) >= 2 and all(np_finite(x) for x in losses)
    # checkpoints written
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def np_finite(x):
    return x == x and abs(x) != float("inf")


def test_serve_cli():
    out = _run([
        "repro.launch.serve", "--arch", "rwkv6_1b6", "--reduced",
        "--batch", "2", "--prompt-len", "16", "--gen", "5",
    ])
    assert "done" in out and "generated token ids" in out


def test_dryrun_cli_single_combo():
    """The dry-run entrypoint itself (fit-proof only, smallest arch)."""
    out = _run([
        "repro.launch.dryrun", "--arch", "whisper_small",
        "--shape", "decode_32k", "--skip-cost",
    ], timeout=580)
    assert "1/1 combinations lowered+compiled" in out
