"""Fused Pallas edge-scatter kernel + backend switch + sharded sweeps.

The contract under test: the Pallas kernel (interpret mode on CPU — the
identical traced program that compiles on TPU) is trajectory-equivalent to
the XLA sparse path, which is itself equivalent to the dense (N, N, d)
reference; the mass invariant survives the fused path; padding edges stay
inert; ``sort_by_dst`` is a pure relabeling (permutation round-trip); the
mesh-sharded sweep engine returns exactly what the single-device vmap
returns; and repeated ``run_byzantine_sweep`` calls do not retrace.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graphs import (
    edge_list,
    edge_masks,
    link_schedule,
    random_strongly_connected,
    random_strongly_connected_edge_list,
    sort_by_dst,
    stack_edge_lists,
)
from repro.core.pushsum import (
    run_pushsum,
    run_pushsum_sparse,
    sparse_mass_invariant,
)
from repro.kernels.pushsum_edge import edge_scatter_ref, resolve_backend
from repro.kernels.pushsum_edge.pushsum_edge import edge_scatter_pallas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sorted_graph(n, extra, seed):
    rng = np.random.default_rng(seed)
    el, perm, inv = sort_by_dst(edge_list(random_strongly_connected(n, extra, rng)))
    return el, perm, inv, rng


class TestEdgeScatterKernel:
    @pytest.mark.parametrize("seed,block_e", [(0, 16), (1, 64), (2, 4096)])
    def test_matches_xla_ref(self, seed, block_e):
        """Single fused call == gather + where + segment_sum, including when
        E is far from a block multiple (padding edges must stay inert)."""
        el, _, _, rng = _sorted_graph(29, 0.25, seed)
        sigma = jnp.asarray(rng.normal(size=(29, 5)).astype(np.float32))
        rho = jnp.asarray(rng.normal(size=(el.E, 5)).astype(np.float32))
        live = jnp.asarray(rng.random(el.E) < 0.5)
        src, dst = jnp.asarray(el.src), jnp.asarray(el.dst)
        rn_ref, rc_ref = edge_scatter_ref(sigma, rho, live, src, dst)
        rn_p, rc_p = edge_scatter_pallas(
            sigma, rho, live, src, dst, block_e=block_e, interpret=True
        )
        np.testing.assert_allclose(np.asarray(rn_p), np.asarray(rn_ref),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rc_p), np.asarray(rc_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_run_spanning_many_blocks(self):
        """A single receiver whose in-edge run spans several kernel blocks:
        every block's partial segment sum must accumulate into one row."""
        n, fan = 40, 33                      # star: everyone -> node 7
        src = np.concatenate([np.arange(1, fan + 1), [7]]).astype(np.int32)
        dst = np.concatenate([np.full(fan, 7), [8]]).astype(np.int32)
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        rng = np.random.default_rng(0)
        E = src.shape[0]
        sigma = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
        rho = jnp.asarray(rng.normal(size=(E, 3)).astype(np.float32))
        live = jnp.asarray(np.ones(E, bool))
        rn_ref, rc_ref = edge_scatter_ref(sigma, rho, live,
                                          jnp.asarray(src), jnp.asarray(dst))
        rn_p, rc_p = edge_scatter_pallas(
            sigma, rho, live, jnp.asarray(src), jnp.asarray(dst),
            block_e=8, interpret=True,       # run of 33 spans 5 blocks
        )
        np.testing.assert_allclose(np.asarray(rc_p), np.asarray(rc_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rn_p), np.asarray(rn_ref))

    def test_unsorted_index_still_correct(self):
        """Sortedness is a fast-path property, not a correctness
        precondition: fragmented runs accumulate to the same segment sums."""
        rng = np.random.default_rng(3)
        el = edge_list(random_strongly_connected(17, 0.3, rng))  # src-major
        sigma = jnp.asarray(rng.normal(size=(17, 2)).astype(np.float32))
        rho = jnp.asarray(rng.normal(size=(el.E, 2)).astype(np.float32))
        live = jnp.asarray(rng.random(el.E) < 0.7)
        src, dst = jnp.asarray(el.src), jnp.asarray(el.dst)
        _, rc_ref = edge_scatter_ref(sigma, rho, live, src, dst)
        _, rc_p = edge_scatter_pallas(sigma, rho, live, src, dst,
                                      block_e=16, interpret=True)
        np.testing.assert_allclose(np.asarray(rc_p), np.asarray(rc_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_auto_backend_is_xla_off_tpu(self):
        """CPU CI must auto-select the XLA fallback (acceptance criterion)."""
        expected = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert resolve_backend("auto") == expected
        with pytest.raises(ValueError):
            resolve_backend("cuda")


class TestBackendTrajectoryEquivalence:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_pallas_vs_xla_vs_dense(self, seed):
        """Identical (T, E) schedules: Pallas interpret == XLA sparse ==
        dense reference, per round, over the whole trajectory."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(6, 12))
        adj = random_strongly_connected(n, 0.3, rng)
        w = rng.normal(size=(n, 3)).astype(np.float32)
        masks = link_schedule(adj, 60, 0.4, 4, seed=seed)
        el0 = edge_list(adj)
        els, perm, _ = sort_by_dst(el0)
        em = edge_masks(masks, el0)[:, perm]     # schedule in sorted layout
        _, traj_dense = run_pushsum(w, adj, masks)
        _, traj_x = run_pushsum_sparse(w, els.src, els.dst, 60, masks=em,
                                       backend="xla")
        _, traj_p = run_pushsum_sparse(w, els.src, els.dst, 60, masks=em,
                                       backend="pallas")
        np.testing.assert_allclose(np.asarray(traj_p), np.asarray(traj_x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(traj_p), np.asarray(traj_dense),
                                   rtol=1e-4, atol=1e-5)

    def test_mass_invariant_preserved_pallas(self):
        """90% drop through the fused path: the augmented-graph invariant
        (Theorem 1's conservation law) holds exactly."""
        el, _, _, rng = _sorted_graph(14, 0.3, 7)
        w = rng.normal(size=(14, 4)).astype(np.float32)
        final, _ = run_pushsum_sparse(
            w, el.src, el.dst, 150, drop_prob=0.9, B=10, backend="pallas",
        )
        inv = np.asarray(sparse_mass_invariant(
            final, jnp.asarray(el.src), jnp.asarray(el.valid)))
        np.testing.assert_allclose(inv, w.sum(0), rtol=2e-3, atol=2e-3)

    def test_padding_edges_carry_nothing_pallas(self):
        """valid=False edges with stray mask Trues are inert in the fused
        path — the sparse analogue of the dense mask & adj regression."""
        rng = np.random.default_rng(4)
        a1 = random_strongly_connected(6, 0.2, rng)
        a2 = random_strongly_connected(6, 0.6, rng)  # more edges -> a1 padded
        el, perm, _ = sort_by_dst(stack_edge_lists([a1, a2]))
        el1, perm1, _ = sort_by_dst(edge_list(a1))
        w = rng.normal(size=(6, 2)).astype(np.float32)
        masks = link_schedule(a1, 50, 0.3, 4, seed=4)
        em1 = edge_masks(masks, edge_list(a1))[:, perm1]
        _, t_ref = run_pushsum_sparse(
            w, el1.src, el1.dst, 50, masks=em1, backend="pallas"
        )
        E1 = el1.E
        padded_masks = np.zeros((50, el.E), bool)
        # project a1's schedule through the batched row-0 sort, then force
        # stray Trues on every padding slot
        raw = np.zeros((50, el.E), bool)
        raw[:, :E1] = edge_masks(masks, edge_list(a1))
        padded_masks = raw[:, perm[0]]
        padded_masks[:, ~el.valid[0]] = True
        _, t_pad = run_pushsum_sparse(
            w, el.src[0], el.dst[0], 50, masks=jnp.asarray(padded_masks),
            valid=el.valid[0], backend="pallas",
        )
        np.testing.assert_allclose(np.asarray(t_pad), np.asarray(t_ref),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.slow
    def test_equivalence_N16384(self):
        """Scale check on the dense-free constructor: one round at N=16384
        through both backends agrees to the acceptance atol (1e-5)."""
        rng = np.random.default_rng(11)
        el = random_strongly_connected_edge_list(16384, 1.5, rng)
        w = rng.normal(size=(16384, 3)).astype(np.float32)
        masks = jnp.asarray(rng.random((2, el.E)) < 0.7)
        fx, tx = run_pushsum_sparse(w, el.src, el.dst, 2, masks=masks,
                                    backend="xla")
        fp, tp = run_pushsum_sparse(w, el.src, el.dst, 2, masks=masks,
                                    backend="pallas")
        np.testing.assert_allclose(np.asarray(tp), np.asarray(tx),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fp.rho), np.asarray(fx.rho),
                                   rtol=1e-5, atol=1e-5)


class TestSortByDst:
    def test_roundtrip_single(self):
        el0 = edge_list(random_strongly_connected(
            23, 0.3, np.random.default_rng(0)))
        els, perm, inv = sort_by_dst(el0)
        assert (np.diff(els.dst) >= 0).all()
        np.testing.assert_array_equal(els.src[inv], el0.src)
        np.testing.assert_array_equal(els.dst[inv], el0.dst)
        np.testing.assert_array_equal(perm[inv], np.arange(el0.E))
        np.testing.assert_array_equal(inv[perm], np.arange(el0.E))

    def test_roundtrip_batched(self):
        rng = np.random.default_rng(1)
        el0 = stack_edge_lists([random_strongly_connected(8, 0.3, rng),
                                random_strongly_connected(8, 0.6, rng)])
        els, perm, inv = sort_by_dst(el0)
        assert (np.diff(els.dst, axis=1) >= 0).all()
        np.testing.assert_array_equal(
            np.take_along_axis(els.src, inv, axis=1), el0.src)
        np.testing.assert_array_equal(
            np.take_along_axis(els.valid, inv, axis=1), el0.valid)

    def test_sparse_constructor_no_dense(self):
        """Direct edge-list construction at N=4096: strong-connectivity
        backbone present, no self-loops, no duplicate edges, sorted."""
        rng = np.random.default_rng(2)
        el = random_strongly_connected_edge_list(4096, 2.0, rng)
        assert (np.diff(el.dst) >= 0).all()
        assert (el.src != el.dst).all()
        key = el.src.astype(np.int64) * 4096 + el.dst
        assert np.unique(key).shape[0] == el.E
        deg_out = np.bincount(el.src, minlength=4096)
        deg_in = np.bincount(el.dst, minlength=4096)
        assert deg_out.min() >= 1 and deg_in.min() >= 1  # cycle backbone


class TestShardedSweep:
    def test_sharded_equals_single_device(self):
        """K=12 scenarios over a 4-device data mesh (subprocess, fake CPU
        devices): identical errors/ratios to the single-device vmap, with K
        padded to the axis size internally and sliced back."""
        prog = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json
            import numpy as np
            import jax
            from repro.core.graphs import (
                random_strongly_connected, sort_by_dst, stack_edge_lists)
            from repro.core.sweeps import run_pushsum_sweep
            from repro.launch import compat

            rng = np.random.default_rng(0)
            el, _, _ = sort_by_dst(stack_edge_lists(
                [random_strongly_connected(24, 0.1, rng) for _ in range(2)]))
            w = rng.normal(size=(24, 2)).astype(np.float32)
            kw = dict(drop_probs=[0.0, 0.6], seeds=[0, 1, 2], B=4)
            r1 = run_pushsum_sweep(w, el, 80, **kw)
            mesh = compat.make_mesh((4,), ("data",))
            r2 = run_pushsum_sweep(w, el, 80, mesh=mesh, **kw)  # K=12 -> pad 16
            err = float(np.abs(np.asarray(r2.err) - np.asarray(r1.err)).max())
            fin = float(np.abs(np.asarray(r2.final_ratio)
                               - np.asarray(r1.final_ratio)).max())
            print(json.dumps({"K": int(r2.K), "err": err, "fin": fin,
                              "devices": jax.device_count()}))
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        for _ in range(2):   # CPU collective rendezvous can flake; retry once
            out = subprocess.run([sys.executable, "-c", prog],
                                 capture_output=True, text=True,
                                 timeout=420, env=env, cwd=REPO)
            if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
                break
        assert out.returncode == 0, out.stderr[-3000:]
        res = json.loads(
            [l for l in out.stdout.splitlines() if l.startswith("{")][-1])
        assert res["devices"] == 4
        assert res["K"] == 12            # pad rows sliced off
        assert res["err"] == 0.0 and res["fin"] == 0.0


class TestBenchHarness:
    """benchmarks/run.py --json-dir merge semantics and the --check gate."""

    def _run_mod(self):
        sys.path.insert(0, REPO)
        try:
            from benchmarks import run as bench_run
        finally:
            sys.path.pop(0)
        return bench_run

    def test_merge_json_preserves_unmeasured_keys(self, tmp_path):
        sys.path.insert(0, REPO)
        try:
            from benchmarks import merge_bench_json
        finally:
            sys.path.pop(0)
        path = str(tmp_path / "BENCH_x.json")
        with open(path, "w") as f:
            json.dump({"old_row": {"us_per_call": 5.0, "derived": "d"}}, f)
        merge_bench_json(path, [("new_row", 7.0, "e"), ("old_row", 6.0, "d2"),
                                ("failed_row", float("nan"), "boom")])
        with open(path) as f:
            merged = json.load(f)
        assert merged["new_row"]["us_per_call"] == 7.0
        assert merged["old_row"]["us_per_call"] == 6.0   # updated, not lost
        assert "failed_row" not in merged      # NaN rows never serialized
        assert "NaN" not in open(path).read()  # strict RFC-8259 artifact

    def test_check_regressions_threshold(self):
        bench_run = self._run_mod()
        baseline = {"a": {"us_per_call": 100.0},
                    "b": {"us_per_call": 100.0},
                    "interp": {"us_per_call": 100.0},
                    "nan_row": {"us_per_call": float("nan")}}
        # 1.2x is within the 25% budget; 1.3x is a regression; names absent
        # from the baseline (new benchmarks), NaN rows, and interpret-mode
        # rows (Pallas-on-CPU equivalence timings) are skipped
        assert bench_run._check_regressions(
            "x", baseline, {"a": (120.0, "d"), "new": (9e9, "d"),
                            "nan_row": (5.0, "d"),
                            "interp": (900.0, "backend=pallas;mode=interpret"),
                            }) == 0
        assert bench_run._check_regressions(
            "x", baseline, {"a": (130.0, "d"), "b": (126.0, "d")}) == 2


class TestByzantineSweepNoRetrace:
    def test_second_call_hits_compiled_cache(self):
        """Acceptance criterion: run_byzantine_sweep twice with the same
        shapes/config does not retrace (one entry in the jit cache)."""
        from repro.core import attacks
        from repro.core.byzantine import ByzantineConfig
        from repro.core.graphs import make_hierarchy
        from repro.core.signals import make_confused_model
        from repro.core.sweeps import cache_registry, run_byzantine_sweep

        topo = make_hierarchy([4, 4, 4], topology="complete", seed=0)
        model = make_confused_model(topo.N, 3, confusion=0.0, seed=0)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                              attack=attacks.large_value())
        reg = cache_registry()["byz.compiled"]
        reg.clear()
        r1 = run_byzantine_sweep(model, cfg, T=12, seeds=[0, 1])
        assert reg.cache_info().currsize == 1
        r2 = run_byzantine_sweep(model, cfg, T=12, seeds=[2, 3])
        # same fingerprint -> same compiled entry, no second compile
        assert reg.cache_info().currsize == 1
        assert r1["large_value"].r.shape == r2["large_value"].r.shape
        # host-side C-set lattice memoized too
        from repro.core.byzantine import _C_SET_LATTICE
        assert len(_C_SET_LATTICE) >= 1

    def test_different_T_retraces_separately(self):
        from repro.core import attacks
        from repro.core.byzantine import ByzantineConfig
        from repro.core.graphs import make_hierarchy
        from repro.core.signals import make_confused_model
        from repro.core.sweeps import cache_registry, run_byzantine_sweep

        topo = make_hierarchy([4, 4, 4], topology="complete", seed=0)
        model = make_confused_model(topo.N, 3, confusion=0.0, seed=0)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                              attack=attacks.large_value())
        reg = cache_registry()["byz.compiled"]
        reg.clear()
        run_byzantine_sweep(model, cfg, T=12, seeds=[0])
        run_byzantine_sweep(model, cfg, T=13, seeds=[0])
        # a distinct horizon gets its own entry, within the LRU bound
        info = reg.cache_info()
        assert info.currsize == 2
        assert info.currsize <= info.maxsize
