"""Schema guard for the committed perf-trajectory artifacts.

CI's bench lane gates timings against a runner-local baseline (cross-
machine numbers are incomparable), so THIS is where the committed
``results/BENCH_*.json`` files are held to the contract every PR: strict
RFC-8259 JSON (no bare NaN), the ``{name: {us_per_call, derived}}`` row
shape the ``--check`` gate and the README table generator consume, and the
benchmark-name coverage the ROADMAP's perf story is tracked by.
"""
import glob
import json
import math
import os

import pytest

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")
BENCH_FILES = sorted(glob.glob(os.path.join(RESULTS, "BENCH_*.json")))


def test_bench_artifacts_exist():
    names = {os.path.basename(p) for p in BENCH_FILES}
    # one artifact per fused engine family (PRs 2-5)
    assert {"BENCH_pushsum_sweep.json", "BENCH_byzantine.json",
            "BENCH_social.json", "BENCH_hps.json"} <= names


@pytest.mark.parametrize("path", BENCH_FILES,
                         ids=[os.path.basename(p) for p in BENCH_FILES])
def test_rows_follow_schema(path):
    # strict parse: parse_constant trips on NaN/Infinity literals, which
    # merge_bench_json promises never to serialize
    with open(path) as f:
        data = json.load(f, parse_constant=lambda c: pytest.fail(
            f"{path}: non-RFC-8259 constant {c!r}"))
    assert isinstance(data, dict) and data
    for name, row in data.items():
        assert isinstance(name, str) and name
        assert set(row) == {"us_per_call", "derived"}, (name, row)
        assert isinstance(row["us_per_call"], (int, float))
        assert math.isfinite(row["us_per_call"]) and row["us_per_call"] >= 0
        assert isinstance(row["derived"], str)


def test_hps_rows_cover_the_acceptance_names():
    """PR acceptance: hps_step_{xla,pallas}_N{1024,16384} and a >= 48
    scenario grid row recorded in BENCH_hps.json."""
    with open(os.path.join(RESULTS, "BENCH_hps.json")) as f:
        rows = json.load(f)
    for backend in ("xla", "pallas"):
        for n in (1024, 16384):
            assert f"hps_step_{backend}_N{n}" in rows
    grids = [n for n in rows if n.startswith("hps_grid_")]
    assert grids
    assert any("scenarios=48" in rows[g]["derived"] for g in grids)
