"""The ExecutionPlan redesign (PR 10): deprecation shims fold loose kwargs
into bit-identical plans and warn once per entrypoint; plan= and legacy
kwargs are mutually exclusive; unsupported plan fields fail loudly; the
unified result index-column convention has a shared describe(); and the
repro.statics signature lint keeps the execution vocabulary from
re-growing loose kwargs (including the retired use_kernel alias)."""
import re
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks
from repro.core.asyncrony import make_async_model
from repro.core.byzantine import ByzantineConfig
from repro.core.graphs import (
    edge_list,
    make_hierarchy,
    random_strongly_connected,
)
from repro.core.hps import HPSConfig, run_hps
from repro.core.plan import (
    LEGACY_PLAN_KWARGS,
    PLAN_FIELDS,
    ExecutionPlan,
    _warned,
)
from repro.core.pushsum import run_pushsum_sparse
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning
from repro.core.sweeps import (
    run_byzantine_grid,
    run_byzantine_sweep,
    run_hps_grid,
    run_hps_sweep,
    run_pushsum_sweep,
    run_social_grid,
    run_social_sweep,
)
from repro.statics import signatures

REPO = Path(__file__).resolve().parents[1]
RNG = np.random.default_rng(0)


def _pushsum_fixture():
    el = edge_list(random_strongly_connected(8, 0.3, RNG))
    w = np.random.default_rng(1).normal(size=(8, 2)).astype(np.float32)
    return el, w


def _hier_fixture():
    topo = make_hierarchy([4, 4, 4], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
    return topo, model, cfg


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


import jax  # noqa: E402  (after the tree helper that uses it)


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Each test sees a clean warn-once registry."""
    saved = set(_warned)
    _warned.clear()
    yield
    _warned.clear()
    _warned.update(saved)


class TestDeprecationShim:
    def test_warns_exactly_once_per_entrypoint(self):
        el, w = _pushsum_fixture()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            run_pushsum_sparse(w, el.src, el.dst, T=3, backend="xla")
            run_pushsum_sparse(w, el.src, el.dst, T=3, backend="xla")
        dep = [r for r in rec
               if issubclass(r.category, DeprecationWarning)
               and "run_pushsum_sparse" in str(r.message)]
        assert len(dep) == 1
        assert "plan=ExecutionPlan" in str(dep[0].message)

    def test_distinct_entrypoints_each_warn(self):
        el, w = _pushsum_fixture()
        _, model, cfg = _hier_fixture()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            run_pushsum_sparse(w, el.src, el.dst, T=3, backend="xla")
            run_social_learning(model, cfg, T=3, store="log_ratio")
        dep = [str(r.message) for r in rec
               if issubclass(r.category, DeprecationWarning)]
        assert any("run_pushsum_sparse" in m for m in dep)
        assert any("run_social_learning" in m for m in dep)

    def test_plan_plus_legacy_is_error(self):
        el, w = _pushsum_fixture()
        with pytest.raises(TypeError, match="not both"):
            run_pushsum_sparse(w, el.src, el.dst, T=3,
                               plan=ExecutionPlan(), backend="xla")

    def test_unknown_kwarg_is_error(self):
        el, w = _pushsum_fixture()
        with pytest.raises(TypeError, match="unexpected keyword"):
            run_pushsum_sparse(w, el.src, el.dst, T=3, bakend="xla")

    def test_async_is_plan_only(self):
        """async_ is NOT a legacy kwarg — it must never become loose
        execution kwarg number 15."""
        assert "async_" not in LEGACY_PLAN_KWARGS
        assert "async_" in PLAN_FIELDS
        el, w = _pushsum_fixture()
        with pytest.raises(TypeError, match="plan-only"):
            run_pushsum_sparse(w, el.src, el.dst, T=3,
                               async_=make_async_model(0.5, 1))

    def test_unsupported_plan_field_is_error(self):
        _, model, cfg = _hier_fixture()
        w = np.zeros((12, 2), np.float32)
        with pytest.raises(ValueError, match="graph_shards"):
            run_hps(w, cfg, T=3, plan=ExecutionPlan(graph_shards=2))
        with pytest.raises(ValueError, match="async_"):
            run_byzantine_sweep(
                model, ByzantineConfig(topo=cfg.topo, F=1, byz=(1,),
                                       gamma_period=4,
                                       attack=attacks.large_value()),
                T=3, seeds=[0],
                plan=ExecutionPlan(async_=make_async_model(0.5, 1)))


class TestPlanEquivalence:
    """plan= and the legacy loose kwargs produce bit-identical results."""

    def _legacy(self, fn, *args, **legacy):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return fn(*args, **legacy)

    def test_run_pushsum_sparse(self):
        el, w = _pushsum_fixture()
        a = self._legacy(run_pushsum_sparse, w, el.src, el.dst, T=5,
                         drop_prob=0.2, B=2, backend="xla")
        b = run_pushsum_sparse(w, el.src, el.dst, T=5, drop_prob=0.2, B=2,
                               plan=ExecutionPlan(backend="xla"))
        _assert_trees_equal(a, b)

    def test_run_hps(self):
        _, _, cfg = _hier_fixture()
        w = np.random.default_rng(2).normal(size=(12, 2)).astype(np.float32)
        a = self._legacy(run_hps, w, cfg, T=4, backend="xla", store="gap")
        b = run_hps(w, cfg, T=4,
                    plan=ExecutionPlan(backend="xla", store="gap"))
        _assert_trees_equal(a, b)

    def test_run_social_learning(self):
        _, model, cfg = _hier_fixture()
        a = self._legacy(run_social_learning, model, cfg, T=4,
                         backend="xla", store="log_ratio")
        b = run_social_learning(model, cfg, T=4,
                                plan=ExecutionPlan(backend="xla",
                                                   store="log_ratio"))
        _assert_trees_equal(a, b)

    def test_run_pushsum_sweep(self):
        el, w = _pushsum_fixture()
        a = self._legacy(run_pushsum_sweep, w, el, T=4,
                         drop_probs=[0.0, 0.3], seeds=[0], B=2,
                         backend="xla")
        b = run_pushsum_sweep(w, el, T=4, drop_probs=[0.0, 0.3], seeds=[0],
                              B=2, plan=ExecutionPlan(backend="xla"))
        _assert_trees_equal(a, b)

    def test_run_byzantine_sweep_and_grid(self):
        _, model, cfg = _hier_fixture()
        bcfg = ByzantineConfig(topo=cfg.topo, F=1, byz=(1,), gamma_period=4,
                               attack=attacks.large_value())
        a = self._legacy(run_byzantine_sweep, model, bcfg, T=3, seeds=[0],
                         backend="xla", store="final")
        b = run_byzantine_sweep(model, bcfg, T=3, seeds=[0],
                                plan=ExecutionPlan(backend="xla",
                                                   store="final"))
        _assert_trees_equal(a, b)
        ga = self._legacy(run_byzantine_grid, model, [bcfg], T=3, seeds=[0],
                          backend="xla", store="decisions")
        gb = run_byzantine_grid(model, [bcfg], T=3, seeds=[0],
                                plan=ExecutionPlan(backend="xla",
                                                   store="decisions"))
        _assert_trees_equal(ga, gb)

    def test_run_hps_and_social_sweeps(self):
        _, model, cfg = _hier_fixture()
        w = np.random.default_rng(3).normal(size=(12, 2)).astype(np.float32)
        a = self._legacy(run_hps_sweep, w, cfg, T=3,
                         drop_probs=[0.0, 0.3], seeds=[0], backend="xla",
                         store="gap")
        b = run_hps_sweep(w, cfg, T=3, drop_probs=[0.0, 0.3], seeds=[0],
                          plan=ExecutionPlan(backend="xla", store="gap"))
        _assert_trees_equal(a, b)
        sa = self._legacy(run_social_sweep, model, cfg, T=3,
                          drop_probs=[0.0, 0.3], seeds=[0], backend="xla",
                          store="log_ratio")
        sb = run_social_sweep(model, cfg, T=3, drop_probs=[0.0, 0.3],
                              seeds=[0],
                              plan=ExecutionPlan(backend="xla",
                                                 store="log_ratio"))
        _assert_trees_equal(sa, sb)

    def test_run_hps_and_social_grids(self):
        _, model, cfg = _hier_fixture()
        cfgs = [cfg, HPSConfig(topo=cfg.topo, gamma_period=2, B=2,
                               drop_prob=0.0)]
        w = np.random.default_rng(4).normal(size=(12, 2)).astype(np.float32)
        a = self._legacy(run_hps_grid, w, cfgs, T=3, seeds=[0],
                         backend="xla", store="gap")
        b = run_hps_grid(w, cfgs, T=3, seeds=[0],
                         plan=ExecutionPlan(backend="xla", store="gap"))
        _assert_trees_equal(a, b)
        sa = self._legacy(run_social_grid, model, cfgs, T=3, seeds=[0],
                          backend="xla", store="log_ratio")
        sb = run_social_grid(model, cfgs, T=3, seeds=[0],
                             plan=ExecutionPlan(backend="xla",
                                                store="log_ratio"))
        _assert_trees_equal(sa, sb)


class TestResultConvention:
    """The unified index-column convention: scenario -> fault -> async_
    fixed row order, absent axes are None (not zeros), and every result
    family shares describe()."""

    def test_describe_names_axes_and_payload(self):
        el, w = _pushsum_fixture()
        res = run_pushsum_sweep(w, el, T=3, drop_probs=[0.0, 0.3],
                                seeds=[0, 1], B=2,
                                plan=ExecutionPlan(backend="xla"))
        txt = res.describe()
        assert f"K={res.K}" in txt
        assert "async minor-most" in txt
        assert "drop_prob" in txt and "seed" in txt
        assert "fault     absent (no axis)" in txt
        assert "async_    absent (no axis)" in txt
        assert "err" in txt and "final_ratio" in txt

    def test_absent_axes_are_none(self):
        el, w = _pushsum_fixture()
        res = run_pushsum_sweep(w, el, T=3, drop_probs=0.2, seeds=0, B=2,
                                plan=ExecutionPlan(backend="xla"))
        assert res.fault is None and res.async_ is None

    def test_byzantine_grid_has_fault_column(self):
        """The historical gap this convention fixes: ByzantineGridResult
        previously had no fault field at all."""
        _, model, cfg = _hier_fixture()
        bcfg = ByzantineConfig(topo=cfg.topo, F=1, byz=(1,), gamma_period=4,
                               attack=attacks.large_value())
        res = run_byzantine_grid(model, [bcfg], T=3, seeds=[0, 1],
                                 plan=ExecutionPlan(backend="xla"))
        assert "fault" in type(res)._fields
        assert "async_" in type(res)._fields
        assert res.fault is None          # no fault model applied
        assert res.async_ is None         # byzantine engine has no async
        assert f"K={res.K}" in res.describe()


class TestSignatureLint:
    def test_all_entrypoints_pass(self):
        assert signatures.check_entrypoints() == []

    def test_flags_reintroduced_execution_kwarg(self):
        def bad_run(w, T, backend="auto", plan=None, **legacy):
            pass

        findings = signatures.check_signature(bad_run, "bad_run")
        assert len(findings) == 1
        assert "backend" in findings[0].message

    def test_flags_missing_plan_and_use_kernel(self):
        def seed_era_run(w, T, use_kernel=True):
            pass

        findings = signatures.check_signature(seed_era_run, "seed_era_run")
        checks = sorted(f.message for f in findings)
        assert len(findings) == 2
        assert any("no plan=" in m for m in checks)
        assert any("use_kernel" in m for m in checks)

    def test_legacy_catchall_is_not_flagged(self):
        def good_run(w, T, *, plan=None, **legacy):
            pass

        assert signatures.check_signature(good_run, "good_run") == []


class TestNoUseKernelAnywhere:
    def test_no_source_or_test_passes_use_kernel(self):
        """The seed-era use_kernel= alias is gone: no .py under src/ or
        tests/ passes (or declares) it. Prose mentions in docstrings are
        exempt (matched by the double-backtick convention)."""
        pat = re.compile(r"use_kernel\s*=")
        offenders = []
        this_file = Path(__file__).resolve()
        for root in ("src", "tests", "benchmarks", "examples"):
            for p in sorted((REPO / root).rglob("*.py")):
                if p.resolve() == this_file:
                    continue  # the linter fixtures above declare it
                for i, line in enumerate(
                        p.read_text().splitlines(), start=1):
                    if pat.search(line) and "``" not in line:
                        offenders.append(f"{p.relative_to(REPO)}:{i}")
        assert offenders == [], offenders
