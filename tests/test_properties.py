"""Hypothesis property tests on system invariants.

These complement the example-based tests with randomized structural
checks: push-sum mass conservation on arbitrary strongly connected
digraphs and drop schedules, SCC correctness vs brute-force reachability,
KL dual-averaging == softmax, ring-alignment of the decode cache.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.graphs import (
    link_schedule, random_strongly_connected, strongly_connected_components,
    is_strongly_connected,
)
from repro.core.graphs import edge_list, edge_masks
from repro.core.pushsum import run_pushsum, run_pushsum_sparse, mass_invariant
from repro.core.social import kl_dual_averaging_update


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 10),
    drop=st.floats(0.0, 0.8),
    B=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_pushsum_mass_conserved_any_graph(n, drop, B, seed):
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, 0.3, rng)
    w = rng.normal(size=(n, 2)).astype(np.float32)
    masks = link_schedule(adj, 60, drop, B, seed=seed)
    final, _ = run_pushsum(w, adj, masks)
    inv = np.asarray(mass_invariant(final, jnp.asarray(adj)))
    np.testing.assert_allclose(inv, w.sum(0), rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 10),
    drop=st.floats(0.0, 0.8),
    B=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_sparse_matches_dense_any_graph(n, drop, B, seed):
    """The edge-list core is trajectory-equivalent to the dense reference on
    any strongly connected digraph and any admissible drop schedule."""
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, 0.3, rng)
    w = rng.normal(size=(n, 2)).astype(np.float32)
    masks = link_schedule(adj, 60, drop, B, seed=seed)
    el = edge_list(adj)
    _, traj_d = run_pushsum(w, adj, masks)
    _, traj_s = run_pushsum_sparse(
        w, el.src, el.dst, 60, masks=edge_masks(masks, el)
    )
    np.testing.assert_allclose(np.asarray(traj_s), np.asarray(traj_d),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 16), p=st.floats(0.0, 0.8), seed=st.integers(0, 2**16))
def test_sort_by_dst_roundtrip(n, p, seed):
    """sort_by_dst is a pure relabeling: perm/inv are inverse permutations,
    the sorted dst is nondecreasing, and projecting per-edge data into the
    sorted layout and back is the identity — on any digraph."""
    from repro.core.graphs import sort_by_dst

    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, p, rng)
    el0 = edge_list(adj)
    els, perm, inv = sort_by_dst(el0)
    assert (np.diff(els.dst) >= 0).all()
    np.testing.assert_array_equal(np.sort(perm), np.arange(el0.E))
    np.testing.assert_array_equal(perm[inv], np.arange(el0.E))
    np.testing.assert_array_equal(els.src[inv], el0.src)
    np.testing.assert_array_equal(els.dst[inv], el0.dst)
    data = rng.normal(size=(el0.E, 2))
    np.testing.assert_array_equal(data[perm][inv], data)
    # same multiset of edges
    k0 = np.sort(el0.src.astype(np.int64) * n + el0.dst)
    ks = np.sort(els.src.astype(np.int64) * n + els.dst)
    np.testing.assert_array_equal(k0, ks)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(4, 10),
    drop=st.floats(0.0, 0.8),
    seed=st.integers(0, 2**16),
)
def test_pallas_backend_matches_xla_any_graph(n, drop, seed):
    """The fused Pallas edge-scatter (interpret mode) is trajectory-
    equivalent to the XLA sparse path on any strongly connected digraph
    and drop schedule (sorted-edge layout via sort_by_dst)."""
    from repro.core.graphs import sort_by_dst

    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, 0.3, rng)
    w = rng.normal(size=(n, 2)).astype(np.float32)
    masks = link_schedule(adj, 30, drop, 4, seed=seed)
    el0 = edge_list(adj)
    els, perm, _ = sort_by_dst(el0)
    em = edge_masks(masks, el0)[:, perm]
    _, traj_x = run_pushsum_sparse(w, els.src, els.dst, 30, masks=em,
                                   backend="xla")
    _, traj_p = run_pushsum_sparse(w, els.src, els.dst, 30, masks=em,
                                   backend="pallas")
    np.testing.assert_allclose(np.asarray(traj_p), np.asarray(traj_x),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), p=st.floats(0.0, 0.5), seed=st.integers(0, 2**16))
def test_scc_matches_bruteforce_reachability(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < p
    np.fill_diagonal(adj, False)
    comps = strongly_connected_components(adj)
    # brute force: transitive closure
    reach = adj.copy()
    for k in range(n):
        reach = reach | (reach[:, k:k + 1] & reach[k:k + 1, :])
    same = lambda i, j: (reach[i, j] and reach[j, i]) or i == j
    # partition property: i,j in same comp <=> mutually reachable
    comp_of = {}
    for ci, comp in enumerate(comps):
        for v in comp:
            comp_of[v] = ci
    for i in range(n):
        for j in range(n):
            assert (comp_of[i] == comp_of[j]) == same(i, j), (i, j)
    # partition covers all nodes exactly once
    assert sorted(v for c in comps for v in c) == list(range(n))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 6),
    m=st.integers(2, 6),
    seed=st.integers(0, 2**16),
)
def test_kl_dual_averaging_is_softmax(n, m, seed):
    """The KL-proximal dual-averaging projection has the closed softmax
    form (the identity Algorithm 3's belief update relies on)."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32) * 5)
    mass = jnp.asarray(rng.uniform(0.2, 3.0, size=(n,)).astype(np.float32))
    mu = np.asarray(kl_dual_averaging_update(z, mass))
    np.testing.assert_allclose(mu.sum(axis=1), 1.0, rtol=1e-5)
    want = np.asarray(jax.nn.softmax(np.asarray(z) / np.asarray(mass)[:, None],
                                     axis=-1))
    np.testing.assert_allclose(mu, want, rtol=1e-5, atol=1e-6)
    # argmax preserved: the belief ranks hypotheses by accumulated evidence
    assert (mu.argmax(1) == np.asarray(z).argmax(1)).all()


@settings(max_examples=10, deadline=None)
@given(
    S=st.integers(4, 24),
    wlen=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_decode_ring_cache_alignment(S, wlen, seed):
    """Sliding-window prefill + decode must agree with the full forward for
    ANY prompt length (the ring-roll alignment property)."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import model as M

    cfg = dataclasses.replace(
        reduced(get_config("qwen3_8b")), block_pattern=("swa",), window=wlen,
        n_layers=2,
    )
    key = jax.random.PRNGKey(seed)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab)
    # capacity covers prompt + 1 decode token; the swa cache caps itself at
    # the window and ring-rolls (the alignment property under test)
    _, cache = M.prefill(params, cfg, toks, cache_len=S + 1)
    nxt = jax.random.randint(jax.random.fold_in(key, 1), (1, 1), 0, cfg.vocab)
    dec, _ = M.decode_step(params, cfg, cache, nxt)
    full, _ = M.forward_train(params, cfg, jnp.concatenate([toks, nxt], 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=5e-3, atol=5e-3)
