"""Optimizer, data pipeline, checkpoint, analysis-layer tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.data import SyntheticLMData
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.analysis.roofline import (
    parse_collectives, roofline_terms, model_flops, _shape_bytes,
)
from repro.configs import get_config, INPUT_SHAPES


class TestAdamW:
    def test_quadratic_descent(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, clip_norm=100.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1,
                          total_steps=10)
        params = {"w": jnp.zeros(4)}
        state = adamw_init(params)
        p1, s1 = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, state, params)
        p2, s2 = adamw_update(cfg, {"w": jnp.full(4, 2e6)}, state, params)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   rtol=1e-6)

    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5, abs=0.01)
        assert lrs[2] == pytest.approx(1.0, abs=0.01)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, abs=0.01)

    def test_weight_decay_only_matrices(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1,
                          total_steps=10)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = adamw_init(params)
        zero_grads = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        p, _ = adamw_update(cfg, zero_grads, state, params)
        assert float(p["w"].max()) < 1.0   # decayed
        assert float(p["b"].min()) == 1.0  # biases/scales not decayed


class TestData:
    def test_determinism(self):
        d = SyntheticLMData(1000, 16, 4, flavour="markov", seed=3)
        b1, b2 = d.batch(7), d.batch(7)
        assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
        b3 = d.batch(8)
        assert (np.asarray(b1["tokens"]) != np.asarray(b3["tokens"])).any()

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMData(1000, 16, 4, seed=0)
        b = d.batch(0)
        assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)

    def test_agent_shards_differ(self):
        d = SyntheticLMData(1000, 16, 8, flavour="markov", n_agents=4, seed=0)
        s0 = d.shard_batch(0, agent=0, local_batch=2)
        s1 = d.shard_batch(0, agent=1, local_batch=2)
        assert (np.asarray(s0["tokens"]) != np.asarray(s1["tokens"])).any()

    def test_tokens_in_vocab(self):
        d = SyntheticLMData(50, 64, 4, flavour="markov", seed=1)
        t = np.asarray(d.batch(0)["tokens"])
        assert t.min() >= 0 and t.max() < 50


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": [jnp.zeros(3), jnp.full(2, 7.0)]},
        }
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 5, tree)
        save_checkpoint(d, 9, tree)
        assert latest_step(d) == 9
        restored = restore_checkpoint(d, 5, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_shape_mismatch_rejected(self, tmp_path):
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, 1, {"a": jnp.zeros((3, 3))})


class TestRooflineAnalysis:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[2,3]") == 12
        assert _shape_bytes("f32[10]") == 40
        assert _shape_bytes("(f32[4], u32[2])") == 24
        assert _shape_bytes("pred[]") == 1

    def test_parse_collectives_synthetic(self):
        hlo = """
          %ag = bf16[32,128]{1,0} all-gather(bf16[2,128]{1,0} %x), replica_groups=[32,16]<=[512], dimensions={0}
          %ar = f32[64]{0} all-reduce(f32[64]{0} %y), replica_groups={{0,1,2,3}}, to_apply=%add
          %rs = f32[8]{0} reduce-scatter(f32[64]{0} %z), replica_groups=[64,8]<=[512], dimensions={0}
          %cp = bf16[16]{0} collective-permute(bf16[16]{0} %w), source_target_pairs={{0,1}}
        """
        out = parse_collectives(hlo, 512)
        kinds = out["count_by_kind"]
        assert kinds["all-gather"] == 1 and kinds["all-reduce"] == 1
        assert kinds["reduce-scatter"] == 1 and kinds["collective-permute"] == 1
        ag = out["bytes_by_kind"]["all-gather"]
        assert ag == pytest.approx((16 - 1) / 16 * 32 * 128 * 2)
        ar = out["bytes_by_kind"]["all-reduce"]
        assert ar == pytest.approx(2 * 3 / 4 * 64 * 4)
        rs = out["bytes_by_kind"]["reduce-scatter"]
        assert rs == pytest.approx(7 / 8 * 8 * 4 * 8)
        cp = out["bytes_by_kind"]["collective-permute"]
        assert cp == pytest.approx(16 * 2)

    def test_roofline_dominant_term(self):
        cost = {"flops": 197e12, "bytes accessed": 819e9 * 3}
        coll = {"wire_bytes_per_device": 50e9 * 0.5}
        t = roofline_terms(cost, coll, 256, 1e15)
        assert t["dominant"] == "memory"
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(3.0)
        assert t["collective_s"] == pytest.approx(0.5)

    def test_model_flops_kinds(self):
        cfg = get_config("qwen3_8b")
        tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
        pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
        dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
        assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
        assert pf == pytest.approx(2 * cfg.param_count() * 32 * 32768, rel=1e-6)
        assert dc == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
        moe = get_config("qwen3_moe_235b_a22b")
        assert model_flops(moe, INPUT_SHAPES["train_4k"]) < \
            6 * moe.param_count() * 256 * 4096 / 5  # active << total

    def test_memory_model_405b_single_pod_infeasible(self):
        """The analytic model reproduces the real capacity wall: 405B
        training with f32 Adam moments cannot fit 256 x 16 GB."""
        from repro.analysis.memory_model import train_memory_gb
        cfg = get_config("llama3_405b")
        single = train_memory_gb(cfg, INPUT_SHAPES["train_4k"],
                                 {"data": 16, "model": 16}, fsdp=True,
                                 n_micro=16)
        multi = train_memory_gb(cfg, INPUT_SHAPES["train_4k"],
                                {"pod": 2, "data": 16, "model": 16},
                                fsdp=True, n_micro=8)
        assert not single["fits_16gb"]
        assert multi["optimizer_gb"] < single["optimizer_gb"]


class TestDryRunHelpers:
    def test_input_specs_no_allocation(self):
        from repro.launch import dryrun as DR
        for shape in INPUT_SHAPES:
            specs = DR.input_specs("qwen3_8b", shape)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_long500k_switches_to_sliding_window(self):
        from repro.launch.dryrun import serve_cfg_for, LONG_WINDOW
        cfg = get_config("llama3_405b")
        out = serve_cfg_for(cfg, INPUT_SHAPES["long_500k"])
        assert out.block_pattern == ("swa",) and out.window == LONG_WINDOW
        # ssm arch unchanged
        r = get_config("rwkv6_1b6")
        assert serve_cfg_for(r, INPUT_SHAPES["long_500k"]).block_pattern == \
            ("wkv6",)

    def test_micro_batching_divides_evenly(self):
        from repro.launch.dryrun import pick_n_micro
        from repro.launch.mesh import make_production_mesh
        import repro.launch.dryrun as DR
        from repro.launch.compat import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))

        class FakeMesh:
            shape = {"data": 16, "model": 16}
        for arch in ("qwen3_8b", "llama3_405b", "olmoe_1b_7b"):
            cfg = get_config(arch)
            n = pick_n_micro(cfg, INPUT_SHAPES["train_4k"], FakeMesh())
            b_dev = 256 // 16
            assert b_dev % n == 0 and n >= 1
