"""Fused Algorithm 3 engine + innovation kernel + batched social sweeps.

The contract under test: the Pallas innovation kernel (interpret mode on
CPU — the identical traced program that compiles on TPU) matches the XLA
oracle; the fused engine's trajectories are bit-identical to the
pre-refactor ``run_social_learning`` structure (a step-by-step oracle
re-run here with the satellite-mandated PRNG fixes) and to the swept path;
``store="final"`` materializes no (T, N, m) value (jaxpr inspection); the
link-mask and signal PRNG streams have disjoint fold-in domains (the seed
scheme aliased them whenever ``seed == signal_seed``); a
(drop x Gamma x topology x seed) grid of >= 48 scenarios runs as ONE
compiled program; and the compiled-sweep cache is LRU-bounded.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graphs import block_complete_edge_list, make_hierarchy
from repro.core.hps import HPSConfig, hps_fusion
from repro.core.pushsum import (
    init_sparse_state,
    sparse_pushsum_step,
    step_edge_mask,
)
from repro.core.signals import make_confused_model
from repro.core.social import (
    N_SOCIAL_STREAMS,
    STREAM_LINK,
    STREAM_SIGNAL,
    kl_dual_averaging_update,
    run_social_learning,
    run_social_runtime,
    social_runtime_from_edge_list,
    social_stream_fold,
)
from repro.core.sweeps import run_social_grid, run_social_sweep
from repro.kernels.social_innov import innovation_ref, resolve_backend
from repro.kernels.social_innov.social_innov import innovation_pallas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(0)


def _innov_problem(N, m, S, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(N, m)).astype(np.float32))
    mass = jnp.asarray(np.abs(rng.normal(size=(N,))).astype(np.float32))
    u = jnp.asarray(rng.random(N).astype(np.float32))
    probs = rng.dirichlet(np.ones(S), size=N).astype(np.float32)
    cdf = jnp.cumsum(jnp.asarray(probs), axis=-1)
    lt = jnp.asarray(np.log(np.maximum(
        rng.dirichlet(np.ones(S), size=(N, m)), 2e-2
    )).astype(np.float32))
    return z, mass, u, cdf, lt


class TestInnovationKernel:
    @pytest.mark.parametrize("N,m,S,block_n", [
        (29, 3, 4, 8),      # N far from a block multiple: padding inert
        (64, 5, 7, 64),
        (18, 3, 4, 1024),   # block_n > N clamps
        (128, 2, 3, 32),
    ])
    def test_pallas_matches_xla_ref(self, N, m, S, block_n):
        z, mass, u, cdf, lt = _innov_problem(N, m, S, seed=N)
        z_r, mu_r = innovation_ref(z, mass, u, cdf, lt)
        z_p, mu_p = innovation_pallas(z, mass, u, cdf, lt,
                                      block_n=block_n, interpret=True)
        np.testing.assert_array_equal(np.asarray(z_p), np.asarray(z_r))
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_r),
                                   rtol=1e-6, atol=1e-7)

    def test_ref_matches_seed_lowering(self):
        """The oracle IS the seed path's op sequence (plus the alphabet
        clamp): inverse-CDF sample, take_along_axis gather, z += loglik,
        kl_dual_averaging_update."""
        z, mass, u, cdf, lt = _innov_problem(23, 4, 5, seed=1)
        sig = jnp.minimum((u[:, None] > cdf).sum(axis=-1), cdf.shape[1] - 1)
        loglik = jnp.take_along_axis(
            lt, sig[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]
        z_want = z + loglik
        mu_want = kl_dual_averaging_update(z_want, mass)
        z_got, mu_got = innovation_ref(z, mass, u, cdf, lt)
        np.testing.assert_array_equal(np.asarray(z_got), np.asarray(z_want))
        np.testing.assert_array_equal(np.asarray(mu_got), np.asarray(mu_want))

    def test_uniform_above_cdf_top_clamps_to_last_letter(self):
        """An fp32 cumsum can end below 1.0; a uniform above it must map to
        the last alphabet letter, not index past the table (the unclamped
        sample NaN-fills the XLA gather while the Pallas one-hot silently
        drops the signal — permanent z poisoning AND backend divergence)."""
        z, mass, _, cdf, lt = _innov_problem(8, 3, 4, seed=3)
        cdf = cdf.at[:, -1].set(1.0 - 1e-6)
        u = jnp.full((8,), 0.9999999, jnp.float32)
        z_r, mu_r = innovation_ref(z, mass, u, cdf, lt)
        z_p, mu_p = innovation_pallas(z, mass, u, cdf, lt, block_n=8,
                                      interpret=True)
        assert np.isfinite(np.asarray(z_r)).all()
        np.testing.assert_array_equal(np.asarray(z_r),
                                      np.asarray(z + lt[:, :, -1]))
        np.testing.assert_array_equal(np.asarray(z_p), np.asarray(z_r))
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_r),
                                   rtol=1e-6, atol=1e-7)

    def test_zero_mass_rows_stay_finite(self):
        """mass = 0 (the padding-row regime) must not produce NaN/inf —
        the belief degrades to the max-subtracted softmax of z / 1e-30."""
        z, mass, u, cdf, lt = _innov_problem(16, 3, 4, seed=2)
        mass = mass.at[3].set(0.0).at[7].set(0.0)
        z = z.at[3].set(0.0)
        for got in innovation_pallas(z, mass, u, cdf, lt, block_n=8,
                                     interpret=True):
            assert np.isfinite(np.asarray(got)).all()

    def test_auto_backend_is_xla_off_tpu(self):
        expected = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert resolve_backend("auto") == expected


def _setup(seed=2, sizes=(6, 6, 6), m=3, truth=1, confusion=0.5):
    topo = make_hierarchy(list(sizes), topology="complete", seed=seed)
    model = make_confused_model(N=topo.N, m=m, truth=truth,
                                confusion=confusion, seed=0)
    return topo, model


def _oracle(model, cfg, T, seed, signal_seed):
    """The pre-refactor ``run_social_learning`` scan, re-run verbatim: the
    same sparse push-sum consensus, the UNFUSED five-op innovation sequence
    with the (N, S) cumsum recomputed inside the body, no share hoist, and
    the precomputed host-side fusion schedule — modulo only the
    satellite-mandated PRNG fixes (dst-sorted edge layout, one (N,) uniform
    draw, disjoint stream domains) and the normal-range belief floor. The
    fused engine must reproduce it bit for bit."""
    from repro.core.social import _MU_FLOOR

    topo = cfg.topo
    el = cfg.edge_index()
    src, dst = jnp.asarray(el.src), jnp.asarray(el.dst)
    valid = jnp.asarray(el.valid)
    rep_mask = cfg.rep_mask()
    mask_key = jax.random.PRNGKey(seed)
    base_key = jax.random.PRNGKey(signal_seed)
    fuse = jnp.arange(1, T + 1) % cfg.gamma_period == 0
    state0 = init_sparse_state(jnp.zeros((topo.N, model.m), jnp.float32), el.E)
    log_tables = model.log_tables().astype(jnp.float32)
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)

    def body(state, xs):
        do_fusion, t = xs
        mask = step_edge_mask(
            mask_key, t, el.E, cfg.drop_prob, cfg.B,
            fold_t=social_stream_fold(t, STREAM_LINK),
        )
        st = sparse_pushsum_step(state, mask, src, dst, valid, "xla")
        key = jax.random.fold_in(
            base_key, social_stream_fold(t, STREAM_SIGNAL)
        )
        u = jax.random.uniform(key, (topo.N,))
        cdf = jnp.cumsum(truth_probs, axis=-1)
        sig = jnp.minimum((u[:, None] > cdf).sum(axis=-1), model.S - 1)
        loglik = jnp.take_along_axis(
            log_tables, sig[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]
        z = st.z + loglik
        mu = kl_dual_averaging_update(z, st.m)
        z_f, m_f = hps_fusion(z, st.m, rep_mask, topo.M)
        z = jnp.where(do_fusion, z_f, z)
        m = jnp.where(do_fusion, m_f, st.m)
        return st._replace(z=z, m=m), mu

    def run():
        _, mus = jax.lax.scan(
            body, state0, (fuse, jnp.arange(T, dtype=jnp.int32))
        )
        log_mu = jnp.log(jnp.maximum(mus, _MU_FLOOR))
        return mus, log_mu - log_mu[:, :, model.truth : model.truth + 1]

    return jax.jit(run)()


class TestEngineEquivalence:
    """Acceptance: fused engine == pre-refactor oracle, bit for bit."""

    @pytest.mark.parametrize("drop,gamma,B", [(0.0, 4, 1), (0.3, 8, 2),
                                              (0.6, 3, 4)])
    def test_fused_engine_matches_oracle(self, drop, gamma, B):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=B, drop_prob=drop)
        mus, lr = _oracle(model, cfg, T=40, seed=3, signal_seed=11)
        res = run_social_learning(model, cfg, T=40, seed=3, signal_seed=11,
                                  backend="xla")
        np.testing.assert_array_equal(np.asarray(res.beliefs),
                                      np.asarray(mus))
        np.testing.assert_array_equal(np.asarray(res.log_ratio),
                                      np.asarray(lr))

    def test_pallas_backend_matches_xla(self):
        """interpret-mode fused kernels == XLA lowerings over a full run
        (fp tolerance: the softmax max-subtraction reorders rounding)."""
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        x = run_social_learning(model, cfg, T=50, seed=0, backend="xla")
        p = run_social_learning(model, cfg, T=50, seed=0, backend="pallas")
        np.testing.assert_allclose(np.asarray(p.beliefs),
                                   np.asarray(x.beliefs),
                                   rtol=1e-4, atol=1e-5)

    def test_dense_free_runtime_matches_config_path(self):
        """block_complete_edge_list + run_social_runtime (the N ~ 1e4 path
        that never builds an (N, N) adjacency) == the HPSConfig path."""
        topo, model = _setup()
        el, rep_mask = block_complete_edge_list([6, 6, 6])
        rt = social_runtime_from_edge_list(el, rep_mask, drop_prob=0.3,
                                           gamma_period=8, B=2)
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        a = run_social_runtime(model, rt, topo.M, T=40, seed=5,
                               signal_seed=9)
        b = run_social_learning(model, cfg, T=40, seed=5, signal_seed=9)
        np.testing.assert_array_equal(np.asarray(a.beliefs),
                                      np.asarray(b.beliefs))

    def test_store_shapes_and_consistency(self):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        N, m, T = topo.N, model.m, 60
        traj = run_social_learning(model, cfg, T=T, seed=0)
        lrr = run_social_learning(model, cfg, T=T, seed=0, store="log_ratio")
        fin = run_social_learning(model, cfg, T=T, seed=0, store="final")
        assert traj.beliefs.shape == traj.log_ratio.shape == (T, N, m)
        assert lrr.beliefs.shape == (N, m) and lrr.log_ratio.shape == (T,)
        assert fin.beliefs.shape == fin.log_ratio.shape == (N, m)
        b = np.asarray(traj.beliefs)
        lr = np.asarray(traj.log_ratio)
        np.testing.assert_array_equal(np.asarray(fin.beliefs), b[-1])
        np.testing.assert_array_equal(np.asarray(lrr.beliefs), b[-1])
        np.testing.assert_array_equal(np.asarray(fin.log_ratio), lr[-1])
        worst = np.delete(lr, model.truth, axis=2).max(axis=(1, 2))
        np.testing.assert_array_equal(np.asarray(lrr.log_ratio), worst)

    def test_invalid_store_rejected(self):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        with pytest.raises(ValueError, match="store"):
            run_social_learning(model, cfg, T=5, store="everything")


# The jaxpr walker these tests introduced now lives in repro.statics.walk
# (PR 6); imported under the historical names so the assertions below stay
# bit-for-bit what they were when the helpers were local.
from repro.statics.walk import collect_avals as _collect_avals  # noqa: E402
from repro.statics.walk import subjaxprs as _subjaxprs  # noqa: E402,F401


class TestNoTrajectoryMaterialized:
    """Acceptance: store="final" holds no (T, ...) value in its jaxpr."""

    T = 37   # distinct from N=18, m=3, E=90 so the walker cannot confuse axes

    def _shapes(self, store):
        from repro.core.social import _social_scan_core, make_social_runtime

        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        rt = make_social_runtime(cfg)
        truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)

        def run(mk, sk):
            return _social_scan_core(
                mk, sk, rt, model.log_tables().astype(jnp.float32),
                jnp.cumsum(truth_probs, axis=-1),
                truth=model.truth, M=topo.M, T=self.T, store=store,
                backend="xla",
            )

        key = jax.random.PRNGKey(0)
        return _collect_avals(jax.make_jaxpr(run)(key, key).jaxpr, [])

    def test_final_store_has_no_T_value(self):
        shapes = self._shapes("final")
        assert shapes, "jaxpr walker found no values"
        traj_like = [s for s in shapes if len(s) >= 2 and s[0] == self.T]
        assert not traj_like, f"(T, ...) intermediates: {traj_like}"

    def test_log_ratio_store_carries_only_curves(self):
        shapes = self._shapes("log_ratio")
        traj_like = [s for s in shapes if len(s) >= 2 and s[0] == self.T]
        assert not traj_like, f"(T, ...) intermediates: {traj_like}"
        assert (self.T,) in shapes          # the in-scan-reduced curve

    def test_detector_flags_trajectory_store(self):
        """Sanity: the same walker does find the (T, N, m) history in the
        trajectory store, so the final-store assertion has teeth."""
        shapes = self._shapes("trajectory")
        assert (self.T, 18, 3) in shapes


class TestPRNGStreams:
    def test_streams_disjoint_over_horizon(self):
        """Regression for the seed scheme, which folded plain ``t`` into
        both base keys — with seed == signal_seed the link-mask key at t
        EQUALED the signal key at t. The two fold-in domains must never
        intersect over any horizon."""
        T = 20000
        t = np.arange(T, dtype=np.uint64)
        folds = {
            s: set(np.asarray(social_stream_fold(t, s)).tolist())
            for s in (STREAM_LINK, STREAM_SIGNAL)
        }
        assert not (folds[STREAM_LINK] & folds[STREAM_SIGNAL])
        assert len(set().union(*folds.values())) == 2 * T
        assert N_SOCIAL_STREAMS == 2

    def test_seed_scheme_would_have_aliased(self):
        """The bug being regressed: with one shared fold value the two
        per-iteration keys coincide whenever the base keys do."""
        k = jax.random.PRNGKey(7)
        np.testing.assert_array_equal(       # the seed scheme: both fold t
            np.asarray(jax.random.fold_in(k, 3)),
            np.asarray(jax.random.fold_in(k, 3)),
        )
        new_mask = jax.random.fold_in(k, social_stream_fold(3, STREAM_LINK))
        new_sig = jax.random.fold_in(k, social_stream_fold(3, STREAM_SIGNAL))
        assert (np.asarray(new_mask) != np.asarray(new_sig)).any()

    def test_equal_seeds_still_learn_and_streams_both_matter(self):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.4)
        base = run_social_learning(model, cfg, T=80, seed=5, signal_seed=5)
        other_sig = run_social_learning(model, cfg, T=80, seed=5,
                                        signal_seed=6)
        other_mask = run_social_learning(model, cfg, T=80, seed=6,
                                         signal_seed=5)
        b = np.asarray(base.beliefs)
        assert np.isfinite(b).all()
        assert (b != np.asarray(other_sig.beliefs)).any()    # signals matter
        assert (b != np.asarray(other_mask.beliefs)).any()   # masks matter


def _grid_fixture():
    topos = [make_hierarchy([6, 6, 6], topology="ring+",
                            extra_edge_prob=0.8, seed=s) for s in range(2)]
    model = make_confused_model(N=18, m=3, truth=1, confusion=0.3, seed=0)
    cfgs = []
    for topo in topos:
        for drop in (0.0, 0.3, 0.6):
            for gamma in (4, 8):
                cfgs.append(HPSConfig(topo=topo, gamma_period=gamma, B=2,
                                      drop_prob=drop))
    return model, cfgs


class TestSocialSweep:
    def test_drop_gamma_topo_seed_grid_single_trace(self):
        """Acceptance: 2 topologies x 3 drops x 2 Γ x 4 seeds = 48
        scenarios as ONE compiled program — one jit cache entry, no retrace
        on a second seed batch."""
        from repro.core.sweeps import _social_sweep_fn, cache_registry

        model, cfgs = _grid_fixture()
        res = run_social_grid(model, cfgs, T=25, seeds=list(range(4)))
        assert res.K == 48
        assert res.log_ratio.shape == (48, 25)
        assert res.beliefs.shape == (48, 18, 3)
        fn = _social_sweep_fn(None, "data", truth=model.truth, M=3, T=25,
                              store="log_ratio", backend="xla")
        assert fn._cache_size() == 1
        res2 = run_social_grid(model, cfgs, T=25, seeds=list(range(4, 8)))
        assert fn._cache_size() == 1         # same shapes -> no retrace
        assert res2.K == 48
        info = cache_registry()["social.compiled"].cache_info()
        assert info.currsize <= info.maxsize

    def test_uniform_E_grid_matches_single_runs_bit_identical(self):
        """Acceptance: traced (drop, Γ) on the vmap axis must reproduce
        each config's single run bit for bit (single topology -> no edge
        padding -> identical link-mask streams)."""
        topo, model = _setup()
        cfgs = [HPSConfig(topo=topo, gamma_period=g, B=2, drop_prob=d)
                for d in (0.0, 0.4, 0.8) for g in (3, 8)]
        res = run_social_grid(model, cfgs, T=30, seeds=[0, 3],
                              store="log_ratio")
        for k in range(res.K):
            ci, sd = int(res.cfg[k]), int(res.seed[k])
            single = run_social_learning(
                model, cfgs[ci], T=30, seed=sd, signal_seed=sd,
                backend="xla", store="log_ratio",
            )
            np.testing.assert_array_equal(np.asarray(res.log_ratio[k]),
                                          np.asarray(single.log_ratio))
            np.testing.assert_array_equal(np.asarray(res.beliefs[k]),
                                          np.asarray(single.beliefs))
            assert np.float32(res.drop_prob[k]) == np.float32(
                cfgs[ci].drop_prob)
            assert int(res.gamma[k]) == cfgs[ci].gamma_period

    def test_mixed_E_grid_matches_padded_runtimes(self):
        """Topology draws with different edge counts pad to a common E —
        which re-indexes the (E,) link-mask draw, so the contract is
        bit-identity against the single run on the SAME padded runtime."""
        from repro.core.social import make_social_runtime

        model, cfgs = _grid_fixture()
        e_max = max(int(np.count_nonzero(c.topo.adj)) for c in cfgs)
        e_all = {int(np.count_nonzero(c.topo.adj)) for c in cfgs}
        assert len(e_all) > 1, "fixture must exercise heterogeneous E"
        res = run_social_grid(model, cfgs, T=25, seeds=[1],
                              store="log_ratio")
        for k in range(0, res.K, 5):
            ci, sd = int(res.cfg[k]), int(res.seed[k])
            rt = make_social_runtime(cfgs[ci], e_max=e_max)
            single = run_social_runtime(
                model, rt, cfgs[ci].topo.M, T=25, seed=sd,
                backend="xla", store="log_ratio",
            )
            np.testing.assert_array_equal(np.asarray(res.log_ratio[k]),
                                          np.asarray(single.log_ratio))
            np.testing.assert_array_equal(np.asarray(res.beliefs[k]),
                                          np.asarray(single.beliefs))

    def test_sweep_cross_product_coordinates(self):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.0)
        res = run_social_sweep(model, cfg, T=10, drop_probs=[0.0, 0.5],
                               gammas=[2, 8], seeds=[0, 1, 2])
        assert res.K == 12
        coords = {(float(res.drop_prob[k]), int(res.gamma[k]),
                   int(res.seed[k])) for k in range(res.K)}
        assert coords == {(d, g, s) for d in (0.0, 0.5) for g in (2, 8)
                          for s in (0, 1, 2)}

    def test_trajectory_store_sweep(self):
        topo, model = _setup()
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        res = run_social_sweep(model, cfg, T=15, seeds=[0, 1],
                               store="trajectory")
        assert res.beliefs.shape == (2, 15, 18, 3)
        single = run_social_learning(model, cfg, T=15, seed=1, signal_seed=1)
        np.testing.assert_array_equal(np.asarray(res.beliefs[1]),
                                      np.asarray(single.beliefs))

    def test_incompatible_configs_rejected(self):
        model, cfgs = _grid_fixture()
        other = make_hierarchy([5, 5, 5], topology="complete")
        bad = HPSConfig(topo=other, gamma_period=4, B=2, drop_prob=0.0)
        with pytest.raises(ValueError, match="share"):
            run_social_grid(model, [cfgs[0], bad], T=5, seeds=[0])
        with pytest.raises(ValueError, match="store"):
            run_social_grid(model, [cfgs[0]], T=5, seeds=[0], store="bogus")
        with pytest.raises(ValueError, match="at least one"):
            run_social_grid(model, [], T=5, seeds=[0])

    def test_compiled_cache_is_lru_bounded(self):
        from repro.core.sweeps import cache_registry

        reg = cache_registry()
        compiled = reg["social.compiled"].cache_info()
        runtime = reg["social.runtime"].cache_info()
        assert 0 < compiled.maxsize <= 64
        assert 0 < runtime.maxsize <= 64
        assert compiled.currsize <= compiled.maxsize

    def test_sharded_sweep_equals_single_device(self):
        """K=12 grid over a 4-device data mesh (subprocess, fake CPU
        devices): bit-identical to the single-device vmap."""
        prog = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json
            import numpy as np
            import jax
            from repro.core.graphs import make_hierarchy
            from repro.core.hps import HPSConfig
            from repro.core.signals import make_confused_model
            from repro.core.sweeps import run_social_sweep
            from repro.launch import compat

            topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
            model = make_confused_model(N=18, m=3, truth=1, confusion=0.5,
                                        seed=0)
            cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.0)
            kw = dict(drop_probs=[0.0, 0.4, 0.8], gammas=[4, 16],
                      seeds=[0, 1])
            r1 = run_social_sweep(model, cfg, T=20, **kw)
            mesh = compat.make_mesh((4,), ("data",))
            r2 = run_social_sweep(model, cfg, T=20, mesh=mesh, **kw)
            same = bool((np.asarray(r1.log_ratio)
                         == np.asarray(r2.log_ratio)).all())
            err = float(np.abs(np.asarray(r1.beliefs)
                               - np.asarray(r2.beliefs)).max())
            print(json.dumps({"K": int(r2.K), "same": same, "err": err,
                              "devices": jax.device_count()}))
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        for _ in range(2):   # CPU collective rendezvous can flake; retry once
            out = subprocess.run([sys.executable, "-c", prog],
                                 capture_output=True, text=True,
                                 timeout=420, env=env, cwd=REPO)
            if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
                break
        assert out.returncode == 0, out.stderr[-3000:]
        res = json.loads(
            [l for l in out.stdout.splitlines() if l.startswith("{")][-1])
        assert res["devices"] == 4
        assert res["K"] == 12            # pad rows sliced off
        assert res["same"] and res["err"] == 0.0
