"""Precision-policy layer: fp32 bit-identity, bf16 storage, donation,
halo variants, cache registry, and the policy-aware budget models.

The contract under test (repro.core.precision): the DEFAULT policy (None
or "fp32") is bit-identical to the pre-policy engines — every cast the
policy threading inserted is a same-dtype ``astype`` that traces to a
no-op — while "bf16" swaps only the *storage* dtype of persistent state
(scan carries, relay latches) and keeps fp32 accumulators, so results
stay finite and within a quantization envelope (tests in
test_bf16_envelope.py). Donation and the sorted-gather hints must never
change values, only buffers/lowering.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks
from repro.core.byzantine import ByzantineConfig, run_byzantine_learning
from repro.core.graphs import (
    edge_list,
    make_hierarchy,
    random_strongly_connected,
    sort_by_dst,
)
from repro.core.hps import HPSConfig, run_hps
from repro.core.precision import BF16, FP32, Policy, resolve_policy
from repro.core.pushsum import (
    _get_step_jit,
    init_sparse_state,
    run_pushsum_sparse,
    sparse_pushsum_step,
    sparse_pushsum_step_jit,
)
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning
from repro.core.sweeps import cache_registry, run_pushsum_sweep


def _graph(n=12, p=0.3, seed=0):
    rng = np.random.default_rng(seed)
    el = edge_list(random_strongly_connected(n, p, rng))
    w = rng.normal(size=(n, 3)).astype(np.float32)
    return el, w


class TestPolicy:
    def test_default_is_fp32_and_default(self):
        p = Policy()
        assert p == FP32
        assert p.is_default
        assert p.storage_dtype == jnp.float32
        assert p.compute_dtype == jnp.float32
        assert p.accum_dtype == jnp.float32
        assert p.storage_bytes == 4

    def test_bf16_halves_storage_only(self):
        assert BF16.storage_dtype == jnp.bfloat16
        assert BF16.storage_bytes == 2
        assert BF16.accum_dtype == jnp.float32
        assert not BF16.is_default

    def test_resolve_names_and_passthrough(self):
        assert resolve_policy(None) == FP32
        assert resolve_policy("fp32") == FP32
        assert resolve_policy("bf16") == BF16
        assert resolve_policy(BF16) is BF16
        with pytest.raises(ValueError):
            resolve_policy("int8")

    def test_accum_must_stay_wide(self):
        with pytest.raises(ValueError):
            Policy(accum="bfloat16").validate()

    def test_tags_are_distinct(self):
        assert FP32.tag() != BF16.tag()


class TestFp32BitIdentity:
    """policy=None and policy="fp32" must be the SAME traced program —
    asserted exactly (==), not to a tolerance, per engine."""

    def test_pushsum_sweep(self):
        el, w = _graph()
        kw = dict(drop_probs=[0.0, 0.4], seeds=[0, 1], B=2)
        r0 = run_pushsum_sweep(w, el, 25, **kw)
        r1 = run_pushsum_sweep(w, el, 25, policy="fp32", **kw)
        np.testing.assert_array_equal(np.asarray(r0.err), np.asarray(r1.err))

    def test_pushsum_sparse_runtime(self):
        el, w = _graph(seed=3)
        f0, t0 = run_pushsum_sparse(w, el.src, el.dst, 20, drop_prob=0.3,
                                    B=2, key=jax.random.PRNGKey(7))
        f1, t1 = run_pushsum_sparse(w, el.src, el.dst, 20, drop_prob=0.3,
                                    B=2, key=jax.random.PRNGKey(7),
                                    policy="fp32")
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
        np.testing.assert_array_equal(np.asarray(f0.z), np.asarray(f1.z))

    def test_dst_sorted_hint_changes_nothing(self):
        """indices_are_sorted is metadata: on a genuinely sorted index the
        hinted program must produce identical values."""
        el, w = _graph(seed=5)
        el_s, _, _ = sort_by_dst(el)
        f0, t0 = run_pushsum_sparse(w, el_s.src, el_s.dst, 20, drop_prob=0.2,
                                    B=2, key=jax.random.PRNGKey(1))
        f1, t1 = run_pushsum_sparse(w, el_s.src, el_s.dst, 20, drop_prob=0.2,
                                    B=2, key=jax.random.PRNGKey(1),
                                    dst_sorted=True)
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))

    def test_social(self):
        topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
        model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                    seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        r0 = run_social_learning(model, cfg, T=25, seed=0)
        r1 = run_social_learning(model, cfg, T=25, seed=0, policy="fp32")
        np.testing.assert_array_equal(np.asarray(r0.beliefs),
                                      np.asarray(r1.beliefs))

    def test_hps(self):
        topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        w = np.random.default_rng(3).normal(size=(15, 2)).astype(np.float32)
        r0 = run_hps(w, cfg, T=20, seed=0)
        r1 = run_hps(w, cfg, T=20, seed=0, policy="fp32")
        np.testing.assert_array_equal(np.asarray(r0.ratio),
                                      np.asarray(r1.ratio))
        np.testing.assert_array_equal(np.asarray(r0.gap),
                                      np.asarray(r1.gap))

    def test_byzantine(self):
        topo = make_hierarchy([7] * 4, topology="complete", seed=0)
        model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=4,
                              attack=attacks.sign_flip())
        r0 = run_byzantine_learning(model, cfg, T=10, seed=0, core="sparse")
        r1 = run_byzantine_learning(model, cfg, T=10, seed=0, core="sparse",
                                    policy="fp32")
        np.testing.assert_array_equal(np.asarray(r0.r), np.asarray(r1.r))
        np.testing.assert_array_equal(np.asarray(r0.decisions),
                                      np.asarray(r1.decisions))


class TestBf16Storage:
    def test_init_state_dtype(self):
        _, w = _graph()
        st = init_sparse_state(jnp.asarray(w), 40, policy="bf16")
        for leaf in st:
            assert leaf.dtype == jnp.bfloat16
        st32 = init_sparse_state(jnp.asarray(w), 40)
        for leaf in st32:
            assert leaf.dtype == jnp.float32

    def test_step_carries_storage_outputs(self):
        el, w = _graph()
        st = init_sparse_state(jnp.asarray(w), int(el.E), policy=BF16)
        mask = jnp.ones((int(el.E),), bool)
        out = sparse_pushsum_step(st, mask, el.src, el.dst, el.valid,
                                  "xla", policy=BF16)
        for leaf in out:
            assert leaf.dtype == jnp.bfloat16

    def test_sweep_runs_finite_and_decays(self):
        el, w = _graph()
        r = run_pushsum_sweep(w, el, 40, drop_probs=[0.0, 0.3],
                              seeds=[0, 1], B=2, policy="bf16")
        err = np.asarray(r.err, np.float32)
        assert np.isfinite(err).all()
        assert (err[:, -1] <= err[:, 0] + 1e-3).all()

    def test_social_beliefs_stay_float32(self):
        """Outputs are upcast after the scan: user-facing arrays are fp32
        regardless of the storage policy."""
        topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
        model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                    seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
        r = run_social_learning(model, cfg, T=15, seed=0, policy="bf16")
        assert np.asarray(r.beliefs).dtype == np.float32
        assert np.isfinite(np.asarray(r.beliefs)).all()


class TestDonation:
    def test_lowered_step_aliases_all_state_buffers(self):
        text = _get_step_jit("xla", False, None).lower(
            *_tiny_step_args(None)).as_text()
        assert text.count("tf.aliasing_output") == 6

    def test_lowered_step_aliases_under_bf16(self):
        text = _get_step_jit("xla", False, BF16).lower(
            *_tiny_step_args(BF16)).as_text()
        assert text.count("tf.aliasing_output") == 6

    def test_jit_step_matches_eager(self):
        el, w = _graph(seed=9)
        st = init_sparse_state(jnp.asarray(w), int(el.E))
        mask = jnp.ones((int(el.E),), bool)
        eager = sparse_pushsum_step(st, mask, el.src, el.dst, el.valid,
                                    "xla")
        jitted = sparse_pushsum_step_jit(st, mask, el.src, el.dst, el.valid,
                                         "xla")
        for a, b in zip(eager, jitted):
            # whole-function jit may contract FMAs: ~1 ulp, not bitwise
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_statics_donation_check_passes(self):
        from repro.statics.precision import step_donation_findings

        assert step_donation_findings("xla", None) == []
        assert step_donation_findings("xla", "bf16") == []


def _tiny_step_args(pol):
    rng = np.random.default_rng(0)
    n, e, d = 7, 11, 2
    w = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    state = init_sparse_state(w, e, policy=pol)
    mask = jnp.ones((e,), bool)
    src = jnp.asarray(rng.integers(0, n, size=e).astype(np.int32))
    dst = jnp.asarray(np.sort(rng.integers(0, n, size=e)).astype(np.int32))
    valid = jnp.ones((e,), bool)
    return state, mask, src, dst, valid, None


class TestHaloVariants:
    def test_scatter_matches_psum_exactly_on_emulation(self):
        """halo="scatter" reorders the collective into reduce-scatter +
        all-gather; under fp32 it must move the same values — asserted
        exactly on the single-device emulation path."""
        rng = np.random.default_rng(4)
        el = edge_list(random_strongly_connected(32, 0.2, rng))
        w = rng.normal(size=(32, 2)).astype(np.float32)
        kw = dict(drop_probs=[0.0, 0.3], seeds=[0, 1], B=2, graph_shards=2)
        r_p = run_pushsum_sweep(w, el, 20, halo="psum", **kw)
        r_s = run_pushsum_sweep(w, el, 20, halo="scatter", **kw)
        np.testing.assert_array_equal(np.asarray(r_p.err),
                                      np.asarray(r_s.err))

    def test_bf16_scatter_finite(self):
        rng = np.random.default_rng(4)
        el = edge_list(random_strongly_connected(32, 0.2, rng))
        w = rng.normal(size=(32, 2)).astype(np.float32)
        r = run_pushsum_sweep(w, el, 20, drop_probs=[0.2], seeds=[0], B=2,
                              graph_shards=2, policy="bf16", halo="scatter")
        assert np.isfinite(np.asarray(r.err, np.float32)).all()

    def test_bad_halo_rejected(self):
        el, w = _graph()
        with pytest.raises(ValueError):
            run_pushsum_sweep(w, el, 5, drop_probs=[0.0], seeds=[0], B=2,
                              graph_shards=2, halo="ring")


class TestCacheRegistry:
    def test_registry_lists_every_engine_cache(self):
        reg = cache_registry()
        for name in (
            "pushsum.sweep-jit", "pushsum.sweep2d-jit", "pushsum.step-jit",
            "byz.compiled", "byz.grid", "byz.runtime",
            "social.compiled", "social.runtime",
            "hps.compiled", "hps.runtime",
        ):
            assert name in reg, name

    def test_cache_info_counts_and_clear(self):
        el, w = _graph()
        h = cache_registry()["pushsum.sweep-jit"]
        h.clear()
        assert h.cache_info().currsize == 0
        run_pushsum_sweep(w, el, 5, drop_probs=[0.0], seeds=[0], B=2)
        assert h.cache_info().currsize >= 1
        h.clear()
        assert h.cache_info().currsize == 0


class TestPolicyBudgets:
    def test_default_reproduces_historical_numbers(self):
        from repro.statics.memory import (
            pushsum_step_bytes,
            social_step_bytes,
        )

        # the seed-era fp32 constants, unchanged by the policy refactor
        assert pushsum_step_bytes(1024, 3102, 1) == \
            3102 * 4 * 4 + 1024 * 4 * 4 + 3102 * 4
        assert social_step_bytes(18, 90, 3) == \
            90 * 5 * 4 + 2 * 18 * 3 * 4 + 18 * 3 * 4 + 90 * 4

    def test_bf16_roughly_halves_state_traffic(self):
        from repro.statics.memory import (
            pushsum_sharded_step_bytes,
            pushsum_step_bytes,
            social_step_bytes,
        )

        for f32, b16 in (
            (pushsum_step_bytes(131072, 524288, 4),
             pushsum_step_bytes(131072, 524288, 4, policy="bf16")),
            (social_step_bytes(16384, 114688, 3),
             social_step_bytes(16384, 114688, 3, policy="bf16")),
            (pushsum_sharded_step_bytes(1 << 20, 1 << 21, n_shards=8),
             pushsum_sharded_step_bytes(1 << 20, 1 << 21, n_shards=8,
                                        policy="bf16")),
        ):
            assert b16 < f32
            # masks/ids stay 4 B, so the ratio lands above exactly-half
            assert 0.5 <= b16 / f32 <= 0.7

    def test_acceptance_rows_hit_40pct_budget_reduction(self):
        """The two acceptance benchmarks' budget-model bytes drop >= 40%
        under bf16 (the committed BENCH rows carry the same numbers)."""
        from repro.statics.memory import pushsum_step_bytes, \
            social_step_bytes

        ps32 = pushsum_step_bytes(131072, 393216, 4)
        ps16 = pushsum_step_bytes(131072, 393216, 4, policy="bf16")
        so32 = social_step_bytes(16384, 114688, 3)
        so16 = social_step_bytes(16384, 114688, 3, policy="bf16")
        assert ps16 <= 0.6 * ps32
        assert so16 <= 0.6 * so32

    def test_halo_wire_model(self):
        from repro.analysis.roofline import pushsum_halo_wire_bytes

        n, d, s = 1 << 20, 1, 8
        psum = pushsum_halo_wire_bytes(n, d, s)
        scat32 = pushsum_halo_wire_bytes(n, d, s, variant="scatter")
        scat16 = pushsum_halo_wire_bytes(n, d, s, variant="scatter",
                                         storage_bytes=2)
        assert psum == scat32            # fp32: same bytes, different order
        assert scat16 == pytest.approx(0.75 * psum)
        assert pushsum_halo_wire_bytes(n, d, 1) == 0.0
        with pytest.raises(ValueError):
            pushsum_halo_wire_bytes(n, d, s, variant="tree")

    def test_validate_bench_reads_policy_tag(self, tmp_path):
        import json

        from repro.statics.memory import validate_bench

        # a bf16 row whose config would bust the fp32 budget but fits at
        # storage width 2 — the policy tag must be what makes it pass
        N = 1 << 28
        E = 4 * N
        row = {"us_per_call": 1.0,
               "derived": f"E={E};d=1;policy=bf16"}
        (tmp_path / "BENCH_t.json").write_text(
            json.dumps({f"x_N{N}": row}))
        bf = validate_bench(tmp_path)
        row32 = {"us_per_call": 1.0, "derived": f"E={E};d=1"}
        (tmp_path / "BENCH_t.json").write_text(
            json.dumps({f"x_N{N}": row32}))
        f32 = validate_bench(tmp_path)
        assert len(f32) == 1 and "exceeds" in f32[0].message
        assert bf == []

    def test_validate_bench_measured_over_budget(self, tmp_path):
        import json

        from repro.statics.memory import validate_bench

        # a row whose recorded compiled traffic exceeds its analytic
        # budget must be a finding: the model claims to upper-bound the
        # program (the bench_table roofline column relies on it)
        row = {"us_per_call": 1.0,
               "derived": "E=3068;d=4;bytes_per_step=999999999"}
        (tmp_path / "BENCH_t.json").write_text(
            json.dumps({"x_N1024": row}))
        fs = validate_bench(tmp_path)
        assert len(fs) == 1
        assert "no longer upper-bounds" in fs[0].message
        # NaN traffic (backend without cost_analysis) is not a finding
        row["derived"] = "E=3068;d=4;bytes_per_step=nan"
        (tmp_path / "BENCH_t.json").write_text(
            json.dumps({"x_N1024": row}))
        assert validate_bench(tmp_path) == []


class TestFp32CarryContract:
    def test_flags_synthetic_fp32_carry(self):
        from repro.statics.precision import find_fp32_scan_state
        from repro.statics.walk import trace

        def bad(w):
            def body(c, t):
                return c * 0.5, c.sum()
            return jax.lax.scan(body, w, jnp.arange(5))

        closed = trace(bad, np.zeros((13, 3), np.float32))
        fs = find_fp32_scan_state(closed, {"N": 13, "d": 3, "T": 5})
        assert len(fs) == 1
        assert fs[0].check == "fp32-carry"

    def test_bf16_engines_pass(self):
        """The shipped engines under policy="bf16" carry no fp32
        per-edge/per-node state (the full-fixture version runs in the
        repro.statics lint)."""
        from repro.statics.precision import find_fp32_scan_state
        from repro.statics.walk import trace

        el, w = _graph()
        closed = trace(
            lambda w_: run_pushsum_sparse(
                w_, el.src, el.dst, 5, drop_prob=0.2, B=2,
                policy="bf16")[0].z,
            w)
        assert find_fp32_scan_state(
            closed, {"N": 12, "d": 3, "E": int(el.E)}) == []

    def test_bf16_carry_allows_fp32_accum_transients(self):
        from repro.statics.precision import find_fp32_scan_state
        from repro.statics.walk import trace

        def good(w):
            def body(c, t):
                acc = c.astype(jnp.float32) * 2.0    # in-body accum: fine
                return acc.astype(jnp.bfloat16), acc.sum()
            return jax.lax.scan(body, w.astype(jnp.bfloat16),
                                jnp.arange(5))

        closed = trace(good, np.zeros((13, 3), np.float32))
        assert find_fp32_scan_state(closed, {"N": 13, "d": 3, "T": 5}) == []
