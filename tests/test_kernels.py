"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle, plus hypothesis property tests on the Byzantine filter."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.trimmed_mean.ops import trimmed_mean, trimmed_mean_pytree
from repro.kernels.trimmed_mean.ref import trimmed_mean_ref
from repro.kernels.wkv6.ref import wkv6_ref, wkv6_chunked_jnp, wkv6_decode_step
from repro.kernels.wkv6.wkv6 import wkv6_chunked_pallas
from repro.kernels.swa.ref import attn_decode_ref
from repro.kernels.swa.swa import attn_decode_pallas
from repro.kernels.swa.prefill import swa_prefill_pallas
from repro.models.layers import _naive_attention

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# trimmed mean (Byzantine filter)
# ---------------------------------------------------------------------------

class TestTrimmedMean:
    @pytest.mark.parametrize("W,D,F", [
        (8, 100, 0), (8, 1000, 3), (16, 5000, 3), (16, 2048, 7),
        (32, 4096, 7), (5, 333, 2),
    ])
    def test_matches_ref(self, W, D, F):
        x = jnp.asarray(RNG.normal(size=(W, D)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(trimmed_mean(x, F, backend="pallas")),
            np.asarray(trimmed_mean_ref(x, F)),
            rtol=1e-5, atol=1e-6,
        )

    @pytest.mark.parametrize("dtype,tol", [
        (jnp.float32, 1e-5), (jnp.bfloat16, 3e-2),
    ])
    def test_dtypes(self, dtype, tol):
        x = jnp.asarray(RNG.normal(size=(16, 777)), dtype=dtype)
        got = np.asarray(trimmed_mean(x, 4, backend="pallas"), np.float32)
        want = np.asarray(trimmed_mean_ref(x, 4), np.float32)
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_duplicates(self):
        x = jnp.asarray(np.round(RNG.normal(size=(16, 512)) * 2) / 2,
                        dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(trimmed_mean(x, 5, backend="pallas")),
            np.asarray(trimmed_mean_ref(x, 5)), rtol=1e-5, atol=1e-6,
        )

    def test_rejects_overtrim(self):
        x = jnp.zeros((4, 8))
        with pytest.raises(ValueError):
            trimmed_mean(x, 2)

    def test_pytree(self):
        tree = {
            "a": jnp.asarray(RNG.normal(size=(16, 3, 5)).astype(np.float32)),
            "b": jnp.asarray(RNG.normal(size=(16, 7)).astype(np.float32)),
        }
        out = trimmed_mean_pytree(tree, 2, backend="pallas")
        want = trimmed_mean_ref(tree["a"].reshape(16, -1), 2).reshape(3, 5)
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        assert out["b"].shape == (7,)

    @settings(max_examples=25, deadline=None)
    @given(
        W=st.integers(3, 20),
        D=st.integers(1, 64),
        F=st.integers(0, 4),
        seed=st.integers(0, 2**16),
    )
    def test_property_bounded_and_permutation_invariant(self, W, D, F, seed):
        if W <= 2 * F:
            return
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(W, D)).astype(np.float32) * 10
        out = np.asarray(trimmed_mean(jnp.asarray(x), F, backend="pallas"))
        s = np.sort(x, axis=0)
        kept_lo, kept_hi = s[F], s[W - F - 1]
        assert (out >= kept_lo - 1e-4).all() and (out <= kept_hi + 1e-4).all()
        perm = rng.permutation(W)
        out_p = np.asarray(trimmed_mean(jnp.asarray(x[perm]), F, backend="pallas"))
        np.testing.assert_allclose(out, out_p, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        W=st.integers(5, 16),
        F=st.integers(1, 3),
        seed=st.integers(0, 2**16),
    )
    def test_property_byzantine_resistance(self, W, F, seed):
        """Corrupting <= F rows with arbitrarily large values keeps the
        output within the honest rows' range — the paper's Alg.2 filter
        guarantee, coordinate-wise."""
        if W <= 2 * F:
            return
        rng = np.random.default_rng(seed)
        D = 32
        honest = rng.normal(size=(W - F, D)).astype(np.float32)
        attack = (rng.choice([-1, 1], size=(F, D)) * 1e6).astype(np.float32)
        x = np.concatenate([honest, attack], axis=0)
        rng.shuffle(x, axis=0)
        out = np.asarray(trimmed_mean(jnp.asarray(x), F, backend="pallas"))
        assert (out >= honest.min(0) - 1e-3).all()
        assert (out <= honest.max(0) + 1e-3).all()


# ---------------------------------------------------------------------------
# WKV6 (chunked linear recurrence)
# ---------------------------------------------------------------------------

class TestWKV6:
    @pytest.mark.parametrize("BH,T,K,V,C", [
        (2, 64, 32, 32, 16), (3, 128, 64, 64, 64), (1, 96, 16, 48, 32),
        (2, 256, 64, 64, 128),
    ])
    def test_pallas_matches_ref(self, BH, T, K, V, C):
        r = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(BH, T, V)).astype(np.float32))
        lw = jnp.asarray(-np.exp(RNG.normal(size=(BH, T, K))).astype(np.float32))
        u = jnp.asarray(RNG.normal(size=(BH, K)).astype(np.float32))
        y_ref, s_ref = wkv6_ref(r, k, v, lw, u)
        y, s = wkv6_chunked_pallas(r, k, v, lw, u, chunk=C)
        # tolerance scales with chunk: C-term f32 sums reorder vs the scan
        tol = 2e-4 * max(C // 64, 1) * 5
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=tol, atol=tol)

    def test_chunked_jnp_matches_ref(self):
        BH, T, K, V = 2, 128, 32, 32
        r = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(BH, T, V)).astype(np.float32))
        lw = jnp.asarray(-np.exp(RNG.normal(size=(BH, T, K))).astype(np.float32))
        u = jnp.asarray(RNG.normal(size=(BH, K)).astype(np.float32))
        y_ref, s_ref = wkv6_ref(r, k, v, lw, u)
        for C in (16, 32, 64):
            y, s = wkv6_chunked_jnp(r, k, v, lw, u, chunk=C)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-4, atol=2e-4)

    def test_decode_step_consistency(self):
        """T sequential decode steps == full-sequence reference."""
        BH, T, K, V = 2, 16, 16, 16
        r = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(BH, T, K)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(BH, T, V)).astype(np.float32))
        lw = jnp.asarray(-np.exp(RNG.normal(size=(BH, T, K))).astype(np.float32))
        u = jnp.asarray(RNG.normal(size=(BH, K)).astype(np.float32))
        y_ref, s_ref = wkv6_ref(r, k, v, lw, u)
        s = jnp.zeros((BH, K, V))
        ys = []
        for t in range(T):
            y, s = wkv6_decode_step(r[:, t], k[:, t], v[:, t], lw[:, t], u, s)
            ys.append(y)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), C=st.sampled_from([16, 32]))
    def test_property_strong_decay_forgets(self, seed, C):
        """With log-decay ~ -8 (w ~ 3e-4) the state contribution from >= 2
        chunks back is negligible — kernel must agree with ref regardless."""
        rng = np.random.default_rng(seed)
        BH, T, K = 1, 64, 16
        r = jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32))
        lw = jnp.full((BH, T, K), -8.0, jnp.float32)
        u = jnp.asarray(rng.normal(size=(BH, K)).astype(np.float32))
        y_ref, _ = wkv6_ref(r, k, v, lw, u)
        y, _ = wkv6_chunked_pallas(r, k, v, lw, u, chunk=C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# sliding-window flash decode
# ---------------------------------------------------------------------------

class TestSWADecode:
    @pytest.mark.parametrize("B,H,Hkv,Wc,dh,blk", [
        (2, 8, 2, 1024, 64, 256), (1, 4, 4, 512, 128, 128),
        (3, 6, 1, 768, 32, 256), (2, 2, 2, 2048, 64, 512),
    ])
    def test_matches_ref(self, B, H, Hkv, Wc, dh, blk):
        q = jnp.asarray(RNG.normal(size=(B, H, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)).astype(np.float32))
        lens = jnp.asarray(RNG.integers(1, Wc + 1, size=(B,)), jnp.int32)
        np.testing.assert_allclose(
            np.asarray(attn_decode_pallas(q, k, v, lens, block_w=blk)),
            np.asarray(attn_decode_ref(q, k, v, lens)),
            rtol=2e-4, atol=2e-4,
        )

    def test_bf16(self):
        B, H, Hkv, Wc, dh = 2, 4, 2, 512, 64
        q = jnp.asarray(RNG.normal(size=(B, H, dh)), dtype=jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)), dtype=jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)), dtype=jnp.bfloat16)
        lens = jnp.asarray([100, 512], jnp.int32)
        got = np.asarray(attn_decode_pallas(q, k, v, lens, block_w=128),
                         np.float32)
        want = np.asarray(attn_decode_ref(q, k, v, lens), np.float32)
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    def test_partial_cache_masks_tail(self):
        """Entries beyond `lengths` must not influence the result."""
        B, H, Hkv, Wc, dh = 1, 2, 1, 256, 32
        q = jnp.asarray(RNG.normal(size=(B, H, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(B, Hkv, Wc, dh)).astype(np.float32))
        lens = jnp.asarray([64], jnp.int32)
        out1 = attn_decode_pallas(q, k, v, lens, block_w=64)
        k2 = k.at[:, :, 64:].set(1e3)
        v2 = v.at[:, :, 64:].set(-1e3)
        out2 = attn_decode_pallas(q, k2, v2, lens, block_w=64)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6, atol=1e-6)


class TestSWAPrefill:
    @pytest.mark.parametrize("B,H,Hkv,S,dh,win,blk", [
        (2, 4, 2, 256, 64, 0, 64),      # full causal, GQA
        (1, 8, 2, 512, 32, 128, 128),   # sliding window
        (2, 2, 1, 256, 64, 64, 64),     # MQA + window
        (1, 4, 4, 256, 128, 0, 128),    # MHA
    ])
    def test_matches_naive(self, B, H, Hkv, S, dh, win, blk):
        q = jnp.asarray(RNG.normal(size=(B, H, S, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(B, Hkv, S, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(B, Hkv, S, dh)).astype(np.float32))
        got = swa_prefill_pallas(q, k, v, window=win, bq=blk, bk=blk)
        want = _naive_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=win,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    def test_window_band_skipping_is_exact(self):
        """Blocks outside the (causal, window) band are skipped; perturbing
        keys there must not change the output."""
        B, H, Hkv, S, dh, win = 1, 2, 2, 512, 32, 64
        q = jnp.asarray(RNG.normal(size=(B, H, S, dh)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(B, Hkv, S, dh)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(B, Hkv, S, dh)).astype(np.float32))
        out1 = swa_prefill_pallas(q, k, v, window=win, bq=64, bk=64)
        # corrupt keys/values far outside any query's window
        k2 = k.at[:, :, :256].set(1e3)
        v2 = v.at[:, :, :256].set(-1e3)
        out2 = swa_prefill_pallas(q, k2, v2, window=win, bq=64, bk=64)
        np.testing.assert_allclose(np.asarray(out1[:, :, 384:]),
                                   np.asarray(out2[:, :, 384:]),
                                   rtol=1e-6, atol=1e-6)
