"""Canary for the jax 0.4.x residue in :mod:`repro.launch.compat`.

ROADMAP's "jax 0.4.x residue" item documents two shims that exist ONLY
because the pinned container toolchain is jax 0.4.x: the fully-manual
``shard_map`` path (the era's XLA SPMD partitioner aborts on partial-auto
programs) and the skipped grad-accumulator sharding constraint (0.4.x CPU
SPMD miscompiles the constrained backward). This module asserts those
behaviors while the container is legacy — and FAILS LOUDLY, pointing at
the exact code to delete, the moment the container jax moves to >= 0.6.
That failure is the signal to do the cleanup, not a regression.
"""
import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import compat

# The modern sharding surface (jax >= 0.6): top-level shard_map, AxisType
# meshes, jax.set_mesh, jax.lax.axis_size. All four land together.
LEGACY = not hasattr(jax, "shard_map")

MODERNIZE = (
    "container jax is >= 0.6 ({}): the 0.4.x residue is now deletable — "
    "drop the fully-manual shard_map fallback and the HAS_AXIS_TYPE "
    "grad-constraint gate (repro/launch/compat.py, "
    "repro/distributed/trainer.py), re-enable partial-auto shard_map and "
    "the grad-accumulator sharding constraint, then retire this canary. "
    "See ROADMAP.md 'jax 0.4.x residue'."
).format(jax.__version__)


def test_container_toolchain_still_needs_the_shims():
    """THE canary: fails (with the deletion checklist) once the container
    jax gains the modern surface the shims paper over."""
    if not LEGACY:
        pytest.fail(MODERNIZE)
    # the four modern APIs are absent together — the shims' premise
    assert not hasattr(jax.sharding, "AxisType")
    assert not hasattr(jax, "set_mesh")
    assert not hasattr(jax.lax, "axis_size")
    assert compat.HAS_AXIS_TYPE is False


@pytest.mark.skipif(not LEGACY, reason="0.4.x-only shim behaviour "
                    "(the canary above already demands deletion)")
class TestLegacyShimBehaviour:
    def test_shard_map_runs_fully_manual(self):
        """On 0.4.x the shim must route through
        ``jax.experimental.shard_map`` with ``auto=frozenset()`` — fully
        manual even when ``axis_names`` names every mesh axis — and the
        wrapped body must still execute correctly on a 1-device mesh."""
        src = inspect.getsource(compat.shard_map)
        assert "auto=frozenset()" in src       # the manual-mode pin

        mesh = compat.make_mesh((1,), ("data",))
        x = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)

        def body(block):
            return block * compat.axis_size("data")

        out = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
            axis_names=frozenset({"data"}), check_vma=False,
        ))(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    def test_axis_size_falls_back_to_psum(self):
        """``jax.lax.axis_size`` does not exist on 0.4.x; the shim's
        ``psum(1, name)`` evaluates statically to the axis size."""
        mesh = compat.make_mesh((1,), ("data",))
        got = jax.jit(compat.shard_map(
            lambda: jnp.int32(compat.axis_size("data")),
            mesh=mesh, in_specs=(), out_specs=P(),
            axis_names=frozenset({"data"}), check_vma=False,
        ))()
        assert int(got) == 1

    def test_make_mesh_drops_axis_types_kwarg(self):
        """0.4.x ``jax.make_mesh`` has no ``axis_types=``; the shim must
        swallow the kwarg instead of exploding."""
        mesh = compat.make_mesh((1,), ("data",), axis_types=("whatever",))
        assert tuple(mesh.axis_names) == ("data",)

    def test_set_mesh_is_the_resource_env_context(self):
        """No ``jax.set_mesh`` on 0.4.x: the shim returns the Mesh itself
        (a context manager), and the ambient mesh is visible through
        ``get_abstract_mesh`` inside the context only."""
        mesh = compat.make_mesh((1,), ("data",))
        ctx = compat.set_mesh(mesh)
        assert ctx is mesh
        with ctx:
            inside = compat.get_abstract_mesh()
            assert inside is not None and not inside.empty
        assert compat.get_abstract_mesh() is None

    def test_trainer_skips_grad_constraint_on_legacy(self):
        """The gspmd trainer must gate the grad-accumulator sharding
        constraint on ``compat.HAS_AXIS_TYPE`` — 0.4.x CPU SPMD
        miscompiles the constrained backward pass (grads off by O(1)
        relative), so on the legacy toolchain the constraint is skipped."""
        from repro.distributed import trainer

        src = inspect.getsource(trainer._make_gspmd_step)
        assert "HAS_AXIS_TYPE" in src, (
            "the grad-constraint legacy gate disappeared from "
            "trainer._make_gspmd_step — if it was removed on purpose, "
            "delete this canary with it"
        )
        assert trainer.compat.HAS_AXIS_TYPE is False
