"""repro.statics — the jaxpr static-analysis subsystem (PR 6).

Covers, in order:
* the IR walker's equivalence with the historical per-test helpers,
* exactness of the affine stream-disjointness decision procedure
  (hypothesis property tests when the library is available, a seeded
  4000-trial randomized sweep otherwise — same property either way),
* would-have-caught regressions for the three PRNG aliasing bugs that
  shipped in PRs 3-5 and the PR-4 subnormal belief-floor NaN,
* the dense-intermediate linter on a synthetic injection AND the real
  engines,
* the retrace sentinel (positive and negative),
* the static memory budgeter against the committed BENCH artifacts,
* the benchmark --check vacuous-pass fix,
* the CLI end-to-end, including verdict-cache behavior.
"""
from __future__ import annotations

import json
import random
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.statics import contracts, dense, memory, retrace, streams, walk
from repro.statics.streams import (
    AffineMap,
    LEGACY_BUGGY_STREAMS,
    affine_disjoint,
    brute_force_disjoint,
    check_streams,
    fit_affine,
)

REPO_ROOT = Path(__file__).resolve().parents[1]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _ensure_engines_imported():
    retrace.register_default_caches()


# ---------------------------------------------------------------------------
# Walker
# ---------------------------------------------------------------------------

def _legacy_collect_avals(jaxpr, out):
    """The exact helper the PR-3/4/5 tests carried, re-inlined as the
    equivalence oracle for repro.statics.walk.collect_avals."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                out.append(v.aval.shape)
        for val in eqn.params.values():
            for sub in walk.subjaxprs(val):
                _legacy_collect_avals(sub, out)
    return out


class TestWalker:
    def test_collect_avals_matches_historical_helper(self):
        def fn(x):
            def body(c, t):
                return c * 0.5 + jnp.sin(t), c.sum()
            c, ys = jax.lax.scan(body, x, jnp.arange(5, dtype=jnp.float32))
            return jnp.where(ys[-1] > 0, c, -c)

        closed = jax.make_jaxpr(fn)(jnp.ones((3, 4), jnp.float32))
        assert walk.collect_avals(closed.jaxpr, []) == \
            _legacy_collect_avals(closed.jaxpr, [])

    def test_collect_values_paths_and_bytes(self):
        def fn(x):
            def body(c, _):
                return c @ c.T @ c, ()
            c, _ = jax.lax.scan(body, x, None, length=3)
            return c

        closed = jax.make_jaxpr(fn)(jnp.ones((4, 4), jnp.float32))
        vals = walk.collect_values(closed)
        in_scan = [v for v in vals if "scan" in v.path]
        assert in_scan and all(v.nbytes == 4 * 4 * 4 for v in in_scan)

    def test_collect_values_tolerates_key_dtypes(self):
        closed = jax.make_jaxpr(
            lambda k: jax.random.uniform(jax.random.fold_in(k, 3), (7,))
        )(jax.random.PRNGKey(0))
        fp = memory.jaxpr_footprint(closed)
        assert fp["n_values"] > 0 and fp["total_bytes"] > 0

    def test_symbolize(self):
        assert walk.symbolize((64, 64, 3), {"N": 64, "m": 3}) == \
            ("N", "N", "m")
        assert walk.symbolize((5,), {"N": 64}) == (5,)

    def test_symbolize_rejects_ambiguous_dims(self):
        with pytest.raises(ValueError, match="ambiguous"):
            walk.symbolize((8,), {"N": 8, "T": 8})


# ---------------------------------------------------------------------------
# Affine disjointness: exactness property
# ---------------------------------------------------------------------------

def _check_one(a1, b1, a2, b2, T, T2):
    m1, m2 = AffineMap("x", a1, b1), AffineMap("y", a2, b2)
    disjoint, wit = affine_disjoint(m1, m2, T, T2)
    assert disjoint == brute_force_disjoint(m1, m2, T, T2), \
        (a1, b1, a2, b2, T, T2)
    if not disjoint:
        t1, t2, val = wit
        assert 0 <= t1 < T and 0 <= t2 < T2, (wit, m1, m2, T, T2)
        assert m1(t1) == m2(t2) == val, (wit, m1, m2)


class TestAffineDisjointProperty:
    if HAVE_HYPOTHESIS:
        @settings(max_examples=500, deadline=None)
        @given(
            a1=st.integers(-6, 6), b1=st.integers(-20, 20),
            a2=st.integers(-6, 6), b2=st.integers(-20, 20),
            T=st.integers(1, 30), T2=st.integers(1, 30),
        )
        def test_matches_brute_force(self, a1, b1, a2, b2, T, T2):
            _check_one(a1, b1, a2, b2, T, T2)
    else:
        def test_matches_brute_force(self):
            # seeded fallback: same box, dense randomized coverage
            rng = random.Random(0)
            for _ in range(4000):
                _check_one(
                    rng.randint(-6, 6), rng.randint(-20, 20),
                    rng.randint(-6, 6), rng.randint(-20, 20),
                    rng.randint(1, 30), rng.randint(1, 30),
                )

    def test_degenerate_and_zero_slope_cases(self):
        for args in [(0, 5, 0, 5, 9, 9), (0, 5, 0, 6, 9, 9),
                     (2, 0, 0, 4, 9, 9), (0, 4, 2, 0, 9, 9),
                     (-3, 0, 3, 0, 9, 9), (1, 0, 1, 0, 1, 1)]:
            _check_one(*args)

    def test_horizon_bound_enforced(self):
        big = AffineMap("big", 1 << 20, 0)
        with pytest.raises(ValueError, match="signed fold-in"):
            affine_disjoint(big, AffineMap("y", 1, 0), 1 << 12)


class TestFitAffine:
    def test_recovers_engine_folds(self):
        from repro.core.byzantine import STREAM_GOSSIP, stream_fold
        from repro.core.hps import hps_stream_fold
        from repro.core.social import STREAM_SIGNAL, social_stream_fold

        m = fit_affine(lambda t: stream_fold(t, STREAM_GOSSIP), "bg")
        assert (m.a, m.b) == (3, 1)
        m = fit_affine(lambda t: social_stream_fold(t, STREAM_SIGNAL), "ss")
        assert (m.a, m.b) == (2, 1)
        m = fit_affine(hps_stream_fold, "hl")
        assert (m.a, m.b) == (-1, -1)

    def test_rejects_non_affine(self):
        with pytest.raises(ValueError, match="not affine"):
            fit_affine(lambda t: t * t, "sq")


# ---------------------------------------------------------------------------
# Would-have-caught: the three shipped PRNG aliasing bugs
# ---------------------------------------------------------------------------

class TestHistoricalPRNGBugs:
    """Each scheme below SHIPPED in an earlier PR and was fixed after the
    fact. The analyzer must flag every one with a valid witness — and pass
    the current schemes."""

    def test_byzantine_legacy_scheme_caught_with_witness(self):
        # pre-PR-3: signal t, gossip 2t+1, fusion 2t+2
        findings = check_streams(LEGACY_BUGGY_STREAMS["byzantine"], 1 << 20)
        msgs = [f.message for f in findings]
        assert len(findings) == 2
        assert any("signal@t=1 == gossip@t=0 (both fold 1)" in m
                   for m in msgs), msgs
        assert any("signal@t=2 == fusion@t=0 (both fold 2)" in m
                   for m in msgs), msgs

    def test_social_legacy_scheme_caught_at_origin(self):
        # pre-PR-4: link and signal both folded plain t
        findings = check_streams(LEGACY_BUGGY_STREAMS["social"], 1 << 20)
        assert len(findings) == 1
        assert "link@t=0 == signal@t=0 (both fold 0)" in findings[0].message

    def test_hps_legacy_collides_with_social_link(self):
        # pre-PR-5: hps folded plain t; social's link stream is 2t+0, so a
        # shared experiment seed aliased the two schedules at every even t
        _ensure_engines_imported()
        social_c = contracts.get("social")
        social_maps = [fit_affine(s.fold, f"social.{s.name}")
                       for s in social_c.streams]
        legacy_hps = LEGACY_BUGGY_STREAMS["hps"][0]
        disjoint, wit = affine_disjoint(legacy_hps, social_maps[0], 1 << 20)
        assert not disjoint and wit == (0, 0, 0)

    def test_current_schemes_all_disjoint(self):
        _ensure_engines_imported()
        for c in contracts.all_contracts():
            maps = [fit_affine(s.fold, s.name) for s in c.streams]
            assert check_streams(maps, c.horizon, where=c.name) == []
        # cross-engine: the declared shared-seed pairs
        hps_c = contracts.get("hps")
        hps_map = fit_affine(hps_c.streams[0].fold, "hps.link")
        for other in hps_c.shares_seed_with:
            for s in contracts.get(other).streams:
                disjoint, _ = affine_disjoint(
                    hps_map, fit_affine(s.fold, s.name), hps_c.horizon)
                assert disjoint, (other, s.name)

    def test_hps_shared_seed_declarations_present(self):
        """The PR-5 bug class is only covered if hps actually DECLARES the
        engines it may share a seed with."""
        _ensure_engines_imported()
        assert set(contracts.get("hps").shares_seed_with) == \
            {"social", "byzantine"}


# ---------------------------------------------------------------------------
# Dense-intermediate linter + subnormal constants
# ---------------------------------------------------------------------------

class TestDenseLinter:
    def test_synthetic_dense_injection_caught(self):
        N, d = 11, 2

        def bad(w):
            return (jnp.ones((N, N), w.dtype) / N) @ w

        closed = jax.make_jaxpr(bad)(jnp.zeros((N, d), jnp.float32))
        found = dense.find_forbidden(
            closed, {"N": N, "d": d}, (("N", "N"),), where="synthetic")
        assert found and all("('N', 'N')" in f.message for f in found)

    def test_real_sparse_engine_clean(self):
        from repro.core.graphs import edge_list, random_strongly_connected
        from repro.core.pushsum import run_pushsum_sparse

        rng = np.random.default_rng(0)
        el = edge_list(random_strongly_connected(11, 0.3, rng))
        w = rng.normal(size=(11, 2)).astype(np.float32)
        closed = walk.trace(
            lambda w_, k_: run_pushsum_sparse(
                w_, el.src, el.dst, T=7, drop_prob=0.1, B=2, key=k_,
                backend="xla"),
            w, jax.random.PRNGKey(0))
        assert dense.assert_nonempty(closed) == []
        assert dense.find_forbidden(
            closed, {"N": 11, "d": 2, "T": 7, "E": int(el.E)},
            (("N", "N"),)) == []

    def test_empty_program_guard(self):
        closed = jax.make_jaxpr(lambda x: x)(jnp.ones(3))
        found = dense.assert_nonempty(closed, where="identity")
        assert found and "no values" in found[0].message

    def test_subnormal_literal_caught(self):
        # the PR-4 belief floor: 1e-38 < fp32 tiny -> FTZ reads 0 -> log(0)
        def bad(mu):
            return jnp.log(jnp.maximum(mu, 1e-38))

        closed = jax.make_jaxpr(bad)(jnp.ones((4, 3), jnp.float32))
        found = dense.find_subnormal_consts(closed, where="belief-floor")
        assert found and "flush-to-zero" in found[0].message

    def test_normal_floor_clean(self):
        from repro.core.social import _MU_FLOOR

        def good(mu):
            return jnp.log(jnp.maximum(mu, _MU_FLOOR))

        closed = jax.make_jaxpr(good)(jnp.ones((4, 3), jnp.float32))
        assert dense.find_subnormal_consts(closed) == []

    def test_real_social_engine_free_of_subnormals(self):
        from repro.core.graphs import make_hierarchy
        from repro.core.hps import HPSConfig
        from repro.core.signals import make_confused_model
        from repro.core.social import make_social_runtime, run_social_runtime

        topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
        model = make_confused_model(N=18, m=3, truth=1, confusion=0.5,
                                    seed=0)
        rt = make_social_runtime(
            HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3))
        closed = walk.trace(
            lambda rt_: run_social_runtime(model, rt_, M=3, T=9,
                                           backend="xla", store="final"),
            rt)
        assert dense.find_subnormal_consts(closed) == []


# ---------------------------------------------------------------------------
# Retrace sentinel
# ---------------------------------------------------------------------------

class TestRetraceSentinel:
    def test_repeat_sweep_hits_caches(self):
        _ensure_engines_imported()
        from repro.core.graphs import make_hierarchy
        from repro.core.hps import HPSConfig
        from repro.core.sweeps import run_hps_sweep

        topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=2, B=2, drop_prob=0.0)
        w = np.random.default_rng(0).normal(size=(15, 2)).astype(np.float32)
        found = retrace.check_idempotent(
            lambda: run_hps_sweep(w, cfg, T=4, drop_probs=[0.0, 0.3],
                                  seeds=[0], backend="xla", store="gap"),
            where="run_hps_sweep")
        assert found == [], [str(f) for f in found]

    def test_unstable_cache_key_caught(self):
        grower = {}
        calls = [0]

        def thunk():
            calls[0] += 1
            grower[calls[0]] = object()   # a key that never repeats

        retrace.register_cache("test.unstable", grower)
        try:
            found = retrace.check_idempotent(thunk, where="unstable")
            assert len(found) == 1
            assert "test.unstable" in found[0].message
            assert "grew by 1" in found[0].message
        finally:
            del retrace.CACHE_REGISTRY["test.unstable"]

    def test_watch_reports_deltas(self):
        c = {}
        retrace.register_cache("test.watch", c)
        try:
            with retrace.CacheWatch(strict=True, where="w") as watch:
                c["k"] = 1
            assert watch.deltas == {"test.watch": 1}
            assert len(watch.findings()) == 1
            with retrace.CacheWatch(allowed={"test.watch": 1},
                                    strict=True) as watch:
                c["k2"] = 2
            assert watch.findings() == []
        finally:
            del retrace.CACHE_REGISTRY["test.watch"]


# ---------------------------------------------------------------------------
# Static memory budgeter
# ---------------------------------------------------------------------------

class TestMemoryBudget:
    def test_committed_bench_rows_fit_budget(self):
        found = memory.validate_bench(REPO_ROOT / "results")
        assert found == [], [str(f) for f in found]

    def test_missing_artifacts_is_loud(self, tmp_path):
        found = memory.validate_bench(tmp_path)
        assert found and "no BENCH rows" in found[0].message

    def test_dense_reference_infeasible_at_benchmark_scale(self):
        # the N=4096 dense-oracle row the benchmarks stop at: >0.5 GB per
        # round, vs a few hundred KB for the sparse core at the same scale
        assert memory.byz_dense_bytes(4096, 3) > 0.5e9
        assert memory.byz_sparse_step_bytes(4096, 8, 3) < 5e6

    def test_sparse_models_scale_linearly_in_E(self):
        one = memory.pushsum_step_bytes(1024, 3102)
        two = memory.pushsum_step_bytes(1024, 6204)
        # doubling E grows traffic but less than 2x: the node-state term
        # (sigma, weights) is E-independent
        assert one < two < 2 * one

    def test_step_floor_wired_through_roofline(self):
        floor = memory.step_floor(819e9)   # exactly one second of HBM bw
        assert floor["dominant"] == "memory"
        assert floor["bound_step_time_s"] == pytest.approx(1.0)

    def test_impossible_edge_count_flagged(self, tmp_path):
        (tmp_path / "BENCH_x.json").write_text(json.dumps({
            "pushsum_sparse_N8": {"us_per_call": 1.0, "derived": "E=999"},
        }))
        found = memory.validate_bench(tmp_path)
        assert found and "impossible" in found[0].message


# ---------------------------------------------------------------------------
# benchmarks/run.py --check: the vacuous-pass fix
# ---------------------------------------------------------------------------

def _load_bench_run():
    # benchmarks/ is a package with relative imports: import it as one
    if str(REPO_ROOT) not in sys.path:
        sys.path.insert(0, str(REPO_ROOT))
    import benchmarks.run
    return benchmarks.run


class TestBenchCheckVacuousPass:
    def test_disjoint_name_sets_fail_loudly(self, capsys):
        run = _load_bench_run()
        bad = run._check_regressions(
            "base.json",
            {"old_name": {"us_per_call": 1.0}},
            {"new_name": (1.0, "")})
        assert bad == 1
        assert "NONE match" in capsys.readouterr().out

    def test_overlapping_names_still_gate(self, capsys):
        run = _load_bench_run()
        assert run._check_regressions(
            "base.json", {"a": {"us_per_call": 1.0}}, {"a": (1.01, "")}) == 0
        assert run._check_regressions(
            "base.json", {"a": {"us_per_call": 1.0}}, {"a": (99.0, "")}) == 1

    def test_interpret_rows_skip_without_tripping_guard(self, capsys):
        # overlap exists but every overlapping row is interpret-mode: the
        # gate must PASS with 0 checked rows (the CPU CI lane), not fail
        run = _load_bench_run()
        assert run._check_regressions(
            "base.json", {"a": {"us_per_call": 1.0}},
            {"a": (99.0, "mode=interpret")}) == 0


# ---------------------------------------------------------------------------
# CLI end-to-end
# ---------------------------------------------------------------------------

def _run_cli(*args, cwd=REPO_ROOT):
    return subprocess.run(
        [sys.executable, "-m", "repro.statics", *args],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/tmp"},
    )


class TestCLI:
    def test_lint_passes_and_caches(self, tmp_path):
        cache = tmp_path / "cache"
        first = _run_cli("lint", "--skip-exec", "--cache-dir", str(cache))
        assert first.returncode == 0, first.stderr
        assert "PASS" in first.stdout
        verdict = json.loads((cache / "lint-verdict.json").read_text())
        assert verdict["ok"] is True
        second = _run_cli("lint", "--skip-exec", "--cache-dir", str(cache))
        assert second.returncode == 0
        assert "cached PASS" in second.stdout

    def test_lint_catches_legacy_byzantine_scheme(self):
        r = _run_cli("lint", "--skip-exec", "--no-cache",
                     "--inject-legacy-streams", "byzantine")
        assert r.returncode == 1
        assert "signal@t=1 == gossip@t=0 (both fold 1)" in r.stderr

    def test_lint_catches_legacy_social_scheme(self):
        r = _run_cli("lint", "--skip-exec", "--no-cache",
                     "--inject-legacy-streams", "social")
        assert r.returncode == 1
        assert "link@t=0 == signal@t=0 (both fold 0)" in r.stderr

    def test_lint_catches_legacy_hps_scheme_cross_engine(self):
        r = _run_cli("lint", "--skip-exec", "--no-cache",
                     "--inject-legacy-streams", "hps")
        assert r.returncode == 1
        assert "hps x social" in r.stderr

    def test_lint_catches_dense_injection(self):
        r = _run_cli("lint", "--skip-exec", "--no-cache", "--inject-dense")
        assert r.returncode == 1
        assert "dense-intermediate" in r.stderr
        assert "('N', 'N')" in r.stderr

    def test_budget_runs(self):
        r = _run_cli("budget")
        assert r.returncode == 0, r.stderr
        assert "byz-DENSE" in r.stdout

    def test_list_shows_contracts_and_caches(self):
        r = _run_cli("list")
        assert r.returncode == 0, r.stderr
        for name in ("pushsum", "social", "hps", "byzantine"):
            assert name in r.stdout
        assert "byz.compiled" in r.stdout


# ---------------------------------------------------------------------------
# Contracts registry
# ---------------------------------------------------------------------------

class TestContracts:
    def test_all_engines_registered(self):
        _ensure_engines_imported()
        assert {"pushsum", "social", "hps", "byzantine"} <= \
            set(contracts.REGISTRY)

    def test_forbidden_for_merges_star_and_store(self):
        c = contracts.EngineContract(
            name="x",
            forbidden={"*": (("N", "N"),), "final": (("T", "*"),)})
        assert c.forbidden_for(None) == (("N", "N"),)
        assert set(c.forbidden_for("final")) == {("N", "N"), ("T", "*")}

    def test_decorator_is_transparent_and_attaches(self):
        @contracts.contract(name="_tmp_test_contract",
                            streams=(("s", lambda t: t),))
        def fn(x):
            return x + 1

        try:
            assert fn(1) == 2
            assert fn.__statics_contract__.name == "_tmp_test_contract"
            assert contracts.get("_tmp_test_contract").n_prng_sites == 1
        finally:
            del contracts.REGISTRY["_tmp_test_contract"]

    def test_engine_caches_are_registered(self):
        _ensure_engines_imported()
        registered = set(retrace.CACHE_REGISTRY)
        for c in contracts.all_contracts():
            missing = set(c.caches) - registered
            assert not missing, (c.name, missing)
