"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture instantiates its REDUCED variant (2 layers,
d_model <= 512, <= 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs. Serve-path equivalence
(prefill + decode == full forward) is validated for every family, including
the scan-over-layers paths used by the deep production configs.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, reduced
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = [a for a in ARCH_IDS if a != "paper_sim"]
KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = jax.random.normal(
            KEY, (B, cfg.n_frames, cfg.d_model), dtype=jnp.float32
        )
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_patches, 1024), dtype=jnp.float32
        )
    return toks, kwargs


class TestConfigs:
    def test_exact_assigned_dimensions(self):
        """The full configs carry the exact public-literature dimensions."""
        c = get_config("llama3_405b")
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab) == (126, 16384, 128, 8, 53248, 128256)
        c = get_config("qwen3_moe_235b_a22b")
        assert (c.n_layers, c.n_experts, c.top_k, c.n_kv_heads) == (94, 128, 8, 4)
        c = get_config("recurrentgemma_2b")
        assert c.block_pattern == ("rglru", "rglru", "swa") and c.window == 2048
        c = get_config("rwkv6_1b6")
        assert c.block_pattern == ("wkv6",) and c.family == "ssm"
        c = get_config("whisper_small")
        assert c.encoder_layers == 12 and c.n_frames == 1500

    def test_param_counts_sane(self):
        expect = {
            "llama3_405b": (390e9, 420e9),
            "qwen3_moe_235b_a22b": (225e9, 245e9),
            "qwen3_8b": (7e9, 9e9),
            "olmoe_1b_7b": (6e9, 8e9),
            "rwkv6_1b6": (1.4e9, 2.0e9),
            "minitron_4b": (3.5e9, 4.8e9),
        }
        for name, (lo, hi) in expect.items():
            n = get_config(name).param_count()
            assert lo < n < hi, f"{name}: {n/1e9:.2f}B"
        # MoE active params
        assert get_config("olmoe_1b_7b").active_param_count() < 2e9
        assert get_config("qwen3_moe_235b_a22b").active_param_count() < 30e9

    def test_reduced_is_small(self):
        for name in ARCHS:
            r = reduced(get_config(name))
            assert r.n_layers <= 4 and r.d_model <= 512
            if r.is_moe:
                assert r.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = reduced(get_config(arch))
        params = M.init_params(KEY, cfg)
        toks, kwargs = _inputs(cfg)

        logits, aux = M.forward_train(params, cfg, toks, **kwargs)
        S_total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
        assert logits.shape == (B, S_total, cfg.vocab)
        assert not np.isnan(np.asarray(logits, np.float32)).any()

        # one real optimizer step reduces nothing but must stay finite
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
        opt = adamw_init(params)

        def loss_fn(p):
            return M.loss_fn(p, cfg, toks, toks, **{
                ("patch_embeds" if k == "patch_embeds" else "frames"): v
                for k, v in kwargs.items()
            })

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        new_params, _ = adamw_update(opt_cfg, grads, opt, params)
        for leaf in jax.tree_util.tree_leaves(new_params):
            assert not np.isnan(np.asarray(leaf, np.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_equals_forward(arch):
    """prefill + 3 decode steps must reproduce the full forward logits."""
    cfg = reduced(get_config(arch))
    params = M.init_params(KEY, cfg)
    toks, kwargs = _inputs(cfg)
    n_steps = 3
    clen = S + n_steps + 1 + (cfg.n_patches if cfg.family == "vlm" else 0)
    _, cache = M.prefill(params, cfg, toks, cache_len=clen, **kwargs)
    seq = toks
    for step in range(n_steps):
        nxt = jax.random.randint(jax.random.PRNGKey(step + 7), (B, 1), 0,
                                 cfg.vocab)
        lg_dec, cache = M.decode_step(params, cfg, cache, nxt)
        seq = jnp.concatenate([seq, nxt], 1)
    lg_full, _ = M.forward_train(params, cfg, seq, **kwargs)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0]), np.asarray(lg_full[:, -1]),
        rtol=5e-3, atol=5e-3,
    )


@pytest.mark.parametrize("arch,extra", [
    ("llama3_405b", {"n_layers": 4, "scan_layers": True}),
    ("qwen3_moe_235b_a22b", {"n_layers": 4, "scan_layers": True}),
    ("recurrentgemma_2b", {"n_layers": 8, "scan_layers": True}),
    ("rwkv6_1b6", {"n_layers": 4, "scan_layers": True}),
])
def test_scan_path_serve_equivalence(arch, extra):
    """The scan-over-layers path (used by the deep production configs) must
    agree with unrolled semantics on both train and serve."""
    cfg = dataclasses.replace(reduced(get_config(arch)), **extra)
    params = M.init_params(KEY, cfg)
    toks, kwargs = _inputs(cfg)
    clen = S + 3
    _, cache = M.prefill(params, cfg, toks, cache_len=clen, **kwargs)
    seq = toks
    for step in range(2):
        nxt = jax.random.randint(jax.random.PRNGKey(step), (B, 1), 0,
                                 cfg.vocab)
        lg_dec, cache = M.decode_step(params, cfg, cache, nxt)
        seq = jnp.concatenate([seq, nxt], 1)
    lg_full, _ = M.forward_train(params, cfg, seq, **kwargs)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0]), np.asarray(lg_full[:, -1]),
        rtol=5e-3, atol=5e-3,
    )


def test_sliding_window_attention_masks_far_context():
    """swa mixers must ignore tokens beyond the window."""
    cfg = dataclasses.replace(
        reduced(get_config("qwen3_8b")), block_pattern=("swa",), window=8,
    )
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 32), 0, cfg.vocab)
    logits, _ = M.forward_train(params, cfg, toks)
    # perturbing a token > window away from the last position must not
    # change the last position's logits
    toks2 = toks.at[0, 5].set((toks[0, 5] + 1) % cfg.vocab)
    logits2, _ = M.forward_train(params, cfg, toks2)
    np.testing.assert_allclose(
        np.asarray(logits[0, -1]), np.asarray(logits2[0, -1]),
        rtol=1e-5, atol=1e-5,
    )


def test_moe_router_balance_loss_positive():
    cfg = reduced(get_config("olmoe_1b_7b"))
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    _, aux = M.forward_train(params, cfg, toks)
    assert float(aux) > 0.0


def test_long_context_decode_rwkv_constant_state():
    """SSM decode state is O(1) in sequence length — the long_500k path."""
    cfg = reduced(get_config("rwkv6_1b6"))
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    _, cache = M.prefill(params, cfg, toks, cache_len=16)
    leaves = jax.tree_util.tree_leaves(cache)
    total_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
    # state must not scale with a 500k context: bound is layers * (H*hd^2+d)
    assert total_bytes < 5e6
