"""Equivalence tests for the EXPERIMENTS.md §Perf optimization variants.

Every beyond-paper performance change must be semantics-preserving; these
tests pin that: sharded MoE == GSPMD MoE, padded heads == unpadded heads,
Pallas WKV6 gradients == jnp gradients, bf16-moment AdamW tracks f32.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)


def test_padded_heads_exact_equivalence():
    """pad_heads_to: zero wq columns / wo rows => identical logits."""
    cfg = dataclasses.replace(
        reduced(get_config("minitron_4b")), n_heads=3, n_kv_heads=1,
        head_dim=32,
    )
    cfgp = dataclasses.replace(cfg, pad_heads_to=4)
    p = M.init_params(KEY, cfg)
    pp = M.init_params(KEY, cfgp)

    def graft(a, b):
        if a.shape == b.shape:
            return a
        out = jnp.zeros_like(b)
        return out.at[tuple(slice(0, s) for s in a.shape)].set(a)

    pp = jax.tree_util.tree_map(graft, p, pp)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1, _ = M.forward_train(p, cfg, toks)
    l2, _ = M.forward_train(pp, cfgp, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)
    # decode path too
    _, c1 = M.prefill(p, cfg, toks, cache_len=20)
    _, c2 = M.prefill(pp, cfgp, toks, cache_len=20)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    d1, _ = M.decode_step(p, cfg, c1, nxt)
    d2, _ = M.decode_step(pp, cfgp, c2, nxt)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_wkv6_kernel_custom_vjp_matches_jnp_grads():
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_chunked_jnp

    rng = np.random.default_rng(0)
    BH, T, K = 2, 64, 16
    r, k, v = (jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32))
               for _ in range(3))
    lw = jnp.asarray(-np.exp(rng.normal(size=(BH, T, K))).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(BH, K)).astype(np.float32))

    gk = jax.grad(
        lambda *a: wkv6(*a, chunk=16, backend="pallas")[0].sum(),
        argnums=(0, 1, 2, 3, 4),
    )(r, k, v, lw, u)
    gj = jax.grad(
        lambda *a: wkv6_chunked_jnp(*a, chunk=16)[0].sum(),
        argnums=(0, 1, 2, 3, 4),
    )(r, k, v, lw, u)
    for a, b in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_bf16_moments_track_f32():
    cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                        total_steps=100, clip_norm=100.0)
    cfg16 = dataclasses.replace(cfg32, moment_dtype="bfloat16")
    params = {"w": jnp.array([5.0, -3.0, 1.0])}
    s32 = adamw_init(params)
    s16 = adamw_init(params, "bfloat16")
    p32 = p16 = params
    for i in range(50):
        g32 = {"w": 2 * p32["w"]}
        g16 = {"w": 2 * p16["w"]}
        p32, s32 = adamw_update(cfg32, g32, s32, p32)
        p16, s16 = adamw_update(cfg16, g16, s16, p16)
    # both trajectories descend and the bf16-moment one tracks f32 closely
    assert float(jnp.abs(p16["w"]).max()) < float(jnp.abs(params["w"]).max())
    np.testing.assert_allclose(np.asarray(p16["w"]), np.asarray(p32["w"]),
                               atol=0.05)


def test_sharded_moe_matches_gspmd():
    """Runs on 8 fake devices in a subprocess (needs a multi-device mesh)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.launch import compat
        from repro.models import layers as L
        mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
        cfg = reduced(get_config("olmoe_1b_7b"))
        p = L.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              dtype=jnp.float32)
        y_ref, _ = L._moe_block_gspmd(p, x, cfg)
        cfg_s = dataclasses.replace(cfg, moe_impl="sharded")
        with compat.set_mesh(mesh):
            y_s, _ = jax.jit(lambda p, x: L.moe_block(p, x, cfg_s))(p, x)
        print(json.dumps({"err": float(jnp.abs(y_s - y_ref).max())}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for attempt in range(2):
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=420,
                             env=env, cwd=REPO)
        if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
            break
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(
        [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    )
    assert res["err"] < 1e-4


def test_sharded_trim_equals_plain_trim():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.aggregation import AGGREGATORS, AggregatorConfig
        from repro.kernels.trimmed_mean.ref import trimmed_mean_ref
        from repro.launch import compat
        mesh = compat.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.normal(size=(8, 1003)).astype(np.float32))
        cfg = AggregatorConfig(kind="trimmed_mean_sharded", F=2)
        fn = AGGREGATORS["trimmed_mean_sharded"]
        def body(g, key):
            return fn({"g": g[0]}, cfg, "data", "pod", key)["g"][None]
        sm = compat.shard_map(body, mesh=mesh,
                              in_specs=(P(("pod","data"), None), P()),
                              out_specs=P(("pod","data"), None),
                              axis_names=frozenset({"pod","data"}),
                              check_vma=False)
        out = np.asarray(jax.jit(sm)(g_all, jax.random.PRNGKey(0)))
        want = np.asarray(trimmed_mean_ref(g_all, 2))
        print(json.dumps({"err": float(np.abs(out - want).max())}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for attempt in range(2):
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=420,
                             env=env, cwd=REPO)
        if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
            break
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(
        [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    )
    assert res["err"] < 1e-5
