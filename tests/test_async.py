"""Async execution mode (PR 10 tentpole): Poisson wake clocks, bounded
stale per-edge buffers, degenerate bit-identity with the synchronous
engines, mass conservation under arbitrary wake schedules, the disjoint
async PRNG fold-in domain, and the sweep async axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asyncrony import (
    ASYNC_DOMAIN_BASE,
    AsyncModel,
    async_stream_fold,
    init_async_buffer,
    is_degenerate_async,
    make_async_model,
    wake_mask,
)
from repro.core.faults import (
    ENGINE_HPS,
    ENGINE_PUSHSUM,
    ENGINE_SOCIAL,
    N_ENGINES,
    fault_stream_fold,
)
from repro.core.graphs import (
    edge_list,
    make_hierarchy,
    random_strongly_connected,
)
from repro.core.hps import HPSConfig, hps_stream_fold, run_hps
from repro.core.plan import ExecutionPlan
from repro.core.pushsum import (
    init_sparse_state,
    run_pushsum_sparse,
    sparse_mass_invariant,
    sparse_pushsum_step,
)
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning
from repro.core.sweeps import run_pushsum_sweep, run_social_sweep

RNG = np.random.default_rng(0)


def _pushsum_fixture(n=8):
    el = edge_list(random_strongly_connected(n, 0.3, RNG))
    w = np.random.default_rng(1).normal(size=(n, 3)).astype(np.float32)
    return el, w


def _hier_fixture():
    topo = make_hierarchy([4, 4, 4], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
    return topo, model, cfg


def _trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(
        bool(jnp.array_equal(jnp.asarray(x), jnp.asarray(y)))
        for x, y in zip(la, lb))


class TestStreamDisjointness:
    """The async wake-coin domain never collides with any other stream."""

    def test_affine_form(self):
        assert async_stream_fold(0, ENGINE_PUSHSUM) == -ASYNC_DOMAIN_BASE
        assert (async_stream_fold(5, ENGINE_SOCIAL)
                == -(5 * N_ENGINES + ENGINE_SOCIAL) - ASYNC_DOMAIN_BASE)

    def test_disjoint_from_fault_and_hps_domains(self):
        horizon = 1 << 20
        # async image upper bound (t = 0) and lower bound (t = horizon-1)
        hi = int(async_stream_fold(0, ENGINE_PUSHSUM))
        lo = int(async_stream_fold(
            horizon - 1, max(ENGINE_PUSHSUM, ENGINE_SOCIAL, ENGINE_HPS)))
        assert lo < hi <= -ASYNC_DOMAIN_BASE
        # the fault band lives strictly above the async band
        fault_lo = min(
            int(fault_stream_fold(horizon - 1, e, s))
            for e in range(N_ENGINES) for s in range(3))
        assert hi < fault_lo
        # hps ~t domain: [-2^20, -1] — above the async band too
        assert hi < int(hps_stream_fold(horizon - 1))
        # engine-to-engine: stride-N_ENGINES congruence, never equal
        a = {int(async_stream_fold(t, ENGINE_PUSHSUM)) for t in range(64)}
        b = {int(async_stream_fold(t, ENGINE_SOCIAL)) for t in range(64)}
        assert not (a & b)

    def test_int32_pin(self):
        v = async_stream_fold(3, ENGINE_HPS)
        assert isinstance(v, np.int32)


class TestDegenerateModel:
    def test_detection(self):
        assert is_degenerate_async(None)
        assert is_degenerate_async(make_async_model())
        assert is_degenerate_async(make_async_model(1.0, 0))
        assert not is_degenerate_async(make_async_model(0.7, 0))
        assert not is_degenerate_async(make_async_model(1.0, 2))
        # batched / abstract models are never concretely degenerate
        batched = jax.tree_util.tree_map(
            lambda x: jnp.stack([x, x]), make_async_model())
        assert not is_degenerate_async(batched)
        assert not is_degenerate_async(
            jax.eval_shape(make_async_model))

        @jax.jit
        def probe(am):
            return jnp.asarray(is_degenerate_async(am))

        assert not bool(probe(make_async_model()))

    def test_wake_mask_degenerate_is_all_true(self):
        key = jax.random.PRNGKey(0)
        m = wake_mask(key, 0, 64, 1.0, engine=ENGINE_PUSHSUM)
        assert bool(m.all())

    @pytest.mark.parametrize("engine", ["pushsum", "hps", "social"])
    def test_entrypoint_bit_identity(self, engine):
        """A concretely degenerate plan.async_ routes to the synchronous
        program itself — exact equality, not tolerance."""
        deg = ExecutionPlan(backend="xla",
                            async_=make_async_model(1.0, 0))
        sync = ExecutionPlan(backend="xla")
        if engine == "pushsum":
            el, w = _pushsum_fixture()
            a = run_pushsum_sparse(w, el.src, el.dst, T=6, drop_prob=0.2,
                                   B=2, plan=deg)
            b = run_pushsum_sparse(w, el.src, el.dst, T=6, drop_prob=0.2,
                                   B=2, plan=sync)
        elif engine == "hps":
            _, _, cfg = _hier_fixture()
            w = np.random.default_rng(2).normal(
                size=(12, 2)).astype(np.float32)
            a = run_hps(w, cfg, T=6, plan=deg.replace(store="gap"))
            b = run_hps(w, cfg, T=6, plan=sync.replace(store="gap"))
        else:
            _, model, cfg = _hier_fixture()
            a = run_social_learning(model, cfg, T=6,
                                    plan=deg.replace(store="log_ratio"))
            b = run_social_learning(model, cfg, T=6,
                                    plan=sync.replace(store="log_ratio"))
        assert _trees_equal(a, b)

    def test_step_machinery_degenerate_matches_sync(self):
        """Eager single-step check: awake all-True + staleness 0 runs the
        REAL buffered machinery and still reproduces the synchronous XLA
        step bit for bit (same-tick rendezvous latches this tick's staged
        value on every delivering edge)."""
        el, w = _pushsum_fixture()
        E, d = el.src.shape[0], w.shape[1]
        state = init_sparse_state(jnp.asarray(w), E)
        mask = jnp.asarray(
            np.random.default_rng(3).random(E) < 0.7)
        valid = jnp.ones((E,), bool)
        ref = sparse_pushsum_step(state, mask, el.src, el.dst, valid,
                                  backend="xla")
        got, abuf = sparse_pushsum_step(
            state, mask, el.src, el.dst, valid, backend="xla",
            awake=jnp.ones((w.shape[0],), bool),
            abuf=init_async_buffer(E, d),
            staleness=jnp.asarray(0, jnp.int32))
        assert _trees_equal(ref, got)
        # every edge latched fresh this tick
        assert bool((abuf.age == 0).all())

    def test_graph_axis_plus_abuf_rejected(self):
        el, w = _pushsum_fixture()
        E, d = el.src.shape[0], w.shape[1]
        state = init_sparse_state(jnp.asarray(w), E)
        with pytest.raises(ValueError, match="graph_axis"):
            # share= supplied so the check is hit before any psum needs
            # a bound mesh axis
            sparse_pushsum_step(
                state, jnp.ones((E,), bool), el.src, el.dst,
                jnp.ones((E,), bool), backend="xla", graph_axis="graph",
                share=jnp.full((w.shape[0],), 0.25, jnp.float32),
                awake=jnp.ones((w.shape[0],), bool),
                abuf=init_async_buffer(E, d),
                staleness=jnp.asarray(1, jnp.int32))


class TestMassConservation:
    """The telescoping rho_new - rho_old integration conserves push-sum
    mass under ANY wake schedule — the property the buffer design exists
    to protect."""

    @pytest.mark.parametrize("wake_prob,staleness", [
        (0.3, 0), (0.5, 2), (0.8, 5),
    ])
    def test_invariant_under_random_wakes(self, wake_prob, staleness):
        el, w = _pushsum_fixture(10)
        E, d = el.src.shape[0], w.shape[1]
        n = w.shape[0]
        state = init_sparse_state(jnp.asarray(w), E)
        abuf = init_async_buffer(E, d)
        valid = jnp.ones((E,), bool)
        key = jax.random.PRNGKey(7)
        total0 = jnp.asarray(w).sum(axis=0)
        st = jnp.asarray(staleness, jnp.int32)
        rng = np.random.default_rng(9)
        for t in range(12):
            awake = wake_mask(key, t, n, wake_prob,
                              engine=ENGINE_PUSHSUM)
            mask = jnp.asarray(rng.random(E) < 0.6)
            state, abuf = sparse_pushsum_step(
                state, mask, el.src, el.dst, valid, backend="xla",
                awake=awake, abuf=abuf, staleness=st)
            inv = sparse_mass_invariant(state, el.src, valid)
            np.testing.assert_allclose(np.asarray(inv),
                                       np.asarray(total0),
                                       rtol=1e-5, atol=1e-5)

    def test_invariant_through_entrypoint(self):
        el, w = _pushsum_fixture(9)
        state, _ = run_pushsum_sparse(
            w, el.src, el.dst, T=15, drop_prob=0.3, B=2,
            plan=ExecutionPlan(backend="xla",
                               async_=make_async_model(0.5, 3)))
        inv = sparse_mass_invariant(
            state, jnp.asarray(el.src, jnp.int32),
            jnp.ones((el.src.shape[0],), bool))
        np.testing.assert_allclose(np.asarray(inv),
                                   np.asarray(w.sum(axis=0)),
                                   rtol=1e-5, atol=1e-5)


class TestAsyncEngines:
    def test_pushsum_async_converges(self):
        """Non-degenerate async still drives the ratio to consensus —
        the average of w — just more slowly."""
        el, w = _pushsum_fixture(8)
        state, traj = run_pushsum_sparse(
            w, el.src, el.dst, T=400, drop_prob=0.1, B=2,
            record_every=400,
            plan=ExecutionPlan(backend="xla",
                               async_=make_async_model(0.7, 2)))
        target = w.mean(axis=0)
        final = np.asarray(traj[-1])
        err = np.abs(final - target[None, :]).max()
        assert err < 1e-3

    def test_social_async_finite_and_converging(self):
        _, model, cfg = _hier_fixture()
        res = run_social_learning(
            model, cfg, T=60,
            plan=ExecutionPlan(backend="xla", store="log_ratio",
                               async_=make_async_model(0.6, 2)))
        lr = np.asarray(res.log_ratio)
        assert np.isfinite(lr).all()
        # worst-case wrong/true log-ratio should be falling by the end
        assert lr[-1] < lr[5]

    def test_hps_async_finite(self):
        _, _, cfg = _hier_fixture()
        w = np.random.default_rng(5).normal(size=(12, 2)).astype(np.float32)
        res = run_hps(
            w, cfg, T=40,
            plan=ExecutionPlan(backend="xla", store="gap",
                               async_=make_async_model(0.6, 2)))
        gap = np.asarray(res.gap)
        assert np.isfinite(gap).all()
        assert gap[-1] < gap[0]

    def test_async_composes_with_faults(self):
        from repro.core.faults import make_fault_model
        el, w = _pushsum_fixture(8)
        state, traj = run_pushsum_sparse(
            w, el.src, el.dst, T=10, drop_prob=0.2, B=2,
            plan=ExecutionPlan(
                backend="xla",
                faults=make_fault_model(p_gb=0.1, p_bg=0.5,
                                        leave_prob=0.05, join_prob=0.5),
                async_=make_async_model(0.6, 2)))
        assert np.isfinite(np.asarray(traj)).all()


class TestAsyncErrors:
    def test_masks_plus_async_rejected(self):
        el, w = _pushsum_fixture()
        T, E = 4, el.src.shape[0]
        masks = np.ones((T, E), bool)
        with pytest.raises(ValueError, match="async"):
            run_pushsum_sparse(
                w, el.src, el.dst, T=T, masks=masks,
                plan=ExecutionPlan(async_=make_async_model(0.5, 1)))

    def test_sweep_async_plus_graph_shards_rejected(self):
        el, w = _pushsum_fixture()
        with pytest.raises(ValueError, match="async"):
            run_pushsum_sweep(
                w, el, T=4, drop_probs=[0.0], seeds=[0], B=2,
                plan=ExecutionPlan(graph_shards=2,
                                   async_=make_async_model(0.5, 1)))


class TestSweepAsyncAxis:
    def test_async_axis_minor_most(self):
        el, w = _pushsum_fixture()
        ams = [make_async_model(1.0, 0), make_async_model(0.6, 2)]
        res = run_pushsum_sweep(
            w, el, T=4, drop_probs=[0.0, 0.3], seeds=[0], B=2,
            plan=ExecutionPlan(backend="xla", async_=ams))
        assert res.K == 4
        np.testing.assert_array_equal(np.asarray(res.async_), [0, 1, 0, 1])
        # drop_prob is the slower axis
        np.testing.assert_allclose(
            np.asarray(res.drop_prob), [0.0, 0.0, 0.3, 0.3], atol=1e-7)
        assert "async_" in res.describe()

    def test_batched_degenerate_matches_sync_rows(self):
        """Row 0 of the async axis IS the degenerate model, run through
        the real buffered machinery — it must match the synchronous sweep
        to fault-plane tolerance."""
        el, w = _pushsum_fixture()
        ams = [make_async_model(1.0, 0), make_async_model(0.5, 1)]
        res = run_pushsum_sweep(
            w, el, T=5, drop_probs=[0.2], seeds=[0, 1], B=2,
            plan=ExecutionPlan(backend="xla", async_=ams))
        ref = run_pushsum_sweep(
            w, el, T=5, drop_probs=[0.2], seeds=[0, 1], B=2,
            plan=ExecutionPlan(backend="xla"))
        np.testing.assert_allclose(
            np.asarray(res.err[0::2]), np.asarray(ref.err),
            rtol=1e-5, atol=1e-6)

    def test_single_degenerate_collapses_axis(self):
        el, w = _pushsum_fixture()
        res = run_pushsum_sweep(
            w, el, T=4, drop_probs=[0.0], seeds=[0], B=2,
            plan=ExecutionPlan(backend="xla",
                               async_=make_async_model(1.0, 0)))
        assert res.async_ is None

    def test_social_sweep_async_axis(self):
        _, model, cfg = _hier_fixture()
        ams = [make_async_model(1.0, 0), make_async_model(0.6, 2)]
        res = run_social_sweep(
            model, cfg, T=4, drop_probs=[0.1], seeds=[0],
            plan=ExecutionPlan(backend="xla", store="log_ratio",
                               async_=ams))
        assert res.K == 2
        np.testing.assert_array_equal(np.asarray(res.async_), [0, 1])
        assert np.isfinite(np.asarray(res.log_ratio)).all()
