"""End-to-end behaviour tests for the paper's algorithms.

Validates the paper's own claims at simulation scale:
  Theorem 1 — HPS reaches average consensus under packet drops, error
              decays exponentially;
  Theorem 2 — Algorithm 3 drives every normal agent's belief to theta*
              despite drops and sparse PS fusion;
  Theorem 3 — Algorithm 2 lets every normal agent learn theta* under
              Byzantine attacks, while the unfiltered baseline fails.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.graphs import (
    make_hierarchy, link_schedule, check_assumption3, is_strongly_connected,
    ring, complete, strongly_connected_components, source_components,
    diameter,
)
from repro.core.signals import (
    make_confused_model, check_global_observability, log_ratio_bound,
)
from repro.core.pushsum import run_pushsum, mass_invariant, init_state
from repro.core.hps import HPSConfig, run_hps, theorem1_bound
from repro.core.social import run_social_learning
from repro.core.byzantine import (
    ByzantineConfig, run_byzantine_learning, run_byzantine_learning_ovr,
    healthy_networks,
)
from repro.core import attacks


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------

class TestGraphs:
    def test_ring_strongly_connected(self):
        assert is_strongly_connected(ring(5))
        assert diameter(ring(5)) == 4

    def test_scc_condensation(self):
        # two rings joined by a single edge: 2 SCCs, 1 source
        adj = np.zeros((6, 6), bool)
        adj[:3, :3] = ring(3)
        adj[3:, 3:] = ring(3)
        adj[0, 3] = True
        comps = strongly_connected_components(adj)
        assert sorted(map(len, comps)) == [3, 3]
        srcs = source_components(adj)
        assert len(srcs) == 1 and srcs[0] == [0, 1, 2]

    def test_assumption3_complete_vs_ring(self):
        # complete with n >= 3F+1 satisfies A3; a ring cannot tolerate F=1
        assert check_assumption3(complete(4), F=1)
        assert check_assumption3(complete(7), F=2)
        assert not check_assumption3(ring(5), F=1)

    def test_link_schedule_b_window(self):
        adj = ring(6)
        masks = link_schedule(adj, T=40, drop_prob=0.9, B=4, seed=0)
        # every link is forced up at t % B == B-1
        for t in range(3, 40, 4):
            assert (masks[t] == adj).all()

    def test_hierarchy_block_structure(self):
        topo = make_hierarchy([4, 5, 3], topology="complete")
        assert topo.N == 12 and topo.M == 3
        # no cross-network edges
        off = topo.offsets
        assert not topo.adj[off[0]:off[1], off[1]:].any()
        assert topo.rep_mask().sum() == 3


# ---------------------------------------------------------------------------
# signals
# ---------------------------------------------------------------------------

class TestSignals:
    def test_global_observability(self):
        m = make_confused_model(N=10, m=3, truth=1, confusion=0.5, seed=0)
        assert check_global_observability(np.asarray(m.tables))

    def test_local_confusion_exists(self):
        m = make_confused_model(N=10, m=3, truth=0, confusion=0.5, seed=0)
        t = np.asarray(m.tables)
        # at least one agent has identical rows for some hypothesis pair
        confused = any(
            np.allclose(t[j, a], t[j, b])
            for j in range(10) for a in range(3) for b in range(a + 1, 3)
        )
        assert confused

    def test_log_ratio_bounded(self):
        m = make_confused_model(N=6, m=4, seed=1)
        L = log_ratio_bound(np.asarray(m.tables))
        assert 0 < L < 10  # probability floor keeps L finite


# ---------------------------------------------------------------------------
# push-sum (Theorem 1 machinery)
# ---------------------------------------------------------------------------

class TestPushSum:
    def test_consensus_no_drops(self):
        topo = make_hierarchy([8], topology="ring+", seed=1)
        w = np.random.default_rng(0).normal(size=(8, 3)).astype(np.float32)
        masks = link_schedule(topo.adj, 200, 0.0, 1, seed=0)
        _, traj = run_pushsum(w, topo.adj, masks)
        err = np.abs(np.asarray(traj[-1]) - w.mean(0)).max()
        assert err < 1e-4

    @pytest.mark.parametrize("drop", [0.3, 0.6])
    def test_consensus_under_drops(self, drop):
        topo = make_hierarchy([8], topology="ring+", seed=1)
        w = np.random.default_rng(0).normal(size=(8, 2)).astype(np.float32)
        masks = link_schedule(topo.adj, 500, drop, 4, seed=2)
        _, traj = run_pushsum(w, topo.adj, masks)
        err = np.abs(np.asarray(traj[-1]) - w.mean(0)).max()
        assert err < 1e-3, f"drop={drop} err={err}"

    def test_mass_invariant_under_drops(self):
        topo = make_hierarchy([6], topology="ring+", seed=3)
        w = np.random.default_rng(1).normal(size=(6, 4)).astype(np.float32)
        masks = link_schedule(topo.adj, 123, 0.5, 5, seed=4)
        final, _ = run_pushsum(w, topo.adj, masks)
        inv = np.asarray(mass_invariant(final, jnp.asarray(topo.adj)))
        np.testing.assert_allclose(inv, w.sum(0), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# HPS (Theorem 1)
# ---------------------------------------------------------------------------

class TestHPS:
    def test_cross_network_consensus(self):
        topo = make_hierarchy([5, 6, 4], topology="complete", seed=2)
        w = np.random.default_rng(1).normal(size=(topo.N, 2)).astype(np.float32)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
        res = run_hps(jnp.asarray(w), cfg, 800, seed=3)
        err = np.abs(np.asarray(res.ratio[-1]) - w.mean(0)).max()
        assert err < 5e-2

    def test_exponential_decay(self):
        """Theorem 1: error ~ gamma^(t/2Gamma) — check repeated halving.

        The (T,) error curve comes straight out of the scan via
        ``store="gap"``; no (T, N, d) trajectory is materialized.
        """
        topo = make_hierarchy([5, 5], topology="complete", seed=0)
        w = np.random.default_rng(2).normal(size=(topo.N, 1)).astype(np.float32)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=1, drop_prob=0.1)
        err_t = np.asarray(
            run_hps(jnp.asarray(w), cfg, 600, seed=1, store="gap").gap
        )
        checkpoints = err_t[[100, 300, 599]]
        assert checkpoints[1] < 0.5 * checkpoints[0]
        assert checkpoints[2] < 0.5 * checkpoints[1]

    def test_theorem1_bound_holds(self):
        topo = make_hierarchy([4, 4], topology="complete", seed=5)
        w = np.random.default_rng(3).normal(size=(topo.N, 2)).astype(np.float32)
        cfg = HPSConfig(topo=topo, gamma_period=2, B=1, drop_prob=0.0)
        err = np.asarray(
            run_hps(jnp.asarray(w), cfg, 400, seed=2, store="gap").gap
        )
        for t in (50, 200, 399):
            assert err[t] <= theorem1_bound(cfg, w, t) + 1e-6


# ---------------------------------------------------------------------------
# Algorithm 3 (Theorem 2)
# ---------------------------------------------------------------------------

class TestSocialLearning:
    def test_all_agents_learn_truth_under_drops(self):
        topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
        model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                    seed=0)
        cfg = HPSConfig(topo=topo, gamma_period=8, B=2, drop_prob=0.3)
        res = run_social_learning(model, cfg, T=600, seed=0)
        final = np.asarray(res.beliefs[-1])
        assert final[:, 1].min() > 0.95, final[:, 1]

    def test_log_ratio_linear_decay(self):
        """Theorem 2: log mu(theta)/mu(theta*) decreases over time."""
        topo = make_hierarchy([6, 6], topology="complete", seed=3)
        model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.4,
                                    seed=1)
        cfg = HPSConfig(topo=topo, gamma_period=4, B=1, drop_prob=0.1)
        res = run_social_learning(model, cfg, T=800, seed=1)
        lr = np.asarray(res.log_ratio)  # (T, N, m)
        lr = np.delete(lr, model.truth, axis=2)  # exclude theta* (== 0)
        worst = lr.max(axis=(1, 2))     # worst wrong-hypothesis ratio
        assert worst[-1] < worst[200] < worst[50] + 1e-6
        assert worst[-1] < -5.0

    def test_gamma_insensitivity_remark3(self):
        """Remark 3: sparser PS fusion (larger Gamma) barely hurts."""
        topo = make_hierarchy([6, 6], topology="complete", seed=4)
        model = make_confused_model(N=topo.N, m=3, truth=0, seed=2)
        finals = []
        for gamma in (4, 32):
            cfg = HPSConfig(topo=topo, gamma_period=gamma, B=1, drop_prob=0.1)
            res = run_social_learning(model, cfg, T=500, seed=2)
            finals.append(float(np.asarray(res.beliefs[-1])[:, 0].min()))
        assert finals[0] > 0.9 and finals[1] > 0.9


# ---------------------------------------------------------------------------
# Algorithm 2 (Theorem 3)
# ---------------------------------------------------------------------------

def _byz_setup(seed=0, M_nets=4, n=7):
    topo = make_hierarchy([n] * M_nets, topology="complete", seed=seed)
    # confusion=0: every agent informative => per-network A4 survives
    # removing F agents (required now that healthy_networks checks A4)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0,
                                seed=seed)
    return topo, model


class TestByzantine:
    def test_healthy_networks_detection(self):
        topo, _ = _byz_setup()
        bm = np.zeros(topo.N, bool)
        bm[[2, 9]] = True
        C = healthy_networks(topo, bm, F=2)
        assert C == [0, 1, 2, 3]  # complete(7) tolerates F=2 (7 >= 3F+1)

    @pytest.mark.parametrize("attack_name", ["large_value", "sign_flip",
                                             "truth_suppression"])
    def test_normal_agents_learn_truth(self, attack_name):
        topo, model = _byz_setup()
        byz = (2, 9)
        atk = (attacks.ATTACKS[attack_name](0)
               if attack_name == "truth_suppression"
               else attacks.ATTACKS[attack_name]())
        cfg = ByzantineConfig(topo=topo, F=2, byz=byz, gamma_period=10,
                              attack=atk)
        res = run_byzantine_learning(model, cfg, T=500, seed=0)
        dec = np.asarray(res.decisions[-1])
        bm = cfg.byz_mask()
        assert (dec[~bm] == model.truth).all(), \
            f"{attack_name}: {np.bincount(dec[~bm], minlength=3)}"

    def test_unfiltered_baseline_fails(self):
        """Without the trim filter (F=0 in the update), truth_suppression
        poisons the network — the paper's filter is necessary."""
        topo, model = _byz_setup()
        cfg = ByzantineConfig(
            topo=topo, F=0, byz=(2, 9), gamma_period=10,
            attack=attacks.truth_suppression(0, magnitude=1e4),
        )
        # F=0 keeps Assumption 5 trivially (all nets healthy), no trimming
        res = run_byzantine_learning(model, cfg, T=300, seed=0)
        dec = np.asarray(res.decisions[-1])
        bm = np.zeros(topo.N, bool)
        bm[[2, 9]] = True
        # the attack must fool at least some normal agents
        assert (dec[~bm] != model.truth).any()

    def test_byzantine_majority_outside_C(self):
        """Remark 5: a sub-network outside C may be majority-Byzantine and
        its normal agents still learn via PS gossip."""
        topo = make_hierarchy([7, 7, 7, 3], topology="complete", seed=1)
        model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0,
                                    seed=3)
        byz = (21, 22)  # 2 of 3 agents in network 3 => outside C
        cfg = ByzantineConfig(topo=topo, F=2, byz=byz, gamma_period=8,
                              attack=attacks.large_value())
        bm = cfg.byz_mask()
        C = healthy_networks(topo, bm, cfg.F)
        assert 3 not in C and len(C) >= cfg.F + 1
        # M=4 < 2F+1=5 also exercises the C-reps + extras selection branch
        res = run_byzantine_learning(model, cfg, T=800, seed=1)
        dec = np.asarray(res.decisions[-1])
        normal_out_C = [23]
        assert (dec[normal_out_C] == model.truth).all()


    def test_seed_sweep_vmapped(self):
        """run_byzantine_sweep: one jitted vmap over seeds per attack; every
        seed's normal agents converge to theta*."""
        from repro.core.sweeps import run_byzantine_sweep

        topo, model = _byz_setup()
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=10,
                              attack=attacks.large_value())
        out = run_byzantine_sweep(model, cfg, T=300, seeds=[0, 1])
        res = out["large_value"]
        dec = np.asarray(res.decisions)
        assert dec.shape == (2, 300, topo.N)
        bm = cfg.byz_mask()
        assert (dec[:, -1][:, ~bm] == model.truth).all()

    def test_one_vs_rest_variant(self):
        """DESIGN.md §8 extension: m one-vs-rest dynamics instead of the
        paper's m(m-1) pairwise ones — same filter, cheaper, validated as
        an ablation."""
        topo = make_hierarchy([7] * 5, topology="complete", seed=0)
        model = make_confused_model(N=topo.N, m=4, truth=1, confusion=0.0,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=10,
                              attack=attacks.truth_suppression(1))
        res = run_byzantine_learning_ovr(model, cfg, T=400, seed=0)
        dec = np.asarray(res.decisions[-1])
        assert (dec[~cfg.byz_mask()] == 1).all()
