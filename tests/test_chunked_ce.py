"""Streamed cross-entropy (ce_chunk) must be bit-equal (loss AND grads) to
the full-logits path — it is a §Perf memory optimization, not a change."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
import repro.models.model as M


@pytest.mark.parametrize("arch", ["qwen3_8b", "internvl2_26b", "olmoe_1b_7b"])
@pytest.mark.parametrize("chunk", [4, 8])  # 20 positions: covers pad + exact
def test_chunked_ce_matches_full(arch, chunk):
    cfg = reduced(get_config(arch))
    cfgc = dataclasses.replace(cfg, ce_chunk=chunk)
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_patches, 1024),
            dtype=jnp.float32)
    l1 = M.loss_fn(p, cfg, toks, toks, **kw)
    l2 = M.loss_fn(p, cfgc, toks, toks, **kw)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda pp: M.loss_fn(pp, cfg, toks, toks, **kw))(p)
    g2 = jax.grad(lambda pp: M.loss_fn(pp, cfgc, toks, toks, **kw))(p)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
