"""Sparse edge-list push-sum core: equivalence, invariants, sweep engine.

The dense (N, N, d) implementation is the executable spec; the sparse
(E, d) core must match it on identical link schedules. On top: mass
conservation under extreme (90%) drop rates, the mask-outside-topology
regression (a stray True on a non-edge must never corrupt relay state), an
N=1024 smoke proving the sparse path needs no (N, N) arrays, and the
vmapped scenario-sweep engine.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.graphs import (
    edge_list,
    edge_masks,
    link_schedule,
    random_strongly_connected,
    ring,
    stack_edge_lists,
)
from repro.core.pushsum import (
    init_state,
    mass_invariant,
    pushsum_step,
    run_pushsum,
    run_pushsum_sparse,
    sparse_mass_invariant,
    sparse_ratios,
)
from repro.core.sweeps import run_pushsum_sweep


class TestSparseDenseEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_ratios_match_dense_reference(self, seed):
        """Same schedule -> same trajectory, up to fp32 reduction order."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(5, 14))
        adj = random_strongly_connected(n, 0.3, rng)
        w = rng.normal(size=(n, 3)).astype(np.float32)
        masks = link_schedule(adj, 80, 0.4, 4, seed=seed)
        el = edge_list(adj)
        _, traj_d = run_pushsum(w, adj, masks)
        _, traj_s = run_pushsum_sparse(
            w, el.src, el.dst, 80, masks=edge_masks(masks, el)
        )
        np.testing.assert_allclose(
            np.asarray(traj_s), np.asarray(traj_d), rtol=1e-4, atol=1e-5
        )

    def test_final_mass_invariant_matches(self):
        rng = np.random.default_rng(7)
        adj = random_strongly_connected(9, 0.25, rng)
        w = rng.normal(size=(9, 2)).astype(np.float32)
        masks = link_schedule(adj, 100, 0.5, 5, seed=7)
        el = edge_list(adj)
        fd, _ = run_pushsum(w, adj, masks)
        fs, _ = run_pushsum_sparse(
            w, el.src, el.dst, 100, masks=edge_masks(masks, el)
        )
        inv_d = np.asarray(mass_invariant(fd, jnp.asarray(adj)))
        inv_s = np.asarray(
            sparse_mass_invariant(fs, jnp.asarray(el.src), jnp.asarray(el.valid))
        )
        np.testing.assert_allclose(inv_s, inv_d, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(inv_s, w.sum(0), rtol=1e-3, atol=1e-3)


class TestSparseCore:
    def test_mass_conserved_at_90pct_drop(self):
        """In-scan Bernoulli masks at drop 0.9: the cumulative-sum recovery
        keeps total mass exact (Theorem 1's augmented-graph invariant)."""
        rng = np.random.default_rng(1)
        adj = random_strongly_connected(12, 0.3, rng)
        w = rng.normal(size=(12, 4)).astype(np.float32)
        el = edge_list(adj)
        final, _ = run_pushsum_sparse(
            w, el.src, el.dst, 200, drop_prob=0.9, B=10,
            key=jnp.asarray(np.array([0, 42], np.uint32)),
        )
        inv = np.asarray(
            sparse_mass_invariant(final, jnp.asarray(el.src), jnp.asarray(el.valid))
        )
        np.testing.assert_allclose(inv, w.sum(0), rtol=2e-3, atol=2e-3)

    def test_consensus_under_90pct_drop(self):
        rng = np.random.default_rng(2)
        adj = random_strongly_connected(8, 0.4, rng)
        w = rng.normal(size=(8, 2)).astype(np.float32)
        el = edge_list(adj)
        final, _ = run_pushsum_sparse(
            w, el.src, el.dst, 800, drop_prob=0.9, B=8
        )
        err = np.abs(np.asarray(sparse_ratios(final)) - w.mean(0)).max()
        assert err < 1e-2, err

    def test_n1024_no_dense_arrays(self):
        """N=1024 agents on a sparse digraph: state stays O(E d); the whole
        run never builds an (N, N) array (the dense rho alone would be 4 GB
        at this d)."""
        rng = np.random.default_rng(3)
        adj = random_strongly_connected(1024, 0.002, rng)
        el = edge_list(adj)
        assert el.E < 0.01 * 1024 ** 2      # E << N^2
        w = rng.normal(size=(1024, 4)).astype(np.float32)
        final, _ = run_pushsum_sparse(
            w, el.src, el.dst, 8, drop_prob=0.2, B=4, record_every=8
        )
        assert final.rho.shape == (el.E, 4)
        assert final.z.shape == (1024, 4)
        inv = np.asarray(
            sparse_mass_invariant(final, jnp.asarray(el.src), jnp.asarray(el.valid))
        )
        np.testing.assert_allclose(inv, w.sum(0), rtol=1e-3, atol=1e-2)


class TestMaskTopologyIntersection:
    def test_stray_mask_bit_cannot_corrupt_dense_state(self):
        """Regression: pushsum_step must AND the mask with the topology —
        a True on a non-edge used to latch sigma into rho for a link that
        does not exist, silently breaking the mass invariant."""
        adj = ring(5)
        w = np.random.default_rng(0).normal(size=(5, 2)).astype(np.float32)
        good = np.asarray(adj)
        bad = good.copy()
        bad[2, 0] = True                     # 2 -> 0 is NOT a ring edge
        assert not adj[2, 0]
        st_good = pushsum_step(init_state(jnp.asarray(w)),
                               jnp.asarray(good), jnp.asarray(adj))
        st_bad = pushsum_step(init_state(jnp.asarray(w)),
                              jnp.asarray(bad), jnp.asarray(adj))
        for a, b in zip(st_good, st_bad):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_padding_edges_carry_nothing_sparse(self):
        """Batched/padded edge lists: invalid edges never deliver, so two
        stacked copies of the same graph give identical dynamics."""
        rng = np.random.default_rng(4)
        a1 = random_strongly_connected(6, 0.2, rng)
        a2 = random_strongly_connected(6, 0.6, rng)   # more edges -> padding in a1
        el = stack_edge_lists([a1, a2])
        el1 = edge_list(a1)
        assert el.src.shape[1] > el1.E                # a1's row is padded
        w = rng.normal(size=(6, 2)).astype(np.float32)
        masks = link_schedule(a1, 50, 0.3, 4, seed=4)
        _, t_ref = run_pushsum_sparse(
            w, el1.src, el1.dst, 50, masks=edge_masks(masks, el1)
        )
        padded_masks = np.zeros((50, el.src.shape[1]), bool)
        padded_masks[:, : el1.E] = edge_masks(masks, el1)
        padded_masks[:, el1.E:] = True                # stray Trues on padding
        _, t_pad = run_pushsum_sparse(
            w, el.src[0], el.dst[0], 50, masks=jnp.asarray(padded_masks),
            valid=el.valid[0],
        )
        np.testing.assert_allclose(
            np.asarray(t_pad), np.asarray(t_ref), rtol=1e-5, atol=1e-6
        )


class TestSweepEngine:
    def test_vmapped_sweep_errors_decay_per_scenario(self):
        """One jitted call over graph x drop x seed; consensus error decays
        (or is already at the noise floor) in every scenario and mass is
        conserved across the whole grid."""
        rng = np.random.default_rng(0)
        adjs = [random_strongly_connected(32, 0.05, rng) for _ in range(2)]
        el = stack_edge_lists(adjs)
        w = rng.normal(size=(32, 3)).astype(np.float32)
        res = run_pushsum_sweep(
            w, el, T=250, drop_probs=[0.0, 0.5, 0.9], seeds=[0, 1], B=4
        )
        assert res.K == 2 * 3 * 2
        err = np.asarray(res.err)
        assert np.isfinite(err).all()
        # decay: final error under the round-25 level (or fp noise floor)
        assert (err[:, -1] <= np.maximum(err[:, 25], 1e-4)).all(), err[:, -1]
        assert err[:, -1].max() < 1e-2
        np.testing.assert_allclose(
            np.asarray(res.mass_gap), 0.0, atol=5e-3
        )

    def test_sweep_single_graph_broadcast(self):
        """A non-batched EdgeList sweeps over drop x seed only."""
        rng = np.random.default_rng(5)
        el = edge_list(random_strongly_connected(16, 0.2, rng))
        w = rng.normal(size=(16, 2)).astype(np.float32)
        res = run_pushsum_sweep(w, el, T=150, drop_probs=[0.2, 0.6],
                                seeds=[0, 1, 2], B=4)
        assert res.K == 6
        assert np.asarray(res.err)[:, -1].max() < 1e-2
