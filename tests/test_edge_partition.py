"""Edge-partitioned push-sum (the 2-D graph x data mesh mode).

Covers: the partitioner's layout invariants, the CSR offsets extension of
``sort_by_dst``, bit-identity of the sharded sweep against its single-device
references (vmap emulation in-process; the real 2-D mesh in a subprocess,
with RAGGED padding on both mesh axes — K not divisible by the data axis, E
not divisible by the graph axis), the engine-level (HPS) threading, the
dense-intermediate budget semantics the linter applies to per-shard values,
and the explicit-skip benchmark rows single-device hosts emit.

Subprocess tests follow tests/test_distributed.py: fake devices via
``--xla_force_host_platform_device_count`` in a fresh interpreter so the
forced device count never leaks into this process's jax runtime. They are
additionally marked ``multidevice`` so the dedicated CI lane can select
them (they still run in the plain tier-1 suite — the child process forces
its own devices).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graphs import (
    EdgeList,
    EdgeShards,
    edge_list,
    partition_edge_list,
    random_strongly_connected,
    random_strongly_connected_edge_list,
    sort_by_dst,
    stack_edge_lists,
)
from repro.core.sweeps import run_pushsum_sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _edge_multiset(src, dst, valid):
    return sorted(zip(np.asarray(src)[np.asarray(valid)].tolist(),
                      np.asarray(dst)[np.asarray(valid)].tolist()))


class TestPartitioner:
    @pytest.mark.parametrize("n_shards", [1, 2, 3, 5])
    def test_shards_sorted_padded_and_lossless(self, n_shards):
        rng = np.random.default_rng(0)
        el = random_strongly_connected_edge_list(23, 1.5, rng, sort=False)
        sh = partition_edge_list(el, n_shards)
        assert sh.n_shards == n_shards
        assert sh.e_shard == max(-(-el.E // n_shards), 1)
        # every shard individually dst-sorted (incl. the padded tail), so
        # the concatenation is globally dst-sorted too
        flat = sh.padded_edge_list()
        assert (np.diff(flat.dst) >= 0).all()
        for k in range(n_shards):
            assert (np.diff(sh.dst[k]) >= 0).all()
        # padding is inert and the valid multiset is exactly the input's
        assert int(sh.valid.sum()) == el.E
        assert _edge_multiset(flat.src, flat.dst, flat.valid) == \
            _edge_multiset(el.src, el.dst, el.valid)

    def test_boundary_marks_split_runs_only(self):
        # dst runs: node 0 x3, node 1 x2, node 2 x1 (E = 6)
        el = EdgeList(src=np.array([1, 2, 3, 0, 2, 0], np.int32),
                      dst=np.array([0, 0, 0, 1, 1, 2], np.int32), n=4,
                      valid=np.ones(6, bool))
        # S=2 cuts at 3: exactly between the node-0 and node-1 runs
        assert not partition_edge_list(el, 2).boundary.any()
        # S=3 cuts at 2 and 4: splits node 0's and node 1's runs
        sh = partition_edge_list(el, 3)
        np.testing.assert_array_equal(sh.boundary,
                                      [True, True, False, False])

    def test_batched_partition(self):
        rng = np.random.default_rng(1)
        adjs = [random_strongly_connected(12, 0.1, rng) for _ in range(2)]
        el, _, _ = sort_by_dst(stack_edge_lists(adjs))
        sh = partition_edge_list(el, 3)
        assert sh.is_batched
        assert sh.src.shape == (2, 3, sh.e_shard)
        assert sh.boundary.shape == (2, 12)
        flat = sh.padded_edge_list()
        for g in range(2):
            assert _edge_multiset(flat.src[g], flat.dst[g], flat.valid[g]) \
                == _edge_multiset(el.src[g], el.dst[g], el.valid[g])

    def test_sort_by_dst_offsets(self):
        rng = np.random.default_rng(2)
        el = random_strongly_connected_edge_list(17, 1.0, rng, sort=False)
        s_el, _, _, off = sort_by_dst(el, return_offsets=True)
        assert off.shape == (18,) and off.dtype == np.int32
        assert off[0] == 0 and off[-1] == el.E
        counts = np.bincount(np.asarray(el.dst), minlength=17)
        np.testing.assert_array_equal(np.diff(off), counts)
        for v in range(17):
            assert (s_el.dst[off[v]:off[v + 1]] == v).all()
        # batched: per-row offsets
        adjs = [random_strongly_connected(9, 0.2, rng) for _ in range(2)]
        bel = stack_edge_lists(adjs)
        s_bel, _, _, boff = sort_by_dst(bel, return_offsets=True)
        assert boff.shape == (2, 10)
        for g in range(2):
            np.testing.assert_array_equal(
                np.diff(boff[g]),
                np.bincount(np.asarray(s_bel.dst[g]), minlength=9))


def _boundary_free_el(n=6, in_deg=4, seed=3):
    """Every node gets exactly ``in_deg`` in-edges, so any shard count
    dividing E at run boundaries produces an empty halo index."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    for v in range(n):
        senders = rng.choice([u for u in range(n) if u != v], size=in_deg,
                             replace=False)
        src += senders.tolist()
        dst += [v] * in_deg
    return EdgeList(src=np.array(src, np.int32),
                    dst=np.array(dst, np.int32), n=n,
                    valid=np.ones(n * in_deg, bool))


class TestShardedSweepIdentity:
    """Single-process checks via the ``vmap(axis_name=)`` emulation — the
    bit-exact twin of the mesh path (same psum order on every device)."""

    def test_boundary_free_cut_is_bit_exact(self):
        el = _boundary_free_el(n=6, in_deg=4)       # E = 24
        rng = np.random.default_rng(0)
        w = rng.normal(size=(6, 3)).astype(np.float32)
        kw = dict(drop_probs=[0.0, 0.4], seeds=[0, 1], B=3)
        for S in (2, 3, 6):    # e_shard in {12, 8, 4}: cuts on run bounds
            sh = partition_edge_list(el, S)
            assert not sh.boundary.any()
            assert sh.e_pad == el.E                 # no padding either
            ref = run_pushsum_sweep(w, sh.padded_edge_list(), 12, **kw)
            two_d = run_pushsum_sweep(w, el, 12, graph_shards=S, **kw)
            np.testing.assert_array_equal(np.asarray(two_d.err),
                                          np.asarray(ref.err))
            np.testing.assert_array_equal(np.asarray(two_d.final_ratio),
                                          np.asarray(ref.final_ratio))

    def test_random_graph_matches_to_reduce_order(self):
        """With boundary nodes the halo psum reassociates those receivers'
        sums — equality up to fp reduce order, as documented."""
        rng = np.random.default_rng(4)
        el = random_strongly_connected_edge_list(24, 1.5, rng, sort=False)
        w = rng.normal(size=(24, 2)).astype(np.float32)
        kw = dict(drop_probs=[0.0, 0.3], seeds=[0, 1], B=3)
        sh = partition_edge_list(el, 3)
        assert sh.boundary.any()                    # the interesting case
        ref = run_pushsum_sweep(w, sh.padded_edge_list(), 15, **kw)
        two_d = run_pushsum_sweep(w, sh, 15, graph_shards=3, **kw)
        np.testing.assert_allclose(np.asarray(two_d.err),
                                   np.asarray(ref.err), atol=1e-5)
        np.testing.assert_allclose(np.asarray(two_d.final_ratio),
                                   np.asarray(ref.final_ratio), atol=1e-5)
        assert np.abs(np.asarray(two_d.mass_gap)).max() < 1e-3

    def test_edge_shards_input_and_shard_count_mismatch(self):
        rng = np.random.default_rng(5)
        el = random_strongly_connected_edge_list(10, 1.0, rng, sort=False)
        sh = partition_edge_list(el, 2)
        res = run_pushsum_sweep(np.ones((10, 2), np.float32), sh, 5,
                                drop_probs=[0.2], seeds=[0])
        assert res.err.shape == (1, 5)
        with pytest.raises(ValueError, match="shards"):
            run_pushsum_sweep(np.ones((10, 2), np.float32), sh, 5,
                              graph_shards=4)

    def test_hps_engine_sharded_emulation_matches_plain(self):
        """The HPS scan core with graph_axis/n_shards under a
        vmap(axis_name=) over shard-sliced runtimes: node-state outputs are
        shard-replicated and match the plain core on the padded list."""
        from repro.core.hps import (
            HPSRuntime, _hps_compiled, hps_runtime_from_edge_list,
        )

        el = _boundary_free_el(n=6, in_deg=4)       # exactness guaranteed
        sh = partition_edge_list(el, 2)
        rep = np.zeros(6, bool)
        rep[::3] = True
        rt = hps_runtime_from_edge_list(
            sh.padded_edge_list(), rep, drop_prob=0.3, gamma_period=4, B=2)
        rng = np.random.default_rng(6)
        w = rng.normal(size=(6, 2)).astype(np.float32)
        key = jax.random.PRNGKey(0)

        final_p, (ratio_p, gap_p) = _hps_compiled(
            key, rt, w, T=9, store="trajectory", backend="xla")

        rt_sh = rt._replace(src=jnp.asarray(sh.src),
                            dst=jnp.asarray(sh.dst),
                            valid=jnp.asarray(sh.valid))
        in_rt = HPSRuntime(src=0, dst=0, valid=0, rep_mask=None,
                           drop_prob=None, gamma=None, B=None, M=None)
        final_s, (ratio_s, gap_s) = jax.vmap(
            lambda r: _hps_compiled(
                key, r, w, T=9, store="trajectory", backend="xla",
                graph_axis="hpslint", n_shards=2),
            in_axes=(in_rt,), axis_name="hpslint",
        )(rt_sh)

        ratio_s, gap_s = np.asarray(ratio_s), np.asarray(gap_s)
        # shard-replicated node outputs: every shard returns the same thing
        assert (ratio_s[0] == ratio_s[1]).all()
        np.testing.assert_array_equal(ratio_s[0], np.asarray(ratio_p))
        np.testing.assert_array_equal(gap_s[0], np.asarray(gap_p))
        # edge state really is per-shard: (S, e_shard, d) not (S, E, d)
        assert final_s.rho.shape == (2, sh.e_shard, 2)


@pytest.mark.multidevice
class TestMesh2D:
    def test_mesh_matches_emulation_ragged_both_axes(self):
        """shard_map on a real (data=2, graph=4) mesh vs the single-device
        emulation, bit-exact, with ragged padding exercised on BOTH mesh
        axes: K=5 scenarios over a 2-device data axis (pad 1) and an edge
        count not divisible by 4 shards (padded tails)."""
        res = _run_subprocess("""
            from repro.core.graphs import (
                partition_edge_list, random_strongly_connected_edge_list)
            from repro.core.sweeps import run_pushsum_sweep
            from repro.distributed.sharding import sweep_mesh

            rng = np.random.default_rng(7)
            el = random_strongly_connected_edge_list(30, 1.3, rng,
                                                     sort=False)
            assert el.E % 4 != 0, el.E        # ragged over the graph axis
            w = rng.normal(size=(30, 2)).astype(np.float32)
            kw = dict(drop_probs=[0.0, 0.2, 0.5, 0.7, 0.9], seeds=[0],
                      B=3, graph_shards=4)    # K = 5, ragged over data=2
            emu = run_pushsum_sweep(w, el, 20, **kw)
            mesh = sweep_mesh(2, 4)
            msh = run_pushsum_sweep(w, el, 20, mesh=mesh, **kw)
            sh = partition_edge_list(el, 4)
            ref = run_pushsum_sweep(w, sh.padded_edge_list(), 20,
                                    drop_probs=kw["drop_probs"],
                                    seeds=[0], B=3)
            print(json.dumps({
                "K": int(msh.K),
                "mesh_vs_emul": float(np.abs(
                    np.asarray(msh.err) - np.asarray(emu.err)).max()),
                "mesh_vs_ref": float(np.abs(
                    np.asarray(msh.err) - np.asarray(ref.err)).max()),
                "final_vs_emul": float(np.abs(
                    np.asarray(msh.final_ratio)
                    - np.asarray(emu.final_ratio)).max()),
                "gap": float(np.abs(np.asarray(msh.mass_gap)).max()),
            }))
        """)
        assert res["K"] == 5                       # pad rows sliced off
        assert res["mesh_vs_emul"] == 0.0          # bit-exact twin
        assert res["final_vs_emul"] == 0.0
        assert res["mesh_vs_ref"] < 1e-5           # reduce order only
        assert res["gap"] < 1e-3

    def test_data_axis_ragged_k_unchanged(self):
        """Satellite regression: the plain 1-D data-sharded path still
        pads ragged K (5 scenarios over 8 devices) bit-identically."""
        res = _run_subprocess("""
            from repro.core.graphs import random_strongly_connected_edge_list
            from repro.core.sweeps import run_pushsum_sweep
            from repro.distributed.sharding import sweep_mesh

            rng = np.random.default_rng(8)
            el = random_strongly_connected_edge_list(20, 1.0, rng)
            w = rng.normal(size=(20, 2)).astype(np.float32)
            kw = dict(drop_probs=[0.0, 0.3, 0.5, 0.7, 0.9], seeds=[0], B=3)
            ref = run_pushsum_sweep(w, el, 20, **kw)
            msh = run_pushsum_sweep(w, el, 20, mesh=sweep_mesh(8), **kw)
            print(json.dumps({
                "K": int(msh.K),
                "err": float(np.abs(
                    np.asarray(msh.err) - np.asarray(ref.err)).max()),
            }))
        """)
        assert res["K"] == 5
        assert res["err"] == 0.0


def _run_subprocess(body: str, devices: int = 8, timeout: int = 420) -> dict:
    """tests/test_distributed.py's fresh-interpreter fake-device runner."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for attempt in range(2):
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            timeout=timeout, env=env, cwd=REPO,
        )
        if out.returncode == 0:
            break
        if "rendezvous" not in out.stderr.lower():
            break
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    line = [l for l in out.stdout.strip().splitlines() if l.startswith("{")][-1]
    return json.loads(line)


class TestStaticsBudgetTeaching:
    """The dense-intermediate linter must treat per-shard (E_shard, d)
    values as in-budget while any gathered full-E superset is a failure —
    using the REGISTERED pushsum_sharded contract's own patterns."""

    def _patterns(self):
        import repro.core.sweeps  # noqa: F401  (registers the contract)
        from repro.statics.contracts import get
        return get("pushsum_sharded").forbidden_for(None)

    def test_per_shard_values_in_budget(self):
        from repro.statics import dense, walk

        pats = self._patterns()
        assert ("E", "*") in pats and ("N", "N") in pats
        dims = {"N": 11, "d": 3, "S": 2, "Es": 4, "E": 8}

        def per_shard_step(rho, w):          # (Es, d), (N, d)
            upd = rho * 2.0 + 1.0            # (Es, d) — shard-local
            recv = jnp.zeros_like(w).at[:4].add(upd)
            return upd, recv

        closed = walk.trace(per_shard_step,
                            jnp.zeros((4, 3)), jnp.zeros((11, 3)))
        assert dense.find_forbidden(closed, dims, pats) == []

    def test_gathered_full_e_flagged(self):
        from repro.statics import dense, walk

        pats = self._patterns()
        dims = {"N": 11, "d": 3, "S": 2, "Es": 4, "E": 8}

        def gathered(rho_sh):                # (S, Es, d) -> (E, d) gather
            return rho_sh.reshape(8, 3) + 1.0

        finds = dense.find_forbidden(
            walk.trace(gathered, jnp.zeros((2, 4, 3))), dims, pats)
        assert finds, "a full-E gather must be a lint failure"
        assert all(f.check == "dense-intermediate" for f in finds)

    def test_registered_fixture_traces_clean(self):
        """The CLI fixture for the contract (the exact program `statics
        lint` walks) has no forbidden intermediates."""
        from repro.statics import dense
        from repro.statics.cli import _FIXTURES

        dims, stores, make = _FIXTURES["pushsum_sharded"]()
        pats = self._patterns()
        for store in stores:
            closed = make("xla", store)
            assert dense.find_forbidden(closed, dims, pats) == []


class TestBenchSkipRows:
    def test_merge_keeps_explicit_skips_drops_plain_nan(self, tmp_path):
        from benchmarks import merge_bench_json

        p = str(tmp_path / "BENCH_x.json")
        merge_bench_json(p, [
            ("ok_N16", 1.5, "E=32"),
            ("failed_N16", float("nan"), "subprocess_failed;boom"),
            ("gated_N16", float("nan"), "skipped=single_device_host;devices=1"),
        ])
        text = open(p).read()
        assert "NaN" not in text             # strict RFC-8259 artifact
        data = json.loads(text)
        assert "failed_N16" not in data      # degraded rows still dropped
        assert data["gated_N16"]["us_per_call"] is None
        assert data["gated_N16"]["derived"].startswith("skipped=")

    def test_check_announces_skip_and_table_renders_dash(self, tmp_path,
                                                         capsys, monkeypatch):
        from benchmarks import bench_table, merge_bench_json
        from benchmarks.run import _check_regressions

        bad = _check_regressions(
            "b.json", {"ok_N16": {"us_per_call": 1.0}},
            {"ok_N16": (1.1, "E=32"),
             "gated_N16": (float("nan"), "skipped=single_device_host")})
        assert bad == 0
        out = capsys.readouterr().out
        assert "# SKIP gated_N16: skipped=single_device_host" in out

        merge_bench_json(str(tmp_path / "BENCH_t.json"), [
            ("gated_N16", float("nan"), "skipped=single_device_host"),
        ])
        monkeypatch.setattr(bench_table, "RESULTS", str(tmp_path))
        (table,) = bench_table.tables()
        assert "| `gated_N16` | — |" in table

    def test_smoke_rows_skip_or_measure_by_device_count(self):
        from benchmarks.pushsum_sweep import _bench_edge_sharded_smoke

        r = _bench_edge_sharded_smoke(n=64, T=10)
        if jax.device_count() < 2:
            assert r["us_per_call"] != r["us_per_call"]      # NaN
            assert r["derived"].startswith("skipped=")
        else:
            assert r["us_per_call"] == r["us_per_call"]
            assert "shards=2" in r["derived"]
