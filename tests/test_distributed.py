"""Multi-device distributed-runtime tests.

The heavy multi-worker scenarios run in SUBPROCESSES: the in-process CPU
collectives rendezvous is unreliable when one pytest process reuses a
device-backed client across many different executables on a single-core
host (thread starvation aborts the process). One scenario per fresh
interpreter is deterministic.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(body: str, devices: int = 8, timeout: int = 420) -> dict:
    """Run a snippet under N fake devices; it must print one JSON line."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch import compat
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # The in-process CPU collective rendezvous can abort under host load
    # (XLA kills after a 40 s stall on this 1-core box); retry once.
    for attempt in range(2):
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            timeout=timeout, env=env, cwd=REPO,
        )
        if out.returncode == 0:
            break
        if "rendezvous" not in out.stderr.lower():
            break
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    line = [l for l in out.stdout.strip().splitlines() if l.startswith("{")][-1]
    return json.loads(line)


class TestAggregators:
    def test_mean_trim_pushsum_semantics(self):
        res = _run_subprocess("""
            from repro.distributed.aggregation import AGGREGATORS, AggregatorConfig
            from repro.kernels.trimmed_mean.ref import trimmed_mean_ref
            mesh = compat.make_mesh((2, 4), ("pod", "data"))
            W, D = 8, 512
            rng = np.random.default_rng(0)
            g_all = jnp.asarray(rng.normal(size=(W, D)).astype(np.float32))

            def run(kind, **kw):
                cfg = AggregatorConfig(kind=kind, **kw)
                fn = AGGREGATORS[kind]
                def body(g, key):
                    out = fn({"g": g[0]}, cfg, "data", "pod", key)["g"]
                    return out[None]
                sm = compat.shard_map(body, mesh=mesh,
                                      in_specs=(P(("pod","data"), None), P()),
                                      out_specs=P(("pod","data"), None),
                                      axis_names=frozenset({"pod","data"}),
                                      check_vma=False)
                return np.asarray(jax.jit(sm)(g_all, jax.random.PRNGKey(0)))

            mean_err = float(np.abs(run("mean")[0] - np.asarray(g_all.mean(0))).max())
            trim = run("trimmed_mean", F=2)
            trim_err = float(np.abs(trim[0] - np.asarray(trimmed_mean_ref(g_all, 2))).max())
            trim_agree = float(np.ptp(trim, axis=0).max())
            scale = float(np.abs(np.asarray(g_all)).max())
            ps = run("pushsum", gossip_rounds=120, gamma_period=4, drop_prob=0.2)
            ps_err = float(np.abs(ps - np.asarray(g_all.mean(0))).max()) / scale
            ps_err_few = float(np.abs(
                run("pushsum", gossip_rounds=10, gamma_period=4, drop_prob=0.2)
                - np.asarray(g_all.mean(0))).max()) / scale
            print(json.dumps(dict(mean_err=mean_err, trim_err=trim_err,
                                  trim_agree=trim_agree, ps_err=ps_err,
                                  ps_err_few=ps_err_few)))
        """)
        assert res["mean_err"] < 1e-5
        assert res["trim_err"] < 1e-5
        assert res["trim_agree"] == 0.0          # all workers identical
        # ring gossip + sparse PS fusion converges per Theorem 1 (the rate
        # constant for a 4-ring per pod is modest — check level + direction)
        assert res["ps_err"] < 0.15              # relative consensus error
        assert res["ps_err"] < 0.5 * res["ps_err_few"]

    def test_hierarchical_trim_filters_byzantine_pod(self):
        res = _run_subprocess("""
            from repro.distributed.aggregation import AGGREGATORS, AggregatorConfig
            mesh = compat.make_mesh((2, 4), ("pod", "data"))
            rng = np.random.default_rng(1)
            D = 256
            honest = rng.normal(size=(8, D)).astype(np.float32)
            g_all = honest.copy()
            g_all[3] = 1e6          # one Byzantine worker in pod 0
            cfg = AggregatorConfig(kind="hierarchical_trim", F=1)
            fn = AGGREGATORS["hierarchical_trim"]
            def body(g, key):
                return fn({"g": g[0]}, cfg, "data", "pod", key)["g"][None]
            sm = compat.shard_map(body, mesh=mesh,
                                  in_specs=(P(("pod","data"), None), P()),
                                  out_specs=P(("pod","data"), None),
                                  axis_names=frozenset({"pod","data"}),
                                  check_vma=False)
            out = np.asarray(jax.jit(sm)(jnp.asarray(g_all), jax.random.PRNGKey(0)))
            ok = bool((np.abs(out) <= np.abs(honest).max() + 1e-3).all())
            print(json.dumps(dict(bounded=ok, mx=float(np.abs(out).max()))))
        """)
        assert res["bounded"], res


class TestRobustTraining:
    def test_trimmed_training_survives_byzantine_worker(self):
        """Decentralized training with a sign-flipping Byzantine worker:
        trimmed_mean keeps the loss finite and decreasing; param copies
        stay in exact consensus."""
        res = _run_subprocess("""
            import dataclasses
            from repro.configs import get_config, reduced
            from repro.distributed.trainer import (TrainConfig, make_train_step,
                replicate_for_workers, worker_opt_init, param_spread)
            from repro.distributed.aggregation import AggregatorConfig
            from repro.optim import AdamWConfig
            from repro.data import SyntheticLMData
            import repro.models.model as M
            mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
            cfg = dataclasses.replace(reduced(get_config("paper_sim")),
                                      attn_impl="naive")
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            data = SyntheticLMData(cfg.vocab, 32, 8, flavour="markov", seed=0)
            tc = TrainConfig(arch=cfg,
                agg=AggregatorConfig(kind="trimmed_mean", F=1),
                opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30),
                byzantine_workers=(2,))
            factory, _ = make_train_step(tc, mesh)
            pw = replicate_for_workers(params, 4)
            ow = worker_opt_init(pw)
            losses = []
            with compat.set_mesh(mesh):
                step = jax.jit(factory(pw))
                for s in range(12):
                    pw, ow, loss = step(pw, ow, data.batch(s),
                                        jax.random.PRNGKey(s))
                    losses.append(float(loss))
            print(json.dumps(dict(first=losses[0], last=losses[-1],
                                  spread=float(param_spread(pw)))))
        """)
        assert np.isfinite(res["last"])
        assert res["last"] < res["first"]
        assert res["spread"] < 1e-5  # identical trim output => exact consensus

    def test_pushsum_training_bounded_divergence(self):
        """Gossip aggregation: worker copies drift by the consensus error,
        which stays bounded and training still descends."""
        res = _run_subprocess("""
            import dataclasses
            from repro.configs import get_config, reduced
            from repro.distributed.trainer import (TrainConfig, make_train_step,
                replicate_for_workers, worker_opt_init, param_spread)
            from repro.distributed.aggregation import AggregatorConfig
            from repro.optim import AdamWConfig
            from repro.data import SyntheticLMData
            import repro.models.model as M
            mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
            cfg = dataclasses.replace(reduced(get_config("paper_sim")),
                                      attn_impl="naive")
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            data = SyntheticLMData(cfg.vocab, 32, 8, flavour="markov", seed=0)
            tc = TrainConfig(arch=cfg,
                agg=AggregatorConfig(kind="pushsum", gossip_rounds=16,
                                     gamma_period=4, drop_prob=0.2),
                opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30))
            factory, _ = make_train_step(tc, mesh)
            pw = replicate_for_workers(params, 4)
            ow = worker_opt_init(pw)
            losses = []
            with compat.set_mesh(mesh):
                step = jax.jit(factory(pw))
                for s in range(10):
                    pw, ow, loss = step(pw, ow, data.batch(s),
                                        jax.random.PRNGKey(s))
                    losses.append(float(loss))
            print(json.dumps(dict(first=losses[0], last=losses[-1],
                                  spread=float(param_spread(pw)))))
        """)
        assert np.isfinite(res["last"])
        assert res["last"] < res["first"]
        assert 0 < res["spread"] < 0.05

    def test_gspmd_with_tensor_parallel_matches_single_device(self):
        """The GSPMD mean path on a (1,2,4) mesh must track the same loss
        as single-device execution (same seeds, same data)."""
        res = _run_subprocess("""
            import dataclasses
            from repro.configs import get_config, reduced
            from repro.distributed.trainer import TrainConfig, make_train_step
            from repro.optim import AdamWConfig, adamw_init
            from repro.data import SyntheticLMData
            import repro.models.model as M
            cfg = dataclasses.replace(reduced(get_config("qwen3_8b")),
                                      attn_impl="naive")
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            data = SyntheticLMData(cfg.vocab, 32, 8, seed=0)
            results = {}
            for name, shape in [("dp_tp", (2, 4)), ("single", (1, 1))]:
                mesh = compat.make_mesh(shape, ("data", "model"),
                    devices=jax.devices()[: shape[0]*shape[1]])
                tc = TrainConfig(arch=cfg, opt=AdamWConfig(
                    lr=1e-3, warmup_steps=2, total_steps=20))
                factory, _ = make_train_step(tc, mesh)
                p, o = params, adamw_init(params)
                with compat.set_mesh(mesh):
                    step = jax.jit(factory(p))
                    ls = []
                    for s in range(4):
                        p, o, loss = step(p, o, data.batch(s))
                        ls.append(float(loss))
                results[name] = ls
            print(json.dumps(results))
        """)
        np.testing.assert_allclose(res["dp_tp"], res["single"], rtol=2e-3,
                                   atol=2e-3)


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        """Every arch's param tree gets a spec tree of identical structure,
        and every sharded axis divides the dimension (single-pod mesh)."""
        from repro.configs import all_configs, reduced
        from repro.distributed.sharding import param_specs
        from repro.models import model as M
        from jax.sharding import PartitionSpec as P

        from repro.launch.compat import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        for name, cfg in all_configs().items():
            r = reduced(cfg)
            struct = jax.eval_shape(
                lambda c=r: M.init_params(jax.random.PRNGKey(0), c)
            )
            specs = param_specs(struct, r, mesh, fsdp=True)
            s_leaves = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)
            )
            p_leaves = jax.tree_util.tree_leaves(struct)
            assert len(s_leaves) == len(p_leaves), name
