"""Sparse neighbor-list Byzantine core + fused trim-gather kernel.

The contract under test: the Pallas extraction kernel (interpret mode on CPU
— the identical traced program that compiles on TPU) matches the sort-based
XLA oracle, which itself matches the dense ``trimmed_neighbor_mean``
reference per receiver; full Algorithm 2 trajectories agree between the
dense broadcast core and the sparse neighbor-list core for F in {0, 1, 2},
pairwise and one-vs-rest, sorted/shuffled/padded neighbor layouts; the
sparse path never materializes an (N, N, ...) intermediate (jaxpr
inspection); the three per-iteration PRNG streams have disjoint fold-in
domains; a (topology x F x seed) grid runs as ONE compiled program; and the
compiled-scan caches are LRU-bounded.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import attacks
from repro.core.byzantine import (
    ByzantineConfig,
    N_STREAMS,
    STREAM_FUSION,
    STREAM_GOSSIP,
    STREAM_SIGNAL,
    make_byzantine_runtime,
    make_byzantine_scan,
    run_byzantine_learning,
    run_byzantine_learning_ovr,
    stream_fold,
    trimmed_neighbor_mean,
)
from repro.core.graphs import (
    make_hierarchy,
    neighbor_lists,
    random_strongly_connected,
    stack_neighbor_lists,
)
from repro.core.signals import make_confused_model
from repro.kernels.byz_trim import resolve_backend, trim_gather, trim_gather_ref
from repro.kernels.byz_trim.byz_trim import trim_gather_pallas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_problem(n, p_edge, P, seed, deg_max=None, shuffle=None):
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, p_edge, rng)
    nl = neighbor_lists(adj, deg_max=deg_max, shuffle_seed=shuffle)
    r = jnp.asarray(rng.normal(size=(n, P)).astype(np.float32))
    bmsg = jnp.asarray(
        (1e3 * rng.normal(size=(n, nl.deg_max, P))).astype(np.float32)
    )
    byz_nbr = jnp.asarray(rng.random((n, nl.deg_max)) < 0.2) & jnp.asarray(
        nl.valid
    )
    return adj, nl, r, bmsg, byz_nbr


class TestNeighborLists:
    def test_slots_match_adjacency(self):
        rng = np.random.default_rng(0)
        adj = random_strongly_connected(13, 0.3, rng)
        nl = neighbor_lists(adj)
        assert nl.deg_max == adj.sum(axis=0).max()
        np.testing.assert_array_equal(nl.in_degree(), adj.sum(axis=0))
        for j in range(13):
            senders = sorted(nl.idx[j, nl.valid[j]].tolist())
            assert senders == sorted(np.nonzero(adj[:, j])[0].tolist())

    def test_deg_max_padding_and_bounds(self):
        adj = random_strongly_connected(8, 0.4, np.random.default_rng(1))
        nl = neighbor_lists(adj, deg_max=11)
        assert nl.deg_max == 11
        np.testing.assert_array_equal(nl.in_degree(), adj.sum(axis=0))
        with pytest.raises(ValueError):
            neighbor_lists(adj, deg_max=1)

    def test_stack_pads_to_widest(self):
        rng = np.random.default_rng(2)
        a1 = random_strongly_connected(9, 0.1, rng)
        a2 = random_strongly_connected(9, 0.6, rng)
        nls = [neighbor_lists(a) for a in (a1, a2)]
        st = stack_neighbor_lists(nls)
        assert st.is_batched and st.deg_max == max(n.deg_max for n in nls)
        np.testing.assert_array_equal(st.in_degree()[0], a1.sum(axis=0))
        np.testing.assert_array_equal(st.in_degree()[1], a2.sum(axis=0))

    def test_topo_accepted(self):
        topo = make_hierarchy([4, 4], topology="complete")
        nl = neighbor_lists(topo)
        np.testing.assert_array_equal(nl.in_degree(), topo.adj.sum(axis=0))


class TestTrimGatherKernel:
    @pytest.mark.parametrize("F,block_n,seed", [(0, 8, 0), (1, 16, 1),
                                                (2, 8, 2), (2, 1024, 3)])
    def test_pallas_matches_xla_ref(self, F, block_n, seed):
        """Extraction kernel == sort oracle, including when N is far from a
        block multiple (padding receiver rows must stay inert)."""
        _, nl, r, bmsg, byz_nbr = _random_problem(29, 0.3, 5, seed)
        args = (r, jnp.asarray(nl.idx), jnp.asarray(nl.valid), bmsg, byz_nbr)
        ts_ref, k_ref = trim_gather_ref(*args, F)
        ts_p, k_p = trim_gather_pallas(*args, F, block_n=block_n,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(ts_p), np.asarray(ts_ref),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_array_equal(np.asarray(k_p), np.asarray(k_ref))

    @pytest.mark.parametrize("shuffle", [None, 7])
    @pytest.mark.parametrize("F", [0, 1, 2])
    def test_ref_matches_dense_oracle(self, F, shuffle):
        """Sorted and shuffled slot layouts, padded degree: the neighbor-list
        trim equals the dense (N, N) broadcast + sort per receiver."""
        adj, nl, r, bmsg, byz_nbr = _random_problem(
            17, 0.4, 4, seed=F + 10, deg_max=15, shuffle=shuffle
        )
        n = 17
        # scatter the slot values into the dense (sender, receiver) layout
        vals = np.zeros((n, n, 4), np.float32)
        vals[:] = np.asarray(r)[:, None, :]          # honest: sender's state
        bm = np.asarray(bmsg)
        bn = np.asarray(byz_nbr)
        for j in range(n):
            for k in range(nl.deg_max):
                if nl.valid[j, k] and bn[j, k]:
                    vals[nl.idx[j, k], j] = bm[j, k]
        ts_d, k_d = trimmed_neighbor_mean(
            jnp.asarray(vals)[:, :, :, None], jnp.asarray(adj), F
        )
        ts_s, k_s = trim_gather_ref(
            r, jnp.asarray(nl.idx), jnp.asarray(nl.valid), bmsg, byz_nbr, F
        )
        np.testing.assert_allclose(np.asarray(ts_s),
                                   np.asarray(ts_d)[..., 0],
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(k_s), np.asarray(k_d))

    def test_under_trimmed_degree_keeps_nothing(self):
        """deg <= 2F receivers keep zero values — same as the dense rank
        window [F, deg - F) being empty."""
        idx = jnp.asarray([[1, 2, 0], [2, 0, 0], [0, 0, 0]], jnp.int32)
        valid = jnp.asarray([[True, True, True],
                             [True, False, False],
                             [False, False, False]])
        r = jnp.asarray(np.arange(6, dtype=np.float32).reshape(3, 2))
        bmsg = jnp.zeros((3, 3, 2), jnp.float32)
        bnbr = jnp.zeros((3, 3), bool)
        for backend, kw in (("xla", {}), ("pallas", {"interpret": True})):
            ts, kept = trim_gather(r, idx, valid, bmsg, bnbr, 2,
                                   backend=backend, **kw)
            np.testing.assert_array_equal(np.asarray(kept), [0.0, 0.0, 0.0])
            np.testing.assert_array_equal(np.asarray(ts), np.zeros((3, 2)))

    def test_dynamic_F_traced_matches_static(self):
        """The sort-based lowering accepts a traced F — what batched
        (topology, F) grids vmap over."""
        _, nl, r, bmsg, byz_nbr = _random_problem(15, 0.4, 3, seed=5)
        args = (r, jnp.asarray(nl.idx), jnp.asarray(nl.valid), bmsg, byz_nbr)
        dyn = jax.jit(lambda f: trim_gather_ref(*args, f))
        for F in (0, 1, 2):
            ts_s, k_s = trim_gather_ref(*args, F)
            ts_d, k_d = dyn(jnp.asarray(F, jnp.int32))
            np.testing.assert_allclose(np.asarray(ts_d), np.asarray(ts_s))
            np.testing.assert_array_equal(np.asarray(k_d), np.asarray(k_s))

    def test_pallas_rejects_traced_F(self):
        _, nl, r, bmsg, byz_nbr = _random_problem(9, 0.4, 2, seed=6)
        with pytest.raises(ValueError, match="static int F"):
            trim_gather(r, jnp.asarray(nl.idx), jnp.asarray(nl.valid),
                        bmsg, byz_nbr, jnp.asarray(1), backend="pallas")

    def test_auto_backend_is_xla_off_tpu(self):
        expected = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert resolve_backend("auto") == expected


def _byz_setup(seed=0, M_nets=4, n=7, m=3):
    topo = make_hierarchy([n] * M_nets, topology="complete", seed=seed)
    model = make_confused_model(N=topo.N, m=m, truth=0, confusion=0.0,
                                seed=seed)
    return topo, model


_EQUIV_ATTACKS = ["large_value", "sign_flip", "extreme_pull",
                  "truth_suppression"]


def _attack(name):
    return (attacks.ATTACKS[name](0) if name == "truth_suppression"
            else attacks.ATTACKS[name]())


class TestByzantineCoreEquivalence:
    """Acceptance: sparse trajectories == dense oracle within atol 1e-5."""

    @pytest.mark.parametrize("F,byz", [(0, ()), (1, (2,)), (2, (2, 9))])
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_pairwise_trajectory_equivalence(self, F, byz, backend):
        topo, model = _byz_setup()
        cfg = ByzantineConfig(topo=topo, F=F, byz=byz, gamma_period=7,
                              attack=attacks.large_value())
        dense = run_byzantine_learning(model, cfg, T=50, seed=0, core="dense")
        sparse = run_byzantine_learning(model, cfg, T=50, seed=0,
                                        core="sparse", backend=backend)
        np.testing.assert_allclose(np.asarray(sparse.r), np.asarray(dense.r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(sparse.decisions),
                                      np.asarray(dense.decisions))

    @pytest.mark.parametrize("attack_name", _EQUIV_ATTACKS)
    def test_attack_equivalence(self, attack_name):
        """Every deterministic attack's sparse form reproduces its dense
        point-to-point tensor exactly (random_noise draws per-slot instead
        of per-pair, so only its distribution matches)."""
        topo, model = _byz_setup()
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=5,
                              attack=_attack(attack_name))
        dense = run_byzantine_learning(model, cfg, T=40, seed=1, core="dense")
        sparse = run_byzantine_learning(model, cfg, T=40, seed=1,
                                        core="sparse", backend="xla")
        np.testing.assert_allclose(np.asarray(sparse.r), np.asarray(dense.r),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("F,byz", [(0, ()), (1, (2,)), (2, (2, 9))])
    def test_ovr_trajectory_equivalence(self, F, byz):
        topo, model = _byz_setup(M_nets=5, m=4)
        cfg = ByzantineConfig(topo=topo, F=F, byz=byz, gamma_period=6,
                              attack=attacks.sign_flip())
        dense = run_byzantine_learning_ovr(model, cfg, T=40, seed=0,
                                           core="dense")
        sparse = run_byzantine_learning_ovr(model, cfg, T=40, seed=0,
                                            core="sparse")
        assert sparse.r.shape == dense.r.shape == (40, topo.N, 4, 1)
        np.testing.assert_allclose(np.asarray(sparse.r), np.asarray(dense.r),
                                   rtol=1e-5, atol=1e-5)

    def test_padded_degree_scan_equivalence(self):
        """A runtime padded past the true max in-degree changes nothing."""
        from repro.core.byzantine import _scan_core, _sparse_gossip
        import functools

        topo, model = _byz_setup()
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=7,
                              attack=attacks.large_value())
        base = run_byzantine_learning(model, cfg, T=30, seed=0)
        rt, extra_reps, n_reps, _ = make_byzantine_runtime(
            model, cfg, deg_max=11
        )
        padded = _scan_core(
            jax.random.PRNGKey(0), rt,
            gossip=functools.partial(_sparse_gossip, attack=cfg.attack,
                                     mode="pairwise", backend="xla"),
            log_tables=model.log_tables().astype(jnp.float32),
            truth_probs=model.tables[:, model.truth, :].astype(jnp.float32),
            T=30, mode="pairwise", attack=cfg.attack, store="trajectory",
            static_F=cfg.F, extra_reps=extra_reps, n_reps=n_reps,
        )
        np.testing.assert_allclose(np.asarray(padded.r), np.asarray(base.r),
                                   rtol=1e-5, atol=1e-5)

    def test_dense_fallback_attack_without_nbr_messages(self):
        """A custom attack lacking the sparse interface still runs on the
        sparse core (via the dense-gather compatibility path) and matches
        the dense oracle."""
        base = attacks.extreme_pull()
        legacy = attacks.Attack("legacy", base.messages, base.ps_reply)
        topo, model = _byz_setup()
        cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=5,
                              attack=legacy)
        dense = run_byzantine_learning(model, cfg, T=30, seed=0, core="dense")
        sparse = run_byzantine_learning(model, cfg, T=30, seed=0,
                                        core="sparse")
        np.testing.assert_allclose(np.asarray(sparse.r), np.asarray(dense.r),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.slow
    def test_equivalence_N4096(self):
        """Scale check: xla and pallas sparse paths agree at N=4096."""
        topo = make_hierarchy([8] * 512, topology="complete", seed=0)
        model = make_confused_model(N=4096, m=3, truth=0, confusion=0.0,
                                    seed=1)
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=4,
                              attack=attacks.large_value())
        x = run_byzantine_learning(model, cfg, T=3, seed=0, backend="xla")
        p = run_byzantine_learning(model, cfg, T=3, seed=0, backend="pallas")
        np.testing.assert_allclose(np.asarray(p.r), np.asarray(x.r),
                                   rtol=1e-5, atol=1e-5)


# The jaxpr walker these tests introduced now lives in repro.statics.walk
# (PR 6); imported under the historical names so the assertions below stay
# bit-for-bit what they were when the helpers were local.
from repro.statics.walk import collect_avals as _collect_avals  # noqa: E402
from repro.statics.walk import subjaxprs as _subjaxprs  # noqa: E402,F401


class TestNoDenseIntermediate:
    """Acceptance: the sparse path's jaxpr holds no (N, N, ...) value."""

    def _shapes(self, core):
        topo = make_hierarchy([8] * 8, topology="complete", seed=0)  # N=64
        model = make_confused_model(N=64, m=3, truth=0, confusion=0.0, seed=1)
        cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=4,
                              attack=attacks.large_value())
        run = make_byzantine_scan(model, cfg, T=5, core=core,
                                  backend="xla", store="decisions")
        jaxpr = jax.make_jaxpr(run)(jax.random.PRNGKey(0)).jaxpr
        return _collect_avals(jaxpr, []), 64

    def test_sparse_has_no_NN_value(self):
        shapes, n = self._shapes("sparse")
        assert shapes, "jaxpr walker found no values"
        dense_like = [s for s in shapes
                      if len(s) >= 2 and s[0] == n and s[1] == n]
        assert not dense_like, f"(N, N, ...) intermediates: {dense_like}"
        m = 3
        assert max(int(np.prod(s)) for s in shapes) < n * n * m * m

    def test_detector_flags_dense_core(self):
        """Sanity: the same walker does find the (N, N, m, m) broadcast in
        the dense oracle, so the sparse assertion has teeth."""
        shapes, n = self._shapes("dense")
        assert any(len(s) >= 2 and s[0] == n and s[1] == n for s in shapes)


class TestPRNGStreams:
    def test_streams_disjoint_over_horizon(self):
        """Regression for the seed's t / 2t+1 / 2t+2 scheme, where the
        signal key at t=3 equaled the gossip key at t=1: the three fold-in
        domains must never intersect over any horizon."""
        T = 20000
        t = np.arange(T, dtype=np.uint64)
        folds = {
            s: set(np.asarray(stream_fold(t, s)).tolist())
            for s in (STREAM_SIGNAL, STREAM_GOSSIP, STREAM_FUSION)
        }
        for a in folds:
            for b in folds:
                if a != b:
                    assert not (folds[a] & folds[b])
        assert N_STREAMS == 3
        # injectivity over (t, stream): total count is preserved
        assert len(set().union(*folds.values())) == 3 * T

    def test_seed_scheme_would_have_collided(self):
        """The bug being regressed: fold-ins t, 2t+1, 2t+2 alias."""
        t = np.arange(100)
        assert set(t) & set(2 * t + 1)        # signal hits gossip keys
        assert set(t) & set(2 * t + 2)        # signal hits fusion keys


class TestStoreOptions:
    def test_store_shapes_and_consistency(self):
        from repro.core.sweeps import run_byzantine_sweep

        topo, model = _byz_setup(M_nets=3, n=4)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                              attack=attacks.large_value())
        traj = run_byzantine_sweep(model, cfg, T=20, seeds=[0, 1])
        dec = run_byzantine_sweep(model, cfg, T=20, seeds=[0, 1],
                                  store="decisions")
        fin = run_byzantine_sweep(model, cfg, T=20, seeds=[0, 1],
                                  store="final")
        rt, rd, rf = (traj["large_value"], dec["large_value"],
                      fin["large_value"])
        N = topo.N
        assert rt.r.shape == (2, 20, N, 3, 3)
        assert rd.r.shape == (2, N, 3, 3) and rd.decisions.shape == (2, 20, N)
        assert rf.r.shape == (2, N, 3, 3) and rf.decisions.shape == (2, N)
        np.testing.assert_array_equal(np.asarray(rd.decisions),
                                      np.asarray(rt.decisions))
        np.testing.assert_allclose(np.asarray(rf.r),
                                   np.asarray(rt.r[:, -1]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(rf.decisions),
                                      np.asarray(rt.decisions[:, -1]))


def _grid_fixture():
    model = make_confused_model(N=15, m=3, truth=0, confusion=0.0, seed=0)
    atk = attacks.large_value()
    topos = [make_hierarchy([5, 5, 5], topology="ring+", extra_edge_prob=0.9,
                            seed=s) for s in range(3)]
    cfgs = []
    for topo in topos:
        cfgs.append(ByzantineConfig(topo=topo, F=0, byz=(), gamma_period=4,
                                    attack=atk))
        cfgs.append(ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                                    attack=atk))
    return model, cfgs, atk


class TestByzantineGrid:
    def test_topology_F_seed_grid_single_trace(self):
        """Acceptance: 3 topologies x 2 F x 8 seeds as ONE compiled program
        — one jit cache entry, no retrace on a second seed batch."""
        from repro.core.sweeps import cache_registry, run_byzantine_grid

        model, cfgs, atk = _grid_fixture()
        reg = cache_registry()["byz.grid"]
        reg.clear()
        res = run_byzantine_grid(model, cfgs, T=30, seeds=list(range(8)))
        assert res.K == 48
        assert res.decisions.shape == (48, 30, 15)
        # heterogeneous F (0 and 1) forces the sort lowering on every
        # platform, so the effective backend in the cache key is "xla"
        # and the second seed batch reuses the one compiled entry
        assert reg.cache_info().currsize == 1
        res2 = run_byzantine_grid(model, cfgs, T=30, seeds=list(range(8, 16)))
        assert reg.cache_info().currsize == 1
        assert res2.K == 48

    def test_grid_matches_single_runs(self):
        """Heterogeneous F on the vmap axis (traced, sort lowering) must
        reproduce each config's static-F single run exactly."""
        from repro.core.sweeps import run_byzantine_grid

        model, cfgs, _ = _grid_fixture()
        res = run_byzantine_grid(model, cfgs, T=25, seeds=[0, 3])
        for k in range(0, res.K, 3):
            ci, sd = int(res.cfg[k]), int(res.seed[k])
            single = run_byzantine_learning(
                model, cfgs[ci], T=25, seed=sd, store="decisions",
                backend="xla",
            )
            np.testing.assert_array_equal(np.asarray(res.decisions[k]),
                                          np.asarray(single.decisions))
            np.testing.assert_allclose(np.asarray(res.r[k]),
                                       np.asarray(single.r),
                                       rtol=1e-5, atol=1e-5)

    def test_incompatible_configs_rejected(self):
        from repro.core.sweeps import run_byzantine_grid

        model, cfgs, atk = _grid_fixture()
        # M = 4 < 2F+1 = 5 with a majority-Byzantine network outside C
        # needs the static extra-reps branch, which cannot ride a vmap axis
        small = make_hierarchy([7, 7, 7, 3], topology="complete", seed=1)
        model24 = make_confused_model(N=24, m=3, truth=0, confusion=0.0,
                                      seed=3)
        bad = ByzantineConfig(topo=small, F=2, byz=(21, 22), gamma_period=4,
                              attack=atk)
        with pytest.raises(ValueError, match="2F\\+1"):
            run_byzantine_grid(model24, [bad], T=10, seeds=[0])
        # node-count mismatch
        with pytest.raises(ValueError, match="share"):
            run_byzantine_grid(
                model, [cfgs[0],
                        ByzantineConfig(topo=make_hierarchy([5, 5, 5, 5],
                                                            "complete"),
                                        F=0, byz=(), gamma_period=4,
                                        attack=atk)],
                T=10, seeds=[0])

    def test_sharded_grid_equals_single_device(self):
        """K=12 grid over a 4-device data mesh (subprocess, fake CPU
        devices): identical decisions to the single-device vmap."""
        prog = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json
            import numpy as np
            import jax
            from repro.core import attacks
            from repro.core.byzantine import ByzantineConfig
            from repro.core.graphs import make_hierarchy
            from repro.core.signals import make_confused_model
            from repro.core.sweeps import run_byzantine_grid
            from repro.launch import compat

            model = make_confused_model(N=15, m=3, truth=0, confusion=0.0,
                                        seed=0)
            atk = attacks.large_value()
            topos = [make_hierarchy([5, 5, 5], topology="ring+",
                                    extra_edge_prob=0.9, seed=s)
                     for s in range(3)]
            cfgs = []
            for topo in topos:
                cfgs.append(ByzantineConfig(topo=topo, F=0, byz=(),
                                            gamma_period=4, attack=atk))
                cfgs.append(ByzantineConfig(topo=topo, F=1, byz=(1,),
                                            gamma_period=4, attack=atk))
            r1 = run_byzantine_grid(model, cfgs, T=20, seeds=[0, 1])
            mesh = compat.make_mesh((4,), ("data",))
            r2 = run_byzantine_grid(model, cfgs, T=20, seeds=[0, 1],
                                    mesh=mesh)
            same = bool((np.asarray(r1.decisions)
                         == np.asarray(r2.decisions)).all())
            err = float(np.abs(np.asarray(r1.r) - np.asarray(r2.r)).max())
            print(json.dumps({"K": int(r2.K), "same": same, "err": err,
                              "devices": jax.device_count()}))
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        for _ in range(2):   # CPU collective rendezvous can flake; retry once
            out = subprocess.run([sys.executable, "-c", prog],
                                 capture_output=True, text=True,
                                 timeout=420, env=env, cwd=REPO)
            if out.returncode == 0 or "rendezvous" not in out.stderr.lower():
                break
        assert out.returncode == 0, out.stderr[-3000:]
        res = json.loads(
            [l for l in out.stdout.splitlines() if l.startswith("{")][-1])
        assert res["devices"] == 4
        assert res["K"] == 12            # pad rows sliced off
        assert res["same"] and res["err"] == 0.0


class TestTrimmedMeanPytreeDtype:
    """The gradient-aggregator trim (the Byzantine filter applied
    coordinate-wise over a worker axis) computes in fp32 internally but must
    hand every leaf back in its input dtype."""

    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    def test_bf16_roundtrip_and_mixed_dtypes(self, backend):
        from repro.kernels.trimmed_mean.ops import trimmed_mean_pytree
        from repro.kernels.trimmed_mean.ref import trimmed_mean_ref

        rng = np.random.default_rng(0)
        tree = {
            "bf16": jnp.asarray(rng.normal(size=(8, 4, 3)),
                                dtype=jnp.bfloat16),
            "f32": jnp.asarray(rng.normal(size=(8, 9)).astype(np.float32)),
        }
        out = trimmed_mean_pytree(tree, 2, backend=backend)
        assert out["bf16"].dtype == jnp.bfloat16
        assert out["bf16"].shape == (4, 3)
        assert out["f32"].dtype == jnp.float32
        want = trimmed_mean_ref(
            tree["bf16"].reshape(8, -1).astype(jnp.float32), 2
        ).reshape(4, 3)
        np.testing.assert_allclose(
            np.asarray(out["bf16"], np.float32),
            np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2,
        )


class TestLRUCaches:
    def test_lru_eviction_and_recency(self):
        from repro.core.sweeps import _LRUCache

        c = _LRUCache(maxsize=3)
        for k in "abc":
            c[k] = k.upper()
        assert c["a"] == "A"             # refresh 'a'
        c["d"] = "D"                     # evicts 'b' (stalest), not 'a'
        assert set(c) == {"a", "c", "d"}
        assert c.get("b") is None
        c["e"] = "E"; c["f"] = "F"
        assert len(c) == 3               # bounded forever

    def test_compiled_caches_are_bounded(self):
        from repro.core.sweeps import cache_registry

        reg = cache_registry()
        assert isinstance(reg["byz.compiled"].cache_info().maxsize, int)
        assert 0 < reg["byz.compiled"].cache_info().maxsize <= 64
        assert 0 < reg["byz.grid"].cache_info().maxsize <= 64

    def test_sweep_cache_evicts_under_churn(self):
        """Churning more fingerprints than maxsize through the sweep cache
        keeps it bounded (the satellite's 'long parameter study')."""
        from repro.core.sweeps import cache_registry, run_byzantine_sweep

        reg = cache_registry()["byz.compiled"]
        topo, model = _byz_setup(M_nets=3, n=4)
        cfg = ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                              attack=attacks.large_value())
        bound = reg.cache_info().maxsize
        for T in range(5, 5 + bound + 3):
            run_byzantine_sweep(model, cfg, T=T, seeds=[0])
        assert reg.cache_info().currsize <= bound
