"""Precision-policy statics — two compile-time proofs for the bandwidth
work:

* :func:`step_donation_findings` — lower (never execute) the shipped
  donating step entry (:func:`repro.core.pushsum.sparse_pushsum_step_jit`)
  and assert the compiled module actually aliases every donated state
  buffer (``tf.aliasing_output`` on the StableHLO arguments). Donation
  that silently degrades to a copy (shape/dtype mismatch between the
  donated input and any output, or an accidental second use of the donated
  value) is invisible at the Python layer — the program still computes the
  right numbers, it just doubles the state's HBM footprint. This check
  turns that regression into a lint failure.
* :func:`find_fp32_scan_state` — the reduced-precision carry contract:
  under a bf16 storage policy, no scan may carry persistent per-edge /
  per-node float32 state. A single fp32 ``(E, d)`` relay latch or
  ``(N, d)`` value column smuggled through the carry silently forfeits the
  storage-bandwidth win the policy exists for (the scan re-reads and
  re-writes it every round at full width). Accumulators are *supposed* to
  be fp32 — but they live inside the scan body as transients, not in the
  carry, which is exactly the structural line this check draws.
"""
from __future__ import annotations

import numpy as np

from .dense import Finding
from .walk import iter_eqns, symbolize

__all__ = [
    "step_donation_findings",
    "find_fp32_scan_state",
    "count_aliased_outputs",
]

# One donated SparsePushSumState = 6 array leaves (z, m, sigma, sigma_m,
# rho, rho_m); each must surface as an input->output alias in the lowered
# module.
_STATE_LEAVES = 6


def count_aliased_outputs(lowered_text: str) -> int:
    """Number of argument buffers the compiled module aliases to outputs
    (the StableHLO rendering of XLA's ``input_output_alias``)."""
    return lowered_text.count("tf.aliasing_output")


def step_donation_findings(
    backend: str = "xla",
    policy=None,
    *,
    dst_sorted: bool = False,
    where: str | None = None,
) -> list[Finding]:
    """Prove the donating step entry aliases all six state leaves.

    Lowers the exact cached callable ``sparse_pushsum_step_jit`` dispatches
    to, on a tiny (N=7, E=11, d=2) fixture — abstract lowering only,
    nothing executes and nothing is donated for real.
    """
    import jax.numpy as jnp

    from repro.core.precision import resolve_policy
    from repro.core.pushsum import _get_step_jit, init_sparse_state

    pol = None if policy is None else resolve_policy(policy)
    tag = "fp32" if pol is None else pol.tag()
    where = where or f"pushsum.step-jit[backend={backend}, policy={tag}]"

    n, e, d = 7, 11, 2
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    state = init_sparse_state(w, e, policy=pol)
    mask = jnp.ones((e,), bool)
    src = jnp.asarray(rng.integers(0, n, size=e).astype(np.int32))
    dst = jnp.sort(jnp.asarray(rng.integers(0, n, size=e).astype(np.int32)))
    valid = jnp.ones((e,), bool)

    fn = _get_step_jit(backend, dst_sorted, pol)
    try:
        text = fn.lower(state, mask, src, dst, valid, None).as_text()
    except Exception as exc:  # lowering itself must not break
        return [Finding(
            check="buffer-donation", where=where,
            message=f"lowering the donating step failed: "
                    f"{type(exc).__name__}: {exc}",
        )]
    n_alias = count_aliased_outputs(text)
    if n_alias < _STATE_LEAVES:
        return [Finding(
            check="buffer-donation", where=where,
            message=(
                f"compiled step aliases only {n_alias} of the "
                f"{_STATE_LEAVES} donated state buffers — donation is "
                "silently copying (aval mismatch between the donated "
                "input state and the returned state?)"
            ),
        )]
    return []


def _scan_carry_avals(eqn):
    """Carry avals of one ``scan`` equation: body invars between the
    hoisted consts and the per-iteration xs slices."""
    body = eqn.params["jaxpr"]
    nc = int(eqn.params["num_consts"])
    nk = int(eqn.params["num_carry"])
    return [v.aval for v in body.jaxpr.invars[nc:nc + nk]]


def find_fp32_scan_state(
    closed,
    dims: dict[str, int],
    *,
    axes: tuple[str, ...] = ("N", "E"),
    where: str = "",
) -> list[Finding]:
    """Report scan carries holding wide-float per-edge/per-node state.

    ``dims`` is the fixture's symbol table (as everywhere in statics);
    ``axes`` names the "population" dims — a floating carry of itemsize
    >= 4 with ANY dimension of one of those sizes is persistent engine
    state stored at full width, which a reduced-precision policy forbids
    (any-dim, not leading-dim: vmapped sweeps prepend the scenario batch
    axis to every carry). Integer/bool/key carries (iteration counters,
    PRNG keys, decision flags) and scalar floats pass; so do fp32
    *transients* inside the body — only the carry, the state that survives
    rounds, is held to the storage dtype.
    """
    pop_sizes = {int(dims[a]) for a in axes if a in dims}
    out: list[Finding] = []
    for path, eqn in iter_eqns(closed):
        if eqn.primitive.name != "scan":
            continue
        for aval in _scan_carry_avals(eqn):
            dtype = getattr(aval, "dtype", None)
            shape = tuple(getattr(aval, "shape", ()))
            if dtype is None or not shape:
                continue
            if not np.issubdtype(np.dtype(dtype), np.floating):
                continue
            if np.dtype(dtype).itemsize < 4:
                continue
            if not any(int(s) in pop_sizes for s in shape):
                continue
            sym = symbolize(shape, dims)
            loc = "/".join(path + ("scan",)) or "scan"
            out.append(Finding(
                check="fp32-carry", where=where or loc,
                message=(
                    f"scan at {loc} carries persistent "
                    f"{np.dtype(dtype).name} state of shape {sym} under a "
                    "reduced-precision storage policy — the carry must be "
                    "in the policy's storage dtype (fp32 belongs to "
                    "in-body accumulators only)"
                ),
            ))
    return out
