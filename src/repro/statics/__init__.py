"""repro.statics — jaxpr static analysis for the fused engines.

Everything here runs at TRACE time: no engine executes, no accelerator is
needed, yet the checks prove properties that runtime tests can only sample
— that no (N, N) intermediate exists in a sparse path for *any* input,
that two PRNG fold-in domains are disjoint for *every* iteration pair over
the horizon, that a repeated sweep call compiles *zero* new executables,
and that a benchmarked configuration fits the hardware budget by
construction.

Layout (each module's docstring carries the full story):

* :mod:`~repro.statics.walk`      — the jaxpr IR walker everything shares
* :mod:`~repro.statics.contracts` — ``@statics.contract`` declarations
* :mod:`~repro.statics.dense`     — dense-intermediate + subnormal linter
* :mod:`~repro.statics.streams`   — PRNG stream-domain disjointness proofs
* :mod:`~repro.statics.retrace`   — compiled-cache retrace sentinel
* :mod:`~repro.statics.memory`    — static memory/FLOP budgeter
* :mod:`~repro.statics.cli`       — ``python -m repro.statics lint``

The engines under :mod:`repro.core` declare their invariants at the
definition site via :func:`contract`; the CLI (and ``tests/test_statics.py``)
replay every declaration against freshly traced programs.
"""
from .contracts import EngineContract, REGISTRY, all_contracts, contract, get
from .dense import (
    Finding,
    assert_nonempty,
    find_forbidden,
    find_subnormal_consts,
)
from .memory import jaxpr_footprint, step_floor, validate_bench
from .retrace import CacheWatch, check_idempotent, register_cache, snapshot
from .streams import AffineMap, affine_disjoint, check_streams, fit_affine
from .walk import collect_avals, collect_values, subjaxprs, symbolize, trace

__all__ = [
    "AffineMap",
    "CacheWatch",
    "EngineContract",
    "Finding",
    "REGISTRY",
    "affine_disjoint",
    "all_contracts",
    "assert_nonempty",
    "check_idempotent",
    "check_streams",
    "collect_avals",
    "collect_values",
    "contract",
    "find_forbidden",
    "find_subnormal_consts",
    "fit_affine",
    "get",
    "jaxpr_footprint",
    "register_cache",
    "snapshot",
    "step_floor",
    "subjaxprs",
    "symbolize",
    "trace",
    "validate_bench",
]
