"""PRNG stream-domain analyzer — static disjointness proofs for fold-in maps.

Every engine derives its per-iteration PRNG streams by folding a small
integer into one base key: ``fold_in(key, f(t))``. Independence of the
streams rests entirely on the fold-in maps having *disjoint images* over
the run horizon — three separate aliasing bugs shipped in PRs 3-5 because
ad-hoc schemes (``t`` / ``2t+1`` / ``2t+2``; ``t`` twice; plain ``t``
against ``2t+s``) silently intersected.

Every map in the codebase is affine, ``f(t) = a*t + b`` (including the HPS
``~t`` domain: ``~t = -t - 1``), so disjointness over a horizon is an
exactly decidable integer-lattice problem, not a property test:

    a1*t1 + b1 = a2*t2 + b2,  t1 in [0, T1),  t2 in [0, T2)

is a linear Diophantine equation; Bezout gives the full solution family
and intersecting the box constraints decides it — producing the colliding
``(t1, t2)`` WITNESS when the verdict is "not disjoint".

Soundness domain: ``fold_in`` consumes the value mod 2^32, and the signed
range (-2^31, 2^31) maps injectively into uint32 space, so integer
disjointness implies fold-in disjointness as long as every image stays in
that range over the horizon — :func:`affine_disjoint` checks this bound
and refuses (loudly) rather than answer outside it.

:func:`fit_affine` recovers ``(a, b)`` from the engine's actual fold
callable by probing it at several ``t`` and verifying affinity, so the
declared contract can never drift from the shipped code.

``LEGACY_BUGGY_STREAMS`` keeps the three historical (fixed) schemes
importable behind this test-only name, so the regression tests can assert
the analyzer catches each one with a correct witness.
"""
from __future__ import annotations

import dataclasses
from math import gcd
from typing import Callable, Sequence

import numpy as np

from .dense import Finding

__all__ = [
    "AffineMap",
    "fit_affine",
    "affine_disjoint",
    "check_streams",
    "brute_force_disjoint",
    "LEGACY_BUGGY_STREAMS",
]

# fold_in consumes values mod 2^32; (-2^31, 2^31) signed maps injectively
# into that space, so images confined to it keep the integer proof sound.
_FOLD_BOUND = 1 << 31


@dataclasses.dataclass(frozen=True)
class AffineMap:
    """``t -> a*t + b`` over the integer iteration index."""

    name: str
    a: int
    b: int

    def __call__(self, t: int) -> int:
        return self.a * t + self.b

    def image_bound(self, T: int) -> int:
        """max |value| over t in [0, T)."""
        return max(abs(self.b), abs(self.a * (T - 1) + self.b))

    def __str__(self) -> str:
        return f"{self.name}: t -> {self.a}*t + {self.b}"


def fit_affine(
    fold: Callable[[int], int],
    name: str,
    probes: Sequence[int] = (0, 1, 2, 7, 129, 4099),
) -> AffineMap:
    """Recover the affine coefficients of an engine's fold callable.

    Probes at several ``t``; a map that is not affine over the probes (the
    analyzer's soundness assumption) is rejected rather than approximated.
    Numpy scalar returns (``~np.int32(t)``) are normalized to Python ints.
    """
    ys = [int(np.asarray(fold(int(t)))) for t in probes]
    a = ys[1] - ys[0]
    b = ys[0]
    for t, y in zip(probes, ys):
        if a * int(t) + b != y:
            raise ValueError(
                f"stream {name!r}: fold map is not affine over probes "
                f"{tuple(probes)} (got {ys}); the lattice analyzer cannot "
                "certify it — extend repro.statics.streams first"
            )
    return AffineMap(name=name, a=a, b=b)


def _k_range(t0: int, step: int, hi: int) -> tuple[int, int] | None:
    """Integer k with 0 <= t0 + step*k < hi, as an inclusive (lo, hi) range.

    ``None`` means empty; ``step == 0`` collapses to all-k or none.
    """
    if step == 0:
        return (None if not (0 <= t0 < hi) else (-(1 << 62), 1 << 62))
    # 0 <= t0 + step*k <= hi - 1, solved with exact ceil/floor division
    # (Python's // floors toward -inf, so ceil(p/q) = -((-p) // q) for q > 0)
    if step > 0:
        lo_k = -(t0 // step)                     # ceil(-t0 / step)
        hi_k = (hi - 1 - t0) // step             # floor((hi-1-t0)/step)
    else:
        s = -step
        lo_k = -((hi - 1 - t0) // s)             # ceil((t0-(hi-1))/s)
        hi_k = t0 // s                           # floor(t0 / s)
    if lo_k > hi_k:
        return None
    return (lo_k, hi_k)


def affine_disjoint(
    m1: AffineMap,
    m2: AffineMap,
    T: int,
    T2: int | None = None,
) -> tuple[bool, tuple[int, int, int] | None]:
    """Decide image disjointness of two affine maps over bounded horizons.

    Returns ``(True, None)`` if ``{m1(t1)} ∩ {m2(t2)} = ∅`` for
    ``t1 in [0, T)``, ``t2 in [0, T2 or T)``; else ``(False, witness)``
    with ``witness = (t1, t2, value)`` the smallest-``t1`` collision.
    """
    T2 = T if T2 is None else T2
    if T <= 0 or T2 <= 0:
        return True, None
    for m in (m1, m2):
        if m.image_bound(max(T, T2)) >= _FOLD_BOUND:
            raise ValueError(
                f"stream {m.name!r}: image exceeds the signed fold-in "
                f"range over horizon {max(T, T2)}; the wraparound-free "
                "proof does not apply — shrink the horizon or the map"
            )
    a1, b1, a2, b2 = m1.a, m1.b, m2.a, m2.b
    c = b2 - b1
    # a1*t1 - a2*t2 = c
    if a1 == 0 and a2 == 0:
        if c != 0:
            return True, None
        return False, (0, 0, b1)
    if a1 == 0:
        # t2 = (b1 - b2) / a2
        num = b1 - b2
        if num % a2:
            return True, None
        t2 = num // a2
        if 0 <= t2 < T2:
            return False, (0, t2, b1)
        return True, None
    if a2 == 0:
        num = b2 - b1
        if num % a1:
            return True, None
        t1 = num // a1
        if 0 <= t1 < T:
            return False, (t1, 0, b2)
        return True, None

    # Normalize to A*t1 + B*t2 = c with positive-gcd Bezout coefficients
    A, B = a1, -a2
    g = gcd(A, B)
    if c % g:
        return True, None
    x0, y0 = _bezout(abs(A), abs(B))             # x0|A| + y0|B| = g
    x = x0 if A >= 0 else -x0
    y = y0 if B >= 0 else -y0
    scale = c // g
    t1p, t2p = x * scale, y * scale
    # solution family: (t1p + (B//g)*k, t2p - (A//g)*k)
    s1, s2 = B // g, -(A // g)
    r1 = _k_range(t1p, s1, T)
    r2 = _k_range(t2p, s2, T2)
    if r1 is None or r2 is None:
        return True, None
    lo = max(r1[0], r2[0])
    hi = min(r1[1], r2[1])
    if lo > hi:
        return True, None
    # choose the k minimizing t1 for a stable, smallest witness
    k = lo if s1 > 0 else hi
    t1 = t1p + s1 * k
    t2 = t2p + s2 * k
    return False, (t1, t2, a1 * t1 + b1)


def _bezout(a: int, b: int) -> tuple[int, int]:
    """(x, y) with x*a + y*b == gcd(a, b)."""
    old_r, r = a, b
    old_x, x = 1, 0
    old_y, y = 0, 1
    while r:
        q = old_r // r
        old_r, r = r, old_r - q * r
        old_x, x = x, old_x - q * x
        old_y, y = y, old_y - q * y
    return old_x, old_y


def brute_force_disjoint(
    m1: AffineMap, m2: AffineMap, T: int, T2: int | None = None
) -> bool:
    """Enumeration oracle for small boxes (property tests only)."""
    T2 = T if T2 is None else T2
    img1 = {m1(t) for t in range(T)}
    return all(m2(t) not in img1 for t in range(T2))


def check_streams(
    maps: Sequence[AffineMap],
    T: int,
    *,
    where: str = "<streams>",
) -> list[Finding]:
    """Pairwise disjointness over the horizon; one finding per collision,
    carrying the exact ``(t, stream)`` witness."""
    out: list[Finding] = []
    for i, m1 in enumerate(maps):
        for m2 in maps[i + 1:]:
            disjoint, wit = affine_disjoint(m1, m2, T)
            if not disjoint:
                t1, t2, val = wit
                out.append(Finding(
                    check="prng-stream-collision",
                    where=where,
                    message=(
                        f"streams collide: {m1.name}@t={t1} == "
                        f"{m2.name}@t={t2} (both fold {val}); maps "
                        f"[{m1}] vs [{m2}] over horizon T={T}"
                    ),
                ))
    return out


# The three shipped-and-fixed aliasing schemes, kept importable for the
# would-have-caught regression tests ONLY (tests/test_statics.py). Each is
# a (engine, ((stream, a, b), ...)) record of the buggy fold-in maps:
#
#   byzantine (pre-PR-3): signal t, gossip 2t+1, fusion 2t+2
#                         -> signal@3 == gossip@1 == 3
#   social    (pre-PR-4): link t, signal t (both plain)
#                         -> link@0 == signal@0 == 0
#   hps       (pre-PR-5): link t, aliasing social's link 2t+0
#                         -> hps@0 == social-link@0 == 0
LEGACY_BUGGY_STREAMS: dict[str, tuple[AffineMap, ...]] = {
    "byzantine": (
        AffineMap("signal", 1, 0),
        AffineMap("gossip", 2, 1),
        AffineMap("fusion", 2, 2),
    ),
    "social": (
        AffineMap("link", 1, 0),
        AffineMap("signal", 1, 0),
    ),
    "hps": (
        AffineMap("hps-link", 1, 0),
    ),
}
