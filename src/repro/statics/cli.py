"""``python -m repro.statics`` — run the static checks from the command line.

Subcommands:

* ``lint``   — trace every public entry point across backend x store
  combos and run the full registry of checks: the ``run_*`` signature
  linter (no entry point may re-grow a loose execution kwarg covered by
  ``ExecutionPlan`` — see :mod:`repro.statics.signatures`),
  dense-intermediate linter,
  subnormal-constant scan, PRNG stream-domain disjointness proofs (within
  each engine and across engines that may share one experiment seed), the
  per-trace PRNG-site lower bound, the retrace sentinel (tiny XLA runs,
  executed twice — the second call must compile nothing), the static
  memory-budget validation of the committed BENCH artifacts, and the
  precision-policy proofs (the donating step entry must alias every state
  buffer in its lowered module; no engine scan may carry persistent fp32
  per-edge/per-node state under the bf16 policy). Exit 0 iff no findings.
* ``budget`` — print the analytic per-engine step-byte models, their
  TPU-v5e roofline floors, the per-policy budgets (fp32 vs bf16 storage),
  and the traced-footprint accounting.
* ``list``   — show the registered contracts and compiled caches.

A passing lint verdict is cached in ``--cache-dir`` keyed on the sha256 of
every ``src/repro/**/*.py`` file, the BENCH artifacts, and the jax
version, so repeated CI runs on unchanged sources answer from the cache
(the CI lane additionally persists that directory across workflow runs).

The ``--inject-*`` flags are TEST hooks: they swap a known-bad historical
configuration (the three shipped PRNG aliasing schemes, or a synthetic
dense intermediate) into the checked set so ``tests/test_statics.py`` can
prove the lint would have caught each one. They are not for normal use.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
from pathlib import Path

import numpy as np

from . import (
    contracts,
    dense,
    memory,
    precision,
    retrace,
    signatures,
    streams,
    walk,
)
from .dense import Finding

_REPO_ROOT = Path(__file__).resolve().parents[3]

_TRACE_BACKENDS = ("xla", "pallas")


# ---------------------------------------------------------------------------
# Fixtures — tiny concrete programs per engine. Dim sizes are pairwise
# distinct WITHIN each fixture so repro.statics.walk.symbolize can never
# confuse axes (the discipline the historical per-test walkers used).
# ---------------------------------------------------------------------------

def _pushsum_fixture():
    import jax

    from repro.core.graphs import edge_list, random_strongly_connected
    from repro.core.plan import ExecutionPlan
    from repro.core.pushsum import run_pushsum_sparse

    rng = np.random.default_rng(0)
    adj = random_strongly_connected(11, 0.3, rng)
    el = edge_list(adj)
    w = rng.normal(size=(11, 2)).astype(np.float32)
    dims = {"N": 11, "d": 2, "T": 7, "E": int(el.E)}

    def make(backend, store):
        return walk.trace(
            lambda w_, key_: run_pushsum_sparse(
                w_, el.src, el.dst, T=7, drop_prob=0.1, B=2,
                key=key_, plan=ExecutionPlan(backend=backend),
            ),
            w, jax.random.PRNGKey(0),
        )

    return dims, (None,), make


def _social_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPSConfig
    from repro.core.plan import ExecutionPlan
    from repro.core.signals import make_confused_model
    from repro.core.social import (
        SOCIAL_STORES,
        make_social_runtime,
        run_social_runtime,
    )

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
    rt = make_social_runtime(cfg)
    dims = {"N": 18, "m": 3, "T": 37, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        return walk.trace(
            lambda rt_: run_social_runtime(
                model, rt_, M=len(topo.sizes), T=37,
                plan=ExecutionPlan(backend=backend, store=store),
            ),
            rt,
        )

    return dims, SOCIAL_STORES, make


def _hps_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPS_STORES, HPSConfig, make_hps_runtime, run_hps
    from repro.core.plan import ExecutionPlan

    topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
    rt = make_hps_runtime(cfg)
    w = np.random.default_rng(3).normal(size=(15, 2)).astype(np.float32)
    dims = {"N": 15, "d": 2, "T": 31, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        return walk.trace(
            lambda w_: run_hps(w_, cfg, T=31, seed=0,
                               plan=ExecutionPlan(backend=backend,
                                                  store=store)),
            w,
        )

    return dims, HPS_STORES, make


def _byz_fixture():
    import jax

    from repro.core import attacks
    from repro.core.byzantine import (
        STORES,
        ByzantineConfig,
        make_byzantine_scan,
    )
    from repro.core.graphs import make_hierarchy
    from repro.core.signals import make_confused_model

    topo = make_hierarchy([8] * 8, topology="complete", seed=0)   # N = 64
    model = make_confused_model(N=64, m=3, truth=0, confusion=0.0, seed=1)
    cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=4,
                          attack=attacks.sign_flip())
    dims = {"N": 64, "m": 3, "T": 5}

    def make(backend, store):
        run = make_byzantine_scan(model, cfg, T=5, core="sparse",
                                  backend=backend, store=store)
        return walk.trace(run, jax.random.PRNGKey(0))

    return dims, STORES, make


def _chaos_model():
    """One non-degenerate FaultModel shared by all four faulted fixtures:
    every fault mechanism (burst chain, churn, PS crash) is live so every
    fault stream is actually drawn in the traced program."""
    from repro.core.faults import make_fault_model

    return make_fault_model(p_gb=0.2, p_bg=0.5, drop_bad=0.9,
                            leave_prob=0.05, join_prob=0.5,
                            ps_crash_prob=0.3)


def _pushsum_faults_fixture():
    import jax

    from repro.core.graphs import edge_list, random_strongly_connected
    from repro.core.plan import ExecutionPlan
    from repro.core.pushsum import run_pushsum_sparse

    rng = np.random.default_rng(0)
    adj = random_strongly_connected(11, 0.3, rng)
    el = edge_list(adj)
    w = rng.normal(size=(11, 2)).astype(np.float32)
    fm = _chaos_model()
    dims = {"N": 11, "d": 2, "T": 7, "E": int(el.E)}

    def make(backend, store):
        # record_every=T: a single ratio frame, so the (T, *) ban can hold
        # over the whole faulted trace (fault state itself is O(E)+O(N)).
        return walk.trace(
            lambda w_, key_: run_pushsum_sparse(
                w_, el.src, el.dst, T=7, drop_prob=0.1, B=2,
                key=key_, record_every=7,
                plan=ExecutionPlan(backend=backend, faults=fm),
            ),
            w, jax.random.PRNGKey(0),
        )

    return dims, (None,), make


def _social_faults_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPSConfig
    from repro.core.plan import ExecutionPlan
    from repro.core.signals import make_confused_model
    from repro.core.social import make_social_runtime, run_social_runtime

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
    rt = make_social_runtime(cfg)
    fm = _chaos_model()
    dims = {"N": 18, "m": 3, "T": 37, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        plan = ExecutionPlan(backend=backend, store=store, faults=fm)
        return walk.trace(
            lambda rt_: run_social_runtime(
                model, rt_, M=len(topo.sizes), T=37, plan=plan),
            rt,
        )

    # log_ratio is the in-scan-reduced store: the one where (T, *) is a
    # provable ban rather than the store's own output.
    return dims, ("log_ratio",), make


def _hps_faults_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPSConfig, make_hps_runtime, run_hps
    from repro.core.plan import ExecutionPlan

    topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
    rt = make_hps_runtime(cfg)
    w = np.random.default_rng(3).normal(size=(15, 2)).astype(np.float32)
    fm = _chaos_model()
    dims = {"N": 15, "d": 2, "T": 31, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        plan = ExecutionPlan(backend=backend, store=store, faults=fm)
        return walk.trace(
            lambda w_: run_hps(w_, cfg, T=31, seed=0, plan=plan),
            w,
        )

    return dims, ("gap",), make


def _byz_faults_fixture():
    import jax

    from repro.core import attacks
    from repro.core.byzantine import ByzantineConfig, make_byzantine_scan
    from repro.core.graphs import make_hierarchy
    from repro.core.signals import make_confused_model

    topo = make_hierarchy([8] * 8, topology="complete", seed=0)   # N = 64
    model = make_confused_model(N=64, m=3, truth=0, confusion=0.0, seed=1)
    cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=4,
                          attack=attacks.sign_flip())
    fm = _chaos_model()
    dims = {"N": 64, "m": 3, "T": 5}

    def make(backend, store):
        run = make_byzantine_scan(model, cfg, T=5, core="sparse",
                                  backend=backend, store=store, faults=fm)
        return walk.trace(run, jax.random.PRNGKey(0))

    return dims, ("final",), make


def _pushsum_sharded_fixture():
    from repro.core.graphs import (
        partition_edge_list,
        random_strongly_connected_edge_list,
    )
    from repro.core.sweeps import _sweep2d_emulated

    rng = np.random.default_rng(5)
    el = random_strongly_connected_edge_list(11, 0.25, rng, sort=False)
    sh = partition_edge_list(el, 2)
    w = rng.normal(size=(11, 3)).astype(np.float32)
    # (K=2, S, Es) scenario-gathered shards, exactly what the sweep feeds
    # the vmap(axis_name=) emulation — the single-device twin of the 2-D
    # mesh program (same traced collectives), so linting it lints both
    src_k = np.broadcast_to(sh.src[None], (2,) + sh.src.shape).copy()
    dst_k = np.broadcast_to(sh.dst[None], (2,) + sh.dst.shape).copy()
    val_k = np.broadcast_to(sh.valid[None], (2,) + sh.valid.shape).copy()
    drop_b = np.array([0.1, 0.3], np.float32)
    seed_b = np.array([0, 1], np.uint32)
    dims = {"N": 11, "d": 3, "T": 5, "S": sh.n_shards,
            "E": sh.e_pad, "Es": sh.e_shard}
    assert len(set(dims.values())) == len(dims), dims

    def make(backend, store):
        return walk.trace(
            lambda w_, s_, d_, v_, dp_, sd_: _sweep2d_emulated(
                w_, s_, d_, v_, dp_, sd_,
                T=5, B=2, backend=backend,
                graph_axis="shardlint", n_shards=sh.n_shards,
            ),
            w, src_k, dst_k, val_k, drop_b, seed_b,
        )

    return dims, (None,), make


def _async_model():
    """One non-degenerate AsyncModel shared by the three async fixtures:
    agents sleep (wake_prob < 1) and stale snapshots deliver
    (staleness > 0), so the wake stream is actually drawn and the
    O(E·d) buffer is actually carried in the traced program. A
    degenerate model would dispatch to the synchronous engine and
    trace no async machinery at all."""
    from repro.core.asyncrony import make_async_model

    return make_async_model(wake_prob=0.6, staleness=2)


def _pushsum_async_fixture():
    import jax

    from repro.core.graphs import edge_list, random_strongly_connected
    from repro.core.plan import ExecutionPlan
    from repro.core.pushsum import run_pushsum_sparse

    rng = np.random.default_rng(0)
    adj = random_strongly_connected(11, 0.3, rng)
    el = edge_list(adj)
    w = rng.normal(size=(11, 2)).astype(np.float32)
    plan_of = lambda b: ExecutionPlan(backend=b, async_=_async_model())
    dims = {"N": 11, "d": 2, "T": 7, "E": int(el.E)}

    def make(backend, store):
        # record_every=T: a single ratio frame, so the (T, *) ban holds
        # over the async trace (the buffer itself is O(E*d), not O(T)).
        return walk.trace(
            lambda w_, key_: run_pushsum_sparse(
                w_, el.src, el.dst, T=7, drop_prob=0.1, B=2,
                key=key_, record_every=7, plan=plan_of(backend),
            ),
            w, jax.random.PRNGKey(0),
        )

    return dims, (None,), make


def _social_async_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPSConfig
    from repro.core.plan import ExecutionPlan
    from repro.core.signals import make_confused_model
    from repro.core.social import make_social_runtime, run_social_runtime

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
    rt = make_social_runtime(cfg)
    dims = {"N": 18, "m": 3, "T": 37, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        plan = ExecutionPlan(backend=backend, store=store,
                             async_=_async_model())
        return walk.trace(
            lambda rt_: run_social_runtime(
                model, rt_, M=len(topo.sizes), T=37, plan=plan),
            rt,
        )

    # log_ratio is the in-scan-reduced store: the one where (T, *) is a
    # provable ban rather than the store's own output.
    return dims, ("log_ratio",), make


def _hps_async_fixture():
    from repro.core.graphs import make_hierarchy
    from repro.core.hps import HPSConfig, make_hps_runtime, run_hps
    from repro.core.plan import ExecutionPlan

    topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.2)
    rt = make_hps_runtime(cfg)
    w = np.random.default_rng(3).normal(size=(15, 2)).astype(np.float32)
    dims = {"N": 15, "d": 2, "T": 31, "E": int(np.asarray(rt.src).shape[0])}

    def make(backend, store):
        plan = ExecutionPlan(backend=backend, store=store,
                             async_=_async_model())
        return walk.trace(
            lambda w_: run_hps(w_, cfg, T=31, seed=0, plan=plan),
            w,
        )

    return dims, ("gap",), make


_FIXTURES = {
    "pushsum": _pushsum_fixture,
    "pushsum_sharded": _pushsum_sharded_fixture,
    "social": _social_fixture,
    "hps": _hps_fixture,
    "byzantine": _byz_fixture,
    "pushsum_faults": _pushsum_faults_fixture,
    "social_faults": _social_faults_fixture,
    "hps_faults": _hps_faults_fixture,
    "byzantine_faults": _byz_faults_fixture,
    "pushsum_async": _pushsum_async_fixture,
    "social_async": _social_async_fixture,
    "hps_async": _hps_async_fixture,
}


def _retrace_thunks():
    """Tiny concrete runs of every sweep/grid entry point (XLA, CPU-safe).
    Each is executed twice by the sentinel; the second call must hit every
    compiled cache."""
    from repro.core import attacks
    from repro.core.byzantine import ByzantineConfig
    from repro.core.graphs import edge_list, make_hierarchy, \
        random_strongly_connected
    from repro.core.hps import HPSConfig
    from repro.core.plan import ExecutionPlan
    from repro.core.signals import make_confused_model
    from repro.core.sweeps import (
        run_byzantine_grid,
        run_byzantine_sweep,
        run_hps_grid,
        run_hps_sweep,
        run_pushsum_sweep,
        run_social_grid,
        run_social_sweep,
    )

    rng = np.random.default_rng(1)
    el = edge_list(random_strongly_connected(16, 0.2, rng))
    w16 = rng.normal(size=(16, 2)).astype(np.float32)

    topo = make_hierarchy([5, 5, 5], topology="complete", seed=0)   # N = 15
    model = make_confused_model(N=15, m=3, truth=0, confusion=0.0, seed=0)
    bcfgs = [
        ByzantineConfig(topo=topo, F=0, byz=(), gamma_period=4,
                        attack=attacks.sign_flip()),
        ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                        attack=attacks.sign_flip()),
    ]
    hcfgs = [HPSConfig(topo=topo, gamma_period=g, B=2, drop_prob=0.0)
             for g in (2, 4)]
    w15 = rng.normal(size=(15, 2)).astype(np.float32)
    xla = ExecutionPlan(backend="xla")

    return {
        "run_pushsum_sweep": lambda: run_pushsum_sweep(
            w16, el, T=5, drop_probs=[0.0, 0.5], seeds=[0, 1], B=2,
            plan=xla),
        "run_pushsum_sweep_sharded": lambda: run_pushsum_sweep(
            w16, el, T=5, drop_probs=[0.0, 0.5], seeds=[0, 1], B=2,
            plan=xla.replace(graph_shards=2)),
        "run_pushsum_sweep_async": lambda: run_pushsum_sweep(
            w16, el, T=5, drop_probs=[0.0, 0.5], seeds=[0, 1], B=2,
            plan=xla.replace(async_=_async_model())),
        "run_byzantine_sweep": lambda: run_byzantine_sweep(
            model, bcfgs[1], T=3, seeds=[0, 1],
            plan=xla.replace(store="final")),
        "run_byzantine_grid": lambda: run_byzantine_grid(
            model, bcfgs, T=3, seeds=[0, 1],
            plan=xla.replace(store="decisions")),
        "run_hps_sweep": lambda: run_hps_sweep(
            w15, hcfgs[0], T=4, drop_probs=[0.0, 0.3], seeds=[0],
            plan=xla.replace(store="gap")),
        "run_hps_grid": lambda: run_hps_grid(
            w15, hcfgs, T=4, seeds=[0, 1], plan=xla.replace(store="gap")),
        "run_social_sweep": lambda: run_social_sweep(
            model, hcfgs[0], T=4, drop_probs=[0.0, 0.3], seeds=[0],
            plan=xla.replace(store="log_ratio")),
        "run_social_grid": lambda: run_social_grid(
            model, hcfgs, T=4, seeds=[0, 1],
            plan=xla.replace(store="log_ratio")),
    }


def _count_prng_sites(closed) -> int:
    n = 0
    for _, eqn in walk.iter_eqns(closed):
        name = eqn.primitive.name
        if "threefry" in name or name.startswith("random_"):
            n += 1
    return n


def _synthetic_dense(dims):
    """A deliberately-broken pushsum-shaped program: materializes the
    (N, N) averaging matrix the sparse core exists to avoid."""
    import jax.numpy as jnp

    N = dims["N"]

    def bad(w):
        dense_mix = jnp.ones((N, N), w.dtype) / N      # the bug
        return dense_mix @ w

    return walk.trace(bad, np.zeros((N, dims["d"]), np.float32))


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------

def _trace_findings(engines, inject_dense=False) -> list[Finding]:
    out: list[Finding] = []
    for name in engines:
        c = contracts.get(name)
        dims, stores, make = _FIXTURES[name]()
        for backend in _TRACE_BACKENDS:
            for store in stores:
                where = f"{name}[backend={backend}" + (
                    f", store={store}]" if store else "]")
                try:
                    closed = make(backend, store)
                except Exception as e:  # tracing itself must not break
                    out.append(Finding(
                        check="trace-error", where=where,
                        message=f"{type(e).__name__}: {e}",
                    ))
                    continue
                out.extend(dense.assert_nonempty(closed, where=where))
                out.extend(dense.find_forbidden(
                    closed, dims, c.forbidden_for(store), where=where))
                out.extend(dense.find_subnormal_consts(closed, where=where))
                sites = _count_prng_sites(closed)
                if sites < c.n_prng_sites:
                    out.append(Finding(
                        check="prng-sites", where=where,
                        message=(
                            f"traced program holds {sites} counter-PRNG "
                            f"call sites but the contract declares "
                            f"{c.n_prng_sites} streams — a stream was "
                            "hoisted or dropped"
                        ),
                    ))
        if inject_dense and name == "pushsum":
            out.extend(dense.find_forbidden(
                _synthetic_dense(dims), dims, c.forbidden_for(None),
                where="pushsum[synthetic-dense-injection]"))
    return out


def _fitted_streams(c, override: dict | None) -> list[streams.AffineMap]:
    if override and c.name in override:
        return list(override[c.name])
    return [streams.fit_affine(s.fold, f"{c.name}.{s.name}")
            for s in c.streams]


def _stream_findings(engines, override: dict | None = None) -> list[Finding]:
    out: list[Finding] = []
    fitted = {}
    for name in engines:
        c = contracts.get(name)
        try:
            fitted[name] = _fitted_streams(c, override)
        except ValueError as e:
            out.append(Finding(check="prng-stream-collision", where=name,
                               message=str(e)))
            fitted[name] = []
    for name in engines:
        c = contracts.get(name)
        out.extend(streams.check_streams(fitted[name], c.horizon,
                                         where=name))
        for other in c.shares_seed_with:
            if other not in fitted:
                oc = contracts.get(other)
                fitted[other] = _fitted_streams(oc, override)
            oc = contracts.get(other)
            horizon = min(c.horizon, oc.horizon)
            for m1 in fitted[name]:
                for m2 in fitted[other]:
                    disjoint, wit = streams.affine_disjoint(
                        m1, m2, horizon)
                    if not disjoint:
                        t1, t2, val = wit
                        out.append(Finding(
                            check="prng-stream-collision",
                            where=f"{name} x {other}",
                            message=(
                                f"shared-seed engines collide: {m1.name}"
                                f"@t={t1} == {m2.name}@t={t2} (both fold "
                                f"{val}); maps [{m1}] vs [{m2}] over "
                                f"horizon T={horizon}"
                            ),
                        ))
    return out


def _retrace_findings() -> list[Finding]:
    out: list[Finding] = []
    for name, thunk in _retrace_thunks().items():
        out.extend(retrace.check_idempotent(thunk, where=name))
    return out


def _precision_findings() -> list[Finding]:
    """Precision-policy proofs (trace/lower only, nothing executes):
    donation aliasing on the step entry for both policies, and the
    bf16-carry contract over every engine's scan."""
    import jax

    from repro.core import attacks
    from repro.core.byzantine import ByzantineConfig, make_byzantine_scan
    from repro.core.graphs import edge_list, make_hierarchy, \
        random_strongly_connected
    from repro.core.hps import HPSConfig, make_hps_runtime, run_hps
    from repro.core.plan import ExecutionPlan
    from repro.core.signals import make_confused_model
    from repro.core.social import make_social_runtime, run_social_runtime
    from repro.core.sweeps import _sweep_body

    out: list[Finding] = []
    out += precision.step_donation_findings("xla", None)
    out += precision.step_donation_findings("xla", "bf16")

    # pushsum sweep body, K=2 scenario batch, bf16 storage
    rng = np.random.default_rng(7)
    el = edge_list(random_strongly_connected(11, 0.3, rng))
    w11 = rng.normal(size=(11, 3)).astype(np.float32)
    src_b = np.broadcast_to(el.src[None], (2, el.E)).copy()
    dst_b = np.broadcast_to(el.dst[None], (2, el.E)).copy()
    val_b = np.ones((2, el.E), bool)
    drop_b = np.array([0.1, 0.4], np.float32)
    seed_b = np.array([0, 1], np.uint32)
    closed = walk.trace(
        lambda *a: _sweep_body(*a, T=5, B=2, backend="xla", policy="bf16"),
        w11, src_b, dst_b, val_b, drop_b, seed_b)
    out += precision.find_fp32_scan_state(
        closed, {"N": 11, "d": 3, "T": 5, "E": int(el.E), "K": 2},
        where="pushsum[policy=bf16]")

    # social + hps share the [6,6,6]/[5,5,5] hierarchy fixtures
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=2, drop_prob=0.3)
    rt = make_social_runtime(cfg)
    closed = walk.trace(
        lambda rt_: run_social_runtime(
            model, rt_, M=len(topo.sizes), T=37,
            plan=ExecutionPlan(backend="xla", store="log_ratio",
                               policy="bf16")),
        rt)
    out += precision.find_fp32_scan_state(
        closed,
        {"N": 18, "m": 3, "T": 37, "E": int(np.asarray(rt.src).shape[0])},
        where="social[policy=bf16]")

    topo5 = make_hierarchy([5, 5, 5], topology="complete", seed=0)
    hcfg = HPSConfig(topo=topo5, gamma_period=4, B=2, drop_prob=0.2)
    hrt = make_hps_runtime(hcfg)
    w15 = rng.normal(size=(15, 2)).astype(np.float32)
    closed = walk.trace(
        lambda w_: run_hps(w_, hcfg, T=31, seed=0,
                           plan=ExecutionPlan(backend="xla", store="gap",
                                              policy="bf16")),
        w15)
    out += precision.find_fp32_scan_state(
        closed,
        {"N": 15, "d": 2, "T": 31, "E": int(np.asarray(hrt.src).shape[0])},
        where="hps[policy=bf16]")

    topo8 = make_hierarchy([8] * 8, topology="complete", seed=0)   # N = 64
    bmodel = make_confused_model(N=64, m=3, truth=0, confusion=0.0, seed=1)
    bcfg = ByzantineConfig(topo=topo8, F=2, byz=(2, 9), gamma_period=4,
                           attack=attacks.sign_flip())
    run = make_byzantine_scan(bmodel, bcfg, T=5, core="sparse",
                              backend="xla", store="final", policy="bf16")
    closed = walk.trace(run, jax.random.PRNGKey(0))
    out += precision.find_fp32_scan_state(
        closed, {"N": 64, "m": 3, "T": 5},
        where="byzantine[policy=bf16]")
    return out


# ---------------------------------------------------------------------------
# Verdict cache
# ---------------------------------------------------------------------------

def _source_fingerprint() -> str:
    import jax

    h = hashlib.sha256()
    h.update(jax.__version__.encode())
    src = _REPO_ROOT / "src" / "repro"
    for p in sorted(src.rglob("*.py")):
        h.update(str(p.relative_to(src)).encode())
        h.update(hashlib.sha256(p.read_bytes()).digest())
    results = _REPO_ROOT / "results"
    if results.is_dir():
        for p in sorted(results.glob("BENCH_*.json")):
            h.update(p.name.encode())
            h.update(hashlib.sha256(p.read_bytes()).digest())
    return h.hexdigest()


def _cache_path(cache_dir: str) -> Path:
    return Path(cache_dir) / "lint-verdict.json"


# ---------------------------------------------------------------------------
# Subcommands
# ---------------------------------------------------------------------------

def _cmd_lint(args) -> int:
    retrace.register_default_caches()
    engines = sorted(contracts.REGISTRY)

    key = _source_fingerprint()
    cache_file = _cache_path(args.cache_dir)
    if not args.no_cache and not args.inject_legacy_streams \
            and not args.inject_dense and cache_file.is_file():
        try:
            verdict = json.loads(cache_file.read_text())
        except (OSError, ValueError):
            verdict = {}
        if verdict.get("key") == key and verdict.get("ok"):
            print(f"lint: cached PASS for source fingerprint "
                  f"{key[:12]} ({cache_file})")
            return 0

    override = None
    if args.inject_legacy_streams:
        override = {args.inject_legacy_streams:
                    streams.LEGACY_BUGGY_STREAMS[args.inject_legacy_streams]}

    findings: list[Finding] = []
    findings += signatures.check_entrypoints()
    findings += _trace_findings(engines, inject_dense=args.inject_dense)
    findings += _stream_findings(engines, override)
    if not args.skip_exec:
        findings += _retrace_findings()
    findings += _precision_findings()
    findings += memory.validate_bench(_REPO_ROOT / "results")

    for f in findings:
        print(f, file=sys.stderr)
    n_targets = sum(len(_FIXTURES[e]()[1]) for e in engines) \
        * len(_TRACE_BACKENDS)
    if findings:
        print(f"lint: FAIL — {len(findings)} finding(s) over {n_targets} "
              "traced targets", file=sys.stderr)
        return 1

    print(f"lint: PASS — {n_targets} traced targets, "
          f"{len(engines)} engine contracts, 0 findings")
    if not args.no_cache and not args.inject_legacy_streams \
            and not args.inject_dense:
        cache_file.parent.mkdir(parents=True, exist_ok=True)
        cache_file.write_text(json.dumps(
            {"key": key, "ok": True, "targets": n_targets}))
    return 0


def _cmd_budget(args) -> int:
    from repro.analysis.memory_model import pushsum_device_memory_gb
    from repro.analysis.roofline import pushsum_halo_wire_bytes

    retrace.register_default_caches()
    print("analytic per-round step bytes and TPU-v5e roofline floors:")
    cases = [
        ("pushsum  N=1024 E=3102 d=1",
         memory.pushsum_step_bytes(1024, 3102, 1)),
        ("social   N=18 E=90 m=3", memory.social_step_bytes(18, 90, 3)),
        ("hps      N=15 E=62 d=2", memory.hps_step_bytes(15, 62, 2)),
        ("byz-sparse N=64 deg=8 m=3",
         memory.byz_sparse_step_bytes(64, 8, 3)),
        ("byz-DENSE  N=4096 m=3", memory.byz_dense_bytes(4096, 3)),
    ]
    for label, b in cases:
        floor = memory.step_floor(b)
        print(f"  {label:28s} {b / 1e6:10.3f} MB  "
              f"floor {floor['bound_step_time_s'] * 1e6:8.3f} us  "
              f"({floor['dominant']}-bound)")

    print("edge-partitioned per-DEVICE budgets (graph axis, halo psum "
          "on the collective term):")
    for Ns, Es, ds, Ss in ((1 << 20, 1 << 21, 1, 8),
                           (1 << 20, 1 << 21, 1, 1)):
        b = memory.pushsum_sharded_step_bytes(Ns, Es, d=ds, n_shards=Ss)
        wire = pushsum_halo_wire_bytes(Ns, ds, Ss)
        floor = memory.step_floor(b, wire_bytes=wire, n_devices=Ss)
        resid = pushsum_device_memory_gb(Ns, Es, d=ds, n_shards=Ss)
        label = f"pushsum-2d N={Ns} E={Es} d={ds} S={Ss}"
        print(f"  {label:38s} {b / 1e6:10.3f} MB/step  "
              f"halo {wire / 1e6:7.3f} MB  "
              f"floor {floor['bound_step_time_s'] * 1e6:8.3f} us  "
              f"({floor['dominant']}-bound)  "
              f"resident {resid['total_gb']} GB "
              f"fits_16gb={resid['fits_16gb']}")

    print("per-policy step budgets (storage dtype is the bandwidth knob; "
          "masks, PRNG draws, sort keys and ids stay fp32/int32, so bf16 "
          "lands near — not exactly at — half):")
    pol_cases = [
        ("pushsum    N=131072 E=524288 d=1",
         lambda p: memory.pushsum_step_bytes(131072, 524288, 1, policy=p)),
        ("pushsum-2d N=1048576 E=2097152 S=8",
         lambda p: memory.pushsum_sharded_step_bytes(
             1 << 20, 1 << 21, d=1, n_shards=8, policy=p)),
        ("social     N=16384 E=65536 m=3",
         lambda p: memory.social_step_bytes(16384, 65536, 3, policy=p)),
        ("hps        N=15 E=62 d=2",
         lambda p: memory.hps_step_bytes(15, 62, 2, policy=p)),
        ("byz-sparse N=64 deg=8 m=3",
         lambda p: memory.byz_sparse_step_bytes(64, 8, 3, policy=p)),
    ]
    for label, fn in pol_cases:
        f32, b16 = fn(None), fn("bf16")
        print(f"  {label:36s} fp32 {f32 / 1e6:10.3f} MB  "
              f"bf16 {b16 / 1e6:10.3f} MB  ratio {b16 / f32:.3f}")
    print("halo wire bytes per round per device (N=1048576 d=1 S=8), "
          "psum vs scatter+gather:")
    for sb, tag in ((4, "fp32"), (2, "bf16")):
        wp = pushsum_halo_wire_bytes(1 << 20, 1, 8)
        ws = pushsum_halo_wire_bytes(1 << 20, 1, 8, variant="scatter",
                                     storage_bytes=sb)
        print(f"  storage={tag}: psum {wp / 1e6:8.3f} MB  "
              f"scatter {ws / 1e6:8.3f} MB  ratio {ws / wp:.3f}")

    print("traced footprints:")
    for name in sorted(contracts.REGISTRY):
        dims, stores, make = _FIXTURES[name]()
        closed = make("xla", stores[0])
        fp = memory.jaxpr_footprint(closed, dims)
        print(f"  {name}: {fp['n_values']} values, peak "
              f"{fp['peak_value_bytes']} B, total {fp['total_bytes']} B")
        for line in fp["top"][:3]:
            print(f"    {line}")

    findings = memory.validate_bench(_REPO_ROOT / "results")
    for f in findings:
        print(f, file=sys.stderr)
    return 1 if findings else 0


def _cmd_list(args) -> int:
    retrace.register_default_caches()
    print("contracts:")
    for c in contracts.all_contracts():
        pats = {k: list(v) for k, v in c.forbidden.items()}
        print(f"  {c.name}: streams={[s.name for s in c.streams]}, "
              f"forbidden={pats}, shares_seed_with="
              f"{list(c.shares_seed_with)}, caches={list(c.caches)}")
    print("registered caches:")
    for name, size in retrace.snapshot().items():
        print(f"  {name}: {size} entries")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.statics",
        description="jaxpr static analysis for the fused engines",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    lint = sub.add_parser("lint", help="run every static check")
    lint.add_argument("--cache-dir", default=str(_REPO_ROOT / ".statics-cache"),
                      help="verdict-cache directory (CI persists this)")
    lint.add_argument("--no-cache", action="store_true",
                      help="ignore and do not write the verdict cache")
    lint.add_argument("--skip-exec", action="store_true",
                      help="skip the executed retrace-sentinel checks "
                           "(trace-only lint)")
    lint.add_argument("--inject-legacy-streams",
                      choices=sorted(streams.LEGACY_BUGGY_STREAMS),
                      help="TEST ONLY: check the named engine with its "
                           "historical buggy fold-in scheme")
    lint.add_argument("--inject-dense", action="store_true",
                      help="TEST ONLY: add a synthetic (N, N) intermediate "
                           "to the pushsum lint target")
    lint.set_defaults(fn=_cmd_lint)

    budget = sub.add_parser("budget", help="static memory/FLOP budgets")
    budget.set_defaults(fn=_cmd_budget)

    lst = sub.add_parser("list", help="show contracts and caches")
    lst.set_defaults(fn=_cmd_list)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
