"""``python -m repro.statics`` entry point."""
import sys

from .cli import main

sys.exit(main())
