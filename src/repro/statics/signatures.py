"""Signature linter — the execution plane stays behind ``plan=``.

PR 10 moved every execution knob (backend, policy, faults, mesh, graph
sharding, storage, async clocks, ...) out of the ``run_*`` keyword lists
and into the frozen :class:`repro.core.plan.ExecutionPlan`. The loose
kwargs survive only as warn-once deprecation shims routed through
``**legacy`` — they are *not* named parameters anymore, so the execution
vocabulary cannot silently re-grow one kwarg at a time ("kwarg 15" was
the failure mode this redesign retired).

This pass freezes that boundary structurally: :func:`check_entrypoints`
inspects the signature of every public ``run_*`` entry point and fails
the lint if

* the entry point lacks a ``plan`` parameter, or
* any *named* parameter (positional or keyword-only) re-introduces a
  covered execution kwarg — any :data:`repro.core.plan.PLAN_FIELDS`
  name, the ``async_`` field itself, or the retired seed-era
  ``use_kernel=`` backend alias.

Science knobs (``drop_probs``, ``seeds``, ``T``, ``B``, ``F``,
``attacks``, ``mode``, ``core``, ``record_every``, ...) are untouched:
they parameterize the *experiment*, not the execution substrate, and the
linter only matches the covered execution names.
"""
from __future__ import annotations

import inspect

from .dense import Finding

__all__ = ["ENTRYPOINTS", "check_signature", "check_entrypoints"]

#: module path -> public run_* entry points covered by the plan contract.
ENTRYPOINTS: tuple[tuple[str, str], ...] = (
    ("repro.core.pushsum", "run_pushsum_sparse"),
    ("repro.core.hps", "run_hps_runtime"),
    ("repro.core.hps", "run_hps"),
    ("repro.core.social", "run_social_runtime"),
    ("repro.core.social", "run_social_learning"),
    ("repro.core.sweeps", "run_pushsum_sweep"),
    ("repro.core.sweeps", "run_byzantine_sweep"),
    ("repro.core.sweeps", "run_byzantine_grid"),
    ("repro.core.sweeps", "run_hps_sweep"),
    ("repro.core.sweeps", "run_hps_grid"),
    ("repro.core.sweeps", "run_social_sweep"),
    ("repro.core.sweeps", "run_social_grid"),
)


def _covered_names() -> frozenset[str]:
    from repro.core.plan import PLAN_FIELDS

    return frozenset(PLAN_FIELDS) | {"use_kernel"}


def check_signature(fn, name: str) -> list[Finding]:
    """Lint one entry point's signature against the plan contract."""
    out: list[Finding] = []
    covered = _covered_names()
    params = inspect.signature(fn).parameters
    if "plan" not in params:
        out.append(Finding(
            check="plan-signature", where=name,
            message="entry point has no plan= parameter — execution "
                    "config must arrive as ExecutionPlan",
        ))
    offenders = [
        p.name for p in params.values()
        if p.kind not in (p.VAR_KEYWORD, p.VAR_POSITIONAL)
        and p.name in covered
    ]
    if offenders:
        out.append(Finding(
            check="plan-signature", where=name,
            message=(
                f"named parameter(s) {offenders} re-introduce covered "
                "execution kwargs — these are ExecutionPlan fields (or "
                "the retired use_kernel alias) and may only pass through "
                "**legacy deprecation shims"
            ),
        ))
    return out


def check_entrypoints() -> list[Finding]:
    """Lint every registered ``run_*`` entry point."""
    import importlib

    out: list[Finding] = []
    for mod_name, fn_name in ENTRYPOINTS:
        try:
            fn = getattr(importlib.import_module(mod_name), fn_name)
        except (ImportError, AttributeError) as e:
            out.append(Finding(
                check="plan-signature", where=f"{mod_name}.{fn_name}",
                message=f"entry point missing: {type(e).__name__}: {e}",
            ))
            continue
        out.extend(check_signature(fn, f"{mod_name}.{fn_name}"))
    return out
