"""jaxpr IR walker — the traversal core every static check is built on.

A traced :class:`jax.core.ClosedJaxpr` is a tree: equations at the top
level, with sub-jaxprs riding equation params (``scan``/``while`` carry
their bodies under ``jaxpr``, ``cond`` under ``branches``, ``pjit``/
``custom_jvp_call``/``custom_vjp_call`` under ``jaxpr``/``call_jaxpr``,
``pallas_call`` under ``jaxpr`` as well). The helpers here walk that tree
once and hand back flat views the checks consume:

* :func:`subjaxprs` / :func:`iter_eqns` — the raw traversal (drop-in for
  the per-test walkers that used to be copy-pasted across
  ``test_byz_trim_kernel.py`` / ``test_social_engine.py`` /
  ``test_hps_engine.py``).
* :func:`collect_avals` — every equation-output shape, the exact contract
  of the historical test helpers (outvars only; invars are some other
  equation's outvars or jaxpr inputs, so outputs cover every intermediate).
* :func:`collect_values` — the richer view: shape + dtype + producing
  primitive + path into the sub-jaxpr tree, for findings that need to say
  *where* a dense intermediate lives.
* :func:`collect_literals` — every scalar/small-array constant (equation
  ``Literal`` invars and the closed jaxpr's hoisted consts), for the
  subnormal-constant check.
* :func:`symbolize` — map concrete dims back to the symbolic sizes
  (``N``, ``E``, ``T``, ...) a fixture was built with, so findings read
  ``(N, N, m)`` instead of ``(64, 64, 3)``. Fixtures must keep symbol
  sizes pairwise distinct (the same discipline the historical tests used:
  "T = 37, distinct from N = 18 ... so the walker cannot confuse axes");
  ambiguous dim tables are rejected loudly rather than guessed at.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import jax
import numpy as np

__all__ = [
    "Value",
    "subjaxprs",
    "iter_eqns",
    "collect_avals",
    "collect_values",
    "collect_literals",
    "symbolize",
    "trace",
]


def subjaxprs(val) -> Iterator[Any]:
    """Yield every jaxpr hiding inside one equation-param value.

    Handles ``ClosedJaxpr``, raw ``Jaxpr``, and (nested) lists/tuples of
    either — the shapes ``scan``/``cond``/``pjit``/``custom_*``/
    ``pallas_call`` store their bodies in.
    """
    if isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from subjaxprs(item)


def iter_eqns(jaxpr, path: tuple = ()) -> Iterator[tuple[tuple, Any]]:
    """Depth-first ``(path, eqn)`` pairs over a jaxpr and every sub-jaxpr.

    ``path`` names the chain of enclosing primitives, e.g.
    ``("scan", "cond")`` for an equation inside a branch inside the scan
    body — what a finding prints so the offending value is locatable.
    """
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield path, eqn
        for val in eqn.params.values():
            for sub in subjaxprs(val):
                yield from iter_eqns(sub, path + (eqn.primitive.name,))


def collect_avals(jaxpr, out: list) -> list:
    """Append every equation-output shape (recursing into sub-jaxprs).

    Signature and behavior are bit-for-bit the historical per-test walker:
    ``_collect_avals(jaxpr, [])`` on a raw ``Jaxpr`` returns the flat shape
    list the existing assertions consume.
    """
    for _, eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                out.append(v.aval.shape)
    return out


@dataclasses.dataclass(frozen=True)
class Value:
    """One intermediate value of a traced program."""

    shape: tuple[int, ...]
    dtype: Any
    prim: str            # producing primitive
    path: tuple[str, ...]  # enclosing primitives, outermost first

    @property
    def nbytes(self) -> int:
        size = int(np.prod(self.shape)) if self.shape else 1
        return size * _itemsize(self.dtype)

    def describe(self, dims: dict[str, int] | None = None) -> str:
        shape = symbolize(self.shape, dims) if dims else self.shape
        where = "/".join(self.path) or "<top>"
        return f"{self.prim} -> {shape} [{_dtype_name(self.dtype)}] at {where}"


def _itemsize(dtype) -> int:
    """Bytes per element, tolerating jax extended dtypes (``key<fry>`` is
    not a numpy dtype; a threefry key is two uint32 counters)."""
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return int(getattr(dtype, "itemsize", 8))


def _dtype_name(dtype) -> str:
    try:
        return np.dtype(dtype).name
    except TypeError:
        return str(dtype)


def collect_values(jaxpr) -> list[Value]:
    """Every equation-output value with dtype/primitive/path metadata."""
    out: list[Value] = []
    for path, eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(Value(
                    shape=tuple(aval.shape),
                    dtype=getattr(aval, "dtype", np.float32),
                    prim=eqn.primitive.name,
                    path=path,
                ))
    return out


# Constants larger than this are data (likelihood tables, edge indices),
# not tuning literals; scanning every element of a big operand would make
# the subnormal check O(input size) for no added signal.
_LITERAL_SCAN_CAP = 64


def collect_literals(closed) -> list[tuple[tuple, Any]]:
    """``(path, value)`` for every compile-time constant small enough to be
    a hand-written literal: equation ``Literal`` invars (recursing into
    sub-jaxprs) plus the closed jaxpr's hoisted consts.
    """
    out: list[tuple[tuple, Any]] = []
    jaxpr = closed
    if isinstance(closed, jax.core.ClosedJaxpr):
        for c in closed.consts:
            arr = np.asarray(c)
            if arr.size <= _LITERAL_SCAN_CAP:
                out.append(((), arr))
        jaxpr = closed.jaxpr
    for path, eqn in iter_eqns(jaxpr):
        for v in eqn.invars:
            if isinstance(v, jax.core.Literal):
                arr = np.asarray(v.val)
                if arr.size <= _LITERAL_SCAN_CAP:
                    out.append((path + (eqn.primitive.name,), arr))
    return out


def symbolize(shape: tuple[int, ...], dims: dict[str, int]) -> tuple:
    """Map a concrete shape back to fixture symbols: (64, 64, 3) with
    ``dims={"N": 64, "m": 3}`` reads ``("N", "N", "m")``.

    Dims whose size matches no symbol stay concrete ints. Two symbols
    sharing one size would make every report a guess, so ambiguous tables
    are rejected — pick pairwise-distinct fixture sizes instead (T=37
    against N=18 etc., the discipline the historical tests established).
    """
    rev: dict[int, str] = {}
    for name, size in dims.items():
        size = int(size)
        if size in rev:
            raise ValueError(
                f"ambiguous symbol table: {rev[size]!r} and {name!r} both "
                f"have size {size}; lint fixtures need pairwise-distinct dims"
            )
        rev[size] = name
    return tuple(rev.get(int(d), int(d)) for d in shape)


def trace(fn: Callable, *args, **kwargs) -> jax.core.ClosedJaxpr:
    """``jax.make_jaxpr`` with kwargs threaded through — the one tracing
    entry every check shares (abstract evaluation only; nothing runs)."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
