"""Static memory / FLOP budgeter — traced footprints + analytic per-engine
models, validated against the committed benchmark artifacts.

Two complementary views:

* :func:`jaxpr_footprint` — walk a traced program and account every
  intermediate's bytes (peak single value, total traffic proxy, largest
  offenders). This is the *structural* number: it scales exactly how the
  jaxpr scales, so asserting ``peak = O(E d)`` (and not ``O(N^2)``) is a
  compile-time proof, no execution needed.
* the ``*_step_bytes`` analytic models — closed-form per-iteration HBM
  traffic for each engine, the same style as the seed-era
  :mod:`repro.analysis.memory_model`. These feed
  :func:`repro.analysis.roofline.roofline_terms` (via :func:`step_floor`)
  to get a memory-bound step-time lower bound on the paper's TPU v5e
  target — a *floor*, never compared against wall-clock measured on other
  machines.

:func:`validate_bench` replays the committed ``results/BENCH_*.json`` rows
through the analytic models: every benchmarked sparse configuration must
fit the 16 GB HBM budget (with room for the O(N^2) dense reference to NOT
fit at the N=4096 scale the benchmarks stop at — the recorded
infeasibility the sparse refactor exists for).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from repro.analysis.roofline import HW, roofline_terms

from .dense import Finding
from .walk import collect_values

__all__ = [
    "jaxpr_footprint",
    "step_floor",
    "pushsum_step_bytes",
    "pushsum_sharded_step_bytes",
    "social_step_bytes",
    "hps_step_bytes",
    "byz_sparse_step_bytes",
    "byz_dense_bytes",
    "validate_bench",
]

_F32 = 4  # accum/index width: reductions, PRNG draws, int32 ids stay 4 B


def _storage_bytes(policy) -> int:
    """Bytes per element of the policy's *storage* dtype — what persistent
    engine state (scan carries, relay latches, wire payloads) is charged
    at. ``None`` = the default fp32 policy (4 B). Lazy import: statics
    modules must stay importable without dragging in repro.core."""
    if policy is None:
        return _F32
    if isinstance(policy, str):
        from repro.core.precision import resolve_policy
        return resolve_policy(policy).storage_bytes
    return int(policy.storage_bytes)


def jaxpr_footprint(closed, dims: dict[str, int] | None = None) -> dict:
    """Byte accounting over every intermediate of a traced program.

    ``total_bytes`` (sum over all equation outputs) over-counts live
    memory but is a faithful HBM-traffic proxy; ``peak_value_bytes`` is
    the largest single intermediate — the number that must stay O(E d).
    """
    values = collect_values(closed)
    sized = sorted(values, key=lambda v: v.nbytes, reverse=True)
    return {
        "n_values": len(values),
        "total_bytes": int(sum(v.nbytes for v in values)),
        "peak_value_bytes": int(sized[0].nbytes) if sized else 0,
        "top": [v.describe(dims) + f" = {v.nbytes} B" for v in sized[:5]],
    }


def step_floor(step_bytes: float, step_flops: float = 0.0, hw: HW = HW(),
               *, wire_bytes: float = 0.0, n_devices: int = 1) -> dict:
    """Roofline lower bound for one engine iteration on the TPU target.

    Reuses :func:`repro.analysis.roofline.roofline_terms` with the
    analytic byte/FLOP counts standing in for ``cost_analysis``:
    ``bound_step_time_s`` is the max of the memory, compute and (with
    ``wire_bytes`` > 0 — the edge-partitioned mode's per-round halo psum,
    see :func:`repro.analysis.roofline.pushsum_halo_wire_bytes`)
    collective terms.
    """
    return roofline_terms(
        {"flops": float(step_flops), "bytes accessed": float(step_bytes)},
        {"wire_bytes_per_device": float(wire_bytes)},
        n_devices=n_devices,
        mf=0.0,
        hw=hw,
    )


# ---------------------------------------------------------------------------
# Analytic per-iteration HBM traffic. Each counts the reads+writes of the
# engine's scan body; persistent state is charged at the precision policy's
# storage width (``policy=None`` = fp32, reproducing the historical
# numbers), while PRNG draws, sort keys, and int32 ids stay 4 B. Constants
# are small and checked by the structural tests against the traced
# footprints, not hand-tuned.
# ---------------------------------------------------------------------------

def pushsum_step_bytes(N: int, E: int, d: int = 1, *, policy=None) -> int:
    """Sparse push-sum round: gather E edge contributions of (value, mass),
    segment-sum into N nodes, plus the edge mask draw."""
    sb = _storage_bytes(policy)
    edge = E * (2 * d + 2) * sb            # relay (rho, rho_m) read + write
    node = N * (2 * d + 2) * sb            # read state, write state
    mask = E * _F32                        # per-edge Bernoulli keep mask
    return edge + node + mask


def pushsum_sharded_step_bytes(N: int, E: int, d: int = 1,
                               n_shards: int = 1, *, policy=None) -> int:
    """Per-DEVICE HBM traffic of one edge-partitioned push-sum round.

    Edge traffic drops to the shard-local ceil(E / S) slice; node traffic
    stays full (state is replicated across graph shards); the mask term is
    the FULL padded (S * ceil(E/S),) draw — the price of
    :func:`repro.core.pushsum.shard_edge_mask`'s bit-identity contract,
    every device generates the whole Bernoulli vector and windows it. The
    halo psum's wire cost is separate
    (:func:`repro.analysis.roofline.pushsum_halo_wire_bytes`) — it rides
    the collective term of :func:`step_floor`, not HBM.
    """
    S = max(int(n_shards), 1)
    sb = _storage_bytes(policy)
    e_shard = -(-E // S)
    edge = e_shard * (2 * d + 2) * sb
    node = N * (2 * d + 2) * sb
    mask = S * e_shard * _F32
    return edge + node + mask


def social_step_bytes(N: int, E: int, m: int, M: int = 1, *,
                      policy=None) -> int:
    """Algorithm 3 round: edge-gathered belief exchange (E x m), private
    Bayesian update (N x m likelihood row), per-edge drop mask."""
    sb = _storage_bytes(policy)
    edge = E * (m + 2) * sb
    # beliefs rw at storage width + the fp32 likelihood-table row
    node = 2 * N * m * sb + N * m * _F32
    mask = E * _F32
    return (edge + node + mask) * max(M, 1)


def hps_step_bytes(N: int, E: int, d: int = 1, *, policy=None) -> int:
    """Hierarchical push-sum round — push-sum traffic plus the fusion-layer
    trimmed pool touching every node value once more."""
    sb = _storage_bytes(policy)
    return pushsum_step_bytes(N, E, d, policy=policy) + 2 * N * d * sb


def byz_sparse_step_bytes(N: int, deg: int, m: int, *, policy=None) -> int:
    """Sparse Byzantine round: per-node neighbor gather (deg x m), trimmed
    reduce, belief rw."""
    sb = _storage_bytes(policy)
    gather = N * deg * m * sb
    trim = 2 * N * deg * m * _F32          # sort keys + gathered survivors
    node = 2 * N * m * sb
    return gather + trim + node


def byz_dense_bytes(N: int, m: int = 3) -> int:
    """Working set of the dense (N x N) trim reference at one round: the
    all-pairs belief matrix, its sort permutation, and the gathered output.
    This is the term that kills dense at scale — at N=4096, m=3 it is
    ~0.6 GB *per round* where the sparse core needs a few MB."""
    return 3 * N * N * m * _F32


# ---------------------------------------------------------------------------
# Benchmark-artifact validation
# ---------------------------------------------------------------------------

_NAME_N_RE = re.compile(r"_N(\d+)")
_DERIVED_E_RE = re.compile(r"(?:^|;)E=(\d+)")
_DERIVED_SHARDS_RE = re.compile(r"(?:^|;)shards=(\d+)")
_DERIVED_D_RE = re.compile(r"(?:^|;)d=(\d+)")
_DERIVED_POLICY_RE = re.compile(r"(?:^|;)policy=([\w/]+)")
_DERIVED_BYTES_RE = re.compile(r"(?:^|;)bytes_per_step=([0-9.eE+-]+|nan)")


def validate_bench(results_dir: str | Path, hw: HW = HW()) -> list[Finding]:
    """Check every committed BENCH row's configuration against the
    analytic memory models (structure only — never wall-clock).

    Rows whose ``derived`` carries ``shards=S`` (the edge-partitioned 2-D
    mesh benchmarks) are budgeted per DEVICE: shard-local edge traffic
    (:func:`pushsum_sharded_step_bytes`) and the
    :func:`repro.analysis.memory_model.pushsum_device_memory_gb` residency
    prediction must both fit the per-chip HBM — that is the whole point of
    partitioning, so a sharded row that only fits in aggregate is a
    failure. Rows tagged ``policy=<tag>`` (e.g. ``policy=bf16``) are
    budgeted at that policy's storage width, so the reduced-precision
    benchmarks are held to their correspondingly smaller analytic budget.
    Explicitly skipped rows (``derived`` starting ``skipped=``, written by
    single-device bench hosts) are ignored.
    """
    from repro.analysis.memory_model import pushsum_device_memory_gb

    results_dir = Path(results_dir)
    out: list[Finding] = []
    rows = 0
    for path in sorted(results_dir.glob("BENCH_*.json")):
        data = json.loads(path.read_text())
        for name, row in data.items():
            derived = str(row.get("derived", ""))
            if derived.startswith("skipped="):
                continue
            m = _NAME_N_RE.search(name)
            if not m:
                continue
            N = int(m.group(1))
            e_m = _DERIVED_E_RE.search(derived)
            E = int(e_m.group(1)) if e_m else 4 * N
            s_m = _DERIVED_SHARDS_RE.search(derived)
            S = int(s_m.group(1)) if s_m else 1
            d_m = _DERIVED_D_RE.search(derived)
            d = int(d_m.group(1)) if d_m else 1
            p_m = _DERIVED_POLICY_RE.search(derived)
            policy = p_m.group(1) if p_m else None
            rows += 1
            if not (0 < E <= N * (N - 1)):
                out.append(Finding(
                    check="memory-budget", where=f"{path.name}:{name}",
                    message=f"derived edge count E={E} impossible for N={N}",
                ))
                continue
            if S > 1:
                step = pushsum_sharded_step_bytes(N, E, d=d, n_shards=S,
                                                  policy=policy)
                resid = pushsum_device_memory_gb(N, E, d=d, n_shards=S)
                if not resid["fits_16gb"]:
                    out.append(Finding(
                        check="memory-budget", where=f"{path.name}:{name}",
                        message=(
                            f"per-device residency {resid['total_gb']} GB at "
                            f"N={N}, E={E}, d={d}, shards={S} — the "
                            "edge-partitioned row does not fit one chip"
                        ),
                    ))
            else:
                step = pushsum_step_bytes(N, E, d=d, policy=policy)
            b_m = _DERIVED_BYTES_RE.search(derived)
            if b_m and "mode=interpret" not in derived:
                # the row recorded its compiled per-step traffic: hold it
                # to the analytic budget. The model upper-bounds a round
                # (every leaf read+written, no fusion credit), so measured
                # above budget means the model no longer covers the
                # program — e.g. a policy change that stopped shrinking
                # the stored state while the budget still assumes it did.
                # mode=interpret rows are exempt: they cost the Pallas
                # interpreter's Python-level traffic, not the kernel's.
                measured = float(b_m.group(1))
                if measured == measured and measured > step:
                    out.append(Finding(
                        check="memory-budget", where=f"{path.name}:{name}",
                        message=(
                            f"measured bytes_per_step={measured:.0f} exceeds "
                            f"the analytic budget {step} "
                            f"(policy={policy or 'fp32'}) — the model no "
                            "longer upper-bounds the compiled program"
                        ),
                    ))
            if step >= hw.hbm_bytes:
                out.append(Finding(
                    check="memory-budget", where=f"{path.name}:{name}",
                    message=(
                        f"sparse step needs {step / 1e9:.2f} GB at N={N}, "
                        f"E={E} — exceeds the {hw.hbm_bytes / 1e9:.0f} GB "
                        "HBM budget the benchmarks assume"
                    ),
                ))
    if rows == 0:
        out.append(Finding(
            check="memory-budget", where=str(results_dir),
            message=(
                "no BENCH rows with an _N<size> name found — the budget "
                "validation ran against nothing (artifacts moved/renamed?)"
            ),
        ))
    return out
