"""Retrace sentinel — compiled-cache growth accounting.

The sweep engines keep their executables in module-level LRU caches
(``_BYZ_COMPILED``, ``_SOCIAL_COMPILED``, ``_HPS_COMPILED``, the grid and
runtime caches) plus jit tracing caches on the module-level jits. The
whole point of those caches is that a *repeated* call with the same
shapes/statics costs zero compilations — a key that hashes unstably (a
default-``__hash__`` dataclass, a float that should be rounded, an array
in a static) silently retraces every call and the only symptom is a 100x
slower sweep.

This module makes that property checkable:

* engines :func:`register_cache` their cache objects at the definition
  site (name -> ``len()``-able mapping or a ``() -> int`` size callable —
  jit wrappers register their ``_cache_size`` bound method);
* :class:`CacheWatch` snapshots every registered size on enter/exit and
  turns unexpected growth into findings;
* :func:`check_idempotent` runs a thunk twice and fails if the SECOND
  call grew any cache — the exact "repeat call must not retrace"
  contract.
"""
from __future__ import annotations

from typing import Callable, Mapping

from .dense import Finding

__all__ = [
    "CACHE_REGISTRY",
    "register_cache",
    "register_default_caches",
    "snapshot",
    "CacheWatch",
    "check_idempotent",
]

# name -> () -> current entry count. Populated by the engine modules at
# import time (sweeps.py / social.py / hps.py call register_cache).
CACHE_REGISTRY: dict[str, Callable[[], int]] = {}


def register_cache(name: str, cache) -> None:
    """Register a compiled cache under ``name``.

    ``cache`` is either a sized mapping (the ``_LRUCache`` dicts) or a
    zero-arg callable returning the entry count (a jit's ``_cache_size``).
    Re-registration replaces (importlib.reload must not error).
    """
    if callable(cache) and not hasattr(cache, "__len__"):
        CACHE_REGISTRY[name] = cache
    else:
        CACHE_REGISTRY[name] = lambda c=cache: len(c)


def register_default_caches() -> None:
    """Import the core engines so their definition-site registrations run."""
    from repro.core import hps, social, sweeps  # noqa: F401


def snapshot() -> dict[str, int]:
    """Current entry count of every registered cache."""
    return {name: int(fn()) for name, fn in sorted(CACHE_REGISTRY.items())}


class CacheWatch:
    """Context manager: snapshot registered caches around a block.

    ``allowed`` bounds per-cache growth (entries); caches not named are
    allowed unlimited growth when ``strict=False`` (warm-up blocks) and
    zero growth when ``strict=True`` (repeat-call blocks).
    """

    def __init__(
        self,
        allowed: Mapping[str, int] | None = None,
        *,
        strict: bool = False,
        where: str = "<caches>",
    ):
        self.allowed = dict(allowed or {})
        self.strict = strict
        self.where = where
        self.before: dict[str, int] = {}
        self.after: dict[str, int] = {}

    def __enter__(self) -> "CacheWatch":
        self.before = snapshot()
        return self

    def __exit__(self, *exc) -> None:
        self.after = snapshot()

    @property
    def deltas(self) -> dict[str, int]:
        return {
            name: self.after.get(name, 0) - self.before.get(name, 0)
            for name in self.after
            if self.after.get(name, 0) != self.before.get(name, 0)
        }

    def findings(self) -> list[Finding]:
        out: list[Finding] = []
        for name, delta in sorted(self.deltas.items()):
            budget = self.allowed.get(name, 0 if self.strict else None)
            if budget is None or delta <= budget:
                continue
            out.append(Finding(
                check="unexpected-retrace",
                where=self.where,
                message=(
                    f"cache {name!r} grew by {delta} "
                    f"({self.before.get(name, 0)} -> {self.after.get(name, 0)}) "
                    f"but at most {budget} new entries were expected — a "
                    "repeated call is recompiling (unstable cache key?)"
                ),
            ))
        return out


def check_idempotent(
    thunk: Callable[[], object],
    *,
    where: str = "<entry point>",
) -> list[Finding]:
    """Run ``thunk`` twice; the second run must not grow ANY registered
    cache. The first run may compile freely (that is what caches are for);
    a second identical call that still compiles is the retrace bug class
    this sentinel exists to catch."""
    thunk()  # warm-up: may populate caches
    with CacheWatch(strict=True, where=where) as watch:
        thunk()
    return watch.findings()
