"""Dense-intermediate linter + subnormal-constant check.

The repo's core scaling guarantee is *structural*: the sparse engines must
never materialize an (N, N, ...) value, and the in-scan-reducing ``store``
variants must never materialize a (T, ...) value. These used to be
enforced by per-test jaxpr walkers; here they are one reusable pass over
:func:`repro.statics.walk.collect_values`.

Patterns are symbolic shape *prefixes* over the fixture's dim table:
``("N", "N")`` flags any value whose first two axes are both N (the exact
predicate the historical tests asserted — ``s[0] == n and s[1] == n``);
``("T", "*")`` flags any rank >= 2 value led by the horizon axis (``"*"``
matches any single axis). Engines declare their patterns per ``store``
variant via :func:`repro.statics.contracts.contract`.

:func:`find_subnormal_consts` is the would-have-caught check for the PR-4
belief-floor bug: a literal like ``1e-38`` sits below the smallest normal
fp32 (~1.1754944e-38), so XLA CPU's flush-to-zero turned
``log(max(mu, 1e-38))`` into ``log(0) = -inf`` and NaN'd the Theorem-2
ratios. Any float literal in the subnormal range of its own dtype is a
latent FTZ bug and gets flagged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .walk import Value, collect_literals, collect_values, symbolize

__all__ = ["Finding", "find_forbidden", "find_subnormal_consts"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation; ``check`` names the pass, ``where`` the engine /
    entry point the traced program came from."""

    check: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.where}: {self.message}"


def _matches(sym_shape: tuple, pattern: tuple) -> bool:
    """Anchored prefix match with ``"*"`` single-axis wildcard."""
    if len(sym_shape) < len(pattern):
        return False
    for got, want in zip(sym_shape, pattern):
        if want != "*" and got != want:
            return False
    return True


def find_forbidden(
    closed,
    dims: dict[str, int],
    patterns: tuple[tuple, ...],
    *,
    where: str = "<traced fn>",
) -> list[Finding]:
    """Flag every intermediate whose symbolized shape starts with a
    forbidden pattern. ``dims`` maps fixture symbols to the concrete sizes
    the program was traced at (pairwise-distinct; see
    :func:`repro.statics.walk.symbolize`)."""
    out: list[Finding] = []
    for val in collect_values(closed):
        sym = symbolize(val.shape, dims)
        for pat in patterns:
            if _matches(sym, pat):
                out.append(Finding(
                    check="dense-intermediate",
                    where=where,
                    message=(
                        f"forbidden {pat} value: {val.describe(dims)} "
                        f"(concrete shape {val.shape})"
                    ),
                ))
                break
    return out


def assert_nonempty(closed, *, where: str = "<traced fn>") -> list[Finding]:
    """A jaxpr with no equations means the walker was handed a constant
    program — the historical tests guarded this ("jaxpr walker found no
    values"), so the framework does too."""
    if collect_values(closed):
        return []
    return [Finding(
        check="dense-intermediate", where=where,
        message="jaxpr walker found no values (empty traced program?)",
    )]


def find_subnormal_consts(closed, *, where: str = "<traced fn>") -> list[Finding]:
    """Flag float literals in the subnormal range of their own dtype."""
    out: list[Finding] = []
    for path, arr in collect_literals(closed):
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        tiny = np.finfo(arr.dtype).tiny
        vals = np.atleast_1d(arr)
        bad = vals[(vals != 0) & np.isfinite(vals) & (np.abs(vals) < tiny)]
        if bad.size:
            at = "/".join(path) or "<consts>"
            out.append(Finding(
                check="subnormal-const",
                where=where,
                message=(
                    f"literal {bad.ravel()[0]!r} at {at} is subnormal for "
                    f"{arr.dtype} (tiny={tiny!r}); XLA CPU flush-to-zero "
                    "reads it as 0.0 — use the dtype's smallest NORMAL "
                    "value instead (the PR-4 belief-floor NaN class)"
                ),
            ))
    return out
