"""Engine contracts — the declared structural invariants the linter enforces.

Every engine that wants the static checks by default declares them ONCE at
its definition site with the :func:`contract` decorator (or a direct
:func:`register` call), instead of each invariant living in a copy-pasted
test walker:

    @statics.contract(
        name="social",
        forbidden={"*": (("N", "N"),), "final": (("T", "*"),)},
        streams=(("link", lambda t: social_stream_fold(t, STREAM_LINK)),
                 ("signal", lambda t: social_stream_fold(t, STREAM_SIGNAL))),
        caches=("social.compiled", "social.runtime"),
    )
    def _social_scan_core(...):

The decorator is transparent — it registers the declaration and returns
the function unchanged, so tracing/jit behavior is untouched. Checks pull
declarations from :data:`REGISTRY`; the CLI (:mod:`repro.statics.cli`)
maps each registered name to a small concrete fixture and runs the full
registry over it.

Declaration vocabulary:

* ``forbidden`` — symbolic shape patterns (see
  :func:`repro.statics.dense.find_forbidden`) keyed by ``store`` variant;
  the ``"*"`` key applies to every variant.
* ``streams`` — ``(name, fold)`` pairs, one per PRNG stream the engine
  folds into its base key each iteration. The stream-domain analyzer fits
  each ``fold`` to an affine map over ``t`` and statically proves pairwise
  disjointness over ``horizon`` (:mod:`repro.statics.streams`).
* ``shares_seed_with`` — names of OTHER registered engines whose streams
  must also stay disjoint from this one's, because one experiment seed may
  legitimately root both engines' base keys (the HPS link stream vs the
  social streams — the PR-5 aliasing bug class).
* ``caches`` — names in the retrace-sentinel cache registry
  (:mod:`repro.statics.retrace`) whose growth this engine is accountable
  for.
* ``min_prng_sites`` — lower bound on counter-PRNG call sites the traced
  scan must contain (defaults to ``len(streams)``); a program that traces
  fewer has hoisted or dropped a stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

__all__ = [
    "StreamDecl",
    "EngineContract",
    "REGISTRY",
    "contract",
    "register",
    "get",
    "all_contracts",
]

# The default horizon disjointness is proven over: far beyond any committed
# benchmark run (T <= ~1e3 today) while keeping every affine image well
# inside the signed-int32 fold-in space the proof requires.
DEFAULT_HORIZON = 1 << 20


@dataclasses.dataclass(frozen=True)
class StreamDecl:
    """One per-iteration PRNG stream: ``fold(t)`` is the value folded into
    the engine's base key at iteration ``t``."""

    name: str
    fold: Callable[[int], int]


@dataclasses.dataclass(frozen=True)
class EngineContract:
    name: str
    forbidden: Mapping[str, tuple[tuple, ...]] = dataclasses.field(
        default_factory=dict
    )
    streams: tuple[StreamDecl, ...] = ()
    shares_seed_with: tuple[str, ...] = ()
    caches: tuple[str, ...] = ()
    horizon: int = DEFAULT_HORIZON
    min_prng_sites: int | None = None

    def forbidden_for(self, store: str | None) -> tuple[tuple, ...]:
        pats = tuple(self.forbidden.get("*", ()))
        if store is not None:
            pats += tuple(self.forbidden.get(store, ()))
        return pats

    @property
    def n_prng_sites(self) -> int:
        if self.min_prng_sites is not None:
            return self.min_prng_sites
        return len(self.streams)


REGISTRY: dict[str, EngineContract] = {}


def register(c: EngineContract) -> EngineContract:
    """Insert (or replace — re-imports under importlib.reload must not
    error) a contract in the global registry."""
    REGISTRY[c.name] = c
    return c


def contract(
    *,
    name: str,
    forbidden: Mapping[str, Sequence[tuple]] | None = None,
    streams: Sequence[tuple[str, Callable[[int], int]]] = (),
    shares_seed_with: Sequence[str] = (),
    caches: Sequence[str] = (),
    horizon: int = DEFAULT_HORIZON,
    min_prng_sites: int | None = None,
):
    """Declare an engine's static invariants at its definition site.

    Transparent: returns the decorated function unchanged, with the
    registered :class:`EngineContract` attached as
    ``fn.__statics_contract__`` for discovery.
    """
    c = EngineContract(
        name=name,
        forbidden={k: tuple(tuple(p) for p in v)
                   for k, v in (forbidden or {}).items()},
        streams=tuple(StreamDecl(n, f) for n, f in streams),
        shares_seed_with=tuple(shares_seed_with),
        caches=tuple(caches),
        horizon=horizon,
        min_prng_sites=min_prng_sites,
    )
    register(c)

    def deco(fn):
        fn.__statics_contract__ = c
        return fn

    return deco


def get(name: str) -> EngineContract:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no statics contract named {name!r}; registered: "
            f"{sorted(REGISTRY)}"
        ) from None


def all_contracts() -> list[EngineContract]:
    return [REGISTRY[k] for k in sorted(REGISTRY)]
