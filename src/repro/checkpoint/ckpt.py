"""Minimal dependency-free pytree checkpointing.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` holding the tree
structure (flattened key paths) and dtypes. Atomic via tmp-dir rename.
Host-gathered (fine at the scales this container executes; a sharded
ocdbt-style writer is out of scope and noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # numpy .npz cannot store bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
