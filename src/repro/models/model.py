"""Model assembly: decoder-only / enc-dec / hybrid stacks with scan-over-layers.

Param layout
------------
Homogeneous stacks (``cfg.scan_layers`` and a length-1 ``block_pattern``)
store one pytree of *stacked* leaves ``(L, ...)`` under ``params["blocks"]``
and run ``jax.lax.scan`` over the layer axis (one HLO block body regardless
of depth — required to compile 126-layer llama3-405b on the CPU dry-run).

Patterned stacks (e.g. recurrentgemma's (rglru, rglru, attn)) store one
stacked pytree per pattern position under ``params["groups"]`` (each
``(R, ...)`` with R = n_layers // P repeats) plus unrolled ``params["tail"]``
layers for the remainder.

Enc-dec (whisper) keeps explicit unrolled lists (12+12 layers).

Three entry points, matching the assigned input shapes:
  ``forward_train``  — full-sequence logits (train_4k)
  ``prefill``        — logits for the last position + KV/state caches
  ``decode_step``    — one token, cache-to-cache     (decode_32k, long_500k)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": L.init_attention,
    "swa": L.init_attention,
    "wkv6": L.init_wkv6,
    "rglru": L.init_rglru,
}


def _init_block(key, cfg: ArchConfig, kind: str, with_xattn: bool = False) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "norm1": L.init_norm(ks[0], cfg),
        "mixer": _MIXER_INIT[kind](ks[1], cfg),
    }
    if not cfg.parallel_block:
        p["norm2"] = L.init_norm(ks[2], cfg)
    if with_xattn:
        p["xattn"] = L.init_cross_attention(ks[3], cfg)
        p["norm_x"] = L.init_norm(ks[3], cfg)
    if cfg.ffn_kind == "moe":
        p["ffn"] = L.init_moe(ks[4], cfg)
    elif cfg.ffn_kind == "mlp":
        p["ffn"] = L.init_mlp(ks[4], cfg)
    else:
        p["ffn"] = L.init_rwkv_cm(ks[4], cfg)
    return p


def _apply_ffn(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.ffn_kind == "moe":
        y, aux = L.moe_block(p, x, cfg)
        return y, aux
    if cfg.ffn_kind == "mlp":
        return L.mlp_block(p, x, cfg), jnp.float32(0.0)
    return L.rwkv_cm_block(p, x, cfg), jnp.float32(0.0)


def _apply_mixer_train(p, x, cfg: ArchConfig, kind: str, positions):
    if kind == "attn":
        return L.attention_block(p, x, cfg, positions, window=0)
    if kind == "swa":
        return L.attention_block(p, x, cfg, positions, window=cfg.window)
    if kind == "wkv6":
        return L.wkv6_block(p, x, cfg)
    if kind == "rglru":
        return L.rglru_block(p, x, cfg)
    raise ValueError(kind)


def block_train(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, kind: str, positions,
    enc: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual block; returns (x, aux_loss)."""
    h = L.apply_norm(p["norm1"], x, cfg)
    mix = _apply_mixer_train(p["mixer"], h, cfg, kind, positions)
    if cfg.parallel_block:
        ffn_out, aux = _apply_ffn(p["ffn"], h, cfg)
        return x + mix + ffn_out, aux
    x = x + mix
    if "xattn" in p:
        hx = L.apply_norm(p["norm_x"], x, cfg)
        x = x + L.cross_attention_block(p["xattn"], hx, enc, cfg)
    h2 = L.apply_norm(p["norm2"], x, cfg)
    ffn_out, aux = _apply_ffn(p["ffn"], h2, cfg)
    return x + ffn_out, aux


# ---------------------------------------------------------------------------
# stack structure helpers
# ---------------------------------------------------------------------------

def _stack_plan(cfg: ArchConfig) -> tuple[int, int]:
    """(repeats, tail): n_layers = repeats * len(pattern) + tail."""
    P = len(cfg.block_pattern)
    return cfg.n_layers // P, cfg.n_layers % P


def init_params(key, cfg: ArchConfig) -> Params:
    ks = iter(jax.random.split(key, 1024))
    d, V = cfg.d_model, cfg.vocab
    params: Params = {
        "embed": L._dense_init(next(ks), (V, d), L._dt(cfg), scale=0.02),
        "final_norm": L.init_norm(next(ks), cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(next(ks), (d, V), L._dt(cfg))
    if cfg.family == "vlm":
        d_vis = 1024  # InternViT feature dim (stub frontend)
        params["vis_proj"] = {
            "w1": L._dense_init(next(ks), (d_vis, d), L._dt(cfg)),
            "w2": L._dense_init(next(ks), (d, d), L._dt(cfg)),
        }
    if cfg.encoder_layers:
        params["encoder"] = [
            _init_block(next(ks), cfg, "attn") for _ in range(cfg.encoder_layers)
        ]
        params["enc_norm"] = L.init_norm(next(ks), cfg)

    with_x = cfg.encoder_layers > 0
    P = len(cfg.block_pattern)
    R, tail = _stack_plan(cfg)
    if cfg.scan_layers and R > 1:
        groups = []
        for pos in range(P):
            kind = cfg.block_pattern[pos]
            stacked = [
                _init_block(next(ks), cfg, kind, with_x) for _ in range(R)
            ]
            groups.append(
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)
            )
        params["groups"] = groups
        params["tail"] = [
            _init_block(next(ks), cfg, cfg.block_pattern[i % P], with_x)
            for i in range(tail)
        ]
    else:
        params["layers"] = [
            _init_block(next(ks), cfg, cfg.mixer_of(i), with_x)
            for i in range(cfg.n_layers)
        ]
    return params


# ---------------------------------------------------------------------------
# encoder (whisper stub frontend -> transformer encoder)
# ---------------------------------------------------------------------------

def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """frames: (B, T_enc, d) precomputed conv-frontend output (stub)."""
    T = frames.shape[1]
    pos = jnp.arange(T)
    x = frames
    for blk in params["encoder"]:
        h = L.apply_norm(blk["norm1"], x, cfg)
        x = x + L.attention_block(blk["mixer"], h, cfg, pos, causal=False)
        h2 = L.apply_norm(blk["norm2"], x, cfg)
        y, _ = _apply_ffn(blk["ffn"], h2, cfg)
        x = x + y
    return L.apply_norm(params["enc_norm"], x, cfg)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(
    params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
    patch_embeds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    x = params["embed"][tokens]  # (B, S, d) gather
    if cfg.family == "vlm" and patch_embeds is not None:
        p = params["vis_proj"]
        vis = jax.nn.gelu((patch_embeds @ p["w1"]).astype(jnp.float32)).astype(
            x.dtype
        ) @ p["w2"]
        x = jnp.concatenate([vis, x], axis=1)  # patches prepended
    return x


def lm_logits(params: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c).astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def forward_train(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,                       # (B, S)
    patch_embeds: jnp.ndarray | None = None,   # vlm stub
    frames: jnp.ndarray | None = None,         # audio stub
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S_total, V), aux_loss)."""
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])
    enc = encode(params, frames, cfg) if cfg.encoder_layers else None
    aux_total = jnp.float32(0.0)

    P = len(cfg.block_pattern)

    if "groups" in params:
        def segment(x_aux, group_params, kind):
            R = jax.tree_util.tree_leaves(group_params)[0].shape[0]
            G = cfg.remat_group if cfg.remat and R % max(cfg.remat_group, 1) == 0 \
                else 1
            if G > 1:
                # grouped remat: save the residual stream every G layers only
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape((R // G, G) + a.shape[1:]), group_params
                )

                def inner(carry, blk):
                    xc, auxc = carry
                    xo, aux = block_train(blk, xc, cfg, kind, positions, enc)
                    return (xo, auxc + aux), None

                run_group = jax.checkpoint(
                    lambda c, g: jax.lax.scan(inner, c, g)[0]
                )

                def outer(carry, grp):
                    return run_group(carry, grp), None

                return jax.lax.scan(outer, x_aux, grouped)[0]

            def body(carry, blk):
                xc, auxc = carry
                if cfg.remat:
                    xo, aux = jax.checkpoint(
                        lambda b, xx: block_train(b, xx, cfg, kind, positions, enc)
                    )(blk, xc)
                else:
                    xo, aux = block_train(blk, xc, cfg, kind, positions, enc)
                return (xo, auxc + aux), None

            return jax.lax.scan(body, x_aux, group_params)[0]

        if P == 1:
            (x, aux_total) = segment((x, aux_total), params["groups"][0],
                                     cfg.block_pattern[0])
        else:
            # scan over repeats; each step applies the whole pattern
            def rep_body(carry, blks):
                xc, auxc = carry
                for pos in range(P):
                    fn = lambda b, xx, _pos=pos: block_train(
                        b, xx, cfg, cfg.block_pattern[_pos], positions, enc
                    )
                    if cfg.remat:
                        fn = jax.checkpoint(fn)
                    xc, aux = fn(blks[pos], xc)
                    auxc = auxc + aux
                return (xc, auxc), None

            (x, aux_total), _ = jax.lax.scan(
                rep_body, (x, aux_total), tuple(params["groups"])
            )
        for i, blk in enumerate(params["tail"]):
            kind = cfg.block_pattern[i % P]
            x, aux = block_train(blk, x, cfg, kind, positions, enc)
            aux_total = aux_total + aux
    else:
        for i, blk in enumerate(params["layers"]):
            fn = lambda b, xx, _i=i: block_train(
                b, xx, cfg, cfg.mixer_of(_i), positions, enc
            )
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, aux = fn(blk, x)
            aux_total = aux_total + aux

    return lm_logits(params, cfg, x), aux_total


def _ce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return (lse - gold).sum()


def loss_fn(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    patch_embeds=None,
    frames=None,
) -> jnp.ndarray:
    if cfg.ce_chunk <= 0:
        logits, aux = forward_train(params, cfg, tokens, patch_embeds, frames)
        if cfg.family == "vlm" and patch_embeds is not None:
            logits = logits[:, patch_embeds.shape[1]:]
        n_tok = logits.shape[0] * logits.shape[1]
        return _ce_from_logits(logits, labels) / n_tok + aux

    # --- streamed CE (EXPERIMENTS.md §Perf): compute the trunk once, then
    # per position-chunk project to vocab + CE under jax.checkpoint, so the
    # (T, vocab) logits never exist at once (backward recomputes per chunk).
    hidden, aux = forward_hidden(params, cfg, tokens, patch_embeds, frames)
    if cfg.family == "vlm" and patch_embeds is not None:
        hidden = hidden[:, patch_embeds.shape[1]:]
    B, S, _ = hidden.shape
    C = cfg.ce_chunk
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // C
    hc = hidden.reshape(B, nc, C, -1).swapaxes(0, 1)     # (nc, B, C, d)
    lc = labels.reshape(B, nc, C).swapaxes(0, 1)
    valid = (jnp.arange(hidden.shape[1]) < S).reshape(nc, 1, C)

    @jax.checkpoint
    def chunk_ce(h, l, v):
        logits = lm_logits(params, cfg, h)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), l[..., None], axis=-1
        )[..., 0]
        return ((lse - gold) * v).sum()

    def body(acc, xs):
        h, l, v = xs
        return acc + chunk_ce(h, l, v), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0), (hc, lc, valid.astype(jnp.float32))
    )
    return total / (B * S) + aux


def forward_hidden(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    patch_embeds: jnp.ndarray | None = None,
    frames: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The decoder trunk without the LM head: (pre-head hidden, aux)."""
    import dataclasses as _dc

    # run forward_train with an identity head by slicing it out is wasteful;
    # instead replicate its body up to (but excluding) lm_logits.
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])
    enc = encode(params, frames, cfg) if cfg.encoder_layers else None
    aux_total = jnp.float32(0.0)
    P = len(cfg.block_pattern)

    if "groups" in params:
        # identical control flow to forward_train (kept in sync)
        def segment(x_aux, group_params, kind):
            R = jax.tree_util.tree_leaves(group_params)[0].shape[0]
            G = cfg.remat_group if cfg.remat and R % max(cfg.remat_group, 1) == 0 \
                else 1
            if G > 1:
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape((R // G, G) + a.shape[1:]), group_params
                )

                def inner(carry, blk):
                    xc, auxc = carry
                    xo, aux = block_train(blk, xc, cfg, kind, positions, enc)
                    return (xo, auxc + aux), None

                run_group = jax.checkpoint(
                    lambda c, g: jax.lax.scan(inner, c, g)[0]
                )
                return jax.lax.scan(
                    lambda c, grp: (run_group(c, grp), None), x_aux, grouped
                )[0]

            def body(carry, blk):
                xc, auxc = carry
                if cfg.remat:
                    xo, aux = jax.checkpoint(
                        lambda b, xx: block_train(b, xx, cfg, kind, positions,
                                                  enc)
                    )(blk, xc)
                else:
                    xo, aux = block_train(blk, xc, cfg, kind, positions, enc)
                return (xo, auxc + aux), None

            return jax.lax.scan(body, x_aux, group_params)[0]

        if P == 1:
            (x, aux_total) = segment((x, aux_total), params["groups"][0],
                                     cfg.block_pattern[0])
        else:
            def rep_body(carry, blks):
                xc, auxc = carry
                for pos in range(P):
                    fn = lambda b, xx, _pos=pos: block_train(
                        b, xx, cfg, cfg.block_pattern[_pos], positions, enc
                    )
                    if cfg.remat:
                        fn = jax.checkpoint(fn)
                    xc, aux = fn(blks[pos], xc)
                    auxc = auxc + aux
                return (xc, auxc), None

            (x, aux_total), _ = jax.lax.scan(
                rep_body, (x, aux_total), tuple(params["groups"])
            )
        for i, blk in enumerate(params["tail"]):
            kind = cfg.block_pattern[i % P]
            x, aux = block_train(blk, x, cfg, kind, positions, enc)
            aux_total = aux_total + aux
    else:
        for i, blk in enumerate(params["layers"]):
            fn = lambda b, xx, _i=i: block_train(
                b, xx, cfg, cfg.mixer_of(_i), positions, enc
            )
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, aux = fn(blk, x)
            aux_total = aux_total + aux
    return x, aux_total


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------

def _cache_spec(cfg: ArchConfig, kind: str, B: int, cache_len: int) -> Params:
    if kind in ("attn", "swa"):
        wlen = min(cache_len, cfg.window) if (kind == "swa" and cfg.window) else cache_len
        mixer = L.init_attn_cache(cfg, B, wlen)
    elif kind == "wkv6":
        mixer = L.init_wkv6_cache(cfg, B)
    elif kind == "rglru":
        mixer = L.init_rglru_cache(cfg, B)
    else:
        raise ValueError(kind)
    c: Params = {"mixer": mixer}
    if cfg.ffn_kind == "rwkv_cm":
        # channel-mix token-shift state (previous post-norm2 activation)
        c["cm_prev"] = jnp.zeros((B, cfg.d_model), L._dt(cfg))
    return c


def init_cache(
    params: Params, cfg: ArchConfig, B: int, cache_len: int,
    enc: jnp.ndarray | None = None,
) -> Params:
    """Build an all-zeros cache pytree (pos=cache_len-ready for decode tests,
    callers set pos explicitly)."""
    P = len(cfg.block_pattern)
    cache: Params = {}
    if "groups" in params:
        R, tail = _stack_plan(cfg)
        cache["groups"] = [
            jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape),
                _cache_spec(cfg, cfg.block_pattern[pos], B, cache_len),
            )
            for pos in range(P)
        ]
        cache["tail"] = [
            _cache_spec(cfg, cfg.block_pattern[i % P], B, cache_len)
            for i in range(tail)
        ]
    else:
        cache["layers"] = [
            _cache_spec(cfg, cfg.mixer_of(i), B, cache_len)
            for i in range(cfg.n_layers)
        ]
    if enc is not None:
        cache["enc"] = enc
    return cache


def _mixer_decode(p, x, cfg: ArchConfig, kind: str, cache):
    if kind == "attn":
        return L.attention_decode(p, x, cfg, cache, window=0)
    if kind == "swa":
        return L.attention_decode(p, x, cfg, cache, window=cfg.window)
    if kind == "wkv6":
        return L.wkv6_decode(p, x, cfg, cache)
    if kind == "rglru":
        return L.rglru_decode(p, x, cfg, cache)
    raise ValueError(kind)


def block_decode(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, kind: str, cache: Params,
    enc: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, Params]:
    h = L.apply_norm(p["norm1"], x, cfg)
    mix, mixer_cache = _mixer_decode(p["mixer"], h, cfg, kind, cache["mixer"])
    new_cache = dict(cache)
    new_cache["mixer"] = mixer_cache
    if cfg.parallel_block:
        ffn_out, _ = _apply_ffn(p["ffn"], h, cfg)
        return x + mix + ffn_out, new_cache
    x = x + mix
    if "xattn" in p and enc is not None:
        hx = L.apply_norm(p["norm_x"], x, cfg)
        x = x + L.cross_attention_block(p["xattn"], hx, enc, cfg)
    h2 = L.apply_norm(p["norm2"], x, cfg)
    if cfg.ffn_kind == "rwkv_cm":
        ffn_out = L.rwkv_cm_block(
            p["ffn"], h2, cfg, x_prev=cache["cm_prev"][:, None]
        )
        new_cache["cm_prev"] = h2[:, 0]
    else:
        ffn_out, _ = _apply_ffn(p["ffn"], h2, cfg)
    return x + ffn_out, new_cache


def decode_step(
    params: Params, cfg: ArchConfig, cache: Params, token: jnp.ndarray,
) -> tuple[jnp.ndarray, Params]:
    """token: (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    x = params["embed"][token]
    enc = cache.get("enc")
    P = len(cfg.block_pattern)

    if "groups" in params:
        # repeat-major: each scan step applies one full pattern repeat, so
        # layer order matches forward_train exactly.
        def rep_body(xc, blks_caches):
            blks, caches = blks_caches
            new_caches = []
            for pos in range(P):
                xc, c_new = block_decode(
                    blks[pos], xc, cfg, cfg.block_pattern[pos], caches[pos], enc
                )
                new_caches.append(c_new)
            return xc, tuple(new_caches)

        x, new_group_caches = jax.lax.scan(
            rep_body, x, (tuple(params["groups"]), tuple(cache["groups"]))
        )
        cache["groups"] = list(new_group_caches)
        for i, blk in enumerate(params["tail"]):
            kind = cfg.block_pattern[i % P]
            x, cache["tail"][i] = block_decode(
                blk, x, cfg, kind, cache["tail"][i], enc
            )
    else:
        for i, blk in enumerate(params["layers"]):
            x, cache["layers"][i] = block_decode(
                blk, x, cfg, cfg.mixer_of(i), cache["layers"][i], enc
            )
    return lm_logits(params, cfg, x), cache


def prefill(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,                      # (B, S)
    patch_embeds: jnp.ndarray | None = None,
    frames: jnp.ndarray | None = None,
    cache_len: int | None = None,             # KV capacity; default = S (ring)
) -> tuple[jnp.ndarray, Params]:
    """Full-sequence prefill; returns (last-position logits, primed cache).

    Implementation: run the train forward for the hidden states (the flash
    path keeps memory bounded) and prime caches by projecting K/V per layer.
    Recurrent mixers (wkv6 / rglru) recompute their final state with the
    chunked scan. This trades a second mixer projection pass for a much
    simpler cache plumbing — acceptable because prefill is compute-bound.
    """
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    B, S, d = x.shape
    enc = encode(params, frames, cfg) if cfg.encoder_layers else None
    positions = jnp.arange(S)
    cache = init_cache(params, cfg, B, cache_len or S, enc)

    P = len(cfg.block_pattern)

    def prime_and_apply(blk, xc, kind, c):
        """One block forward that also fills this block's cache."""
        h = L.apply_norm(blk["norm1"], xc, cfg)
        if kind in ("attn", "swa"):
            q, k, v = L._qk_project(blk["mixer"], h, cfg, positions)
            wlen = c["mixer"]["k"].shape[2]
            k_c = jnp.swapaxes(k[:, -wlen:], 1, 2).astype(c["mixer"]["k"].dtype)
            v_c = jnp.swapaxes(v[:, -wlen:], 1, 2).astype(c["mixer"]["v"].dtype)
            pad = wlen - k_c.shape[2]
            if pad > 0:
                k_c = jnp.pad(k_c, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v_c = jnp.pad(v_c, ((0, 0), (0, 0), (0, pad), (0, 0)))
            elif S >= wlen and S % wlen:
                # ring-align: token t lives at slot t % wlen for decode
                k_c = jnp.roll(k_c, S % wlen, axis=2)
                v_c = jnp.roll(v_c, S % wlen, axis=2)
            c_new = {
                "k": k_c, "v": v_c,
                "pos": jnp.full((B,), S, jnp.int32),
            }
            window = cfg.window if kind == "swa" else 0
            impl = cfg.attn_impl
            if impl == "auto":
                impl = "chunked" if S >= 2048 else "naive"
            attn_fn = (
                L._chunked_attention if impl == "chunked" else L._naive_attention
            )
            out = attn_fn(q, k, v, causal=True, window=window)
            mix = out.reshape(B, S, -1) @ blk["mixer"]["wo"]
        elif kind == "wkv6":
            mixp = blk["mixer"]
            x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            r, k_, v, g, lw = L._wkv6_inputs(mixp, h, x_prev, cfg)
            hd = cfg.wkv_head_dim
            H = d // hd
            resh = lambda a: a.reshape(B, S, H, hd).transpose(0, 2, 1, 3).reshape(
                B * H, S, hd
            )
            from repro.kernels.wkv6.ops import wkv6 as _wkv
            u = jnp.broadcast_to(mixp["u"][None], (B, H, hd)).reshape(B * H, hd)
            y, s_fin = _wkv(resh(r), resh(k_), resh(v), resh(lw), u,
                            backend="pallas" if cfg.use_pallas else "xla")
            y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, d)
            y = L._wkv_groupnorm(y, mixp["ln_x"], H)
            y = y * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
            mix = y @ mixp["wo"]
            c_new = {
                "state": s_fin.reshape(B, H, hd, hd),
                "x_prev": h[:, -1],
            }
        elif kind == "rglru":
            mixp = blk["mixer"]
            xw = h @ mixp["w_in"]
            padded = jnp.pad(xw, ((0, 0), (3, 0), (0, 0)))
            conv = sum(
                padded[:, 3 - i : padded.shape[1] - i]
                * mixp["conv_w"][3 - i][None, None]
                for i in range(4)
            ) + mixp["conv_b"]
            hh, _, _ = L._rglru_core(mixp, conv)
            gate = jax.nn.gelu((h @ mixp["w_gate_branch"]).astype(jnp.float32))
            mix = (hh * gate).astype(h.dtype) @ mixp["w_out"]
            c_new = {
                "h": hh[:, -1],
                "conv": xw[:, -3:].astype(c["mixer"]["conv"].dtype),
            }
        else:
            raise ValueError(kind)

        out_cache: Params = {"mixer": c_new}
        if cfg.parallel_block:
            ffn_out, _ = _apply_ffn(blk["ffn"], h, cfg)
            return xc + mix + ffn_out, out_cache
        xc = xc + mix
        if "xattn" in blk and enc is not None:
            hx = L.apply_norm(blk["norm_x"], xc, cfg)
            xc = xc + L.cross_attention_block(blk["xattn"], hx, enc, cfg)
        h2 = L.apply_norm(blk["norm2"], xc, cfg)
        ffn_out, _ = _apply_ffn(blk["ffn"], h2, cfg)
        if cfg.ffn_kind == "rwkv_cm":
            out_cache["cm_prev"] = h2[:, -1]
        return xc + ffn_out, out_cache

    if "groups" in params:
        def rep_body(xc, blks_caches):
            blks, caches = blks_caches
            new_caches = []
            for pos in range(P):
                xc, c_new = prime_and_apply(
                    blks[pos], xc, cfg.block_pattern[pos], caches[pos]
                )
                new_caches.append(c_new)
            return xc, tuple(new_caches)

        x, new_group_caches = jax.lax.scan(
            rep_body, x, (tuple(params["groups"]), tuple(cache["groups"]))
        )
        cache["groups"] = list(new_group_caches)
        for i, blk in enumerate(params["tail"]):
            kind = cfg.block_pattern[i % P]
            x, cache["tail"][i] = prime_and_apply(
                blk, x, kind, cache["tail"][i]
            )
    else:
        for i, blk in enumerate(params["layers"]):
            x, cache["layers"][i] = prime_and_apply(
                blk, x, cfg.mixer_of(i), cache["layers"][i]
            )

    logits = lm_logits(params, cfg, x[:, -1:])
    return logits, cache
