"""Model building blocks, pure JAX (pjit/GSPMD-friendly).

Every block is a pair of functions: ``init_<block>(key, cfg) -> params`` and
``<block>(params, x, ...) -> y``. Params are nested dicts of jnp arrays so
they stack cleanly for lax.scan over layers and map 1:1 onto PartitionSpecs
in ``repro.distributed.sharding``.

Numerics: matmul weights are stored in ``cfg.dtype`` (bf16 on TPU); all
norm/softmax/recurrence accumulations are float32.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch import compat

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers / norms / activations
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:  # (E, d, f) expert weights: fan-in is the middle dim
        fan_in = shape[1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, cfg: ArchConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), _dt(cfg))}
    return {"scale": jnp.ones((d,), _dt(cfg)), "bias": jnp.zeros((d,), _dt(cfg))}


def apply_norm(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # (B,S,dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, optional sliding window)
# ---------------------------------------------------------------------------

def _n_heads_eff(cfg: ArchConfig) -> int:
    """Query head count incl. TP zero-padding (pad_heads_to).

    Padded heads carry zero wq columns and zero wo rows: their attention
    output is multiplied by zeros, so the math is EXACTLY the unpadded
    model — but every tensor dim is now divisible by the model axis
    (EXPERIMENTS.md §Perf, minitron prefill iteration)."""
    return max(cfg.n_heads, cfg.pad_heads_to or 0)


def init_attention(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, hd, Hkv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    H = cfg.n_heads
    Hp = _n_heads_eff(cfg)
    wq = _dense_init(ks[0], (d, H * hd), _dt(cfg))
    wo = _dense_init(ks[3], (H * hd, d), _dt(cfg))
    if Hp > H:
        wq = jnp.concatenate(
            [wq, jnp.zeros((d, (Hp - H) * hd), wq.dtype)], axis=1
        )
        wo = jnp.concatenate(
            [wo, jnp.zeros(((Hp - H) * hd, d), wo.dtype)], axis=0
        )
    p = {
        "wq": wq,
        "wk": _dense_init(ks[1], (d, Hkv * hd), _dt(cfg)),
        "wv": _dense_init(ks[2], (d, Hkv * hd), _dt(cfg)),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), _dt(cfg))
        p["k_norm"] = jnp.zeros((hd,), _dt(cfg))
    return p


def _qk_project(p: Params, x: jnp.ndarray, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    H = _n_heads_eff(cfg)
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _naive_attention(q, k, v, *, causal: bool, window: int, q_offset: int = 0):
    """q: (B,S,H,dh); k/v: (B,T,Hkv,dh). Materializes (B,H,S,T) scores."""
    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32) * dh**-0.5
    qg = qf.reshape(B, S, Hkv, G, dh)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32))
    qi = jnp.arange(S)[:, None] + q_offset
    ki = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, window: int,
                       q_chunk: int = 512, kv_chunk: int = 1024):
    """Flash-style two-level scan: O(S * kv_chunk) live scores per head.

    This is the memory-roofline-friendly lowering used for the 32k/500k
    shapes; it never materializes an (S, T) score matrix.
    """
    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    if S % q_chunk or T % kv_chunk:
        return _naive_attention(q, k, v, causal=causal, window=window)
    nq, nk = S // q_chunk, T // kv_chunk

    qf = (q.astype(jnp.float32) * dh**-0.5).reshape(B, nq, q_chunk, Hkv, G, dh)
    kf = k.astype(jnp.float32).reshape(B, nk, kv_chunk, Hkv, dh)
    vf = v.astype(jnp.float32).reshape(B, nk, kv_chunk, Hkv, dh)

    def q_block(qi, qb):  # qb: (B, q_chunk, Hkv, G, dh)
        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kb, vb = inputs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb)  # (B,Hkv,G,qc,kc)
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, dh), jnp.float32)
        ks_idx = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks_idx, jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,Hkv,G,qc,dh)
        return jnp.moveaxis(out, 3, 1)                    # (B,qc,Hkv,G,dh)

    outs = jax.lax.map(lambda i: q_block(i, qf[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dh)
    return out.astype(q.dtype)


def attention_block(
    p: Params,
    x: jnp.ndarray,          # (B, S, d) pre-normed input
    cfg: ArchConfig,
    positions: jnp.ndarray,
    *,
    window: int = 0,
    causal: bool = True,
) -> jnp.ndarray:
    q, k, v = _qk_project(p, x, cfg, positions)
    S = x.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if S >= 2048 else "naive"
    fn = _chunked_attention if impl == "chunked" else _naive_attention
    out = fn(q, k, v, causal=causal, window=window)
    B, S_, H, dh = out.shape
    return out.reshape(B, S_, H * dh) @ p["wo"]


def attention_decode(
    p: Params,
    x: jnp.ndarray,            # (B, 1, d)
    cfg: ArchConfig,
    cache: Params,             # {"k": (B,Hkv,Wc,dh), "v": ..., "pos": (B,)}
    *,
    window: int = 0,
) -> tuple[jnp.ndarray, Params]:
    """Single-token decode against a (ring-buffer when windowed) KV cache."""
    B = x.shape[0]
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    H = _n_heads_eff(cfg)
    pos = cache["pos"]  # (B,) int32 — absolute position of the new token
    q, k, v = _qk_project(p, x, cfg, pos[:, None])
    Wc = cache["k"].shape[2]
    slot = pos % Wc  # ring buffer; equals append while pos < Wc
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, :, slot].set(
        jnp.swapaxes(k, 1, 2)[:, :, 0].astype(cache["k"].dtype)
    )
    v_cache = cache["v"].at[bidx, :, slot].set(
        jnp.swapaxes(v, 1, 2)[:, :, 0].astype(cache["v"].dtype)
    )
    lengths = jnp.minimum(pos + 1, Wc).astype(jnp.int32)

    if cfg.use_pallas:
        from repro.kernels.swa.ops import attn_decode as _decode
        out = _decode(q[:, 0].transpose(0, 1, 2), k_cache, v_cache, lengths)
    else:
        from repro.kernels.swa.ref import attn_decode_ref
        out = attn_decode_ref(q[:, 0], k_cache, v_cache, lengths)
    y = out.reshape(B, 1, H * hd).astype(x.dtype) @ p["wo"]
    return y, {"k": k_cache, "v": v_cache, "pos": pos + 1}


def init_attn_cache(cfg: ArchConfig, B: int, cache_len: int) -> Params:
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((B, Hkv, cache_len, hd), _dt(cfg)),
        "v": jnp.zeros((B, Hkv, cache_len, hd), _dt(cfg)),
        "pos": jnp.zeros((B,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (d, f), _dt(cfg)),
            "w_up": _dense_init(ks[1], (d, f), _dt(cfg)),
            "w_down": _dense_init(ks[2], (f, d), _dt(cfg)),
        }
    return {
        "w_up": _dense_init(ks[0], (d, f), _dt(cfg)),
        "w_down": _dense_init(ks[1], (f, d), _dt(cfg)),
    }


def mlp_block(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if "w_gate" in p:
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype) * (
            x @ p["w_up"]
        )
    else:
        h = jax.nn.gelu((x @ p["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-bounded local dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": _dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "w_gate": _dense_init(ks[1], (E, d, f), _dt(cfg)),
        "w_up": _dense_init(ks[2], (E, d, f), _dt(cfg)),
        "w_down": _dense_init(ks[3], (E, f, d), _dt(cfg)),
    }


def moe_block(
    p: Params, x: jnp.ndarray, cfg: ArchConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatches between the GSPMD one-shot dispatch and the shard_map
    expert-parallel implementation (EXPERIMENTS.md §Perf iteration 1)."""
    if cfg.moe_impl == "sharded":
        mesh = compat.get_abstract_mesh()
        if mesh is not None and "model" in (mesh.axis_names or ()):
            return _moe_block_sharded(p, x, cfg, mesh)
    return _moe_block_gspmd(p, x, cfg)


def _moe_block_gspmd(
    p: Params, x: jnp.ndarray, cfg: ArchConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k MoE with per-expert capacity; returns (y, aux_loss).

    Dispatch is scatter/gather by slot index (no (S, E, C) one-hot tensor):
    per expert, tokens take slots in arrival order; beyond-capacity
    assignments are dropped (their gate mass is lost, standard behaviour).
    Under expert-parallel sharding the expert axis of the einsums is sharded
    on "model"; activations stay on ("pod","data").
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                 # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux (Switch): E * sum_e f_e * P_e ---
    ones_frac = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(ones_frac * probs.mean(axis=0)) * cfg.router_aux_coef

    # --- slot assignment: rank of each (token, choice) within its expert ---
    cap = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    flat_ids = ids.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (T*k, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot).sum(
        axis=1, where=onehot.astype(bool)
    )
    slot = flat_ids * cap + ranks                         # (T*k,)
    valid = ranks < cap
    slot = jnp.where(valid, slot, E * cap)                # overflow -> dropped

    # --- dispatch: (E*cap, d) buffer ---
    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].add(
        xt[tok_idx], mode="drop"
    )
    h = buf[: E * cap].reshape(E, cap, d)

    # --- expert FFN (einsum over expert axis -> expert parallel) ---
    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]).astype(jnp.float32)).astype(
        x.dtype
    )
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    y_e = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])  # (E, cap, d)

    # --- combine ---
    y_flat = jnp.concatenate(
        [y_e.reshape(E * cap, d), jnp.zeros((1, d), x.dtype)], axis=0
    )
    per_assign = y_flat[slot] * gates.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok_idx].add(per_assign)
    return y.reshape(B, S, d), aux


def _moe_local_dispatch(xt, router_w, w_gate, w_up, w_down, cfg: ArchConfig,
                        E_total: int, e_offset: jnp.ndarray):
    """Per-device expert compute: route T_loc local tokens over ALL experts,
    keep the assignments owned by this shard's E_loc experts, scatter into a
    capacity-padded buffer, run the expert FFN, combine partial output.

    Requires activations replicated across the model axis (megatron layout
    after the attention psum), so dispatch needs NO cross-device traffic;
    the only collective is the output psum — the paper-facing win recorded
    in EXPERIMENTS.md §Perf (vs the GSPMD dispatch whose scatter/gather
    forced whole-batch replication)."""
    T, d = xt.shape
    E_loc, _, f = w_gate.shape
    k = cfg.top_k

    logits = (xt.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    ones_frac = jnp.zeros((E_total,), jnp.float32).at[ids.reshape(-1)].add(
        1.0
    ) / (T * k)
    aux = E_total * jnp.sum(ones_frac * probs.mean(axis=0)) \
        * cfg.router_aux_coef

    cap = max(1, int(math.ceil(T * k / E_total * cfg.capacity_factor)))
    flat_ids = ids.reshape(-1)                       # (T*k,) global expert id
    local_ids = flat_ids - e_offset                  # id within this shard
    mine = (local_ids >= 0) & (local_ids < E_loc)
    onehot = jax.nn.one_hot(
        jnp.where(mine, local_ids, E_loc), E_loc + 1, dtype=jnp.int32
    )[:, :E_loc]
    ranks = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(axis=1)
    slot = jnp.where(mine & (ranks < cap), local_ids * cap + ranks,
                     E_loc * cap)
    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E_loc * cap + 1, d), xt.dtype).at[slot].add(
        xt[tok_idx], mode="drop"
    )
    h = buf[: E_loc * cap].reshape(E_loc, cap, d)

    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", h, w_gate).astype(jnp.float32)).astype(
        xt.dtype
    )
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    y_e = jnp.einsum("ecf,efd->ecd", g * u, w_down)

    y_flat = jnp.concatenate(
        [y_e.reshape(E_loc * cap, d), jnp.zeros((1, d), xt.dtype)], axis=0
    )
    per_assign = y_flat[slot] * gates.reshape(-1)[:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[tok_idx].add(per_assign)
    return y, aux


def _moe_block_sharded(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, mesh
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map expert parallelism: tokens manual over (pod, data), experts
    manual over model, activations replicated across model going in, partial
    outputs psum'd across model coming out."""
    from jax.sharding import PartitionSpec as P

    axis_names = mesh.axis_names
    baxes = tuple(a for a in ("pod", "data") if a in axis_names)
    manual = frozenset(baxes + ("model",))
    E = cfg.n_experts
    B, S, d = x.shape

    def body(xb, router_w, w_gate, w_up, w_down):
        T_loc = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(T_loc, d)
        e_offset = jax.lax.axis_index("model") * w_gate.shape[0]
        y, aux = _moe_local_dispatch(
            xt, router_w, w_gate, w_up, w_down, cfg, E, e_offset
        )
        y = jax.lax.psum(y, "model")
        aux = jax.lax.psum(aux, "model") / compat.axis_size("model")
        if baxes:
            aux = jax.lax.pmean(aux, baxes)
        return y.reshape(xb.shape), aux

    bspec = P(baxes if baxes else None, None, None)
    out = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(bspec, P()),
        axis_names=manual,
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out


# ---------------------------------------------------------------------------
# RWKV6 time-mix + channel-mix
# ---------------------------------------------------------------------------

def init_wkv6(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    hd = cfg.wkv_head_dim
    H = d // hd
    lora = 64
    return {
        "mu": 0.5 * jnp.ones((5, d), _dt(cfg)),  # token-shift lerp r,k,v,g,w
        "wr": _dense_init(ks[0], (d, d), _dt(cfg)),
        "wk": _dense_init(ks[1], (d, d), _dt(cfg)),
        "wv": _dense_init(ks[2], (d, d), _dt(cfg)),
        "wg": _dense_init(ks[3], (d, d), _dt(cfg)),
        "w0": jnp.zeros((d,), jnp.float32) - 0.5,       # base log-log decay
        "w_lora_a": _dense_init(ks[4], (d, lora), _dt(cfg)),
        "w_lora_b": _dense_init(ks[5], (lora, d), _dt(cfg), scale=0.01),
        "u": _dense_init(ks[6], (H, hd), jnp.float32, scale=0.5),
        "ln_x": jnp.ones((d,), jnp.float32),            # per-head groupnorm
        "wo": _dense_init(ks[7], (d, d), _dt(cfg)),
    }


def _wkv6_inputs(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray, cfg: ArchConfig):
    """Token-shift + projections; x_prev is x shifted right by one token."""
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mix = lambda i: (xf + mu[i] * (xpf - xf)).astype(x.dtype)
    r = mix(0) @ p["wr"]
    k_ = mix(1) @ p["wk"]
    v = mix(2) @ p["wv"]
    g = mix(3) @ p["wg"]
    ww = jnp.tanh((mix(4) @ p["w_lora_a"]).astype(jnp.float32)) @ p[
        "w_lora_b"
    ].astype(jnp.float32)
    lw = -jnp.exp(jnp.clip(p["w0"] + ww, -8.0, 4.0))     # (B,S,d) log-decay <= 0
    return r, k_, v, g, lw


def _wkv_groupnorm(y: jnp.ndarray, scale: jnp.ndarray, H: int) -> jnp.ndarray:
    B, S, d = y.shape
    hd = d // H
    yh = y.reshape(B, S, H, hd).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yn = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (yn.reshape(B, S, d) * scale).astype(y.dtype)


def wkv6_block(
    p: Params, x: jnp.ndarray, cfg: ArchConfig
) -> jnp.ndarray:
    """Training/prefill path (full sequence)."""
    B, S, d = x.shape
    hd = cfg.wkv_head_dim
    H = d // hd
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k_, v, g, lw = _wkv6_inputs(p, x, x_prev, cfg)

    resh = lambda a: a.reshape(B, S, H, hd).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    rr, kk, vv = resh(r), resh(k_), resh(v)
    lww = lw.reshape(B, S, H, hd).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    u = jnp.broadcast_to(p["u"][None], (B, H, hd)).reshape(B * H, hd)

    from repro.kernels.wkv6.ops import wkv6 as _wkv
    y, _ = _wkv(rr, kk, vv, lww, u,
                backend="pallas" if cfg.use_pallas else "xla")
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, d)
    y = _wkv_groupnorm(y, p["ln_x"], H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return y @ p["wo"]


def wkv6_decode(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, cache: Params
) -> tuple[jnp.ndarray, Params]:
    """Single-token decode. cache: {"state": (B,H,hd,hd), "x_prev": (B,d)}."""
    B = x.shape[0]
    d = cfg.d_model
    hd = cfg.wkv_head_dim
    H = d // hd
    x_prev = cache["x_prev"][:, None, :]
    r, k_, v, g, lw = _wkv6_inputs(p, x, x_prev, cfg)
    resh = lambda a: a.reshape(B, H, hd).reshape(B * H, hd)
    from repro.kernels.wkv6.ops import wkv6_decode_step
    u = jnp.broadcast_to(p["u"][None], (B, H, hd)).reshape(B * H, hd)
    y, s_new = wkv6_decode_step(
        resh(r[:, 0]), resh(k_[:, 0]), resh(v[:, 0]), resh(lw[:, 0]), u,
        cache["state"].reshape(B * H, hd, hd),
    )
    y = y.reshape(B, 1, d)
    y = _wkv_groupnorm(y, p["ln_x"], H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return y @ p["wo"], {
        "state": s_new.reshape(B, H, hd, hd),
        "x_prev": x[:, 0],
    }


def init_wkv6_cache(cfg: ArchConfig, B: int) -> Params:
    d = cfg.d_model
    hd = cfg.wkv_head_dim
    H = d // hd
    return {
        "state": jnp.zeros((B, H, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((B, d), _dt(cfg)),
    }


def init_rwkv_cm(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": 0.5 * jnp.ones((2, d), _dt(cfg)),
        "wk": _dense_init(ks[0], (d, f), _dt(cfg)),
        "wv": _dense_init(ks[1], (f, d), _dt(cfg)),
        "wr": _dense_init(ks[2], (d, d), _dt(cfg)),
    }


def rwkv_cm_block(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, x_prev: jnp.ndarray | None = None
) -> jnp.ndarray:
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mix = lambda i: (xf + mu[i] * (xpf - xf)).astype(x.dtype)
    kk = jnp.square(jax.nn.relu((mix(0) @ p["wk"]).astype(jnp.float32))).astype(
        x.dtype
    )
    r = jax.nn.sigmoid((mix(1) @ p["wr"]).astype(jnp.float32)).astype(x.dtype)
    return r * (kk @ p["wv"])


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 7)
    d, w = cfg.d_model, cfg.rnn_width
    return {
        "w_in": _dense_init(ks[0], (d, w), _dt(cfg)),
        "w_gate_branch": _dense_init(ks[1], (d, w), _dt(cfg)),
        "conv_w": _dense_init(ks[2], (4, w), _dt(cfg), scale=0.5),
        "conv_b": jnp.zeros((w,), _dt(cfg)),
        "wa": _dense_init(ks[3], (w, w), _dt(cfg), scale=0.02),
        "wx": _dense_init(ks[4], (w, w), _dt(cfg), scale=0.02),
        "lam": jnp.full((w,), 4.0, jnp.float32),   # softplus^-1 of decay param
        "w_out": _dense_init(ks[5], (w, d), _dt(cfg)),
    }


def _rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray | None = None):
    """h_t = a_t * h_{t-1} + b_t along axis 1 via associative scan. f32."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    op = lambda x, y: (x[0] * y[0], y[0] * x[1] + y[1])
    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def _rglru_core(p: Params, xw: jnp.ndarray, h0=None):
    """xw: (B, S, w) post-conv activations -> (h, h_last). float32 path."""
    c = 8.0
    xf = xw.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32))
    log_a = -c * r * jax.nn.softplus(p["lam"])           # (B,S,w) <= 0
    a = jnp.exp(log_a)
    gated = i * xf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    h = _rglru_scan(a, b, h0)
    return h, a, b


def rglru_block(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Training/prefill path."""
    xw = x @ p["w_in"]
    # temporal conv, width 4, causal
    pad = jnp.pad(xw, ((0, 0), (3, 0), (0, 0)))
    conv = sum(
        pad[:, 3 - i : pad.shape[1] - i] * p["conv_w"][3 - i][None, None]
        for i in range(4)
    ) + p["conv_b"]
    h, _, _ = _rglru_core(p, conv)
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return y @ p["w_out"]


def rglru_decode(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, cache: Params
) -> tuple[jnp.ndarray, Params]:
    """cache: {"h": (B,w) f32, "conv": (B,3,w)} — O(1) state decode."""
    xw = x @ p["w_in"]                                   # (B,1,w)
    hist = jnp.concatenate([cache["conv"], xw.astype(cache["conv"].dtype)], axis=1)
    conv = (
        jnp.einsum("btw,tw->bw", hist.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )[:, None, :]
    h, a, b = _rglru_core(p, conv, h0=cache["h"])
    h = h[:, 0]
    gate = jax.nn.gelu((x[:, 0] @ p["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype) @ p["w_out"]
    return y[:, None, :], {"h": h, "conv": hist[:, 1:]}


def init_rglru_cache(cfg: ArchConfig, B: int) -> Params:
    w = cfg.rnn_width
    return {"h": jnp.zeros((B, w), jnp.float32), "conv": jnp.zeros((B, 3, w), _dt(cfg))}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, hd, H = cfg.d_model, cfg.head_dim, cfg.n_heads
    return {
        "wq": _dense_init(ks[0], (d, H * hd), _dt(cfg)),
        "wk": _dense_init(ks[1], (d, H * hd), _dt(cfg)),
        "wv": _dense_init(ks[2], (d, H * hd), _dt(cfg)),
        "wo": _dense_init(ks[3], (H * hd, d), _dt(cfg)),
    }


def cross_attention_block(
    p: Params, x: jnp.ndarray, enc: jnp.ndarray, cfg: ArchConfig
) -> jnp.ndarray:
    """x: (B,S,d) queries; enc: (B,T,d) encoder output (keys/values)."""
    B, S, d = x.shape
    T = enc.shape[1]
    hd, H = cfg.head_dim, cfg.n_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc @ p["wk"]).reshape(B, T, H, hd)
    v = (enc @ p["wv"]).reshape(B, T, H, hd)
    out = _naive_attention(q, k, v, causal=False, window=0)
    return out.reshape(B, S, H * hd) @ p["wo"]
