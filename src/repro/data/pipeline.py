"""Deterministic synthetic LM data pipeline.

Offline container => no corpora; we synthesize token streams with a fixed
per-(step, shard) PRNG so runs are exactly reproducible and shardable: the
global batch is generated shard-locally (each data-parallel worker draws its
own slice — no host-to-device scatter of a giant array).

Two flavours:
* ``iid``      — uniform tokens (throughput benchmarking).
* ``markov``   — per-agent biased bigram chains: each data shard (= "agent"
  in the paper's sense) samples from a slightly different distribution, the
  LM analogue of the paper's non-IID local signals. Used by the robust-
  training examples, where Byzantine workers can also corrupt their stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    flavour: str = "iid"          # "iid" | "markov"
    n_agents: int = 1             # data-parallel worker count (markov bias)
    seed: int = 0

    def batch(self, step: int) -> dict[str, jnp.ndarray]:
        """Host-side global batch (tests / single-process examples)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = self._tokens(key, self.global_batch, agent=0)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_batch(self, step: int, agent: int, local_batch: int):
        """Worker-local slice, drawn independently per (step, agent)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), agent
        )
        toks = self._tokens(key, local_batch, agent)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _tokens(self, key, batch: int, agent: int) -> jnp.ndarray:
        S = self.seq_len + 1
        if self.flavour == "iid":
            return jax.random.randint(key, (batch, S), 0, self.vocab, jnp.int32)
        # markov: agent-specific drift — token_{t+1} = token_t + step_draw
        k1, k2 = jax.random.split(key)
        start = jax.random.randint(k1, (batch, 1), 0, self.vocab)
        drift = 1 + (agent % 7)  # per-agent bigram bias
        steps = jax.random.randint(k2, (batch, S - 1), 0, 2 * drift + 1) - drift
        toks = jnp.cumsum(jnp.concatenate([start, steps], axis=1), axis=1)
        return jnp.mod(toks, self.vocab).astype(jnp.int32)


def make_batch_specs(seq_len: int, global_batch: int, vocab: int):
    """ShapeDtypeStructs for one training batch (dry-run stand-ins)."""
    return {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
