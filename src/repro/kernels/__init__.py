"""Pallas TPU kernels for the framework's compute hot-spots.

- ``trimmed_mean`` — the Byzantine filter of Algorithm 2 applied
  coordinate-wise over the worker axis (the paper's scalar-dynamics trick
  vectorized over every gradient coordinate).
- ``pushsum_edge`` — fused edge-scatter for the sparse robust push-sum
  core: gather ``sigma[src]``, mask-latch, and the per-receiver increment
  sum in one streaming pass over a dst-sorted edge index (Algorithm 1's
  per-round hot path at N ~ 1e5).
- ``byz_trim`` — fused neighbor trim-gather for the sparse Byzantine
  gossip core: gather over a padded neighbor list, Byzantine-message
  substitution, and the F-round extremes-extraction trim in one streaming
  pass over receiver blocks (Algorithm 2's per-round hot path).
- ``social_innov`` — fused innovation + belief step for the Algorithm 3
  social-learning engine: inverse-CDF signal sampling, the log-likelihood
  table gather, dual accumulation, and the KL-proximal softmax belief in
  one streaming pass over agent blocks (Algorithm 3's per-round hot path
  alongside ``pushsum_edge``).
- ``wkv6`` — chunked RWKV6 linear recurrence with data-dependent decay
  (rwkv6-1.6b's training/prefill hot-spot).
- ``swa`` — flash-decode attention over a sliding-window KV cache
  (decode_32k / long_500k serve hot-spot for the dense GQA archs).

All kernels use ``pl.pallas_call`` with explicit BlockSpec VMEM tiling and
are validated against their pure-jnp ``ref.py`` oracles via
``interpret=True`` on CPU (see tests/test_kernels.py).

Backend dispatch is shared repo-wide through :mod:`repro.kernels.dispatch`
(``backend="auto"|"xla"|"pallas"``). The model-stack kernels (``swa``,
``wkv6``, ``trimmed_mean``) predate the engine kernels and carried a
seed-era ``use_kernel`` boolean for the layers/aggregation callers; that
alias was removed in PR 10 — ``backend=`` is the one switch everywhere,
and the ``repro.statics.signatures`` lint keeps retired execution kwargs
from re-growing. They serve the seed model stack only — no Algorithm 1-3
engine calls them — pending ROADMAP's model-stack integration item.
"""
from .trimmed_mean.ops import trimmed_mean, trimmed_mean_pytree
from .pushsum_edge.ops import edge_scatter
from .byz_trim.ops import trim_gather, trim_gather_pairs
from .social_innov.ops import innovation_step
from .wkv6.ops import wkv6, wkv6_decode_step
from .swa.ops import attn_decode
from .swa.prefill import swa_prefill_pallas

__all__ = [
    "trimmed_mean",
    "trimmed_mean_pytree",
    "edge_scatter",
    "trim_gather",
    "trim_gather_pairs",
    "innovation_step",
    "wkv6",
    "wkv6_decode_step",
    "attn_decode",
    "swa_prefill_pallas",
]
