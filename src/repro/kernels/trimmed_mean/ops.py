"""Public entry points for the trimmed-mean Byzantine filter.

``trimmed_mean``        — (W, D) array -> (D,)
``trimmed_mean_pytree`` — apply over a pytree of per-worker stacked leaves,
                          the form the gradient aggregator consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dispatch import resolve_backend
from .ref import trimmed_mean_ref
from .trimmed_mean import trimmed_mean_pallas

__all__ = ["trimmed_mean", "trimmed_mean_pytree", "trimmed_mean_ref"]


def trimmed_mean(
    x: jnp.ndarray, F: int, block_d: int = 2048,
    *, backend: str = "auto",
) -> jnp.ndarray:
    """Coordinate-wise trimmed mean over the leading worker axis.

    ``backend`` is the repo-wide ``"auto"|"xla"|"pallas"`` switch (the
    seed-era ``use_kernel`` boolean is gone); ``"xla"`` is the
    :func:`trimmed_mean_ref` oracle the Pallas path is tested against.
    """
    if resolve_backend(backend) != "pallas":
        return trimmed_mean_ref(x, F)
    return trimmed_mean_pallas(x, F, block_d=block_d)


def trimmed_mean_pytree(stacked, F: int, *, backend: str = "auto"):
    """stacked: pytree whose leaves are (W, ...) per-worker values.

    Flattens every leaf to (W, -1), trims coordinate-wise, restores shapes.
    Leaves are concatenated into a single (W, D_total) matrix first so the
    kernel launches once (one HBM stream) instead of per-leaf. The trim is
    computed in float32 for accuracy, but every output leaf is returned in
    its input dtype (bf16 trees round-trip as bf16; mixed-dtype trees keep
    each leaf's own dtype).
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    W = leaves[0].shape[0]
    flat = [l.reshape(W, -1).astype(jnp.float32) for l in leaves]
    sizes = [f.shape[1] for f in flat]
    big = jnp.concatenate(flat, axis=1)
    out = trimmed_mean(big, F, backend=backend)
    outs = []
    off = 0
    for leaf, size in zip(leaves, sizes):
        outs.append(out[off : off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, outs)
