"""Pure-jnp oracle for the coordinate-wise trimmed mean.

This is the Byzantine filter of Algorithm 2 (lines 9 and 18) applied
per-coordinate — the paper's "collection of scalar dynamics" trick — over a
worker axis: for every coordinate independently, drop the F largest and F
smallest of the W worker values and average the survivors.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["trimmed_mean_ref"]


def trimmed_mean_ref(x: jnp.ndarray, F: int) -> jnp.ndarray:
    """x: (W, D) worker values; returns (D,) trimmed mean with 2F dropped.

    Requires W > 2F. Ties are handled like a sort (duplicates count once per
    occurrence), which the kernel's iterative argmax extraction matches.
    """
    W = x.shape[0]
    if W <= 2 * F:
        raise ValueError(f"need W > 2F, got W={W}, F={F}")
    if F == 0:
        return x.mean(axis=0)
    s = jnp.sort(x, axis=0)
    return s[F : W - F].mean(axis=0)
