"""Pallas TPU kernel: coordinate-wise trimmed mean over a worker axis.

Workload shape: ``x (W, D)`` with a small worker axis (W = 8..64 data-parallel
workers) and a huge coordinate axis (D = every parameter of the model). The
paper's per-scalar Byzantine consensus trim (Alg. 2) becomes, per coordinate,
"drop F largest + F smallest, average the rest".

TPU design notes
----------------
* The coordinate axis is tiled into lane-aligned blocks (multiples of 128)
  that stream HBM -> VMEM; the worker axis stays resident (it is tiny).
* A full per-coordinate sort would waste the VPU: F <= (W-1)/2 is small, so
  we run F rounds of argmax/argmin *extraction* — each round is a (W, BD)
  max + compare + select, all rank-2 vregs, no cross-lane shuffles.
* Ties are broken by first occurrence (same as a stable sort slice, which is
  what the ref oracle computes).
* The trim count F is a Python static => the extraction loop fully unrolls.

Arithmetic intensity is O(F) per element, bytes are O(W) per output — this
kernel is memory-bound by design; the win over the naive sort-based lowering
is the removal of the O(W log W) sorting network XLA would emit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["trimmed_mean_pallas"]


def _kernel(x_ref, o_ref, *, F: int):
    x = x_ref[...].astype(jnp.float32)          # (W, BD) block in VMEM
    W = x.shape[0]

    if F == 0:
        o_ref[...] = (x.sum(axis=0) / W).astype(o_ref.dtype)
        return

    # Keep-mask formulation: flip one extremum per round, then sum the
    # survivors directly. (A total - top - bottom formulation catastrophically
    # cancels when Byzantine values are ~1e6x the honest scale — found by the
    # hypothesis resistance property test.)
    ranks = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    neg = jnp.float32(jnp.finfo(jnp.float32).min)
    pos = jnp.float32(jnp.finfo(jnp.float32).max)
    keep = jnp.ones(x.shape, jnp.bool_)

    cur = x
    for _ in range(F):                           # static unroll: drop maxima
        idx = jnp.argmax(cur, axis=0)
        onehot = ranks == idx[None, :]
        keep = keep & ~onehot
        cur = jnp.where(onehot, neg, cur)
    cur = jnp.where(keep, x, pos)
    for _ in range(F):                           # drop minima among survivors
        idx = jnp.argmin(cur, axis=0)
        onehot = ranks == idx[None, :]
        keep = keep & ~onehot
        cur = jnp.where(onehot, pos, cur)

    kept_sum = jnp.where(keep, x, 0.0).sum(axis=0)
    o_ref[...] = (kept_sum / (W - 2 * F)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("F", "block_d", "interpret"))
def trimmed_mean_pallas(
    x: jnp.ndarray,
    F: int,
    block_d: int = 2048,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Coordinate-wise trimmed mean. x: (W, D) -> (D,).

    D is padded to a multiple of ``block_d`` (lane-aligned); the pad region
    is sliced off the output. ``interpret=None`` auto-selects interpret mode
    off-TPU so the same call site works in CI and on hardware.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    W, D = x.shape
    if W <= 2 * F:
        raise ValueError(f"need W > 2F, got W={W}, F={F}")
    pad = (-D) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    Dp = D + pad

    out = pl.pallas_call(
        functools.partial(_kernel, F=F),
        grid=(Dp // block_d,),
        in_specs=[pl.BlockSpec((W, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Dp,), x.dtype),
        interpret=interpret,
    )(x)
    return out[:D]
