"""Pure-XLA oracle for the fused social-learning innovation step.

The contract both backends implement, per agent ``j`` independently — the
innovation + belief half of one Algorithm 3 iteration (lines 13-16):

    sig[j]    = #{ s : u[j] > cdf[j, s] }          (inverse-CDF categorical)
    loglik[j] = log_tables[j, :, sig[j]]           ((m,) gather)
    z_new[j]  = z[j] + loglik[j]                   (dual accumulator)
    mu[j]     = softmax(z_new[j] / max(mass[j], 1e-30))   (KL-prox belief)

``cdf`` is the *precomputed* inclusive cumsum of the truth-row likelihoods
(hoisted out of the scan — the seed path recomputed the (N, S) cumsum every
iteration), ``u`` the per-agent uniforms for this iteration (one
``jax.random.uniform(key, (N,))`` draw; the seed path split N keys and
vmapped scalar draws). The belief formula is exactly
:func:`repro.core.social.kl_dual_averaging_update`; it lives here too so a
single fused pass can emit both the accumulator and the belief.

This lowering is the equivalence oracle for the Pallas kernel
(:mod:`.social_innov`) and the executable the engine uses off-TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["innovation_ref"]


def innovation_ref(
    z: jnp.ndarray,           # (N, m) log-likelihood accumulator
    mass: jnp.ndarray,        # (N,)  push-sum mass
    u: jnp.ndarray,           # (N,)  uniforms for this iteration
    cdf: jnp.ndarray,         # (N, S) inclusive cumsum of truth-row probs
    log_tables: jnp.ndarray,  # (N, m, S) log l_j(s | theta_k)
    *,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(z_new (N, m), mu (N, m))``.

    ``z_new`` is emitted in ``z.dtype`` (the persistent/storage value);
    ``accum_dtype`` names the dtype the accumulation and belief softmax run
    in (the precision policy's accum slot) and the dtype ``mu`` is emitted
    in — ``None`` keeps ``z.dtype``, the pre-policy program.
    """
    ad = z.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    S = cdf.shape[1]
    # inverse-CDF sample: cdf is an inclusive cumsum of non-negative probs,
    # hence non-decreasing per row, so a binary-search lowering is legal and
    # bit-identical to the (u > cdf) compare/reduce it replaces.
    # clamp: an fp32 cumsum can end below 1.0, so u >= cdf[:, -1] would
    # index past the alphabet (NaN gather fill poisoning z forever)
    sig = jax.vmap(
        lambda c, uu: jnp.searchsorted(c, uu, side="left")
    )(cdf, u)
    sig = jnp.minimum(sig, S - 1)                        # (N,) int
    loglik = jnp.take_along_axis(
        log_tables, sig[:, None, None].astype(jnp.int32), axis=2
    )[:, :, 0]                                           # (N, m)
    z_acc = z.astype(ad) + loglik.astype(ad)
    z_new = z_acc.astype(z.dtype)
    mu = jax.nn.softmax(
        z_acc / jnp.maximum(mass.astype(ad), 1e-30)[:, None], axis=-1
    )
    return z_new, mu
