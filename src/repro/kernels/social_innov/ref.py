"""Pure-XLA oracle for the fused social-learning innovation step.

The contract both backends implement, per agent ``j`` independently — the
innovation + belief half of one Algorithm 3 iteration (lines 13-16):

    sig[j]    = #{ s : u[j] > cdf[j, s] }          (inverse-CDF categorical)
    loglik[j] = log_tables[j, :, sig[j]]           ((m,) gather)
    z_new[j]  = z[j] + loglik[j]                   (dual accumulator)
    mu[j]     = softmax(z_new[j] / max(mass[j], 1e-30))   (KL-prox belief)

``cdf`` is the *precomputed* inclusive cumsum of the truth-row likelihoods
(hoisted out of the scan — the seed path recomputed the (N, S) cumsum every
iteration), ``u`` the per-agent uniforms for this iteration (one
``jax.random.uniform(key, (N,))`` draw; the seed path split N keys and
vmapped scalar draws). The belief formula is exactly
:func:`repro.core.social.kl_dual_averaging_update`; it lives here too so a
single fused pass can emit both the accumulator and the belief.

This lowering is the equivalence oracle for the Pallas kernel
(:mod:`.social_innov`) and the executable the engine uses off-TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["innovation_ref"]


def innovation_ref(
    z: jnp.ndarray,           # (N, m) log-likelihood accumulator
    mass: jnp.ndarray,        # (N,)  push-sum mass
    u: jnp.ndarray,           # (N,)  uniforms for this iteration
    cdf: jnp.ndarray,         # (N, S) inclusive cumsum of truth-row probs
    log_tables: jnp.ndarray,  # (N, m, S) log l_j(s | theta_k)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(z_new (N, m), mu (N, m))``."""
    S = cdf.shape[1]
    # clamp: an fp32 cumsum can end below 1.0, so u >= cdf[:, -1] would
    # index past the alphabet (NaN gather fill poisoning z forever)
    sig = jnp.minimum((u[:, None] > cdf).sum(axis=-1), S - 1)    # (N,) int
    loglik = jnp.take_along_axis(
        log_tables, sig[:, None, None].astype(jnp.int32), axis=2
    )[:, :, 0]                                           # (N, m)
    z_new = z + loglik
    mu = jax.nn.softmax(z_new / jnp.maximum(mass, 1e-30)[:, None], axis=-1)
    return z_new, mu
