"""Fused innovation + belief step for the Algorithm 3 social-learning engine.

One social-learning iteration interleaves a consensus half (robust push-sum
over the packet-dropping edge list — :mod:`repro.kernels.pushsum_edge`) with
an innovation half, per agent j:

    draw a private signal  s ~ l_j(. | theta*)        (inverse-CDF on u[j])
    loglik[j] = log l_j(s | .)                        ((m,) table gather)
    z[j]     += loglik[j]                             (dual accumulator)
    mu[j]     = softmax(z[j] / mass[j])               (KL-prox belief)

The seed lowering ran these as five separate XLA ops per scan step — with
the (N, S) truth-CDF *recomputed* inside the scan and a per-agent
key-split/vmap for the uniforms — each op a full HBM round-trip over (N, ·)
intermediates. Here the CDF is precomputed once (hoisted loop invariant),
the uniforms are one (N,) draw, and the remaining work is a single
streaming pass over agent blocks.

:mod:`.ref` is the always-available XLA oracle; :mod:`.ops` hosts the
``backend="auto"|"xla"|"pallas"`` dispatch used by
:mod:`repro.core.social`; :mod:`.social_innov` is the fused Pallas kernel.
"""
from .ops import BACKENDS, innovation_step, resolve_backend
from .ref import innovation_ref

__all__ = [
    "innovation_step",
    "innovation_ref",
    "resolve_backend",
    "BACKENDS",
]
