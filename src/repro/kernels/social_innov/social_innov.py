"""Pallas TPU kernel: fused innovation + belief step for Algorithm 3.

One call covers the innovation half of a social-learning iteration (lines
13-16) in a single streaming pass over agent blocks: inverse-CDF categorical
signal sampling, the (m,)-row log-likelihood gather from the resident log
tables, the ``z += loglik`` dual-averaging accumulation, and the KL-proximal
softmax belief — replacing five separate XLA ops (compare, reduce, gather,
add, softmax) each reading/writing (N, ·) HBM intermediates per scan step.

Design (see /opt/skills/guides/pallas_guide.md)
-----------------------------------------------
* Grid: 1-D over agent blocks of ``block_n`` rows. Every input is
  block-mapped — nothing is resident across blocks, so the kernel streams:
  per block it touches O(block_n * (m S + S + m)) VMEM and emits
  O(block_n * m). No cross-block state means any grid order is legal.
* The per-agent gather ``log_tables[j, :, sig[j]]`` is lowered as a one-hot
  contraction over the alphabet axis (``iota_S == sig`` mask + sum) rather
  than a dynamic gather: S is small (4-16 for the paper's models), the
  one-hot select is pure VPU, and Mosaic vectorizes it where a per-row
  dynamic slice would serialize.
* The softmax runs on the block tile with the standard max-subtraction;
  hypotheses m is small so the reduction axis is cheap — the streaming axis
  (agents) carries the throughput, as with the other consensus kernels.
* Padding agents (to a multiple of ``block_n``) carry ``mass = 0`` /
  ``u = 0`` / all-zero table rows: their ``sig`` is 0, their ``z_new`` row
  is ``z + 0`` and the softmax of a zero row is uniform — finite, inert,
  and sliced off.

``interpret=None`` auto-selects interpreter mode off-TPU so CPU CI
validates the identical program (tests/test_social_engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["innovation_pallas"]


def _kernel(z_ref, mass_ref, u_ref, cdf_ref, lt_ref, z_out_ref, mu_ref):
    z = z_ref[...]                               # (BN, m)
    mass = mass_ref[...]                         # (BN,)
    u = u_ref[...]                               # (BN,)
    cdf = cdf_ref[...]                           # (BN, S)
    lt = lt_ref[...]                             # (BN, m, S)

    # --- inverse-CDF categorical sample per agent; clamp because an fp32
    # cumsum can end below 1.0 (u >= cdf[-1] must map to the last letter) ---
    s_max = cdf.shape[1] - 1
    sig = jnp.minimum((u[:, None] > cdf).sum(axis=-1), s_max).astype(jnp.int32)

    # --- (m,) log-likelihood row gather as a one-hot contraction over S ---
    s_iota = jax.lax.broadcasted_iota(jnp.int32, lt.shape, 2)
    onehot = s_iota == sig[:, None, None]
    acc = mu_ref.dtype                           # the policy accum slot
    loglik = jnp.where(onehot, lt.astype(acc), 0.0).sum(axis=-1)  # (BN, m)

    # --- dual accumulation + KL-proximal belief (softmax of z/m) ---
    # the accumulation and softmax run in the accum slot; z_new is downcast
    # to the persistent storage dtype on the way out
    z_new = z.astype(acc) + loglik
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)
    ratio = z_new / jnp.maximum(mass.astype(acc), 1e-30)[:, None]
    shifted = ratio - ratio.max(axis=-1, keepdims=True)
    e = jnp.exp(shifted)
    mu_ref[...] = e / e.sum(axis=-1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("block_n", "interpret", "accum_dtype")
)
def innovation_pallas(
    z: jnp.ndarray,           # (N, m) log-likelihood accumulator
    mass: jnp.ndarray,        # (N,)  push-sum mass
    u: jnp.ndarray,           # (N,)  uniforms for this iteration
    cdf: jnp.ndarray,         # (N, S) inclusive cumsum of truth-row probs
    log_tables: jnp.ndarray,  # (N, m, S)
    *,
    block_n: int = 4096,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused innovation step -> ``(z_new (N, m), mu (N, m))``.

    Matches :func:`repro.kernels.social_innov.ref.innovation_ref` to fp32
    rounding (the softmax applies the max-subtraction the XLA lowering also
    performs). N is padded to a multiple of ``block_n`` with inert rows; the
    pad rows are sliced off. ``z_new`` is emitted in ``z.dtype``;
    ``accum_dtype`` names the dtype the accumulation/softmax run in and
    ``mu`` is emitted in (``None`` keeps ``z.dtype``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    acc = z.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    n, m = z.shape
    S = cdf.shape[1]
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        z = jnp.pad(z, ((0, pad), (0, 0)))
        mass = jnp.pad(mass, (0, pad))
        u = jnp.pad(u, (0, pad))
        cdf = jnp.pad(cdf, ((0, pad), (0, 0)))
        log_tables = jnp.pad(log_tables, ((0, pad), (0, 0), (0, 0)))
    n_pad = n + pad

    z_new, mu = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, S), lambda i: (i, 0)),
            pl.BlockSpec((block_n, m, S), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, m), z.dtype),
            jax.ShapeDtypeStruct((n_pad, m), acc),
        ],
        interpret=interpret,
    )(z, mass, u, cdf, log_tables)
    return z_new[:n], mu[:n]
