"""Backend dispatch for the fused social-learning innovation step.

``innovation_step(..., backend=...)`` is the single entry point the
Algorithm 3 engine calls per iteration:

``"xla"``     — compare/reduce + gather + softmax (:mod:`.ref`); runs
                anywhere and is the equivalence oracle.
``"pallas"``  — the fused streaming kernel (:mod:`.social_innov`);
                compiled on TPU, interpreter mode elsewhere (equivalence
                testing only — interpret mode is not a fast path).
``"auto"``    — ``"pallas"`` on a TPU default backend, else ``"xla"``.

Resolution is host-side and static (the choice changes the traced program),
so callers thread ``backend`` through ``static_argnames`` when jitting.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import BACKENDS, resolve_backend
from .ref import innovation_ref
from .social_innov import innovation_pallas

__all__ = ["innovation_step", "resolve_backend", "BACKENDS"]


def innovation_step(
    z: jnp.ndarray,           # (N, m)
    mass: jnp.ndarray,        # (N,)
    u: jnp.ndarray,           # (N,)
    cdf: jnp.ndarray,         # (N, S)
    log_tables: jnp.ndarray,  # (N, m, S)
    backend: str = "auto",
    *,
    block_n: int = 4096,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused sample + gather + accumulate + belief; see package docstring.

    Returns ``(z_new (N, m), mu (N, m))`` — ``z_new`` in ``z.dtype`` (the
    persistent value), ``mu`` in ``accum_dtype`` (the precision policy's
    accum slot; ``None`` keeps ``z.dtype``).
    """
    if resolve_backend(backend) == "xla":
        return innovation_ref(z, mass, u, cdf, log_tables,
                              accum_dtype=accum_dtype)
    return innovation_pallas(
        z, mass, u, cdf, log_tables, block_n=block_n, interpret=interpret,
        accum_dtype=accum_dtype,
    )
