"""Public WKV6 entry points: kernel for training/prefill, jnp step for decode.

``wkv6`` dispatches between the chunked Pallas kernel (T multiple of chunk,
perf path) and the sequential oracle (fallback for ragged shapes / debugging).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import functools

from ..dispatch import resolve_backend
from .ref import wkv6_ref, wkv6_decode_step, wkv6_chunked_jnp
from .wkv6 import wkv6_chunked_pallas

__all__ = ["wkv6", "wkv6_decode_step", "wkv6_ref", "wkv6_chunked_jnp"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _wkv6_kernel_ad(r, k, v, lw, u, chunk):
    """Pallas forward with a jnp-chunked backward: pallas_call has no
    built-in transpose, so the VJP re-runs the mathematically identical
    chunked-jnp path under jax.vjp (one extra forward in the backward pass,
    same as remat)."""
    return wkv6_chunked_pallas(r, k, v, lw, u, chunk=chunk)


def _wkv6_fwd(r, k, v, lw, u, chunk):
    out = wkv6_chunked_pallas(r, k, v, lw, u, chunk=chunk)
    return out, (r, k, v, lw, u)


def _wkv6_bwd(chunk, res, cot):
    r, k, v, lw, u = res
    _, vjp = jax.vjp(
        lambda *a: wkv6_chunked_jnp(*a, chunk=chunk), r, k, v, lw, u
    )
    return vjp(cot)


_wkv6_kernel_ad.defvjp(_wkv6_fwd, _wkv6_bwd)


def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lw: jnp.ndarray,
    u: jnp.ndarray,
    chunk: int | None = None,
    *,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(BH, T, K/V) chunked WKV6 -> (y, final_state).

    Dispatch: Pallas kernel on TPU (chunk 64, MXU-sized); chunked-jnp
    off-TPU (same math, python chunk loop so dry-run cost analysis sees
    every chunk — capped at 32 unrolled chunks since WKV FLOPs are dwarfed
    by the r/k/v/g projections); sequential scan oracle for ragged shapes.
    ``backend`` is the repo-wide ``"auto"|"xla"|"pallas"`` switch (the
    seed-era ``use_kernel`` alias is gone); ``"xla"`` maps onto the
    chunked-jnp oracle path.
    """
    T = r.shape[1]
    if resolve_backend(backend) == "pallas":
        c = chunk or 64
        if T % c == 0 and T >= c:
            return _wkv6_kernel_ad(r, k, v, lw, u, c)
        return wkv6_ref(r, k, v, lw, u)
    c = chunk or max(64, T // 32)
    while T % c:
        c //= 2
    if c >= 16:
        return wkv6_chunked_jnp(r, k, v, lw, u, chunk=c)
    return wkv6_ref(r, k, v, lw, u)
