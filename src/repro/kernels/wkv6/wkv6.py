"""Pallas TPU kernel: chunked RWKV6 WKV with data-dependent decay.

The sequential recurrence (see ref.py) has O(T) depth; the chunked form
recovers MXU-friendly matmuls by splitting T into chunks of C tokens and
carrying the (K, V) state across chunks in a VMEM scratch buffer — the TPU
grid iterates the time axis sequentially, so the carry is race-free.

Within a chunk (local indices i, j; P = inclusive cumsum of log-decay,
E_i = P_i - lw_i = exclusive cumsum):

    y_i  = (r_i . exp(E_i)) @ S_start                      inter-chunk
         + sum_{j<i} [sum_kdim r_i k_j exp(E_i - P_j)] v_j  intra-chunk
         + (r_i . u . k_i) @ v_i                            bonus diagonal
    S_end = diag(exp(P_last)) S_start
          + sum_j (k_j . exp(P_last - P_j))^T v_j

Numerical safety: every exponent above is <= 0 by construction (log-decays
are <= 0 and j <= i), so the kernel never forms exp of a positive number —
this is why the pairwise (C, C, K) tensor is built *jointly* instead of
factoring exp(E_i) * exp(-P_j) into a separable (and overflowing) matmul.
VMEM cost of the pairwise tensor: C^2 * K * 4B = 1 MiB at C=64, K=64.

Grid: (BH, T // C). Block shapes are (1, C, K) / (1, C, V) slabs; K and V
are the lane dimension (multiples of 128 after padding in ops.py, 64 on the
smoke path — still a legal, if half-utilized, vreg layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_chunked_pallas"]


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sfin_ref, s_scr, *, C: int):
    c = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)    # (C, K)
    k = k_ref[0].astype(jnp.float32)    # (C, K)
    v = v_ref[0].astype(jnp.float32)    # (C, V)
    lw = lw_ref[0].astype(jnp.float32)  # (C, K) log-decay <= 0
    u = u_ref[0].astype(jnp.float32)    # (1, K)
    s = s_scr[...]                      # (K, V) carried state

    P = jnp.cumsum(lw, axis=0)          # inclusive (C, K)
    E = P - lw                          # exclusive (C, K)

    # --- inter-chunk: contribution of the carried state ---
    q_dec = r * jnp.exp(E)              # (C, K), exponents <= 0
    y = q_dec @ s                       # (C, V) MXU

    # --- intra-chunk: pairwise decayed attention, strictly causal ---
    # D[i, j, k] = E[i, k] - P[j, k]  (<= 0 for j < i)
    D = E[:, None, :] - P[None, :, :]                       # (C, C, K)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    causal = (j_idx < i_idx)[:, :, None]
    A = jnp.where(causal, jnp.exp(jnp.where(causal, D, 0.0)), 0.0)
    scores = jnp.einsum("ik,jk,ijk->ij", r, k, A)           # (C, C)
    y = y + scores @ v                                      # MXU

    # --- bonus diagonal (current token): y_i += (sum_k r_ik u_k k_ik) v_i ---
    y = y + jnp.sum(r * u * k, axis=1, keepdims=True) * v

    y_ref[0] = y.astype(y_ref.dtype)

    # --- state carry to next chunk ---
    p_last = P[-1]                                          # (K,)
    k_dec = k * jnp.exp(p_last[None, :] - P)                # (C, K), <= 0 exp
    s_new = jnp.exp(p_last)[:, None] * s + k_dec.T @ v      # (K, V)
    s_scr[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _emit_final():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def wkv6_chunked_pallas(
    r: jnp.ndarray,   # (BH, T, K)
    k: jnp.ndarray,   # (BH, T, K)
    v: jnp.ndarray,   # (BH, T, V)
    lw: jnp.ndarray,  # (BH, T, K) log-decay (<= 0)
    u: jnp.ndarray,   # (BH, K)
    chunk: int = 64,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV6. Returns (y (BH, T, V), s_final (BH, K, V))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    BH, T, K = r.shape
    V = v.shape[-1]
    if T % chunk != 0:
        raise ValueError(f"T={T} must be a multiple of chunk={chunk}")
    n_chunks = T // chunk

    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, C=chunk),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y, s_fin
