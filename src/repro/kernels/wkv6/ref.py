"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head with key-dim K, value-dim V, the data-dependent-decay recurrence is

    S_t = diag(w_t) S_{t-1} + k_t v_t^T                 (S in R^{K x V})
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

where ``w_t = exp(lw_t)`` with per-channel log-decay ``lw_t <= 0`` and ``u``
is the current-token bonus. This sequential scan is the ground truth the
chunked Pallas kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref", "wkv6_decode_step"]


def wkv6_ref(
    r: jnp.ndarray,   # (BH, T, K) receptance
    k: jnp.ndarray,   # (BH, T, K)
    v: jnp.ndarray,   # (BH, T, V)
    lw: jnp.ndarray,  # (BH, T, K) log-decay (<= 0)
    u: jnp.ndarray,   # (BH, K) bonus
    s0: jnp.ndarray | None = None,  # (BH, K, V) initial state
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (BH, T, V), s_final (BH, K, V)). float32 internals."""
    BH, T, K = r.shape
    V = v.shape[-1]
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(lw.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((BH, K, V), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (BH,K),(BH,K),(BH,V),(BH,K)
        kv = kt[:, :, None] * vt[:, None, :]          # (BH, K, V)
        y = jnp.einsum("bk,bkv->bv", rt, s + uf[:, :, None] * kv)
        s_new = wt[:, :, None] * s + kv
        return s_new, y

    xs = (
        jnp.moveaxis(rf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(wf, 1, 0),
    )
    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_final


def wkv6_chunked_jnp(
    r: jnp.ndarray,   # (BH, T, K)
    k: jnp.ndarray,
    v: jnp.ndarray,   # (BH, T, V)
    lw: jnp.ndarray,  # (BH, T, K) log-decay <= 0
    u: jnp.ndarray,   # (BH, K)
    chunk: int = 64,
    s0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV6 in plain jnp — the same math as the Pallas kernel
    (see wkv6.py), with a *python* loop over chunks so XLA cost analysis
    sees every chunk's FLOPs (a lax.scan body is only counted once).
    Used off-TPU for training/prefill and as a second oracle."""
    BH, T, K = r.shape
    V = v.shape[-1]
    C = chunk
    assert T % C == 0
    rf = r.astype(jnp.float32).reshape(BH, T // C, C, K)
    kf = k.astype(jnp.float32).reshape(BH, T // C, C, K)
    vf = v.astype(jnp.float32).reshape(BH, T // C, C, V)
    lwf = lw.astype(jnp.float32).reshape(BH, T // C, C, K)
    uf = u.astype(jnp.float32)
    s = (s0 if s0 is not None else jnp.zeros((BH, K, V))).astype(jnp.float32)

    i_idx = jnp.arange(C)[:, None]
    j_idx = jnp.arange(C)[None, :]
    causal = (j_idx < i_idx)[None, :, :, None]  # (1, C, C, 1)

    ys = []
    for c in range(T // C):
        rc, kc, vc, lwc = rf[:, c], kf[:, c], vf[:, c], lwf[:, c]
        P = jnp.cumsum(lwc, axis=1)          # (BH, C, K) inclusive
        E = P - lwc                          # exclusive
        q_dec = rc * jnp.exp(E)
        y = jnp.einsum("bik,bkv->biv", q_dec, s)
        D = E[:, :, None, :] - P[:, None, :, :]          # (BH, C, C, K)
        A = jnp.where(causal, jnp.exp(jnp.where(causal, D, 0.0)), 0.0)
        scores = jnp.einsum("bik,bjk,bijk->bij", rc, kc, A)
        y = y + jnp.einsum("bij,bjv->biv", scores, vc)
        y = y + jnp.sum(rc * uf[:, None, :] * kc, axis=2, keepdims=True) * vc
        p_last = P[:, -1]
        k_dec = kc * jnp.exp(p_last[:, None, :] - P)
        s = jnp.exp(p_last)[:, :, None] * s + jnp.einsum(
            "bjk,bjv->bkv", k_dec, vc
        )
        ys.append(y)
    out = jnp.concatenate(ys, axis=1).astype(r.dtype)
    return out, s


def wkv6_decode_step(
    r: jnp.ndarray,   # (BH, K)
    k: jnp.ndarray,   # (BH, K)
    v: jnp.ndarray,   # (BH, V)
    lw: jnp.ndarray,  # (BH, K)
    u: jnp.ndarray,   # (BH, K)
    s: jnp.ndarray,   # (BH, K, V)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token decode: returns (y (BH, V), s_new). O(K*V) — no kernel
    needed; this is the long_500k serve path's whole attention cost."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(lw.astype(jnp.float32))
    kv = kf[:, :, None] * vf[:, None, :]
    y = jnp.einsum("bk,bkv->bv", rf, s + u.astype(jnp.float32)[:, :, None] * kv)
    s_new = wf[:, :, None] * s + kv
    return y.astype(r.dtype), s_new
