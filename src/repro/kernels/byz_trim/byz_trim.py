"""Pallas TPU kernel: fused neighbor trim-gather for the Byzantine core.

One call covers the whole gossip half of an Algorithm 2 round in a single
streaming pass over receiver blocks: gather each receiver's in-neighbor
statistics from the resident ``r`` matrix, substitute attack values on slots
whose sender is Byzantine, run the 2F-extraction trim per pair coordinate,
and emit the survivor sum plus kept count — no ``(N, N, m, m)`` broadcast,
no ``jnp.sort``.

Design (see /opt/skills/guides/pallas_guide.md)
-----------------------------------------------
* Grid: 1-D over receiver blocks of ``block_n`` rows. ``r`` (N, P) stays
  VMEM-resident with a constant index map (at the target workload N ~ 1e5
  with P = m^2 small it is a few MB); per-block inputs are the neighbor
  index/validity/Byzantine tensors, all O(block_n * deg_max * P).
* A full per-coordinate sort would waste the VPU: F << deg_max, so the trim
  runs F rounds of argmax *extraction* followed by F rounds of argmin — the
  same O(F * deg) design as :mod:`repro.kernels.trimmed_mean`, generalized
  to a per-receiver slot axis with validity padding. Each round is an
  argmax + compare + select over the (block_n, deg_max, P) tile; the F loop
  is a Python static, so it fully unrolls.
* Extraction == sort-slice trimming as multisets: each max round removes one
  instance of the current maximum over the still-kept valid slots (ties by
  first occurrence, like a stable sort slice), so after F rounds exactly the
  F largest values are gone; symmetrically for minima. When deg <= 2F both
  formulations keep nothing: extraction exhausts the valid slots (argmax
  over an all-sentinel column is a no-op on the keep mask), and the rank
  window [F, deg - F) is empty. Survivor sums therefore agree with the sort
  oracle up to fp ordering.
* Survivors are summed through a keep mask, never via total - extremes —
  Byzantine magnitudes ~1e6x the honest scale make the subtractive
  formulation cancel catastrophically (same lesson as trimmed_mean).
* Padding receivers (to a multiple of ``block_n``) carry all-invalid slot
  rows: their trimmed sum is exactly zero and the rows are sliced off.

The pair axis P = m^2 (or m for one-vs-rest) is small, which underutilizes
the 128-wide lanes; the streaming axis (receivers) carries the throughput.
``interpret=None`` auto-selects interpreter mode off-TPU so CPU CI validates
the identical program (tests/test_byz_trim_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["trim_gather_pallas"]


def _kernel(r_ref, idx_ref, valid_ref, bmsg_ref, bnbr_ref,
            tsum_ref, kept_ref, *, F: int):
    r = r_ref[...]                               # (N, P) resident
    idx = idx_ref[...]                           # (BN, deg_max)
    valid = valid_ref[...]                       # (BN, deg_max)
    bmsg = bmsg_ref[...]                         # (BN, deg_max, P)
    bnbr = bnbr_ref[...]                         # (BN, deg_max)
    bn, dm = idx.shape
    p = r.shape[1]

    gathered = jnp.take(r, idx.reshape(-1), axis=0).reshape(bn, dm, p)
    vals = jnp.where(bnbr[:, :, None], bmsg, gathered).astype(jnp.float32)

    deg = valid.sum(axis=1).astype(jnp.int32)    # (BN,)
    kept_ref[...] = jnp.maximum(deg - 2 * F, 0).astype(kept_ref.dtype)

    keep = jnp.broadcast_to(valid[:, :, None], vals.shape)
    if F > 0:
        ranks = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        neg = jnp.float32(jnp.finfo(jnp.float32).min)
        pos = jnp.float32(jnp.finfo(jnp.float32).max)
        cur = jnp.where(keep, vals, neg)
        for _ in range(F):                       # static unroll: drop maxima
            onehot = ranks == jnp.argmax(cur, axis=1)[:, None, :]
            keep = keep & ~onehot
            cur = jnp.where(onehot, neg, cur)
        cur = jnp.where(keep, vals, pos)
        for _ in range(F):                       # drop minima among survivors
            onehot = ranks == jnp.argmin(cur, axis=1)[:, None, :]
            keep = keep & ~onehot
            cur = jnp.where(onehot, pos, cur)

    tsum = jnp.where(keep, vals, 0.0).sum(axis=1)
    tsum_ref[...] = tsum.astype(tsum_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("F", "block_n", "interpret", "accum_dtype")
)
def trim_gather_pallas(
    r: jnp.ndarray,         # (N, P) current statistics
    nbr_idx: jnp.ndarray,   # (N, deg_max) int32
    nbr_valid: jnp.ndarray, # (N, deg_max) bool
    byz_msgs: jnp.ndarray,  # (N, deg_max, P)
    byz_nbr: jnp.ndarray,   # (N, deg_max) bool
    F: int,
    *,
    block_n: int = 1024,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused trim-gather -> ``(trimmed_sum (N, P), kept (N,))``.

    Matches :func:`repro.kernels.byz_trim.ref.trim_gather_ref` to fp32
    reduction order. N is padded to a multiple of ``block_n`` with
    all-invalid receiver rows; the pad rows are sliced off. The kernel
    already runs its trim/sum in fp32 internally; ``accum_dtype`` names the
    dtype the survivor sum is *emitted* in (the precision policy's accum
    slot) — ``None`` keeps ``r.dtype``, the pre-policy program.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    acc = r.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    n, p = r.shape
    dm = nbr_idx.shape[1]
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        nbr_idx = jnp.pad(nbr_idx, ((0, pad), (0, 0)))
        nbr_valid = jnp.pad(nbr_valid, ((0, pad), (0, 0)))     # False
        byz_msgs = jnp.pad(byz_msgs, ((0, pad), (0, 0), (0, 0)))
        byz_nbr = jnp.pad(byz_nbr, ((0, pad), (0, 0)))
    n_pad = n + pad

    tsum, kept = pl.pallas_call(
        functools.partial(_kernel, F=F),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((n, p), lambda i: (0, 0)),            # r resident
            pl.BlockSpec((block_n, dm), lambda i: (i, 0)),
            pl.BlockSpec((block_n, dm), lambda i: (i, 0)),
            pl.BlockSpec((block_n, dm, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, dm), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, p), acc),
            jax.ShapeDtypeStruct((n_pad,), acc),
        ],
        interpret=interpret,
    )(r, nbr_idx, nbr_valid, byz_msgs, byz_nbr)
    return tsum[:n], kept[:n]
