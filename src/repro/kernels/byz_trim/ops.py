"""Backend dispatch for the fused Byzantine trim-gather.

``trim_gather(..., backend=...)`` is the single entry point the sparse
Byzantine core calls per gossip round:

``"xla"``     — gather + sort + rank mask (:mod:`.ref`); runs anywhere and
                accepts a *traced* F (dynamic-F scenario batches).
``"pallas"``  — the fused O(F * deg) extraction kernel (:mod:`.byz_trim`);
                compiled on TPU, interpreter mode elsewhere (equivalence
                testing only — interpret mode is not a fast path). Requires
                a static int F (the extraction loop unrolls).
``"auto"``    — ``"pallas"`` on a TPU default backend, else ``"xla"``.

Resolution is host-side and static (the choice changes the traced program),
so callers thread ``backend`` through ``static_argnames`` when jitting.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import BACKENDS, resolve_backend
from .byz_trim import trim_gather_pallas
from .ref import trim_gather_ref

__all__ = ["trim_gather", "trim_gather_pairs", "resolve_backend", "BACKENDS"]


def trim_gather(
    r: jnp.ndarray,         # (N, P)
    nbr_idx: jnp.ndarray,   # (N, deg_max) int32
    nbr_valid: jnp.ndarray, # (N, deg_max) bool
    byz_msgs: jnp.ndarray,  # (N, deg_max, P)
    byz_nbr: jnp.ndarray,   # (N, deg_max) bool
    F,
    backend: str = "auto",
    *,
    block_n: int = 1024,
    interpret: bool | None = None,
    indices_sorted: bool = False,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused gather + Byzantine substitution + 2F trim; see package docstring.

    Returns ``(trimmed_sum (N, P), kept (N,))``. ``indices_sorted=True``
    promises the flattened ``nbr_idx`` traversal is non-decreasing (only the
    single-row pool layout of ``ps_trimmed_pool`` qualifies — general
    neighbor lists do not). ``accum_dtype`` names the survivor-sum dtype
    (the precision policy's accum slot); ``None`` keeps ``r.dtype``.
    """
    if resolve_backend(backend) == "xla":
        return trim_gather_ref(r, nbr_idx, nbr_valid, byz_msgs, byz_nbr, F,
                               indices_sorted=indices_sorted,
                               accum_dtype=accum_dtype)
    if not isinstance(F, int):
        raise ValueError(
            "backend='pallas' needs a static int F (the extraction loop "
            "unrolls); use backend='xla' for traced per-scenario F"
        )
    return trim_gather_pallas(
        r, nbr_idx, nbr_valid, byz_msgs, byz_nbr, F,
        block_n=block_n, interpret=interpret, accum_dtype=accum_dtype,
    )


def trim_gather_pairs(
    r: jnp.ndarray,         # (N, *pair) — e.g. (N, m, m) or (N, m)
    nbr_idx: jnp.ndarray,
    nbr_valid: jnp.ndarray,
    byz_msgs: jnp.ndarray,  # (N, deg_max, *pair)
    byz_nbr: jnp.ndarray,
    F,
    backend: str = "auto",
    *,
    indices_sorted: bool = False,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pair-shaped wrapper: flattens the trailing pair axes into the kernel's
    coordinate axis and restores them on the way out."""
    n = r.shape[0]
    pair = r.shape[1:]
    dm = nbr_idx.shape[-1]
    tsum, kept = trim_gather(
        r.reshape(n, -1), nbr_idx, nbr_valid,
        byz_msgs.reshape(n, dm, -1), byz_nbr, F, backend,
        indices_sorted=indices_sorted, accum_dtype=accum_dtype,
    )
    return tsum.reshape((n,) + pair), kept
