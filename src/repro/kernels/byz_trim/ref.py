"""Pure-XLA oracle for the fused Byzantine trim-gather.

The contract both backends implement, per receiver ``j`` and per pair
coordinate ``p`` independently (the paper's "collection of scalar dynamics"):

    vals[j, k, p] = byz_msgs[j, k, p]      if byz_nbr[j, k]
                    r[nbr_idx[j, k], p]    otherwise
    drop slots with nbr_valid[j, k] == False,
    drop the F largest and F smallest of the remaining values,
    trimmed_sum[j, p] = sum of the survivors
    kept[j]           = max(deg_j - 2F, 0)

``kept`` is the survivor count Algorithm 2's update divides by; it does not
depend on the pair coordinate because padding is per-slot, not per-value.

This lowering sorts the static ``deg_max`` slot axis and masks by rank, so
``F`` may be a *traced* scalar — the keep window ``[F, deg - F)`` moves at
runtime while the program stays fixed. That is what lets batched
(topology, F) sweeps put F on a ``vmap`` scenario axis with a single trace
(:func:`repro.core.sweeps.run_byzantine_grid`). The Pallas kernel
(:mod:`.byz_trim`) requires a static F instead (its extraction loop unrolls).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["trim_gather_ref"]


def trim_gather_ref(
    r: jnp.ndarray,         # (N, P) current statistics, P pair coordinates
    nbr_idx: jnp.ndarray,   # (N, deg_max) int32 sender per slot
    nbr_valid: jnp.ndarray, # (N, deg_max) bool
    byz_msgs: jnp.ndarray,  # (N, deg_max, P) attack values per slot
    byz_nbr: jnp.ndarray,   # (N, deg_max) bool — slot's sender is Byzantine
    F,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(trimmed_sum (N, P), kept (N,) float)``."""
    big = jnp.asarray(jnp.finfo(r.dtype).max / 4, r.dtype)
    gathered = r[nbr_idx]                                  # (N, deg_max, P)
    vals = jnp.where(byz_nbr[:, :, None], byz_msgs, gathered)
    masked = jnp.where(nbr_valid[:, :, None], vals, big)   # pads sort high
    s = jnp.sort(masked, axis=1)
    deg = nbr_valid.sum(axis=1).astype(jnp.int32)          # (N,)
    ranks = jnp.arange(masked.shape[1])[None, :, None]
    keep = (ranks >= F) & (ranks < (deg[:, None, None] - F))
    tsum = (s * keep.astype(s.dtype)).sum(axis=1)
    kept = jnp.maximum(deg - 2 * F, 0).astype(r.dtype)
    return tsum, kept
