"""Pure-XLA oracle for the fused Byzantine trim-gather.

The contract both backends implement, per receiver ``j`` and per pair
coordinate ``p`` independently (the paper's "collection of scalar dynamics"):

    vals[j, k, p] = byz_msgs[j, k, p]      if byz_nbr[j, k]
                    r[nbr_idx[j, k], p]    otherwise
    drop slots with nbr_valid[j, k] == False,
    drop the F largest and F smallest of the remaining values,
    trimmed_sum[j, p] = sum of the survivors
    kept[j]           = max(deg_j - 2F, 0)

``kept`` is the survivor count Algorithm 2's update divides by; it does not
depend on the pair coordinate because padding is per-slot, not per-value.

This lowering sorts the static ``deg_max`` slot axis and masks by rank, so
``F`` may be a *traced* scalar — the keep window ``[F, deg - F)`` moves at
runtime while the program stays fixed. That is what lets batched
(topology, F) sweeps put F on a ``vmap`` scenario axis with a single trace
(:func:`repro.core.sweeps.run_byzantine_grid`). The Pallas kernel
(:mod:`.byz_trim`) requires a static F instead (its extraction loop unrolls).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["trim_gather_ref"]


def trim_gather_ref(
    r: jnp.ndarray,         # (N, P) current statistics, P pair coordinates
    nbr_idx: jnp.ndarray,   # (N, deg_max) int32 sender per slot
    nbr_valid: jnp.ndarray, # (N, deg_max) bool
    byz_msgs: jnp.ndarray,  # (N, deg_max, P) attack values per slot
    byz_nbr: jnp.ndarray,   # (N, deg_max) bool — slot's sender is Byzantine
    F,
    *,
    indices_sorted: bool = False,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(trimmed_sum (N, P), kept (N,) float)``.

    ``indices_sorted=True`` promises the flattened ``nbr_idx`` traversal is
    non-decreasing — true for the single-row pool layout of
    :func:`repro.core.hps.ps_trimmed_pool` (an ``arange``), NOT for general
    per-receiver neighbor lists — letting the gather lowering skip its sort
    bookkeeping. The gather always runs under ``promise_in_bounds``:
    neighbor slots are constructed in-range (padding slots carry index 0),
    so the out-of-bounds fill machinery of the default indexing mode is
    dead weight. ``accum_dtype`` names the dtype of the survivor sum and
    kept count (the precision policy's accum slot); ``None`` keeps
    ``r.dtype`` — the pre-policy program, byte-identical for fp32 inputs.
    """
    ad = r.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    big = jnp.asarray(jnp.finfo(r.dtype).max / 4, r.dtype)
    gathered = r.at[nbr_idx].get(
        mode="promise_in_bounds", indices_are_sorted=indices_sorted
    )                                                      # (N, deg_max, P)
    vals = jnp.where(byz_nbr[:, :, None], byz_msgs, gathered)
    masked = jnp.where(nbr_valid[:, :, None], vals, big)   # pads sort high
    s = jnp.sort(masked, axis=1)
    deg = nbr_valid.sum(axis=1).astype(jnp.int32)          # (N,)
    ranks = jnp.arange(masked.shape[1])[None, :, None]
    keep = (ranks >= F) & (ranks < (deg[:, None, None] - F))
    tsum = (s.astype(ad) * keep.astype(ad)).sum(axis=1)
    kept = jnp.maximum(deg - 2 * F, 0).astype(ad)
    return tsum, kept
