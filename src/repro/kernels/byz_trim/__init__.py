"""Fused neighbor trim-gather for the sparse Byzantine gossip core.

One Algorithm 2 gossip round's hot half is, per receiver j on the padded
neighbor-list layout (:class:`repro.core.graphs.NeighborList` — ``nbr_idx``
(N, deg_max) sender indices + ``nbr_valid`` padding mask):

    vals[j, k] = attack value        if sender nbr_idx[j, k] is Byzantine
                 r[nbr_idx[j, k]]    otherwise                  (gather)
    drop invalid slots, then the F largest and F smallest       (trim)
    trimmed_sum[j] = sum of survivors;  kept[j] = max(deg_j - 2F, 0)

applied independently per pair coordinate (the paper's scalar-dynamics
trick). The dense seed lowering broadcast an (N, N, m, m) message tensor
and ran ``jnp.sort`` over the full sender axis — O(N^2 m^2 log N) compute,
O(N^2 m^2) memory; on the neighbor-list layout the same contract costs
O(N deg_max m^2 F) with nothing larger than (N, deg_max, m^2) live.

:mod:`.ref` is the always-available XLA oracle (sort + rank mask; accepts a
traced F, which is what batched (topology, F) sweeps vmap over); :mod:`.ops`
hosts the ``backend="auto"|"xla"|"pallas"`` dispatch used by
:func:`repro.core.byzantine.make_byzantine_scan`; :mod:`.byz_trim` is the
fused Pallas kernel (F-round extremes extraction, no sort). The dense
``trimmed_neighbor_mean`` in :mod:`repro.core.byzantine` is retained purely
as the equivalence oracle for tests.
"""
from .ops import BACKENDS, resolve_backend, trim_gather, trim_gather_pairs
from .ref import trim_gather_ref

__all__ = [
    "trim_gather",
    "trim_gather_pairs",
    "trim_gather_ref",
    "resolve_backend",
    "BACKENDS",
]
