"""Pallas TPU kernel: flash-decode over a sliding-window KV cache (GQA).

One new token attends to a ``Wc``-entry cache. The cache axis is tiled into
``block_w`` slabs streamed HBM -> VMEM; a running (max, denominator,
accumulator) triple lives in VMEM scratch across the sequential grid steps
(online softmax — never materializes the (Wc,) score row in HBM).

GQA is handled in the index map: query head ``h`` reads KV head ``h // G``,
so KV slabs are fetched once per query-head group position — the compiler's
double-buffering pipelines the next slab during the current slab's FLOPs.

The per-batch valid length arrives via scalar prefetch (SMEM), masking
ring-buffer caches that are not yet full.

Roofline: decode attention is memory-bound (intensity ~ 1 MAC/byte); the
kernel's job is to keep the cache stream dense and skip fully-invalid slabs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["attn_decode_pallas"]

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
             block_w: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_blk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    base = j * block_w

    @pl.when(base < length)
    def _process():
        q = q_ref[...].reshape(1, -1).astype(jnp.float32) * scale  # (1, dh)
        k = k_ref[0, 0].astype(jnp.float32)                # (block_w, dh)
        v = v_ref[0, 0].astype(jnp.float32)                # (block_w, dh)
        s = k @ q.T                                        # (block_w, 1)
        idx = jax.lax.broadcasted_iota(jnp.int32, (block_w, 1), 0) + base
        s = jnp.where(idx < length, s, _NEG)

        m_prev = m_scr[0, 0]
        m_new = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (block_w, 1)
        l_scr[0, 0] = l_scr[0, 0] * alpha + p.sum()
        acc_scr[...] = acc_scr[...] * alpha + p.T @ v      # (1, dh)
        m_scr[0, 0] = m_new

    @pl.when(j == n_blk - 1)
    def _emit():
        o_ref[...] = (acc_scr[...] / l_scr[0, 0]).reshape(o_ref.shape).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("block_w", "interpret", "scale"))
def attn_decode_pallas(
    q: jnp.ndarray,        # (B, H, dh)
    k: jnp.ndarray,        # (B, Hkv, Wc, dh)
    v: jnp.ndarray,        # (B, Hkv, Wc, dh)
    lengths: jnp.ndarray,  # (B,) int32
    block_w: int = 512,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash-decode GQA attention. Returns (B, H, dh)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, dh = q.shape
    Hkv, Wc = k.shape[1], k.shape[2]
    G = H // Hkv
    if Wc % block_w != 0:
        raise ValueError(f"cache length {Wc} must be a multiple of {block_w}")
    scale_f = float(scale if scale is not None else dh**-0.5)

    grid = (B, H, Wc // block_w)
    out = pl.pallas_call(
        functools.partial(_kernel, block_w=block_w, scale=scale_f),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, dh), lambda b, h, j, lens: (b, h, 0)),
                pl.BlockSpec(
                    (1, 1, block_w, dh), lambda b, h, j, lens: (b, h // G, j, 0)
                ),
                pl.BlockSpec(
                    (1, 1, block_w, dh), lambda b, h, j, lens: (b, h // G, j, 0)
                ),
            ],
            out_specs=pl.BlockSpec((1, 1, dh), lambda b, h, j, lens: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
    return out
