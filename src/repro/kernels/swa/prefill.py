"""Pallas TPU kernel: flash attention forward for causal/sliding-window
prefill (GQA).

Grid ``(B*H, S/bq, S/bk)`` with the kv axis innermost (sequential on TPU):
a (m, l, acc) online-softmax triple lives in VMEM scratch per q block.
Blocks entirely outside the causal/window band are skipped with ``pl.when``
— for a window w the work per q block is O(w + bq) instead of O(S), which
is what makes the long_500k serve variant of the dense archs sub-quadratic
in practice (the jnp fallback computes the same masked math).

Forward-only (serving/prefill); training attention uses the XLA flash path
in ``models/layers.py:_chunked_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["swa_prefill_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, window: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = i * bq
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    # band check: this kv block intersects [q_pos - window + 1, q_pos]
    relevant = (k_lo <= q_hi)
    if window:
        relevant &= (k_lo + bk - 1) > (q_lo - window)

    @pl.when(relevant)
    def _process():
        q = q_ref[0].astype(jnp.float32) * scale      # (bq, dh)
        k = k_ref[0].astype(jnp.float32)              # (bk, dh)
        v = v_ref[0].astype(jnp.float32)              # (bk, dh)
        s = q @ k.T                                   # (bq, bk)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_lo
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + k_lo
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]                           # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("window", "bq", "bk", "scale", "interpret")
)
def swa_prefill_pallas(
    q: jnp.ndarray,   # (B, H, S, dh)
    k: jnp.ndarray,   # (B, Hkv, S, dh)
    v: jnp.ndarray,   # (B, Hkv, S, dh)
    window: int = 0,  # 0 = full causal
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) flash attention forward."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, S, dh = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    if S % bq or S % bk:
        raise ValueError(f"S={S} must divide bq={bq}, bk={bk}")
    scale_f = float(scale if scale is not None else dh**-0.5)

    qf = q.reshape(B * H, S, dh)
    kf = k.reshape(B * Hkv, S, dh)
    vf = v.reshape(B * Hkv, S, dh)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, window=window,
                          scale=scale_f),
        grid=(B * H, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
            # GQA: query-flat index bh = b*H + h maps to kv-flat
            # bh // G = b*Hkv + h//G (exact because G divides H)
            pl.BlockSpec((1, bk, dh), lambda bh, i, j, G=G: (bh // G, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, i, j, G=G: (bh // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dh)
