"""Pure-jnp oracle for single-token GQA attention over a (windowed) KV cache.

This is the whole per-layer attention cost of the decode_32k / long_500k
serve shapes: one query token attending to a cache of ``Wc`` entries, with
grouped KV heads and a per-batch valid length (ring-buffer caches may be
partially filled).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attn_decode_ref"]


def attn_decode_ref(
    q: jnp.ndarray,        # (B, H, dh)
    k: jnp.ndarray,        # (B, Hkv, Wc, dh)
    v: jnp.ndarray,        # (B, Hkv, Wc, dh)
    lengths: jnp.ndarray,  # (B,) int32 — number of valid cache entries
    scale: float | None = None,
) -> jnp.ndarray:
    """Returns (B, H, dh). Softmax in float32."""
    B, H, dh = q.shape
    Hkv, Wc = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32) * (scale if scale is not None else dh**-0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, G, dh)
    scores = jnp.einsum("bhgd,bhwd->bhgw", qg, kf)          # (B, Hkv, G, Wc)
    valid = jnp.arange(Wc)[None, :] < lengths[:, None]      # (B, Wc)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgw,bhwd->bhgd", p, vf)
    return out.reshape(B, H, dh).astype(q.dtype)
