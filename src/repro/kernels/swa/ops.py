"""Public entry point for windowed flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp

from .ref import attn_decode_ref
from .swa import attn_decode_pallas

__all__ = ["attn_decode", "attn_decode_ref"]


def attn_decode(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    block_w: int = 512,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Single-token GQA attention over a KV cache. (B,H,dh) out."""
    Wc = k.shape[2]
    if use_kernel and Wc % block_w == 0 and Wc >= block_w:
        return attn_decode_pallas(q, k, v, lengths, block_w=block_w)
    return attn_decode_ref(q, k, v, lengths)
