"""Public entry point for windowed flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import resolve_backend
from .ref import attn_decode_ref
from .swa import attn_decode_pallas

__all__ = ["attn_decode", "attn_decode_ref"]


def attn_decode(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    block_w: int = 512,
    *,
    backend: str = "auto",
) -> jnp.ndarray:
    """Single-token GQA attention over a KV cache. (B,H,dh) out.

    ``backend`` is the repo-wide ``"auto"|"xla"|"pallas"`` switch (the
    seed-era ``use_kernel`` alias is gone); ragged windows still fall
    back to :func:`attn_decode_ref` — the oracle the Pallas path is
    tested against."""
    Wc = k.shape[2]
    if resolve_backend(backend) == "pallas" \
            and Wc % block_w == 0 and Wc >= block_w:
        return attn_decode_pallas(q, k, v, lengths, block_w=block_w)
    return attn_decode_ref(q, k, v, lengths)
