"""Public entry point for windowed flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import resolve_use_kernel
from .ref import attn_decode_ref
from .swa import attn_decode_pallas

__all__ = ["attn_decode", "attn_decode_ref"]


def attn_decode(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    block_w: int = 512,
    use_kernel: bool = True,
    *,
    backend: str | None = None,
) -> jnp.ndarray:
    """Single-token GQA attention over a KV cache. (B,H,dh) out.

    ``backend`` (``"auto"|"xla"|"pallas"``) overrides ``use_kernel`` when
    given; ragged windows still fall back to :func:`attn_decode_ref` — the
    oracle the Pallas path is tested against."""
    Wc = k.shape[2]
    if resolve_use_kernel(backend, use_kernel) \
            and Wc % block_w == 0 and Wc >= block_w:
        return attn_decode_pallas(q, k, v, lengths, block_w=block_w)
    return attn_decode_ref(q, k, v, lengths)
