"""Pure-XLA oracle for the fused edge-scatter: gather + where + segment_sum.

This is exactly the lowering the sparse push-sum core shipped before the
Pallas kernel existed, factored out so both backends share one contract:

    rho_new[e] = sigma[src[e]] if live[e] else rho[e]
    recv[v]    = sum_{e : dst[e] == v} (rho_new[e] - rho[e])

``sigma`` carries the value columns and the mass column stacked as one
(N, d+1) matrix (see :func:`repro.core.pushsum.sparse_pushsum_step`), so a
single segment reduction serves both the z and m recursions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["edge_scatter_ref"]


def edge_scatter_ref(
    sigma: jnp.ndarray,   # (N, D) staged cumulative send per node
    rho: jnp.ndarray,     # (E, D) last heard cumulative per edge
    live: jnp.ndarray,    # (E,) bool — operational AND valid this round
    src: jnp.ndarray,     # (E,) int32
    dst: jnp.ndarray,     # (E,) int32
    *,
    indices_sorted: bool = False,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(rho_new (E, D), recv (N, D))``. Any edge order is legal;
    ``indices_sorted=True`` asserts ``dst`` is non-decreasing (the
    :func:`repro.core.graphs.sort_by_dst` / ``partition_edge_list`` layout)
    so the segment reduction skips its internal argsort.

    ``accum_dtype`` names the dtype of the increment reduction — the
    precision-policy split (:mod:`repro.core.precision`): the latched
    ``rho_new`` stays in the storage dtype (the bandwidth knob) while the
    per-receiver segment sum runs full-precision. ``None`` keeps the
    input dtype (the pre-policy program, byte-identical for fp32 inputs
    because a same-dtype cast is a traced no-op)."""
    n = sigma.shape[0]
    ad = rho.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    rho_new = jnp.where(live[:, None], sigma[src], rho)
    recv = jax.ops.segment_sum(
        rho_new.astype(ad) - rho.astype(ad), dst, num_segments=n,
        indices_are_sorted=indices_sorted,
    )
    return rho_new, recv
