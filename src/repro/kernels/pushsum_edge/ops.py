"""Backend dispatch for the fused push-sum edge scatter.

``edge_scatter(..., backend=...)`` is the single entry point the sparse
push-sum core calls per round:

``"xla"``     — gather + ``segment_sum`` (:mod:`.ref`); runs anywhere.
``"pallas"``  — the fused streaming kernel (:mod:`.pushsum_edge`);
                compiled on TPU, interpreter mode elsewhere (equivalence
                testing only — interpret mode is not a fast path).
``"auto"``    — ``"pallas"`` on a TPU default backend, else ``"xla"``.

Resolution is host-side and static (the choice changes the traced program),
so callers thread ``backend`` through ``static_argnames`` when jitting.
"""
from __future__ import annotations

import jax.numpy as jnp

# Re-exported for back-compat: the resolver now lives in
# repro.kernels.dispatch and is shared by every kernel family.
from ..dispatch import BACKENDS, resolve_backend
from .pushsum_edge import edge_scatter_pallas
from .ref import edge_scatter_ref

__all__ = ["edge_scatter", "resolve_backend", "BACKENDS"]


def edge_scatter(
    sigma: jnp.ndarray,   # (N, D)
    rho: jnp.ndarray,     # (E, D)
    live: jnp.ndarray,    # (E,) bool
    src: jnp.ndarray,     # (E,) int32
    dst: jnp.ndarray,     # (E,) int32
    backend: str = "auto",
    *,
    block_e: int = 4096,
    interpret: bool | None = None,
    indices_sorted: bool = False,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused mask-latch + per-receiver increment sum; see package docstring.

    Returns ``(rho_new (E, D), recv (N, D))``. ``indices_sorted=True``
    promises a dst-sorted edge index, letting the XLA lowering drop one
    argsort (the Pallas kernel already streams in dst order and ignores it).
    ``accum_dtype`` names the dtype of the ``recv`` reduction (the
    precision policy's accum slot — see :mod:`repro.core.precision`);
    ``None`` keeps the input dtype.
    """
    if resolve_backend(backend) == "xla":
        return edge_scatter_ref(sigma, rho, live, src, dst,
                                indices_sorted=indices_sorted,
                                accum_dtype=accum_dtype)
    return edge_scatter_pallas(
        sigma, rho, live, src, dst, block_e=block_e, interpret=interpret,
        accum_dtype=accum_dtype,
    )
