"""Pallas kernel: fused push-sum edge scatter over a dst-sorted edge index.

One call covers the whole delivery + integration half of a robust push-sum
round (Su '18 Alg. 1 lines 6-11) in a single streaming pass over the edge
list — gather ``sigma[src]``, latch it into ``rho`` on live edges, and
accumulate the per-receiver sum of increments — replacing XLA's gather +
generic scatter lowering of ``jax.ops.segment_sum``.

Design (see /opt/skills/guides/pallas_guide.md)
-----------------------------------------------
* Grid: 1-D over edge blocks of ``block_e`` edges. TPU grids execute
  sequentially on a core, which the kernel exploits: ``recv`` is a full
  (N, D) VMEM-resident output with a constant index map, zeroed at block 0
  and accumulated into by every block (the matmul-K-loop accumulator
  pattern). ``sigma`` (N, D) is likewise resident — at the target workload
  (N ~ 1e5, D = d+1 with d small) it is a few MB, well under VMEM.
* Within a block the per-receiver reduction uses the *sorted-run* trick:
  with edges pre-sorted by ``dst`` (:func:`repro.core.graphs.sort_by_dst`)
  each receiver's edges form one contiguous run, so a *segmented* scan
  along the edge axis (log2(block_e) flag-carrying Hillis-Steele steps,
  pure VPU shift+add) leaves each run's inclusive sum at its last edge and
  the scatter touches each receiver row exactly once per block:
  ``recv[v] += seg[end]``. Unique indices are the fast path Mosaic can
  vectorize — the thing XLA's sorted-scatter lowering never recovers on
  its own. The scan is segmented rather than a plain cumsum with boundary
  differences precisely because push-sum's z/m ratio amplifies absolute
  error by 1/m (m decays geometrically): subtracting two large
  cross-segment prefixes to recover a small segment sum cancels
  catastrophically, while segment-local partial sums keep the error at
  the run's own reduction scale.
* Correctness does NOT require sortedness: an unsorted index just breaks
  runs into more fragments, each accumulated with scatter-add semantics.
  Sorting is purely what collapses the update count to O(distinct dst).
* A run spanning a block boundary is finished by the next block: the first
  edge of every block opens a fresh run (``c_prev[0] == 0``), and the
  trailing partial sum was already flushed by the previous block's
  ``is_end[-1]`` update, so the two partials add up in ``recv``.
* Padding edges (to a multiple of ``block_e``) are appended with
  ``live=False`` and ``dst = N - 1``: their increment is exactly zero, so
  the only effect is a zero added to the last receiver row.

The feature axis D = d+1 is small for consensus workloads, which
underutilizes the 128-wide lanes; the streaming axis (edges) carries the
throughput. ``interpret=None`` auto-selects interpreter mode off-TPU so CPU
CI validates the identical program (tests/test_pushsum_edge_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["edge_scatter_pallas"]


def _segmented_cumsum(delta, is_first):
    """Inclusive scan over the edge axis that restarts at run boundaries.

    Flag-carrying Hillis-Steele: at stride s, position i absorbs i-s only
    if no segment start lies in (i-s, i]; flags OR upward so the check
    stays O(1) per step. log2(BE) static steps, shift+add only, and every
    partial sum is segment-local (no cross-segment cancellation).
    """
    v, f = delta, is_first
    n = delta.shape[0]
    s = 1
    while s < n:
        v_prev = jnp.concatenate([jnp.zeros_like(v[:s]), v[:-s]], axis=0)
        f_prev = jnp.concatenate(
            [jnp.ones((min(s, n),), jnp.bool_), f[:-s]], axis=0
        )
        v = jnp.where(f[:, None], v, v + v_prev)
        f = f | f_prev
        s *= 2
    return v


def _kernel(sigma_ref, rho_ref, live_ref, src_ref, dst_ref,
            rho_out_ref, recv_ref):
    @pl.when(pl.program_id(0) == 0)
    def _():
        recv_ref[...] = jnp.zeros_like(recv_ref)

    sigma = sigma_ref[...]                      # (N, D) resident
    rho = rho_ref[...]                          # (BE, D)
    live = live_ref[...]                        # (BE,)
    src = src_ref[...]                          # (BE,)
    dst = dst_ref[...]                          # (BE,)

    # --- mask-latch: live edges adopt the sender's staged cumulative ---
    gathered = jnp.take(sigma, src, axis=0)     # (BE, D)
    rho_new = jnp.where(live[:, None], gathered, rho)
    rho_out_ref[...] = rho_new

    # --- per-receiver segment sum of increments via sorted runs ---
    # the accumulator dtype is recv's (the policy's accum slot): latched
    # state streams at storage precision, the reduction runs full-precision
    acc = recv_ref.dtype
    delta = rho_new.astype(acc) - rho.astype(acc)  # zero on dead/pad edges
    change = dst[1:] != dst[:-1]                # (BE-1,) run boundaries
    one = jnp.ones((1,), jnp.bool_)
    is_end = jnp.concatenate([change, one])     # last edge of each run
    is_first = jnp.concatenate([one, change])   # first edge of each run
    seg = _segmented_cumsum(delta, is_first)    # run-local inclusive sums
    upd = jnp.where(is_end[:, None], seg, 0.0)
    recv_ref[...] = recv_ref[...].at[dst].add(upd)


@functools.partial(
    jax.jit, static_argnames=("block_e", "interpret", "accum_dtype")
)
def edge_scatter_pallas(
    sigma: jnp.ndarray,   # (N, D) staged cumulative send per node
    rho: jnp.ndarray,     # (E, D) last heard cumulative per edge
    live: jnp.ndarray,    # (E,) bool — operational AND valid this round
    src: jnp.ndarray,     # (E,) int32
    dst: jnp.ndarray,     # (E,) int32, pre-sorted ascending for the fast path
    *,
    block_e: int = 4096,
    interpret: bool | None = None,
    accum_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused edge scatter -> ``(rho_new (E, D), recv (N, D))``.

    Matches :func:`repro.kernels.pushsum_edge.ref.edge_scatter_ref` to fp32
    reduction order. E is padded to a multiple of ``block_e`` with inert
    edges; the pad rows are sliced off ``rho_new``. ``accum_dtype`` names
    the dtype of the ``recv`` accumulator (the precision policy's accum
    slot; casts happen at the kernel block boundary) — ``None`` keeps the
    input dtype, the pre-policy program.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    acc = sigma.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    n, D = sigma.shape
    E = rho.shape[0]
    pad = (-E) % block_e
    if pad:
        rho = jnp.pad(rho, ((0, pad), (0, 0)))
        live = jnp.pad(live, (0, pad))                       # False
        src = jnp.pad(src, (0, pad))                         # node 0
        dst = jnp.pad(dst, (0, pad), constant_values=n - 1)  # inert target
    Ep = E + pad

    rho_new, recv = pl.pallas_call(
        _kernel,
        grid=(Ep // block_e,),
        in_specs=[
            pl.BlockSpec((n, D), lambda i: (0, 0)),          # sigma resident
            pl.BlockSpec((block_e, D), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, D), lambda i: (i, 0)),
            pl.BlockSpec((n, D), lambda i: (0, 0)),          # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ep, D), rho.dtype),
            jax.ShapeDtypeStruct((n, D), acc),
        ],
        interpret=interpret,
    )(sigma, rho, live, src, dst)
    return rho_new[:E], recv
