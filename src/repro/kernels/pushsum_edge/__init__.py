"""Fused edge-scatter kernel for the robust push-sum delivery/integration.

One push-sum round's hot half is, per directed edge e (src -> dst):

    rho_new[e] = sigma[src[e]]  if live[e] else rho[e]     (mask-latch)
    recv[v]   += rho_new[e] - rho[e]  for v = dst[e]       (integration)

XLA lowers this to a gather plus a generic ``segment_sum`` scatter per
round; with the edge index pre-sorted by ``dst``
(:func:`repro.core.graphs.sort_by_dst`) the whole thing is one streaming
pass over E with contiguous per-receiver segments, which is what the
Pallas kernel in :mod:`.pushsum_edge` implements. :mod:`.ref` is the
always-available XLA fallback and the equivalence oracle; :mod:`.ops`
hosts the ``backend="auto"|"xla"|"pallas"`` dispatch used by
:func:`repro.core.pushsum.sparse_pushsum_step`.
"""
from .ops import edge_scatter, resolve_backend
from .ref import edge_scatter_ref

__all__ = ["edge_scatter", "edge_scatter_ref", "resolve_backend"]
