"""Repo-wide kernel backend dispatch — the ONE resolver every ops.py uses.

``backend`` is the user-facing switch on every kernel entry point:

``"xla"``     — the pure-jnp reference lowering; runs anywhere and is the
                equivalence oracle the Pallas path is tested against.
``"pallas"``  — the fused Pallas TPU kernel; compiled on TPU, interpreter
                mode elsewhere (equivalence testing only, not a fast path).
``"auto"``    — ``"pallas"`` on a TPU default backend, else ``"xla"``.

Resolution is host-side and static (the choice changes the traced
program), so callers thread ``backend`` through ``static_argnames`` when
jitting. Historically this lived in ``pushsum_edge/ops.py`` and the other
engine kernels imported it from there; it is now owned here and the
model-stack kernels (``swa``, ``wkv6``, ``trimmed_mean``) speak the same
vocabulary. Their seed-era ``use_kernel`` boolean alias was removed in
PR 10 (the ExecutionPlan redesign): ``backend=`` is the only dispatch
switch, and the :mod:`repro.statics.signatures` lint keeps ``use_kernel``
from coming back.
"""
from __future__ import annotations

import jax

__all__ = ["BACKENDS", "resolve_backend"]

BACKENDS = ("auto", "xla", "pallas")


def resolve_backend(backend: str) -> str:
    """Map ``"auto"`` to the platform default; validate explicit choices."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(
            f"backend must be one of {BACKENDS}, got {backend!r}"
        )
    return backend
