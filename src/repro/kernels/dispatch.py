"""Repo-wide kernel backend dispatch — the ONE resolver every ops.py uses.

``backend`` is the user-facing switch on every kernel entry point:

``"xla"``     — the pure-jnp reference lowering; runs anywhere and is the
                equivalence oracle the Pallas path is tested against.
``"pallas"``  — the fused Pallas TPU kernel; compiled on TPU, interpreter
                mode elsewhere (equivalence testing only, not a fast path).
``"auto"``    — ``"pallas"`` on a TPU default backend, else ``"xla"``.

Resolution is host-side and static (the choice changes the traced
program), so callers thread ``backend`` through ``static_argnames`` when
jitting. Historically this lived in ``pushsum_edge/ops.py`` and the other
engine kernels imported it from there; it is now owned here so the
model-stack kernels (``swa``, ``wkv6``, ``trimmed_mean``) share the same
vocabulary — their legacy ``use_kernel`` booleans remain supported and are
bridged through :func:`resolve_use_kernel`.
"""
from __future__ import annotations

import jax

__all__ = ["BACKENDS", "resolve_backend", "resolve_use_kernel"]

BACKENDS = ("auto", "xla", "pallas")


def resolve_backend(backend: str) -> str:
    """Map ``"auto"`` to the platform default; validate explicit choices."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(
            f"backend must be one of {BACKENDS}, got {backend!r}"
        )
    return backend


def resolve_use_kernel(backend: str | None, use_kernel: bool) -> bool:
    """Bridge the repo-wide ``backend`` switch onto a kernel whose internal
    dispatch is the legacy ``use_kernel`` boolean.

    ``backend=None`` (the default everywhere) preserves the caller's
    ``use_kernel`` bit exactly; an explicit ``backend`` wins over it, with
    ``"auto"`` resolving per platform like every other kernel.
    """
    if backend is None:
        return use_kernel
    return resolve_backend(backend) == "pallas"
