from .roofline import (
    parse_collectives,
    roofline_terms,
    HW,
    model_flops,
)

__all__ = ["parse_collectives", "roofline_terms", "HW", "model_flops"]
