"""Render EXPERIMENTS.md tables from the dry-run result JSONs.

    PYTHONPATH=src python -m repro.analysis.render results/dryrun_single.json
"""
import json
import sys


def fmt_row(r):
    t = r["roofline"]
    m = r["analytic_memory"]
    coll = r["collectives"]["bytes_by_kind"]
    top_coll = max(coll, key=coll.get) if any(coll.values()) else "-"
    return (
        f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
        f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
        f"**{t['dominant']}** | {t['useful_flop_ratio']:.2f} | "
        f"{m['total_gb']:.1f} | {'yes' if m['fits_16gb'] else 'NO'} | "
        f"{top_coll} |"
    )


def main():
    path = sys.argv[1]
    with open(path) as f:
        recs = json.load(f)
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " useful | mem GB/dev | fits | top collective |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("ok"):
            print(fmt_row(r))
        else:
            print(f"| {r['arch']} | {r['shape']} | FAIL: {r['error'][:60]} |"
                  + " |" * 7)


if __name__ == "__main__":
    main()
