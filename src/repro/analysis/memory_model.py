"""Analytic per-device memory model.

``compiled.memory_analysis()`` on the CPU dry-run backend is a usable
*relative* signal but systematically pessimistic for TPU (no TPU fusion/
scheduling, nested-loop accounting is worst-case). For the fits-in-HBM
judgement we therefore compute the engineering truth analytically from the
config + sharding layout — every term below is exact up to small transients
— and report the XLA number alongside it.

Terms (train):
    params            P * 2B   / param_shards
    adam moments      P * 8B   / param_shards
    grad accumulator  P * 4B   / grad_shards       (n_micro > 1)
    saved residuals   (L / remat_group) * tok_micro_dev * d * 2B
    logits + CE f32   2 * tok_micro_dev * vocab/model * 4B
    transient slack   25% of the above

Serve adds the KV cache / recurrent state per device instead of optimizer
terms.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, InputShape

__all__ = ["train_memory_gb", "serve_memory_gb", "pushsum_device_memory_gb"]


def _shards(mesh_shape: dict, fsdp: bool) -> tuple[int, int]:
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    param_shards = model * (data if fsdp else 1)
    return param_shards, data


def train_memory_gb(
    cfg: ArchConfig, shape: InputShape, mesh_shape: dict,
    fsdp: bool, n_micro: int, worker_axis: bool = False,
    moment_bytes: int = 4,
) -> dict:
    P = cfg.param_count()
    param_shards, data = _shards(mesh_shape, fsdp)
    model = mesh_shape.get("model", 1)
    if worker_axis:
        # decentralized layout: every worker holds a full (TP-sharded) copy
        param_shards = model
    tok_dev = shape.global_batch * shape.seq_len // data
    tok_micro = tok_dev // max(n_micro, 1)

    params_b = P * 2 / param_shards
    moments_b = P * 2 * moment_bytes / param_shards
    gacc_b = (P * 4 / param_shards) if n_micro > 1 else 0.0
    L_eff = max(cfg.n_layers // max(cfg.remat_group, 1), 1)
    resid_b = L_eff * tok_micro * cfg.d_model * 2
    logits_b = 2 * tok_micro * (cfg.vocab / model) * 4
    work_b = 0.25 * (resid_b + logits_b + params_b)

    total = params_b + moments_b + gacc_b + resid_b + logits_b + work_b
    return {
        "params_gb": round(params_b / 1e9, 3),
        "optimizer_gb": round(moments_b / 1e9, 3),
        "grad_acc_gb": round(gacc_b / 1e9, 3),
        "residuals_gb": round(resid_b / 1e9, 3),
        "logits_gb": round(logits_b / 1e9, 3),
        "total_gb": round(total / 1e9, 3),
        "fits_16gb": bool(total < 16e9),
    }


def pushsum_device_memory_gb(
    N: int, E: int, d: int = 1, n_shards: int = 1,
    scenarios_per_device: int = 1,
) -> dict:
    """Per-device residency of the (edge-partitioned) sparse push-sum.

    Terms, all f32, per scenario resident on this device
    (:class:`repro.core.pushsum.SparsePushSumState` plus the per-round
    transients of the sharded step):

        node state      N (2d + 2) * 4     z/sigma (N, d) + m/sigma_m (N,)
                                           — REPLICATED across graph shards
        edge state      ceil(E / S) (d+1) * 4    rho + rho_m, shard-local
        mask draw       S * ceil(E / S)          full (E_pad,) Bernoulli
                                           bits (bit-identity contract of
                                           shard_edge_mask) as bool
        halo operand    N (d + 1) * 4      the psum'd recv/recv_m pair
        transient slack 25% of the above

    Multiply by ``scenarios_per_device`` for the 2-D mesh (a data-axis row
    holds a scenario batch). This is the analytic prediction
    ``repro.statics.memory.validate_bench`` checks the measured sharded
    BENCH rows against; the unpartitioned mode is ``n_shards=1`` (where
    the halo term drops — no collective exists).
    """
    S = max(int(n_shards), 1)
    e_shard = -(-E // S)
    node_b = N * (2 * d + 2) * 4
    edge_b = e_shard * (d + 1) * 4
    mask_b = S * e_shard
    halo_b = N * (d + 1) * 4 if S > 1 else 0.0
    per_scenario = node_b + edge_b + mask_b + halo_b
    total = 1.25 * per_scenario * max(int(scenarios_per_device), 1)
    return {
        "node_state_gb": round(node_b / 1e9, 6),
        "edge_state_gb": round(edge_b / 1e9, 6),
        "mask_draw_gb": round(mask_b / 1e9, 6),
        "halo_gb": round(halo_b / 1e9, 6),
        "total_gb": round(total / 1e9, 6),
        "fits_16gb": bool(total < 16e9),
    }


def serve_memory_gb(
    cfg: ArchConfig, shape: InputShape, mesh_shape: dict, cache_len: int,
    weight_gathered: bool = False,
) -> dict:
    P = cfg.param_count()
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    b_dev = max(shape.global_batch // data, 1)

    params_b = P * 2 / (model * (data if weight_gathered else 1))
    cache_b = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.mixer_of(i)
        if kind in ("attn", "swa"):
            wlen = min(cache_len, cfg.window) if (kind == "swa" and cfg.window) \
                else cache_len
            # heads shard over model when divisible; otherwise the cache
            # falls back to sequence-parallel sharding over model
            if cfg.n_kv_heads % model == 0:
                shard = model
            elif wlen % model == 0:
                shard = model
            else:
                shard = 1
            cache_b += 2 * b_dev * cfg.n_kv_heads * wlen * cfg.head_dim * 2 \
                / shard
        elif kind == "wkv6":
            H = cfg.d_model // cfg.wkv_head_dim
            cache_b += b_dev * max(H / model, 1) * cfg.wkv_head_dim**2 * 4
        elif kind == "rglru":
            cache_b += b_dev * (cfg.rnn_width / model) * (4 + 3 * 2)
    if cfg.encoder_layers:
        cache_b += b_dev * cfg.n_frames * cfg.d_model * 2
    if shape.kind == "prefill":
        # prefill working set: one layer's activations + q/k/v in f32-ish
        act_b = 6 * b_dev * shape.seq_len * cfg.d_model * 2
    else:
        act_b = 4 * b_dev * cfg.d_model * 4
    work_b = 0.25 * params_b + act_b

    total = params_b + cache_b + work_b
    return {
        "params_gb": round(params_b / 1e9, 3),
        "cache_gb": round(cache_b / 1e9, 3),
        "work_gb": round(work_b / 1e9, 3),
        "total_gb": round(total / 1e9, 3),
        "fits_16gb": bool(total < 16e9),
    }
