"""Roofline analysis from compiled HLO (no hardware required).

Per (arch x shape x mesh) we derive three time-lower-bound terms from the
dry-run's compiled artifact:

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = wire_bytes_per_device / link_bw            (~50 GB/s ICI)

``cost_analysis()`` supplies per-device FLOPs/bytes. Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and, for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
estimate bytes-on-the-wire per device with the standard ring-algorithm
factors:

    all-reduce      2 (n-1)/n * operand bytes
    all-gather        (n-1)/n * result  bytes
    reduce-scatter    (n-1)/n * operand bytes
    all-to-all        (n-1)/n * operand bytes
    collective-permute          operand bytes

where n is the replica-group size parsed from the op's ``replica_groups``.

The dominant term is the bottleneck the perf loop iterates on. We also report
MODEL_FLOPS / (HLO_FLOPs * chips): the fraction of compiled compute that is
"useful" model math (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = [
    "HW", "parse_collectives", "roofline_terms", "model_flops",
    "pushsum_halo_wire_bytes",
]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (the assignment's hardware target)."""

    peak_flops: float = 197e12     # bf16
    hbm_bw: float = 819e9          # bytes/s
    link_bw: float = 50e9          # bytes/s per ICI link
    hbm_bytes: float = 16e9


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string: 'bf16[2,3]' or '(f32[4], u32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).strip()
        return len(first.split(",")) if first else total_devices
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int) -> dict[str, Any]:
    """Scan optimized HLO for collectives; returns per-kind wire bytes
    (per device) and op counts."""
    bytes_by_kind: dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    count_by_kind: dict[str, int] = {k: 0 for k in _COLL_KINDS}

    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)",
                     line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        kind = None
        for k in _COLL_KINDS:
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        n = _group_size(line, total_devices)
        result_bytes = _shape_bytes(result_type)
        # operand types appear inside the call parens; for these ops operand
        # and result bytes relate simply:
        if kind == "all-gather":
            wire = (n - 1) / max(n, 1) * result_bytes
        elif kind == "all-reduce":
            wire = 2 * (n - 1) / max(n, 1) * result_bytes
        elif kind == "reduce-scatter":
            wire = (n - 1) / max(n, 1) * result_bytes * n  # operand = result*n
        elif kind == "all-to-all":
            wire = (n - 1) / max(n, 1) * result_bytes
        else:  # collective-permute
            wire = result_bytes
        bytes_by_kind[kind] += wire
        count_by_kind[kind] += 1

    total = sum(bytes_by_kind.values())
    return {
        "wire_bytes_per_device": total,
        "bytes_by_kind": bytes_by_kind,
        "count_by_kind": count_by_kind,
    }


def model_flops(arch, shape) -> float:
    """Useful model FLOPs for the step (global, all chips).

    train:   6 * N_active * tokens  (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch   (one token per sequence)
    """
    n = arch.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def pushsum_halo_wire_bytes(N: int, d: int, n_shards: int, *,
                            variant: str = "psum",
                            storage_bytes: int = 4) -> float:
    """Per-device wire bytes of one edge-partitioned push-sum round.

    The halo combine of :func:`repro.core.pushsum.sparse_pushsum_step`
    (``graph_axis=``) merges ``recv`` (N, d) and ``recv_m`` (N,) partials
    — an N (d+1) element operand in the accum dtype (fp32) — across the
    graph axis. Two lowerings, selected by the step's ``halo=`` argument:

    ``variant="psum"``
        two all-reduces over the fp32 operand; ring factor
        ``2 (n-1)/n * N (d+1) * 4`` as in :func:`parse_collectives`.
    ``variant="scatter"``
        ``psum_scatter`` + ``all_gather``: the reduce-scatter leg moves the
        fp32 partials at ``(n-1)/n * N (d+1) * 4``, and the re-broadcast
        gather leg moves the result AFTER the downcast to the policy's
        storage dtype — ``(n-1)/n * N (d+1) * storage_bytes``. Under bf16
        storage (``storage_bytes=2``) the wire total drops to 3/4 of the
        psum variant; under fp32 the two variants move identical bytes
        (the split only changes reduce order).

    The per-round out-degree psum is hoisted out of the scan, so it does
    not appear in the steady-state per-step budget. ``n_shards <= 1`` is
    the unpartitioned mode: no collective, 0 bytes.
    """
    if n_shards <= 1:
        return 0.0
    if variant not in ("psum", "scatter"):
        raise ValueError(
            f"variant must be 'psum' or 'scatter', got {variant!r}")
    elems = N * (d + 1)
    ring = (n_shards - 1) / n_shards
    if variant == "psum":
        return 2.0 * ring * elems * 4
    return ring * elems * (4 + float(storage_bytes))


def roofline_terms(
    cost: dict[str, float],
    coll: dict[str, Any],
    n_devices: int,
    mf: float,
    hw: HW = HW(),
) -> dict[str, Any]:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = float(coll["wire_bytes_per_device"])

    t_compute = flops_dev / hw.peak_flops
    t_memory = bytes_dev / hw.hbm_bw
    t_collective = wire_dev / hw.link_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    useful = mf / max(flops_dev * n_devices, 1.0)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire_dev,
        "model_flops_total": mf,
        "useful_flop_ratio": useful,
        "bound_step_time_s": max(terms.values()),
    }
