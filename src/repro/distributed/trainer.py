"""Train-step builders.

Two execution modes:

* ``gspmd`` (aggregator "mean"): one jit'd SPMD program; the gradient
  all-reduce is implicit. Supports FSDP param sharding — this is the
  plain-production baseline the paper's robust modes are compared against.

* ``robust`` (aggregator != "mean"): decentralized training. Every data
  worker keeps its OWN model copy (leading worker axis on every param leaf,
  sharded over (pod, data)) and evolves it by the paper's
  consensus + innovation loop: local grads (innovation) -> robust
  aggregation across workers (consensus) -> local AdamW step. Executed as a
  ``shard_map`` with (pod, data) manual and ``model`` auto, so tensor
  parallelism inside the model stays GSPMD while worker identity is
  explicit. Byzantine workers are simulated by corrupting the gradient of
  the configured worker indices before aggregation (the strongest in-scope
  attack: sign-flip + rescale).

Consensus error across worker copies is observable via ``param_spread`` —
the training-side analogue of Theorem 1's consensus-error bound.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import compat
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update
from .aggregation import AGGREGATORS, AggregatorConfig
from .sharding import batch_axes, batch_specs, param_specs, opt_state_specs

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    arch: ArchConfig
    agg: AggregatorConfig = AggregatorConfig()
    opt: AdamWConfig = AdamWConfig()
    fsdp: bool = False
    n_micro: int = 1                          # gradient-accumulation steps
    byzantine_workers: tuple[int, ...] = ()   # simulated compromised workers
    byzantine_scale: float = 10.0
    seed: int = 0


# ---------------------------------------------------------------------------
# GSPMD baseline
# ---------------------------------------------------------------------------

def make_train_step(tc: TrainConfig, mesh: Mesh):
    if tc.agg.kind == "mean":
        return _make_gspmd_step(tc, mesh)
    return _make_robust_step(tc, mesh)


def _loss(params, cfg, batch):
    return M.loss_fn(
        params, cfg, batch["tokens"], batch["labels"],
        patch_embeds=batch.get("patch_embeds"), frames=batch.get("frames"),
    )


def _micro_split(batch, n_micro: int):
    """(B, ...) -> (n_micro, B/n_micro, ...) with stride-n_micro interleave,
    so every data shard contributes equally to every micro-batch (the
    leading micro axis never crosses shard boundaries)."""

    def split(x):
        B = x.shape[0]
        return x.reshape((B // n_micro, n_micro) + x.shape[1:]).swapaxes(0, 1)

    return jax.tree_util.tree_map(split, batch)


def _grads_microbatched(params, cfg, batch, n_micro: int, grad_shardings=None):
    """Gradient accumulation: scan over micro-batches, f32 accumulator.
    Peak activation memory = one micro-batch's worth. ``grad_shardings``
    (NamedSharding tree) pins the accumulator to the param layout so GSPMD
    cannot replicate it."""
    constrain = (
        (lambda t: jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, t, grad_shardings))
        if grad_shardings is not None else (lambda t: t)
    )
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(_loss)(params, cfg, batch)
        return loss, constrain(grads)
    micro = _micro_split(batch, n_micro)
    gz = constrain(jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    ))

    def body(carry, mb):
        loss_acc, gacc = carry
        l, g = jax.value_and_grad(_loss)(params, cfg, mb)
        gacc = constrain(jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32), gacc, g
        ))
        return (loss_acc + l, gacc), None

    (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), gz), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss_sum * inv, grads


def _make_gspmd_step(tc: TrainConfig, mesh: Mesh):
    cfg = tc.arch

    def shardings(params_like, batch_keys=("tokens", "labels")):
        pspecs = param_specs(params_like, cfg, mesh, fsdp=tc.fsdp)
        ospecs = opt_state_specs(pspecs)
        bspec = _batch_spec_tree(mesh, batch_keys)
        return pspecs, ospecs, bspec

    def train_step_factory(params_like, batch_keys=("tokens", "labels")):
        pspecs, _, _ = shardings(params_like, batch_keys)
        # Pinning the grad accumulator to the param layout is a memory
        # optimization only; jax 0.4.x's XLA CPU SPMD partitioner miscompiles
        # the constrained backward pass (grads off by O(1) relative), so the
        # constraint is applied on modern jax exclusively.
        gshard = None
        if compat.HAS_AXIS_TYPE:
            gshard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )

        def train_step(params, opt_state, batch):
            loss, grads = _grads_microbatched(
                params, cfg, batch, tc.n_micro, grad_shardings=gshard
            )
            new_params, new_opt = adamw_update(tc.opt, grads, opt_state, params)
            return new_params, new_opt, loss

        return train_step

    return train_step_factory, shardings


def _batch_spec_tree(mesh: Mesh, keys=("tokens", "labels")):
    b = batch_specs(mesh)
    full = {
        "tokens": b, "labels": b,
        "patch_embeds": P(batch_axes(mesh), None, None),
        "frames": P(batch_axes(mesh), None, None),
    }
    return {k: full[k] for k in keys}


# ---------------------------------------------------------------------------
# decentralized robust step
# ---------------------------------------------------------------------------

def _make_robust_step(tc: TrainConfig, mesh: Mesh):
    cfg = tc.arch
    baxes = batch_axes(mesh)
    pod_axis = "pod" if "pod" in mesh.axis_names else None
    data_axis = "data"
    agg_fn = AGGREGATORS[tc.agg.kind]
    n_workers = mesh.shape[data_axis] * (mesh.shape["pod"] if pod_axis else 1)

    def per_worker(params_w, opt_w, batch, step_key):
        # params_w: leading worker axis of size 1 on every leaf (manual view)
        params = jax.tree_util.tree_map(lambda x: x[0], params_w)
        opt_state = jax.tree_util.tree_map(lambda x: x[0], opt_w)
        loss, grads = _grads_microbatched(params, cfg, batch, tc.n_micro)

        # --- simulated Byzantine workers: colluding sign-flip attack ---
        if tc.byzantine_workers:
            widx = jax.lax.axis_index(data_axis)
            if pod_axis:
                widx = widx + jax.lax.axis_index(pod_axis) * mesh.shape[data_axis]
            is_byz = jnp.zeros((), bool)
            for b in tc.byzantine_workers:
                is_byz = is_byz | (widx == b)
            grads = jax.tree_util.tree_map(
                lambda g: jnp.where(is_byz, -tc.byzantine_scale * g, g), grads
            )

        agg = agg_fn(grads, tc.agg, data_axis, pod_axis, step_key)
        new_params, new_opt = adamw_update(tc.opt, agg, opt_state, params)
        loss_mean = jax.lax.pmean(
            loss, (pod_axis, data_axis) if pod_axis else (data_axis,)
        )
        expand = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        return expand(new_params), expand(new_opt), loss_mean

    def shardings(params_like, batch_keys=("tokens", "labels")):
        pspecs = param_specs(params_like, cfg, mesh, worker_axis=True)
        ospecs = {
            "m": pspecs, "v": pspecs,
            "step": P(batch_axes(mesh)),
        }
        return pspecs, ospecs, _batch_spec_tree(mesh, batch_keys)

    def train_step_factory(params_like, batch_keys=("tokens", "labels")):
        pspecs, ospecs, bspec = shardings(params_like, batch_keys)
        manual = frozenset(("pod", "data") if pod_axis else ("data",))
        strip = lambda tree: jax.tree_util.tree_map(
            lambda s: _manual_only(s, manual), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
        return compat.shard_map(
            per_worker,
            mesh=mesh,
            in_specs=(strip(pspecs), strip(ospecs), strip(bspec), P()),
            out_specs=(strip(pspecs), strip(ospecs), P()),
            axis_names=manual,          # model stays auto (GSPMD inside)
            check_vma=False,
        )

    return train_step_factory, shardings


def _manual_only(spec: P, manual: frozenset) -> P:
    """Project a PartitionSpec onto the manual axes (auto axes -> None)."""
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in manual)
            out.append(kept if kept else None)
        else:
            out.append(e if e in manual else None)
    return P(*out)


# ---------------------------------------------------------------------------
# worker-axis param helpers
# ---------------------------------------------------------------------------

def replicate_for_workers(params: Params, n_workers: int) -> Params:
    """Tile a single model copy into the worker-axis layout."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), params
    )


def worker_opt_init(params_w: Params) -> Params:
    """Per-worker AdamW state (leading worker axis, incl. per-worker step)."""
    W = jax.tree_util.tree_leaves(params_w)[0].shape[0]
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params_w),
        "v": jax.tree_util.tree_map(zeros, params_w),
        "step": jnp.zeros((W,), jnp.int32),
    }


def param_spread(params_w: Params) -> jnp.ndarray:
    """Max over leaves of the max |worker_i - mean| — the consensus error."""
    def spread(x):
        mu = x.mean(axis=0, keepdims=True)
        return jnp.abs(x.astype(jnp.float32) - mu).max()

    return jnp.stack(
        [spread(l) for l in jax.tree_util.tree_leaves(params_w)]
    ).max()
