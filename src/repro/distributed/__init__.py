from .sharding import (
    param_specs,
    opt_state_specs,
    batch_specs,
    batch_axes,
    cache_specs,
)
from .aggregation import AGGREGATORS
from .trainer import make_train_step, TrainConfig
from .server import make_prefill_step, make_decode_step

__all__ = [
    "param_specs", "opt_state_specs", "batch_specs", "batch_axes",
    "cache_specs", "AGGREGATORS", "make_train_step", "TrainConfig",
    "make_prefill_step", "make_decode_step",
]
