"""Serve-step builders: batched prefill and decode under the production mesh.

Serving has no gradient aggregation, but inherits the paper's fault story at
the *request* level: the launcher (``repro.launch.serve``) runs the
decode loop; multi-pod meshes shard the request batch over (pod, data) and
heads/experts over model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from .sharding import batch_axes, cache_specs, param_specs

Params = Any


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, cache_len: int | None = None):
    def prefill_step(params, batch):
        return M.prefill(
            params, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            frames=batch.get("frames"),
            cache_len=cache_len,
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh: Mesh):
    def decode_step(params, cache, token):
        return M.decode_step(params, cfg, cache, token)

    return decode_step


def serve_shardings(cfg: ArchConfig, mesh: Mesh, params_like, cache_like):
    baxes = batch_axes(mesh)
    pspecs = param_specs(params_like, cfg, mesh, fsdp=False)
    cspecs = cache_specs(cache_like, cfg, mesh)
    token_spec = P(baxes, None)
    return pspecs, cspecs, token_spec
