"""Serve-step builders: batched prefill and decode under the production mesh.

Serving has no gradient aggregation, but inherits the paper's fault story at
the *request* level: the launcher (``repro.launch.serve``) runs the
decode loop; multi-pod meshes shard the request batch over (pod, data) and
heads/experts over model. :class:`RetryPolicy` / :func:`call_with_retry`
give that request level the same treatment the engines got from
``repro.core.faults``: a transient link burst at the serving tier shows up
as a timed-out or erroring request, and the caller retries it under a
bounded, jittered exponential backoff instead of failing the batch.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from .sharding import batch_axes, cache_specs, param_specs

Params = Any


class RequestTimeout(Exception):
    """A single request attempt exceeded ``RetryPolicy.timeout``."""


class RetriesExhausted(Exception):
    """All ``RetryPolicy.max_attempts`` attempts failed; carries the last
    underlying exception as ``__cause__``."""


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with per-request timeout and jittered exponential
    backoff.

    Attempt ``k`` (0-based) that fails sleeps ``base_delay * backoff**k``
    scaled by a uniform jitter in ``[1 - jitter, 1 + jitter]``, capped at
    ``max_delay`` — full-jitter backoff, so a burst of simultaneous
    failures does not resynchronize into a retry stampede. A ``timeout``
    of ``None`` disables the per-attempt deadline (the attempt's own
    duration still counts nothing toward failure unless it raises).
    """

    max_attempts: int = 3
    timeout: float | None = 1.0     # seconds per attempt
    base_delay: float = 0.05        # first backoff sleep
    backoff: float = 2.0            # multiplier per failed attempt
    max_delay: float = 2.0          # backoff cap
    jitter: float = 0.5             # +/- fraction of the nominal delay
    retry_on: tuple[type[BaseException], ...] = (Exception,)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    def delay(self, attempt: int, rng: random.Random) -> float:
        nominal = min(self.base_delay * self.backoff ** attempt,
                      self.max_delay)
        lo = 1.0 - self.jitter
        return nominal * (lo + (1.0 + self.jitter - lo) * rng.random())


def call_with_retry(
    fn: Callable[[], Any],
    policy: RetryPolicy = RetryPolicy(),
    *,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    rng: random.Random | None = None,
    on_retry: Callable[[int, BaseException], None] | None = None,
):
    """Run ``fn()`` under ``policy``; return its value or raise
    :class:`RetriesExhausted`.

    ``clock`` / ``sleep`` / ``rng`` are injectable so tests drive the
    schedule with a fake clock instead of wall time. The per-attempt
    timeout is cooperative — checked against ``clock()`` after ``fn``
    returns — because the serve loop is single-threaded jax dispatch: a
    compiled step cannot be preempted mid-call, but a stuck attempt must
    still count as a failure for the retry accounting and backoff.
    ``on_retry(attempt, exc)`` fires before each backoff sleep.
    """
    rng = rng if rng is not None else random.Random()
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        start = clock()
        try:
            out = fn()
            if (policy.timeout is not None
                    and clock() - start > policy.timeout):
                raise RequestTimeout(
                    f"attempt {attempt} took {clock() - start:.3f}s "
                    f"(> {policy.timeout}s)")
            return out
        except policy.retry_on as e:  # noqa: PERF203 — retry loop
            last = e
        if attempt + 1 < policy.max_attempts:
            if on_retry is not None:
                on_retry(attempt, last)
            sleep(policy.delay(attempt, rng))
    raise RetriesExhausted(
        f"{policy.max_attempts} attempts failed") from last


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, cache_len: int | None = None):
    def prefill_step(params, batch):
        return M.prefill(
            params, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            frames=batch.get("frames"),
            cache_len=cache_len,
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh: Mesh):
    def decode_step(params, cache, token):
        return M.decode_step(params, cfg, cache, token)

    return decode_step


def serve_shardings(cfg: ArchConfig, mesh: Mesh, params_like, cache_like):
    baxes = batch_axes(mesh)
    pspecs = param_specs(params_like, cfg, mesh, fsdp=False)
    cspecs = cache_specs(cache_like, cfg, mesh)
    token_spec = P(baxes, None)
    return pspecs, cspecs, token_spec
