"""Robust gradient aggregation — the paper's algorithms as training features.

The paper's mapping onto the TPU mesh (see DESIGN.md §3):

* agent          = data-parallel worker (one coordinate of the `data` axis)
* sub-network    = a pod (`pod` axis); single-pod runs are one sub-network
* gossip edge    = `jax.lax.ppermute` ring step on the `data` axis
* packet drop    = Bernoulli mask on the ppermute payload; recovery via the
                   paper's cumulative-sum (sigma/rho) bookkeeping
* PS fusion      = masked psum over per-pod representatives every Gamma
                   gossip rounds (the doubly-stochastic fusion matrix F)
* Byzantine trim = coordinate-wise trimmed mean over gathered worker grads
                   (the paper's scalar-dynamics trick, one dynamic per
                   gradient coordinate; Pallas kernel on TPU)

Aggregators
-----------
``mean``         — exact pmean (the non-robust baseline the paper compares
                   against; equivalent to the implicit GSPMD all-reduce).
``pushsum``      — Algorithm 1 over the data-axis ring with simulated packet
                   drops: robust push-sum rounds + hierarchical fusion; the
                   returned estimate is z/m (consensus error decays per
                   Theorem 1 in the number of rounds).
``pushsum_sparse`` — Algorithm 1 on an *arbitrary* random digraph over all
                   workers via the edge-list core of
                   :mod:`repro.core.pushsum`: one all-gather, then every
                   worker integrates the same sparse consensus and keeps its
                   own row. Wire = one all-gather (vs one ppermute/round for
                   ``pushsum``); use it to prototype non-ring gossip
                   topologies (denser graphs -> faster Theorem 1 contraction)
                   before committing them to collectives.
``trimmed_mean`` — Algorithm 2's extreme-value filter, coordinate-wise over
                   the worker axis (tolerates F Byzantine workers).
``hierarchical_trim`` — intra-pod trimmed mean + cross-pod trimmed fusion of
                   pod estimates (the full two-level Algorithm 2 shape).

All of them run inside ``shard_map`` with the (pod, data) axes *manual* and
the ``model`` axis *auto*: per-worker gradient identity is explicit (the
Byzantine threat model requires it) while tensor parallelism inside the loss
stays GSPMD-managed. This is the central systems consequence of the paper:
robust aggregation is incompatible with FSDP-sharded gradients (no single
device ever holds "worker i's gradient"), so robust modes keep params
replicated across `data` — memory cost of Byzantine tolerance. See
EXPERIMENTS.md §Perf for the measured overheads.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Params = Any


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    kind: str = "mean"
    # pushsum knobs
    gossip_rounds: int = 16
    gamma_period: int = 4           # PS fusion every Γ rounds
    drop_prob: float = 0.1          # simulated packet-drop probability
    B: int = 2                      # every link delivers ≥ once per B rounds
    # pushsum_sparse knobs: worker gossip digraph = random Hamiltonian cycle
    # + Bernoulli extra edges (repro.core.graphs.random_strongly_connected)
    graph_extra_edge_prob: float = 0.25
    graph_seed: int = 0
    pushsum_backend: str = "auto"   # "auto" | "xla" | "pallas" delivery
                                    # lowering for the edge-list core (see
                                    # repro.kernels.pushsum_edge)
    # byzantine knobs
    F: int = 1                      # trim F from each extreme
    trim_backend: str = "xla"       # trimmed-mean lowering ("xla" ref /
                                    # "pallas" TPU kernel / "auto")
    trim_chunk: int = 1 << 22       # coordinates per all-gather chunk
    comm_dtype: str = "float32"     # wire dtype for gather/a2a payloads
                                    # ("bfloat16" halves collective bytes;
                                    # trim decisions are scale-invariant so
                                    # the Byzantine guarantee is unchanged)


def _axis_size(name) -> int:
    from repro.launch.compat import axis_size
    return axis_size(name)


def _worker_index(data_axis: str, pod_axis: str | None) -> jnp.ndarray:
    idx = jax.lax.axis_index(data_axis)
    if pod_axis is not None:
        idx = jax.lax.axis_index(pod_axis) * _axis_size(data_axis) + idx
    return idx


# ---------------------------------------------------------------------------
# mean (baseline)
# ---------------------------------------------------------------------------

def agg_mean(grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key):
    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
    return jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axes), grads)


# ---------------------------------------------------------------------------
# robust push-sum over the data-axis ring (Algorithm 1)
# ---------------------------------------------------------------------------

def agg_pushsum(grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key):
    """Fast robust push-sum on a directed ring within each pod, cumulative
    sigma/rho drop recovery, hierarchical PS fusion across pods every Γ.

    Ring: worker i sends to (i+1) mod W. Out-degree 1 => share = 1/2.
    Returns each worker's z/m estimate (approximate mean; the residual is
    the paper's consensus error, measurable as cross-worker disagreement).
    """
    W = _axis_size(data_axis)
    n_pods = _axis_size(pod_axis) if pod_axis else 1
    fwd = [(i, (i + 1) % W) for i in range(W)]

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    z0 = [l.astype(jnp.float32) for l in leaves]
    zeros = [jnp.zeros_like(z) for z in z0]

    didx = jax.lax.axis_index(data_axis)
    pidx = jax.lax.axis_index(pod_axis) if pod_axis else 0
    is_rep = (didx == 0)

    def round_fn(t, carry):
        zs, m, sigmas, sig_m, rhos, rho_m = carry
        # Bernoulli drop on the (unique) outgoing ring link of each worker,
        # forced up every B rounds (the paper's B-connectivity window).
        kk = jax.random.fold_in(key, t)
        # per-link randomness must differ per *sender*; fold in worker id
        ku = jax.random.fold_in(kk, didx + W * pidx)
        up = (jax.random.uniform(ku) >= cfg.drop_prob) | ((t % cfg.B) == cfg.B - 1)

        # stage cumulative halves (sigma += z/2)
        sigmas = [s + z * 0.5 for s, z in zip(sigmas, zs)]
        sig_m = sig_m + m * 0.5
        # transmit sigma+; receiver sees sender's mask
        sent = [jnp.where(up, s, jnp.nan) for s in sigmas]  # nan == dropped
        sent_m = jnp.where(up, sig_m, jnp.nan)
        recv = [jax.lax.ppermute(s, data_axis, fwd) for s in sent]
        recv_m = jax.lax.ppermute(sent_m, data_axis, fwd)
        ok = ~jnp.isnan(recv_m)
        rho_new = [jnp.where(ok, r, old) for r, old in zip(recv, rhos)]
        rho_m_new = jnp.where(ok, recv_m, rho_m)
        # integrate: z+ = z/2 + (rho_new - rho_old)
        zs = [z * 0.5 + (rn - ro) for z, rn, ro in zip(zs, rho_new, rhos)]
        m = m * 0.5 + (rho_m_new - rho_m)
        # second staging (line 12): sigma += z+/2, z = z+/2
        sigmas = [s + z * 0.5 for s, z in zip(sigmas, zs)]
        sig_m = sig_m + m * 0.5
        zs = [z * 0.5 for z in zs]
        m = m * 0.5

        # hierarchical fusion every Γ rounds (reps: data index 0 of each pod)
        if pod_axis is not None and n_pods > 1:
            do_fuse = (t + 1) % cfg.gamma_period == 0

            def fuse(args):
                zs, m = args
                repf = is_rep.astype(jnp.float32)
                pooled = [
                    jax.lax.psum(
                        jax.lax.psum(z * repf, data_axis), pod_axis
                    ) / (2.0 * n_pods)
                    for z in zs
                ]
                pooled_m = jax.lax.psum(
                    jax.lax.psum(m * repf, data_axis), pod_axis
                ) / (2.0 * n_pods)
                zs = [
                    jnp.where(is_rep, 0.5 * z + pz, z)
                    for z, pz in zip(zs, pooled)
                ]
                m = jnp.where(is_rep, 0.5 * m + pooled_m, m)
                return zs, m

            zs, m = jax.lax.cond(do_fuse, fuse, lambda a: a, (zs, m))
        return zs, m, sigmas, sig_m, rho_new, rho_m_new

    m0 = jnp.float32(1.0)
    carry = (z0, m0, zeros, jnp.float32(0.0),
             [jnp.zeros_like(z) for z in z0], jnp.float32(0.0))
    zs, m, *_ = jax.lax.fori_loop(0, cfg.gossip_rounds, round_fn, carry)
    est = [
        (z / jnp.maximum(m, 1e-12)).astype(l.dtype) for z, l in zip(zs, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, est)


# ---------------------------------------------------------------------------
# edge-list push-sum on an arbitrary worker digraph (Algorithm 1, sparse core)
# ---------------------------------------------------------------------------

def agg_pushsum_sparse(
    grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key
):
    """Robust push-sum over a random strongly connected digraph of ALL
    workers (pods flattened), using the O(E d) edge-list core.

    Each worker all-gathers the per-worker gradients once, then runs the
    identical ``gossip_rounds`` of :func:`repro.core.pushsum.
    sparse_pushsum_step` (same key -> same masks on every worker) and keeps
    its own row of z/m. Deterministically identical inputs mean workers
    agree on the whole consensus state, so the per-worker estimates are the
    true Algorithm 1 iterates on that topology — the training-time testbed
    for non-ring gossip graphs. The edge index is kept in the sorted-by-dst
    layout so ``cfg.pushsum_backend="pallas"`` hits the fused kernel's
    contiguous-run fast path on TPU (``"auto"`` falls back to XLA off-TPU).
    """
    import numpy as np

    from repro.core.graphs import (
        edge_list, random_strongly_connected, sort_by_dst,
    )
    from repro.core.pushsum import (
        init_sparse_state, sparse_pushsum_step, sparse_ratios, step_edge_mask,
    )

    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
    W = 1
    for a in axes:
        W *= _axis_size(a)
    adj = random_strongly_connected(
        W, cfg.graph_extra_edge_prob, np.random.default_rng(cfg.graph_seed)
    )
    el, _, _ = sort_by_dst(edge_list(adj))
    src = jnp.asarray(el.src)
    dst = jnp.asarray(el.dst)
    valid = jnp.asarray(el.valid)
    widx = _worker_index(data_axis, pod_axis)

    def gossip_leaf(g):
        gf = g.astype(jnp.float32).reshape(-1)
        allv = jax.lax.all_gather(gf, axes).reshape(W, -1)   # (W, D)

        def round_fn(t, state):
            mask = step_edge_mask(key, t, el.E, cfg.drop_prob, cfg.B)
            return sparse_pushsum_step(
                state, mask, src, dst, valid, cfg.pushsum_backend
            )

        final = jax.lax.fori_loop(
            0, cfg.gossip_rounds, round_fn, init_sparse_state(allv, el.E)
        )
        est = sparse_ratios(final)                           # (W, D)
        return est[widx].reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(gossip_leaf, grads)


# ---------------------------------------------------------------------------
# coordinate-wise trimmed mean (Algorithm 2's filter over workers)
# ---------------------------------------------------------------------------

def _trim_matrix(x: jnp.ndarray, F: int, backend: str) -> jnp.ndarray:
    """x: (W, D) -> (D,)."""
    from repro.kernels.trimmed_mean.ops import trimmed_mean
    return trimmed_mean(x, F, backend=backend)


def agg_trimmed(grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key):
    """Trim F largest/smallest per coordinate across ALL workers (pods
    flattened) then average — tolerates any F Byzantine workers system-wide."""
    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)

    def trim_leaf(g):
        gf = g.astype(jnp.float32).reshape(-1)
        gathered = jax.lax.all_gather(gf, axes)          # (P, W, D) or (W, D)
        flat = gathered.reshape(-1, gf.shape[0])
        return _trim_matrix(flat, cfg.F, cfg.trim_backend).reshape(g.shape).astype(
            g.dtype
        )

    return jax.tree_util.tree_map(trim_leaf, grads)


def agg_hierarchical_trim(
    grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key
):
    """Two-level Algorithm 2: trim within each pod (sub-network consensus),
    then trimmed fusion of pod estimates across pods (PS gossip rule).

    With n_pods <= 2F the cross-pod trim degenerates to a mean — exactly the
    paper's Assumption 5 constraint (need >= 2F+1 sub-networks to trim)."""
    n_pods = _axis_size(pod_axis) if pod_axis else 1

    def trim_leaf(g):
        gf = g.astype(jnp.float32).reshape(-1)
        within = jax.lax.all_gather(gf, data_axis)       # (W, D)
        pod_est = _trim_matrix(within, cfg.F, cfg.trim_backend)
        if pod_axis is None or n_pods == 1:
            return pod_est.reshape(g.shape).astype(g.dtype)
        across = jax.lax.all_gather(pod_est, pod_axis)   # (P, D)
        f_cross = cfg.F if n_pods >= 2 * cfg.F + 1 else 0
        out = _trim_matrix(across, f_cross, cfg.trim_backend)
        return out.reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(trim_leaf, grads)


def agg_trimmed_sharded(
    grads: Params, cfg: AggregatorConfig, data_axis, pod_axis, key
):
    """Beyond-paper optimization of Algorithm 2's filter (§Perf iteration):

    The faithful ``trimmed_mean`` all-gathers the full gradient to every
    worker (wire ~ (W-1) * D bytes/device) although each coordinate's trim
    is independent. Instead, partition coordinates into per-worker stripes:

        all_to_all   — worker w receives stripe w from every other worker
                       ((W-1)/W * D bytes),
        local trim   — w trims/averages only its D/W coordinates,
        all_gather   — stripes reassemble the full estimate ((W-1)/W * D).

    Wire bytes drop ~(W-1)x -> ~2x D and the trim FLOPs drop by W. The
    result is bit-identical to ``trimmed_mean`` (same per-coordinate
    filter), so the Byzantine guarantee is unchanged.
    """
    axes = [a for a in (pod_axis, data_axis) if a]
    W = 1
    for a in axes:
        W *= _axis_size(a)

    def trim_leaf(g):
        shape = g.shape
        wire_dt = jnp.dtype(cfg.comm_dtype)
        gf = g.astype(wire_dt).reshape(-1)
        D = gf.shape[0]
        pad = (-D) % W
        if pad:
            gf = jnp.concatenate([gf, jnp.zeros((pad,), gf.dtype)])
        stripes = gf.reshape(W, -1)                      # (W, D/W)
        # all_to_all over the (possibly two) worker axes in sequence
        recv = stripes
        if pod_axis:
            n_pod = _axis_size(pod_axis)
            n_dat = _axis_size(data_axis)
            # (pod, data, stripe) exchange: first flatten stripes per axis
            recv = recv.reshape(n_pod, n_dat, -1)
            recv = jax.lax.all_to_all(recv, pod_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
            recv = jax.lax.all_to_all(recv, data_axis, split_axis=1,
                                      concat_axis=1, tiled=False)
            recv = recv.reshape(W, -1)
        else:
            recv = jax.lax.all_to_all(recv, data_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
        mine = _trim_matrix(recv.astype(jnp.float32), cfg.F, cfg.trim_backend)
        full = jax.lax.all_gather(mine.astype(wire_dt), tuple(axes))
        full = full.reshape(-1)[:D]
        return full.reshape(shape).astype(g.dtype)

    return jax.tree_util.tree_map(trim_leaf, grads)


AGGREGATORS: dict[str, Callable] = {
    "mean": agg_mean,
    "pushsum": agg_pushsum,
    "pushsum_sparse": agg_pushsum_sparse,
    "trimmed_mean": agg_trimmed,
    "trimmed_mean_sharded": agg_trimmed_sharded,
    "hierarchical_trim": agg_hierarchical_trim,
}
