"""PartitionSpec rules for every architecture's param/activation/cache trees.

Axes: single-pod mesh ``("data", "model")``; multi-pod ``("pod", "data",
"model")``. Batch always shards over (pod, data); tensor dims over "model";
large 2-D weights additionally FSDP-shard their input dim over "data"
(GSPMD inserts the per-layer all-gathers) when ``fsdp=True`` — required for
llama3-405b-class params to fit 16 GB/chip.

Rules dispatch on the leaf's key-path (module-qualified names from
``repro.models.layers`` inits) and pad with leading ``None`` for stacked
layer axes.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

Params = Any


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


# weight-name -> spec for the *trailing* dims (stack dims padded with None)
_COL = ("wq", "wk", "wv", "wg", "w_gate", "w_up", "w_in", "w_gate_branch",
        "w_lora_b", "w1", "wr")
_ROW = ("wo", "w_down", "w_out", "w2")


def _rule(names: list[str], leaf, cfg: ArchConfig, fsdp: bool, mesh: Mesh):
    name = names[-1]
    in_ffn = "ffn" in names
    in_moe = cfg.is_moe and in_ffn
    fs = "data" if fsdp else None

    if name == "embed":
        return ("model", None)
    if name == "lm_head":
        return (fs, "model")
    if name == "router":
        return (None, None)
    if in_moe and name in ("w_gate", "w_up", "w_down"):
        return ("model", fs, None)        # expert parallel + fsdp inner dim
    if in_ffn and cfg.ffn_kind == "rwkv_cm":
        # channel-mix: wk (d,f) col, wv (f,d) row, wr (d,d) col
        if name == "wk":
            return (fs, "model")
        if name == "wv":
            return ("model", fs)
        if name == "wr":
            return (fs, "model")
    if name in _COL:
        return (fs, "model")
    if name in _ROW:
        return ("model", fs)
    if name == "conv_w":
        return (None, "model")
    if name == "u":
        return (None, None)
    if name == "w_lora_a":
        return (fs, None)
    # 1-D scales/biases, lam, w0, mu, ln_x, conv_b: replicate
    return tuple(None for _ in range(leaf.ndim))


def param_specs(
    params_like: Params, cfg: ArchConfig, mesh: Mesh, fsdp: bool = False,
    worker_axis: bool = False,
) -> Params:
    """PartitionSpec pytree matching ``params_like``.

    worker_axis: the decentralized-training layout — every leaf has a
    leading per-worker axis sharded over (pod, data); see
    ``repro.distributed.aggregation``.
    """
    baxes = batch_axes(mesh)

    def assign(path, leaf):
        names = _path_names(path)
        rule = _rule(names, leaf, cfg, fsdp, mesh)
        ndim = leaf.ndim - (1 if worker_axis else 0)
        rule = tuple(rule[-ndim:]) if ndim else ()
        pad = ndim - len(rule)
        spec = (None,) * pad + rule
        if worker_axis:
            spec = (baxes,) + spec
        # divisibility guard (odd vocabs like 92553, kv heads < model, ...)
        return fit_spec(P(*spec), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, params_like)


def opt_state_specs(pspecs: Params) -> Params:
    """Adam moments share their param's spec; step is replicated."""
    return {
        "m": pspecs,
        "v": pspecs,
        "step": P(),
    }


def batch_specs(mesh: Mesh) -> P:
    return P(batch_axes(mesh), None)


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the array dim — jit
    in_shardings require exact divisibility (e.g. kv_heads=8 cannot shard
    over model=16; batch=1 cannot shard over data)."""
    sizes = dict(mesh.shape)
    out = []
    for i, e in enumerate(spec):
        if e is None or i >= len(shape):
            out.append(None if i >= len(shape) else e)
            continue
        axes = e if isinstance(e, (tuple, list)) else (e,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(e if shape[i] % total == 0 else None)
    return P(*out)


def cache_specs(cache_like: Params, cfg: ArchConfig, mesh: Mesh) -> Params:
    """KV/state caches: batch over (pod, data); heads/channels over model."""
    baxes = batch_axes(mesh)

    def assign(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v"):            # (B, Hkv, Wc, dh) [+stack]
            # heads on model when divisible, else sequence-parallel cache
            spec = (baxes, "model", None, None)
            pad0 = leaf.ndim - 4
            trial = P(*(((None,) * pad0) + spec))
            fitted = fit_spec(trial, leaf.shape, mesh)
            if fitted[pad0 + 1] is None:
                spec = (baxes, None, "model", None)
        elif name == "pos":
            spec = (baxes,)
        elif name == "state":             # wkv6 (B, H, hd, hd)
            spec = (baxes, "model", None, None)
        elif name == "x_prev" or name == "cm_prev":
            spec = (baxes, None)
        elif name == "h":                 # rglru (B, w)
            spec = (baxes, "model")
        elif name == "conv":              # (B, 3, w)
            spec = (baxes, None, "model")
        elif name == "enc":               # (B, T_enc, d)
            spec = (baxes, None, None)
        else:
            spec = tuple(None for _ in range(leaf.ndim))
        pad = leaf.ndim - len(spec)
        return fit_spec(P(*(((None,) * pad) + tuple(spec))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, cache_like)


def to_shardings(spec_tree: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Scenario-sweep meshes: the 2-D (data x graph) layout of the edge-
# partitioned push-sum engines (repro.core.sweeps)
# ---------------------------------------------------------------------------

def sweep_mesh(
    n_data: int,
    n_graph: int = 1,
    *,
    data_axis: str = "data",
    graph_axis: str = "graph",
    devices=None,
):
    """Mesh for the scenario-sweep engines: ``data_axis`` shards the K
    scenario axis (one scenario batch per device row), ``graph_axis``
    shards the edge index of each scenario into ``n_graph`` dst-contiguous
    shards (:func:`repro.core.graphs.partition_edge_list`). ``n_data *
    n_graph`` must not exceed the available device count. Built through
    :func:`repro.launch.compat.make_mesh` so the same call works across the
    jax versions the repo supports.
    """
    from repro.launch import compat

    return compat.make_mesh(
        (n_data, n_graph), (data_axis, graph_axis), devices=devices
    )


def sweep_specs(data_axis: str = "data", graph_axis: str = "graph"):
    """PartitionSpecs of the 2-D sweep program's four argument roles.

    * ``"replicated"``  — w and any other every-device value,
    * ``"scenario"``    — (K,) per-scenario coordinates (drop, seed): data
      axis only, every graph-shard device sees its row's full batch,
    * ``"edge_shards"`` — (K, S, E_shard) partitioned edge arrays: scenario
      rows over data, the shard axis over graph,
    * ``"out"``         — results: node state is graph-replicated after the
      per-round psum combine, so outputs name only the data axis.
    """
    return {
        "replicated": P(),
        "scenario": P(data_axis),
        "edge_shards": P(data_axis, graph_axis),
        "out": P(data_axis),
    }
