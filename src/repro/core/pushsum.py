"""Fast robust push-sum over packet-dropping links (Su '18, Alg. 1 lines 3-12).

The algorithm tolerates packet-dropping links *without* delivery
acknowledgements by transmitting cumulative sums:

* ``sigma_j``  — cumulative value agent j has made available to each of its
  outgoing neighbors up to now (broadcast: identical per neighbor),
* ``rho_{j'j}`` — the latest cumulative value receiver j has actually heard
  from sender j'.

A successful delivery at time t lets the receiver integrate
``rho_new - rho_old`` — which automatically includes every previously dropped
increment. Mass bookkeeping (``m``, ``sigma_m``, ``rho_m``) runs the identical
recursion so the ratio ``z/m`` debiases the graph and the losses.

State shapes for an N-agent network with d-dimensional values:
    z (N, d) | m (N,) | sigma (N, d) | sigma_m (N,) | rho (N, N, d) |
    rho_m (N, N)    (rho[j', j] = last heard on link j' -> j)

Everything is jax-traceable; the per-iteration link mask is data.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PushSumState", "init_state", "pushsum_step", "run_pushsum", "ratios"]


class PushSumState(NamedTuple):
    z: jnp.ndarray        # (N, d) value
    m: jnp.ndarray        # (N,)   mass
    sigma: jnp.ndarray    # (N, d) cumulative value offered per out-link
    sigma_m: jnp.ndarray  # (N,)
    rho: jnp.ndarray      # (N, N, d) cumulative value heard per in-link
    rho_m: jnp.ndarray    # (N, N)


def init_state(w: jnp.ndarray) -> PushSumState:
    """w: (N, d) initial values; push-sum drives z/m -> mean(w)."""
    n, d = w.shape
    return PushSumState(
        z=w,
        m=jnp.ones((n,), w.dtype),
        sigma=jnp.zeros((n, d), w.dtype),
        sigma_m=jnp.zeros((n,), w.dtype),
        rho=jnp.zeros((n, n, d), w.dtype),
        rho_m=jnp.zeros((n, n), w.dtype),
    )


def pushsum_step(
    state: PushSumState,
    mask: jnp.ndarray,   # (N, N) bool — operational links this round (subset of adj)
    adj: jnp.ndarray,    # (N, N) bool — underlying topology (defines d_out)
) -> PushSumState:
    """One iteration of fast robust push-sum (Alg. 1 / Alg. 3 lines 4-12)."""
    z, m, sigma, sigma_m, rho, rho_m = state
    d_out = adj.sum(axis=1).astype(z.dtype)  # (N,) out-degree of underlying graph
    share = 1.0 / (d_out + 1.0)              # (N,)

    # --- first half: stage cumulative send (lines 4-5) ---
    sigma_p = sigma + z * share[:, None]
    sigma_m_p = sigma_m + m * share

    # --- delivery (lines 6-10): successful links latch the new cumulative ---
    mask_f = mask.astype(z.dtype)
    rho_new = jnp.where(mask[:, :, None], sigma_p[:, None, :], rho)
    rho_m_new = jnp.where(mask, sigma_m_p[:, None], rho_m)
    # only links that exist in the topology can ever carry anything
    adj_f = adj.astype(z.dtype)
    recv = ((rho_new - rho) * adj_f[:, :, None]).sum(axis=0)      # (N, d)
    recv_m = ((rho_m_new - rho_m) * adj_f).sum(axis=0)            # (N,)
    del mask_f

    # --- integrate (line 11) ---
    z_p = z * share[:, None] + recv
    m_p = m * share + recv_m

    # --- second half: immediately re-stage (line 12) ---
    sigma_n = sigma_p + z_p * share[:, None]
    sigma_m_n = sigma_m_p + m_p * share
    z_n = z_p * share[:, None]
    m_n = m_p * share

    return PushSumState(z_n, m_n, sigma_n, sigma_m_n, rho_new, rho_m_new)


def ratios(state: PushSumState) -> jnp.ndarray:
    """The push-sum estimate z/m per agent, (N, d)."""
    return state.z / jnp.maximum(state.m, 1e-30)[:, None]


def run_pushsum(
    w: jnp.ndarray,       # (N, d) inputs
    adj: jnp.ndarray,     # (N, N) bool topology
    masks: jnp.ndarray,   # (T, N, N) bool operational-link schedule
    record_every: int = 1,
) -> tuple[PushSumState, jnp.ndarray]:
    """Run T iterations; returns final state and (T//record_every, N, d) ratios."""
    adj = jnp.asarray(adj)
    state0 = init_state(jnp.asarray(w))

    def body(state, mask):
        new = pushsum_step(state, mask, adj)
        return new, ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.asarray(masks))
    return final, traj[::record_every]


def mass_invariant(state: PushSumState, adj: jnp.ndarray) -> jnp.ndarray:
    """Total conserved value: held + in-flight on every link. (d,) vector.

    sum_j z_j + sum_{(j',j) in E} (sigma_{j'} - rho_{j'j})  ==  sum_j w_j
    — the augmented-graph mass-preservation property Theorem 1 relies on.
    Exposed for tests/benchmarks.
    """
    adj_f = jnp.asarray(adj, state.z.dtype)
    in_flight = ((state.sigma[:, None, :] - state.rho) * adj_f[:, :, None]).sum((0, 1))
    return state.z.sum(axis=0) + in_flight
