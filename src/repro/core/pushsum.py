"""Fast robust push-sum over packet-dropping links (Su '18, Alg. 1 lines 3-12).

The algorithm tolerates packet-dropping links *without* delivery
acknowledgements by transmitting cumulative sums:

* ``sigma_j``  — cumulative value agent j has made available to each of its
  outgoing neighbors up to now (broadcast: identical per neighbor),
* ``rho_{j'j}`` — the latest cumulative value receiver j has actually heard
  from sender j'.

A successful delivery at time t lets the receiver integrate
``rho_new - rho_old`` — which automatically includes every previously dropped
increment. Mass bookkeeping (``m``, ``sigma_m``, ``rho_m``) runs the identical
recursion so the ratio ``z/m`` debiases the graph and the losses.

Two interchangeable state representations:

**Dense (reference).** For an N-agent network with d-dimensional values:
    z (N, d) | m (N,) | sigma (N, d) | sigma_m (N,) | rho (N, N, d) |
    rho_m (N, N)    (rho[j', j] = last heard on link j' -> j)
O(N^2 d) memory; kept as the executable spec the sparse path is tested
against.

**Sparse edge-list (production).** ``rho`` only carries information on
actual links, so over a precomputed edge index (src[e] -> dst[e], E edges):
    z (N, d) | m (N,) | sigma (N, d) | sigma_m (N,) | rho (E, d) |
    rho_m (E,)
O(E d) memory — N ~ 1e5 agents on sparse digraphs never touch an
(N, N, ...) array — and per-round link masks are (E,) Bernoulli draws
generated inside the scan (no (T, N, N) schedule is ever materialized).
Su & Vaidya's analysis (arXiv:1606.08904, relaxed in arXiv:1901.01943) is
stated per-link, so the edge-list core is the faithful representation, not
an approximation.

The delivery + integration half of each round is routed through a
``backend`` switch (``sparse_pushsum_step`` / ``run_pushsum_sparse``, and
the engines built on them in :mod:`repro.core.sweeps` and
:mod:`repro.distributed.aggregation`):

* ``"xla"``    — gather ``sigma[src]`` + ``jnp.where`` latch + one
  ``jax.ops.segment_sum`` over ``dst``; runs on every platform and is the
  equivalence oracle.
* ``"pallas"`` — the fused streaming kernel of
  :mod:`repro.kernels.pushsum_edge`: one pass over E doing the gather, the
  mask-latch, and the per-receiver increment accumulation together. It
  expects the *sorted-edge layout*: pre-sort the index by ``dst`` at
  construction with :func:`repro.core.graphs.sort_by_dst` (the returned
  inverse permutation maps per-edge state/masks back to the original edge
  order). Unsorted indices stay correct but lose the contiguous-run fast
  path. Value and mass columns ride one (·, d+1) matrix so a single pass
  serves both recursions.
* ``"auto"``   — ``"pallas"`` on TPU, ``"xla"`` elsewhere (CPU CI runs the
  kernel in ``interpret=True`` mode for equivalence tests only).

Everything is jax-traceable; see :mod:`repro.core.sweeps` for the vmapped
(and mesh-sharded) scenario engine built on the sparse core.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.asyncrony import (
    AsyncBuffer,
    AsyncModel,
    init_async_buffer,
    is_degenerate_async,
    wake_mask,
)
from repro.core.faults import (
    ENGINE_PUSHSUM,
    FaultModel,
    FaultState,
    faulty_edge_mask,
    freeze,
    init_fault_state,
    step_faults,
)
from repro.core.plan import ExecutionPlan, resolve_plan
from repro.core.precision import Policy, resolve_policy
from repro.statics.contracts import contract as statics_contract
from repro.statics.retrace import register_cache as register_statics_cache

__all__ = [
    "PushSumState",
    "init_state",
    "pushsum_step",
    "run_pushsum",
    "ratios",
    "mass_invariant",
    "SparsePushSumState",
    "init_sparse_state",
    "sparse_pushsum_step",
    "sparse_pushsum_step_jit",
    "sparse_ratios",
    "sparse_mass_invariant",
    "run_pushsum_sparse",
    "step_edge_mask",
    "shard_edge_mask",
]

HALO_VARIANTS = ("psum", "scatter")


# ---------------------------------------------------------------------------
# Dense reference implementation
# ---------------------------------------------------------------------------

class PushSumState(NamedTuple):
    z: jnp.ndarray        # (N, d) value
    m: jnp.ndarray        # (N,)   mass
    sigma: jnp.ndarray    # (N, d) cumulative value offered per out-link
    sigma_m: jnp.ndarray  # (N,)
    rho: jnp.ndarray      # (N, N, d) cumulative value heard per in-link
    rho_m: jnp.ndarray    # (N, N)


def init_state(w: jnp.ndarray) -> PushSumState:
    """w: (N, d) initial values; push-sum drives z/m -> mean(w)."""
    n, d = w.shape
    return PushSumState(
        z=w,
        m=jnp.ones((n,), w.dtype),
        sigma=jnp.zeros((n, d), w.dtype),
        sigma_m=jnp.zeros((n,), w.dtype),
        rho=jnp.zeros((n, n, d), w.dtype),
        rho_m=jnp.zeros((n, n), w.dtype),
    )


def pushsum_step(
    state: PushSumState,
    mask: jnp.ndarray,   # (N, N) bool — operational links this round
    adj: jnp.ndarray,    # (N, N) bool — underlying topology (defines d_out)
) -> PushSumState:
    """One iteration of fast robust push-sum (Alg. 1 / Alg. 3 lines 4-12).

    The mask is intersected with the topology before latching ``rho``: a
    stray True on a non-edge (a malformed schedule) must never corrupt relay
    state — non-edges carry no ``sigma`` and their ``rho`` stays 0 forever.
    """
    z, m, sigma, sigma_m, rho, rho_m = state
    d_out = adj.sum(axis=1).astype(z.dtype)  # (N,) out-degree of underlying graph
    share = 1.0 / (d_out + 1.0)              # (N,)

    # --- first half: stage cumulative send (lines 4-5) ---
    sigma_p = sigma + z * share[:, None]
    sigma_m_p = sigma_m + m * share

    # --- delivery (lines 6-10): successful *existing* links latch the new
    # cumulative; mask & adj guards against out-of-topology mask bits ---
    live = mask & adj
    rho_new = jnp.where(live[:, :, None], sigma_p[:, None, :], rho)
    rho_m_new = jnp.where(live, sigma_m_p[:, None], rho_m)
    recv = (rho_new - rho).sum(axis=0)        # (N, d)
    recv_m = (rho_m_new - rho_m).sum(axis=0)  # (N,)

    # --- integrate (line 11) ---
    z_p = z * share[:, None] + recv
    m_p = m * share + recv_m

    # --- second half: immediately re-stage (line 12) ---
    sigma_n = sigma_p + z_p * share[:, None]
    sigma_m_n = sigma_m_p + m_p * share
    z_n = z_p * share[:, None]
    m_n = m_p * share

    return PushSumState(z_n, m_n, sigma_n, sigma_m_n, rho_new, rho_m_new)


def ratios(state: PushSumState) -> jnp.ndarray:
    """The push-sum estimate z/m per agent, (N, d)."""
    return state.z / jnp.maximum(state.m, 1e-30)[:, None]


def run_pushsum(
    w: jnp.ndarray,       # (N, d) inputs
    adj: jnp.ndarray,     # (N, N) bool topology
    masks: jnp.ndarray,   # (T, N, N) bool operational-link schedule
    record_every: int = 1,
) -> tuple[PushSumState, jnp.ndarray]:
    """Run T iterations; returns final state and (T//record_every, N, d) ratios."""
    adj = jnp.asarray(adj)
    state0 = init_state(jnp.asarray(w))

    def body(state, mask):
        new = pushsum_step(state, mask, adj)
        return new, ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.asarray(masks))
    return final, traj[::record_every]


def mass_invariant(state: PushSumState, adj: jnp.ndarray) -> jnp.ndarray:
    """Total conserved value: held + in-flight on every link. (d,) vector.

    sum_j z_j + sum_{(j',j) in E} (sigma_{j'} - rho_{j'j})  ==  sum_j w_j
    — the augmented-graph mass-preservation property Theorem 1 relies on.
    Exposed for tests/benchmarks.
    """
    adj_f = jnp.asarray(adj, state.z.dtype)
    in_flight = ((state.sigma[:, None, :] - state.rho) * adj_f[:, :, None]).sum((0, 1))
    return state.z.sum(axis=0) + in_flight


# ---------------------------------------------------------------------------
# Sparse edge-list implementation
# ---------------------------------------------------------------------------

class SparsePushSumState(NamedTuple):
    z: jnp.ndarray        # (N, d) value
    m: jnp.ndarray        # (N,)   mass
    sigma: jnp.ndarray    # (N, d) cumulative value offered per out-link
    sigma_m: jnp.ndarray  # (N,)
    rho: jnp.ndarray      # (E, d) cumulative value heard, per directed edge
    rho_m: jnp.ndarray    # (E,)


def init_sparse_state(
    w: jnp.ndarray, n_edges: int, policy: Policy | str | None = None
) -> SparsePushSumState:
    """w: (N, d) initial values; ``n_edges`` the (padded) edge count E.

    ``policy`` (a :class:`repro.core.precision.Policy`, a name, or ``None``)
    selects the *storage* dtype of every persistent field — the bandwidth
    knob. ``None`` keeps ``w.dtype`` exactly (the pre-policy behavior,
    including float64 states under x64 mode)."""
    n, d = w.shape
    dt = w.dtype if policy is None else resolve_policy(policy).storage_dtype
    return SparsePushSumState(
        z=w.astype(dt),
        m=jnp.ones((n,), dt),
        sigma=jnp.zeros((n, d), dt),
        sigma_m=jnp.zeros((n,), dt),
        rho=jnp.zeros((n_edges, d), dt),
        rho_m=jnp.zeros((n_edges,), dt),
    )


def _out_degree(src: jnp.ndarray, valid: jnp.ndarray, n: int,
                dtype) -> jnp.ndarray:
    return jax.ops.segment_sum(
        valid.astype(dtype), src, num_segments=n, indices_are_sorted=False
    )


def sparse_pushsum_step(
    state: SparsePushSumState,
    mask: jnp.ndarray,     # (E,) bool — operational edges this round
    src: jnp.ndarray,      # (E,) int32 sender per edge
    dst: jnp.ndarray,      # (E,) int32 receiver per edge
    valid: jnp.ndarray,    # (E,) bool — False on padding edges
    backend: str = "auto",
    *,
    share: jnp.ndarray | None = None,
    graph_axis: str | None = None,
    dst_sorted: bool = False,
    policy: Policy | str | None = None,
    halo: str = "psum",
    n_shards: int = 1,
    faults: FaultState | None = None,
    awake: jnp.ndarray | None = None,
    abuf: AsyncBuffer | None = None,
    staleness: jnp.ndarray | None = None,
) -> SparsePushSumState | tuple[SparsePushSumState, AsyncBuffer]:
    """One fast-robust-push-sum iteration on edge-list state.

    Identical recursion to :func:`pushsum_step`; delivery gathers
    ``sigma[src]`` per operational edge and integration scatter-adds the
    latched increments into receivers — via ``jax.ops.segment_sum``
    (``backend="xla"``) or the fused Pallas edge-scatter kernel
    (``backend="pallas"``, sorted-by-dst edge layout; see the module
    docstring). The mask is intersected with ``valid`` so padding edges can
    never carry mass — the sparse analogue of the dense step's
    ``mask & adj``. ``backend`` is static: thread it through
    ``static_argnames`` when jitting.

    ``share`` optionally supplies the precomputed (N,) ``1 / (d_out + 1)``
    factors — a loop invariant of the fixed edge index that scan-heavy
    callers (:mod:`repro.core.social`) hoist once instead of re-deriving
    the segment-sum out-degree every iteration. It must equal
    ``1 / (_out_degree(src, valid, N) + 1)`` — computed over the *global*
    edge set when running edge-partitioned (below).

    **Edge-partitioned mode** (``graph_axis=``): inside a
    ``compat.shard_map`` (or an emulating ``vmap(axis_name=...)``) over a
    mesh graph axis, ``src``/``dst``/``valid``/``mask`` and the per-edge
    state carry only this device's (E_shard,) slice of a
    :func:`repro.core.graphs.partition_edge_list` layout while node state
    stays replicated. Each shard computes its local receiver partials and
    the halo combine is one ``lax.psum`` pair over ``graph_axis`` —
    interior receivers (all in-edges on one shard) get exact ``+0.0``
    contributions from foreign shards; only boundary receivers (in-edge
    runs split by a shard cut) see a genuine multi-operand sum, which is
    where reduce-order fp differences vs. the single-device reference can
    appear. When ``share`` is not supplied the local out-degree is psum'd
    the same way before the reciprocal.

    ``dst_sorted=True`` asserts the edge index is dst-sorted (the
    partitioner's layout, or :func:`graphs.sort_by_dst` output) and lets
    the XLA lowering's ``segment_sum`` skip its internal sort.

    **Precision policy** (``policy=``, see :mod:`repro.core.precision`):
    persistent state stays in the storage dtype, elementwise staging runs
    in the compute dtype, and every reduction (the per-receiver segment
    sum, the halo combine) runs in the accum dtype. The staged cumulative
    is quantized to storage *before* delivery, and the re-stage reads the
    quantized value back — so receivers latch exactly the value the sender
    persists and the telescoping sums ``rho_new - rho_old`` self-correct:
    quantization error never compounds across rounds, it is re-measured
    against the stored cumulative each time. ``policy=None`` (default) is
    dtype-transparent and emits the bit-identical pre-policy program.

    **Halo variant** (``halo=``, edge-partitioned mode only): ``"psum"``
    all-reduces the full (N, d+1) partials — each device moves
    ``2 (n-1)/n * N (d+1)`` accum-dtype elements per round. ``"scatter"``
    reduce-scatters the partials so each device owns an N/n_shards row
    block, quantizes the *reduced* block to the storage dtype, and
    all-gathers it — ``(n-1)/n * N (d+1)`` accum elements in plus the same
    count of *storage* elements out, i.e. ~25% less wire even at fp32 and
    ~44% less under bf16 storage (modeled in
    :func:`repro.analysis.roofline.pushsum_halo_wire_bytes`). Reduce order
    differs from ``"psum"``, so ``"scatter"`` is opt-in, not bit-identical.
    ``n_shards`` (the graph-axis extent) must be given for ``"scatter"``.

    **Fault plane** (``faults=``, a :class:`repro.core.faults.FaultState`):
    edges with a dead endpoint are masked in both directions and the four
    node-state fields of a dead agent are frozen (``where(live, new, old)``)
    so it rejoins with stale state — the churn semantics of
    :mod:`repro.core.faults`. Per-edge relay state needs no freeze: a
    masked edge never latches. ``faults=None`` (default) emits the
    bit-identical pre-fault program.

    **Async mode** (``awake=`` (N,) bool + ``abuf=`` an
    :class:`repro.core.asyncrony.AsyncBuffer` + ``staleness=`` () int32,
    all three together): one tick of the event-driven engine. Awake
    senders latch this tick's staged cumulative into the per-edge
    bounded buffer (age reset to 0, stale snapshots age by 1); delivery
    latches the *buffered* snapshot into ``rho`` when the link is up,
    the receiver is awake, and the snapshot is at most ``staleness``
    ticks old — a sleeping sender's last message still delivers, which
    is the asynchrony. Asleep agents' node state is frozen exactly like
    churn-dead agents (composes with ``faults=``: effective liveness is
    ``awake & node_live``). Returns ``(state, new_abuf)`` instead of
    the bare state. Delivery always lowers through the XLA
    ``where`` + ``segment_sum`` path (the Pallas edge-scatter kernel
    gathers node-indexed ``sigma`` and cannot read a per-edge buffer);
    the degenerate model (wake-prob 1, staleness 0) reproduces the
    synchronous XLA step bit for bit. Incompatible with
    ``graph_axis=`` edge partitioning.
    """
    from repro.kernels.pushsum_edge import edge_scatter, resolve_backend

    if halo not in HALO_VARIANTS:
        raise ValueError(f"halo={halo!r} not in {HALO_VARIANTS}")
    pol = None if policy is None else resolve_policy(policy)
    z, m, sigma, sigma_m, rho, rho_m = state
    n = z.shape[0]
    if pol is None:
        st_dt = cp_dt = z.dtype
        ac_dt = z.dtype
        accum_name = None
    else:
        st_dt = pol.storage_dtype
        cp_dt = pol.compute_dtype
        ac_dt = pol.accum_dtype
        accum_name = pol.accum
    if share is None:
        d_out = _out_degree(src, valid, n, cp_dt)     # (N,) local
        if graph_axis is not None:
            d_out = jax.lax.psum(d_out, graph_axis)   # (N,) global
        share = 1.0 / (d_out + 1.0)
    share = share.astype(cp_dt)

    # --- first half: stage cumulative send (compute dtype), then quantize
    # to storage — the quantized value is what gets delivered AND re-staged,
    # so relay state and receivers agree exactly ---
    sigma_p = sigma.astype(cp_dt) + z.astype(cp_dt) * share[:, None]
    sigma_m_p = sigma_m.astype(cp_dt) + m.astype(cp_dt) * share
    sigma_p_s = sigma_p.astype(st_dt)
    sigma_m_p_s = sigma_m_p.astype(st_dt)

    # --- delivery: operational edges latch the sender's new cumulative ---
    if faults is not None:
        # a dead endpoint takes the edge down in both directions
        mask = mask & faults.node_live[src] & faults.node_live[dst]
    live = mask & valid
    abuf_new = None
    if abuf is not None:
        if graph_axis is not None:
            raise ValueError(
                "async mode does not compose with graph_axis edge "
                "partitioning (the per-edge buffer would need halo state)"
            )
        # async tick: awake (live) senders overwrite their edges' buffer
        # slot with the freshly staged cumulative; everyone else's
        # snapshot ages by one tick
        send = awake[src] & valid
        if faults is not None:
            send = send & faults.node_live[src]
        snap = jnp.where(send[:, None], sigma_p_s[src], abuf.snap)
        snap_m = jnp.where(send, sigma_m_p_s[src], abuf.snap_m)
        age = jnp.where(send, 0, abuf.age + 1)
        abuf_new = AsyncBuffer(snap=snap, snap_m=snap_m, age=age)
        # delivery consumes the buffer: link up AND receiver awake AND
        # snapshot within the staleness bound. The receiver integrates
        # exactly rho_new - rho_old of the cumulative relay, so mass is
        # conserved under any wake schedule and an expired snapshot is
        # self-healed by the telescoping on the next fresh one.
        live = live & awake[dst] & (age <= staleness)
        rho_new = jnp.where(live[:, None], snap, rho)
        rho_m_new = jnp.where(live, snap_m, rho_m)
        recv = jax.ops.segment_sum(
            rho_new.astype(ac_dt) - rho.astype(ac_dt), dst, num_segments=n,
            indices_are_sorted=dst_sorted,
        )
        recv_m = jax.ops.segment_sum(
            rho_m_new.astype(ac_dt) - rho_m.astype(ac_dt), dst,
            num_segments=n, indices_are_sorted=dst_sorted,
        )
    elif resolve_backend(backend) == "pallas":
        # value + mass columns in one (·, d+1) pass through the kernel
        sigma_cat = jnp.concatenate([sigma_p_s, sigma_m_p_s[:, None]], axis=1)
        rho_cat = jnp.concatenate([rho, rho_m[:, None]], axis=1)
        rho_cat_new, recv_cat = edge_scatter(
            sigma_cat, rho_cat, live, src, dst, backend="pallas",
            indices_sorted=dst_sorted, accum_dtype=accum_name,
        )
        rho_new, rho_m_new = rho_cat_new[:, :-1], rho_cat_new[:, -1]
        recv, recv_m = recv_cat[:, :-1], recv_cat[:, -1]
    else:
        rho_new = jnp.where(live[:, None], sigma_p_s[src], rho)
        rho_m_new = jnp.where(live, sigma_m_p_s[src], rho_m)
        recv = jax.ops.segment_sum(
            rho_new.astype(ac_dt) - rho.astype(ac_dt), dst, num_segments=n,
            indices_are_sorted=dst_sorted,
        )
        recv_m = jax.ops.segment_sum(
            rho_m_new.astype(ac_dt) - rho_m.astype(ac_dt), dst,
            num_segments=n, indices_are_sorted=dst_sorted,
        )
    if graph_axis is not None:
        if halo == "scatter":
            # reduce-scatter + quantize + all-gather: each device reduces
            # its own N/n_shards row block, so the gathered payload can ride
            # the storage dtype (the reduction already happened in accum)
            cat = jnp.concatenate([recv, recv_m[:, None]], axis=1)
            pad_n = (-n) % n_shards
            if pad_n:
                cat = jnp.pad(cat, ((0, pad_n), (0, 0)))
            part = jax.lax.psum_scatter(
                cat, graph_axis, scatter_dimension=0, tiled=True
            )
            cat = jax.lax.all_gather(
                part.astype(st_dt), graph_axis, axis=0, tiled=True
            ).astype(ac_dt)
            if pad_n:
                cat = cat[:n]
            recv, recv_m = cat[:, :-1], cat[:, -1]
        else:
            # halo combine: interior receivers add exact +0.0 partials,
            # boundary receivers (see EdgeShards.boundary) sum their split
            # in-edge runs
            recv = jax.lax.psum(recv, graph_axis)
            recv_m = jax.lax.psum(recv_m, graph_axis)

    # --- integrate (accum dtype) ---
    z_p = (z.astype(cp_dt) * share[:, None]).astype(ac_dt) + recv
    m_p = (m.astype(cp_dt) * share).astype(ac_dt) + recv_m

    # --- second half: immediately re-stage, downcast to storage ---
    z_pc = z_p.astype(cp_dt)
    m_pc = m_p.astype(cp_dt)
    sigma_n = (sigma_p_s.astype(cp_dt) + z_pc * share[:, None]).astype(st_dt)
    sigma_m_n = (sigma_m_p_s.astype(cp_dt) + m_pc * share).astype(st_dt)
    z_n = (z_pc * share[:, None]).astype(st_dt)
    m_n = (m_pc * share).astype(st_dt)

    if awake is not None:
        # asleep agents do nothing: same freeze as churn, composing to
        # an effective liveness of awake & node_live
        z_n = freeze(awake, z_n, z)
        m_n = freeze(awake, m_n, m)
        sigma_n = freeze(awake, sigma_n, sigma)
        sigma_m_n = freeze(awake, sigma_m_n, sigma_m)
    if faults is not None:
        # freeze dead agents: state carries unchanged through the dead
        # rounds (stale-rejoin semantics) and every term of the global
        # mass invariant is conserved exactly — the live rest just sees
        # an ordinary all-edges-dropped round toward the dead agent
        ln = faults.node_live
        z_n = freeze(ln, z_n, z)
        m_n = freeze(ln, m_n, m)
        sigma_n = freeze(ln, sigma_n, sigma)
        sigma_m_n = freeze(ln, sigma_m_n, sigma_m)

    new = SparsePushSumState(z_n, m_n, sigma_n, sigma_m_n, rho_new, rho_m_new)
    if abuf is not None:
        return new, abuf_new
    return new


_HALF_DTYPES = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))


def sparse_ratios(state: SparsePushSumState) -> jnp.ndarray:
    """The push-sum estimate z/m per agent, (N, d).

    Half-precision storage states are upcast to fp32 for the division —
    the 1e-30 mass floor underflows to zero in bf16/fp16, and the ratio is
    a diagnostic, not a persistent value (a static dtype check, so fp32 and
    fp64 states keep the bit-identical pre-policy program)."""
    z, m = state.z, state.m
    if z.dtype in _HALF_DTYPES:
        z, m = z.astype(jnp.float32), m.astype(jnp.float32)
    return z / jnp.maximum(m, 1e-30)[:, None]


def sparse_mass_invariant(
    state: SparsePushSumState,
    src: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    graph_axis: str | None = None,
) -> jnp.ndarray:
    """sum_j z_j + sum_{e valid} (sigma[src[e]] - rho[e]) == sum_j w_j, (d,).

    Under edge partitioning (``graph_axis=``) the per-edge in-flight term is
    psum'd over the shards while the replicated node sum is counted once.
    Half-precision storage states are upcast to fp32 before the O(E) sums
    (same static-dtype rule as :func:`sparse_ratios`).
    """
    z, sigma, rho = state.z, state.sigma, state.rho
    if z.dtype in _HALF_DTYPES:
        z = z.astype(jnp.float32)
        sigma = sigma.astype(jnp.float32)
        rho = rho.astype(jnp.float32)
    vf = valid.astype(z.dtype)
    in_flight = ((sigma[src] - rho) * vf[:, None]).sum(axis=0)
    if graph_axis is not None:
        in_flight = jax.lax.psum(in_flight, graph_axis)
    return z.sum(axis=0) + in_flight


# Compiled step entry points, keyed by their static arguments. Donation is
# the point: ``state`` in and ``state`` out have identical avals leaf-for-
# leaf, so donating argument 0 lets XLA alias every output buffer onto its
# input — the (E, d) relay state, the dominant allocation, is updated
# in-place instead of double-buffered. The statics lint's donation check
# asserts the compiled executable actually reports the aliasing
# (``repro.statics.cli``). Keyed dict rather than functools.lru_cache so
# the retrace sentinel can sum the inner jit cache sizes.
_STEP_JIT: dict = {}


def _step_jit_entries() -> int:
    return sum(f._cache_size() for f in _STEP_JIT.values())


register_statics_cache("pushsum.step-jit", _step_jit_entries)


def sparse_pushsum_step_jit(
    state: SparsePushSumState,
    mask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    valid: jnp.ndarray,
    backend: str = "auto",
    *,
    share: jnp.ndarray | None = None,
    dst_sorted: bool = False,
    policy: Policy | str | None = None,
) -> SparsePushSumState:
    """Jitted :func:`sparse_pushsum_step` with the input state *donated*.

    The returned state reuses the argument's buffers, so the caller must
    not touch ``state`` afterwards — standard donation semantics. Use this
    for step-at-a-time driving (benchmarks, interactive loops); inside a
    ``lax.scan`` the carry is already double-buffer-free, and the sweep
    bodies have no aval-matched input/output pairs to donate (their inputs
    are (K,)-batched scenarios, their outputs reductions), which is why
    donation lives on the step entry and not the sweep jits.

    Values match calling :func:`sparse_pushsum_step` op-by-op up to XLA's
    whole-function fusion (FMA contraction), ~1 ulp on the value columns.

    ``graph_axis`` mode is excluded: collectives need a surrounding
    ``shard_map``, whose jit owns the donation decision there.
    """
    pol = None if policy is None else resolve_policy(policy)
    return _get_step_jit(backend, dst_sorted, pol)(
        state, mask, src, dst, valid, share)


def _get_step_jit(backend: str, dst_sorted: bool, pol: Policy | None):
    """Build-or-fetch the donating jitted step for one static key. Split
    from :func:`sparse_pushsum_step_jit` so :mod:`repro.statics.precision`
    can ``.lower()`` the exact shipped callable (proving the compiled
    executable aliases the donated state buffers) without executing it."""
    key = (backend, dst_sorted, pol)
    fn = _STEP_JIT.get(key)
    if fn is None:
        def _step(state, mask, src, dst, valid, share,
                  _backend=backend, _sorted=dst_sorted, _pol=pol):
            return sparse_pushsum_step(
                state, mask, src, dst, valid, _backend,
                share=share, dst_sorted=_sorted, policy=_pol,
            )

        fn = jax.jit(_step, donate_argnums=(0,))
        _STEP_JIT[key] = fn
    return fn


def step_edge_mask(
    key: jnp.ndarray,
    t: jnp.ndarray,
    n_edges: int,
    drop_prob,
    B,
    fold_t=None,
) -> jnp.ndarray:
    """(E,) operational mask for round t: i.i.d. Bernoulli keep with forced
    delivery at ``t % B == B - 1`` (the paper's B-connectivity window),
    matching :func:`repro.core.graphs.link_schedule` semantics without ever
    materializing a (T, N, N) schedule.

    ``fold_t`` overrides the fold-in value (default ``t``) so callers that
    consume several PRNG streams per iteration can give the link-mask
    stream its own disjoint fold-in domain (see
    :func:`repro.core.social.social_stream_fold`) while the B-window logic
    still runs on the *iteration* index. ``drop_prob`` and ``B`` may be
    traced scalars — scenario sweeps put both on a vmap axis.
    """
    kt = jax.random.fold_in(key, t if fold_t is None else fold_t)
    up = jax.random.uniform(kt, (n_edges,)) >= drop_prob
    return up | ((t % B) == (B - 1))


def shard_edge_mask(
    key: jnp.ndarray,
    t: jnp.ndarray,
    e_shard: int,
    drop_prob,
    B,
    *,
    graph_axis: str,
    n_shards: int,
    fold_t=None,
) -> jnp.ndarray:
    """This device's (E_shard,) window of the round-t operational mask.

    Bit-identity anchor of the edge-partitioned mode: every shard draws the
    *full* (n_shards * e_shard,) Bernoulli vector — threefry bits are a
    function of (key, counter position), so there is no per-slice shortcut
    that reproduces a window of a longer draw — then dynamically slices its
    own window at ``axis_index(graph_axis) * e_shard``. The result equals
    ``step_edge_mask(key, t, e_pad, ...)`` restricted to this shard's slots
    exactly, which is what makes the sharded run bit-comparable to the
    single-device reference over ``EdgeShards.padded_edge_list()``. The
    full draw is O(e_pad) *bytes* per device per round — accounted in
    :func:`repro.statics.memory.pushsum_sharded_step_bytes` — but carries
    no (E_pad, d) payload.
    """
    full = step_edge_mask(key, t, n_shards * e_shard, drop_prob, B,
                          fold_t=fold_t)
    start = jax.lax.axis_index(graph_axis) * e_shard
    return jax.lax.dynamic_slice(full, (start,), (e_shard,))


@statics_contract(
    name="pushsum",
    # The sparse core's reason to exist: no (N, N) value may ever appear
    # in the traced program (the trajectory output is (T, N, d) — fine).
    forbidden={"*": (("N", "N"),)},
    # One PRNG stream, folded at the plain iteration index; engines that
    # add more streams must move to a strided domain (see social/byzantine).
    streams=(("link", lambda t: t),),
    caches=("pushsum.sweep-jit",),
)
def run_pushsum_sparse(
    w: jnp.ndarray,            # (N, d) inputs
    src: jnp.ndarray,          # (E,) int32
    dst: jnp.ndarray,          # (E,) int32
    T: int,
    *,
    drop_prob=0.0,
    B: int = 1,
    key: jnp.ndarray | None = None,
    valid: jnp.ndarray | None = None,
    masks: jnp.ndarray | None = None,   # optional explicit (T, E) schedule
    record_every: int = 1,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> tuple[SparsePushSumState, jnp.ndarray]:
    """Run T iterations of the edge-list core.

    Masks are (E,) Bernoulli draws generated inside the scan from ``key``
    (drop_prob / B semantics of :func:`graphs.link_schedule`); pass an
    explicit ``masks`` (T, E) schedule instead to reproduce a dense run
    bit-for-bit (see :func:`graphs.edge_masks`); its length must equal T.

    Execution knobs ride ``plan=`` (:class:`repro.core.plan.ExecutionPlan`;
    loose ``backend=``/``policy=``/``dst_sorted=``/``faults=`` kwargs are
    deprecated shims that fold into a plan bit-identically):
    ``plan.backend`` selects the per-round delivery lowering (module
    docstring); ``"pallas"`` expects a dst-sorted edge index.
    ``plan.policy`` selects the storage dtype of the scan-carried state
    (:mod:`repro.core.precision`; ``None`` = dtype-transparent fp32
    default, bit-identical to the pre-policy engine); ``plan.dst_sorted``
    declares the edge index sorted by receiver so the integration scatter
    gets the sorted-segments hint.

    Returns the final state and the ratio trajectory recorded at rounds
    ``record_every - 1, 2*record_every - 1, ...`` — i.e. the *end* of each
    record window, so the last row is always round T-1 when ``record_every``
    divides T. In the key-driven path with ``record_every`` dividing T the
    recording happens inside the scan (a fori_loop per window), so only
    T/record_every ratio frames ever exist — at N=1024 this is what keeps
    long-horizon runs O(N d) instead of O(T N d).

    ``plan.faults`` (a :class:`repro.core.faults.FaultModel`) activates
    the unified fault plane: the Bernoulli link draw generalizes to a
    per-edge Gilbert-Elliott burst chain, agents churn on the liveness
    mask (edges down, state frozen, stale rejoin), and the per-round
    realization state — O(E) + O(N), carried in the scan — advances on
    the fault plane's own disjoint PRNG streams. ``faults=None``
    (default) emits the bit-identical pre-fault program, and a
    degenerate :func:`repro.core.faults.make_fault_model` reproduces the
    same mask values draw-for-draw. Incompatible with an explicit
    ``masks`` schedule.

    ``plan.async_`` (an :class:`repro.core.asyncrony.AsyncModel`)
    activates the event-driven mode: agents wake on independent
    Bernoulli-discretized Poisson clocks (their own disjoint PRNG
    stream), messages ride per-edge bounded stale buffers — an O(E·d)
    extra scan carry — and each scan tick steps one block of concurrent
    wakeups. Composes with ``plan.faults``; incompatible with an
    explicit ``masks`` schedule. The degenerate
    :func:`repro.core.asyncrony.make_async_model` (wake-prob 1,
    staleness 0) is bit-identical to the synchronous engine.
    """
    plan = resolve_plan(
        plan, _entry="run_pushsum_sparse",
        _supports=("backend", "policy", "dst_sorted", "faults", "async_"),
        **legacy)
    backend, policy = plan.backend, plan.policy
    dst_sorted, faults, async_ = plan.dst_sorted, plan.faults, plan.async_
    if async_ is not None and is_degenerate_async(async_):
        # bit-identity by construction: a concretely degenerate model IS
        # the synchronous engine (see repro.core.asyncrony)
        async_ = None
    w = jnp.asarray(w)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = src.shape[0]
    if valid is None:
        valid = jnp.ones((E,), bool)
    else:
        valid = jnp.asarray(valid, bool)
    state0 = init_sparse_state(w, E, policy=policy)
    k = record_every

    if masks is not None:
        if faults is not None:
            raise ValueError(
                "faults= requires key-driven masks; an explicit masks "
                "schedule already fixes the link realization"
            )
        if async_ is not None:
            raise ValueError(
                "async_= requires key-driven masks; an explicit masks "
                "schedule already fixes the link realization"
            )
        masks = jnp.asarray(masks)
        if masks.shape[0] != T:
            raise ValueError(
                f"masks schedule has {masks.shape[0]} rounds but T={T}"
            )

        def body(state, mask):
            new = sparse_pushsum_step(state, mask, src, dst, valid, backend,
                                      policy=policy, dst_sorted=dst_sorted)
            return new, sparse_ratios(new)

        final, traj = jax.lax.scan(body, state0, masks)
        return final, traj[k - 1 :: k]

    if key is None:
        key = jax.random.PRNGKey(0)

    if faults is not None or async_ is not None:
        # stateful scan: the carry gains the O(E) + O(N) FaultState
        # and/or the O(E·d) AsyncBuffer. The link uniform is drawn on
        # the SAME fold as step_edge_mask, so the degenerate FaultModel
        # reproduces the Bernoulli mask values draw-for-draw, while the
        # GE/churn and wake streams live in their own disjoint fold-in
        # domains.
        n_nodes = w.shape[0]
        carry0 = (state0,)
        if async_ is not None:
            carry0 += (init_async_buffer(E, w.shape[1], state0.z.dtype),)
        if faults is not None:
            carry0 += (init_fault_state(n_nodes, E),)

        def stateful_round(carry, t):
            state = carry[0]
            abuf = carry[1] if async_ is not None else None
            fs = carry[-1] if faults is not None else None
            if faults is not None:
                fs = step_faults(key, t, faults, fs, engine=ENGINE_PUSHSUM)
                u = jax.random.uniform(jax.random.fold_in(key, t), (E,))
                mask = faulty_edge_mask(u, t, faults, fs, src, dst,
                                        drop_prob, B)
            else:
                mask = step_edge_mask(key, t, E, drop_prob, B)
            if async_ is not None:
                awake = wake_mask(key, t, n_nodes, async_.wake_prob,
                                  engine=ENGINE_PUSHSUM)
                new, abuf = sparse_pushsum_step(
                    state, mask, src, dst, valid, backend, policy=policy,
                    dst_sorted=dst_sorted, faults=fs, awake=awake,
                    abuf=abuf, staleness=async_.staleness)
            else:
                new = sparse_pushsum_step(
                    state, mask, src, dst, valid, backend, policy=policy,
                    dst_sorted=dst_sorted, faults=fs)
            out = (new,)
            if async_ is not None:
                out += (abuf,)
            if faults is not None:
                out += (fs,)
            return out

        if k > 1 and T % k == 0:
            def swindow(carry, t0):
                new = jax.lax.fori_loop(
                    0, k, lambda i, c: stateful_round(c, t0 + jnp.uint32(i)),
                    carry)
                return new, sparse_ratios(new[0])

            (final, *_), traj = jax.lax.scan(
                swindow, carry0, jnp.arange(0, T, k, dtype=jnp.uint32))
            return final, traj

        def sbody(carry, t):
            new = stateful_round(carry, t)
            return new, sparse_ratios(new[0])

        (final, *_), traj = jax.lax.scan(
            sbody, carry0, jnp.arange(T, dtype=jnp.uint32))
        return final, traj[k - 1 :: k]

    if k > 1 and T % k == 0:
        # record inside the scan: one fori_loop per window, one frame out
        def window(state, t0):
            def inner(i, st):
                mask = step_edge_mask(key, t0 + jnp.uint32(i), E, drop_prob, B)
                return sparse_pushsum_step(st, mask, src, dst, valid, backend,
                                           policy=policy,
                                           dst_sorted=dst_sorted)

            new = jax.lax.fori_loop(0, k, inner, state)
            return new, sparse_ratios(new)

        final, traj = jax.lax.scan(
            window, state0, jnp.arange(0, T, k, dtype=jnp.uint32)
        )
        return final, traj

    def body(state, t):
        mask = step_edge_mask(key, t, E, drop_prob, B)
        new = sparse_pushsum_step(state, mask, src, dst, valid, backend,
                                  policy=policy, dst_sorted=dst_sorted)
        return new, sparse_ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.arange(T, dtype=jnp.uint32))
    return final, traj[k - 1 :: k]
