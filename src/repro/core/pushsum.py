"""Fast robust push-sum over packet-dropping links (Su '18, Alg. 1 lines 3-12).

The algorithm tolerates packet-dropping links *without* delivery
acknowledgements by transmitting cumulative sums:

* ``sigma_j``  — cumulative value agent j has made available to each of its
  outgoing neighbors up to now (broadcast: identical per neighbor),
* ``rho_{j'j}`` — the latest cumulative value receiver j has actually heard
  from sender j'.

A successful delivery at time t lets the receiver integrate
``rho_new - rho_old`` — which automatically includes every previously dropped
increment. Mass bookkeeping (``m``, ``sigma_m``, ``rho_m``) runs the identical
recursion so the ratio ``z/m`` debiases the graph and the losses.

Two interchangeable state representations:

**Dense (reference).** For an N-agent network with d-dimensional values:
    z (N, d) | m (N,) | sigma (N, d) | sigma_m (N,) | rho (N, N, d) |
    rho_m (N, N)    (rho[j', j] = last heard on link j' -> j)
O(N^2 d) memory; kept as the executable spec the sparse path is tested
against.

**Sparse edge-list (production).** ``rho`` only carries information on
actual links, so over a precomputed edge index (src[e] -> dst[e], E edges):
    z (N, d) | m (N,) | sigma (N, d) | sigma_m (N,) | rho (E, d) |
    rho_m (E,)
O(E d) memory — N ~ 1e5 agents on sparse digraphs never touch an
(N, N, ...) array — and per-round link masks are (E,) Bernoulli draws
generated inside the scan (no (T, N, N) schedule is ever materialized).
Su & Vaidya's analysis (arXiv:1606.08904, relaxed in arXiv:1901.01943) is
stated per-link, so the edge-list core is the faithful representation, not
an approximation.

The delivery + integration half of each round is routed through a
``backend`` switch (``sparse_pushsum_step`` / ``run_pushsum_sparse``, and
the engines built on them in :mod:`repro.core.sweeps` and
:mod:`repro.distributed.aggregation`):

* ``"xla"``    — gather ``sigma[src]`` + ``jnp.where`` latch + one
  ``jax.ops.segment_sum`` over ``dst``; runs on every platform and is the
  equivalence oracle.
* ``"pallas"`` — the fused streaming kernel of
  :mod:`repro.kernels.pushsum_edge`: one pass over E doing the gather, the
  mask-latch, and the per-receiver increment accumulation together. It
  expects the *sorted-edge layout*: pre-sort the index by ``dst`` at
  construction with :func:`repro.core.graphs.sort_by_dst` (the returned
  inverse permutation maps per-edge state/masks back to the original edge
  order). Unsorted indices stay correct but lose the contiguous-run fast
  path. Value and mass columns ride one (·, d+1) matrix so a single pass
  serves both recursions.
* ``"auto"``   — ``"pallas"`` on TPU, ``"xla"`` elsewhere (CPU CI runs the
  kernel in ``interpret=True`` mode for equivalence tests only).

Everything is jax-traceable; see :mod:`repro.core.sweeps` for the vmapped
(and mesh-sharded) scenario engine built on the sparse core.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.statics.contracts import contract as statics_contract

__all__ = [
    "PushSumState",
    "init_state",
    "pushsum_step",
    "run_pushsum",
    "ratios",
    "mass_invariant",
    "SparsePushSumState",
    "init_sparse_state",
    "sparse_pushsum_step",
    "sparse_ratios",
    "sparse_mass_invariant",
    "run_pushsum_sparse",
    "step_edge_mask",
    "shard_edge_mask",
]


# ---------------------------------------------------------------------------
# Dense reference implementation
# ---------------------------------------------------------------------------

class PushSumState(NamedTuple):
    z: jnp.ndarray        # (N, d) value
    m: jnp.ndarray        # (N,)   mass
    sigma: jnp.ndarray    # (N, d) cumulative value offered per out-link
    sigma_m: jnp.ndarray  # (N,)
    rho: jnp.ndarray      # (N, N, d) cumulative value heard per in-link
    rho_m: jnp.ndarray    # (N, N)


def init_state(w: jnp.ndarray) -> PushSumState:
    """w: (N, d) initial values; push-sum drives z/m -> mean(w)."""
    n, d = w.shape
    return PushSumState(
        z=w,
        m=jnp.ones((n,), w.dtype),
        sigma=jnp.zeros((n, d), w.dtype),
        sigma_m=jnp.zeros((n,), w.dtype),
        rho=jnp.zeros((n, n, d), w.dtype),
        rho_m=jnp.zeros((n, n), w.dtype),
    )


def pushsum_step(
    state: PushSumState,
    mask: jnp.ndarray,   # (N, N) bool — operational links this round
    adj: jnp.ndarray,    # (N, N) bool — underlying topology (defines d_out)
) -> PushSumState:
    """One iteration of fast robust push-sum (Alg. 1 / Alg. 3 lines 4-12).

    The mask is intersected with the topology before latching ``rho``: a
    stray True on a non-edge (a malformed schedule) must never corrupt relay
    state — non-edges carry no ``sigma`` and their ``rho`` stays 0 forever.
    """
    z, m, sigma, sigma_m, rho, rho_m = state
    d_out = adj.sum(axis=1).astype(z.dtype)  # (N,) out-degree of underlying graph
    share = 1.0 / (d_out + 1.0)              # (N,)

    # --- first half: stage cumulative send (lines 4-5) ---
    sigma_p = sigma + z * share[:, None]
    sigma_m_p = sigma_m + m * share

    # --- delivery (lines 6-10): successful *existing* links latch the new
    # cumulative; mask & adj guards against out-of-topology mask bits ---
    live = mask & adj
    rho_new = jnp.where(live[:, :, None], sigma_p[:, None, :], rho)
    rho_m_new = jnp.where(live, sigma_m_p[:, None], rho_m)
    recv = (rho_new - rho).sum(axis=0)        # (N, d)
    recv_m = (rho_m_new - rho_m).sum(axis=0)  # (N,)

    # --- integrate (line 11) ---
    z_p = z * share[:, None] + recv
    m_p = m * share + recv_m

    # --- second half: immediately re-stage (line 12) ---
    sigma_n = sigma_p + z_p * share[:, None]
    sigma_m_n = sigma_m_p + m_p * share
    z_n = z_p * share[:, None]
    m_n = m_p * share

    return PushSumState(z_n, m_n, sigma_n, sigma_m_n, rho_new, rho_m_new)


def ratios(state: PushSumState) -> jnp.ndarray:
    """The push-sum estimate z/m per agent, (N, d)."""
    return state.z / jnp.maximum(state.m, 1e-30)[:, None]


def run_pushsum(
    w: jnp.ndarray,       # (N, d) inputs
    adj: jnp.ndarray,     # (N, N) bool topology
    masks: jnp.ndarray,   # (T, N, N) bool operational-link schedule
    record_every: int = 1,
) -> tuple[PushSumState, jnp.ndarray]:
    """Run T iterations; returns final state and (T//record_every, N, d) ratios."""
    adj = jnp.asarray(adj)
    state0 = init_state(jnp.asarray(w))

    def body(state, mask):
        new = pushsum_step(state, mask, adj)
        return new, ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.asarray(masks))
    return final, traj[::record_every]


def mass_invariant(state: PushSumState, adj: jnp.ndarray) -> jnp.ndarray:
    """Total conserved value: held + in-flight on every link. (d,) vector.

    sum_j z_j + sum_{(j',j) in E} (sigma_{j'} - rho_{j'j})  ==  sum_j w_j
    — the augmented-graph mass-preservation property Theorem 1 relies on.
    Exposed for tests/benchmarks.
    """
    adj_f = jnp.asarray(adj, state.z.dtype)
    in_flight = ((state.sigma[:, None, :] - state.rho) * adj_f[:, :, None]).sum((0, 1))
    return state.z.sum(axis=0) + in_flight


# ---------------------------------------------------------------------------
# Sparse edge-list implementation
# ---------------------------------------------------------------------------

class SparsePushSumState(NamedTuple):
    z: jnp.ndarray        # (N, d) value
    m: jnp.ndarray        # (N,)   mass
    sigma: jnp.ndarray    # (N, d) cumulative value offered per out-link
    sigma_m: jnp.ndarray  # (N,)
    rho: jnp.ndarray      # (E, d) cumulative value heard, per directed edge
    rho_m: jnp.ndarray    # (E,)


def init_sparse_state(w: jnp.ndarray, n_edges: int) -> SparsePushSumState:
    """w: (N, d) initial values; ``n_edges`` the (padded) edge count E."""
    n, d = w.shape
    return SparsePushSumState(
        z=w,
        m=jnp.ones((n,), w.dtype),
        sigma=jnp.zeros((n, d), w.dtype),
        sigma_m=jnp.zeros((n,), w.dtype),
        rho=jnp.zeros((n_edges, d), w.dtype),
        rho_m=jnp.zeros((n_edges,), w.dtype),
    )


def _out_degree(src: jnp.ndarray, valid: jnp.ndarray, n: int,
                dtype) -> jnp.ndarray:
    return jax.ops.segment_sum(
        valid.astype(dtype), src, num_segments=n, indices_are_sorted=False
    )


def sparse_pushsum_step(
    state: SparsePushSumState,
    mask: jnp.ndarray,     # (E,) bool — operational edges this round
    src: jnp.ndarray,      # (E,) int32 sender per edge
    dst: jnp.ndarray,      # (E,) int32 receiver per edge
    valid: jnp.ndarray,    # (E,) bool — False on padding edges
    backend: str = "auto",
    *,
    share: jnp.ndarray | None = None,
    graph_axis: str | None = None,
    dst_sorted: bool = False,
) -> SparsePushSumState:
    """One fast-robust-push-sum iteration on edge-list state.

    Identical recursion to :func:`pushsum_step`; delivery gathers
    ``sigma[src]`` per operational edge and integration scatter-adds the
    latched increments into receivers — via ``jax.ops.segment_sum``
    (``backend="xla"``) or the fused Pallas edge-scatter kernel
    (``backend="pallas"``, sorted-by-dst edge layout; see the module
    docstring). The mask is intersected with ``valid`` so padding edges can
    never carry mass — the sparse analogue of the dense step's
    ``mask & adj``. ``backend`` is static: thread it through
    ``static_argnames`` when jitting.

    ``share`` optionally supplies the precomputed (N,) ``1 / (d_out + 1)``
    factors — a loop invariant of the fixed edge index that scan-heavy
    callers (:mod:`repro.core.social`) hoist once instead of re-deriving
    the segment-sum out-degree every iteration. It must equal
    ``1 / (_out_degree(src, valid, N) + 1)`` — computed over the *global*
    edge set when running edge-partitioned (below).

    **Edge-partitioned mode** (``graph_axis=``): inside a
    ``compat.shard_map`` (or an emulating ``vmap(axis_name=...)``) over a
    mesh graph axis, ``src``/``dst``/``valid``/``mask`` and the per-edge
    state carry only this device's (E_shard,) slice of a
    :func:`repro.core.graphs.partition_edge_list` layout while node state
    stays replicated. Each shard computes its local receiver partials and
    the halo combine is one ``lax.psum`` pair over ``graph_axis`` —
    interior receivers (all in-edges on one shard) get exact ``+0.0``
    contributions from foreign shards; only boundary receivers (in-edge
    runs split by a shard cut) see a genuine multi-operand sum, which is
    where reduce-order fp differences vs. the single-device reference can
    appear. When ``share`` is not supplied the local out-degree is psum'd
    the same way before the reciprocal.

    ``dst_sorted=True`` asserts the edge index is dst-sorted (the
    partitioner's layout, or :func:`graphs.sort_by_dst` output) and lets
    the XLA lowering's ``segment_sum`` skip its internal sort.
    """
    from repro.kernels.pushsum_edge import edge_scatter, resolve_backend

    z, m, sigma, sigma_m, rho, rho_m = state
    n = z.shape[0]
    if share is None:
        d_out = _out_degree(src, valid, n, z.dtype)   # (N,) local
        if graph_axis is not None:
            d_out = jax.lax.psum(d_out, graph_axis)   # (N,) global
        share = 1.0 / (d_out + 1.0)

    # --- first half: stage cumulative send ---
    sigma_p = sigma + z * share[:, None]
    sigma_m_p = sigma_m + m * share

    # --- delivery: operational edges latch the sender's new cumulative ---
    live = mask & valid
    if resolve_backend(backend) == "pallas":
        # value + mass columns in one (·, d+1) pass through the kernel
        sigma_cat = jnp.concatenate([sigma_p, sigma_m_p[:, None]], axis=1)
        rho_cat = jnp.concatenate([rho, rho_m[:, None]], axis=1)
        rho_cat_new, recv_cat = edge_scatter(
            sigma_cat, rho_cat, live, src, dst, backend="pallas",
            indices_sorted=dst_sorted,
        )
        rho_new, rho_m_new = rho_cat_new[:, :-1], rho_cat_new[:, -1]
        recv, recv_m = recv_cat[:, :-1], recv_cat[:, -1]
    else:
        rho_new = jnp.where(live[:, None], sigma_p[src], rho)
        rho_m_new = jnp.where(live, sigma_m_p[src], rho_m)
        recv = jax.ops.segment_sum(
            rho_new - rho, dst, num_segments=n, indices_are_sorted=dst_sorted
        )
        recv_m = jax.ops.segment_sum(
            rho_m_new - rho_m, dst, num_segments=n,
            indices_are_sorted=dst_sorted,
        )
    if graph_axis is not None:
        # halo combine: interior receivers add exact +0.0 partials, boundary
        # receivers (see EdgeShards.boundary) sum their split in-edge runs
        recv = jax.lax.psum(recv, graph_axis)
        recv_m = jax.lax.psum(recv_m, graph_axis)

    # --- integrate ---
    z_p = z * share[:, None] + recv
    m_p = m * share + recv_m

    # --- second half: immediately re-stage ---
    sigma_n = sigma_p + z_p * share[:, None]
    sigma_m_n = sigma_m_p + m_p * share
    z_n = z_p * share[:, None]
    m_n = m_p * share

    return SparsePushSumState(z_n, m_n, sigma_n, sigma_m_n, rho_new, rho_m_new)


def sparse_ratios(state: SparsePushSumState) -> jnp.ndarray:
    """The push-sum estimate z/m per agent, (N, d)."""
    return state.z / jnp.maximum(state.m, 1e-30)[:, None]


def sparse_mass_invariant(
    state: SparsePushSumState,
    src: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    graph_axis: str | None = None,
) -> jnp.ndarray:
    """sum_j z_j + sum_{e valid} (sigma[src[e]] - rho[e]) == sum_j w_j, (d,).

    Under edge partitioning (``graph_axis=``) the per-edge in-flight term is
    psum'd over the shards while the replicated node sum is counted once.
    """
    vf = valid.astype(state.z.dtype)
    in_flight = ((state.sigma[src] - state.rho) * vf[:, None]).sum(axis=0)
    if graph_axis is not None:
        in_flight = jax.lax.psum(in_flight, graph_axis)
    return state.z.sum(axis=0) + in_flight


def step_edge_mask(
    key: jnp.ndarray,
    t: jnp.ndarray,
    n_edges: int,
    drop_prob,
    B,
    fold_t=None,
) -> jnp.ndarray:
    """(E,) operational mask for round t: i.i.d. Bernoulli keep with forced
    delivery at ``t % B == B - 1`` (the paper's B-connectivity window),
    matching :func:`repro.core.graphs.link_schedule` semantics without ever
    materializing a (T, N, N) schedule.

    ``fold_t`` overrides the fold-in value (default ``t``) so callers that
    consume several PRNG streams per iteration can give the link-mask
    stream its own disjoint fold-in domain (see
    :func:`repro.core.social.social_stream_fold`) while the B-window logic
    still runs on the *iteration* index. ``drop_prob`` and ``B`` may be
    traced scalars — scenario sweeps put both on a vmap axis.
    """
    kt = jax.random.fold_in(key, t if fold_t is None else fold_t)
    up = jax.random.uniform(kt, (n_edges,)) >= drop_prob
    return up | ((t % B) == (B - 1))


def shard_edge_mask(
    key: jnp.ndarray,
    t: jnp.ndarray,
    e_shard: int,
    drop_prob,
    B,
    *,
    graph_axis: str,
    n_shards: int,
    fold_t=None,
) -> jnp.ndarray:
    """This device's (E_shard,) window of the round-t operational mask.

    Bit-identity anchor of the edge-partitioned mode: every shard draws the
    *full* (n_shards * e_shard,) Bernoulli vector — threefry bits are a
    function of (key, counter position), so there is no per-slice shortcut
    that reproduces a window of a longer draw — then dynamically slices its
    own window at ``axis_index(graph_axis) * e_shard``. The result equals
    ``step_edge_mask(key, t, e_pad, ...)`` restricted to this shard's slots
    exactly, which is what makes the sharded run bit-comparable to the
    single-device reference over ``EdgeShards.padded_edge_list()``. The
    full draw is O(e_pad) *bytes* per device per round — accounted in
    :func:`repro.statics.memory.pushsum_sharded_step_bytes` — but carries
    no (E_pad, d) payload.
    """
    full = step_edge_mask(key, t, n_shards * e_shard, drop_prob, B,
                          fold_t=fold_t)
    start = jax.lax.axis_index(graph_axis) * e_shard
    return jax.lax.dynamic_slice(full, (start,), (e_shard,))


@statics_contract(
    name="pushsum",
    # The sparse core's reason to exist: no (N, N) value may ever appear
    # in the traced program (the trajectory output is (T, N, d) — fine).
    forbidden={"*": (("N", "N"),)},
    # One PRNG stream, folded at the plain iteration index; engines that
    # add more streams must move to a strided domain (see social/byzantine).
    streams=(("link", lambda t: t),),
    caches=("pushsum.sweep-jit",),
)
def run_pushsum_sparse(
    w: jnp.ndarray,            # (N, d) inputs
    src: jnp.ndarray,          # (E,) int32
    dst: jnp.ndarray,          # (E,) int32
    T: int,
    *,
    drop_prob=0.0,
    B: int = 1,
    key: jnp.ndarray | None = None,
    valid: jnp.ndarray | None = None,
    masks: jnp.ndarray | None = None,   # optional explicit (T, E) schedule
    record_every: int = 1,
    backend: str = "auto",
) -> tuple[SparsePushSumState, jnp.ndarray]:
    """Run T iterations of the edge-list core.

    Masks are (E,) Bernoulli draws generated inside the scan from ``key``
    (drop_prob / B semantics of :func:`graphs.link_schedule`); pass an
    explicit ``masks`` (T, E) schedule instead to reproduce a dense run
    bit-for-bit (see :func:`graphs.edge_masks`); its length must equal T.
    ``backend`` selects the per-round delivery lowering (module docstring);
    ``"pallas"`` expects a dst-sorted edge index.

    Returns the final state and the ratio trajectory recorded at rounds
    ``record_every - 1, 2*record_every - 1, ...`` — i.e. the *end* of each
    record window, so the last row is always round T-1 when ``record_every``
    divides T. In the key-driven path with ``record_every`` dividing T the
    recording happens inside the scan (a fori_loop per window), so only
    T/record_every ratio frames ever exist — at N=1024 this is what keeps
    long-horizon runs O(N d) instead of O(T N d).
    """
    w = jnp.asarray(w)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = src.shape[0]
    if valid is None:
        valid = jnp.ones((E,), bool)
    else:
        valid = jnp.asarray(valid, bool)
    state0 = init_sparse_state(w, E)
    k = record_every

    if masks is not None:
        masks = jnp.asarray(masks)
        if masks.shape[0] != T:
            raise ValueError(
                f"masks schedule has {masks.shape[0]} rounds but T={T}"
            )

        def body(state, mask):
            new = sparse_pushsum_step(state, mask, src, dst, valid, backend)
            return new, sparse_ratios(new)

        final, traj = jax.lax.scan(body, state0, masks)
        return final, traj[k - 1 :: k]

    if key is None:
        key = jax.random.PRNGKey(0)

    if k > 1 and T % k == 0:
        # record inside the scan: one fori_loop per window, one frame out
        def window(state, t0):
            def inner(i, st):
                mask = step_edge_mask(key, t0 + jnp.uint32(i), E, drop_prob, B)
                return sparse_pushsum_step(st, mask, src, dst, valid, backend)

            new = jax.lax.fori_loop(0, k, inner, state)
            return new, sparse_ratios(new)

        final, traj = jax.lax.scan(
            window, state0, jnp.arange(0, T, k, dtype=jnp.uint32)
        )
        return final, traj

    def body(state, t):
        mask = step_edge_mask(key, t, E, drop_prob, B)
        new = sparse_pushsum_step(state, mask, src, dst, valid, backend)
        return new, sparse_ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.arange(T, dtype=jnp.uint32))
    return final, traj[k - 1 :: k]
