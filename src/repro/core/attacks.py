"""Byzantine attack strategies.

The system adversary (Section II-B) has full knowledge of the system state,
may collude, and uses *point-to-point* communication: a Byzantine sender may
transmit different values to different receivers. An attack therefore
produces a full ``(N_senders, N_receivers, m, m)`` message tensor for the
compromised rows, plus a per-agent parameter-server reply.

All attacks are pure functions of (key, t, r_normal) so they stay inside
``jax.lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Attack", "sign_flip", "large_value", "random_noise", "extreme_pull",
           "truth_suppression", "ATTACKS"]

# messages(key, t, r) -> (N, N, m, m); ps_reply(key, t, r) -> (N, m, m)
MsgFn = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]
ReplyFn = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Attack:
    """A Byzantine strategy. ``name`` is used by benchmarks/tests."""

    name: str
    messages: MsgFn
    ps_reply: ReplyFn


def _broadcast_reply(msg_fn: MsgFn) -> ReplyFn:
    """Default PS reply: what the agent would send on a self-link."""

    def reply(key, t, r):
        full = msg_fn(key, t, r)  # (N, N, m, m)
        n = full.shape[0]
        return full[jnp.arange(n), jnp.arange(n)]

    return reply


def sign_flip(scale: float = 2.0) -> Attack:
    """Send the negated (scaled) average of the normal agents' states.

    A colluding attack: all Byzantine agents push the consensus toward the
    mirror image of the honest average.
    """

    def messages(key, t, r):
        n = r.shape[0]
        avg = r.mean(axis=0)  # (m, m)
        val = -scale * avg
        return jnp.broadcast_to(val, (n, n) + val.shape)

    return Attack("sign_flip", messages, _broadcast_reply(messages))


def large_value(magnitude: float = 1e3) -> Attack:
    """Send a huge constant — the classic outlier attack trimming must stop."""

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        val = jnp.full((m, m), magnitude, r.dtype)
        return jnp.broadcast_to(val, (n, n, m, m))

    return Attack("large_value", messages, _broadcast_reply(messages))


def random_noise(scale: float = 50.0) -> Attack:
    """Point-to-point i.i.d. Gaussian lies — different value per receiver."""

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        k = jax.random.fold_in(key, t)
        return scale * jax.random.normal(k, (n, n, m, m), r.dtype)

    return Attack("random_noise", messages, _broadcast_reply(messages))


def extreme_pull(offset: float = 10.0) -> Attack:
    """Sit just past the honest extremes to bias the post-trim window."""

    def messages(key, t, r):
        n = r.shape[0]
        hi = r.max(axis=0) + offset  # (m, m)
        return jnp.broadcast_to(hi, (n, n) + hi.shape)

    return Attack("extreme_pull", messages, _broadcast_reply(messages))


def truth_suppression(truth: int, magnitude: float = 1e3) -> Attack:
    """Targeted attack: claim overwhelming evidence *against* theta*.

    For every pair (theta*, theta) send -magnitude, for (theta, theta*) send
    +magnitude — i.e. pretend every other hypothesis dominates the truth.
    The adversary knows theta* (full-knowledge threat model).
    """

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        val = jnp.zeros((m, m), r.dtype)
        val = val.at[truth, :].set(-magnitude)
        val = val.at[:, truth].set(magnitude)
        val = val.at[truth, truth].set(0.0)
        return jnp.broadcast_to(val, (n, n, m, m))

    return Attack("truth_suppression", messages, _broadcast_reply(messages))


ATTACKS = {
    "sign_flip": sign_flip,
    "large_value": large_value,
    "random_noise": random_noise,
    "extreme_pull": extreme_pull,
    "truth_suppression": truth_suppression,
}
