"""Byzantine attack strategies.

The system adversary (Section II-B) has full knowledge of the system state,
may collude, and uses *point-to-point* communication: a Byzantine sender may
transmit different values to different receivers.

Two interfaces coexist, keyed to the two gossip cores:

* ``messages(key, t, r) -> (N_senders, N_receivers, m, m)`` — the dense
  tensor the (N, N)-broadcast oracle consumes. O(N^2) by construction.
* ``nbr_messages(key, t, r, nbr_idx) -> nbr_idx.shape + r.shape[1:]`` — the
  sparse form: the value slot ``(j, k)`` of the padded neighbor list
  receives from sender ``nbr_idx[j, k]``. The sparse Byzantine core only
  evaluates attacks through this entry, so nothing (N, N, ...) is ever
  built. For deterministic attacks the two forms agree exactly
  (``nbr_messages(...)[j, k] == messages(...)[nbr_idx[j, k], j]``), which
  is what the dense<->sparse equivalence tests lean on; ``random_noise``
  draws per-slot instead of per-(sender, receiver) — same distribution,
  different stream. ``r`` may carry any trailing pair shape ((m, m)
  pairwise, (m,) one-vs-rest); attacks broadcast over it.

All attacks are pure functions of (key, t, r_normal) so they stay inside
``jax.lax.scan``. An attack without ``nbr_messages`` still runs on the
sparse core via a dense-gather fallback (compatibility only — it
reintroduces the O(N^2) tensor).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Attack", "sign_flip", "large_value", "random_noise", "extreme_pull",
           "truth_suppression", "ATTACKS"]

# messages(key, t, r) -> (N, N, m, m); ps_reply(key, t, r) -> (N, m, m)
MsgFn = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]
ReplyFn = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]
# nbr_messages(key, t, r, nbr_idx) -> nbr_idx.shape + r.shape[1:]
NbrMsgFn = Callable[
    [jax.Array, jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray
]


@dataclasses.dataclass(frozen=True)
class Attack:
    """A Byzantine strategy. ``name`` is used by benchmarks/tests."""

    name: str
    messages: MsgFn
    ps_reply: ReplyFn
    nbr_messages: NbrMsgFn | None = None


def _broadcast_reply(msg_fn: MsgFn) -> ReplyFn:
    """Default PS reply: what the agent would send on a self-link."""

    def reply(key, t, r):
        full = msg_fn(key, t, r)  # (N, N, m, m)
        n = full.shape[0]
        return full[jnp.arange(n), jnp.arange(n)]

    return reply


def _broadcast_nbr(val_fn) -> NbrMsgFn:
    """Sparse form of a broadcast attack: one value, every slot."""

    def nbr_messages(key, t, r, nbr_idx):
        val = val_fn(key, t, r)                  # r.shape[1:]
        return jnp.broadcast_to(val, nbr_idx.shape + val.shape)

    return nbr_messages


def sign_flip(scale: float = 2.0) -> Attack:
    """Send the negated (scaled) average of the normal agents' states.

    A colluding attack: all Byzantine agents push the consensus toward the
    mirror image of the honest average.
    """

    def val(key, t, r):
        return -scale * r.mean(axis=0)

    def messages(key, t, r):
        n = r.shape[0]
        v = val(key, t, r)
        return jnp.broadcast_to(v, (n, n) + v.shape)

    return Attack("sign_flip", messages, _broadcast_reply(messages),
                  _broadcast_nbr(val))


def large_value(magnitude: float = 1e3) -> Attack:
    """Send a huge constant — the classic outlier attack trimming must stop."""

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        val = jnp.full((m, m), magnitude, r.dtype)
        return jnp.broadcast_to(val, (n, n, m, m))

    def nbr_messages(key, t, r, nbr_idx):
        return jnp.full(nbr_idx.shape + r.shape[1:], magnitude, r.dtype)

    return Attack("large_value", messages, _broadcast_reply(messages),
                  nbr_messages)


def random_noise(scale: float = 50.0) -> Attack:
    """Point-to-point i.i.d. Gaussian lies — different value per receiver."""

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        k = jax.random.fold_in(key, t)
        return scale * jax.random.normal(k, (n, n, m, m), r.dtype)

    def nbr_messages(key, t, r, nbr_idx):
        k = jax.random.fold_in(key, t)
        return scale * jax.random.normal(
            k, nbr_idx.shape + r.shape[1:], r.dtype
        )

    return Attack("random_noise", messages, _broadcast_reply(messages),
                  nbr_messages)


def extreme_pull(offset: float = 10.0) -> Attack:
    """Sit just past the honest extremes to bias the post-trim window."""

    def val(key, t, r):
        return r.max(axis=0) + offset

    def messages(key, t, r):
        n = r.shape[0]
        v = val(key, t, r)
        return jnp.broadcast_to(v, (n, n) + v.shape)

    return Attack("extreme_pull", messages, _broadcast_reply(messages),
                  _broadcast_nbr(val))


def truth_suppression(truth: int, magnitude: float = 1e3) -> Attack:
    """Targeted attack: claim overwhelming evidence *against* theta*.

    For every pair (theta*, theta) send -magnitude, for (theta, theta*) send
    +magnitude — i.e. pretend every other hypothesis dominates the truth.
    The adversary knows theta* (full-knowledge threat model). The attack
    needs the pairwise (m, m) statistic structure; on one-vs-rest dynamics
    it degrades to silence (zeros), matching the dense lowering's behaviour
    when the pair axis is squeezed away.
    """

    def _pair_val(m, dtype):
        val = jnp.zeros((m, m), dtype)
        val = val.at[truth, :].set(-magnitude)
        val = val.at[:, truth].set(magnitude)
        val = val.at[truth, truth].set(0.0)
        return val

    def messages(key, t, r):
        n, m = r.shape[0], r.shape[-1]
        return jnp.broadcast_to(_pair_val(m, r.dtype), (n, n, m, m))

    def nbr_messages(key, t, r, nbr_idx):
        pair = r.shape[1:]
        if len(pair) == 2 and pair[0] == pair[1] and pair[0] > truth:
            val = _pair_val(pair[0], r.dtype)
        else:
            val = jnp.zeros(pair, r.dtype)
        return jnp.broadcast_to(val, nbr_idx.shape + pair)

    return Attack("truth_suppression", messages, _broadcast_reply(messages),
                  nbr_messages)


ATTACKS = {
    "sign_flip": sign_flip,
    "large_value": large_value,
    "random_noise": random_noise,
    "extreme_pull": extreme_pull,
    "truth_suppression": truth_suppression,
}
