"""Unified fault plane — bursty links, agent churn, PS crash/recovery.

The paper's fault model is i.i.d. Bernoulli packet loss: every engine
draws one ``(E,)`` mask per round (:func:`repro.core.pushsum.step_edge_mask`)
and the B-window assumption does the rest. Real hierarchical networks
fail in *correlated* ways, and this module generalizes the link draw in
three directions while keeping the degenerate case bit-identical:

* **Bursty drops** — a per-edge two-state Gilbert-Elliott Markov chain.
  Each edge carries one bit of state (``good``/``bad``); a good edge
  drops with the engine's baseline ``drop_prob``, a bad edge with
  ``drop_bad``, and the state evolves with transition probabilities
  ``p_gb``/``p_bg`` (mean burst length ``1/p_bg`` rounds). The B-window
  forcing that backs the paper's Assumption 2 is *suppressed while an
  edge is bad* — bursts are exactly the violations of the B-window the
  robustness claims must survive. ``p_gb = 0`` never leaves the good
  state and recovers today's i.i.d. Bernoulli mask bit-for-bit (the
  drop uniform is drawn on the engine's existing link stream).

* **Churn** — a capacity-padded ``(N,)`` node liveness mask. A dead
  agent's edges are masked in both directions and its node state is
  frozen (``where(live, new, old)``), so it rejoins with stale state
  and the push-sum mass invariant is conserved exactly through
  leave/rejoin: frozen nodes contribute unchanged terms to
  ``z.sum(0) + ((sigma[src] - rho) * valid).sum(0)`` and the live rest
  sees an ordinary drop round. The cumulative-sum relay then self-heals
  the stale edges on the first live round after rejoin.

* **PS crash/recovery** — a scalar per-round coin for the parameter
  server (or the representative uplink). While the PS is down, the
  gamma-period fusion is skipped entirely: the hierarchy degrades to
  plain local consensus instead of pooling through a dead coordinator.

All runtime numbers live in :class:`FaultModel`, a pytree of scalar
arrays, so fault severity rides the existing vmap scenario axis without
retracing; the per-round realization state is :class:`FaultState`, an
O(E) + O(N) carry (never a ``(T, E)`` or ``(T, N, N)`` schedule — the
registered ``*_faults`` statics contracts pin this).

PRNG discipline: fault draws get their own fold-in domain,
``fault_stream_fold``, an affine map into the *negative* integers below
``-2^21`` — strictly below the HPS ``~t`` domain ``[-2^20, -1]`` and
disjoint from every nonnegative engine stream, with the per-engine /
per-stream slots pairwise disjoint by stride-12 congruence. The maps
are registered with the :mod:`repro.statics.streams` lattice prover via
the four ``*_faults`` contracts below, so a future collision is a lint
failure, not a silent correlation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.statics import contracts as _contracts

__all__ = [
    "ENGINE_PUSHSUM",
    "ENGINE_SOCIAL",
    "ENGINE_HPS",
    "ENGINE_BYZANTINE",
    "FAULT_EDGE",
    "FAULT_CHURN",
    "FAULT_PS",
    "FAULT_DOMAIN_BASE",
    "FaultModel",
    "FaultState",
    "fault_stream_fold",
    "make_fault_model",
    "gilbert_elliott_model",
    "init_fault_state",
    "edge_uniforms",
    "step_faults",
    "step_faults_nbr",
    "faulty_edge_mask",
    "ps_alive",
    "freeze",
]

# One engine slot per scan core that folds fault streams into its base
# key; one stream slot per independent fault draw. The affine fold-in
# map below separates (engine, stream) pairs by congruence class mod
# N_ENGINES * N_FAULT_STREAMS.
N_ENGINES = 4
ENGINE_PUSHSUM, ENGINE_SOCIAL, ENGINE_HPS, ENGINE_BYZANTINE = range(N_ENGINES)

N_FAULT_STREAMS = 3
FAULT_EDGE, FAULT_CHURN, FAULT_PS = range(N_FAULT_STREAMS)

# The fault domain starts below -2^21: strictly below the HPS ~t domain
# [-2^20, -1], and every existing engine stream (t, 2t+s, 3t+s) is
# nonnegative, so the whole plane is disjoint from every shipped stream
# by sign alone. Images stay within +-2^31 over the statics horizon
# (12 * 2^20 + 2^21 + 11 < 2^31), keeping the lattice proof sound.
FAULT_DOMAIN_BASE = 1 << 21

_STRIDE = N_ENGINES * N_FAULT_STREAMS


def fault_stream_fold(t, engine: int, stream: int):
    """Fold-in value for fault ``stream`` of ``engine`` at iteration ``t``.

    ``t -> -(STRIDE * t + 3 * engine + stream) - 2^21`` — affine, so the
    statics lattice prover certifies disjointness exactly. Python ints
    are pinned to ``np.int32`` (the ``hps_stream_fold`` convention) so
    host-side probing and the traced uint32/int32 scan index agree bit
    for bit mod 2^32.
    """
    slot = int(engine) * N_FAULT_STREAMS + int(stream)
    if isinstance(t, (int, np.integer)):
        return np.int32(-(int(t) * _STRIDE + slot) - FAULT_DOMAIN_BASE)
    return -(t * _STRIDE + slot) - FAULT_DOMAIN_BASE


class FaultModel(NamedTuple):
    """Scalar fault-severity knobs; a pytree that rides the vmap scenario
    axis (stack models leaf-wise to sweep fault axes without retracing).

    The defaults of :func:`make_fault_model` are fully degenerate: no
    edge ever turns bad, no agent ever leaves, the PS never crashes —
    and the realized masks equal today's Bernoulli draw bit-for-bit.
    """

    p_gb: jnp.ndarray        # () P(good -> bad) per edge per round
    p_bg: jnp.ndarray        # () P(bad -> good); mean burst = 1/p_bg
    drop_bad: jnp.ndarray    # () drop probability while bad
    leave_prob: jnp.ndarray  # () P(live agent leaves) per round
    join_prob: jnp.ndarray   # () P(dead agent rejoins) per round
    ps_crash_prob: jnp.ndarray  # () P(parameter server down) per round


def make_fault_model(
    *,
    p_gb=0.0,
    p_bg=1.0,
    drop_bad=1.0,
    leave_prob=0.0,
    join_prob=1.0,
    ps_crash_prob=0.0,
) -> FaultModel:
    f = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
    return FaultModel(
        p_gb=f(p_gb), p_bg=f(p_bg), drop_bad=f(drop_bad),
        leave_prob=f(leave_prob), join_prob=f(join_prob),
        ps_crash_prob=f(ps_crash_prob),
    )


def gilbert_elliott_model(
    mean_burst_len: float,
    bad_frac: float,
    *,
    drop_bad: float = 1.0,
    **kw,
) -> FaultModel:
    """Gilbert-Elliott chain parameterized by its stationary behavior:
    bursts last ``mean_burst_len`` rounds on average and an edge spends
    a ``bad_frac`` fraction of time in the bad state."""
    if mean_burst_len < 1.0:
        raise ValueError(f"mean_burst_len must be >= 1, got {mean_burst_len}")
    if not 0.0 <= bad_frac < 1.0:
        raise ValueError(f"bad_frac must be in [0, 1), got {bad_frac}")
    p_bg = 1.0 / mean_burst_len
    p_gb = bad_frac * p_bg / (1.0 - bad_frac)
    return make_fault_model(p_gb=p_gb, p_bg=p_bg, drop_bad=drop_bad, **kw)


class FaultState(NamedTuple):
    """Per-round fault realization carried through the scan: O(E) + O(N)."""

    edge_bad: jnp.ndarray   # (E,) bool — Gilbert-Elliott state per edge
    node_live: jnp.ndarray  # (N,) bool — churn liveness per agent


def init_fault_state(n_nodes: int, edge_shape) -> FaultState:
    """All edges good, all agents live (what t=0 of every engine assumes).

    ``edge_shape`` is the per-shard edge count (int) or a full slot
    shape like the byzantine ``(N, deg_max)`` neighbor table."""
    shape = (edge_shape,) if isinstance(edge_shape, int) else tuple(edge_shape)
    return FaultState(
        edge_bad=jnp.zeros(shape, bool),
        node_live=jnp.ones((n_nodes,), bool),
    )


def edge_uniforms(key, fold_t, e: int, *, graph_axis=None, n_shards: int = 1):
    """One uniform per (local) edge on ``fold_in(key, fold_t)``.

    Under a graph axis this mirrors ``shard_edge_mask``'s full-draw /
    window semantics: every shard draws the identical full
    ``(n_shards * e,)`` vector and slices its own window, so the fault
    realization is the same function of ``(key, t)`` at every shard
    count (threefry has no prefix property, so per-shard keys would
    change the realization with the partitioning).
    """
    kt = jax.random.fold_in(key, fold_t)
    if graph_axis is None:
        return jax.random.uniform(kt, (e,))
    full = jax.random.uniform(kt, (n_shards * e,))
    start = jax.lax.axis_index(graph_axis) * e
    return jax.lax.dynamic_slice(full, (start,), (e,))


def step_faults(
    key,
    t,
    fm: FaultModel,
    fs: FaultState,
    *,
    engine: int,
    graph_axis=None,
    n_shards: int = 1,
) -> FaultState:
    """Advance the Gilbert-Elliott edge chain and the churn liveness mask
    one round, on the engine's FAULT_EDGE / FAULT_CHURN streams.

    The (N,) churn draw is replicated (never windowed), so liveness is
    shard-count invariant for free; the edge draw windows like the link
    mask. Multi-dim edge state (the byzantine neighbor table) is only
    supported unsharded.
    """
    if graph_axis is None:
        ke = jax.random.fold_in(
            key, fault_stream_fold(t, engine, FAULT_EDGE))
        u_e = jax.random.uniform(ke, fs.edge_bad.shape)
    else:
        if fs.edge_bad.ndim != 1:
            raise ValueError(
                "sharded fault state requires 1-D edge_bad, got shape "
                f"{fs.edge_bad.shape}")
        u_e = edge_uniforms(
            key, fault_stream_fold(t, engine, FAULT_EDGE),
            fs.edge_bad.shape[0], graph_axis=graph_axis, n_shards=n_shards)
    edge_bad = jnp.where(fs.edge_bad, u_e >= fm.p_bg, u_e < fm.p_gb)

    kn = jax.random.fold_in(key, fault_stream_fold(t, engine, FAULT_CHURN))
    u_n = jax.random.uniform(kn, fs.node_live.shape)
    node_live = jnp.where(fs.node_live, u_n >= fm.leave_prob,
                          u_n < fm.join_prob)
    return FaultState(edge_bad=edge_bad, node_live=node_live)


def step_faults_nbr(key, t, fm: FaultModel, fs: FaultState, *, engine: int):
    """Neighbor-table variant of :func:`step_faults` -> (state, drop).

    The Byzantine engine's "edges" are the padded (N, deg_max) neighbor
    slots and its gossip has no baseline ``drop_prob`` (good slots always
    deliver), so the chain transition AND this round's per-slot drop coin
    both come from one ``(2, N, deg_max)`` uniform on the engine's
    FAULT_EDGE slot: plane 0 advances the Gilbert-Elliott state, plane 1
    decides whether a bad slot drops (``< drop_bad``). Churn draws on
    FAULT_CHURN exactly as in :func:`step_faults`.
    """
    ke = jax.random.fold_in(key, fault_stream_fold(t, engine, FAULT_EDGE))
    u2 = jax.random.uniform(ke, (2,) + fs.edge_bad.shape)
    edge_bad = jnp.where(fs.edge_bad, u2[0] >= fm.p_bg, u2[0] < fm.p_gb)

    kn = jax.random.fold_in(key, fault_stream_fold(t, engine, FAULT_CHURN))
    u_n = jax.random.uniform(kn, fs.node_live.shape)
    node_live = jnp.where(fs.node_live, u_n >= fm.leave_prob,
                          u_n < fm.join_prob)
    drop = edge_bad & (u2[1] < fm.drop_bad)
    return FaultState(edge_bad=edge_bad, node_live=node_live), drop


def faulty_edge_mask(u, t, fm: FaultModel, fs: FaultState, src, dst,
                     drop_prob, B):
    """Per-edge up/down mask under the fault plane.

    ``u`` is the engine's EXISTING per-round link uniform (drawn on its
    link stream) — with an all-good, all-live :class:`FaultState` the
    result equals ``step_edge_mask``'s ``(u >= drop_prob) | forced``
    bit-for-bit. Bad edges drop at ``drop_bad`` and are exempt from the
    B-window forcing (a burst IS a B-window violation); edges touching a
    dead endpoint are down unconditionally.
    """
    p_eff = jnp.where(fs.edge_bad, fm.drop_bad, drop_prob)
    forced = ((t % B) == (B - 1)) & ~fs.edge_bad
    mask = (u >= p_eff) | forced
    return mask & fs.node_live[src] & fs.node_live[dst]


def ps_alive(key, t, fm: FaultModel, *, engine: int):
    """Scalar bool: is the parameter server up this round (FAULT_PS
    stream)? Fusion rounds gate on this — a dead PS skips fusion, so the
    hierarchy degrades to local consensus instead of pooling garbage."""
    k = jax.random.fold_in(key, fault_stream_fold(t, engine, FAULT_PS))
    return jax.random.uniform(k, ()) >= fm.ps_crash_prob


def freeze(live, new, old):
    """``where(live, new, old)`` for (N,) or (N, d) node state — the
    churn semantics: a dead agent's state is carried unchanged so it
    rejoins stale, and the global mass invariant is untouched."""
    if new.ndim == live.ndim + 1:
        return jnp.where(live[:, None], new, old)
    return jnp.where(live, new, old)


# ---------------------------------------------------------------------------
# Statics contracts — one per engine that folds fault streams into its
# base key. Each declares (a) the fault-state shape discipline: fault
# arrays stay O(E) + O(N), no (N, N) and no (T, *) schedules may appear
# in a faulted trace; and (b) the fault fold-in maps, proven pairwise
# disjoint AND disjoint from the host engine's own streams (same base
# key!) by the shares_seed_with cross-links. repro.statics.cli maps each
# name to a concrete faulted fixture.
# ---------------------------------------------------------------------------

_FAULT_FORBIDDEN = {"*": (("N", "N"), ("T", "*"))}


def _fault_streams(engine: int, *, with_ps: bool):
    decls = [
        _contracts.StreamDecl(
            "fault-edge", lambda t, _e=engine: fault_stream_fold(
                t, _e, FAULT_EDGE)),
        _contracts.StreamDecl(
            "fault-churn", lambda t, _e=engine: fault_stream_fold(
                t, _e, FAULT_CHURN)),
    ]
    if with_ps:
        decls.append(_contracts.StreamDecl(
            "fault-ps", lambda t, _e=engine: fault_stream_fold(
                t, _e, FAULT_PS)))
    return tuple(decls)


# pushsum has no PS/fusion, so no FAULT_PS slot is ever drawn there.
_contracts.register(_contracts.EngineContract(
    name="pushsum_faults",
    forbidden=_FAULT_FORBIDDEN,
    streams=_fault_streams(ENGINE_PUSHSUM, with_ps=False),
    shares_seed_with=("pushsum", "pushsum_sharded"),
))

_contracts.register(_contracts.EngineContract(
    name="social_faults",
    forbidden=_FAULT_FORBIDDEN,
    streams=_fault_streams(ENGINE_SOCIAL, with_ps=True),
    shares_seed_with=("social", "hps", "byzantine",
                      "hps_faults", "byzantine_faults"),
))

_contracts.register(_contracts.EngineContract(
    name="hps_faults",
    forbidden=_FAULT_FORBIDDEN,
    streams=_fault_streams(ENGINE_HPS, with_ps=True),
    shares_seed_with=("hps", "social", "byzantine",
                      "social_faults", "byzantine_faults"),
))

_contracts.register(_contracts.EngineContract(
    name="byzantine_faults",
    forbidden=_FAULT_FORBIDDEN,
    streams=_fault_streams(ENGINE_BYZANTINE, with_ps=True),
    shares_seed_with=("byzantine", "social", "hps",
                      "social_faults", "hps_faults"),
))
