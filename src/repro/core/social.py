"""Non-Bayesian learning over packet-dropping links — Algorithm 3 / Theorem 2.

"Consensus + innovation": interleave one HPS step (on the per-hypothesis
log-likelihood accumulator ``z in R^m`` and the mass ``m``) with the local
innovation ``z(theta) += log l(s_t | theta)`` and the dual-averaging belief
update with KL-divergence proximal, whose closed form is

    mu_j(theta, t)  =  softmax( z_j(., t) / m_j(t) )        (uniform prior)

Per Algorithm 3 ordering: consensus (lines 4-12) -> innovation (13-15) ->
belief (16) -> PS fusion every Gamma (17-22).

The consensus state is the *sparse edge-list* push-sum core
(:mod:`repro.core.pushsum`): ``rho`` is (E, m) over the topology's directed
edges and each round's (E,) operational mask is drawn inside the scan —
memory is O(N m + E m) and no (T, N, N) schedule or (N, N, m) relay tensor
is ever materialized, so hierarchical systems with thousands of agents run
on sparse intra-network graphs at full scan speed.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import edge_list
from .hps import HPSConfig, hps_fusion
from .pushsum import (
    SparsePushSumState,
    init_sparse_state,
    sparse_pushsum_step,
    step_edge_mask,
)
from .signals import SignalModel

__all__ = ["SocialLearningResult", "kl_dual_averaging_update", "run_social_learning"]


class SocialLearningResult(NamedTuple):
    beliefs: jnp.ndarray             # (T, N, m) belief trajectories
    final_state: SparsePushSumState  # edge-list consensus state at T
    log_ratio: jnp.ndarray           # (T, N, m) log mu(theta)/mu(theta*) — Thm 2 LHS


def kl_dual_averaging_update(z: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """The KL-proximal dual-averaging projection, closed form.

    argmin_{mu in simplex} { -<z/m, mu> + D_KL(mu || mu_0) }  =  softmax(z/m)
    for the uniform prior mu_0. z: (N, m_hyp), m: (N,).
    """
    return jax.nn.softmax(z / jnp.maximum(m, 1e-30)[:, None], axis=-1)


def run_social_learning(
    model: SignalModel,
    cfg: HPSConfig,
    T: int,
    seed: int = 0,
    signal_seed: int = 100,
) -> SocialLearningResult:
    """Run Algorithm 3 for T iterations (jax.lax.scan over time).

    ``seed`` drives the per-round link masks (drawn edge-wise inside the
    scan with :func:`pushsum.step_edge_mask` — same drop_prob/B semantics as
    :func:`graphs.link_schedule`); ``signal_seed`` drives private signals.
    """
    topo = cfg.topo
    el = edge_list(topo.adj)
    src = jnp.asarray(el.src)
    dst = jnp.asarray(el.dst)
    valid = jnp.asarray(el.valid)
    rep_mask = cfg.rep_mask()
    mask_key = jax.random.PRNGKey(seed)
    fuse = jnp.arange(1, T + 1) % cfg.gamma_period == 0

    # z accumulates per-hypothesis log-likelihood sums; init 0 (Alg. 3 line 1)
    state0 = init_sparse_state(jnp.zeros((topo.N, model.m), jnp.float32), el.E)
    log_tables = model.log_tables().astype(jnp.float32)  # (N, m, S)
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)  # (N, S)
    base_key = jax.random.PRNGKey(signal_seed)

    def body(state, xs):
        do_fusion, t = xs
        # --- consensus (lines 4-12) ---
        mask = step_edge_mask(mask_key, t, el.E, cfg.drop_prob, cfg.B)
        st = sparse_pushsum_step(state, mask, src, dst, valid)
        # --- innovation (lines 13-15): one fresh private signal per agent ---
        key = jax.random.fold_in(base_key, t)
        keys = jax.random.split(key, topo.N)
        u = jax.vmap(lambda k: jax.random.uniform(k))(keys)  # (N,)
        cdf = jnp.cumsum(truth_probs, axis=-1)               # (N, S)
        sig = (u[:, None] > cdf).sum(axis=-1)                # inverse-CDF sample
        loglik = jnp.take_along_axis(
            log_tables, sig[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]                                           # (N, m)
        z = st.z + loglik
        # --- belief update (line 16) ---
        mu = kl_dual_averaging_update(z, st.m)
        # --- PS fusion (lines 17-22), applied post-innovation ---
        z_f, m_f = hps_fusion(z, st.m, rep_mask, topo.M)
        z = jnp.where(do_fusion, z_f, z)
        m = jnp.where(do_fusion, m_f, st.m)
        new = st._replace(z=z, m=m)
        return new, mu

    final, mus = jax.lax.scan(
        body, state0, (fuse, jnp.arange(T, dtype=jnp.uint32))
    )
    log_mu = jnp.log(jnp.maximum(mus, 1e-38))
    log_ratio = log_mu - log_mu[:, :, model.truth : model.truth + 1]
    return SocialLearningResult(beliefs=mus, final_state=final, log_ratio=log_ratio)


def theorem2_rate(model: SignalModel, topo_N: int) -> np.ndarray:
    """The linear decay slopes -D_KL(theta*||theta)/N of Theorem 2, (m,)."""
    from .signals import pairwise_kl

    kl = pairwise_kl(np.asarray(model.tables))  # (N, m, m) per-agent
    total = kl.sum(axis=0)  # (m, m): joint KL because signals are independent
    return -total[model.truth] / topo_N
