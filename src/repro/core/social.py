"""Non-Bayesian learning over packet-dropping links — Algorithm 3 / Theorem 2.

"Consensus + innovation": interleave one HPS step (on the per-hypothesis
log-likelihood accumulator ``z in R^m`` and the mass ``m``) with the local
innovation ``z(theta) += log l(s_t | theta)`` and the dual-averaging belief
update with KL-divergence proximal, whose closed form is

    mu_j(theta, t)  =  softmax( z_j(., t) / m_j(t) )        (uniform prior)

Per Algorithm 3 ordering: consensus (lines 4-12) -> innovation (13-15) ->
belief (16) -> PS fusion every Gamma (17-22).

The fused, batched engine
-------------------------
The scan body is split into the two per-iteration hot halves, each behind
the repo-wide ``backend="auto"|"xla"|"pallas"`` switch:

* **consensus** — the sparse edge-list push-sum core
  (:mod:`repro.core.pushsum`): ``rho`` is (E, m) over the topology's
  directed edges, each round's (E,) operational mask is drawn inside the
  scan, and delivery + integration run through
  :mod:`repro.kernels.pushsum_edge` (fused gather/mask-latch/segment-sum
  over the dst-sorted edge index). Memory is O(N m + E m); no (T, N, N)
  schedule or (N, N, m) relay tensor ever exists.
* **innovation + belief** — :mod:`repro.kernels.social_innov`: inverse-CDF
  signal sampling, the (N, m) log-likelihood gather, ``z += loglik``, and
  the softmax belief in ONE streaming pass over agent blocks instead of
  five separate XLA ops with (N, S) intermediates per step.

Every loop invariant is hoisted out of the scan: the truth-row CDF (the
seed path recomputed ``jnp.cumsum(truth_probs)`` every iteration), the log
tables, the representative mask, and the out-degree share factors of the
fixed edge index. Per-agent uniforms are one ``jax.random.uniform(key,
(N,))`` draw (the seed path split N keys and vmapped scalar draws).

All per-scenario inputs live in a :class:`SocialRuntime` of *arrays*
(``drop_prob``/``gamma``/``B`` are traced scalars), so a batch of
compatible scenarios stacks leaf-wise and rides one ``jax.vmap`` axis —
see :func:`repro.core.sweeps.run_social_sweep` /
:func:`repro.core.sweeps.run_social_grid` for the batched (and
mesh-sharded) engines built on :func:`_social_scan_core`.

``store`` selects what the scan materializes — ``"trajectory"`` the full
(T, N, m) belief + log-ratio histories, ``"log_ratio"`` the in-scan-reduced
(T,) worst log-ratio curve (Theorem 2's LHS) plus final beliefs, and
``"final"`` final beliefs only — so long horizons never carry O(T N m)
out of the scan unless asked to.

PRNG streams: each iteration consumes two independent streams (link masks,
private signals) with disjoint fold-in domains ``t * 2 + stream``
(:func:`social_stream_fold`), so ``seed == signal_seed`` no longer aliases
the two streams (the seed scheme folded plain ``t`` into both base keys).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .asyncrony import (
    AsyncModel,
    init_async_buffer,
    is_degenerate_async,
    wake_mask,
)
from .faults import (
    ENGINE_SOCIAL,
    FaultModel,
    edge_uniforms,
    faulty_edge_mask,
    freeze,
    init_fault_state,
    ps_alive,
    step_faults,
)
from .graphs import EdgeList
from .hps import HPSConfig, hps_fusion
from .plan import ExecutionPlan, resolve_plan
from .precision import Policy, resolve_policy
from .pushsum import (
    SparsePushSumState,
    _out_degree,
    init_sparse_state,
    shard_edge_mask,
    sparse_pushsum_step,
    step_edge_mask,
)
from .signals import SignalModel
from repro.statics.contracts import contract as statics_contract
from repro.statics.retrace import register_cache as register_statics_cache

__all__ = [
    "SocialLearningResult",
    "SocialRuntime",
    "SOCIAL_STORES",
    "N_SOCIAL_STREAMS",
    "STREAM_LINK",
    "STREAM_SIGNAL",
    "social_stream_fold",
    "kl_dual_averaging_update",
    "make_social_runtime",
    "social_runtime_from_edge_list",
    "run_social_learning",
    "run_social_runtime",
    "theorem2_rate",
]

SOCIAL_STORES = ("trajectory", "log_ratio", "final")

# Belief floor for the log-ratio: the smallest NORMAL fp32. The seed path
# floored at 1e-38, which is subnormal — XLA CPU flushes subnormal log
# inputs to zero, so a fully-converged wrong-hypothesis belief (mu == 0)
# yielded log(-inf) and a NaN truth-column ratio at high drop rates.
_MU_FLOOR = np.float32(np.finfo(np.float32).tiny)

# Per-iteration PRNG streams, disjoint fold-in domains t * N_STREAMS + s
# (same scheme as repro.core.byzantine.stream_fold): the link-mask draw at
# iteration t can never collide with the signal draw of any iteration even
# when both streams are rooted at the same base key (seed == signal_seed).
N_SOCIAL_STREAMS = 2
STREAM_LINK, STREAM_SIGNAL = range(N_SOCIAL_STREAMS)


def social_stream_fold(t, stream: int):
    """Fold-in value of ``stream`` at iteration ``t`` — injective over
    (t, stream), which is what keeps the two per-iteration streams
    non-colliding over any horizon."""
    return t * N_SOCIAL_STREAMS + stream


class SocialLearningResult(NamedTuple):
    """Engine output; shapes depend on the ``store`` option.

    ``store="trajectory"`` (default): ``beliefs`` (T, N, m), ``log_ratio``
    (T, N, m) — log mu(theta)/mu(theta*), Theorem 2's LHS.
    ``store="log_ratio"``: ``beliefs`` is the final (N, m) only and
    ``log_ratio`` the (T,) worst-case curve max_{j, theta != theta*}
    log mu_j(theta)/mu_j(theta*), reduced inside the scan.
    ``store="final"``: both final-step only, (N, m) each.
    """

    beliefs: jnp.ndarray
    final_state: SparsePushSumState  # edge-list consensus state at T
    log_ratio: jnp.ndarray


class SocialRuntime(NamedTuple):
    """Everything the scan body reads that can vary per scenario.

    All fields are arrays, so a batch of *compatible* scenarios — same
    (N, M) and edge lists padded to a common E — stacks leaf-wise onto one
    leading scenario axis and rides a single ``jax.vmap``
    (:func:`repro.core.sweeps.run_social_grid`). ``drop_prob``, ``gamma``
    and ``B`` are scalars here precisely so they can be traced
    per-scenario: the fusion schedule ``(t + 1) % gamma == 0`` and the
    B-window forced delivery are computed in-scan from the traced values,
    keeping ONE compiled program for the whole (drop x Gamma x topology)
    grid.
    """

    src: jnp.ndarray        # (E,) int32 sender per edge (dst-sorted layout)
    dst: jnp.ndarray        # (E,) int32 receiver per edge
    valid: jnp.ndarray      # (E,) bool — False on padding edges
    rep_mask: jnp.ndarray   # (N,) bool — designated representatives
    drop_prob: jnp.ndarray  # () f32 per-link packet-drop probability
    gamma: jnp.ndarray      # () i32 PS fusion period
    B: jnp.ndarray          # () i32 link-reliability window


def kl_dual_averaging_update(z: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """The KL-proximal dual-averaging projection, closed form.

    argmin_{mu in simplex} { -<z/m, mu> + D_KL(mu || mu_0) }  =  softmax(z/m)
    for the uniform prior mu_0. z: (N, m_hyp), m: (N,).
    """
    return jax.nn.softmax(z / jnp.maximum(m, 1e-30)[:, None], axis=-1)


def social_runtime_from_edge_list(
    el: EdgeList,
    rep_mask: np.ndarray,
    *,
    drop_prob: float,
    gamma_period: int,
    B: int = 1,
    e_max: int | None = None,
) -> SocialRuntime:
    """Build a :class:`SocialRuntime` directly from a sparse edge index.

    The dense-free entry point for large-N systems (pair with
    :func:`repro.core.graphs.block_complete_edge_list` — no (N, N)
    adjacency is ever touched). ``el`` should be dst-sorted
    (:func:`graphs.sort_by_dst`) for the Pallas consensus backend; the XLA
    backend accepts any order. ``e_max`` pads the edge axis (inert
    ``valid=False`` edges with ``dst = N - 1``, which keeps a sorted layout
    sorted) so scenario batches over different topologies can share one
    shape.
    """
    if el.is_batched:
        raise ValueError("pass one topology draw; batching happens leaf-wise")
    src, dst, valid = el.src, el.dst, el.valid
    if e_max is not None:
        pad = e_max - el.E
        if pad < 0:
            raise ValueError(f"e_max={e_max} < edge count {el.E}")
        src = np.concatenate([src, np.zeros(pad, np.int32)])
        dst = np.concatenate([dst, np.full(pad, el.n - 1, np.int32)])
        valid = np.concatenate([valid, np.zeros(pad, bool)])
    return SocialRuntime(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        valid=jnp.asarray(valid, bool),
        rep_mask=jnp.asarray(np.asarray(rep_mask, bool)),
        drop_prob=jnp.asarray(drop_prob, jnp.float32),
        gamma=jnp.asarray(gamma_period, jnp.int32),
        B=jnp.asarray(B, jnp.int32),
    )


def make_social_runtime(cfg: HPSConfig, e_max: int | None = None) -> SocialRuntime:
    """Host-side setup of one :class:`~repro.core.hps.HPSConfig` scenario."""
    return social_runtime_from_edge_list(
        cfg.edge_index(),
        cfg.topo.rep_mask(),
        drop_prob=cfg.drop_prob,
        gamma_period=cfg.gamma_period,
        B=cfg.B,
        e_max=e_max,
    )


# ---------------------------------------------------------------------------
# The shared scan core
# ---------------------------------------------------------------------------

@statics_contract(
    name="social",
    # Dense-free everywhere; the in-scan-reducing stores must additionally
    # never materialize a rank>=2 horizon-major value (the (T,) reduced
    # curves are the POINT of those stores and stay allowed).
    forbidden={
        "*": (("N", "N"),),
        "final": (("T", "*"),),
        "log_ratio": (("T", "*"),),
    },
    streams=(
        ("link", lambda t: social_stream_fold(t, STREAM_LINK)),
        ("signal", lambda t: social_stream_fold(t, STREAM_SIGNAL)),
    ),
    caches=("social.compiled", "social.runtime", "social.jit"),
)
def _social_scan_core(
    mask_key: jnp.ndarray,
    sig_key: jnp.ndarray,
    rt: SocialRuntime,
    log_tables: jnp.ndarray,  # (N, m, S) hoisted log-likelihood tables
    cdf: jnp.ndarray,         # (N, S) hoisted truth-row inclusive cumsum
    *,
    truth: int,
    M: int,
    T: int,
    store: str,
    backend: str,
    graph_axis: str | None = None,
    n_shards: int = 1,
    policy: Policy | str | None = None,
    dst_sorted: bool = False,
    halo: str = "psum",
    faults: FaultModel | None = None,
    async_: AsyncModel | None = None,
) -> tuple[SparsePushSumState, tuple[jnp.ndarray, jnp.ndarray]]:
    """Algorithm 3's scan, parameterized over the per-scenario runtime
    arrays (vmappable for batched grids).

    Returns ``(final_state, (beliefs, log_ratio))`` with the store-dependent
    shapes of :class:`SocialLearningResult`.

    ``graph_axis``/``n_shards`` run the consensus half edge-partitioned
    exactly as in :func:`repro.core.hps._hps_scan_core`: the runtime's edge
    arrays carry a per-device (E_shard,) shard, the link-mask stream is
    windowed from the full padded draw on the same fold-in domain, and the
    out-degree / receiver partials are psum'd over the mesh graph axis
    (``halo="scatter"`` swaps the psum pair for the reduce-scatter +
    quantize + all-gather combine of :func:`sparse_pushsum_step`). The
    innovation and fusion halves touch only replicated (N, ...) node state
    and need no changes.

    ``policy`` (:mod:`repro.core.precision`) puts every persistent scan
    value — the push-sum state AND the final-belief carry — in the storage
    dtype while the innovation accumulation, fusion pools, and belief
    softmax run in the accum dtype. ``dst_sorted=True`` asserts the
    runtime's edge index is dst-sorted (true for everything built from
    ``HPSConfig.edge_index()``; user-supplied runtimes default to False).
    All of these kwargs are trace statics — except ``faults``, a TRACED
    :class:`repro.core.faults.FaultModel` pytree riding the vmap scenario
    axis: bursty Gilbert-Elliott links, churn (dead agents neither gossip
    nor observe signals — consensus state, accumulator, and belief all
    freeze until rejoin), and PS crash (fusion rounds skipped while the
    coordinator is down). ``faults=None`` emits the bit-identical
    pre-fault program.

    ``async_`` — also a TRACED pytree (:class:`repro.core.asyncrony
    .AsyncModel`) on the vmap scenario axis — runs the event-driven
    mode: the consensus half steps blocks of concurrent wakeups with
    per-edge bounded stale buffers (an O(E·m) extra carry), asleep
    agents observe no signal (accumulator and belief freeze like the
    churn path, which is why the final-belief carry is forced on), and
    the PS fusion stays on the synchronous global Γ clock — the
    parameter server polls its representatives regardless of their
    gossip clocks. Wake coins ride the engine's async-wake stream
    (:func:`repro.core.asyncrony.async_stream_fold`), disjoint from the
    link/signal/fault folds. Composes with ``faults``; incompatible
    with ``graph_axis`` edge partitioning.
    """
    from repro.kernels.social_innov import innovation_step

    if async_ is not None and graph_axis is not None:
        raise ValueError(
            "async mode does not compose with graph_axis edge partitioning"
        )
    pol = None if policy is None else resolve_policy(policy)
    st_dt = jnp.float32 if pol is None else pol.storage_dtype
    accum_name = None if pol is None else pol.accum
    N, m = log_tables.shape[0], log_tables.shape[1]
    E = rt.src.shape[0]
    # z accumulates per-hypothesis log-likelihood sums; init 0 (Alg. 3 line 1)
    state0 = init_sparse_state(jnp.zeros((N, m), jnp.float32), E,
                               policy=policy)
    # loop invariants of the fixed edge index, hoisted out of the scan
    d_out = _out_degree(rt.src, rt.valid, N, jnp.float32)
    if graph_axis is not None:
        d_out = jax.lax.psum(d_out, graph_axis)
    share = 1.0 / (d_out + 1.0)

    # the trajectory store emits every belief through ys, so only the other
    # stores need the final mu threaded through the carry (storage dtype —
    # under a bf16 policy no fp32 (N, m) value may persist across rounds).
    # The fault and async planes always carry mu: a dead or asleep agent's
    # belief freezes to its last live value, which must therefore survive
    # in the carry.
    carry_mu = store != "trajectory" or faults is not None \
        or async_ is not None
    # carry layout: (state,) [+ mu] [+ abuf] [+ fault_state]
    abuf_idx = 1 + int(carry_mu)

    def body(carry, t):
        state = carry[0]
        if faults is not None:
            fs = step_faults(mask_key, t, faults, carry[-1],
                             engine=ENGINE_SOCIAL,
                             graph_axis=graph_axis, n_shards=n_shards)
        # --- consensus (lines 4-12) ---
        if faults is not None:
            # drop uniform stays on the social link stream (degenerate
            # model == step_edge_mask values draw-for-draw)
            u_e = edge_uniforms(
                mask_key, social_stream_fold(t, STREAM_LINK), E,
                graph_axis=graph_axis, n_shards=n_shards)
            mask = faulty_edge_mask(u_e, t, faults, fs, rt.src, rt.dst,
                                    rt.drop_prob, rt.B)
        elif graph_axis is not None:
            mask = shard_edge_mask(
                mask_key, t, E, rt.drop_prob, rt.B,
                graph_axis=graph_axis, n_shards=n_shards,
                fold_t=social_stream_fold(t, STREAM_LINK),
            )
        else:
            mask = step_edge_mask(
                mask_key, t, E, rt.drop_prob, rt.B,
                fold_t=social_stream_fold(t, STREAM_LINK),
            )
        if async_ is not None:
            awake = wake_mask(mask_key, t, N, async_.wake_prob,
                              engine=ENGINE_SOCIAL)
            st, abuf = sparse_pushsum_step(
                state, mask, rt.src, rt.dst, rt.valid, backend, share=share,
                dst_sorted=dst_sorted, policy=policy,
                faults=None if faults is None else fs,
                awake=awake, abuf=carry[abuf_idx],
                staleness=async_.staleness,
            )
        else:
            st = sparse_pushsum_step(
                state, mask, rt.src, rt.dst, rt.valid, backend, share=share,
                graph_axis=graph_axis, dst_sorted=dst_sorted, policy=policy,
                halo=halo, n_shards=n_shards,
                faults=None if faults is None else fs,
            )
        # --- innovation + belief (lines 13-16), one fused pass ---
        sk = jax.random.fold_in(sig_key, social_stream_fold(t, STREAM_SIGNAL))
        u = jax.random.uniform(sk, (N,))
        z, mu = innovation_step(st.z, st.m, u, cdf, log_tables, backend,
                                accum_dtype=accum_name)
        if async_ is not None:
            # asleep agents observe nothing: accumulator and belief stay
            # at their frozen values until the next wake
            z = freeze(awake, z, st.z)
            mu = freeze(awake, mu, carry[1].astype(mu.dtype))
        if faults is not None:
            # dead agents observe nothing: the accumulator stays at its
            # frozen post-consensus value and the belief stays stale
            z = freeze(fs.node_live, z, st.z)
            mu = freeze(fs.node_live, mu, carry[1].astype(mu.dtype))
        # --- PS fusion every Γ (lines 17-22), applied post-innovation ---
        z_f, m_f = hps_fusion(z, st.m, rt.rep_mask, M,
                              accum_dtype=accum_name,
                              live=None if faults is None else fs.node_live)
        do_fusion = (t + 1) % rt.gamma == 0
        if faults is not None:
            # PS crash: skip the fusion round, degrade to local consensus
            do_fusion = do_fusion & ps_alive(mask_key, t, faults,
                                             engine=ENGINE_SOCIAL)
        new = st._replace(
            z=jnp.where(do_fusion, z_f, z),
            m=jnp.where(do_fusion, m_f, st.m),
        )
        if store == "trajectory":
            ys = mu
        elif store == "log_ratio":
            log_mu = jnp.log(jnp.maximum(mu, _MU_FLOOR))
            lr = log_mu - log_mu[:, truth : truth + 1]
            wrong = jnp.where(jnp.arange(m) == truth, -jnp.inf, lr)
            ys = wrong.max()          # () worst wrong-hypothesis log ratio
        else:
            ys = None
        out = (new,) + ((mu.astype(st_dt),) if carry_mu else ())
        if async_ is not None:
            out = out + (abuf,)
        if faults is not None:
            out = out + (fs,)
        return out, ys

    carry0 = (state0,) + (
        (jnp.zeros((N, m), st_dt),) if carry_mu else ())
    if async_ is not None:
        carry0 = carry0 + (init_async_buffer(E, m, state0.z.dtype),)
    if faults is not None:
        carry0 = carry0 + (init_fault_state(N, E),)
    (final, *rest), ys = jax.lax.scan(
        body, carry0, jnp.arange(T, dtype=jnp.int32)
    )
    if store == "trajectory":
        log_mu = jnp.log(jnp.maximum(ys, _MU_FLOOR))
        return final, (ys, log_mu - log_mu[:, :, truth : truth + 1])
    mu_fin = rest[0]
    if mu_fin.dtype != jnp.float32:
        mu_fin = mu_fin.astype(jnp.float32)   # diagnostics stay full width
    if store == "log_ratio":
        return final, (mu_fin, ys)
    log_mu = jnp.log(jnp.maximum(mu_fin, _MU_FLOOR))
    return final, (mu_fin, log_mu - log_mu[:, truth : truth + 1])


# Module-level jit so repeated runs with the same shapes/statics hit the
# compilation cache instead of retracing a fresh closure per call.
_social_compiled = functools.partial(
    jax.jit,
    static_argnames=("truth", "M", "T", "store", "backend", "graph_axis",
                     "n_shards", "policy", "dst_sorted", "halo"),
)(_social_scan_core)
register_statics_cache("social.jit", _social_compiled._cache_size)


def run_social_runtime(
    model: SignalModel,
    rt: SocialRuntime,
    M: int,
    T: int,
    seed: int = 0,
    signal_seed: int | None = None,
    *,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> SocialLearningResult:
    """Run Algorithm 3 on a prebuilt :class:`SocialRuntime`.

    The dense-free entry point (see :func:`social_runtime_from_edge_list`);
    :func:`run_social_learning` is the :class:`~repro.core.hps.HPSConfig`
    convenience wrapper. ``signal_seed`` defaults to ``seed`` — the two
    streams stay independent either way thanks to the disjoint fold-in
    domains, and the batched sweeps drive both streams from one
    per-scenario seed.

    Execution knobs ride ``plan=`` (:class:`repro.core.plan.ExecutionPlan`;
    loose ``backend=``/``store=``/``policy=``/``dst_sorted=``/``faults=``
    kwargs are deprecated shims folding into a plan bit-identically).
    ``plan.store=None`` means ``"trajectory"``. ``plan.dst_sorted``
    defaults to False because a user-built runtime may carry any edge
    order; the config-driven wrappers pass True
    (``HPSConfig.edge_index()`` is always dst-sorted). A concretely
    degenerate ``plan.async_`` dispatches to the synchronous program
    (bit-identity by construction — see :mod:`repro.core.asyncrony`).
    """
    plan = resolve_plan(
        plan, _entry="run_social_runtime",
        _supports=("backend", "store", "policy", "dst_sorted", "faults",
                   "async_"),
        **legacy)
    store = "trajectory" if plan.store is None else plan.store
    if store not in SOCIAL_STORES:
        raise ValueError(f"store must be one of {SOCIAL_STORES}, got {store!r}")
    async_ = None if is_degenerate_async(plan.async_) else plan.async_
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)
    final, (beliefs, log_ratio) = _social_compiled(
        jax.random.PRNGKey(seed),
        jax.random.PRNGKey(seed if signal_seed is None else signal_seed),
        rt,
        model.log_tables().astype(jnp.float32),
        jnp.cumsum(truth_probs, axis=-1),
        truth=model.truth,
        M=M,
        T=T,
        store=store,
        backend=plan.backend,
        policy=None if plan.policy is None else resolve_policy(plan.policy),
        dst_sorted=plan.dst_sorted,
        faults=plan.faults,
        async_=async_,
    )
    return SocialLearningResult(
        beliefs=beliefs, final_state=final, log_ratio=log_ratio
    )


def run_social_learning(
    model: SignalModel,
    cfg: HPSConfig,
    T: int,
    seed: int = 0,
    signal_seed: int = 100,
    *,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> SocialLearningResult:
    """Run Algorithm 3 for T iterations (single scenario).

    ``seed`` drives the per-round link masks (drawn edge-wise inside the
    scan with :func:`pushsum.step_edge_mask` — same drop_prob/B semantics as
    :func:`graphs.link_schedule`); ``signal_seed`` drives private signals.
    The two streams use disjoint fold-in domains, so any (seed,
    signal_seed) pair — including equal values — yields independent masks
    and signals. Execution knobs ride ``plan=``
    (:class:`repro.core.plan.ExecutionPlan`; loose kwargs are deprecated
    shims): ``plan.backend`` selects the consensus + innovation lowerings
    (module docstring); ``plan.store`` what the scan materializes
    (:class:`SocialLearningResult`; ``None`` = ``"trajectory"``);
    ``plan.policy`` the storage/compute/accum dtype split
    (:mod:`repro.core.precision`); ``plan.faults`` / ``plan.async_`` the
    fault and event-driven planes.
    """
    plan = resolve_plan(
        plan, _entry="run_social_learning",
        _supports=("backend", "store", "policy", "faults", "async_"),
        **legacy)
    return run_social_runtime(
        model, make_social_runtime(cfg), cfg.topo.M, T,
        seed=seed, signal_seed=signal_seed,
        plan=plan.replace(dst_sorted=True),
    )


def theorem2_rate(model: SignalModel, topo_N: int) -> np.ndarray:
    """The linear decay slopes -D_KL(theta*||theta)/N of Theorem 2, (m,)."""
    from .signals import pairwise_kl

    kl = pairwise_kl(np.asarray(model.tables))  # (N, m, m) per-agent
    total = kl.sum(axis=0)  # (m, m): joint KL because signals are independent
    return -total[model.truth] / topo_N
