"""Vmapped + mesh-sharded scenario sweeps — "as many scenarios as you can
imagine".

The sparse edge-list push-sum core (:mod:`repro.core.pushsum`) keeps per-
scenario state at O(E d), so a whole grid of scenarios — seeds x drop
probabilities x topology draws — fits comfortably in one ``jax.vmap`` over a
single compiled ``lax.scan``. One XLA program executes every scenario in
lockstep; per-scenario consensus error is reduced inside the scan so the
sweep's memory is O(K (N d + E d)) regardless of T. Pass a ``mesh`` to
:func:`run_pushsum_sweep` and the scenario axis is additionally sharded
over the mesh's ``data`` axis with ``shard_map`` (one scenario batch per
device), so grids in the thousands run as one program across the fleet.

Two engines:

* :func:`run_pushsum_sweep` — Theorem 1 dynamics (Alg. 1 consensus) over
  seed x drop_prob x topology-draw grids; ``backend`` selects the XLA or
  fused-Pallas delivery lowering per round.
* :func:`run_byzantine_sweep` — Algorithm 2 learning over seed batches per
  attack. Attack *type* changes the traced program (attacks are function-
  valued), so types iterate host-side while seeds ride the vmap axis; the
  compiled scan per (model, config, T, attack) is cached module-side so
  repeated calls with the same shapes/config never retrace.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .attacks import Attack
from .byzantine import ByzantineConfig, ByzantineResult, make_byzantine_scan
from .graphs import EdgeList
from .pushsum import (
    init_sparse_state,
    sparse_mass_invariant,
    sparse_pushsum_step,
    sparse_ratios,
    step_edge_mask,
)
from .signals import SignalModel

__all__ = [
    "PushSumSweepResult",
    "run_pushsum_sweep",
    "run_byzantine_sweep",
]


class PushSumSweepResult(NamedTuple):
    err: jnp.ndarray          # (K, T) max-agent consensus error per round
    final_ratio: jnp.ndarray  # (K, N, d) z/m estimates at T
    mass_gap: jnp.ndarray     # (K, d) mass-invariant violation at T
    drop_prob: jnp.ndarray    # (K,) scenario coordinates
    seed: jnp.ndarray         # (K,)
    graph: jnp.ndarray        # (K,) topology-draw index

    @property
    def K(self) -> int:
        return int(self.err.shape[0])


def _scenario_grid(n_graphs: int, drop_probs, seeds):
    """Flatten the (graph x drop x seed) grid into K-long coordinate arrays."""
    drop_probs = np.atleast_1d(np.asarray(drop_probs, np.float32))
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    g, d, s = np.meshgrid(
        np.arange(n_graphs, dtype=np.int32), drop_probs, seeds, indexing="ij"
    )
    return g.ravel(), d.ravel(), s.ravel()


def _sweep_body(w, src_b, dst_b, valid_b, drop_b, seed_b, *, T, B, backend):
    """Vmapped scenario batch: the shared traced program of both the
    single-device and the shard_map-per-device sweep paths."""
    E = src_b.shape[1]
    target = w.mean(axis=0)          # (d,) true average, shared
    w_sum = w.sum(axis=0)

    def single(src, dst, valid, drop, seed):
        key = jax.random.PRNGKey(seed)
        state0 = init_sparse_state(w, E)

        def body(state, t):
            mask = step_edge_mask(key, t, E, drop, B)
            new = sparse_pushsum_step(state, mask, src, dst, valid, backend)
            err = jnp.abs(sparse_ratios(new) - target).max()
            return new, err

        final, errs = jax.lax.scan(
            body, state0, jnp.arange(T, dtype=jnp.uint32)
        )
        gap = sparse_mass_invariant(final, src, valid) - w_sum
        return errs, sparse_ratios(final), gap

    return jax.vmap(single)(src_b, dst_b, valid_b, drop_b, seed_b)


# Module-level jit so repeated sweeps with the same shapes/statics hit the
# compilation cache instead of retracing a fresh closure per call.
_sweep_compiled = functools.partial(
    jax.jit, static_argnames=("T", "B", "backend")
)(_sweep_body)


@functools.lru_cache(maxsize=None)
def _sweep_sharded(mesh: Mesh, data_axis: str, T: int, B: int, backend: str):
    """Jitted shard_map sweep for one (mesh, axis, statics) combo: the
    scenario axis of every batched argument is split over ``data_axis``,
    one contiguous scenario block per device, and each device runs the
    identical vmapped scan on its block. lru_cache keeps one compiled
    executable per combo (Mesh is hashable), mirroring ``_sweep_compiled``'s
    retrace-free behaviour."""
    from repro.launch import compat

    body = functools.partial(_sweep_body, T=T, B=B, backend=backend)
    sharded = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis), P(data_axis),
                  P(data_axis), P(data_axis)),
        out_specs=(P(data_axis), P(data_axis), P(data_axis)),
        axis_names=frozenset({data_axis}),
        check_vma=False,
    )
    return jax.jit(sharded)


def run_pushsum_sweep(
    w: jnp.ndarray,            # (N, d) initial values, shared by scenarios
    el: EdgeList,              # single graph or stacked draws (leading G axis)
    T: int,
    *,
    drop_probs: Sequence[float] | float = 0.0,
    seeds: Sequence[int] | int = 0,
    B: int = 4,
    backend: str = "auto",
    mesh: Mesh | None = None,
    data_axis: str = "data",
) -> PushSumSweepResult:
    """Run the full scenario grid in ONE jitted, vmapped scan.

    Scenario axes: every topology draw in ``el`` (see
    :func:`graphs.stack_edge_lists`) x every drop probability x every seed —
    K = G * |drop_probs| * |seeds| scenarios total. Per-round (E,) link
    masks are drawn inside the scan; nothing of size (T, N, N) or (N, N)
    ever exists. Compilation is cached at module level: repeated sweeps
    with the same array shapes and statics reuse the executable.

    ``backend`` selects the per-round delivery lowering
    (:mod:`repro.kernels.pushsum_edge`; ``"pallas"`` expects dst-sorted
    edges). With ``mesh`` given, the K scenario axis is sharded over
    ``mesh``'s ``data_axis`` via ``shard_map`` — K is padded by repeating
    the last scenario up to a multiple of the axis size (one scenario batch
    per device; the pad rows are sliced off the result), so grids in the
    thousands still run as a single program.
    """
    w = jnp.asarray(w)
    src = np.atleast_2d(el.src)      # (G, E)
    dst = np.atleast_2d(el.dst)
    valid = np.atleast_2d(el.valid)
    G, E = src.shape
    gi, dp, sd = _scenario_grid(G, drop_probs, seeds)
    K = gi.shape[0]

    if mesh is None:
        pad = 0
    else:
        n_dev = int(mesh.shape[data_axis])
        pad = (-K) % n_dev
        if pad:                       # repeat the last scenario to fill
            fill = np.full(pad, K - 1)
            gi = np.concatenate([gi, gi[fill]])
            dp = np.concatenate([dp, dp[fill]])
            sd = np.concatenate([sd, sd[fill]])

    drop_b = jnp.asarray(dp)
    seed_b = jnp.asarray(sd)
    args = (w, jnp.asarray(src[gi]), jnp.asarray(dst[gi]),
            jnp.asarray(valid[gi]), drop_b, seed_b)
    if mesh is None:
        errs, finals, gaps = _sweep_compiled(*args, T=T, B=B, backend=backend)
    else:
        errs, finals, gaps = _sweep_sharded(
            mesh, data_axis, T, B, backend
        )(*args)
    return PushSumSweepResult(
        err=errs[:K], final_ratio=finals[:K], mass_gap=gaps[:K],
        drop_prob=drop_b[:K], seed=seed_b[:K], graph=jnp.asarray(gi[:K]),
    )


# Compiled Algorithm-2 sweeps, one jitted vmapped scan per
# (model, topology, F, byz set, Gamma, attack, T) combo. The scan closure
# returned by make_byzantine_scan is a fresh Python object per call, so
# wrapping it in jax.jit anew would retrace every time even though the
# traced program is identical; keying the *jitted callable* on the config
# fingerprint gives run_byzantine_sweep the same retrace-free repeated-call
# behaviour as _sweep_compiled. Entries are tiny (a jit wrapper + its
# executable); simulation studies touch at most a handful of combos.
_BYZ_COMPILED: dict[tuple, Callable] = {}


def _byz_sweep_key(model: SignalModel, cfg: ByzantineConfig, T: int) -> tuple:
    topo = cfg.topo
    return (
        np.asarray(model.tables).tobytes(), model.truth,
        topo.adj.tobytes(), topo.sizes, topo.offsets, topo.reps,
        cfg.F, cfg.byz, cfg.gamma_period, cfg.attack, T,
    )


def run_byzantine_sweep(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seeds: Sequence[int],
    attacks: Sequence[Attack] | None = None,
) -> dict[str, ByzantineResult]:
    """Algorithm 2 over a seed batch per attack type.

    For each attack (default: just ``cfg.attack``) the whole seed batch runs
    as one jitted ``vmap`` of the scan built by
    :func:`byzantine.make_byzantine_scan` — results carry a leading seed
    axis: ``r`` is (S, T, N, m, m), ``decisions`` (S, T, N). Attack types
    swap the traced message function, so they iterate host-side.

    Repeated calls with the same (model, config, T, attack) and seed-batch
    shape neither retrace nor re-run the host-side healthy-network
    analysis: the C-set lattice is memoized in :mod:`repro.core.byzantine`
    and the jitted scan is reused from ``_BYZ_COMPILED`` (``Attack`` is a
    frozen dataclass, so the same attack object keys the same entry).
    """
    import dataclasses

    seeds_j = jnp.asarray(np.asarray(seeds, np.uint32))
    keys = jax.vmap(jax.random.PRNGKey)(seeds_j)
    out: dict[str, ByzantineResult] = {}
    for atk in attacks if attacks is not None else [cfg.attack]:
        c = dataclasses.replace(cfg, attack=atk)
        cache_key = _byz_sweep_key(model, c, T)
        fn = _BYZ_COMPILED.get(cache_key)
        if fn is None:
            run = make_byzantine_scan(model, c, T)
            fn = _BYZ_COMPILED[cache_key] = jax.jit(jax.vmap(run))
        out[atk.name] = fn(keys)
    return out
