"""Vmapped scenario sweeps — "as many scenarios as you can imagine".

The sparse edge-list push-sum core (:mod:`repro.core.pushsum`) keeps per-
scenario state at O(E d), so a whole grid of scenarios — seeds x drop
probabilities x topology draws — fits comfortably in one ``jax.vmap`` over a
single compiled ``lax.scan``. One XLA program executes every scenario in
lockstep; per-scenario consensus error is reduced inside the scan so the
sweep's memory is O(K (N d + E d)) regardless of T.

Two engines:

* :func:`run_pushsum_sweep` — Theorem 1 dynamics (Alg. 1 consensus) over
  seed x drop_prob x topology-draw grids.
* :func:`run_byzantine_sweep` — Algorithm 2 learning over seed batches per
  attack. Attack *type* changes the traced program (attacks are function-
  valued), so types iterate host-side while seeds ride the vmap axis.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .attacks import Attack
from .byzantine import ByzantineConfig, ByzantineResult, make_byzantine_scan
from .graphs import EdgeList
from .pushsum import (
    init_sparse_state,
    sparse_mass_invariant,
    sparse_pushsum_step,
    sparse_ratios,
    step_edge_mask,
)
from .signals import SignalModel

__all__ = [
    "PushSumSweepResult",
    "run_pushsum_sweep",
    "run_byzantine_sweep",
]


class PushSumSweepResult(NamedTuple):
    err: jnp.ndarray          # (K, T) max-agent consensus error per round
    final_ratio: jnp.ndarray  # (K, N, d) z/m estimates at T
    mass_gap: jnp.ndarray     # (K, d) mass-invariant violation at T
    drop_prob: jnp.ndarray    # (K,) scenario coordinates
    seed: jnp.ndarray         # (K,)
    graph: jnp.ndarray        # (K,) topology-draw index

    @property
    def K(self) -> int:
        return int(self.err.shape[0])


def _scenario_grid(n_graphs: int, drop_probs, seeds):
    """Flatten the (graph x drop x seed) grid into K-long coordinate arrays."""
    drop_probs = np.atleast_1d(np.asarray(drop_probs, np.float32))
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    g, d, s = np.meshgrid(
        np.arange(n_graphs, dtype=np.int32), drop_probs, seeds, indexing="ij"
    )
    return g.ravel(), d.ravel(), s.ravel()


@functools.partial(jax.jit, static_argnames=("T", "B"))
def _sweep_compiled(w, src_b, dst_b, valid_b, drop_b, seed_b, *, T, B):
    """Module-level jit so repeated sweeps with the same shapes/statics hit
    the compilation cache instead of retracing a fresh closure per call."""
    E = src_b.shape[1]
    target = w.mean(axis=0)          # (d,) true average, shared
    w_sum = w.sum(axis=0)

    def single(src, dst, valid, drop, seed):
        key = jax.random.PRNGKey(seed)
        state0 = init_sparse_state(w, E)

        def body(state, t):
            mask = step_edge_mask(key, t, E, drop, B)
            new = sparse_pushsum_step(state, mask, src, dst, valid)
            err = jnp.abs(sparse_ratios(new) - target).max()
            return new, err

        final, errs = jax.lax.scan(
            body, state0, jnp.arange(T, dtype=jnp.uint32)
        )
        gap = sparse_mass_invariant(final, src, valid) - w_sum
        return errs, sparse_ratios(final), gap

    return jax.vmap(single)(src_b, dst_b, valid_b, drop_b, seed_b)


def run_pushsum_sweep(
    w: jnp.ndarray,            # (N, d) initial values, shared by scenarios
    el: EdgeList,              # single graph or stacked draws (leading G axis)
    T: int,
    *,
    drop_probs: Sequence[float] | float = 0.0,
    seeds: Sequence[int] | int = 0,
    B: int = 4,
) -> PushSumSweepResult:
    """Run the full scenario grid in ONE jitted, vmapped scan.

    Scenario axes: every topology draw in ``el`` (see
    :func:`graphs.stack_edge_lists`) x every drop probability x every seed —
    K = G * |drop_probs| * |seeds| scenarios total. Per-round (E,) link
    masks are drawn inside the scan; nothing of size (T, N, N) or (N, N)
    ever exists. Compilation is cached at module level: repeated sweeps
    with the same array shapes and (T, B) reuse the executable.
    """
    w = jnp.asarray(w)
    src = np.atleast_2d(el.src)      # (G, E)
    dst = np.atleast_2d(el.dst)
    valid = np.atleast_2d(el.valid)
    G, E = src.shape
    gi, dp, sd = _scenario_grid(G, drop_probs, seeds)

    drop_b = jnp.asarray(dp)
    seed_b = jnp.asarray(sd)
    errs, finals, gaps = _sweep_compiled(
        w, jnp.asarray(src[gi]), jnp.asarray(dst[gi]),
        jnp.asarray(valid[gi]), drop_b, seed_b, T=T, B=B,
    )
    return PushSumSweepResult(
        err=errs, final_ratio=finals, mass_gap=gaps,
        drop_prob=drop_b, seed=seed_b, graph=jnp.asarray(gi),
    )


def run_byzantine_sweep(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seeds: Sequence[int],
    attacks: Sequence[Attack] | None = None,
) -> dict[str, ByzantineResult]:
    """Algorithm 2 over a seed batch per attack type.

    For each attack (default: just ``cfg.attack``) the whole seed batch runs
    as one jitted ``vmap`` of the scan built by
    :func:`byzantine.make_byzantine_scan` — results carry a leading seed
    axis: ``r`` is (S, T, N, m, m), ``decisions`` (S, T, N). Attack types
    swap the traced message function, so they iterate host-side. Unlike
    :func:`run_pushsum_sweep`, each call retraces (the scan closes over
    per-config host analysis); amortize by batching all seeds of interest
    into one call rather than calling per seed.
    """
    import dataclasses

    seeds_j = jnp.asarray(np.asarray(seeds, np.uint32))
    keys = jax.vmap(jax.random.PRNGKey)(seeds_j)
    out: dict[str, ByzantineResult] = {}
    for atk in attacks if attacks is not None else [cfg.attack]:
        c = dataclasses.replace(cfg, attack=atk)
        run = make_byzantine_scan(model, c, T)
        out[atk.name] = jax.jit(jax.vmap(run))(keys)
    return out
