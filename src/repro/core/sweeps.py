"""Vmapped + mesh-sharded scenario sweeps — "as many scenarios as you can
imagine".

The sparse edge-list push-sum core (:mod:`repro.core.pushsum`) keeps per-
scenario state at O(E d), so a whole grid of scenarios — seeds x drop
probabilities x topology draws — fits comfortably in one ``jax.vmap`` over a
single compiled ``lax.scan``. One XLA program executes every scenario in
lockstep; per-scenario consensus error is reduced inside the scan so the
sweep's memory is O(K (N d + E d)) regardless of T. Pass a ``mesh`` to
:func:`run_pushsum_sweep` and the scenario axis is additionally sharded
over the mesh's ``data`` axis with ``shard_map`` (one scenario batch per
device), so grids in the thousands run as one program across the fleet.

Two engines:

* :func:`run_pushsum_sweep` — Theorem 1 dynamics (Alg. 1 consensus) over
  seed x drop_prob x topology-draw grids; ``backend`` selects the XLA or
  fused-Pallas delivery lowering per round.
* :func:`run_byzantine_sweep` — Algorithm 2 learning over seed batches per
  attack. Attack *type* changes the traced program (attacks are function-
  valued), so types iterate host-side while seeds ride the vmap axis; the
  compiled scan per (model, config, T, attack) is cached module-side so
  repeated calls with the same shapes/config never retrace.
* :func:`run_byzantine_grid` — batched (topology, F) x seed grids on the
  sparse neighbor-list core: compatible configs (same N, M, m; neighbor
  lists padded to a common deg_max) stack leaf-wise into one
  :class:`repro.core.byzantine.ByzRuntime` batch and the whole grid runs as
  ONE vmapped scan — heterogeneous F rides the scenario axis as a traced
  scalar through the sort-based trim. Pass ``mesh=`` to shard the scenario
  axis like :func:`run_pushsum_sweep`.
* :func:`run_hps_grid` / :func:`run_hps_sweep` — Algorithm 1 (hierarchical
  push-sum) over batched (topology x M x Gamma x drop) x seed grids on the
  fused HPS engine (:mod:`repro.core.hps`): compatible configs (same N;
  edge lists padded to a common E) stack leaf-wise into one
  :class:`repro.core.hps.HPSRuntime` batch, with drop_prob, Gamma, the
  B-window AND the sub-network count M riding the scenario axis as traced
  scalars — grids may mix hierarchies with different numbers of
  sub-networks in one compiled program. ``store="gap"`` (default) reduces
  each scenario's Theorem-1 consensus-error curve inside the scan.
* :func:`run_social_grid` / :func:`run_social_sweep` — Algorithm 3
  (packet-drop-tolerant non-Bayesian learning) over batched
  (topology x drop_prob x Gamma) x seed grids on the fused social engine
  (:mod:`repro.core.social`): compatible configs (same N, M; edge lists
  padded to a common E) stack leaf-wise into one
  :class:`repro.core.social.SocialRuntime` batch, with drop_prob, the
  fusion period Gamma, and the B-window riding the scenario axis as traced
  scalars — the whole grid is ONE traced program, jitted once per
  (mesh, statics) combo regardless of model or topology identity. Pass
  ``mesh=`` to shard the scenario axis like the other engines.

Compiled-executable caches are LRU-bounded (:class:`_LRUCache`): long
parameter studies cycle through many config fingerprints, and an unbounded
dict would pin every retired executable for the process lifetime.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .attacks import Attack
from .asyncrony import (
    AsyncModel,
    init_async_buffer,
    is_degenerate_async,
    wake_mask,
)
from .faults import (
    ENGINE_PUSHSUM,
    FaultModel,
    edge_uniforms,
    faulty_edge_mask,
    init_fault_state,
    step_faults,
)
from .byzantine import (
    ByzantineConfig,
    ByzantineResult,
    ByzRuntime,
    _scan_core,
    _sparse_gossip,
    make_byzantine_runtime,
    make_byzantine_scan,
)
from .graphs import EdgeList, EdgeShards, partition_edge_list
from .plan import ExecutionPlan, resolve_plan
from .precision import Policy, resolve_policy
from .pushsum import (
    _out_degree,
    init_sparse_state,
    shard_edge_mask,
    sparse_mass_invariant,
    sparse_pushsum_step,
    sparse_ratios,
    step_edge_mask,
)
from .hps import (
    HPS_STORES,
    HPSConfig,
    HPSRuntime,
    _hps_scan_core,
    make_hps_runtime,
)
from .signals import SignalModel
from .social import SOCIAL_STORES, SocialRuntime, _social_scan_core, make_social_runtime
from repro.statics.contracts import contract as statics_contract
from repro.statics.retrace import register_cache as register_statics_cache

__all__ = [
    "PushSumSweepResult",
    "ByzantineGridResult",
    "HPSSweepResult",
    "SocialSweepResult",
    "CacheHandle",
    "CacheInfo",
    "cache_registry",
    "run_pushsum_sweep",
    "run_byzantine_sweep",
    "run_byzantine_grid",
    "run_hps_sweep",
    "run_hps_grid",
    "run_social_sweep",
    "run_social_grid",
]


class _LRUCache(OrderedDict):
    """Bounded mapping with least-recently-used eviction.

    Used for the compiled-scan caches below: entries are jit wrappers plus
    their executables, keyed on config fingerprints. Reads refresh recency;
    inserting beyond ``maxsize`` drops the stalest entry, so a long
    parameter study holds at most ``maxsize`` executables at a time.
    """

    def __init__(self, maxsize: int):
        super().__init__()
        self.maxsize = maxsize

    def __getitem__(self, key):
        val = super().__getitem__(key)
        self.move_to_end(key)
        return val

    def get(self, key, default=None):
        if key in self:
            return self[key]
        return default

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            # not popitem(): its C path re-enters the recency-tracking
            # __getitem__ on the half-unlinked entry
            del self[next(iter(self))]


# ---------------------------------------------------------------------------
# The unified index-column convention of every *SweepResult / *GridResult:
# each result row is one scenario on the flattened leading K axis, and the
# flattening order is FIXED across all four engines —
#
#     scenario coordinates (graph/cfg major, then drop, gamma, ..., seed)
#       -> fault axis (minor of every scenario coordinate)
#         -> async axis (minor-most)
#
# so e.g. with NF fault models and NA async models, row
# k = ((s * NF) + f) * NA + a. Every index column is a (K,) array; an
# ABSENT axis is ``None`` (not a column of zeros), and ``describe()`` —
# shared by all four result types — names each axis, its level count, and
# its position in the order. (Pre-PR-10, ``fault`` was a column on three
# results and missing from ByzantineGridResult entirely.)
# ---------------------------------------------------------------------------

#: Index-column order of the shared ``describe()``: scenario coordinates
#: first (engine-specific), then ``fault``, then ``async_`` (minor-most).
_AXIS_ORDER = ("graph", "cfg", "drop_prob", "gamma", "M", "F", "seed",
               "fault", "async_")

#: Fields of the result tuples that are payload, not index columns.
_PAYLOAD_FIELDS = frozenset({
    "err", "final_ratio", "mass_gap", "beliefs", "log_ratio", "ratio",
    "gap", "r", "decisions",
})


def _describe_result(res) -> str:
    """Shared ``describe()``: one line per index column in the fixed
    scenario -> fault -> async order, naming levels and payload shapes."""
    lines = [
        f"{type(res).__name__}: K={res.K} scenarios "
        "(row order: scenario coords -> fault -> async_, async minor-most)"
    ]
    for name in _AXIS_ORDER:
        if name not in getattr(res, "_fields", ()):
            continue
        v = getattr(res, name)
        if v is None:
            lines.append(f"  {name:<9} absent (no axis)")
            continue
        arr = np.asarray(v)
        uniq = np.unique(arr)
        preview = ", ".join(str(x) for x in uniq[:6])
        if uniq.size > 6:
            preview += ", ..."
        lines.append(f"  {name:<9} {uniq.size} level(s): [{preview}]")
    payload = [f"{n}{tuple(np.asarray(getattr(res, n)).shape)}"
               for n in res._fields
               if n in _PAYLOAD_FIELDS and getattr(res, n) is not None]
    lines.append("  payload: " + ", ".join(payload))
    return "\n".join(lines)


class PushSumSweepResult(NamedTuple):
    err: jnp.ndarray          # (K, T) max-agent consensus error per round
    final_ratio: jnp.ndarray  # (K, N, d) z/m estimates at T
    mass_gap: jnp.ndarray     # (K, d) mass-invariant violation at T
    drop_prob: jnp.ndarray    # (K,) scenario coordinates
    seed: jnp.ndarray         # (K,)
    graph: jnp.ndarray        # (K,) topology-draw index
    fault: jnp.ndarray | None = None  # (K,) fault-model index, None = no axis
    async_: jnp.ndarray | None = None  # (K,) async-model index, minor-most

    @property
    def K(self) -> int:
        return int(self.err.shape[0])

    def describe(self) -> str:
        return _describe_result(self)


def _scenario_grid(n_graphs: int, drop_probs, seeds):
    """Flatten the (graph x drop x seed) grid into K-long coordinate arrays."""
    drop_probs = np.atleast_1d(np.asarray(drop_probs, np.float32))
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    g, d, s = np.meshgrid(
        np.arange(n_graphs, dtype=np.int32), drop_probs, seeds, indexing="ij"
    )
    return g.ravel(), d.ravel(), s.ravel()


def _expand_fault_axis(coords, faults):
    """Cross a fault-model list into flattened scenario coordinates.

    ``coords`` is a tuple of (K,) arrays; returns ``(coords, fi, stacked)``
    where ``fi`` is the (K * NF,) fault-index coordinate (fault minor, so
    existing scenario ordering is preserved) and ``stacked`` the
    leaf-stacked FaultModel batch with (NF,) leaves — or
    ``(coords, None, None)`` when ``faults`` is None (no fault axis, and
    downstream emits the bit-identical pre-fault program)."""
    if faults is None:
        return coords, None, None
    fl = [faults] if isinstance(faults, FaultModel) else list(faults)
    if not fl:
        raise ValueError("faults= needs at least one FaultModel")
    nf = len(fl)
    k = coords[0].shape[0]
    coords = tuple(np.repeat(c, nf) for c in coords)
    fi = np.tile(np.arange(nf, dtype=np.int32), k)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *fl)
    return coords, fi, stacked


def _expand_async_axis(coords, async_):
    """Cross an async-model list into flattened scenario coordinates.

    Mirror of :func:`_expand_fault_axis` for the
    :class:`repro.core.asyncrony.AsyncModel` axis. Applied AFTER the fault
    expansion (pass ``fi`` inside ``coords``), so the async index is
    minor-most in the unified row order — see the index-column convention
    above. Returns ``(coords, ai, stacked)`` or ``(coords, None, None)``
    when ``async_`` is None (no axis, synchronous program)."""
    if async_ is None:
        return coords, None, None
    al = [async_] if isinstance(async_, AsyncModel) else list(async_)
    if not al:
        raise ValueError("async_= needs at least one AsyncModel")
    na = len(al)
    k = coords[0].shape[0]
    coords = tuple(np.repeat(c, na) for c in coords)
    ai = np.tile(np.arange(na, dtype=np.int32), k)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *al)
    return coords, ai, stacked


def _sweep_body(w, src_b, dst_b, valid_b, drop_b, seed_b, fault_b=None,
                async_b=None, *,
                T, B, backend, policy=None, dst_sorted=False):
    """Vmapped scenario batch: the shared traced program of both the
    single-device and the shard_map-per-device sweep paths.

    ``fault_b`` is an optional batched :class:`repro.core.faults.FaultModel`
    (leaves (K,)) riding the scenario axis — fault severity is traced per
    scenario, same executable for the whole fault grid. ``async_b`` the
    optional batched :class:`repro.core.asyncrony.AsyncModel` (leaves (K,))
    for the event-driven mode, riding the same axis. ``None`` for both
    emits the bit-identical pre-fault/synchronous program."""
    E = src_b.shape[1]
    n = w.shape[0]
    target = w.mean(axis=0)          # (d,) true average, shared
    w_sum = w.sum(axis=0)

    def single(src, dst, valid, drop, seed, fault=None, am=None):
        key = jax.random.PRNGKey(seed)
        state0 = init_sparse_state(w, E, policy=policy)

        if fault is None and am is None:
            def body(state, t):
                mask = step_edge_mask(key, t, E, drop, B)
                new = sparse_pushsum_step(
                    state, mask, src, dst, valid, backend,
                    dst_sorted=dst_sorted, policy=policy,
                )
                err = jnp.abs(sparse_ratios(new) - target).max()
                return new, err

            final, errs = jax.lax.scan(
                body, state0, jnp.arange(T, dtype=jnp.uint32)
            )
        else:
            def body(carry, t):
                # carry: (state,) [+ abuf if async] [+ fault_state last]
                state = carry[0]
                fs = None
                if fault is not None:
                    fs = step_faults(key, t, fault, carry[-1],
                                     engine=ENGINE_PUSHSUM)
                    u = jax.random.uniform(jax.random.fold_in(key, t), (E,))
                    mask = faulty_edge_mask(u, t, fault, fs, src, dst,
                                            drop, B)
                else:
                    mask = step_edge_mask(key, t, E, drop, B)
                if am is not None:
                    awake = wake_mask(key, t, n, am.wake_prob,
                                      engine=ENGINE_PUSHSUM)
                    new, abuf = sparse_pushsum_step(
                        state, mask, src, dst, valid, backend,
                        dst_sorted=dst_sorted, policy=policy, faults=fs,
                        awake=awake, abuf=carry[1], staleness=am.staleness,
                    )
                else:
                    abuf = None
                    new = sparse_pushsum_step(
                        state, mask, src, dst, valid, backend,
                        dst_sorted=dst_sorted, policy=policy, faults=fs,
                    )
                err = jnp.abs(sparse_ratios(new) - target).max()
                out = (new,)
                if am is not None:
                    out = out + (abuf,)
                if fault is not None:
                    out = out + (fs,)
                return out, err

            carry0 = (state0,)
            if am is not None:
                carry0 = carry0 + (
                    init_async_buffer(E, w.shape[1], state0.z.dtype),)
            if fault is not None:
                carry0 = carry0 + (init_fault_state(n, E),)
            (final, *_), errs = jax.lax.scan(
                body, carry0, jnp.arange(T, dtype=jnp.uint32)
            )
        gap = sparse_mass_invariant(final, src, valid) - w_sum
        return errs, sparse_ratios(final), gap

    if fault_b is None and async_b is None:
        return jax.vmap(single)(src_b, dst_b, valid_b, drop_b, seed_b)
    if async_b is None:
        return jax.vmap(single)(src_b, dst_b, valid_b, drop_b, seed_b,
                                fault_b)
    if fault_b is None:
        return jax.vmap(
            lambda s, d, v, dr, sd, am: single(s, d, v, dr, sd, None, am)
        )(src_b, dst_b, valid_b, drop_b, seed_b, async_b)
    return jax.vmap(single)(src_b, dst_b, valid_b, drop_b, seed_b, fault_b,
                            async_b)


# Module-level jit so repeated sweeps with the same shapes/statics hit the
# compilation cache instead of retracing a fresh closure per call.
_sweep_compiled = functools.partial(
    jax.jit, static_argnames=("T", "B", "backend", "policy", "dst_sorted")
)(_sweep_body)


@functools.lru_cache(maxsize=None)
def _sweep_sharded(mesh: Mesh, data_axis: str, T: int, B: int, backend: str,
                   policy: Policy | None = None, dst_sorted: bool = False,
                   has_faults: bool = False, has_async: bool = False):
    """Jitted shard_map sweep for one (mesh, axis, statics) combo: the
    scenario axis of every batched argument is split over ``data_axis``,
    one contiguous scenario block per device, and each device runs the
    identical vmapped scan on its block. lru_cache keeps one compiled
    executable per combo (Mesh is hashable), mirroring ``_sweep_compiled``'s
    retrace-free behaviour. ``has_faults``/``has_async`` add the batched
    FaultModel / AsyncModel arguments (sharded over ``data_axis`` like
    every scenario coordinate)."""
    from repro.launch import compat

    base = functools.partial(_sweep_body, T=T, B=B, backend=backend,
                             policy=policy, dst_sorted=dst_sorted)
    if has_async and not has_faults:
        # shard_map passes positionally; skip the absent fault_b slot
        def body(w, src, dst, valid, drop, seed, async_b):
            return base(w, src, dst, valid, drop, seed, None, async_b)
    else:
        body = base
    in_specs = (P(), P(data_axis), P(data_axis), P(data_axis),
                P(data_axis), P(data_axis))
    if has_faults:
        in_specs += (FaultModel(
            *([P(data_axis)] * len(FaultModel._fields))),)
    if has_async:
        in_specs += (AsyncModel(
            *([P(data_axis)] * len(AsyncModel._fields))),)
    sharded = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(data_axis), P(data_axis), P(data_axis)),
        axis_names=frozenset({data_axis}),
        check_vma=False,
    )
    return jax.jit(sharded)


@statics_contract(
    name="pushsum_sharded",
    # Per-device law of the edge-partitioned mode: nothing dense-N^2, and
    # no rank>=2 value over the GLOBAL padded edge axis may exist on a
    # device — per-shard (E_shard, d) state is the budget; gathering the
    # full (E_pad, d) rho back onto one device defeats the partitioning.
    # (The rank-1 (E_pad,) Bernoulli draw of shard_edge_mask is exempt by
    # construction: the anchored patterns below are all rank >= 2.)
    forbidden={"*": (("N", "N"), ("E", "*"))},
    streams=(("link", lambda t: t),),
    caches=("pushsum.sweep2d-jit",),
)
def _sweep_edge_sharded_body(w, src_sh, dst_sh, valid_sh, drop_b, seed_b,
                             fault_b=None, *,
                             T, B, backend, graph_axis, n_shards,
                             policy=None, halo="psum"):
    """Per-device scenario batch of the edge-partitioned (2-D mesh) sweep.

    Runs under ``shard_map`` over (``data_axis``, ``graph_axis``) — or under
    a ``jax.vmap(axis_name=graph_axis)`` emulation on one device — with
    ``w`` replicated, the edge arrays carrying this device's
    (Kb, 1, E_shard) slice of a :func:`graphs.partition_edge_list` layout,
    and the scenario coordinates (Kb,) sharded over data only. Node state
    is replicated over the graph axis; each round's receiver partials (and
    the hoisted out-degree / final mass invariant) are combined with psum
    inside :func:`sparse_pushsum_step`, so every graph-shard device holds
    identical node state and the outputs are graph-replicated.
    """
    e_shard = src_sh.shape[-1]
    # (Kb, 1, Es) under shard_map, (Kb, Es) under the vmap emulation
    src_sh = src_sh.reshape(src_sh.shape[0], e_shard)
    dst_sh = dst_sh.reshape(dst_sh.shape[0], e_shard)
    valid_sh = valid_sh.reshape(valid_sh.shape[0], e_shard)
    target = w.mean(axis=0)
    w_sum = w.sum(axis=0)
    n = w.shape[0]

    def single(src, dst, valid, drop, seed, fault=None):
        key = jax.random.PRNGKey(seed)
        state0 = init_sparse_state(w, e_shard, policy=policy)
        # loop invariant: global out-degree = psum of shard-local counts
        d_out = jax.lax.psum(
            _out_degree(src, valid, n, w.dtype), graph_axis
        )
        share = 1.0 / (d_out + 1.0)

        if fault is None:
            def body(state, t):
                mask = shard_edge_mask(
                    key, t, e_shard, drop, B,
                    graph_axis=graph_axis, n_shards=n_shards,
                )
                new = sparse_pushsum_step(
                    state, mask, src, dst, valid, backend,
                    share=share, graph_axis=graph_axis, dst_sorted=True,
                    policy=policy, halo=halo, n_shards=n_shards,
                )
                err = jnp.abs(sparse_ratios(new) - target).max()
                return new, err

            final, errs = jax.lax.scan(
                body, state0, jnp.arange(T, dtype=jnp.uint32)
            )
        else:
            def body(carry, t):
                # fault + drop draws window the full-graph vector exactly
                # like shard_edge_mask, so realizations are identical at
                # every shard count
                state, fs = carry
                fs = step_faults(key, t, fault, fs, engine=ENGINE_PUSHSUM,
                                 graph_axis=graph_axis, n_shards=n_shards)
                u = edge_uniforms(key, t, e_shard,
                                  graph_axis=graph_axis, n_shards=n_shards)
                mask = faulty_edge_mask(u, t, fault, fs, src, dst, drop, B)
                new = sparse_pushsum_step(
                    state, mask, src, dst, valid, backend,
                    share=share, graph_axis=graph_axis, dst_sorted=True,
                    policy=policy, halo=halo, n_shards=n_shards,
                    faults=fs,
                )
                err = jnp.abs(sparse_ratios(new) - target).max()
                return (new, fs), err

            (final, _), errs = jax.lax.scan(
                body, (state0, init_fault_state(n, e_shard)),
                jnp.arange(T, dtype=jnp.uint32)
            )
        gap = sparse_mass_invariant(
            final, src, valid, graph_axis=graph_axis
        ) - w_sum
        return errs, sparse_ratios(final), gap

    if fault_b is None:
        return jax.vmap(single, in_axes=(0, 0, 0, 0, 0))(
            src_sh, dst_sh, valid_sh, drop_b, seed_b
        )
    return jax.vmap(single, in_axes=(0, 0, 0, 0, 0, 0))(
        src_sh, dst_sh, valid_sh, drop_b, seed_b, fault_b
    )


def _sweep2d_emulated(w, src_k, dst_k, valid_k, drop_b, seed_b,
                      fault_b=None, *,
                      T, B, backend, graph_axis, n_shards,
                      policy=None, halo="psum"):
    """Single-device oracle of the 2-D mesh program: ``vmap(axis_name=)``
    over the shard axis of the same per-device body, so every collective
    resolves identically. The psum of S operands lowers to the same
    reduction either way, making this path the bit-identity reference the
    mesh path is tested against (and the traceable the statics fixture
    lints). Outputs are shard-replicated; the leading S axis is dropped."""
    errs, finals, gaps = jax.vmap(
        functools.partial(
            _sweep_edge_sharded_body,
            T=T, B=B, backend=backend,
            graph_axis=graph_axis, n_shards=n_shards,
            policy=policy, halo=halo,
        ),
        in_axes=(None, 1, 1, 1, None, None, None),
        out_axes=0,
        axis_name=graph_axis,
    )(w, src_k, dst_k, valid_k, drop_b, seed_b, fault_b)
    return errs[0], finals[0], gaps[0]


_sweep2d_compiled = functools.partial(
    jax.jit,
    static_argnames=("T", "B", "backend", "graph_axis", "n_shards",
                     "policy", "halo"),
)(_sweep2d_emulated)


@functools.lru_cache(maxsize=None)
def _sweep_sharded_2d(mesh: Mesh, data_axis: str, graph_axis: str,
                      T: int, B: int, backend: str,
                      policy: Policy | None = None, halo: str = "psum",
                      has_faults: bool = False):
    """Jitted 2-D (data x graph) shard_map sweep: scenarios split over
    ``data_axis`` exactly as in :func:`_sweep_sharded`, while the edge
    arrays' shard axis splits over ``graph_axis`` — one edge shard per
    graph-device, combined per round by the psum inside the body. Outputs
    are graph-replicated, so their specs name only the data axis."""
    from repro.distributed.sharding import sweep_specs
    from repro.launch import compat

    specs = sweep_specs(data_axis, graph_axis)
    n_shards = int(mesh.shape[graph_axis])
    body = functools.partial(
        _sweep_edge_sharded_body, T=T, B=B, backend=backend,
        graph_axis=graph_axis, n_shards=n_shards,
        policy=policy, halo=halo,
    )
    in_specs = (specs["replicated"], specs["edge_shards"],
                specs["edge_shards"], specs["edge_shards"],
                specs["scenario"], specs["scenario"])
    if has_faults:
        in_specs += (FaultModel(
            *([specs["scenario"]] * len(FaultModel._fields))),)
    sharded = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs["out"], specs["out"], specs["out"]),
        axis_names=frozenset({data_axis, graph_axis}),
        check_vma=False,
    )
    return jax.jit(sharded)


def run_pushsum_sweep(
    w: jnp.ndarray,            # (N, d) initial values, shared by scenarios
    el: EdgeList,              # single graph or stacked draws (leading G axis)
    T: int,
    *,
    drop_probs: Sequence[float] | float = 0.0,
    seeds: Sequence[int] | int = 0,
    B: int = 4,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> PushSumSweepResult:
    """Run the full scenario grid in ONE jitted, vmapped scan.

    Scenario axes: every topology draw in ``el`` (see
    :func:`graphs.stack_edge_lists`) x every drop probability x every seed —
    K = G * |drop_probs| * |seeds| scenarios total. Per-round (E,) link
    masks are drawn inside the scan; nothing of size (T, N, N) or (N, N)
    ever exists. Compilation is cached at module level: repeated sweeps
    with the same array shapes and statics reuse the executable.

    ``backend`` selects the per-round delivery lowering
    (:mod:`repro.kernels.pushsum_edge`; ``"pallas"`` expects dst-sorted
    edges). With ``mesh`` given, the K scenario axis is sharded over
    ``mesh``'s ``data_axis`` via ``shard_map`` — K is padded by repeating
    the last scenario up to a multiple of the axis size (one scenario batch
    per device; the pad rows are sliced off the result), so grids in the
    thousands still run as a single program.

    **Edge-partitioned mode** (``graph_shards=S``): the graph itself is
    additionally split into S dst-contiguous edge shards
    (:func:`graphs.partition_edge_list` — ``el`` may be an
    :class:`graphs.EdgeShards` already), per-edge state drops to
    O(E/S d) per device, and per-round receiver partials are psum'd over
    the mesh ``graph_axis`` — the 2-D (scenarios x graph) program that
    takes single scenarios past N ~ 1e5. With ``mesh`` given its
    ``graph_axis`` extent must equal S; without a mesh the shard axis runs
    as a single-device ``vmap(axis_name=)`` emulation — the bit-identity
    oracle of the mesh path. Either way results are bit-identical to the
    plain path on ``EdgeShards.padded_edge_list()`` up to boundary-node
    reduce order (see :class:`graphs.EdgeShards`); when ``S * e_shard``
    exceeds E the padded mask draw re-indexes edge slots, so compare
    against the padded list, not the original (threefry bits have no
    prefix property).

    ``policy`` selects the precision policy
    (:mod:`repro.core.precision`; name, :class:`Policy`, or ``None`` for
    the dtype-transparent fp32 default — bit-identical to the pre-policy
    sweeps). ``dst_sorted`` asserts the edge lists are dst-sorted so the
    delivery segment-sums skip the scatter sort (the edge-partitioned
    mode always sorts per shard and ignores this flag). ``halo`` picks
    the graph-axis combine of the edge-partitioned mode:
    ``"psum"`` (default, bit-identical to the single-device oracle) or
    ``"scatter"``, the psum_scatter/all_gather form whose gather leg
    moves storage-width bytes (see
    :func:`repro.analysis.roofline.pushsum_halo_wire_bytes`).

    ``faults`` (one :class:`repro.core.faults.FaultModel` or a sequence,
    e.g. a burst-length ladder from
    :func:`repro.core.faults.gilbert_elliott_model`) adds a FOURTH swept
    scenario axis, fault-minor: every (graph, drop, seed) cell runs once
    per model, severity traced per scenario — one executable for the
    whole fault grid. The result's ``fault`` field indexes into the
    sequence; ``faults=None`` (default) keeps the pre-fault program
    bit-identical and ``fault=None`` in the result.

    ``plan.async_`` (one :class:`repro.core.asyncrony.AsyncModel` or a
    sequence) crosses a FIFTH axis, async minor-most: every cell runs
    once per (wake-rate, staleness) model through the event-driven mode,
    indexed by the result's ``async_`` column. A single concretely
    degenerate model dispatches to the synchronous program (no axis,
    ``async_=None`` in the result — bit-identity by construction).
    Incompatible with the edge-partitioned mode (``graph_shards``). All
    execution knobs arrive via ``plan=`` (loose kwargs are deprecated
    shims; see :mod:`repro.core.plan`).
    """
    plan = resolve_plan(
        plan, _entry="run_pushsum_sweep",
        _supports=("backend", "mesh", "data_axis", "graph_axis",
                   "graph_shards", "policy", "dst_sorted", "halo",
                   "faults", "async_"),
        **legacy)
    backend, mesh, data_axis = plan.backend, plan.mesh, plan.data_axis
    graph_axis, graph_shards = plan.graph_axis, plan.graph_shards
    policy, dst_sorted, halo = plan.policy, plan.dst_sorted, plan.halo
    faults = plan.faults
    async_ = plan.async_
    if isinstance(async_, AsyncModel) and is_degenerate_async(async_):
        async_ = None
    w = jnp.asarray(w)
    pol = None if policy is None else resolve_policy(policy)
    if async_ is not None and (graph_shards is not None
                               or isinstance(el, EdgeShards)):
        raise ValueError(
            "async_ is incompatible with the edge-partitioned mode "
            "(graph_shards): the per-edge stale buffer is not partitioned"
        )
    if graph_shards is not None or isinstance(el, EdgeShards):
        shards = (el if isinstance(el, EdgeShards)
                  else partition_edge_list(el, graph_shards))
        if graph_shards is not None and shards.n_shards != graph_shards:
            raise ValueError(
                f"EdgeShards has {shards.n_shards} shards, "
                f"graph_shards={graph_shards}"
            )
        S = shards.n_shards
        src = shards.src if shards.is_batched else shards.src[None]
        dst = shards.dst if shards.is_batched else shards.dst[None]
        valid = shards.valid if shards.is_batched else shards.valid[None]
        G = src.shape[0]                     # (G, S, Es)
        gi, dp, sd = _scenario_grid(G, drop_probs, seeds)
        (gi, dp, sd), fi, fstack = _expand_fault_axis((gi, dp, sd), faults)
        K = gi.shape[0]
        if mesh is not None:
            if int(mesh.shape[graph_axis]) != S:
                raise ValueError(
                    f"mesh {graph_axis} axis has {mesh.shape[graph_axis]} "
                    f"devices but the edge list is cut into {S} shards"
                )
            pad = (-K) % int(mesh.shape[data_axis])
            if pad:
                fill = np.full(pad, K - 1)
                gi = np.concatenate([gi, gi[fill]])
                dp = np.concatenate([dp, dp[fill]])
                sd = np.concatenate([sd, sd[fill]])
                if fi is not None:
                    fi = np.concatenate([fi, fi[fill]])
        drop_b = jnp.asarray(dp)
        seed_b = jnp.asarray(sd)
        args = (w, jnp.asarray(src[gi]), jnp.asarray(dst[gi]),
                jnp.asarray(valid[gi]), drop_b, seed_b)
        if fi is not None:
            args += (jax.tree_util.tree_map(
                lambda x: x[jnp.asarray(fi)], fstack),)
        if mesh is None:
            errs, finals, gaps = _sweep2d_compiled(
                *args, T=T, B=B, backend=backend,
                graph_axis=graph_axis, n_shards=S,
                policy=pol, halo=halo,
            )
        else:
            errs, finals, gaps = _sweep_sharded_2d(
                mesh, data_axis, graph_axis, T, B, backend, pol, halo,
                fi is not None,
            )(*args)
        return PushSumSweepResult(
            err=errs[:K], final_ratio=finals[:K], mass_gap=gaps[:K],
            drop_prob=drop_b[:K], seed=seed_b[:K], graph=jnp.asarray(gi[:K]),
            fault=None if fi is None else jnp.asarray(fi[:K]),
        )

    src = np.atleast_2d(el.src)      # (G, E)
    dst = np.atleast_2d(el.dst)
    valid = np.atleast_2d(el.valid)
    G, E = src.shape
    gi, dp, sd = _scenario_grid(G, drop_probs, seeds)
    (gi, dp, sd), fi, fstack = _expand_fault_axis((gi, dp, sd), faults)
    if fi is None:
        (gi, dp, sd), ai, astack = _expand_async_axis((gi, dp, sd), async_)
    else:
        (gi, dp, sd, fi), ai, astack = _expand_async_axis(
            (gi, dp, sd, fi), async_)
    K = gi.shape[0]

    if mesh is None:
        pad = 0
    else:
        n_dev = int(mesh.shape[data_axis])
        pad = (-K) % n_dev
        if pad:                       # repeat the last scenario to fill
            fill = np.full(pad, K - 1)
            gi = np.concatenate([gi, gi[fill]])
            dp = np.concatenate([dp, dp[fill]])
            sd = np.concatenate([sd, sd[fill]])
            if fi is not None:
                fi = np.concatenate([fi, fi[fill]])
            if ai is not None:
                ai = np.concatenate([ai, ai[fill]])

    drop_b = jnp.asarray(dp)
    seed_b = jnp.asarray(sd)
    args = (w, jnp.asarray(src[gi]), jnp.asarray(dst[gi]),
            jnp.asarray(valid[gi]), drop_b, seed_b)
    if fi is not None or ai is not None:
        args += (None if fi is None else jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(fi)], fstack),)
    if ai is not None:
        args += (jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(ai)], astack),)
    if mesh is None:
        errs, finals, gaps = _sweep_compiled(
            *args, T=T, B=B, backend=backend,
            policy=pol, dst_sorted=dst_sorted,
        )
    else:
        shard_args = args if fi is not None or ai is None else (
            args[:6] + args[7:])     # drop the None fault_b placeholder
        errs, finals, gaps = _sweep_sharded(
            mesh, data_axis, T, B, backend, pol, dst_sorted,
            fi is not None, ai is not None,
        )(*shard_args)
    return PushSumSweepResult(
        err=errs[:K], final_ratio=finals[:K], mass_gap=gaps[:K],
        drop_prob=drop_b[:K], seed=seed_b[:K], graph=jnp.asarray(gi[:K]),
        fault=None if fi is None else jnp.asarray(fi[:K]),
        async_=None if ai is None else jnp.asarray(ai[:K]),
    )


# Compiled Algorithm-2 sweeps, one jitted vmapped scan per
# (model, topology, F, byz set, Gamma, attack, T, mode/core/backend/store)
# combo. The scan closure returned by make_byzantine_scan is a fresh Python
# object per call, so wrapping it in jax.jit anew would retrace every time
# even though the traced program is identical; keying the *jitted callable*
# on the config fingerprint gives run_byzantine_sweep the same retrace-free
# repeated-call behaviour as _sweep_compiled. The cache is LRU-bounded so
# parameter studies cycling through many fingerprints do not accumulate
# executables without limit.
_BYZ_COMPILED = _LRUCache(maxsize=32)
_BYZ_GRID_COMPILED = _LRUCache(maxsize=8)


def _fault_fingerprint(faults: FaultModel | None):
    """Value fingerprint of a FaultModel for compiled-program cache keys.

    The fault scalars are baked into the closure the byzantine caches jit
    (unlike the grid engines, which trace a batched FaultModel argument),
    so the key must name the VALUES — a has-faults flag alone would
    silently reuse an executable compiled for different severities."""
    if faults is None:
        return None
    return tuple(float(np.asarray(x)) for x in faults)


def _byz_sweep_key(
    model: SignalModel, cfg: ByzantineConfig, T: int,
    mode: str = "pairwise", core: str = "sparse", backend: str = "auto",
    store: str = "trajectory", policy: Policy | None = None,
    faults: FaultModel | None = None,
) -> tuple:
    topo = cfg.topo
    return (
        np.asarray(model.tables).tobytes(), model.truth,
        topo.adj.tobytes(), topo.sizes, topo.offsets, topo.reps,
        cfg.F, cfg.byz, cfg.gamma_period, cfg.attack, T,
        mode, core, backend, store, policy, _fault_fingerprint(faults),
    )


def run_byzantine_sweep(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seeds: Sequence[int],
    attacks: Sequence[Attack] | None = None,
    *,
    mode: str = "pairwise",
    core: str = "sparse",
    plan: ExecutionPlan | None = None,
    **legacy,
) -> dict[str, ByzantineResult]:
    """Algorithm 2 over a seed batch per attack type.

    For each attack (default: just ``cfg.attack``) the whole seed batch runs
    as one jitted ``vmap`` of the scan built by
    :func:`byzantine.make_byzantine_scan` — results carry a leading seed
    axis: with ``store="trajectory"`` ``r`` is (S, T, N, m, m) and
    ``decisions`` (S, T, N). Seed batches over long horizons should pass
    ``store="decisions"`` (decision curves reduced in-scan, final r only) or
    ``store="final"`` so the batch never carries the (S, T, N, m, m)
    trajectory out of the scan. Attack types swap the traced message
    function, so they iterate host-side. ``core``/``backend`` select the
    gossip lowering (:func:`make_byzantine_scan`).

    Repeated calls with the same (model, config, T, attack) and seed-batch
    shape neither retrace nor re-run the host-side healthy-network
    analysis: the C-set lattice is memoized in :mod:`repro.core.byzantine`
    and the jitted scan is reused from ``_BYZ_COMPILED`` (``Attack`` is a
    frozen dataclass, so the same attack object keys the same entry).

    ``plan.faults`` layers one :class:`repro.core.faults.FaultModel` over
    every seed in the batch (the unified fault plane of
    :func:`byzantine.make_byzantine_scan`); the compiled cache keys on the
    fault VALUES, so sweeping severities host-side stays correct.
    Execution knobs arrive via ``plan=`` (loose
    ``backend=``/``store=``/``policy=``/``faults=`` kwargs are deprecated
    shims); ``mode``/``core`` are algorithm variants, not execution knobs,
    so they stay named. The Byzantine engine does NOT support the async
    mode — its adversarial-message semantics assume synchronized rounds —
    so a plan carrying ``async_`` raises ``ValueError``.
    """
    plan = resolve_plan(
        plan, _entry="run_byzantine_sweep",
        _supports=("backend", "store", "policy", "faults"),
        **legacy)
    backend, policy, faults = plan.backend, plan.policy, plan.faults
    store = "trajectory" if plan.store is None else plan.store
    pol = None if policy is None else resolve_policy(policy)
    seeds_j = jnp.asarray(np.asarray(seeds, np.uint32))
    keys = jax.vmap(jax.random.PRNGKey)(seeds_j)
    out: dict[str, ByzantineResult] = {}
    for atk in attacks if attacks is not None else [cfg.attack]:
        c = dataclasses.replace(cfg, attack=atk)
        cache_key = _byz_sweep_key(model, c, T, mode, core, backend, store,
                                   pol, faults)
        fn = _BYZ_COMPILED.get(cache_key)
        if fn is None:
            run = make_byzantine_scan(
                model, c, T, mode=mode, core=core, backend=backend,
                store=store, policy=pol, faults=faults,
            )
            fn = _BYZ_COMPILED[cache_key] = jax.jit(jax.vmap(run))
        out[atk.name] = fn(keys)
    return out


class ByzantineGridResult(NamedTuple):
    """One row per scenario (config x seed), leading axis K.

    ``r``/``decisions`` follow the ``store`` shapes of
    :class:`repro.core.byzantine.ByzantineResult` with the extra leading K;
    ``cfg`` indexes into the ``cfgs`` list passed to
    :func:`run_byzantine_grid`, ``F``/``seed`` are the per-scenario
    coordinates. ``fault``/``async_`` follow the unified index-column
    convention above: the grid applies ONE fault model to every scenario
    (so ``fault`` is the all-zeros index when faults are on, ``None``
    otherwise — pre-PR-10 this result had no fault field at all), and the
    Byzantine engine has no async mode, so ``async_`` is always ``None``.
    """

    r: jnp.ndarray
    decisions: jnp.ndarray
    cfg: jnp.ndarray       # (K,) config index
    F: jnp.ndarray         # (K,) trim count of that config
    seed: jnp.ndarray      # (K,)
    fault: jnp.ndarray | None = None  # (K,) fault index, None = no faults
    async_: jnp.ndarray | None = None  # always None (no async mode)

    @property
    def K(self) -> int:
        return int(self.decisions.shape[0])

    def describe(self) -> str:
        return _describe_result(self)


def _cfgs_fingerprint(model, cfgs, atk) -> tuple:
    parts = [np.asarray(model.tables).tobytes(), model.truth, atk]
    for c in cfgs:
        topo = c.topo
        parts.append((
            topo.adj.tobytes(), topo.sizes, topo.offsets, topo.reps,
            c.F, c.byz, c.gamma_period,
        ))
    return tuple(parts)


def _byz_grid_key(model, cfgs, T, atk, mode, backend, store,
                  mesh, data_axis, policy=None, faults=None) -> tuple:
    """``backend`` must be the *effective* lowering (post ``resolve_backend``
    and the dynamic-F downgrade), so the key names the traced program."""
    return _cfgs_fingerprint(model, cfgs, atk) + (
        T, mode, backend, store, mesh, data_axis, policy,
        _fault_fingerprint(faults),
    )


# Stacked ByzRuntime batches keyed on the (model, configs, attack)
# fingerprint: repeated grid calls (e.g. host-side attack/T loops over one
# config set) skip the per-config analysis, neighbor-list construction, and
# device uploads entirely.
_BYZ_RUNTIME_CACHE = _LRUCache(maxsize=16)


def run_byzantine_grid(
    model: SignalModel,
    cfgs: Sequence[ByzantineConfig],
    T: int,
    seeds: Sequence[int] | int,
    *,
    attack: Attack | None = None,
    mode: str = "pairwise",
    plan: ExecutionPlan | None = None,
    **legacy,
) -> ByzantineGridResult:
    """Batched (topology, F) x seed grid as ONE compiled vmapped scan.

    Every config's host analysis runs once; the per-config runtime arrays
    (neighbor lists padded to the common deg_max, byz/active masks, F,
    gamma) stack leaf-wise onto a scenario axis and the K = |cfgs| x |seeds|
    grid executes in lockstep under a single ``jax.vmap``. Configs must be
    *compatible*: same N, same network count M (so one trace serves all),
    and M >= 2F+1 (the all-networks representative rule — the M < 2F+1
    branch needs per-config static index sets). Heterogeneous F values ride
    the scenario axis as traced scalars, which forces the sort-based XLA
    trim; a uniform F keeps the static-F Pallas path available.

    ``attack`` overrides every config's attack (one traced program per grid
    call — loop attacks host-side as in :func:`run_byzantine_sweep`). With
    ``mesh``, the scenario axis is sharded over ``data_axis`` via
    ``shard_map`` exactly like :func:`run_pushsum_sweep` (K padded up to a
    multiple of the axis size by repeating the last scenario).

    The jitted grid program is cached in ``_BYZ_GRID_COMPILED`` keyed on the
    full config-list fingerprint, so repeated studies neither retrace nor
    re-run the reduced-graph analysis.

    ``plan.faults`` applies one :class:`repro.core.faults.FaultModel` to
    every scenario (the cache keys on its values, so host-side severity
    loops stay correct); per-scenario fault axes belong in the
    social/HPS/push-sum grids, whose fault models ride the vmap axis.
    Execution knobs arrive via ``plan=`` (loose kwargs are deprecated
    shims; ``plan.store=None`` means ``"decisions"``); a plan carrying
    ``async_`` raises — the Byzantine engine has no async mode.
    """
    from repro.kernels.byz_trim import resolve_backend

    plan = resolve_plan(
        plan, _entry="run_byzantine_grid",
        _supports=("backend", "store", "mesh", "data_axis", "policy",
                   "faults"),
        **legacy)
    backend, mesh, data_axis = plan.backend, plan.mesh, plan.data_axis
    policy, faults = plan.policy, plan.faults
    store = "decisions" if plan.store is None else plan.store

    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("need at least one config")
    atk = attack if attack is not None else cfgs[0].attack
    N, M = cfgs[0].topo.N, cfgs[0].topo.M
    if any(c.topo.N != N or c.topo.M != M for c in cfgs) or model.N != N:
        raise ValueError("grid configs (and the model) must share (N, M)")

    rt_key = _cfgs_fingerprint(model, cfgs, atk)
    hit = _BYZ_RUNTIME_CACHE.get(rt_key)
    if hit is None:
        runtimes = []
        for c in cfgs:
            rt, extra_reps, _, _ = make_byzantine_runtime(
                model, dataclasses.replace(c, attack=atk)
            )
            if extra_reps is not None:
                raise ValueError(
                    "grid configs must satisfy M >= 2F+1 (the all-networks "
                    f"representative rule); config with F={c.F}, "
                    f"M={c.topo.M} needs the static extra-reps branch"
                )
            runtimes.append(rt)
        deg_max = max(int(rt.nbr_idx.shape[1]) for rt in runtimes)

        def pad_rt(rt: ByzRuntime) -> ByzRuntime:
            pad = deg_max - rt.nbr_idx.shape[1]
            return rt._replace(
                nbr_idx=jnp.pad(rt.nbr_idx, ((0, 0), (0, pad))),
                nbr_valid=jnp.pad(rt.nbr_valid, ((0, 0), (0, pad))),
            )

        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[pad_rt(rt) for rt in runtimes]
        )
        hit = _BYZ_RUNTIME_CACHE[rt_key] = stacked
    stacked = hit
    Fs = np.asarray([c.F for c in cfgs], np.int32)
    # a uniform F stays a static Python int (Pallas-trim eligible);
    # heterogeneous F is traced per scenario, which needs the sort lowering.
    # backend is normalized to the effective lowering so the compiled-cache
    # key names the traced program on every platform.
    static_F = int(Fs[0]) if bool((Fs == Fs[0]).all()) else None
    backend = resolve_backend(backend)
    if static_F is None and backend == "pallas":
        backend = "xla"

    seeds_np = np.atleast_1d(np.asarray(seeds, np.uint32))
    gi, sd = np.meshgrid(
        np.arange(len(cfgs), dtype=np.int32), seeds_np, indexing="ij"
    )
    gi, sd = gi.ravel(), sd.ravel()
    K = gi.shape[0]
    if mesh is not None:
        pad = (-K) % int(mesh.shape[data_axis])
        if pad:
            fill = np.full(pad, K - 1)
            gi = np.concatenate([gi, gi[fill]])
            sd = np.concatenate([sd, sd[fill]])

    pol = None if policy is None else resolve_policy(policy)
    cache_key = _byz_grid_key(model, cfgs, T, atk, mode, backend, store,
                              mesh, data_axis, pol, faults)
    fn = _BYZ_GRID_COMPILED.get(cache_key)
    if fn is None:
        single = functools.partial(
            _scan_core,
            faults=faults,
            gossip=functools.partial(
                _sparse_gossip, attack=atk, mode=mode, backend=backend,
                accum_dtype=None if pol is None else pol.accum,
            ),
            log_tables=model.log_tables().astype(jnp.float32),
            truth_probs=model.tables[:, model.truth, :].astype(jnp.float32),
            T=T,
            mode=mode,
            attack=atk,
            store=store,
            static_F=static_F,
            extra_reps=None,
            n_reps=M,
            policy=pol,
        )
        batched = jax.vmap(single)
        if mesh is not None:
            from repro.launch import compat

            spec = P(data_axis)
            batched = compat.shard_map(
                batched,
                mesh=mesh,
                in_specs=(spec, ByzRuntime(*([spec] * len(ByzRuntime._fields)))),
                out_specs=ByzantineResult(r=spec, decisions=spec),
                axis_names=frozenset({data_axis}),
                check_vma=False,
            )
        fn = _BYZ_GRID_COMPILED[cache_key] = jax.jit(batched)

    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(sd))
    rt_batch = jax.tree_util.tree_map(lambda x: x[jnp.asarray(gi)], stacked)
    res = fn(keys, rt_batch)
    return ByzantineGridResult(
        r=res.r[:K], decisions=res.decisions[:K],
        cfg=jnp.asarray(gi[:K]), F=jnp.asarray(Fs[gi[:K]]),
        seed=jnp.asarray(sd[:K]),
        fault=None if faults is None else jnp.zeros(K, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Algorithm 3: batched (topology x drop_prob x Gamma) x seed social sweeps
# ---------------------------------------------------------------------------

class SocialSweepResult(NamedTuple):
    """One row per scenario (config x seed), leading axis K.

    ``beliefs``/``log_ratio`` follow the ``store`` shapes of
    :class:`repro.core.social.SocialLearningResult` with the extra leading
    K — ``store="log_ratio"`` (the sweep default) gives the (K, T) worst
    log-ratio curves of Theorem 2 plus final (K, N, m) beliefs, which is
    the phase-diagram payload. ``cfg`` indexes into the expanded config
    list; ``drop_prob``/``gamma``/``seed`` are the per-scenario
    coordinates.
    """

    beliefs: jnp.ndarray
    log_ratio: jnp.ndarray
    drop_prob: jnp.ndarray  # (K,)
    gamma: jnp.ndarray      # (K,)
    seed: jnp.ndarray       # (K,)
    cfg: jnp.ndarray        # (K,) config index
    fault: jnp.ndarray | None = None  # (K,) fault-model index, None = no axis
    async_: jnp.ndarray | None = None  # (K,) async-model index, minor-most

    @property
    def K(self) -> int:
        return int(self.seed.shape[0])

    def describe(self) -> str:
        return _describe_result(self)


# Jitted social-sweep programs keyed on (mesh, data_axis, statics). The
# per-scenario data is ALL arrays (SocialRuntime leaves + PRNG keys), so one
# cached executable serves every model/topology of the same shapes — the
# jit wrapper's own cache handles shape changes; the LRU bound keeps long
# parameter studies from pinning retired shard_map wrappers.
_SOCIAL_COMPILED = _LRUCache(maxsize=16)

# Stacked SocialRuntime batches keyed on the (configs,) fingerprint:
# repeated sweep calls (e.g. host-side seed batches over one grid) skip the
# per-config edge-list construction and device uploads entirely.
_SOCIAL_RUNTIME_CACHE = _LRUCache(maxsize=16)


def _social_sweep_fn(mesh, data_axis, *, truth, M, T, store, backend,
                     policy=None, has_faults=False, has_async=False):
    key = (mesh, data_axis, truth, M, T, store, backend, policy, has_faults,
           has_async)
    fn = _SOCIAL_COMPILED.get(key)
    if fn is not None:
        return fn

    def base(keys, rt_batch, log_tables, cdf, fault_b=None, async_b=None):
        def single(k, rt, fault=None, am=None):
            # grid runtimes come from make_social_runtime: dst-sorted
            # edge index, e_max pad rows at dst = N - 1 keep it sorted
            _, outs = _social_scan_core(
                k, k, rt, log_tables, cdf,
                truth=truth, M=M, T=T, store=store, backend=backend,
                policy=policy, dst_sorted=True, faults=fault, async_=am,
            )
            return outs

        if fault_b is None and async_b is None:
            return jax.vmap(single, in_axes=(0, 0))(keys, rt_batch)
        if async_b is None:
            return jax.vmap(single, in_axes=(0, 0, 0))(
                keys, rt_batch, fault_b)
        if fault_b is None:
            return jax.vmap(
                lambda k, rt, am: single(k, rt, None, am),
                in_axes=(0, 0, 0),
            )(keys, rt_batch, async_b)
        return jax.vmap(single, in_axes=(0, 0, 0, 0))(
            keys, rt_batch, fault_b, async_b)

    if has_async and not has_faults:
        # shard_map passes positionally; skip the absent fault_b slot
        def body(keys, rt_batch, log_tables, cdf, async_b):
            return base(keys, rt_batch, log_tables, cdf, None, async_b)
    else:
        body = base

    if mesh is not None:
        from repro.launch import compat

        spec = P(data_axis)
        in_specs = (
            spec,
            SocialRuntime(*([spec] * len(SocialRuntime._fields))),
            P(),
            P(),
        )
        if has_faults:
            in_specs += (FaultModel(
                *([spec] * len(FaultModel._fields))),)
        if has_async:
            in_specs += (AsyncModel(
                *([spec] * len(AsyncModel._fields))),)
        body = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(spec, spec),
            axis_names=frozenset({data_axis}),
            check_vma=False,
        )
    fn = _SOCIAL_COMPILED[key] = jax.jit(body)
    return fn


def _cfg_fingerprint(cfgs) -> tuple:
    """Runtime-cache key over HPSConfig-shaped config lists — everything
    the stacked runtime arrays are derived from (shared by the HPS and
    social grid engines; keep in sync with any cache-relevant field added
    to :class:`repro.core.hps.HPSConfig`)."""
    parts = []
    for c in cfgs:
        topo = c.topo
        parts.append((
            topo.adj.tobytes(), topo.sizes, topo.offsets, topo.reps,
            float(c.drop_prob), c.gamma_period, c.B,
        ))
    return tuple(parts)


def run_social_grid(
    model: SignalModel,
    cfgs: Sequence[HPSConfig],
    T: int,
    seeds: Sequence[int] | int,
    *,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> SocialSweepResult:
    """Batched (topology, drop_prob, Gamma) x seed grid as ONE compiled
    vmapped scan of the fused Algorithm 3 engine.

    Every config's edge index builds once; the per-config runtime arrays
    (edge lists padded to the common E, representative masks, drop_prob /
    Gamma / B as traced scalars) stack leaf-wise onto a scenario axis and
    the K = |cfgs| x |seeds| grid executes in lockstep under a single
    ``jax.vmap``. Configs must be *compatible*: same N and same network
    count M (the fusion matrix divides by 2M, which stays static so one
    trace serves all). Each scenario's seed drives both PRNG streams (link
    masks and signals) through disjoint fold-in domains — a grid row is
    bit-identical to ``run_social_learning(..., seed=s, signal_seed=s)``
    whenever the config's edge count equals the grid's padded E (always
    true for single-topology drop x Gamma x seed sweeps). Mixed-E grids
    pad smaller edge lists up to the widest, which re-indexes the (E,)
    link-mask draw (jax's counter-based bits have no prefix property), so
    those rows are instead bit-identical to :func:`run_social_runtime` on
    the same ``e_max``-padded runtime.

    ``store`` defaults to ``"log_ratio"``: the (K, T) worst log-ratio
    curves are reduced inside the scan, so nothing of size (K, T, N, m)
    ever exists — pass ``store="trajectory"`` explicitly to materialize
    full belief histories. With ``mesh``, the scenario axis is sharded over
    ``data_axis`` via ``shard_map`` exactly like :func:`run_pushsum_sweep`
    (K padded up to a multiple of the axis size by repeating the last
    scenario; results bit-identical to the single-device vmap).

    The jitted program is cached in ``_SOCIAL_COMPILED`` keyed on
    (mesh, statics) only — the grid data is all arrays, so repeated studies
    over different models or topologies of the same shapes reuse one
    executable without retracing.

    ``faults`` (one :class:`repro.core.faults.FaultModel` or a sequence,
    e.g. a churn-rate ladder) crosses a fault-minor scenario axis into the
    grid — severity is traced per scenario, one executable for the whole
    fault grid; the result's ``fault`` field indexes into the sequence.
    ``faults=None`` keeps the pre-fault program bit-identical.

    This config-list API is anchored on dense-adjacency
    :class:`~repro.core.hps.HPSConfig` topologies (the fingerprint
    serializes ``topo.adj``), which targets moderate-N phase diagrams. For
    dense-free large-N grids, build :class:`~repro.core.social.SocialRuntime`
    batches from edge lists (:func:`graphs.block_complete_edge_list` +
    :func:`social.social_runtime_from_edge_list`, stacked leaf-wise) and
    ``jax.vmap`` :func:`repro.core.social._social_scan_core` directly — the
    scan core is the shared vmappable contract.

    ``plan.async_`` (one :class:`repro.core.asyncrony.AsyncModel` or a
    sequence, e.g. a (wake-rate x staleness) grid) crosses an async-minor
    scenario axis exactly like ``faults`` — the result's ``async_`` column
    indexes into the sequence, and a single concretely degenerate model
    dispatches to the synchronous program (no axis). All execution knobs
    arrive via ``plan=`` (loose kwargs are deprecated shims;
    ``plan.store=None`` means ``"log_ratio"``).
    """
    from repro.kernels.social_innov import resolve_backend

    plan = resolve_plan(
        plan, _entry="run_social_grid",
        _supports=("backend", "store", "mesh", "data_axis", "policy",
                   "faults", "async_"),
        **legacy)
    backend, mesh, data_axis = plan.backend, plan.mesh, plan.data_axis
    policy, faults = plan.policy, plan.faults
    store = "log_ratio" if plan.store is None else plan.store
    async_ = plan.async_
    if isinstance(async_, AsyncModel) and is_degenerate_async(async_):
        async_ = None
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("need at least one config")
    if store not in SOCIAL_STORES:
        raise ValueError(f"store must be one of {SOCIAL_STORES}, got {store!r}")
    N, M = cfgs[0].topo.N, cfgs[0].topo.M
    if any(c.topo.N != N or c.topo.M != M for c in cfgs) or model.N != N:
        raise ValueError("grid configs (and the model) must share (N, M)")

    rt_key = _cfg_fingerprint(cfgs)
    stacked = _SOCIAL_RUNTIME_CACHE.get(rt_key)
    if stacked is None:
        e_max = max(int(np.count_nonzero(c.topo.adj)) for c in cfgs)
        runtimes = [make_social_runtime(c, e_max=e_max) for c in cfgs]
        stacked = _SOCIAL_RUNTIME_CACHE[rt_key] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *runtimes
        )

    seeds_np = np.atleast_1d(np.asarray(seeds, np.uint32))
    gi, sd = np.meshgrid(
        np.arange(len(cfgs), dtype=np.int32), seeds_np, indexing="ij"
    )
    gi, sd = gi.ravel(), sd.ravel()
    (gi, sd), fi, fstack = _expand_fault_axis((gi, sd), faults)
    if fi is None:
        (gi, sd), ai, astack = _expand_async_axis((gi, sd), async_)
    else:
        (gi, sd, fi), ai, astack = _expand_async_axis((gi, sd, fi), async_)
    K = gi.shape[0]
    if mesh is not None:
        pad = (-K) % int(mesh.shape[data_axis])
        if pad:
            fill = np.full(pad, K - 1)
            gi = np.concatenate([gi, gi[fill]])
            sd = np.concatenate([sd, sd[fill]])
            if fi is not None:
                fi = np.concatenate([fi, fi[fill]])
            if ai is not None:
                ai = np.concatenate([ai, ai[fill]])

    fn = _social_sweep_fn(
        mesh, data_axis, truth=model.truth, M=M, T=T, store=store,
        backend=resolve_backend(backend),
        policy=None if policy is None else resolve_policy(policy),
        has_faults=fi is not None, has_async=ai is not None,
    )
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(sd))
    rt_batch = jax.tree_util.tree_map(lambda x: x[jnp.asarray(gi)], stacked)
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)
    args = (
        keys, rt_batch,
        model.log_tables().astype(jnp.float32),
        jnp.cumsum(truth_probs, axis=-1),
    )
    if fi is not None:
        args += (jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(fi)], fstack),)
    if ai is not None:
        args += (jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(ai)], astack),)
    beliefs, log_ratio = fn(*args)
    drops = np.asarray([c.drop_prob for c in cfgs], np.float32)
    gammas = np.asarray([c.gamma_period for c in cfgs], np.int32)
    return SocialSweepResult(
        beliefs=beliefs[:K], log_ratio=log_ratio[:K],
        drop_prob=jnp.asarray(drops[gi[:K]]),
        gamma=jnp.asarray(gammas[gi[:K]]),
        seed=jnp.asarray(sd[:K]), cfg=jnp.asarray(gi[:K]),
        fault=None if fi is None else jnp.asarray(fi[:K]),
        async_=None if ai is None else jnp.asarray(ai[:K]),
    )


def run_social_sweep(
    model: SignalModel,
    cfg: HPSConfig | Sequence[HPSConfig],
    T: int,
    *,
    drop_probs: Sequence[float] | float | None = None,
    gammas: Sequence[int] | int | None = None,
    seeds: Sequence[int] | int = 0,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> SocialSweepResult:
    """Cross-product (topology x drop_prob x Gamma x seed) Algorithm 3 sweep.

    ``cfg`` is one base config or a sequence of them (e.g. topology draws —
    all sharing (N, M)); every base is crossed with every ``drop_probs``
    value and every ``gammas`` fusion period (defaults: the base's own
    settings), and the expanded scenario list runs with every seed as ONE
    jitted vmapped scan via :func:`run_social_grid` — drop_prob and Gamma
    ride the scenario axis as traced scalars, so the entire grid is one
    compiled program. Scenario order: base-major, then drop, then Gamma,
    then seed, then fault (matching the ``cfg``/``drop_prob``/``gamma``/
    ``seed``/``fault``/``async_`` coordinate arrays of the result);
    ``plan.faults`` / ``plan.async_`` are the optional fault- and
    async-model axes of :func:`run_social_grid` (execution knobs arrive
    via ``plan=``; loose kwargs are deprecated shims).
    """
    plan = resolve_plan(
        plan, _entry="run_social_sweep",
        _supports=("backend", "store", "mesh", "data_axis", "policy",
                   "faults", "async_"),
        **legacy)
    bases = [cfg] if isinstance(cfg, HPSConfig) else list(cfg)
    expanded = []
    for base in bases:
        dps = ([base.drop_prob] if drop_probs is None
               else np.atleast_1d(np.asarray(drop_probs, np.float32)).tolist())
        gms = ([base.gamma_period] if gammas is None
               else np.atleast_1d(np.asarray(gammas, np.int32)).tolist())
        for dp in dps:
            for g in gms:
                expanded.append(dataclasses.replace(
                    base, drop_prob=float(dp), gamma_period=int(g)
                ))
    return run_social_grid(model, expanded, T, seeds, plan=plan)


# ---------------------------------------------------------------------------
# Algorithm 1: batched (topology x M x Gamma x drop) x seed HPS sweeps
# ---------------------------------------------------------------------------

class HPSSweepResult(NamedTuple):
    """One row per scenario (config x seed), leading axis K.

    ``ratio``/``gap`` follow the ``store`` shapes of
    :class:`repro.core.hps.HPSResult` with the extra leading K —
    ``store="gap"`` (the sweep default) gives the (K, T) worst
    consensus-error curves of Theorem 1 plus final (K, N, d) ratios, which
    is the decay-diagram payload without any O(K T N d) history. ``cfg``
    indexes into the config list; ``drop_prob``/``gamma``/``M``/``seed``
    are the per-scenario coordinates.
    """

    ratio: jnp.ndarray
    gap: jnp.ndarray
    drop_prob: jnp.ndarray  # (K,)
    gamma: jnp.ndarray      # (K,)
    M: jnp.ndarray          # (K,) sub-network count of that scenario
    seed: jnp.ndarray       # (K,)
    cfg: jnp.ndarray        # (K,) config index
    fault: jnp.ndarray | None = None  # (K,) fault-model index, None = no axis
    async_: jnp.ndarray | None = None  # (K,) async-model index, minor-most

    @property
    def K(self) -> int:
        return int(self.seed.shape[0])

    def describe(self) -> str:
        return _describe_result(self)


# Jitted HPS-sweep programs keyed on (mesh, data_axis, statics). The
# per-scenario data is ALL arrays (HPSRuntime leaves + PRNG keys + the
# shared w), so one cached executable serves every topology/M/Gamma/drop
# combo of the same shapes; the LRU bound keeps long parameter studies from
# pinning retired shard_map wrappers.
_HPS_COMPILED = _LRUCache(maxsize=16)

# Stacked HPSRuntime batches keyed on the (configs,) fingerprint: repeated
# sweep calls (e.g. host-side seed batches over one grid) skip the
# per-config edge-list construction and device uploads entirely.
_HPS_RUNTIME_CACHE = _LRUCache(maxsize=16)


def _hps_sweep_fn(mesh, data_axis, *, T, store, backend, policy=None,
                  has_faults=False, has_async=False):
    key = (mesh, data_axis, T, store, backend, policy, has_faults, has_async)
    fn = _HPS_COMPILED.get(key)
    if fn is not None:
        return fn

    def base(keys, rt_batch, w, fault_b=None, async_b=None):
        def single(k, rt, fault=None, am=None):
            # grid runtimes come from make_hps_runtime: dst-sorted edge
            # index, e_max pad rows at dst = N - 1 keep it sorted
            _, outs = _hps_scan_core(
                k, rt, w, T=T, store=store, backend=backend,
                policy=policy, dst_sorted=True, faults=fault, async_=am,
            )
            return outs

        if fault_b is None and async_b is None:
            return jax.vmap(single, in_axes=(0, 0))(keys, rt_batch)
        if async_b is None:
            return jax.vmap(single, in_axes=(0, 0, 0))(
                keys, rt_batch, fault_b)
        if fault_b is None:
            return jax.vmap(
                lambda k, rt, am: single(k, rt, None, am),
                in_axes=(0, 0, 0),
            )(keys, rt_batch, async_b)
        return jax.vmap(single, in_axes=(0, 0, 0, 0))(
            keys, rt_batch, fault_b, async_b)

    if has_async and not has_faults:
        # shard_map passes positionally; skip the absent fault_b slot
        def body(keys, rt_batch, w, async_b):
            return base(keys, rt_batch, w, None, async_b)
    else:
        body = base

    if mesh is not None:
        from repro.launch import compat

        spec = P(data_axis)
        in_specs = (
            spec,
            HPSRuntime(*([spec] * len(HPSRuntime._fields))),
            P(),
        )
        if has_faults:
            in_specs += (FaultModel(
                *([spec] * len(FaultModel._fields))),)
        if has_async:
            in_specs += (AsyncModel(
                *([spec] * len(AsyncModel._fields))),)
        body = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(spec, spec),
            axis_names=frozenset({data_axis}),
            check_vma=False,
        )
    fn = _HPS_COMPILED[key] = jax.jit(body)
    return fn


def run_hps_grid(
    w: jnp.ndarray,
    cfgs: Sequence[HPSConfig],
    T: int,
    seeds: Sequence[int] | int,
    *,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> HPSSweepResult:
    """Batched (topology, M, Gamma, drop) x seed grid as ONE compiled
    vmapped scan of the fused Algorithm 1 engine.

    Every config's edge index builds once; the per-config runtime arrays
    (edge lists padded to the common E, representative masks, drop_prob /
    Gamma / B / M as traced scalars) stack leaf-wise onto a scenario axis
    and the K = |cfgs| x |seeds| grid executes in lockstep under a single
    ``jax.vmap``. Configs must share N — the sub-network count M rides the
    scenario axis as a traced scalar through the 1/2M fusion weight, so
    hierarchies with DIFFERENT numbers of sub-networks batch into the same
    trace. ``w`` (N, d) is shared by every scenario. Each scenario's seed
    drives the link-mask stream on the dedicated ``hps_stream_fold``
    domain — a grid row is bit-identical to ``run_hps(w, cfg, T, seed=s)``
    whenever the config's edge count equals the grid's padded E (always
    true for single-topology Gamma x drop x seed sweeps); mixed-E grids
    pad smaller edge lists up to the widest, which re-indexes the (E,)
    link-mask draw, so those rows are instead bit-identical to
    :func:`repro.core.hps.run_hps_runtime` on the same padded runtime.

    ``store`` defaults to ``"gap"``: the (K, T) worst consensus-error
    curves are reduced inside the scan, so nothing of size (K, T, N, d)
    ever exists — pass ``store="trajectory"`` explicitly to materialize
    full ratio histories. With ``mesh``, the scenario axis is sharded over
    ``data_axis`` via ``shard_map`` exactly like the other engines (K
    padded up to a multiple of the axis size by repeating the last
    scenario; results bit-identical to the single-device vmap).

    The jitted program is cached in ``_HPS_COMPILED`` keyed on
    (mesh, statics) only — the grid data is all arrays, so repeated studies
    over different topologies of the same shapes reuse one executable.

    ``plan.faults`` (one :class:`repro.core.faults.FaultModel` or a
    sequence) crosses a fault-minor scenario axis into the grid exactly as
    in :func:`run_social_grid`; the result's ``fault`` field indexes into
    the sequence, and ``faults=None`` keeps the pre-fault program
    bit-identical. ``plan.async_`` crosses the async-minor axis the same
    way (a single concretely degenerate model dispatches to the
    synchronous program, no axis). Execution knobs arrive via ``plan=``
    (loose kwargs are deprecated shims; ``plan.store=None`` means
    ``"gap"``).
    """
    from repro.kernels.pushsum_edge import resolve_backend

    plan = resolve_plan(
        plan, _entry="run_hps_grid",
        _supports=("backend", "store", "mesh", "data_axis", "policy",
                   "faults", "async_"),
        **legacy)
    backend, mesh, data_axis = plan.backend, plan.mesh, plan.data_axis
    policy, faults = plan.policy, plan.faults
    store = "gap" if plan.store is None else plan.store
    async_ = plan.async_
    if isinstance(async_, AsyncModel) and is_degenerate_async(async_):
        async_ = None
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("need at least one config")
    if store not in HPS_STORES:
        raise ValueError(f"store must be one of {HPS_STORES}, got {store!r}")
    w = jnp.asarray(w)
    N = cfgs[0].topo.N
    if any(c.topo.N != N for c in cfgs) or w.shape[0] != N:
        raise ValueError("grid configs (and w) must share the node count N")

    rt_key = _cfg_fingerprint(cfgs)
    stacked = _HPS_RUNTIME_CACHE.get(rt_key)
    if stacked is None:
        e_max = max(int(np.count_nonzero(c.topo.adj)) for c in cfgs)
        runtimes = [make_hps_runtime(c, e_max=e_max) for c in cfgs]
        stacked = _HPS_RUNTIME_CACHE[rt_key] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *runtimes
        )

    seeds_np = np.atleast_1d(np.asarray(seeds, np.uint32))
    gi, sd = np.meshgrid(
        np.arange(len(cfgs), dtype=np.int32), seeds_np, indexing="ij"
    )
    gi, sd = gi.ravel(), sd.ravel()
    (gi, sd), fi, fstack = _expand_fault_axis((gi, sd), faults)
    if fi is None:
        (gi, sd), ai, astack = _expand_async_axis((gi, sd), async_)
    else:
        (gi, sd, fi), ai, astack = _expand_async_axis((gi, sd, fi), async_)
    K = gi.shape[0]
    if mesh is not None:
        pad = (-K) % int(mesh.shape[data_axis])
        if pad:
            fill = np.full(pad, K - 1)
            gi = np.concatenate([gi, gi[fill]])
            sd = np.concatenate([sd, sd[fill]])
            if fi is not None:
                fi = np.concatenate([fi, fi[fill]])
            if ai is not None:
                ai = np.concatenate([ai, ai[fill]])

    fn = _hps_sweep_fn(
        mesh, data_axis, T=T, store=store, backend=resolve_backend(backend),
        policy=None if policy is None else resolve_policy(policy),
        has_faults=fi is not None, has_async=ai is not None,
    )
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(sd))
    rt_batch = jax.tree_util.tree_map(lambda x: x[jnp.asarray(gi)], stacked)
    args = (keys, rt_batch, w)
    if fi is not None:
        args += (jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(fi)], fstack),)
    if ai is not None:
        args += (jax.tree_util.tree_map(
            lambda x: x[jnp.asarray(ai)], astack),)
    ratio, gap = fn(*args)
    drops = np.asarray([c.drop_prob for c in cfgs], np.float32)
    gammas = np.asarray([c.gamma_period for c in cfgs], np.int32)
    Ms = np.asarray([c.topo.M for c in cfgs], np.int32)
    return HPSSweepResult(
        ratio=ratio[:K], gap=gap[:K],
        drop_prob=jnp.asarray(drops[gi[:K]]),
        gamma=jnp.asarray(gammas[gi[:K]]),
        M=jnp.asarray(Ms[gi[:K]]),
        seed=jnp.asarray(sd[:K]), cfg=jnp.asarray(gi[:K]),
        fault=None if fi is None else jnp.asarray(fi[:K]),
        async_=None if ai is None else jnp.asarray(ai[:K]),
    )


def run_hps_sweep(
    w: jnp.ndarray,
    cfg: HPSConfig | Sequence[HPSConfig],
    T: int,
    *,
    drop_probs: Sequence[float] | float | None = None,
    gammas: Sequence[int] | int | None = None,
    seeds: Sequence[int] | int = 0,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> HPSSweepResult:
    """Cross-product (topology x M x drop_prob x Gamma x seed) HPS sweep.

    ``cfg`` is one base config or a sequence of them (e.g. hierarchies with
    different sub-network counts — all sharing N); every base is crossed
    with every ``drop_probs`` value and every ``gammas`` fusion period
    (defaults: the base's own settings), and the expanded scenario list
    runs with every seed as ONE jitted vmapped scan via
    :func:`run_hps_grid` — drop_prob, Gamma and M ride the scenario axis
    as traced scalars, so the entire grid is one compiled program.
    Scenario order: base-major, then drop, then Gamma, then seed, then
    fault, then async (matching the unified index-column convention;
    execution knobs arrive via ``plan=``, loose kwargs are deprecated
    shims).
    """
    plan = resolve_plan(
        plan, _entry="run_hps_sweep",
        _supports=("backend", "store", "mesh", "data_axis", "policy",
                   "faults", "async_"),
        **legacy)
    bases = [cfg] if isinstance(cfg, HPSConfig) else list(cfg)
    expanded = []
    for base in bases:
        dps = ([base.drop_prob] if drop_probs is None
               else np.atleast_1d(np.asarray(drop_probs, np.float32)).tolist())
        gms = ([base.gamma_period] if gammas is None
               else np.atleast_1d(np.asarray(gammas, np.int32)).tolist())
        for dp in dps:
            for g in gms:
                expanded.append(dataclasses.replace(
                    base, drop_prob=float(dp), gamma_period=int(g)
                ))
    return run_hps_grid(w, expanded, T, seeds, plan=plan)

# ---------------------------------------------------------------------------
# Cache registry: the one front door to every compiled/runtime cache the
# sweep engines (and the jitted push-sum step) own. Tests and operational
# tooling go through here instead of importing the private module globals —
# the globals stay (they ARE the caches), but their names are no longer an
# API surface.
# ---------------------------------------------------------------------------

class CacheInfo(NamedTuple):
    """``cache_info()`` payload: entries held now / eviction bound
    (``None`` = unbounded, e.g. the jit wrappers' own tracing caches)."""

    currsize: int
    maxsize: int | None


class CacheHandle(NamedTuple):
    """Uniform view of one cache: ``cache_info()`` + ``clear()``.

    Wraps the three cache shapes the engines use — :class:`_LRUCache`
    mappings, ``jax.jit`` wrappers (``_cache_size``/``clear_cache``), and
    ``functools.lru_cache`` factories — behind one interface.
    """

    name: str
    size_fn: object
    max_size: int | None
    clear_fn: object

    def cache_info(self) -> CacheInfo:
        return CacheInfo(currsize=int(self.size_fn()), maxsize=self.max_size)

    def clear(self) -> None:
        self.clear_fn()


def cache_registry() -> dict[str, CacheHandle]:
    """Live handles to every sweep-layer cache, keyed by the same names
    the retrace sentinel (:mod:`repro.statics.retrace`) registers.

    Built fresh per call (handles close over the module globals, so a
    handle stays valid across clears); ``clear()`` empties the underlying
    cache — compiled executables, stacked runtimes, or jit tracing caches —
    which is what retrace-sensitive tests use to reset between cases.
    """
    from .pushsum import _STEP_JIT, _step_jit_entries

    def _lru(name: str, c: _LRUCache) -> CacheHandle:
        return CacheHandle(name, lambda: len(c), c.maxsize, c.clear)

    def _jit(name: str, f) -> CacheHandle:
        return CacheHandle(name, f._cache_size, None, f.clear_cache)

    def _factory(name: str, f) -> CacheHandle:
        return CacheHandle(
            name, lambda: f.cache_info().currsize, None, f.cache_clear
        )

    handles = [
        _jit("pushsum.sweep-jit", _sweep_compiled),
        _jit("pushsum.sweep2d-jit", _sweep2d_compiled),
        _factory("pushsum.sweep-sharded", _sweep_sharded),
        _factory("pushsum.sweep2d-sharded", _sweep_sharded_2d),
        CacheHandle(
            "pushsum.step-jit", _step_jit_entries, None, _STEP_JIT.clear
        ),
        _lru("byz.compiled", _BYZ_COMPILED),
        _lru("byz.grid", _BYZ_GRID_COMPILED),
        _lru("byz.runtime", _BYZ_RUNTIME_CACHE),
        _lru("social.compiled", _SOCIAL_COMPILED),
        _lru("social.runtime", _SOCIAL_RUNTIME_CACHE),
        _lru("hps.compiled", _HPS_COMPILED),
        _lru("hps.runtime", _HPS_RUNTIME_CACHE),
    ]
    return {h.name: h for h in handles}


# ---------------------------------------------------------------------------
# Retrace-sentinel registrations: every compiled cache this module owns is
# visible to repro.statics.retrace, so the lint can prove that repeated
# sweep calls with unchanged configs never recompile.
# ---------------------------------------------------------------------------
register_statics_cache("pushsum.sweep-jit", _sweep_compiled._cache_size)
register_statics_cache("pushsum.sweep2d-jit", _sweep2d_compiled._cache_size)
register_statics_cache("byz.compiled", _BYZ_COMPILED)
register_statics_cache("byz.grid", _BYZ_GRID_COMPILED)
register_statics_cache("byz.runtime", _BYZ_RUNTIME_CACHE)
register_statics_cache("social.compiled", _SOCIAL_COMPILED)
register_statics_cache("social.runtime", _SOCIAL_RUNTIME_CACHE)
register_statics_cache("hps.compiled", _HPS_COMPILED)
register_statics_cache("hps.runtime", _HPS_RUNTIME_CACHE)
