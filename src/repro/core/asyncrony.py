"""Asynchronous event-driven execution plane — wake clocks + stale buffers.

Every engine in the repo steps a synchronous global round. The paper's
target regime is not synchronous: hierarchical networks with packet
drops, churn, and a sometimes-down parameter server are event-driven,
and *Robust Asynchronous and Network-Independent Cooperative Learning*
(Mojica-Nava et al.) shows non-Bayesian learning still converges when
agents wake on independent clocks and consume stale messages. This
module adds that mode while keeping the scan shape fixed:

* **Wake clocks.** Each agent carries an independent Poisson clock,
  discretized to one Bernoulli wake coin per scan tick
  (:func:`wake_mask`): a tick is a fixed-width block of concurrent
  wakeups, not a global round. ``wake_prob`` is the per-tick firing
  probability of the agent's clock. Asleep agents do nothing — their
  node state is frozen exactly like churn-dead agents
  (:func:`repro.core.faults.freeze`), they stage no message and
  integrate no delivery.

* **Bounded stale buffers.** Per-edge event streams generalize the
  in-scan Bernoulli link draw the sparse edge-list layout already
  supports: an awake sender latches its freshly staged cumulative sum
  into a per-edge single-slot buffer (:class:`AsyncBuffer` — the
  last-sent snapshot of the ``(E, d)`` ``rho`` relay plane, an O(E·d)
  extra scan carry pinned by the ``*_async`` statics contracts below).
  The buffer ages one tick per round; delivery happens when the link is
  up, the *receiver* is awake, and the snapshot is at most
  ``staleness`` ticks old — the sender may be asleep, which is the
  point. Because the receiver always integrates exactly
  ``rho_new - rho_old`` of the cumulative relay, total push-sum mass is
  conserved under ANY wake schedule, and the telescoping self-heals
  expired (dropped) snapshots on the next fresh one.

* **Degenerate = synchronous, bit for bit.** ``make_async_model()``
  (wake-prob 1, staleness 0) wakes every agent every tick and admits
  only same-tick rendezvous: the buffer then holds exactly this round's
  staged value on every delivering edge and every freeze mask is
  all-True, so one async tick equals one synchronous round op for op
  (bit-identical per step, regression-tested). At engine level the
  ``run_*`` entrypoints go one step further: a *concretely* degenerate
  model is detected at trace time (:func:`is_degenerate_async`) and
  dispatched to the synchronous program itself — bit-identity by
  construction, with zero buffer carry — because XLA's fusion choices
  (FMA contraction) may otherwise differ between the two scan bodies at
  the ~1 ulp level, exactly as they do for the fault plane's degenerate
  models. Traced/batched degenerate models (a sweep's async axis) run
  the real buffered machinery and match the synchronous rows to fault-
  plane tolerance. Async delivery always lowers through the XLA
  ``where`` + ``segment_sum`` path — the Pallas ``edge_scatter`` kernel
  is node-gather-shaped and cannot read a per-edge buffer, so
  ``backend="pallas"`` keeps its kernels for everything except the
  delivery gather.

:class:`AsyncModel` is a pytree of scalar arrays, so (wake-rate ×
staleness-bound) rides the existing vmap scenario axis of the sweep
engines without retracing — minor-most after the fault axis (fixed
order: scenario → fault → async). It arrives at every entrypoint as
the ``async_`` field of :class:`repro.core.plan.ExecutionPlan`, never
as a loose kwarg.

PRNG discipline: wake coins get their own fold-in domain,
``async_stream_fold``, the affine map ``t -> -(4t + engine) - 2^25``.
Over the statics horizon ``t < 2^20`` its image lies in
``[-(4·(2^20-1) + 3) - 2^25, -2^25]`` — strictly below the fault domain
``(-2^21 - 12·2^20, -2^21]``, below the HPS ``~t`` domain
``[-2^20, -1]``, sign-disjoint from every nonnegative engine stream,
and within int32. The three ``*_async`` contracts register the maps
with the :mod:`repro.statics.streams` lattice prover (cross-linked via
``shares_seed_with`` to every contract on the same base key), so a
future collision is a lint failure, not a silent correlation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.statics import contracts as _contracts

from .faults import (
    ENGINE_HPS,
    ENGINE_PUSHSUM,
    ENGINE_SOCIAL,
    N_ENGINES,
)

__all__ = [
    "ASYNC_DOMAIN_BASE",
    "AsyncModel",
    "AsyncBuffer",
    "async_stream_fold",
    "make_async_model",
    "init_async_buffer",
    "is_degenerate_async",
    "wake_mask",
]

#: Base of the async fold-in domain: images live at or below ``-2^25``,
#: strictly outside the fault band (which bottoms out above ``-2^24``
#: over the statics horizon) and every engine stream.
ASYNC_DOMAIN_BASE = 1 << 25


def async_stream_fold(t, engine: int):
    """Fold-in value for ``engine``'s wake-coin stream at tick ``t``.

    ``t -> -(N_ENGINES * t + engine) - 2^25`` — affine, so the statics
    lattice prover certifies disjointness exactly (range-disjoint from
    the fault domain within the horizon, stride-4 congruence between
    engines). Python ints are pinned to ``np.int32`` (the
    ``hps_stream_fold`` convention) so host-side probing and the traced
    int32 scan index agree bit for bit mod 2^32.
    """
    e = int(engine)
    if isinstance(t, (int, np.integer)):
        return np.int32(-(int(t) * N_ENGINES + e) - ASYNC_DOMAIN_BASE)
    return -(t * N_ENGINES + e) - ASYNC_DOMAIN_BASE


class AsyncModel(NamedTuple):
    """Scalar async-severity knobs; a pytree that rides the vmap
    scenario axis (stack models leaf-wise to sweep wake-rate ×
    staleness without retracing).

    The defaults of :func:`make_async_model` are fully degenerate:
    every agent wakes every tick and only same-tick rendezvous
    delivers — bit-identical to the synchronous engines.
    """

    wake_prob: jnp.ndarray  # () per-tick Bernoulli wake probability
    staleness: jnp.ndarray  # () int32 max buffer age that still delivers


def make_async_model(wake_prob=1.0, staleness=0) -> AsyncModel:
    return AsyncModel(
        wake_prob=jnp.asarray(wake_prob, jnp.float32),
        staleness=jnp.asarray(staleness, jnp.int32),
    )


def is_degenerate_async(am: AsyncModel | None) -> bool:
    """True iff ``am`` is a *concrete* scalar model with wake-prob 1 and
    staleness 0. The ``run_*`` entrypoints dispatch such a model to the
    synchronous program itself, making degenerate-async bit-identity a
    property of construction rather than of XLA fusion luck. Traced or
    batched models (a sweep's async axis) return False and run the real
    buffered machinery."""
    if am is None:
        return True
    try:
        return (float(am.wake_prob) >= 1.0 and int(am.staleness) == 0)
    except (TypeError, jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return False


class AsyncBuffer(NamedTuple):
    """Per-edge bounded message buffer carried through the scan: the
    last-sent snapshot of the staged cumulative sums plus its age in
    ticks. O(E·d) + O(E) — never a ``(T, E)`` schedule; the ``*_async``
    statics contracts pin this."""

    snap: jnp.ndarray    # (E, d) sender's staged sigma at its last wake
    snap_m: jnp.ndarray  # (E,)   companion mass snapshot
    age: jnp.ndarray     # (E,)   int32 ticks since the snapshot


def init_async_buffer(n_edges: int, d: int, dtype=jnp.float32) -> AsyncBuffer:
    """Empty buffer: zero snapshots (the relay's own t=0 value, so a
    pre-first-wake delivery integrates exactly nothing) at age 0."""
    return AsyncBuffer(
        snap=jnp.zeros((n_edges, d), dtype),
        snap_m=jnp.zeros((n_edges,), dtype),
        age=jnp.zeros((n_edges,), jnp.int32),
    )


def wake_mask(key, t, n: int, wake_prob, *, engine: int):
    """(N,) bool — which agents' clocks fire this tick, drawn on the
    engine's async-wake stream. ``wake_prob == 1.0`` is exactly
    all-True (``uniform`` samples [0, 1))."""
    kt = jax.random.fold_in(key, async_stream_fold(t, engine))
    return jax.random.uniform(kt, (n,)) < wake_prob


# ---------------------------------------------------------------------------
# Statics contracts — one per engine that folds the wake stream into its
# base key. Same discipline as the *_faults contracts: (a) async state
# stays O(E·d) per-edge carry, no (N, N) and no (T, *) schedules in an
# async trace; (b) the wake fold-in map is proven disjoint from every
# stream on the same base key by the shares_seed_with cross-links.
# repro.statics.cli maps each name to a concrete async fixture.
# ---------------------------------------------------------------------------

_ASYNC_FORBIDDEN = {"*": (("N", "N"), ("T", "*"))}


def _async_streams(engine: int):
    return (
        _contracts.StreamDecl(
            "async-wake", lambda t, _e=engine: async_stream_fold(t, _e)),
    )


_contracts.register(_contracts.EngineContract(
    name="pushsum_async",
    forbidden=_ASYNC_FORBIDDEN,
    streams=_async_streams(ENGINE_PUSHSUM),
    shares_seed_with=("pushsum", "pushsum_sharded", "pushsum_faults"),
))

_contracts.register(_contracts.EngineContract(
    name="social_async",
    forbidden=_ASYNC_FORBIDDEN,
    streams=_async_streams(ENGINE_SOCIAL),
    shares_seed_with=("social", "hps", "byzantine",
                      "social_faults", "hps_faults", "hps_async"),
))

_contracts.register(_contracts.EngineContract(
    name="hps_async",
    forbidden=_ASYNC_FORBIDDEN,
    streams=_async_streams(ENGINE_HPS),
    shares_seed_with=("hps", "social", "byzantine",
                      "hps_faults", "social_faults", "social_async"),
))
