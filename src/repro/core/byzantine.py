"""Hierarchical Byzantine-resilient non-Bayesian learning — Algorithm 2 / Thm 3.

The curse of dimensionality of vector Byzantine consensus (Remark 1:
tolerable fraction <= 1/(d+1)) is dodged by running one **scalar** dynamic per
ordered hypothesis pair (theta1, theta2). Agent j's pairwise statistic

    r_t^j(t1, t2)

accumulates trimmed-averaged neighbor statistics plus the *cumulative*
log-likelihood ratio of all its private signals so far (Eq. (11); this is why
Lemma 2 normalizes by t^2).

Mechanics per iteration t:
* agents in a network in C (the healthy networks satisfying Assumptions 3+4):
  broadcast r_{t-1}; receivers drop the F largest and F smallest received
  values and average the survivors with their own previous value, then add
  the cumulative LLR innovation (Alg. 2 lines 6-9);
* agents outside C are passive;
* every Gamma iterations the parameter server queries max{2F+1, M} random
  representatives, trims F from each end, averages the rest into w_tilde, and
  pushes w_tilde to the queried representatives that are NOT in C
  (lines 10-22). Borel-Cantelli guarantees every non-C agent is selected
  infinitely often, which is what Theorem 4's proof leans on.

All pairwise dynamics for all (m x m) ordered pairs run simultaneously as a
single (N, m, m) tensor program under jax.lax.scan.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .attacks import Attack
from .graphs import HierTopology, check_assumption3
from .signals import SignalModel

__all__ = [
    "ByzantineConfig",
    "ByzantineResult",
    "trimmed_neighbor_mean",
    "make_byzantine_scan",
    "run_byzantine_learning",
    "decide",
    "healthy_networks",
]


@dataclasses.dataclass(frozen=True)
class ByzantineConfig:
    topo: HierTopology
    F: int                      # max number of Byzantine agents system-wide
    byz: tuple[int, ...]        # actual compromised agent indices, |byz| <= F
    gamma_period: int           # PS fusion period Γ
    attack: Attack

    def byz_mask(self) -> np.ndarray:
        m = np.zeros(self.topo.N, dtype=bool)
        for b in self.byz:
            m[b] = True
        return m


class ByzantineResult(NamedTuple):
    r: jnp.ndarray          # (T, N, m, m) pairwise statistics (normals only valid)
    decisions: jnp.ndarray  # (T, N) argmax-min decision per agent per step


# Host-side analysis lattices. Assumption 3's reduced-graph enumeration is
# combinatorial in (block size, F) and healthy_networks re-runs it for every
# sweep call, so both levels are memoized: the per-block A3 verdict keyed by
# (adjacency bytes, F), and the full C set keyed by the (topology, F,
# Byzantine set, model) fingerprint. Sweeps over attack/seed grids then pay
# the analysis exactly once per topology.
_A3_LATTICE: dict[tuple, bool] = {}
_C_SET_LATTICE: dict[tuple, tuple[int, ...]] = {}


def _check_a3_cached(block: np.ndarray, F: int) -> bool:
    key = (block.shape[0], F, block.tobytes())
    hit = _A3_LATTICE.get(key)
    if hit is None:
        hit = _A3_LATTICE[key] = check_assumption3(block, F=F)
    return hit


def healthy_networks(topo: HierTopology, byz_mask: np.ndarray, F: int,
                     model: SignalModel | None = None) -> list[int]:
    """Indices of networks in C.

    A network qualifies iff (A3) every reduced graph has a single source
    component, and (A4) its *normal* agents can jointly distinguish every
    hypothesis pair: sum_j KL_j(l(.|a) || l(.|b)) > 0 for all a != b.
    (A4 is checked over the whole normal set — a necessary condition for
    the per-source-component statement; for the complete graphs we simulate,
    reduced-graph source components contain all but <= 2F normal agents, so
    we additionally require the KL mass not be concentrated on F agents by
    checking the sum with the top-F contributors removed.)

    Results are memoized (see ``_C_SET_LATTICE``): repeated sweep calls on
    the same (topology, F, Byzantine set, model) skip the reduced-graph
    enumeration entirely.
    """
    byz_mask = np.asarray(byz_mask)
    key = (
        topo.adj.tobytes(), topo.sizes, topo.offsets, F, byz_mask.tobytes(),
        None if model is None
        else (np.asarray(model.tables).tobytes(), model.truth),
    )
    hit = _C_SET_LATTICE.get(key)
    if hit is not None:
        return list(hit)
    out = []
    for i in range(topo.M):
        off, sz = topo.offsets[i], topo.sizes[i]
        local_byz = [j - off for j in range(off, off + sz) if byz_mask[j]]
        n_byz = len(local_byz)
        if n_byz * 3 >= sz:  # >= 1/3 compromised cannot satisfy A3 trims
            continue
        if not _check_a3_cached(topo.block(i), F=F):
            continue
        if model is not None and not _check_a4(model, topo, i, byz_mask, F):
            continue
        out.append(i)
    _C_SET_LATTICE[key] = tuple(out)
    return out


def _check_a4(model: SignalModel, topo: HierTopology, i: int,
              byz_mask: np.ndarray, F: int, tol: float = 1e-9) -> bool:
    from .signals import pairwise_kl

    off, sz = topo.offsets[i], topo.sizes[i]
    normal = [j for j in range(off, off + sz) if not byz_mask[j]]
    kl = np.asarray(pairwise_kl(np.asarray(model.tables)))[normal]  # (n,m,m)
    m = kl.shape[1]
    for a in range(m):
        for b in range(m):
            if a == b:
                continue
            contrib = np.sort(kl[:, a, b])       # ascending
            kept = contrib[:-F] if F > 0 else contrib
            if kept.sum() <= tol:                # distinguishers removable
                return False
    return True


def trimmed_neighbor_mean(
    vals: jnp.ndarray,      # (N, N, m, m) — vals[sender, receiver]
    adj: jnp.ndarray,       # (N, N) bool
    F: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-receiver trimmed sum over in-neighbor values (Alg. 2 lines 8-9).

    Returns (trimmed_sum, kept_count): sum over received values after
    dropping the F largest and F smallest, and the number kept, per
    receiver — both (N, m, m) / (N, 1, 1)-broadcastable.
    """
    n = vals.shape[0]
    big = jnp.asarray(jnp.finfo(vals.dtype).max / 4, vals.dtype)
    # non-edges -> +inf so they sort to the high end
    masked = jnp.where(adj[:, :, None, None], vals, big)
    s = jnp.sort(masked, axis=0)  # ascending along senders
    deg = adj.sum(axis=0).astype(jnp.int32)  # in-degree per receiver (N,)
    ranks = jnp.arange(n)[:, None]  # (N, 1) rank index along sender axis
    keep = (ranks >= F) & (ranks < (deg[None, :] - F))  # (N, N) rank x receiver
    keepf = keep[:, :, None, None].astype(vals.dtype)
    trimmed_sum = (s * keepf).sum(axis=0)
    kept = keep.sum(axis=0).astype(vals.dtype)  # (N,)
    return trimmed_sum, kept


def make_byzantine_scan(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
):
    """Build Algorithm 2's scan for a fixed (model, cfg, T).

    All host-side analysis (healthy-network detection, representative-set
    index arrays) runs once here; the returned ``run(base_key) ->
    ByzantineResult`` closure is a pure jax function of the PRNG key, so
    scenario sweeps can ``jax.vmap`` it over a batch of seeds (see
    :func:`repro.core.sweeps.run_byzantine_sweep`) and compile one scan for
    the whole batch.
    """
    topo = cfg.topo
    N, m = topo.N, model.m
    byz_mask_np = cfg.byz_mask()
    C = healthy_networks(topo, byz_mask_np, cfg.F, model)
    if len(C) < cfg.F + 1:
        raise ValueError(
            f"Assumption 5 violated: |C|={len(C)} < F+1={cfg.F + 1}"
        )
    net_of = topo.network_of()
    in_C = np.isin(net_of, C)                      # (N,) agent's network in C
    # gossip runs only inside C networks, between agents of the same network
    same_net = net_of[:, None] == net_of[None, :]
    gossip_adj = topo.adj & same_net & in_C[None, :]   # receivers in C
    active = in_C & ~byz_mask_np                        # normal agents that gossip

    adj_j = jnp.asarray(gossip_adj)
    byz_mask = jnp.asarray(byz_mask_np)
    active_j = jnp.asarray(active)
    in_C_j = jnp.asarray(in_C)
    net_of_j = jnp.asarray(net_of, dtype=jnp.int32)

    use_all_nets = topo.M >= 2 * cfg.F + 1
    n_reps = topo.M if use_all_nets else 2 * cfg.F + 1
    sizes = jnp.asarray(topo.sizes, dtype=jnp.int32)
    offsets = jnp.asarray(topo.offsets, dtype=jnp.int32)
    # static host-side index arrays for the M < 2F+1 branch
    C_arr = np.asarray(C, dtype=np.int32)
    non_C_agents = np.nonzero(~in_C)[0].astype(np.int32)
    if not use_all_nets and len(non_C_agents) == 0:
        # degenerate: every network is healthy — query one rep per network
        use_all_nets, n_reps = True, topo.M

    log_tables = model.log_tables().astype(jnp.float32)
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)
    def run(base_key: jnp.ndarray) -> ByzantineResult:
        def sample_llr(t):
            """One private signal per agent -> per-pair LLR increment (N, m, m)."""
            key = jax.random.fold_in(base_key, t)
            u = jax.random.uniform(key, (N,))
            cdf = jnp.cumsum(truth_probs, axis=-1)
            sig = (u[:, None] > cdf).sum(axis=-1)
            ll = jnp.take_along_axis(
                log_tables, sig[:, None, None].astype(jnp.int32), axis=2
            )[:, :, 0]                                   # (N, m)
            return ll[:, :, None] - ll[:, None, :]       # (N, m, m) antisymmetric

        def select_reps(key):
            """Random representative selection for a fusion round -> (n_reps,) idx."""
            if use_all_nets:
                ks = jax.random.split(key, topo.M)
                picks = [
                    offsets[i] + jax.random.randint(ks[i], (), 0, sizes[i])
                    for i in range(topo.M)
                ]
                return jnp.stack(picks)
            # one rep from each network in C + (2F+1-|C|) uniform from outside C
            ks = jax.random.split(key, len(C_arr) + 1)
            picks = [
                offsets[int(ci)] + jax.random.randint(ks[k], (), 0, sizes[int(ci)])
                for k, ci in enumerate(C_arr)
            ]
            extra = jax.random.choice(
                ks[-1], jnp.asarray(non_C_agents),
                shape=(n_reps - len(C_arr),), replace=False,
            )
            return jnp.concatenate([jnp.stack(picks), extra])

        def body(carry, t):
            r, cum_llr = carry
            key = jax.random.fold_in(base_key, t * 2 + 1)

            # ---- innovation accumulator (cumulative LLR of all signals so far)
            cum_llr = cum_llr + sample_llr(t)

            # ---- intra-C gossip with trimming (lines 6-9)
            honest_msgs = jnp.broadcast_to(r[:, None], (N, N, m, m))
            byz_msgs = cfg.attack.messages(key, t, r)
            msgs = jnp.where(byz_mask[:, None, None, None], byz_msgs, honest_msgs)
            tsum, kept = trimmed_neighbor_mean(msgs, adj_j, cfg.F)
            r_gossip = (tsum + r) / (kept[:, None, None] + 1.0) + cum_llr
            r_new = jnp.where(active_j[:, None, None], r_gossip, r)

            # ---- PS fusion every Γ (lines 10-22)
            def fuse(r_in):
                kk = jax.random.fold_in(base_key, t * 2 + 2)
                reps = select_reps(kk)                            # (n_reps,)
                rep_vals = r_in[reps]                             # (n_reps, m, m)
                byz_replies = cfg.attack.ps_reply(kk, t, r_in)    # (N, m, m)
                rep_vals = jnp.where(
                    byz_mask[reps][:, None, None], byz_replies[reps], rep_vals
                )
                s = jnp.sort(rep_vals, axis=0)
                keep = (jnp.arange(n_reps) >= cfg.F) & (
                    jnp.arange(n_reps) < n_reps - cfg.F
                )
                w = (s * keep[:, None, None]).sum(0) / keep.sum()
                # queried reps outside C adopt w_tilde (line 20-22)
                adopt = jnp.zeros((N,), bool).at[reps].set(True) & (~in_C_j)
                return jnp.where(adopt[:, None, None], w[None], r_in)

            is_fusion = (t + 1) % cfg.gamma_period == 0
            r_new = jax.lax.cond(is_fusion, fuse, lambda x: x, r_new)

            # Byzantine agents' own state is meaningless; keep it at 0.
            r_new = jnp.where(byz_mask[:, None, None], 0.0, r_new)

            dec = decide(r_new)
            return (r_new, cum_llr), (r_new, dec)

        r0 = jnp.zeros((N, m, m), jnp.float32)
        cum0 = jnp.zeros((N, m, m), jnp.float32)
        (_, _), (r_traj, decisions) = jax.lax.scan(
            body, (r0, cum0), jnp.arange(T, dtype=jnp.uint32)
        )
        return ByzantineResult(r=r_traj, decisions=decisions)

    return run


def run_byzantine_learning(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seed: int = 0,
) -> ByzantineResult:
    """Run Algorithm 2 for T iterations (single scenario)."""
    return make_byzantine_scan(model, cfg, T)(jax.random.PRNGKey(seed))


def run_byzantine_learning_ovr(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seed: int = 0,
) -> ByzantineResult:
    """One-vs-rest variant of Algorithm 2 (extension; DESIGN.md §8).

    The paper runs one scalar dynamic per ORDERED hypothesis pair — m(m-1)
    dynamics. For large m, the standard reduction runs m dynamics on the
    one-vs-rest statistics r^j(theta) accumulating
    log l(s|theta) - max_{theta' != theta} log l(s|theta'). Same trimming,
    same fusion rule, m/(m-1) times cheaper; the pairwise guarantee of
    Theorem 3 does not transfer verbatim (the OVR innovation is not
    antisymmetric), so this is benchmarked as an ablation, not claimed.

    Returns a ByzantineResult whose ``r`` has shape (T, N, m, 1).
    """
    topo = cfg.topo
    N, m = topo.N, model.m
    byz_mask_np = cfg.byz_mask()
    C = healthy_networks(topo, byz_mask_np, cfg.F, model)
    if len(C) < cfg.F + 1:
        raise ValueError(
            f"Assumption 5 violated: |C|={len(C)} < F+1={cfg.F + 1}"
        )
    net_of = topo.network_of()
    in_C = np.isin(net_of, C)
    same_net = net_of[:, None] == net_of[None, :]
    gossip_adj = topo.adj & same_net & in_C[None, :]
    active = in_C & ~byz_mask_np

    adj_j = jnp.asarray(gossip_adj)
    byz_mask = jnp.asarray(byz_mask_np)
    active_j = jnp.asarray(active)
    in_C_j = jnp.asarray(in_C)

    n_reps = topo.M  # M >= 2F+1 assumed for the ablation
    sizes = jnp.asarray(topo.sizes, dtype=jnp.int32)
    offsets = jnp.asarray(topo.offsets, dtype=jnp.int32)

    log_tables = model.log_tables().astype(jnp.float32)
    truth_probs = model.tables[:, model.truth, :].astype(jnp.float32)
    base_key = jax.random.PRNGKey(seed)

    def sample_ovr(t):
        key = jax.random.fold_in(base_key, t)
        u = jax.random.uniform(key, (N,))
        cdf = jnp.cumsum(truth_probs, axis=-1)
        sig = (u[:, None] > cdf).sum(axis=-1)
        ll = jnp.take_along_axis(
            log_tables, sig[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]                                   # (N, m)
        rest = jnp.where(jnp.eye(m, dtype=bool)[None], -jnp.inf, ll[:, None, :])
        return ll - rest.max(axis=-1)                 # (N, m) one-vs-rest

    def body(carry, t):
        r, cum = carry
        key = jax.random.fold_in(base_key, t * 2 + 1)
        cum = cum + sample_ovr(t)

        honest = jnp.broadcast_to(r[:, None], (N, N, m))
        byz_full = cfg.attack.messages(key, t, r[:, :, None])[..., 0]
        msgs = jnp.where(byz_mask[:, None, None], byz_full, honest)
        tsum, kept = trimmed_neighbor_mean(
            msgs[..., None], adj_j, cfg.F
        )
        r_gossip = (tsum[..., 0] + r) / (kept[:, None] + 1.0) + cum
        r_new = jnp.where(active_j[:, None], r_gossip, r)

        def fuse(r_in):
            kk = jax.random.fold_in(base_key, t * 2 + 2)
            ks = jax.random.split(kk, topo.M)
            reps = jnp.stack([
                offsets[i] + jax.random.randint(ks[i], (), 0, sizes[i])
                for i in range(topo.M)
            ])
            rep_vals = r_in[reps]
            s = jnp.sort(rep_vals, axis=0)
            keep = (jnp.arange(n_reps) >= cfg.F) & (
                jnp.arange(n_reps) < n_reps - cfg.F
            )
            w = (s * keep[:, None]).sum(0) / keep.sum()
            adopt = jnp.zeros((N,), bool).at[reps].set(True) & (~in_C_j)
            return jnp.where(adopt[:, None], w[None], r_in)

        r_new = jax.lax.cond((t + 1) % cfg.gamma_period == 0, fuse,
                             lambda x: x, r_new)
        r_new = jnp.where(byz_mask[:, None], 0.0, r_new)
        dec = r_new.argmax(axis=-1)
        return (r_new, cum), (r_new[..., None], dec)

    r0 = jnp.zeros((N, m), jnp.float32)
    (_, _), (r_traj, decisions) = jax.lax.scan(
        body, (r0, jnp.zeros((N, m), jnp.float32)),
        jnp.arange(T, dtype=jnp.uint32),
    )
    return ByzantineResult(r=r_traj, decisions=decisions)


def decide(r: jnp.ndarray) -> jnp.ndarray:
    """Decision rule: theta_hat = argmax_a min_{b != a} r(a, b).

    Theorem 3 guarantees a unique hypothesis whose pairwise statistics all
    diverge to +inf; with antisymmetric innovations that is theta*.
    r: (..., m, m) -> (...,) int decisions.
    """
    m = r.shape[-1]
    eye = jnp.eye(m, dtype=bool)
    masked = jnp.where(eye, jnp.inf, r)
    worst = masked.min(axis=-1)
    return worst.argmax(axis=-1)
