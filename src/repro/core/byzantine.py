"""Hierarchical Byzantine-resilient non-Bayesian learning — Algorithm 2 / Thm 3.

The curse of dimensionality of vector Byzantine consensus (Remark 1:
tolerable fraction <= 1/(d+1)) is dodged by running one **scalar** dynamic per
ordered hypothesis pair (theta1, theta2). Agent j's pairwise statistic

    r_t^j(t1, t2)

accumulates trimmed-averaged neighbor statistics plus the *cumulative*
log-likelihood ratio of all its private signals so far (Eq. (11); this is why
Lemma 2 normalizes by t^2).

Mechanics per iteration t:
* agents in a network in C (the healthy networks satisfying Assumptions 3+4):
  broadcast r_{t-1}; receivers drop the F largest and F smallest received
  values and average the survivors with their own previous value, then add
  the cumulative LLR innovation (Alg. 2 lines 6-9);
* agents outside C are passive;
* every Gamma iterations the parameter server queries max{2F+1, M} random
  representatives, trims F from each end, averages the rest into w_tilde, and
  pushes w_tilde to the queried representatives that are NOT in C
  (lines 10-22). Borel-Cantelli guarantees every non-C agent is selected
  infinitely often, which is what Theorem 4's proof leans on.

All pairwise dynamics for all (m x m) ordered pairs run simultaneously as a
single (N, m, m) tensor program under jax.lax.scan.

Gossip cores
------------
``core="sparse"`` (default) runs the trim on the padded neighbor-list layout
(:class:`repro.core.graphs.NeighborList`): per receiver, gather the deg_max
in-neighbor statistics, substitute attack values on Byzantine slots, and trim
via :mod:`repro.kernels.byz_trim` — O(N deg_max m^2 F) per step with nothing
larger than (N, deg_max, m^2) live. ``core="dense"`` is the seed lowering —
an (N, N, m, m) message broadcast filtered by :func:`trimmed_neighbor_mean`
— retained purely as the equivalence oracle for tests. Both cores share one
scan body (innovation, PS fusion, PRNG streams), so their trajectories agree
to fp reordering; ``mode="ovr"`` runs the one-vs-rest ablation through the
same body with pair shape (m,) instead of (m, m).

PRNG streams: each iteration consumes three independent streams (private
signal, gossip attack, PS fusion), given disjoint fold-in domains
``t * 3 + stream`` (see :func:`stream_fold`) so no two streams ever share a
fold-in value over any horizon.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .attacks import Attack
from .faults import (
    ENGINE_BYZANTINE,
    FaultModel,
    init_fault_state,
    ps_alive,
    step_faults_nbr,
)
from .graphs import HierTopology, check_assumption3, neighbor_lists
from .precision import Policy, resolve_policy
from .signals import SignalModel
from repro.statics.contracts import contract as statics_contract

__all__ = [
    "ByzantineConfig",
    "ByzantineResult",
    "ByzRuntime",
    "trimmed_neighbor_mean",
    "make_byzantine_runtime",
    "make_byzantine_scan",
    "run_byzantine_learning",
    "run_byzantine_learning_ovr",
    "decide",
    "healthy_networks",
    "stream_fold",
]

MODES = ("pairwise", "ovr")
CORES = ("sparse", "dense")
STORES = ("trajectory", "decisions", "final")

# Per-iteration PRNG streams. Each gets a disjoint fold-in domain
# t * N_STREAMS + stream, so e.g. the signal key at t can never collide with
# the gossip or fusion key of any other iteration (the seed's t / 2t+1 / 2t+2
# scheme aliased signal keys onto both other streams).
N_STREAMS = 3
STREAM_SIGNAL, STREAM_GOSSIP, STREAM_FUSION = range(N_STREAMS)


def stream_fold(t, stream: int):
    """Fold-in value of ``stream`` at iteration ``t`` — injective over
    (t, stream), which is what keeps the three per-iteration streams
    non-colliding over any horizon."""
    return t * N_STREAMS + stream


@dataclasses.dataclass(frozen=True)
class ByzantineConfig:
    topo: HierTopology
    F: int                      # max number of Byzantine agents system-wide
    byz: tuple[int, ...]        # actual compromised agent indices, |byz| <= F
    gamma_period: int           # PS fusion period Γ
    attack: Attack

    def byz_mask(self) -> np.ndarray:
        m = np.zeros(self.topo.N, dtype=bool)
        for b in self.byz:
            m[b] = True
        return m


class ByzantineResult(NamedTuple):
    """Scan output; shapes depend on the ``store`` option.

    ``store="trajectory"`` (default): ``r`` (T, N, m, m), ``decisions``
    (T, N). ``store="decisions"``: ``r`` is the final (N, m, m) only,
    ``decisions`` still (T, N) — the curve without the O(T N m^2) state.
    ``store="final"``: both are final-step only, (N, m, m) / (N,).
    One-vs-rest runs carry pair shape (m, 1) instead of (m, m).
    """

    r: jnp.ndarray
    decisions: jnp.ndarray


# Host-side analysis lattices. Assumption 3's reduced-graph enumeration is
# combinatorial in (block size, F) and healthy_networks re-runs it for every
# sweep call, so both levels are memoized: the per-block A3 verdict keyed by
# (adjacency bytes, F), and the full C set keyed by the (topology, F,
# Byzantine set, model) fingerprint. Sweeps over attack/seed grids then pay
# the analysis exactly once per topology.
_A3_LATTICE: dict[tuple, bool] = {}
_C_SET_LATTICE: dict[tuple, tuple[int, ...]] = {}


def _check_a3_cached(block: np.ndarray, F: int) -> bool:
    key = (block.shape[0], F, block.tobytes())
    hit = _A3_LATTICE.get(key)
    if hit is None:
        hit = _A3_LATTICE[key] = check_assumption3(block, F=F)
    return hit


def healthy_networks(topo: HierTopology, byz_mask: np.ndarray, F: int,
                     model: SignalModel | None = None) -> list[int]:
    """Indices of networks in C.

    A network qualifies iff (A3) every reduced graph has a single source
    component, and (A4) its *normal* agents can jointly distinguish every
    hypothesis pair: sum_j KL_j(l(.|a) || l(.|b)) > 0 for all a != b.
    (A4 is checked over the whole normal set — a necessary condition for
    the per-source-component statement; for the complete graphs we simulate,
    reduced-graph source components contain all but <= 2F normal agents, so
    we additionally require the KL mass not be concentrated on F agents by
    checking the sum with the top-F contributors removed.)

    Results are memoized (see ``_C_SET_LATTICE``): repeated sweep calls on
    the same (topology, F, Byzantine set, model) skip the reduced-graph
    enumeration entirely.
    """
    byz_mask = np.asarray(byz_mask)
    key = (
        topo.adj.tobytes(), topo.sizes, topo.offsets, F, byz_mask.tobytes(),
        None if model is None
        else (np.asarray(model.tables).tobytes(), model.truth),
    )
    hit = _C_SET_LATTICE.get(key)
    if hit is not None:
        return list(hit)
    out = []
    for i in range(topo.M):
        off, sz = topo.offsets[i], topo.sizes[i]
        local_byz = [j - off for j in range(off, off + sz) if byz_mask[j]]
        n_byz = len(local_byz)
        if n_byz * 3 >= sz:  # >= 1/3 compromised cannot satisfy A3 trims
            continue
        if not _check_a3_cached(topo.block(i), F=F):
            continue
        if model is not None and not _check_a4(model, topo, i, byz_mask, F):
            continue
        out.append(i)
    _C_SET_LATTICE[key] = tuple(out)
    return out


def _check_a4(model: SignalModel, topo: HierTopology, i: int,
              byz_mask: np.ndarray, F: int, tol: float = 1e-9) -> bool:
    from .signals import pairwise_kl

    off, sz = topo.offsets[i], topo.sizes[i]
    normal = [j for j in range(off, off + sz) if not byz_mask[j]]
    kl = np.asarray(pairwise_kl(np.asarray(model.tables)))[normal]  # (n,m,m)
    m = kl.shape[1]
    for a in range(m):
        for b in range(m):
            if a == b:
                continue
            contrib = np.sort(kl[:, a, b])       # ascending
            kept = contrib[:-F] if F > 0 else contrib
            if kept.sum() <= tol:                # distinguishers removable
                return False
    return True


def trimmed_neighbor_mean(
    vals: jnp.ndarray,      # (N, N, m, m) — vals[sender, receiver]
    adj: jnp.ndarray,       # (N, N) bool
    F: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-receiver trimmed sum over in-neighbor values (Alg. 2 lines 8-9).

    Returns (trimmed_sum, kept_count): sum over received values after
    dropping the F largest and F smallest, and the number kept, per
    receiver — both (N, m, m) / (N, 1, 1)-broadcastable.

    This is the dense O(N^2 m^2 log N) lowering; production paths run the
    neighbor-list trim in :mod:`repro.kernels.byz_trim` instead, and this
    stays as the equivalence oracle the sparse core is tested against.
    """
    n = vals.shape[0]
    big = jnp.asarray(jnp.finfo(vals.dtype).max / 4, vals.dtype)
    # non-edges -> +inf so they sort to the high end
    masked = jnp.where(adj[:, :, None, None], vals, big)
    s = jnp.sort(masked, axis=0)  # ascending along senders
    deg = adj.sum(axis=0).astype(jnp.int32)  # in-degree per receiver (N,)
    ranks = jnp.arange(n)[:, None]  # (N, 1) rank index along sender axis
    keep = (ranks >= F) & (ranks < (deg[None, :] - F))  # (N, N) rank x receiver
    keepf = keep[:, :, None, None].astype(vals.dtype)
    trimmed_sum = (s * keepf).sum(axis=0)
    kept = keep.sum(axis=0).astype(vals.dtype)  # (N,)
    return trimmed_sum, kept


# ---------------------------------------------------------------------------
# Scan runtime: the per-scenario arrays of one (topology, F, byz set) config
# ---------------------------------------------------------------------------

class ByzRuntime(NamedTuple):
    """Everything the scan body reads that can vary per scenario.

    All fields are arrays, so a batch of *compatible* configs — same
    (N, M, deg_max) after padding — stacks leaf-wise onto one leading
    scenario axis and rides a single ``jax.vmap``
    (:func:`repro.core.sweeps.run_byzantine_grid`). ``F`` and ``gamma`` are
    scalars here precisely so they can be traced per-scenario; the
    single-config path shadows ``F`` with the static Python int (which is
    what lets the Pallas trim kernel unroll its extraction loop).
    """

    nbr_idx: jnp.ndarray    # (N, deg_max) int32 in-neighbor sender per slot
    nbr_valid: jnp.ndarray  # (N, deg_max) bool — False on padding slots
    byz_mask: jnp.ndarray   # (N,) bool
    active: jnp.ndarray     # (N,) bool — normal agents inside C networks
    in_C: jnp.ndarray       # (N,) bool
    offsets: jnp.ndarray    # (M,) int32 network block starts
    sizes: jnp.ndarray      # (M,) int32 network block sizes
    F: jnp.ndarray          # () int32 trim count
    gamma: jnp.ndarray      # () int32 PS fusion period


def _analyze(model: SignalModel, cfg: ByzantineConfig):
    """Host-side healthy-network analysis shared by every scan builder."""
    topo = cfg.topo
    byz_mask_np = cfg.byz_mask()
    C = healthy_networks(topo, byz_mask_np, cfg.F, model)
    if len(C) < cfg.F + 1:
        raise ValueError(
            f"Assumption 5 violated: |C|={len(C)} < F+1={cfg.F + 1}"
        )
    net_of = topo.network_of()
    in_C = np.isin(net_of, C)                      # (N,) agent's network in C
    # gossip runs only inside C networks, between agents of the same network
    same_net = net_of[:, None] == net_of[None, :]
    gossip_adj = topo.adj & same_net & in_C[None, :]   # receivers in C
    active = in_C & ~byz_mask_np                        # normal agents that gossip
    return C, in_C, gossip_adj, active, byz_mask_np


def make_byzantine_runtime(
    model: SignalModel,
    cfg: ByzantineConfig,
    deg_max: int | None = None,
):
    """Host-side setup of one config -> ``(runtime, extra_reps, n_reps,
    gossip_adj)``.

    ``extra_reps`` is ``None`` when the all-networks representative rule
    applies (M >= 2F+1: one rep per network); otherwise it carries the
    static index arrays of the M < 2F+1 branch (reps from every C network
    plus uniform extras from outside C). ``gossip_adj`` is the dense (N, N)
    intra-C adjacency, consumed only by the ``core="dense"`` oracle.
    """
    C, in_C, gossip_adj, active, byz_mask_np = _analyze(model, cfg)
    topo = cfg.topo
    nl = neighbor_lists(gossip_adj, deg_max=deg_max)
    use_all_nets = topo.M >= 2 * cfg.F + 1
    non_C_agents = np.nonzero(~in_C)[0].astype(np.int32)
    if not use_all_nets and len(non_C_agents) == 0:
        # degenerate: every network is healthy — query one rep per network
        use_all_nets = True
    n_reps = topo.M if use_all_nets else 2 * cfg.F + 1
    extra_reps = None if use_all_nets else (
        tuple(int(c) for c in C), tuple(int(a) for a in non_C_agents), n_reps
    )
    rt = ByzRuntime(
        nbr_idx=jnp.asarray(nl.idx),
        nbr_valid=jnp.asarray(nl.valid),
        byz_mask=jnp.asarray(byz_mask_np),
        active=jnp.asarray(active),
        in_C=jnp.asarray(in_C),
        offsets=jnp.asarray(topo.offsets, dtype=jnp.int32),
        sizes=jnp.asarray(topo.sizes, dtype=jnp.int32),
        F=jnp.asarray(cfg.F, dtype=jnp.int32),
        gamma=jnp.asarray(cfg.gamma_period, dtype=jnp.int32),
    )
    return rt, extra_reps, n_reps, gossip_adj


# ---------------------------------------------------------------------------
# Gossip lowerings (Alg. 2 lines 6-9)
# ---------------------------------------------------------------------------

def _sparse_gossip(key, t, r, rt: ByzRuntime, F, *, attack: Attack,
                   mode: str, backend: str, accum_dtype=None):
    """Neighbor-list trim-gather -> (trimmed_sum (N, *pair), kept (N,))."""
    from repro.kernels.byz_trim import trim_gather_pairs

    n = r.shape[0]
    pair = r.shape[1:]
    if attack.nbr_messages is not None:
        bmsg = attack.nbr_messages(key, t, r, rt.nbr_idx).astype(r.dtype)
    else:
        # compatibility fallback for attacks without a sparse form: build
        # the dense point-to-point tensor and gather the needed slots —
        # correct, but reintroduces the O(N^2) intermediate
        full = attack.messages(
            key, t, r if mode == "pairwise" else r[:, :, None]
        )
        if mode == "ovr":
            full = full[..., 0]
        picked = full[rt.nbr_idx, jnp.arange(n)[:, None]]
        bmsg = jnp.broadcast_to(
            picked, rt.nbr_idx.shape + pair
        ).astype(r.dtype)
    byz_nbr = rt.byz_mask[rt.nbr_idx]
    # indices_sorted stays False: the row-major flattening of the padded
    # neighbor-list gather is not dst-monotone
    return trim_gather_pairs(
        r, rt.nbr_idx, rt.nbr_valid, bmsg, byz_nbr, F, backend,
        accum_dtype=accum_dtype,
    )


def _dense_gossip(key, t, r, rt: ByzRuntime, F, *, attack: Attack,
                  mode: str, adj: jnp.ndarray, accum_dtype=None):
    """(N, N) broadcast + sort oracle -> (trimmed_sum, kept)."""
    if accum_dtype is not None:
        r = r.astype(accum_dtype)
    n = r.shape[0]
    pair = r.shape[1:]
    honest = jnp.broadcast_to(r[:, None], (n, n) + pair)
    if mode == "pairwise":
        byz = attack.messages(key, t, r)
    else:
        byz = attack.messages(key, t, r[:, :, None])[..., 0]
    sender = (slice(None), None) + (None,) * len(pair)
    msgs = jnp.where(rt.byz_mask[sender], byz, honest)
    if mode == "pairwise":
        return trimmed_neighbor_mean(msgs, adj, F)
    tsum, kept = trimmed_neighbor_mean(msgs[..., None], adj, F)
    return tsum[..., 0], kept


# ---------------------------------------------------------------------------
# PS fusion (Alg. 2 lines 10-22)
# ---------------------------------------------------------------------------

def _select_reps(key, rt: ByzRuntime, extra_reps):
    """Random representative selection for a fusion round -> (n_reps,) idx."""
    M = rt.offsets.shape[0]
    if extra_reps is None:
        ks = jax.random.split(key, M)
        rint = jax.vmap(lambda k, s: jax.random.randint(k, (), 0, s))
        return (rt.offsets + rint(ks, rt.sizes)).astype(jnp.int32)
    # one rep from each network in C + (2F+1-|C|) uniform from outside C
    C_arr, non_C, n_reps = extra_reps
    ks = jax.random.split(key, len(C_arr) + 1)
    picks = [
        rt.offsets[ci] + jax.random.randint(ks[k], (), 0, rt.sizes[ci])
        for k, ci in enumerate(C_arr)
    ]
    extra = jax.random.choice(
        ks[-1], jnp.asarray(non_C, dtype=jnp.int32),
        shape=(n_reps - len(C_arr),), replace=False,
    )
    return jnp.concatenate([jnp.stack(picks), extra]).astype(jnp.int32)


def _fusion(key, t, r_in, rt: ByzRuntime, F, *, n_reps: int, extra_reps,
            attack: Attack, accum_dtype=None, live=None):
    """PS fusion round: query reps, trim F from each end, push w_tilde back.

    The trimmed-pool average is :func:`repro.core.hps.ps_trimmed_pool` —
    the same masked-segment reduction Algorithm 1's resilient
    :func:`~repro.core.hps.hps_fusion` lowers through, so the two PS-side
    fusion rules share one implementation (accepting a traced F for the
    batched (topology, F) grids).

    ``live`` (an (N,) churn mask, :mod:`repro.core.faults`) degrades the
    round gracefully: dead representatives neither answer the PS query
    (their pool slots are masked out of the trimmed mean) nor adopt the
    pushed-back value. ``live=None`` is the pre-fault program.
    """
    from .hps import ps_trimmed_pool

    pair = r_in.shape[1:]
    sl = (slice(None),) + (None,) * len(pair)
    reps = _select_reps(key, rt, extra_reps)              # (n_reps,)
    rep_vals = r_in[reps]                                 # (n_reps, *pair)
    if attack.nbr_messages is not None:
        reply = attack.nbr_messages(
            key, t, r_in, reps[None, :]
        )[0].astype(r_in.dtype)
    elif len(pair) == 2:
        reply = attack.ps_reply(key, t, r_in)[reps]
    else:
        reply = rep_vals        # no sparse reply defined: state is replayed
    rep_vals = jnp.where(rt.byz_mask[reps][sl], reply, rep_vals)
    pool_valid = (jnp.ones((n_reps,), bool) if live is None
                  else live[reps])
    w = ps_trimmed_pool(rep_vals, pool_valid, F, accum_dtype=accum_dtype)
    # queried reps outside C adopt w_tilde (lines 20-22); the pooled value
    # comes back in the accum slot — downcast so the carry dtype is stable
    adopt = jnp.zeros((r_in.shape[0],), bool).at[reps].set(True) & (~rt.in_C)
    if live is not None:
        adopt = adopt & live
    return jnp.where(adopt[sl], w[None].astype(r_in.dtype), r_in)


# ---------------------------------------------------------------------------
# The shared scan body
# ---------------------------------------------------------------------------

def _scan_core(
    base_key: jnp.ndarray,
    rt: ByzRuntime,
    *,
    gossip,                 # gossip(key, t, r, rt, F) -> (tsum, kept)
    log_tables: jnp.ndarray,
    truth_probs: jnp.ndarray,
    T: int,
    mode: str,
    attack: Attack,
    store: str,
    static_F: int | None,
    extra_reps,
    n_reps: int,
    policy: Policy | None = None,
    faults: FaultModel | None = None,
) -> ByzantineResult:
    """Algorithm 2's scan, parameterized over the gossip lowering and the
    per-scenario runtime arrays (vmappable for batched grids).

    ``policy`` (a resolved :class:`repro.core.precision.Policy` or None)
    sets the dtype of the persistent (N, *pair) carries — the pairwise
    statistic r and the cumulative LLR — with the gossip trim, fusion
    pool, and innovation arithmetic running in the accum slot. ``None``
    keeps the historical all-fp32 program bit-identical.

    ``faults`` (a traced :class:`repro.core.faults.FaultModel` pytree, or
    None for the bit-identical pre-fault program) layers the unified
    fault plane on top of the Byzantine adversary: Gilbert-Elliott bursts
    on the padded neighbor slots (a bad slot drops its gossip message at
    ``drop_bad``), churn (dead agents neither gossip, observe signals,
    nor answer PS queries — r and the cumulative LLR freeze until
    rejoin), and PS crash (fusion rounds skipped while the coordinator
    is down). Fault draws live on their own negative fold-in domain
    (``fault_stream_fold``), provably disjoint from the signal / gossip /
    fusion streams sharing ``base_key``.
    """
    st_dt = jnp.float32 if policy is None else policy.storage_dtype
    ac_dt = jnp.float32 if policy is None else policy.accum_dtype
    N = rt.byz_mask.shape[0]
    m = log_tables.shape[1]
    pair = (m, m) if mode == "pairwise" else (m,)
    sl = (slice(None),) + (None,) * len(pair)
    F = static_F if static_F is not None else rt.F
    cdf = jnp.cumsum(truth_probs, axis=-1)
    eye = jnp.eye(m, dtype=bool)

    def innovation(t):
        """One private signal per agent -> per-pair statistic increment."""
        key = jax.random.fold_in(base_key, stream_fold(t, STREAM_SIGNAL))
        u = jax.random.uniform(key, (N,))
        # searchsorted(side="left") over the inclusive cumsum counts the
        # entries strictly below u — bit-identical to the old compare+reduce
        # but O(log S) per agent and gather-free under vmap
        s_max = cdf.shape[-1] - 1
        sig = jnp.minimum(
            jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="left"))(
                cdf, u
            ),
            s_max,
        )
        ll = jnp.take_along_axis(
            log_tables, sig[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]                                   # (N, m)
        if mode == "pairwise":
            return ll[:, :, None] - ll[:, None, :]   # (N, m, m) antisymmetric
        rest = jnp.where(eye[None], -jnp.inf, ll[:, None, :])
        return ll - rest.max(axis=-1)                # (N, m) one-vs-rest

    def body(carry, t):
        r, cum_llr = carry[0], carry[1]
        if faults is not None:
            fs, drop = step_faults_nbr(base_key, t, faults, carry[2],
                                       engine=ENGINE_BYZANTINE)
            live = fs.node_live
            # a dropped/bursty slot or a dead endpoint silences the slot;
            # the trim denominator (kept) shrinks with it, so gossip
            # degrades to averaging over whoever actually delivered
            rt_t = rt._replace(
                nbr_valid=(rt.nbr_valid & ~drop
                           & live[rt.nbr_idx] & live[:, None]))
        else:
            rt_t = rt

        # ---- innovation accumulator (cumulative LLR of all signals so far)
        # accumulate in the accum slot, carry in storage (every cast below
        # is a traced no-op under the default fp32 policy)
        cum_new = (cum_llr.astype(ac_dt) + innovation(t)).astype(st_dt)
        if faults is not None:
            # dead agents observe no signals — the accumulator freezes
            cum_new = jnp.where(live[sl], cum_new, cum_llr)
        cum_llr = cum_new

        # ---- intra-C gossip with trimming (lines 6-9)
        gk = jax.random.fold_in(base_key, stream_fold(t, STREAM_GOSSIP))
        tsum, kept = gossip(gk, t, r, rt_t, F)
        r_gossip = ((tsum + r.astype(ac_dt)) / (kept[sl] + 1.0)
                    + cum_llr.astype(ac_dt))
        r_new = jnp.where(rt.active[sl], r_gossip, r.astype(ac_dt))
        r_new = r_new.astype(st_dt)
        if faults is not None:
            # dead agents neither gossip nor update — stale-state rejoin
            r_new = jnp.where(live[sl], r_new, r)

        # ---- PS fusion every Γ (lines 10-22)
        def fuse(r_in):
            fk = jax.random.fold_in(base_key, stream_fold(t, STREAM_FUSION))
            return _fusion(fk, t, r_in, rt, F, n_reps=n_reps,
                           extra_reps=extra_reps, attack=attack,
                           accum_dtype=None if policy is None
                           else policy.accum,
                           live=None if faults is None else live)

        is_fusion = (t + 1) % rt.gamma.astype(t.dtype) == 0
        if faults is not None:
            # PS crash: the whole fusion round is skipped — degrade to
            # intra-network consensus instead of pooling through a dead PS
            is_fusion = is_fusion & ps_alive(base_key, t, faults,
                                             engine=ENGINE_BYZANTINE)
        r_new = jax.lax.cond(is_fusion, fuse, lambda x: x, r_new)

        # Byzantine agents' own state is meaningless; keep it at 0.
        r_new = jnp.where(rt.byz_mask[sl], 0.0, r_new)

        dec = decide(r_new) if mode == "pairwise" else r_new.argmax(axis=-1)
        if store == "trajectory":
            ys = (r_new, dec)
        elif store == "decisions":
            ys = dec
        else:
            ys = None
        out = (r_new, cum_llr) + (() if faults is None else (fs,))
        return out, ys

    zeros = jnp.zeros((N,) + pair, st_dt)
    carry0 = (zeros, zeros) + (
        () if faults is None
        else (init_fault_state(N, rt.nbr_idx.shape),))
    (r_fin, *_), ys = jax.lax.scan(
        body, carry0, jnp.arange(T, dtype=jnp.uint32)
    )
    # diagnostics leave the engine in fp32 whatever the storage policy
    up = (lambda x: x.astype(jnp.float32)) if st_dt != jnp.float32 else (
        lambda x: x)
    tail = (lambda x: x[..., None]) if mode == "ovr" else (lambda x: x)
    if store == "trajectory":
        return ByzantineResult(r=tail(up(ys[0])), decisions=ys[1])
    if store == "decisions":
        return ByzantineResult(r=tail(up(r_fin)), decisions=ys)
    dec_fin = decide(r_fin) if mode == "pairwise" else r_fin.argmax(axis=-1)
    return ByzantineResult(r=tail(up(r_fin)), decisions=dec_fin)


@statics_contract(
    name="byzantine",
    # Covers the production core="sparse" path ONLY: the dense broadcast
    # oracle exists to materialize (N, N) on purpose and is exempt. The
    # "decisions"/"trajectory" stores legitimately carry (T, N) history,
    # so no horizon pattern is declared.
    forbidden={"*": (("N", "N"),)},
    streams=(
        ("signal", lambda t: stream_fold(t, STREAM_SIGNAL)),
        ("gossip", lambda t: stream_fold(t, STREAM_GOSSIP)),
        ("fusion", lambda t: stream_fold(t, STREAM_FUSION)),
    ),
    caches=("byz.compiled", "byz.grid", "byz.runtime"),
)
def make_byzantine_scan(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    *,
    mode: str = "pairwise",
    core: str = "sparse",
    backend: str = "auto",
    store: str = "trajectory",
    policy: Policy | str | None = None,
    faults: FaultModel | None = None,
):
    """Build Algorithm 2's scan for a fixed (model, cfg, T).

    All host-side analysis (healthy-network detection, neighbor-list
    construction, representative-set index arrays) runs once here; the
    returned ``run(base_key) -> ByzantineResult`` closure is a pure jax
    function of the PRNG key, so scenario sweeps can ``jax.vmap`` it over a
    batch of seeds (see :func:`repro.core.sweeps.run_byzantine_sweep`) and
    compile one scan for the whole batch.

    ``mode`` selects pairwise (m, m) dynamics or the one-vs-rest (m,)
    ablation; ``core`` the sparse neighbor-list trim (production) or the
    dense broadcast oracle; ``backend`` the sparse trim lowering
    (:mod:`repro.kernels.byz_trim`); ``store`` what the scan materializes
    (see :class:`ByzantineResult`); ``policy`` the precision policy of the
    persistent carries (:mod:`repro.core.precision`; ``None`` keeps the
    bit-identical all-fp32 program); ``faults`` the unified fault plane
    (:mod:`repro.core.faults` — a traced pytree, so fault severity can
    ride the vmap scenario axis; ``None`` keeps the bit-identical
    pre-fault program).
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if core not in CORES:
        raise ValueError(f"core must be one of {CORES}, got {core!r}")
    if store not in STORES:
        raise ValueError(f"store must be one of {STORES}, got {store!r}")
    if faults is not None and core == "dense":
        # the dense oracle gossips through a static (N, N) adjacency and
        # cannot see per-round fault-silenced neighbor slots
        raise ValueError("faults= requires core='sparse'")
    pol = None if policy is None else resolve_policy(policy)
    accum_name = None if pol is None else pol.accum
    rt, extra_reps, n_reps, gossip_adj = make_byzantine_runtime(model, cfg)
    if core == "sparse":
        gossip = functools.partial(
            _sparse_gossip, attack=cfg.attack, mode=mode, backend=backend,
            accum_dtype=accum_name,
        )
    else:
        gossip = functools.partial(
            _dense_gossip, attack=cfg.attack, mode=mode,
            adj=jnp.asarray(gossip_adj), accum_dtype=accum_name,
        )
    run = functools.partial(
        _scan_core,
        rt=rt,
        gossip=gossip,
        log_tables=model.log_tables().astype(jnp.float32),
        truth_probs=model.tables[:, model.truth, :].astype(jnp.float32),
        T=T,
        mode=mode,
        attack=cfg.attack,
        store=store,
        static_F=cfg.F,
        extra_reps=extra_reps,
        n_reps=n_reps,
        policy=pol,
        faults=faults,
    )
    return run


def run_byzantine_learning(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seed: int = 0,
    **scan_kwargs,
) -> ByzantineResult:
    """Run Algorithm 2 for T iterations (single scenario).

    Keyword arguments (``mode``, ``core``, ``backend``, ``store``,
    ``policy``) pass through to :func:`make_byzantine_scan`.
    """
    return make_byzantine_scan(model, cfg, T, **scan_kwargs)(
        jax.random.PRNGKey(seed)
    )


def run_byzantine_learning_ovr(
    model: SignalModel,
    cfg: ByzantineConfig,
    T: int,
    seed: int = 0,
    **scan_kwargs,
) -> ByzantineResult:
    """One-vs-rest variant of Algorithm 2 (extension; DESIGN.md §8).

    The paper runs one scalar dynamic per ORDERED hypothesis pair — m(m-1)
    dynamics. For large m, the standard reduction runs m dynamics on the
    one-vs-rest statistics r^j(theta) accumulating
    log l(s|theta) - max_{theta' != theta} log l(s|theta'). Same trimming,
    same fusion rule, m/(m-1) times cheaper; the pairwise guarantee of
    Theorem 3 does not transfer verbatim (the OVR innovation is not
    antisymmetric), so this is benchmarked as an ablation, not claimed.

    Returns a ByzantineResult whose ``r`` has shape (T, N, m, 1).
    """
    scan_kwargs.setdefault("mode", "ovr")
    return run_byzantine_learning(model, cfg, T, seed, **scan_kwargs)


def decide(r: jnp.ndarray) -> jnp.ndarray:
    """Decision rule: theta_hat = argmax_a min_{b != a} r(a, b).

    Theorem 3 guarantees a unique hypothesis whose pairwise statistics all
    diverge to +inf; with antisymmetric innovations that is theta*.
    r: (..., m, m) -> (...,) int decisions.
    """
    m = r.shape[-1]
    eye = jnp.eye(m, dtype=bool)
    masked = jnp.where(eye, jnp.inf, r)
    worst = masked.min(axis=-1)
    return worst.argmax(axis=-1)
