"""The paper's primary contribution, in pure JAX.

- :mod:`repro.core.graphs`    — topologies, reduced graphs, B-connectivity
- :mod:`repro.core.signals`   — likelihood models (Assumption 2 machinery)
- :mod:`repro.core.pushsum`   — fast robust push-sum over dropping links
- :mod:`repro.core.hps`       — Algorithm 1: Hierarchical Push-Sum
- :mod:`repro.core.social`    — Algorithm 3: fault-tolerant non-Bayesian learning
- :mod:`repro.core.byzantine` — Algorithm 2: Byzantine-resilient learning
- :mod:`repro.core.attacks`   — adversary strategies
- :mod:`repro.core.plan`      — the frozen ExecutionPlan every ``run_*``
  entry point takes as ``plan=`` (backend/policy/faults/mesh/async/...)
- :mod:`repro.core.asyncrony` — asynchronous wake clocks + bounded stale
  buffers (the ``async_`` plan field)
"""
from .plan import ExecutionPlan, resolve_plan
from .asyncrony import (
    AsyncBuffer,
    AsyncModel,
    async_stream_fold,
    init_async_buffer,
    is_degenerate_async,
    make_async_model,
    wake_mask,
)
from .graphs import (
    HierTopology,
    make_hierarchy,
    link_schedule,
    check_assumption3,
    is_strongly_connected,
    random_strongly_connected,
    EdgeList,
    edge_list,
    stack_edge_lists,
    edge_masks,
    sort_by_dst,
    block_complete_edge_list,
    hier_edge_list,
    random_strongly_connected_edge_list,
    NeighborList,
    neighbor_lists,
    stack_neighbor_lists,
)
from .signals import SignalModel, make_confused_model, check_global_observability
from .pushsum import (
    PushSumState,
    pushsum_step,
    run_pushsum,
    mass_invariant,
    ratios,
    SparsePushSumState,
    sparse_pushsum_step,
    run_pushsum_sparse,
    sparse_mass_invariant,
    sparse_ratios,
)
from .hps import (
    HPSConfig,
    HPSResult,
    HPSRuntime,
    hps_fusion,
    hps_runtime_from_edge_list,
    hps_step,
    hps_stream_fold,
    make_hps_runtime,
    ps_trimmed_pool,
    run_hps,
    run_hps_dense,
    run_hps_runtime,
    theorem1_bound,
)
from .social import (
    SocialLearningResult,
    SocialRuntime,
    kl_dual_averaging_update,
    make_social_runtime,
    run_social_learning,
    run_social_runtime,
    social_runtime_from_edge_list,
    social_stream_fold,
)
from .byzantine import (
    ByzantineConfig,
    ByzRuntime,
    make_byzantine_runtime,
    make_byzantine_scan,
    run_byzantine_learning,
    run_byzantine_learning_ovr,
    trimmed_neighbor_mean,
    healthy_networks,
    decide,
)
from .sweeps import (
    ByzantineGridResult,
    HPSSweepResult,
    PushSumSweepResult,
    SocialSweepResult,
    run_byzantine_grid,
    run_byzantine_sweep,
    run_hps_grid,
    run_hps_sweep,
    run_pushsum_sweep,
    run_social_grid,
    run_social_sweep,
)
from . import attacks

__all__ = [
    "HierTopology", "make_hierarchy", "link_schedule", "check_assumption3",
    "is_strongly_connected", "random_strongly_connected", "EdgeList",
    "edge_list", "stack_edge_lists", "edge_masks", "sort_by_dst",
    "block_complete_edge_list", "hier_edge_list",
    "random_strongly_connected_edge_list", "NeighborList", "neighbor_lists",
    "stack_neighbor_lists", "SignalModel", "make_confused_model",
    "check_global_observability", "PushSumState", "pushsum_step", "run_pushsum",
    "mass_invariant", "ratios", "SparsePushSumState", "sparse_pushsum_step",
    "run_pushsum_sparse", "sparse_mass_invariant", "sparse_ratios",
    "HPSConfig", "HPSResult", "HPSRuntime", "hps_fusion", "hps_step",
    "hps_stream_fold", "hps_runtime_from_edge_list", "make_hps_runtime",
    "ps_trimmed_pool", "run_hps", "run_hps_dense", "run_hps_runtime",
    "theorem1_bound", "run_social_learning", "kl_dual_averaging_update",
    "SocialLearningResult", "SocialRuntime", "make_social_runtime",
    "run_social_runtime", "social_runtime_from_edge_list",
    "social_stream_fold",
    "ByzantineConfig", "ByzRuntime", "make_byzantine_runtime",
    "make_byzantine_scan", "run_byzantine_learning",
    "run_byzantine_learning_ovr", "trimmed_neighbor_mean",
    "healthy_networks", "decide",
    "PushSumSweepResult", "ByzantineGridResult", "HPSSweepResult",
    "SocialSweepResult",
    "run_pushsum_sweep", "run_byzantine_sweep", "run_byzantine_grid",
    "run_hps_sweep", "run_hps_grid",
    "run_social_sweep", "run_social_grid",
    "ExecutionPlan", "resolve_plan",
    "AsyncModel", "AsyncBuffer", "make_async_model", "init_async_buffer",
    "is_degenerate_async", "wake_mask", "async_stream_fold",
    "attacks",
]
