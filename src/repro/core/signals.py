"""Private-signal likelihood models for non-Bayesian social learning.

The paper's observation model (Section III): each agent ``i_j`` observes a
private signal ``s_t`` from a finite alphabet whose distribution depends on
the unknown environment state ``theta* in Theta``; marginals may be identical
across hypotheses at a single agent ("local confusion"), but the *joint*
distribution must be globally observable (Assumption 2).

We use finite-alphabet likelihood tables, the standard instantiation in the
non-Bayesian learning literature (Jadbabaie et al., Nedic et al.), which also
makes the boundedness constant ``L = sup log l(s|theta)/l(s|theta')`` exact.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SignalModel",
    "make_confused_model",
    "check_global_observability",
    "pairwise_kl",
    "log_ratio_bound",
]


@dataclasses.dataclass(frozen=True)
class SignalModel:
    """Finite-alphabet signal structure for N agents, m hypotheses.

    tables: (N, m, S) — ``tables[j, k, s] = l_j(s | theta_k)``; rows sum to 1.
    truth: index of theta* in [0, m).
    """

    tables: jnp.ndarray
    truth: int

    @property
    def N(self) -> int:
        return int(self.tables.shape[0])

    @property
    def m(self) -> int:
        return int(self.tables.shape[1])

    @property
    def S(self) -> int:
        return int(self.tables.shape[2])

    def log_tables(self) -> jnp.ndarray:
        return jnp.log(self.tables)

    def sample(self, key: jax.Array, t_steps: int = 1) -> jnp.ndarray:
        """(t_steps, N) int signals drawn from l_j(. | theta*)."""
        probs = self.tables[:, self.truth, :]  # (N, S)
        keys = jax.random.split(key, self.N)
        draw = lambda k, p: jax.random.choice(
            k, self.S, shape=(t_steps,), p=p
        )
        out = jax.vmap(draw)(keys, probs)  # (N, t_steps)
        return out.T

    def log_lik(self, signals: jnp.ndarray) -> jnp.ndarray:
        """signals: (N,) ints -> (N, m) log l_j(s_j | theta_k)."""
        logt = self.log_tables()  # (N, m, S)
        return jnp.take_along_axis(
            logt, signals[:, None, None].astype(jnp.int32), axis=2
        )[:, :, 0]


def pairwise_kl(tables: np.ndarray) -> np.ndarray:
    """(N, m, m) per-agent KL(l_j(.|theta_a) || l_j(.|theta_b))."""
    t = np.asarray(tables, dtype=np.float64)
    logt = np.log(t)
    # KL[n,a,b] = sum_s t[n,a,s] (log t[n,a,s] - log t[n,b,s])
    self_term = np.einsum("nas,nas->na", t, logt)  # (N, m)
    cross_term = np.einsum("nas,nbs->nab", t, logt)  # (N, m, m)
    return self_term[:, :, None] - cross_term


def check_global_observability(tables: np.ndarray, tol: float = 1e-9) -> bool:
    """Assumption 2: for every pair theta != theta', sum_j KL_j > 0."""
    kl = pairwise_kl(np.asarray(tables))
    total = kl.sum(axis=0)  # (m, m)
    m = total.shape[0]
    off = total[~np.eye(m, dtype=bool)]
    return bool((off > tol).all())


def log_ratio_bound(tables: np.ndarray) -> float:
    """The paper's constant L = sup_{s, theta, theta'} log l(s|t)/l(s|t')."""
    logt = np.log(np.asarray(tables, dtype=np.float64))
    # max over (theta, theta') pairs and s of logt[:, a, s] - logt[:, b, s]
    diff = logt[:, :, None, :] - logt[:, None, :, :]
    return float(diff.max())


def make_confused_model(
    N: int,
    m: int,
    S: int = 4,
    truth: int = 0,
    confusion: float = 0.75,
    sharpness: float = 2.0,
    seed: int = 0,
) -> SignalModel:
    """Build a locally-confused but globally-observable signal model.

    Each agent j is *informative* only about hypothesis pairs containing
    ``k_j = j % m``: its likelihood rows for all other hypotheses are
    identical (full local confusion), mirroring the paper's setup where no
    single agent can learn theta* alone. A ``confusion`` fraction of
    additional agents are made completely uninformative (all rows equal) to
    stress the collaboration requirement.

    Guarantees Assumption 2 as long as every hypothesis index is covered by
    at least one informative agent, which holds when N >= m.
    """
    if N < m:
        raise ValueError("need N >= m for global observability by construction")
    rng = np.random.default_rng(seed)
    base = rng.dirichlet(np.ones(S) * sharpness, size=(N,))  # shared confused row
    tables = np.repeat(base[:, None, :], m, axis=1)  # (N, m, S): all rows equal

    n_uninformative = int(confusion * N)
    informative = np.ones(N, dtype=bool)
    # Keep one informative agent per hypothesis, then disable a random subset.
    disable = rng.permutation(N)[:n_uninformative]
    informative[disable] = False
    for k in range(m):
        covered = any(informative[j] and (j % m) == k for j in range(N))
        if not covered:
            for j in range(N):
                if (j % m) == k:
                    informative[j] = True
                    break

    for j in range(N):
        if not informative[j]:
            continue
        k = j % m
        # A distinct row for hypothesis k makes agent j distinguish k vs rest.
        distinct = rng.dirichlet(np.ones(S) * sharpness)
        # re-draw until meaningfully different from the confused row
        while np.abs(distinct - base[j]).sum() < 0.2:
            distinct = rng.dirichlet(np.ones(S) * sharpness)
        tables[j, k, :] = distinct

    # Floor probabilities so L is finite, renormalize.
    tables = np.maximum(tables, 0.02)
    tables = tables / tables.sum(axis=-1, keepdims=True)

    assert check_global_observability(tables), "construction must satisfy A2"
    return SignalModel(tables=jnp.asarray(tables, dtype=jnp.float32), truth=truth)
