"""Directed-graph machinery for the hierarchical multi-agent system.

Everything in this module is *host-side* (numpy) setup code: topologies are
built once, converted to jnp masks, and then consumed by the jax-traced
dynamics in :mod:`repro.core.pushsum` / :mod:`repro.core.byzantine`.

Conventions
-----------
* ``adj[i, j] = True`` means a directed edge ``i -> j`` (i sends to j).
* Self-loops are never stored in ``adj``; every algorithm in the paper adds
  the implicit self-contribution separately (the ``+1`` in ``d_j + 1``).
* A *hierarchical system* is a block-diagonal adjacency over ``M``
  sub-networks; no direct edges cross blocks (the parameter server is the
  only cross-network channel, modelled in :mod:`repro.core.hps`).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "ring",
    "complete",
    "random_strongly_connected",
    "is_strongly_connected",
    "diameter",
    "strongly_connected_components",
    "source_components",
    "has_single_source_component",
    "reduced_graphs",
    "check_assumption3",
    "beta_i",
    "HierTopology",
    "make_hierarchy",
    "link_schedule",
    "EdgeList",
    "edge_list",
    "stack_edge_lists",
    "edge_masks",
    "sort_by_dst",
    "EdgeShards",
    "partition_edge_list",
    "block_complete_edge_list",
    "hier_edge_list",
    "random_strongly_connected_edge_list",
    "NeighborList",
    "neighbor_lists",
    "stack_neighbor_lists",
]


# ---------------------------------------------------------------------------
# Basic topologies
# ---------------------------------------------------------------------------

def ring(n: int, bidirectional: bool = False) -> np.ndarray:
    """Directed ring ``0 -> 1 -> ... -> n-1 -> 0``."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        adj[i, (i + 1) % n] = True
        if bidirectional:
            adj[(i + 1) % n, i] = True
    return adj


def complete(n: int) -> np.ndarray:
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def random_strongly_connected(
    n: int, extra_edge_prob: float, rng: np.random.Generator
) -> np.ndarray:
    """A random digraph guaranteed strongly connected.

    Built as a random Hamiltonian cycle (strong-connectivity backbone) plus
    Bernoulli extra edges — the standard construction for consensus
    simulations.
    """
    perm = rng.permutation(n)
    adj = np.zeros((n, n), dtype=bool)
    for k in range(n):
        adj[perm[k], perm[(k + 1) % n]] = True
    extra = rng.random((n, n)) < extra_edge_prob
    np.fill_diagonal(extra, False)
    adj |= extra
    return adj


# ---------------------------------------------------------------------------
# Connectivity analysis
# ---------------------------------------------------------------------------

def _reach(adj: np.ndarray, start: int) -> np.ndarray:
    """Boolean reachability vector from ``start`` (BFS)."""
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    seen[start] = True
    frontier = [start]
    while frontier:
        nxt = []
        for u in frontier:
            for v in np.nonzero(adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    return seen


def is_strongly_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    if n == 0:
        return False
    return bool(_reach(adj, 0).all() and _reach(adj.T, 0).all())


def diameter(adj: np.ndarray) -> int:
    """Diameter of a strongly connected digraph (max shortest-path length)."""
    n = adj.shape[0]
    dist = np.where(adj, 1, np.inf)
    np.fill_diagonal(dist, 0)
    for k in range(n):  # Floyd–Warshall; n is small in all our sims
        dist = np.minimum(dist, dist[:, k : k + 1] + dist[k : k + 1, :])
    if np.isinf(dist).any():
        raise ValueError("graph is not strongly connected")
    return int(dist.max())


def strongly_connected_components(adj: np.ndarray) -> list[list[int]]:
    """Tarjan's SCC algorithm, iterative (host-side, small graphs)."""
    n = adj.shape[0]
    index = [-1] * n
    low = [0] * n
    on_stack = [False] * n
    stack: list[int] = []
    comps: list[list[int]] = []
    counter = 0
    succ = [list(np.nonzero(adj[u])[0]) for u in range(n)]

    for root in range(n):
        if index[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            u, pi = work[-1]
            if pi == 0:
                index[u] = low[u] = counter
                counter += 1
                stack.append(u)
                on_stack[u] = True
            advanced = False
            for i in range(pi, len(succ[u])):
                v = int(succ[u][i])
                if index[v] == -1:
                    work[-1] = (u, i + 1)
                    work.append((v, 0))
                    advanced = True
                    break
                elif on_stack[v]:
                    low[u] = min(low[u], index[v])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[u])
            if low[u] == index[u]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp.append(w)
                    if w == u:
                        break
                comps.append(sorted(comp))
    return comps


def source_components(adj: np.ndarray) -> list[list[int]]:
    """SCCs with no incoming edges from outside (sources of the condensation)."""
    comps = strongly_connected_components(adj)
    comp_of = {}
    for ci, comp in enumerate(comps):
        for v in comp:
            comp_of[v] = ci
    has_in = [False] * len(comps)
    rows, cols = np.nonzero(adj)
    for u, v in zip(rows, cols):
        cu, cv = comp_of[int(u)], comp_of[int(v)]
        if cu != cv:
            has_in[cv] = True
    return [comps[ci] for ci in range(len(comps)) if not has_in[ci]]


def has_single_source_component(adj: np.ndarray) -> bool:
    return len(source_components(adj)) == 1


# ---------------------------------------------------------------------------
# Reduced graphs (Definition 1) and Assumption 3
# ---------------------------------------------------------------------------

def reduced_graphs(
    adj: np.ndarray,
    faulty: Sequence[int],
    F: int,
    max_graphs: int | None = None,
    rng: np.random.Generator | None = None,
) -> Iterator[tuple[np.ndarray, list[int]]]:
    """Yield reduced graphs per Definition 1.

    (1) remove faulty nodes and incident links, (2) for each non-faulty node
    remove F additional incoming links (all combinations; sampled when the
    enumeration would exceed ``max_graphs``).

    Yields ``(reduced_adj, good_nodes)`` where ``reduced_adj`` is indexed by
    position in ``good_nodes``.
    """
    n = adj.shape[0]
    faulty_set = set(int(f) for f in faulty)
    good = [v for v in range(n) if v not in faulty_set]
    g = len(good)
    base = adj[np.ix_(good, good)].copy()

    per_node_choices: list[list[tuple[int, ...]]] = []
    for j in range(g):
        incoming = list(np.nonzero(base[:, j])[0])
        if len(incoming) <= F:
            per_node_choices.append([tuple(incoming)])
        else:
            per_node_choices.append(list(itertools.combinations(incoming, F)))

    total = 1
    for c in per_node_choices:
        total *= len(c)
        if max_graphs is not None and total > max_graphs:
            break

    def build(choice_per_node) -> np.ndarray:
        red = base.copy()
        for j, removed in enumerate(choice_per_node):
            for r in removed:
                red[r, j] = False
        return red

    if max_graphs is not None and total > max_graphs:
        rng = rng or np.random.default_rng(0)
        for _ in range(max_graphs):
            choice = [c[rng.integers(len(c))] for c in per_node_choices]
            yield build(choice), good
    else:
        for choice in itertools.product(*per_node_choices):
            yield build(choice), good


def check_assumption3(
    adj: np.ndarray, F: int, max_fault_sets: int = 64, max_graphs: int = 256
) -> bool:
    """Check Assumption 3: every reduced graph has exactly one source component.

    Exhaustive for small graphs, sampled otherwise. A complete graph with
    ``n >= 3F + 1`` always passes (classical result) — we still verify.
    """
    n = adj.shape[0]
    rng = np.random.default_rng(0)
    fault_sets = list(itertools.combinations(range(n), F)) if F > 0 else [()]
    if len(fault_sets) > max_fault_sets:
        idx = rng.choice(len(fault_sets), size=max_fault_sets, replace=False)
        fault_sets = [fault_sets[i] for i in idx]
    for fs in fault_sets:
        for red, _good in reduced_graphs(adj, fs, F, max_graphs=max_graphs, rng=rng):
            if len(source_components(red)) != 1:
                return False
    return True


def beta_i(adj: np.ndarray) -> float:
    """beta_i = 1 / max_j (d_j + 1)^2 — the per-network contraction constant."""
    d_out = adj.sum(axis=1)
    return 1.0 / float((d_out.max() + 1) ** 2)


# ---------------------------------------------------------------------------
# Hierarchical system
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HierTopology:
    """M sub-networks glued block-diagonally; reps exchange with the PS.

    Attributes
    ----------
    adj: (N, N) bool block-diagonal adjacency.
    sizes: per-network agent counts ``n_i``.
    offsets: start index of each network's block.
    reps: global index of each network's designated agent (first of block
        by default — the paper allows an arbitrary choice).
    """

    adj: np.ndarray
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    reps: tuple[int, ...]

    @property
    def N(self) -> int:
        return int(self.adj.shape[0])

    @property
    def M(self) -> int:
        return len(self.sizes)

    def network_of(self) -> np.ndarray:
        """(N,) network index of every agent."""
        out = np.zeros(self.N, dtype=np.int32)
        for i, (off, sz) in enumerate(zip(self.offsets, self.sizes)):
            out[off : off + sz] = i
        return out

    def block(self, i: int) -> np.ndarray:
        off, sz = self.offsets[i], self.sizes[i]
        return self.adj[off : off + sz, off : off + sz]

    def d_star(self) -> int:
        return max(diameter(self.block(i)) for i in range(self.M))

    def min_beta(self) -> float:
        return min(beta_i(self.block(i)) for i in range(self.M))

    def rep_mask(self) -> np.ndarray:
        mask = np.zeros(self.N, dtype=bool)
        for r in self.reps:
            mask[r] = True
        return mask


def make_hierarchy(
    sizes: Sequence[int],
    topology: str = "ring+",
    extra_edge_prob: float = 0.3,
    seed: int = 0,
    rep_choice: str = "first",
) -> HierTopology:
    """Build an M-network hierarchical system.

    topology: "ring" | "complete" | "ring+" (ring + random extra edges).
    """
    rng = np.random.default_rng(seed)
    blocks = []
    for n in sizes:
        if topology == "ring":
            b = ring(n)
        elif topology == "complete":
            b = complete(n)
        elif topology == "ring+":
            b = random_strongly_connected(n, extra_edge_prob, rng)
        else:
            raise ValueError(f"unknown topology {topology!r}")
        assert is_strongly_connected(b)
        blocks.append(b)
    N = int(sum(sizes))
    adj = np.zeros((N, N), dtype=bool)
    offsets = []
    off = 0
    for b, n in zip(blocks, sizes):
        adj[off : off + n, off : off + n] = b
        offsets.append(off)
        off += n
    if rep_choice == "first":
        reps = tuple(offsets)
    elif rep_choice == "random":
        reps = tuple(int(o + rng.integers(n)) for o, n in zip(offsets, sizes))
    else:
        raise ValueError(rep_choice)
    return HierTopology(
        adj=adj, sizes=tuple(int(s) for s in sizes), offsets=tuple(offsets), reps=reps
    )


# ---------------------------------------------------------------------------
# Sparse edge-list representation
# ---------------------------------------------------------------------------
#
# The fast robust push-sum only ever needs per-*directed-link* state (the
# cumulative ``rho`` a receiver has heard on each in-link), so on sparse
# topologies the O(N^2) adjacency/mask tensors are pure waste. An
# :class:`EdgeList` is the host-side (numpy) sparse view consumed by
# :mod:`repro.core.pushsum`'s edge-list core: edge e is the directed link
# ``src[e] -> dst[e]``; per-edge state arrays are (E, ...) and node updates
# use ``jax.ops.segment_sum`` over ``dst``.

@dataclasses.dataclass(frozen=True)
class EdgeList:
    """Sparse directed graph: edge ``e`` is ``src[e] -> dst[e]``.

    ``valid`` marks live edges — always all-True for a single graph, but
    batched/padded edge lists (see :func:`stack_edge_lists`) pad to a common
    E with ``valid=False`` dummy edges so topology draws with different edge
    counts can ride one ``jax.vmap`` axis.
    """

    src: np.ndarray    # (E,) int32 sender of each edge
    dst: np.ndarray    # (E,) int32 receiver of each edge
    n: int             # number of nodes
    valid: np.ndarray  # (E,) bool — False on padding edges

    @property
    def E(self) -> int:
        """Padded edge count — last axis, correct for single and batched."""
        return int(self.src.shape[-1])

    @property
    def is_batched(self) -> bool:
        return self.src.ndim == 2

    def _require_single(self, what: str) -> None:
        if self.is_batched:
            raise ValueError(
                f"{what} is per-graph; this EdgeList batches "
                f"{self.src.shape[0]} topology draws — index a row first"
            )

    def out_degree(self) -> np.ndarray:
        """(N,) out-degree over valid edges (the ``d_j`` of ``d_j + 1``)."""
        self._require_single("out_degree()")
        deg = np.zeros(self.n, dtype=np.int32)
        np.add.at(deg, self.src[self.valid], 1)
        return deg

    def to_dense(self) -> np.ndarray:
        self._require_single("to_dense()")
        adj = np.zeros((self.n, self.n), dtype=bool)
        adj[self.src[self.valid], self.dst[self.valid]] = True
        return adj


def edge_list(adj: np.ndarray) -> EdgeList:
    """Dense (N, N) bool adjacency -> sparse :class:`EdgeList`.

    Edges are emitted in C order (row-major: sorted by src, then dst), so
    ``edge_masks(masks, el)[t, e] == masks[t, el.src[e], el.dst[e]]``.
    """
    src, dst = np.nonzero(np.asarray(adj, dtype=bool))
    return EdgeList(
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        n=int(adj.shape[0]),
        valid=np.ones(src.shape[0], dtype=bool),
    )


def stack_edge_lists(adjs: Sequence[np.ndarray]) -> EdgeList:
    """Batch G topology draws into one padded EdgeList for vmapped sweeps.

    Returns an EdgeList whose fields have a leading graph axis: src/dst/valid
    are (G, E_max); ``n`` must agree across draws. Padding edges point 0 -> 0
    with ``valid=False`` and are excluded from out-degrees and delivery by
    the sparse core (their mask is forced False).
    """
    els = [edge_list(a) for a in adjs]
    n = els[0].n
    if any(el.n != n for el in els):
        raise ValueError("all topology draws must have the same node count")
    E = max(el.E for el in els)
    src = np.zeros((len(els), E), dtype=np.int32)
    dst = np.zeros((len(els), E), dtype=np.int32)
    valid = np.zeros((len(els), E), dtype=bool)
    for g, el in enumerate(els):
        src[g, : el.E] = el.src
        dst[g, : el.E] = el.dst
        valid[g, : el.E] = True
    return EdgeList(src=src, dst=dst, n=n, valid=valid)


def sort_by_dst(el: EdgeList, return_offsets: bool = False):
    """Stable-sort the edge index by receiver -> (sorted, perm, inv).

    The fused Pallas edge-scatter kernel (:mod:`repro.kernels.pushsum_edge`)
    streams edges in ``dst`` order so the per-receiver integration is a run
    of contiguous segments instead of a generic scatter. Sorting is a pure
    relabeling of edge slots:

    * ``perm``  (E,) int32 — sorted position -> original edge index, i.e.
      ``sorted.src == el.src[..., perm]``. Project any original-edge-order
      array (an explicit (T, E) mask schedule, an initial rho) into the
      sorted layout with ``arr[..., perm]``.
    * ``inv``   (E,) int32 — original edge index -> sorted position
      (``inv[perm[i]] == i``), so per-edge state computed in the sorted
      layout maps back via ``rho_sorted[..., inv, :]``.

    With ``return_offsets=True`` a fourth value is returned: CSR-style
    per-destination segment offsets, (..., N+1) int32 with
    ``offsets[..., v] : offsets[..., v + 1]`` the contiguous run of sorted
    edges whose receiver is ``v`` (``offsets[..., 0] == 0``,
    ``offsets[..., N] == E``). The edge partitioner
    (:func:`partition_edge_list`) cuts the sorted index against these runs,
    and the downstream lowerings pass ``indices_are_sorted=True`` to the
    per-receiver ``segment_sum`` so pre-sorted inputs skip one argsort.
    Offsets on batched edge lists count padding edges inside the ``dst == 0``
    run (padding keeps ``dst = 0``); the core's ``mask & valid`` guard is
    what silences them, exactly as for ``perm``/``inv``.

    Batched edge lists sort every topology draw independently (perm/inv are
    then (G, E)); padding edges keep ``valid=False`` and simply sort in with
    the genuine ``dst == 0`` run, where the core's ``mask & valid`` guard
    already silences them.
    """
    dst = np.asarray(el.dst)
    perm = np.argsort(dst, axis=-1, kind="stable").astype(np.int32)
    inv = np.empty_like(perm)
    if perm.ndim == 1:
        inv[perm] = np.arange(perm.shape[0], dtype=np.int32)
        sorted_el = EdgeList(
            src=el.src[perm], dst=el.dst[perm], n=el.n, valid=el.valid[perm]
        )
    else:
        rows = np.arange(perm.shape[0])[:, None]
        inv[rows, perm] = np.arange(perm.shape[1], dtype=np.int32)[None, :]
        sorted_el = EdgeList(
            src=np.take_along_axis(el.src, perm, axis=1),
            dst=np.take_along_axis(el.dst, perm, axis=1),
            n=el.n,
            valid=np.take_along_axis(el.valid, perm, axis=1),
        )
    if not return_offsets:
        return sorted_el, perm, inv
    offsets = _dst_offsets(np.asarray(sorted_el.dst), el.n)
    return sorted_el, perm, inv, offsets


def _dst_offsets(sorted_dst: np.ndarray, n: int) -> np.ndarray:
    """(..., N+1) int32 CSR offsets of a dst-sorted edge index."""
    grid = np.arange(n + 1)
    if sorted_dst.ndim == 1:
        return np.searchsorted(sorted_dst, grid, side="left").astype(np.int32)
    return np.stack([
        np.searchsorted(row, grid, side="left") for row in sorted_dst
    ]).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class EdgeShards:
    """A dst-sorted edge index cut into contiguous capacity-padded shards.

    The device-parallel layout of the edge-partitioned push-sum: shard ``k``
    owns the sorted edge slots ``[k * e_shard, (k + 1) * e_shard)`` — a
    contiguous run of destinations, so each receiver's in-edges live on at
    most two *adjacent* shards. Per-edge state (``rho``/``rho_m``) is
    (E_shard, ...) per device; node state stays replicated and per-step
    receiver partials are combined with a ``psum`` over the mesh ``graph``
    axis (:func:`repro.core.pushsum.sparse_pushsum_step` with
    ``graph_axis=``).

    Shard tails are padded to the common capacity ``e_shard`` with inert
    edges (``valid=False``) that keep ``dst`` equal to the shard's last real
    receiver, so every shard stays dst-sorted and the sorted-segment fast
    path (``indices_are_sorted=True``) remains legal.

    ``boundary`` is the halo index: ``boundary[..., v]`` is True iff
    receiver ``v``'s in-edge run is split across a shard cut — the only
    nodes whose per-step ``recv`` is a genuine multi-shard sum (interior
    nodes add exact ``+0.0`` partials from foreign shards), i.e. the only
    nodes where the combined result can differ from the single-device
    reference by floating-point reduce order.

    Fields carry a leading graph axis (G, S, E_shard) when built from a
    batched :class:`EdgeList`, else (S, E_shard); ``boundary`` is
    correspondingly (G, N) or (N,).
    """

    src: np.ndarray       # (..., S, E_shard) int32
    dst: np.ndarray       # (..., S, E_shard) int32
    valid: np.ndarray     # (..., S, E_shard) bool — False on padding
    n: int                # node count
    e_total: int          # edge count of the (padded) source EdgeList
    boundary: np.ndarray  # (..., N) bool halo index — receivers split by cuts

    @property
    def n_shards(self) -> int:
        return int(self.src.shape[-2])

    @property
    def e_shard(self) -> int:
        """Per-shard edge capacity."""
        return int(self.src.shape[-1])

    @property
    def e_pad(self) -> int:
        """Total padded edge count ``n_shards * e_shard`` — the edge count
        of the bit-exact single-device reference program."""
        return self.n_shards * self.e_shard

    @property
    def is_batched(self) -> bool:
        return self.src.ndim == 3

    def padded_edge_list(self) -> EdgeList:
        """Concatenate the shards back into one (..., E_pad) EdgeList.

        This — not the original pre-partition edge list — is the
        single-device program the sharded run is bit-identical to: the
        per-round (E_pad,) Bernoulli mask each device draws (and windows
        into) indexes the *padded* slots, and jax's counter-based bits have
        no prefix property, so the original unpadded list only matches when
        ``e_pad == E`` or ``drop_prob == 0``.
        """
        flat = lambda a: a.reshape(*a.shape[:-2], -1)
        return EdgeList(src=flat(self.src), dst=flat(self.dst), n=self.n,
                        valid=flat(self.valid))


def partition_edge_list(el: EdgeList, n_shards: int) -> EdgeShards:
    """Cut an edge list into ``n_shards`` dst-contiguous, capacity-padded
    shards for the edge-partitioned (graph-axis) execution mode.

    The index is (re-)sorted by destination, cut at the balanced positions
    ``k * ceil(E / n_shards)`` (cuts may fall mid-segment — the receivers
    split that way are recorded in the ``boundary`` halo index), and each
    shard's tail is padded with inert dst-sorted edges up to the common
    capacity. Batched edge lists partition every topology draw
    independently under one shared capacity, so a whole scenario grid rides
    a single (G, S, E_shard) layout.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")

    def one(src, dst, valid):
        (s_el, _, _) = sort_by_dst(
            EdgeList(src=src, dst=dst, n=el.n, valid=valid))[:3]
        E = s_el.E
        e_shard = max(-(-E // n_shards), 1)
        src_s = np.zeros((n_shards, e_shard), np.int32)
        dst_s = np.zeros((n_shards, e_shard), np.int32)
        val_s = np.zeros((n_shards, e_shard), bool)
        bnd = np.zeros(el.n, bool)
        for k in range(n_shards):
            lo, hi = k * e_shard, min((k + 1) * e_shard, E)
            w = max(hi - lo, 0)
            if w:
                src_s[k, :w] = s_el.src[lo:hi]
                dst_s[k, :w] = s_el.dst[lo:hi]
                val_s[k, :w] = s_el.valid[lo:hi]
                # tail padding keeps the shard's last real dst so the
                # shard stays sorted; src 0 / valid False keep it inert
                dst_s[k, w:] = s_el.dst[hi - 1]
            # a cut strictly inside a receiver's run marks it boundary
            if 0 < lo < E and s_el.dst[lo - 1] == s_el.dst[lo]:
                bnd[s_el.dst[lo]] = True
        return src_s, dst_s, val_s, bnd

    if el.is_batched:
        parts = [one(el.src[g], el.dst[g], el.valid[g])
                 for g in range(el.src.shape[0])]
        src_s, dst_s, val_s, bnd = (np.stack(x) for x in zip(*parts))
    else:
        src_s, dst_s, val_s, bnd = one(el.src, el.dst, el.valid)
    return EdgeShards(src=src_s, dst=dst_s, valid=val_s, n=el.n,
                      e_total=el.E, boundary=bnd)


def random_strongly_connected_edge_list(
    n: int,
    extra_edges_per_node: float,
    rng: np.random.Generator,
    sort: bool = True,
) -> EdgeList:
    """A random strongly connected digraph built directly as an EdgeList.

    The dense :func:`random_strongly_connected` allocates an (N, N) bool
    adjacency — 17 GB at N = 131072 — so the N ~ 1e5 sweeps construct the
    sparse view directly: a random Hamiltonian cycle (strong-connectivity
    backbone) plus ``round(n * extra_edges_per_node)`` uniform extra edges,
    deduplicated and stripped of self-loops, never touching O(N^2) memory.
    ``sort=True`` (default) returns the edges in the sorted-by-dst layout
    the Pallas backend expects; the XLA backend accepts either order.
    """
    perm = rng.permutation(n).astype(np.int64)
    cyc_src = perm
    cyc_dst = np.roll(perm, -1)
    n_extra = int(round(n * extra_edges_per_node))
    ex_src = rng.integers(0, n, size=n_extra)
    ex_dst = rng.integers(0, n, size=n_extra)
    keep = ex_src != ex_dst
    src = np.concatenate([cyc_src, ex_src[keep]])
    dst = np.concatenate([cyc_dst, ex_dst[keep]])
    # dedupe parallel edges via the flat key src * n + dst (int64-safe)
    _, uniq = np.unique(src * np.int64(n) + dst, return_index=True)
    src, dst = src[uniq], dst[uniq]
    el = EdgeList(
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        n=int(n),
        valid=np.ones(src.shape[0], dtype=bool),
    )
    if sort:
        el, _, _ = sort_by_dst(el)
    return el


def hier_edge_list(
    sizes: Sequence[int],
    topology: str = "complete",
    extra_edge_prob: float = 0.3,
    seed: int = 0,
    rep_choice: str = "first",
) -> tuple[EdgeList, np.ndarray]:
    """Hierarchical M-network system built directly as a sparse edge list.

    The dense-free dual of :func:`make_hierarchy`: the same block-diagonal
    topologies ("ring" | "complete" | "ring+"), but emitted as per-block
    edge runs with no (N, N) bool adjacency ever touched — 256 MB at
    N = 16384, 17 GB at N = 131072 — which is what lets the fused
    hierarchical engines (:mod:`repro.core.hps`, :mod:`repro.core.social`)
    run N ~ 1e4-1e5 systems. "ring+" blocks are a random Hamiltonian cycle
    plus ``~extra_edge_prob * n^2`` uniform extra edges (deduplicated) — the
    same cycle-backbone construction as :func:`random_strongly_connected`,
    with a fixed extra-edge count instead of per-pair Bernoulli draws so the
    block never touches an (n, n) array.

    Returns ``(el, rep_mask)``: a dst-sorted :class:`EdgeList` (the layout
    the Pallas consensus kernel expects — rep links to the PS are implicit,
    carried by the (N,) bool representative mask, since the PS fusion is a
    masked reduction, not a set of graph edges) and the mask itself
    (``rep_choice="first"``: first agent of each block, matching
    :func:`make_hierarchy`; ``"random"``: a uniform draw per block).
    """
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    off = 0
    offsets = []
    for sz in sizes:
        idx = np.arange(sz, dtype=np.int64)
        if topology == "ring":
            s, d = idx, (idx + 1) % sz
        elif topology == "complete":
            s = np.repeat(idx, sz)
            d = np.tile(idx, sz)
            keep = s != d
            s, d = s[keep], d[keep]
        elif topology == "ring+":
            perm = rng.permutation(sz).astype(np.int64)
            n_extra = int(round(sz * sz * extra_edge_prob))
            ex_s = rng.integers(0, sz, size=n_extra)
            ex_d = rng.integers(0, sz, size=n_extra)
            keep = ex_s != ex_d
            s = np.concatenate([perm, ex_s[keep]])
            d = np.concatenate([np.roll(perm, -1), ex_d[keep]])
            _, uniq = np.unique(s * np.int64(sz) + d, return_index=True)
            s, d = s[uniq], d[uniq]
        else:
            raise ValueError(f"unknown topology {topology!r}")
        srcs.append(off + s)
        dsts.append(off + d)
        offsets.append(off)
        off += int(sz)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    el = EdgeList(src=src, dst=dst, n=off,
                  valid=np.ones(src.shape[0], dtype=bool))
    el, _, _ = sort_by_dst(el)
    rep_mask = np.zeros(off, dtype=bool)
    if rep_choice == "first":
        reps = np.asarray(offsets)
    elif rep_choice == "random":
        reps = np.asarray([o + rng.integers(sz)
                           for o, sz in zip(offsets, sizes)])
    else:
        raise ValueError(rep_choice)
    rep_mask[reps] = True
    return el, rep_mask


def block_complete_edge_list(
    sizes: Sequence[int],
) -> tuple[EdgeList, np.ndarray]:
    """Hierarchical system of complete sub-networks, built dense-free.

    The ``topology="complete"`` specialization of :func:`hier_edge_list`,
    kept as the established large-N entry point of the social engine.
    """
    return hier_edge_list(sizes, topology="complete")


def edge_masks(masks: np.ndarray, el: EdgeList) -> np.ndarray:
    """Project a dense (T, N, N) link schedule onto the edge list -> (T, E).

    Used by the sparse<->dense equivalence tests; production sweeps draw
    (T, E) Bernoulli masks directly inside the scan and never materialize
    the dense schedule.
    """
    el._require_single("edge_masks()")
    masks = np.asarray(masks)
    return masks[:, el.src, el.dst] & el.valid[None, :]


# ---------------------------------------------------------------------------
# Padded neighbor lists (receiver-major sparse view)
# ---------------------------------------------------------------------------
#
# The Byzantine gossip core (:mod:`repro.core.byzantine`) trims per *receiver*
# over the set of in-neighbor values, so its natural sparse layout is
# receiver-major: one row of in-neighbor indices per agent, padded to the
# maximum in-degree. An :class:`EdgeList` is the edge-major dual used by
# push-sum's per-link state; a :class:`NeighborList` has no per-edge state at
# all — it is a pure gather index consumed by the trim-gather kernel
# (:mod:`repro.kernels.byz_trim`).

@dataclasses.dataclass(frozen=True)
class NeighborList:
    """Padded in-neighbor lists: slot ``(j, k)`` is the k-th in-neighbor of j.

    ``idx[j, k]`` is a *sender* index (``adj[idx[j, k], j]`` is True for
    valid slots); rows are padded to a common ``deg_max`` with ``idx = 0``,
    ``valid = False`` slots, which consumers mask out before trimming.
    Batched/stacked lists (see :func:`stack_neighbor_lists`) carry a leading
    scenario axis on ``idx``/``valid`` so topology draws with different
    degree profiles can ride one ``jax.vmap`` axis.
    """

    idx: np.ndarray    # (N, deg_max) int32 sender per slot, 0 on padding
    valid: np.ndarray  # (N, deg_max) bool — False on padding slots
    n: int             # number of nodes

    @property
    def deg_max(self) -> int:
        """Padded slot count — last axis, correct for single and batched."""
        return int(self.idx.shape[-1])

    @property
    def is_batched(self) -> bool:
        return self.idx.ndim == 3

    def in_degree(self) -> np.ndarray:
        """In-degree per receiver over valid slots (the trim's ``d_j``)."""
        return self.valid.sum(axis=-1).astype(np.int32)


def neighbor_lists(
    topo_or_adj, deg_max: int | None = None, shuffle_seed: int | None = None
) -> NeighborList:
    """Dense (N, N) bool adjacency (or :class:`HierTopology`) -> padded
    in-neighbor lists.

    Slots are emitted in ascending sender order; ``shuffle_seed`` permutes
    each row's valid slots instead (slot order is irrelevant to trimming —
    the equivalence tests exercise both). ``deg_max`` pads beyond the actual
    maximum in-degree, e.g. to align scenario batches.
    """
    adj = topo_or_adj.adj if isinstance(topo_or_adj, HierTopology) else topo_or_adj
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    degs = adj.sum(axis=0)
    dm = int(degs.max()) if degs.size else 0
    if deg_max is not None:
        if deg_max < dm:
            raise ValueError(f"deg_max={deg_max} < actual max in-degree {dm}")
        dm = deg_max
    dm = max(dm, 1)  # keep the slot axis non-empty for edgeless graphs
    rng = None if shuffle_seed is None else np.random.default_rng(shuffle_seed)
    idx = np.zeros((n, dm), dtype=np.int32)
    valid = np.zeros((n, dm), dtype=bool)
    for j in range(n):
        nb = np.nonzero(adj[:, j])[0]
        if rng is not None:
            nb = rng.permutation(nb)
        idx[j, : nb.shape[0]] = nb
        valid[j, : nb.shape[0]] = True
    return NeighborList(idx=idx, valid=valid, n=n)


def stack_neighbor_lists(nls: Sequence[NeighborList]) -> NeighborList:
    """Batch neighbor lists onto a leading scenario axis, padded to the
    widest ``deg_max``; ``n`` must agree across entries."""
    n = nls[0].n
    if any(nl.n != n for nl in nls):
        raise ValueError("all neighbor lists must have the same node count")
    dm = max(nl.deg_max for nl in nls)
    idx = np.zeros((len(nls), n, dm), dtype=np.int32)
    valid = np.zeros((len(nls), n, dm), dtype=bool)
    for g, nl in enumerate(nls):
        idx[g, :, : nl.deg_max] = nl.idx
        valid[g, :, : nl.deg_max] = nl.valid
    return NeighborList(idx=idx, valid=valid, n=n)


# ---------------------------------------------------------------------------
# Packet-drop schedules
# ---------------------------------------------------------------------------

def link_schedule(
    adj: np.ndarray,
    T: int,
    drop_prob: float,
    B: int,
    seed: int = 0,
) -> np.ndarray:
    """(T, N, N) bool operational-link masks with guaranteed B-connectivity.

    Each existing link drops packets i.i.d. with ``drop_prob``, but is forced
    operational at every ``t`` with ``t % B == B - 1`` so the paper's fault
    model ("operational at least once every B iterations") holds exactly.
    """
    rng = np.random.default_rng(seed)
    up = rng.random((T, *adj.shape)) >= drop_prob
    t_idx = np.arange(T) % B == B - 1
    up[t_idx] = True
    return up & adj[None, :, :]
