"""Repo-wide mixed-precision storage policy.

Every fused engine is memory-bandwidth-bound: per-step cost is dominated by
streaming the (E, d) relay state and the (N, d) node state out of HBM
(see ``repro.statics.memory`` / ``repro.analysis.roofline``). The paper's
algorithms only need full precision in the *accumulations* — push-sum
mass/ratio sums, the KL/dual-averaging log-space updates, the trimmed-mean
partial sums — so storage can drop to bf16 while every reduction stays
fp32, roughly halving bytes moved on the hot paths.

:class:`Policy` is the single knob: a hashable NamedTuple of *dtype names*
(strings, so it can ride ``jax.jit`` static arguments and LRU-cache keys
without canonicalization surprises) threaded as ``policy=`` through

* :func:`repro.core.pushsum.sparse_pushsum_step` and the scan cores
  (``_hps_scan_core`` / ``_social_scan_core`` / byzantine ``_scan_core``),
* the kernel ops/refs (``pushsum_edge`` / ``byz_trim`` / ``social_innov``)
  — casts happen at kernel block boundaries, accumulators inside stay
  ``accum`` (fp32),
* the batched sweeps (:mod:`repro.core.sweeps` ``run_*_{sweep,grid}``).

The contract:

* ``storage`` — dtype of every *persistent* value: scan carries, the
  (E, d) relay latches, the (N, d) node state, wire payloads. This is the
  bandwidth knob.
* ``compute`` — dtype elementwise work runs in. Values are upcast
  storage -> compute at block entry.
* ``accum`` — dtype of reductions (segment-sums, trimmed-pool sums, psum
  halos' integration). Never below fp32.

The default :data:`FP32` policy is all-fp32 and **bit-identical** to the
pre-policy engines: ``convert_element_type`` to the same dtype is a traced
no-op in JAX, so the emitted program is unchanged (regression-tested per
engine in ``tests/test_precision_policy.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Policy",
    "FP32",
    "BF16",
    "resolve_policy",
]

# dtype names accepted for each slot; accum is deliberately locked to
# full-precision floats (the whole point of the split is that reductions
# never degrade)
_STORAGE_DTYPES = ("float32", "bfloat16", "float16")
_COMPUTE_DTYPES = ("float32", "bfloat16", "float16")
_ACCUM_DTYPES = ("float32", "float64")


class Policy(NamedTuple):
    """Storage/compute/accumulation dtype split, as dtype *names*.

    String fields keep the tuple hashable and stable as a ``jax.jit``
    static argument / LRU-cache key component; use the ``*_dtype``
    properties for the actual ``jnp`` dtypes at trace time.
    """

    storage: str = "float32"
    compute: str = "float32"
    accum: str = "float32"

    @property
    def storage_dtype(self):
        return jnp.dtype(self.storage)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute)

    @property
    def accum_dtype(self):
        return jnp.dtype(self.accum)

    @property
    def storage_bytes(self) -> int:
        """Bytes per element of the storage dtype — what the analytic
        memory budgets (:mod:`repro.statics.memory`) charge per streamed
        state element."""
        return int(np.dtype(self.storage).itemsize)

    @property
    def is_default(self) -> bool:
        """True iff this policy emits the byte-identical pre-policy
        program (every cast is a same-dtype no-op)."""
        return self == FP32

    def validate(self) -> "Policy":
        if self.storage not in _STORAGE_DTYPES:
            raise ValueError(
                f"policy storage dtype {self.storage!r} not in "
                f"{_STORAGE_DTYPES}")
        if self.compute not in _COMPUTE_DTYPES:
            raise ValueError(
                f"policy compute dtype {self.compute!r} not in "
                f"{_COMPUTE_DTYPES}")
        if self.accum not in _ACCUM_DTYPES:
            raise ValueError(
                f"policy accum dtype {self.accum!r} must be a "
                f"full-precision float {_ACCUM_DTYPES} — reductions never "
                "run below fp32")
        return self

    def tag(self) -> str:
        """Short name for bench rows / budget tables: ``fp32``, ``bf16``,
        or the explicit triple for anything non-standard."""
        for name, pol in _NAMED.items():
            if self == pol:
                return name
        return f"{self.storage}/{self.compute}/{self.accum}"


FP32 = Policy()
BF16 = Policy(storage="bfloat16")

_NAMED = {"fp32": FP32, "bf16": BF16}


def resolve_policy(policy) -> Policy:
    """Normalize ``None`` (default fp32), a name (``"fp32"``/``"bf16"``),
    or a :class:`Policy` to a validated :class:`Policy`."""
    if policy is None:
        return FP32
    if isinstance(policy, str):
        try:
            return _NAMED[policy]
        except KeyError:
            raise ValueError(
                f"unknown policy name {policy!r}; choose from "
                f"{sorted(_NAMED)} or pass a Policy(...)") from None
    if isinstance(policy, Policy):
        return policy.validate()
    raise TypeError(
        f"policy must be None, a name, or a Policy; got {type(policy)!r}")
