"""ExecutionPlan — one object for every ``run_*`` entrypoint's execution knobs.

The engine entrypoints used to thread 10-14 loose keyword arguments each
(``backend=``, ``policy=``, ``faults=``, ``mesh=``, ``data_axis=``,
``graph_axis=``, ``graph_shards=``, ``store=``, ``halo=``, ``dst_sorted=``)
with drifting defaults across the four ``run_*_{sweep,grid}`` families.
:class:`ExecutionPlan` consolidates all of them into one frozen dataclass:

    plan = ExecutionPlan(backend="xla", policy="bf16",
                         faults=gilbert_elliott_model(8.0, 0.5),
                         async_=make_async_model(wake_prob=0.5, staleness=4))
    res = run_social_sweep(model, cfg, T, seeds=seeds, plan=plan)

Every field is an *execution* knob — how the run lowers, shards, stores and
degrades — never a *science* knob (``drop_probs``, ``gammas``, ``seeds``,
``T``, ``B``, ``F`` stay loose parameters of each entrypoint). The async
execution mode (:mod:`repro.core.asyncrony`) arrives exclusively as the
``async_`` field: it was the forcing function for this consolidation and
is deliberately NOT accepted as a loose kwarg.

Legacy loose kwargs still work through each entrypoint's ``**legacy``
catch-all: :func:`resolve_plan` folds them into a plan with identical
semantics (bit-identical results) and emits a :class:`DeprecationWarning`
once per entrypoint per process. Passing ``plan=`` together with loose
kwargs is an error — there is exactly one source of truth per call.

The statics lint (:mod:`repro.statics.signatures`) enforces the contract
from the other side: no ``run_*`` entrypoint may re-introduce a *named*
parameter covered by :class:`ExecutionPlan`.

Field defaults and meaning
--------------------------
``backend``       ``"auto"`` | ``"xla"`` | ``"pallas"`` — per-round kernel
                  lowering (``"auto"`` = Pallas on TPU, XLA elsewhere).
``policy``        precision policy name / :class:`repro.core.precision.Policy`
                  / ``None`` (dtype-transparent fp32).
``faults``        :class:`repro.core.faults.FaultModel` or a sequence of
                  them (grid engines cross a fault-minor scenario axis).
``mesh``          ``jax.sharding.Mesh`` for shard_map'd sweeps.
``data_axis``     mesh axis the scenario batch shards over.
``graph_axis``    mesh axis the edge partition shards over (2-D sweeps).
``graph_shards``  edge-partition count (push-sum sweep only).
``store``         what the scan materializes; ``None`` keeps each engine's
                  own default (``"trajectory"`` / ``"log_ratio"`` /
                  ``"gap"`` / ``"decisions"``).
``async_``        :class:`repro.core.asyncrony.AsyncModel` or a sequence of
                  them (grid engines cross an async-minor scenario axis);
                  ``None`` = synchronous rounds, the bit-identical
                  pre-async program.
``halo``          graph-axis combine variant of the edge-partitioned mode.
``dst_sorted``    asserts dst-sorted edge indices (segment-sum sort hint).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

__all__ = [
    "ExecutionPlan",
    "resolve_plan",
    "PLAN_FIELDS",
    "LEGACY_PLAN_KWARGS",
]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Frozen bundle of execution knobs shared by every ``run_*`` entry."""

    backend: str = "auto"
    policy: Any = None
    faults: Any = None
    mesh: Any = None
    data_axis: str = "data"
    graph_axis: str = "graph"
    graph_shards: int | None = None
    store: str | None = None
    async_: Any = None
    halo: str = "psum"
    dst_sorted: bool = False

    def replace(self, **kw) -> "ExecutionPlan":
        return dataclasses.replace(self, **kw)


#: Every field name of :class:`ExecutionPlan` — the set the statics
#: signature linter bans as loose parameters on ``run_*`` entrypoints.
PLAN_FIELDS = tuple(f.name for f in dataclasses.fields(ExecutionPlan))

#: The loose kwargs the deprecation shim still accepts. ``async_`` is
#: excluded on purpose: the async mode is new API and only ever arrives
#: as a plan field, never as loose kwarg number 15.
LEGACY_PLAN_KWARGS = frozenset(PLAN_FIELDS) - {"async_"}

_DEFAULT = ExecutionPlan()

# Entrypoints that have already emitted their deprecation warning this
# process; the shim warns once per entry, not once per call. Tests reset
# this set directly.
_warned: set[str] = set()


def _differs_from_default(name: str, value) -> bool:
    dflt = getattr(_DEFAULT, name)
    if dflt is None:
        # identity, not ==: fault/async models are array pytrees whose
        # __eq__ would trace elementwise
        return value is not None
    return value != dflt


def resolve_plan(
    plan: ExecutionPlan | None = None,
    *,
    _entry: str,
    _supports: tuple[str, ...] | None = None,
    **legacy,
) -> ExecutionPlan:
    """Normalize one entrypoint call's execution knobs into a plan.

    ``legacy`` is the entrypoint's ``**legacy`` catch-all. Recognized keys
    (:data:`LEGACY_PLAN_KWARGS`) fold into a fresh plan with a one-time
    :class:`DeprecationWarning` per ``_entry``; unknown keys raise
    ``TypeError`` exactly like a normal unexpected keyword argument, and
    combining ``plan=`` with loose kwargs raises — one source of truth.

    ``_supports`` names the plan fields this entrypoint honors; any OTHER
    field set to a non-default value raises ``ValueError`` instead of
    being silently ignored (the drifting-defaults failure mode this API
    replaces).
    """
    if legacy:
        unknown = sorted(set(legacy) - LEGACY_PLAN_KWARGS)
        if unknown:
            hint = ""
            if "async_" in unknown or "async" in unknown:
                hint = (
                    " (the async mode is plan-only: pass "
                    "plan=ExecutionPlan(async_=...))"
                )
            raise TypeError(
                f"{_entry}() got unexpected keyword argument(s) "
                f"{unknown}{hint}"
            )
        if plan is not None:
            raise TypeError(
                f"{_entry}(): pass execution options via plan= OR the "
                f"legacy loose kwargs, not both (got plan= together with "
                f"{sorted(legacy)})"
            )
        if _entry not in _warned:
            _warned.add(_entry)
            warnings.warn(
                f"{_entry}(): loose execution kwargs "
                f"({', '.join(sorted(legacy))}) are deprecated; pass "
                f"plan=ExecutionPlan(...) instead (bit-identical results)",
                DeprecationWarning,
                stacklevel=3,
            )
        plan = ExecutionPlan(**legacy)
    elif plan is None:
        plan = _DEFAULT
    if _supports is not None:
        for name in PLAN_FIELDS:
            if name in _supports:
                continue
            if _differs_from_default(name, getattr(plan, name)):
                raise ValueError(
                    f"{_entry}() does not support the plan field "
                    f"{name!r} (supported: {sorted(_supports)})"
                )
    return plan
