"""Hierarchical Push-Sum (HPS) — Algorithm 1 of the paper.

M sub-networks each run fast robust push-sum in parallel (block-diagonal
adjacency); every ``Gamma`` iterations each network's *designated
representative* pushes half of its (value, mass) to the parameter server,
which averages and pushes back:

    z_rep <- 1/2 z_rep + 1/(2M) sum_i z_{i0}
    m_rep <- 1/2 m_rep + 1/(2M) sum_i m_{i0}

i.e. the doubly-stochastic *hierarchical fusion matrix* F with
``F[j0,j0] = (M+1)/2M`` and ``F[j0,j0'] = 1/2M`` (Eq. (1): M[t] = F Mbar[t]).

Theorem 1: with ``Gamma = B * D*``, the consensus error decays as
``gamma^(t / 2Gamma)`` with ``gamma = 1 - (1/4M^2)(min_i beta_i)^(2 D* B)``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import HierTopology, link_schedule
from .pushsum import PushSumState, init_state, pushsum_step, ratios

__all__ = ["HPSConfig", "hps_fusion", "hps_step", "run_hps", "theorem1_bound"]


@dataclasses.dataclass(frozen=True)
class HPSConfig:
    """Static configuration of an HPS run."""

    topo: HierTopology
    gamma_period: int          # Γ — PS fusion every Γ iterations
    B: int = 1                 # link-reliability window
    drop_prob: float = 0.0     # packet-drop probability per link per round

    def rep_mask(self) -> jnp.ndarray:
        return jnp.asarray(self.topo.rep_mask())

    def adj(self) -> jnp.ndarray:
        return jnp.asarray(self.topo.adj)

    def edge_index(self):
        """The topology's dst-sorted sparse :class:`~repro.core.graphs.EdgeList`
        — the one layout both the XLA and the fused-Pallas consensus
        lowerings consume (:mod:`repro.kernels.pushsum_edge` streams
        contiguous per-receiver runs)."""
        from .graphs import edge_list, sort_by_dst

        el, _, _ = sort_by_dst(edge_list(self.topo.adj))
        return el


def hps_fusion(
    z: jnp.ndarray, m: jnp.ndarray, rep_mask: jnp.ndarray, M
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the hierarchical fusion matrix F to (z, m) at the reps.

    Non-representative agents are untouched; this is exactly lines 13-21 of
    Algorithm 1 (each rep sends half, PS averages the halves and pushes back).
    ``M`` may be a Python int or a traced scalar — batched sweeps whose
    scenarios differ only in arrays keep one traced program either way.
    """
    repf = rep_mask.astype(z.dtype)
    pooled_z = (z * repf[:, None]).sum(axis=0) / (2.0 * M)   # (d,)
    pooled_m = (m * repf).sum() / (2.0 * M)
    z_new = jnp.where(rep_mask[:, None], 0.5 * z + pooled_z[None, :], z)
    m_new = jnp.where(rep_mask, 0.5 * m + pooled_m, m)
    return z_new, m_new


def hps_step(
    state: PushSumState,
    mask: jnp.ndarray,
    adj: jnp.ndarray,
    rep_mask: jnp.ndarray,
    M: int,
    do_fusion: jnp.ndarray,  # scalar bool — t % Γ == 0
) -> PushSumState:
    """One HPS iteration: robust push-sum + (conditionally) PS fusion."""
    st = pushsum_step(state, mask, adj)
    z_f, m_f = hps_fusion(st.z, st.m, rep_mask, M)
    z = jnp.where(do_fusion, z_f, st.z)
    m = jnp.where(do_fusion, m_f, st.m)
    return st._replace(z=z, m=m)


def run_hps(
    w: jnp.ndarray,
    cfg: HPSConfig,
    T: int,
    seed: int = 0,
) -> tuple[PushSumState, jnp.ndarray]:
    """Run HPS for T iterations. Returns final state + per-step ratios (T, N, d)."""
    adj = cfg.adj()
    rep_mask = cfg.rep_mask()
    masks = jnp.asarray(
        link_schedule(cfg.topo.adj, T, cfg.drop_prob, cfg.B, seed=seed)
    )
    fuse = jnp.arange(1, T + 1) % cfg.gamma_period == 0
    state0 = init_state(jnp.asarray(w))

    def body(state, xs):
        mask, do_fusion = xs
        new = hps_step(state, mask, adj, rep_mask, cfg.topo.M, do_fusion)
        return new, ratios(new)

    final, traj = jax.lax.scan(body, state0, (masks, fuse))
    return final, traj


def theorem1_bound(cfg: HPSConfig, w: np.ndarray, t: int) -> float:
    """The RHS of Theorem 1 at iteration t (loose by the paper's own Remark 3)."""
    topo = cfg.topo
    M = topo.M
    d_star = topo.d_star()
    beta_min = topo.min_beta()
    contraction = beta_min ** (2 * d_star * cfg.B)
    gamma = 1.0 - contraction / (4.0 * M * M)
    two_gamma = 2 * cfg.gamma_period
    norm_sum = float(np.linalg.norm(np.asarray(w), axis=1).sum())
    lead = 4.0 * M * M * norm_sum / (contraction * topo.N)
    return lead * gamma ** max(t // two_gamma - 1, 0)
