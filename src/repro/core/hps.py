"""Hierarchical Push-Sum (HPS) — Algorithm 1 of the paper, fused and batched.

M sub-networks each run fast robust push-sum in parallel (block-diagonal
adjacency); every ``Gamma`` iterations each network's *designated
representative* pushes half of its (value, mass) to the parameter server,
which averages and pushes back:

    z_rep <- 1/2 z_rep + 1/(2M) sum_i z_{i0}
    m_rep <- 1/2 m_rep + 1/(2M) sum_i m_{i0}

i.e. the doubly-stochastic *hierarchical fusion matrix* F with
``F[j0,j0] = (M+1)/2M`` and ``F[j0,j0'] = 1/2M`` (Eq. (1): M[t] = F Mbar[t]).

Theorem 1: with ``Gamma = B * D*``, the consensus error decays as
``gamma^(t / 2Gamma)`` with ``gamma = 1 - (1/4M^2)(min_i beta_i)^(2 D* B)``.

The fused, batched engine
-------------------------
The production path mirrors :mod:`repro.core.social`'s architecture: the
consensus half of every iteration runs on the sparse edge-list push-sum core
(:mod:`repro.core.pushsum`) behind the repo-wide
``backend="auto"|"xla"|"pallas"`` switch (delivery + integration through
:mod:`repro.kernels.pushsum_edge` on the dst-sorted edge index), per-round
(E,) operational masks are Bernoulli draws *inside* the scan (no (T, N, N)
``link_schedule`` tensor is ever materialized), and every loop invariant —
the out-degree share factors, the consensus target — is hoisted out of the
scan. All per-scenario inputs live in an :class:`HPSRuntime` of arrays
(``drop_prob`` / ``gamma`` / ``B`` / ``M`` are traced scalars), so a batch
of compatible scenarios — even with *different sub-network counts M* —
stacks leaf-wise and rides one ``jax.vmap`` axis
(:func:`repro.core.sweeps.run_hps_grid`).

``store`` selects what the scan materializes — ``"trajectory"`` the full
(T, N, d) ratio history, ``"gap"`` the in-scan-reduced (T,) worst consensus
error ``max_{j,k} |z_j/m_j - mean(w)|`` (Theorem 1's LHS) plus the final
ratios, and ``"final"`` final ratios only — so Theorem-1 curves at long
horizons never carry O(T N d) out of the scan.

PS-side resilient fusion
------------------------
:func:`hps_fusion` generalizes the plain averaging rule to a masked-pool
reduction: ``F=0`` is the exact Algorithm-1 fusion above (masked mean, no
sort), while ``F>0`` drops the F largest and F smallest representative
contributions per coordinate before averaging — the Byzantine-resilient
gossiping-type rule of the Su & Vaidya PS-fusion lineage — through
:func:`ps_trimmed_pool`, the same lowering Algorithm 2's parameter-server
step (:func:`repro.core.byzantine._fusion`) reduces through. The trimmed
rule is resilient, not average-preserving: it trades the exact
doubly-stochastic mass invariant for outlier rejection.

PRNG stream: the per-round link-mask draw folds in ``hps_stream_fold(t) =
~t`` — the bitwise-not domain, which bitcasts to the top of the uint32
range and is disjoint from the social engine's ``2t + s`` and the Byzantine
engine's ``3t + s`` fold-in domains for any horizon ``T < 2^31 / 3``. The
seed-era ``run_hps`` derived its schedule from ``seed`` alone on the plain
``t`` domain, which aliased the HPS mask stream with the social-learning
mask stream (and the Byzantine signal stream) whenever base seeds matched.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .asyncrony import (
    AsyncModel,
    init_async_buffer,
    is_degenerate_async,
    wake_mask,
)
from .faults import (
    ENGINE_HPS,
    FaultModel,
    edge_uniforms,
    faulty_edge_mask,
    init_fault_state,
    ps_alive,
    step_faults,
)
from .graphs import EdgeList, HierTopology
from .plan import ExecutionPlan, resolve_plan
from .precision import Policy, resolve_policy
from repro.statics.contracts import contract as statics_contract
from repro.statics.retrace import register_cache as register_statics_cache
from .pushsum import (
    PushSumState,
    SparsePushSumState,
    _out_degree,
    init_sparse_state,
    init_state,
    pushsum_step,
    ratios,
    shard_edge_mask,
    sparse_ratios,
    sparse_pushsum_step,
    step_edge_mask,
)

__all__ = [
    "HPSConfig",
    "HPSResult",
    "HPSRuntime",
    "HPS_STORES",
    "hps_stream_fold",
    "ps_trimmed_pool",
    "hps_fusion",
    "hps_step",
    "make_hps_runtime",
    "hps_runtime_from_edge_list",
    "run_hps",
    "run_hps_runtime",
    "run_hps_dense",
    "theorem1_bound",
]

HPS_STORES = ("trajectory", "gap", "final")


def hps_stream_fold(t):
    """Fold-in value of the HPS link-mask stream at iteration ``t``.

    ``~t`` bitcasts to ``2^32 - 1 - t`` in the uint32 fold-in space, so the
    HPS mask stream lives at the top of the domain — disjoint from the
    social engine's ``t * 2 + s`` and the Byzantine engine's ``t * 3 + s``
    streams for any realistic horizon, even when every engine roots its
    base key at the same seed. (The seed scheme folded plain ``t``, which
    collided with the social link-mask stream at every even value.)
    """
    if isinstance(t, int):
        # ~t is negative; fold_in bitcasts int32 but rejects negative
        # PYTHON ints (no dtype to reinterpret), so pin the width here
        t = np.int32(t)
    return ~t


@dataclasses.dataclass(frozen=True)
class HPSConfig:
    """Static configuration of an HPS run."""

    topo: HierTopology
    gamma_period: int          # Γ — PS fusion every Γ iterations
    B: int = 1                 # link-reliability window
    drop_prob: float = 0.0     # packet-drop probability per link per round

    def rep_mask(self) -> jnp.ndarray:
        return jnp.asarray(self.topo.rep_mask())

    def adj(self) -> jnp.ndarray:
        return jnp.asarray(self.topo.adj)

    def edge_index(self):
        """The topology's dst-sorted sparse :class:`~repro.core.graphs.EdgeList`
        — the one layout both the XLA and the fused-Pallas consensus
        lowerings consume (:mod:`repro.kernels.pushsum_edge` streams
        contiguous per-receiver runs)."""
        from .graphs import edge_list, sort_by_dst

        el, _, _ = sort_by_dst(edge_list(self.topo.adj))
        return el


# ---------------------------------------------------------------------------
# PS-side fusion: one masked-pool reduction for Algorithms 1 and 2
# ---------------------------------------------------------------------------

def ps_trimmed_pool(
    pool: jnp.ndarray,    # (R, *coord) candidate values at the PS
    valid: jnp.ndarray,   # (R,) bool — pool membership mask
    F,                    # trim count; Python int or traced scalar
    *,
    accum_dtype: str | None = None,
) -> jnp.ndarray:
    """Trimmed mean over the parameter server's candidate pool, (*coord,).

    Per scalar coordinate independently (the paper's "collection of scalar
    dynamics"): drop invalid slots, drop the F largest and F smallest of
    the rest, average the survivors. This is THE PS-side resilient
    reduction — :func:`hps_fusion` (Algorithm 1, ``F > 0``) and
    :func:`repro.core.byzantine._fusion` (Algorithm 2 lines 10-22) both
    lower through it, so the two fusion rules share one implementation.

    Routed through :func:`repro.kernels.byz_trim.trim_gather_ref` — the
    sort-based XLA lowering, which accepts a *traced* F — as a single
    virtual receiver whose "neighbors" are the pool slots. The pool is
    O(n_reps), far below the streaming Pallas kernel's profitable range, so
    no backend switch is exposed here.
    """
    from repro.kernels.byz_trim import trim_gather_ref

    r = pool.reshape(pool.shape[0], -1)                   # (R, P)
    tsum, kept = trim_gather_ref(
        r,
        jnp.arange(pool.shape[0], dtype=jnp.int32)[None, :],   # (1, R)
        valid[None, :],
        jnp.zeros((1,) + r.shape, r.dtype),               # no substitution
        jnp.zeros((1, pool.shape[0]), bool),
        F,
        # the single index row IS an arange — the one call site where the
        # sorted-gather promise is globally true (general neighbor lists
        # in the Byzantine core are not row-major monotone and keep False)
        indices_sorted=True,
        accum_dtype=accum_dtype,
    )
    return (tsum[0] / jnp.maximum(kept[0], 1.0)).reshape(pool.shape[1:])


def hps_fusion(
    z: jnp.ndarray, m: jnp.ndarray, rep_mask: jnp.ndarray, M, F=0,
    *, accum_dtype: str | None = None, live: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the hierarchical fusion matrix F to (z, m) at the reps.

    Non-representative agents are untouched; with ``F=0`` this is exactly
    lines 13-21 of Algorithm 1 (each rep sends half, PS averages the halves
    and pushes back). ``M`` may be a Python int or a traced scalar —
    batched sweeps whose scenarios differ only in arrays keep one traced
    program either way, and grids may even batch *different M* values.

    ``F > 0`` swaps the plain average for :func:`ps_trimmed_pool`'s trimmed
    rep-pool mean — the Byzantine-resilient gossiping-type PS rule. The
    trimmed rule needs ``M >= 2F + 1`` surviving reps and is not
    average-preserving (module docstring).

    ``accum_dtype`` names the dtype the pooled sums run in (the precision
    policy's accum slot); the returned (z, m) stay in the input dtype —
    persistent values keep the storage dtype. ``None`` keeps the input
    dtype, the pre-policy program.

    ``live`` (an (N,) bool churn-liveness mask, see :mod:`repro.core.faults`)
    degrades the fusion gracefully: only live representatives contribute to
    the PS pool and only live representatives adopt the result, with the
    fusion weight ``1/(2M)`` replaced by ``1/(2 * live-rep-count)`` — each
    live rep still keeps half and receives the pool mean of the halves, so
    the fusion stays mass-preserving over the *live* representative set
    while dead reps are untouched (their state is frozen elsewhere).
    ``live=None`` keeps the exact static-M pre-fault program.
    """
    ad = z.dtype if accum_dtype is None else jnp.dtype(accum_dtype)
    eff = rep_mask if live is None else rep_mask & live
    repf = eff.astype(ad)
    z_a = z.astype(ad)
    m_a = m.astype(ad)
    if isinstance(F, int) and F == 0:
        if live is None:
            denom = 2.0 * M
        else:
            # at least one contributor to avoid 0/0 when every rep is dead
            # (then no rep adopts anyway — eff is all-False)
            denom = 2.0 * jnp.maximum(repf.sum(), 1.0)
        pooled_z = (z_a * repf[:, None]).sum(axis=0) / denom       # (d,)
        pooled_m = (m_a * repf).sum() / denom
    else:
        cat = jnp.concatenate([z, m[:, None]], axis=1)             # (N, d+1)
        pooled = 0.5 * ps_trimmed_pool(cat, eff, F,
                                       accum_dtype=accum_dtype)    # (d+1,)
        pooled_z, pooled_m = pooled[:-1], pooled[-1]
    z_new = jnp.where(eff[:, None],
                      0.5 * z_a + pooled_z[None, :], z_a).astype(z.dtype)
    m_new = jnp.where(eff, 0.5 * m_a + pooled_m, m_a).astype(m.dtype)
    return z_new, m_new


def hps_step(
    state: PushSumState,
    mask: jnp.ndarray,
    adj: jnp.ndarray,
    rep_mask: jnp.ndarray,
    M: int,
    do_fusion: jnp.ndarray,  # scalar bool — t % Γ == 0
) -> PushSumState:
    """One dense HPS iteration: robust push-sum + (conditionally) PS fusion.

    The (N, N)-mask reference step consumed by :func:`run_hps_dense`; the
    production engine runs :func:`_hps_scan_core` on edge-list state.
    """
    st = pushsum_step(state, mask, adj)
    z_f, m_f = hps_fusion(st.z, st.m, rep_mask, M)
    z = jnp.where(do_fusion, z_f, st.z)
    m = jnp.where(do_fusion, m_f, st.m)
    return st._replace(z=z, m=m)


# ---------------------------------------------------------------------------
# Runtime: the per-scenario arrays of one (topology, M, Γ, drop, B) config
# ---------------------------------------------------------------------------

class HPSResult(NamedTuple):
    """Engine output; shapes depend on the ``store`` option.

    ``store="trajectory"`` (default): ``ratio`` (T, N, d) per-step z/m
    estimates, ``gap`` the (T,) worst consensus error (derived post-scan).
    ``store="gap"``: ``ratio`` is the final (N, d) only and ``gap`` the
    (T,) curve ``max_{j,k} |ratio - mean(w)|`` reduced inside the scan
    (Theorem 1's LHS without the O(T N d) history).
    ``store="final"``: final ``ratio`` (N, d) and the final scalar ``gap``.
    """

    ratio: jnp.ndarray
    final_state: SparsePushSumState
    gap: jnp.ndarray


class HPSRuntime(NamedTuple):
    """Everything the scan body reads that can vary per scenario.

    All fields are arrays, so a batch of *compatible* scenarios — same N,
    edge lists padded to a common E — stacks leaf-wise onto one leading
    scenario axis and rides a single ``jax.vmap``
    (:func:`repro.core.sweeps.run_hps_grid`). ``drop_prob``, ``gamma``,
    ``B`` and ``M`` are scalars here precisely so they can be traced
    per-scenario: the fusion schedule ``(t + 1) % gamma == 0``, the
    B-window forced delivery, and the 1/2M fusion weight are all computed
    in-scan from the traced values, keeping ONE compiled program for a
    whole (topology x M x Γ x drop) grid — sub-network count included.
    """

    src: jnp.ndarray        # (E,) int32 sender per edge (dst-sorted layout)
    dst: jnp.ndarray        # (E,) int32 receiver per edge
    valid: jnp.ndarray      # (E,) bool — False on padding edges
    rep_mask: jnp.ndarray   # (N,) bool — designated representatives
    drop_prob: jnp.ndarray  # () f32 per-link packet-drop probability
    gamma: jnp.ndarray      # () i32 PS fusion period
    B: jnp.ndarray          # () i32 link-reliability window
    M: jnp.ndarray          # () i32 sub-network count (fusion weight 1/2M)


def hps_runtime_from_edge_list(
    el: EdgeList,
    rep_mask: np.ndarray,
    *,
    drop_prob: float,
    gamma_period: int,
    B: int = 1,
    M: int | None = None,
    e_max: int | None = None,
) -> HPSRuntime:
    """Build an :class:`HPSRuntime` directly from a sparse edge index.

    The dense-free entry point for large-N systems (pair with
    :func:`repro.core.graphs.hier_edge_list` — no (N, N) adjacency is ever
    touched). ``el`` should be dst-sorted (:func:`graphs.sort_by_dst`) for
    the Pallas consensus backend; the XLA backend accepts any order.
    ``M`` defaults to the representative count; ``e_max`` pads the edge
    axis (inert ``valid=False`` edges with ``dst = N - 1``, which keeps a
    sorted layout sorted) so scenario batches over different topologies can
    share one shape.
    """
    if el.is_batched:
        raise ValueError("pass one topology draw; batching happens leaf-wise")
    rep_mask = np.asarray(rep_mask, bool)
    src, dst, valid = el.src, el.dst, el.valid
    if e_max is not None:
        pad = e_max - el.E
        if pad < 0:
            raise ValueError(f"e_max={e_max} < edge count {el.E}")
        src = np.concatenate([src, np.zeros(pad, np.int32)])
        dst = np.concatenate([dst, np.full(pad, el.n - 1, np.int32)])
        valid = np.concatenate([valid, np.zeros(pad, bool)])
    return HPSRuntime(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        valid=jnp.asarray(valid, bool),
        rep_mask=jnp.asarray(rep_mask),
        drop_prob=jnp.asarray(drop_prob, jnp.float32),
        gamma=jnp.asarray(gamma_period, jnp.int32),
        B=jnp.asarray(B, jnp.int32),
        M=jnp.asarray(
            int(rep_mask.sum()) if M is None else M, jnp.int32
        ),
    )


def make_hps_runtime(cfg: HPSConfig, e_max: int | None = None) -> HPSRuntime:
    """Host-side setup of one :class:`HPSConfig` scenario."""
    return hps_runtime_from_edge_list(
        cfg.edge_index(),
        cfg.topo.rep_mask(),
        drop_prob=cfg.drop_prob,
        gamma_period=cfg.gamma_period,
        B=cfg.B,
        M=cfg.topo.M,
        e_max=e_max,
    )


# ---------------------------------------------------------------------------
# The shared scan core
# ---------------------------------------------------------------------------

@statics_contract(
    name="hps",
    forbidden={
        "*": (("N", "N"),),
        "final": (("T", "*"),),
        "gap": (("T", "*"),),
    },
    # One link-mask stream at the TOP of the uint32 fold-in space (~t):
    # one experiment seed may root this engine together with the social or
    # Byzantine engines (the PR-5 aliasing bug class), so the analyzer must
    # also prove cross-engine disjointness against both.
    streams=(("link", hps_stream_fold),),
    shares_seed_with=("social", "byzantine"),
    caches=("hps.compiled", "hps.runtime", "hps.jit"),
)
def _hps_scan_core(
    key: jnp.ndarray,
    rt: HPSRuntime,
    w: jnp.ndarray,        # (N, d) initial values
    *,
    T: int,
    store: str,
    backend: str,
    F: int = 0,
    graph_axis: str | None = None,
    n_shards: int = 1,
    policy: Policy | str | None = None,
    dst_sorted: bool = False,
    halo: str = "psum",
    faults: FaultModel | None = None,
    async_: AsyncModel | None = None,
) -> tuple[SparsePushSumState, tuple[jnp.ndarray, jnp.ndarray]]:
    """Algorithm 1's scan, parameterized over the per-scenario runtime
    arrays (vmappable for batched grids).

    Returns ``(final_state, (ratio, gap))`` with the store-dependent shapes
    of :class:`HPSResult`.

    With ``graph_axis``/``n_shards`` the consensus half runs
    edge-partitioned: the runtime's edge arrays carry this device's
    (E_shard,) slice of a :func:`graphs.partition_edge_list` layout, link
    masks are this shard's window of the full padded draw
    (:func:`pushsum.shard_edge_mask` — same ``hps_stream_fold`` domain),
    and out-degrees / receiver partials / the mass bookkeeping are psum'd
    over the mesh graph axis (``halo="scatter"`` opts into the
    reduce-scatter + quantize + all-gather combine). Node state — and hence
    the PS fusion half, which only touches (N, d) — stays replicated, so
    the fusion step needs no changes at all.

    ``policy`` (:mod:`repro.core.precision`) keeps every persistent scan
    value in the storage dtype with fusion pools and receiver reductions in
    the accum dtype; the emitted ratio/gap diagnostics stay fp32.
    ``dst_sorted=True`` asserts the runtime's edge index is dst-sorted
    (true for ``HPSConfig.edge_index()`` products). All kwargs here are
    trace statics: thread them through ``static_argnames`` alongside
    ``backend`` — except ``faults``, a TRACED
    :class:`repro.core.faults.FaultModel` pytree that rides the vmap
    scenario axis. With faults on, the link draw generalizes to the
    Gilbert-Elliott burst chain, churn masks edges and freezes dead
    agents, and fusion rounds additionally gate on the FAULT_PS crash
    coin — a down PS skips fusion entirely, degrading to local
    consensus (plus per-rep-link degradation: dead reps drop out of the
    pool via ``hps_fusion(live=)``). ``faults=None`` emits the
    bit-identical pre-fault program.

    ``async_`` (a TRACED :class:`repro.core.asyncrony.AsyncModel` pytree,
    also riding the vmap scenario axis) switches the consensus half to the
    event-driven mode: per-tick wake coins on the ``async_stream_fold``
    HPS domain gate staging and delivery through the per-edge
    :class:`~repro.core.asyncrony.AsyncBuffer` carried in the scan
    (O(E·d), pinned by the ``hps_async`` statics contract), and asleep
    agents' node state is frozen inside :func:`sparse_pushsum_step`. The
    PS fusion half stays on the global Γ clock — the parameter server
    polls its representatives on its own schedule regardless of the
    gossip clocks (it reads whatever frozen state an asleep rep holds).
    Incompatible with ``graph_axis`` (the buffer is edge-local to the
    full index); composes freely with ``faults``.
    """
    if async_ is not None and graph_axis is not None:
        raise ValueError(
            "async_ is incompatible with graph_axis (the per-edge stale "
            "buffer is not partitioned); run async scans unsharded"
        )
    pol = None if policy is None else resolve_policy(policy)
    accum_name = None if pol is None else pol.accum
    N = w.shape[0]
    E = rt.src.shape[0]
    state0 = init_sparse_state(w, E, policy=policy)
    # loop invariants of the fixed edge index / inputs, hoisted out of the
    # scan: out-degree share factors and the consensus target mean(w)
    d_out = _out_degree(rt.src, rt.valid, N, w.dtype)
    if graph_axis is not None:
        d_out = jax.lax.psum(d_out, graph_axis)
    share = 1.0 / (d_out + 1.0)
    target = w.mean(axis=0)

    def body(carry, t):
        # carry layout: (state,) [+ abuf if async] [+ fault_state last]
        state = carry[0]
        fs = None
        if faults is not None:
            fs = step_faults(key, t, faults, carry[-1], engine=ENGINE_HPS,
                             graph_axis=graph_axis, n_shards=n_shards)
        # --- consensus (Alg. 1 lines 3-12) ---
        if faults is not None:
            # the drop uniform stays on the hps link stream (degenerate
            # model == step_edge_mask values draw-for-draw); GE state and
            # churn advance on the fault plane's own streams
            u = edge_uniforms(key, hps_stream_fold(t), E,
                              graph_axis=graph_axis, n_shards=n_shards)
            mask = faulty_edge_mask(u, t, faults, fs, rt.src, rt.dst,
                                    rt.drop_prob, rt.B)
        elif graph_axis is not None:
            mask = shard_edge_mask(
                key, t, E, rt.drop_prob, rt.B,
                graph_axis=graph_axis, n_shards=n_shards,
                fold_t=hps_stream_fold(t),
            )
        else:
            mask = step_edge_mask(
                key, t, E, rt.drop_prob, rt.B, fold_t=hps_stream_fold(t)
            )
        if async_ is not None:
            awake = wake_mask(key, t, N, async_.wake_prob, engine=ENGINE_HPS)
            st, abuf = sparse_pushsum_step(
                state, mask, rt.src, rt.dst, rt.valid, backend, share=share,
                dst_sorted=dst_sorted, policy=policy, faults=fs,
                awake=awake, abuf=carry[1], staleness=async_.staleness,
            )
        else:
            abuf = None
            st = sparse_pushsum_step(
                state, mask, rt.src, rt.dst, rt.valid, backend, share=share,
                graph_axis=graph_axis, dst_sorted=dst_sorted, policy=policy,
                halo=halo, n_shards=n_shards, faults=fs,
            )
        # --- PS fusion every Γ (lines 13-21) ---
        z_f, m_f = hps_fusion(st.z, st.m, rt.rep_mask, rt.M, F,
                              accum_dtype=accum_name,
                              live=None if fs is None else fs.node_live)
        do_fusion = (t + 1) % rt.gamma == 0
        if faults is not None:
            # PS crash: a down server skips the fusion round entirely —
            # the hierarchy degrades to plain local consensus instead of
            # pooling through a dead coordinator
            do_fusion = do_fusion & ps_alive(key, t, faults,
                                             engine=ENGINE_HPS)
        new = st._replace(
            z=jnp.where(do_fusion, z_f, st.z),
            m=jnp.where(do_fusion, m_f, st.m),
        )
        if store == "trajectory":
            ys = sparse_ratios(new)
        elif store == "gap":
            ys = jnp.abs(sparse_ratios(new) - target).max()   # () worst err
        else:
            ys = None
        out = (new,)
        if async_ is not None:
            out = out + (abuf,)
        if faults is not None:
            out = out + (fs,)
        return out, ys

    carry0 = (state0,)
    if async_ is not None:
        carry0 = carry0 + (init_async_buffer(E, w.shape[1], state0.z.dtype),)
    if faults is not None:
        carry0 = carry0 + (init_fault_state(N, E),)
    (final, *_), ys = jax.lax.scan(
        body, carry0, jnp.arange(T, dtype=jnp.int32))
    if store == "trajectory":
        return final, (ys, jnp.abs(ys - target[None, None, :]).max(axis=(1, 2)))
    fr = sparse_ratios(final)
    if store == "gap":
        return final, (fr, ys)
    return final, (fr, jnp.abs(fr - target).max())


# Module-level jit so repeated runs with the same shapes/statics hit the
# compilation cache instead of retracing a fresh closure per call.
_hps_compiled = functools.partial(
    jax.jit,
    static_argnames=("T", "store", "backend", "F", "graph_axis", "n_shards",
                     "policy", "dst_sorted", "halo"),
)(_hps_scan_core)
register_statics_cache("hps.jit", _hps_compiled._cache_size)


def run_hps_runtime(
    w: jnp.ndarray,
    rt: HPSRuntime,
    T: int,
    seed: int = 0,
    *,
    F: int = 0,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> HPSResult:
    """Run Algorithm 1 on a prebuilt :class:`HPSRuntime`.

    The dense-free entry point (see :func:`hps_runtime_from_edge_list`);
    :func:`run_hps` is the :class:`HPSConfig` convenience wrapper. ``seed``
    drives the per-round link-mask stream on the ``hps_stream_fold``
    domain; ``F > 0`` swaps the PS average for the trimmed-pool resilient
    rule (a science knob, so it stays a named parameter).

    Execution knobs ride ``plan=`` (:class:`repro.core.plan.ExecutionPlan`;
    loose ``backend=``/``store=``/``policy=``/``dst_sorted=``/``faults=``
    kwargs are deprecated shims folding into a plan bit-identically).
    ``plan.store=None`` means ``"trajectory"``; ``plan.dst_sorted``
    defaults to False because a user-built runtime may carry any edge
    order (the config-driven wrappers pass True). ``plan.faults``
    activates the unified fault plane; ``plan.async_`` the event-driven
    mode — a concretely degenerate model dispatches to the synchronous
    program (bit-identity by construction, :mod:`repro.core.asyncrony`).
    """
    plan = resolve_plan(
        plan, _entry="run_hps_runtime",
        _supports=("backend", "store", "policy", "dst_sorted", "faults",
                   "async_"),
        **legacy)
    store = "trajectory" if plan.store is None else plan.store
    if store not in HPS_STORES:
        raise ValueError(f"store must be one of {HPS_STORES}, got {store!r}")
    async_ = None if is_degenerate_async(plan.async_) else plan.async_
    final, (ratio, gap) = _hps_compiled(
        jax.random.PRNGKey(seed), rt, jnp.asarray(w),
        T=T, store=store, backend=plan.backend, F=F,
        policy=None if plan.policy is None else resolve_policy(plan.policy),
        dst_sorted=plan.dst_sorted, faults=plan.faults, async_=async_,
    )
    return HPSResult(ratio=ratio, final_state=final, gap=gap)


def run_hps(
    w: jnp.ndarray,
    cfg: HPSConfig,
    T: int,
    seed: int = 0,
    *,
    F: int = 0,
    plan: ExecutionPlan | None = None,
    **legacy,
) -> HPSResult:
    """Run HPS for T iterations (single scenario) on the fused engine.

    Per-round (E,) link masks are drawn inside the scan from ``seed`` with
    the drop_prob / B semantics of :func:`graphs.link_schedule` (forced
    delivery at ``t % B == B - 1``) on the dedicated ``hps_stream_fold``
    PRNG domain — nothing of size (T, N, N) or (N, N) is ever materialized.
    Execution knobs ride ``plan=`` (loose kwargs are deprecated shims);
    see :func:`run_hps_runtime`.
    """
    plan = resolve_plan(
        plan, _entry="run_hps",
        _supports=("backend", "store", "policy", "faults", "async_"),
        **legacy)
    return run_hps_runtime(
        w, make_hps_runtime(cfg), T, seed=seed, F=F,
        plan=plan.replace(dst_sorted=True),
    )


def run_hps_dense(
    w: jnp.ndarray,
    cfg: HPSConfig,
    T: int,
    seed: int = 0,
) -> tuple[PushSumState, jnp.ndarray]:
    """The seed-era dense reference: (N, N) masks, O(N^2 d) relay state.

    Kept as the executable spec the sparse engine is tested against
    (mirroring :func:`repro.core.pushsum.pushsum_step`'s role). It consumes
    the IDENTICAL per-round (E,) mask stream as :func:`run_hps` at the same
    seed — drawn on the ``hps_stream_fold`` domain over the dst-sorted edge
    index and scattered to (N, N) — so matched-seed runs see the same link
    failures; trajectories then agree to fp reduction order (the dense
    axis-0 delivery reduce and the sparse segment-sum associate
    differently, so bit-identity across the two lowerings is a 1-ulp-scale
    non-goal — the bit-exact contract lives between :func:`run_hps` and the
    pre-refactor sparse scan, see tests/test_hps_engine.py).

    Returns the final dense state and the (T, N, d) ratio trajectory.
    """
    el = cfg.edge_index()
    src, dst = jnp.asarray(el.src), jnp.asarray(el.dst)
    E = el.E
    n = cfg.topo.N
    adj = cfg.adj()
    rep_mask = cfg.rep_mask()
    key = jax.random.PRNGKey(seed)
    state0 = init_state(jnp.asarray(w))

    def body(state, t):
        mask_e = step_edge_mask(
            key, t, E, cfg.drop_prob, cfg.B, fold_t=hps_stream_fold(t)
        )
        mask = jnp.zeros((n, n), bool).at[src, dst].set(mask_e)
        do_fusion = (t + 1) % cfg.gamma_period == 0
        new = hps_step(state, mask, adj, rep_mask, cfg.topo.M, do_fusion)
        return new, ratios(new)

    final, traj = jax.lax.scan(body, state0, jnp.arange(T, dtype=jnp.int32))
    return final, traj


def theorem1_bound(cfg: HPSConfig, w: np.ndarray, t: int) -> float:
    """The RHS of Theorem 1 at iteration t (loose by the paper's own Remark 3)."""
    topo = cfg.topo
    M = topo.M
    d_star = topo.d_star()
    beta_min = topo.min_beta()
    contraction = beta_min ** (2 * d_star * cfg.B)
    gamma = 1.0 - contraction / (4.0 * M * M)
    two_gamma = 2 * cfg.gamma_period
    norm_sum = float(np.linalg.norm(np.asarray(w), axis=1).sum())
    lead = 4.0 * M * M * norm_sum / (contraction * topo.N)
    return lead * gamma ** max(t // two_gamma - 1, 0)
