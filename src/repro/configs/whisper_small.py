"""Whisper-small — encoder-decoder ASR; conv+mel frontend is a stub.

[audio] 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356]
The encoder consumes 1500 precomputed frame embeddings (stub frontend);
the 12-layer decoder has causal self-attention + cross-attention.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=12,             # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    encoder_layers=12,
    n_frames=1500,
    norm="layernorm",
    act="gelu",
    scan_layers=False,       # 12+12 shallow: unrolled
    tie_embeddings=True,
)
