"""Qwen3-MoE 235B-A22B — 128-expert top-8, GQA kv=4, deep stack.

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, 128e top-8
[hf:Qwen/Qwen3-30B-A3B family scaling]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="swiglu",
)
