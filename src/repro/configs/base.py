"""Architecture + run configuration.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact public-literature dimensions; ``reduced()`` derives the
CPU-smoke variant (2 layers, d_model <= 512, <= 4 experts) used by tests.

``block_pattern`` drives heterogeneous stacks: a layer's mixer kind is
``pattern[i % len(pattern)]``. Kinds:
  "attn"   — global GQA attention (RoPE, optional qk_norm)
  "swa"    — sliding-window GQA attention (local)
  "wkv6"   — RWKV6 time-mix (data-dependent decay linear recurrence)
  "rglru"  — RG-LRU temporal block (conv4 + gated linear recurrence)
The FFN kind is "moe" when n_experts > 0 for that arch, else "mlp"
("rwkv_cm" channel-mix for the rwkv family).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    source: str                      # citation: arXiv id or HF model card
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gspmd"          # "gspmd" | "sharded" (shard_map EP)

    # --- attention options ---
    pad_heads_to: int = 0            # zero-pad q heads for TP divisibility
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0                  # sliding window size for "swa" mixers
    logit_softcap: float = 0.0

    # --- stack structure ---
    block_pattern: tuple[str, ...] = ("attn",)
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    parallel_block: bool = False     # command-r style parallel attn+mlp
    tie_embeddings: bool = False

    # --- enc-dec / multimodal stubs ---
    encoder_layers: int = 0          # whisper encoder depth
    n_frames: int = 0                # stubbed audio frontend output length
    n_patches: int = 0               # stubbed ViT patch embeddings per image

    # --- ssm/hybrid dims ---
    rnn_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    wkv_head_dim: int = 64           # RWKV6 head size

    # --- execution ---
    scan_layers: bool = True         # lax.scan over the repeated pattern
    remat: bool = True               # checkpoint each scanned block
    remat_group: int = 1             # layers per checkpoint group (>1 saves
                                     # residuals every G layers only)
    ce_chunk: int = 0                # >0: streamed cross-entropy over
                                     # position chunks (never materializes
                                     # the full (T, vocab) logits)
    dtype: str = "bfloat16"
    use_pallas: bool = False         # engage Pallas kernels (TPU runtime)
    attn_impl: Literal["auto", "naive", "chunked"] = "auto"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def ffn_kind(self) -> str:
        if self.family == "ssm":
            return "rwkv_cm"
        return "moe" if self.is_moe else "mlp"

    def mixer_of(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def supports_long_decode(self) -> bool:
        """long_500k runs iff decode state is O(1) or windowed (sub-quadratic)."""
        return True  # every family here decodes with O(window) or O(1) state

    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def param_count(self) -> int:
        """Total parameters (embedding included) — used for 6ND model FLOPs."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, H, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        total = V * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            kind = self.mixer_of(i)
            if kind in ("attn", "swa"):
                total += d * hd * (H + 2 * Hkv) + H * hd * d
            elif kind == "wkv6":
                total += 5 * d * d + d * 64 * 2 + d * d  # r,k,v,g,w-lora,out
            elif kind == "rglru":
                w = self.rnn_width
                total += 2 * d * w + 4 * w + w * d + w * 3  # in/gate, conv4, out, lru
            if self.ffn_kind == "moe":
                total += self.n_experts * 3 * d * f + d * self.n_experts
            elif self.ffn_kind == "mlp":
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                total += mult * d * f
            else:  # rwkv channel mix
                total += 2 * d * f + d * d
            total += 2 * d  # norms
        if self.encoder_layers:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            total += self.encoder_layers * (4 * d * d + mult * d * f + 2 * d)
            total += L * 2 * d * d  # decoder cross-attn extra (q,o approx)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * self.n_experts * 3 * d * f
        return int(dense + L * self.top_k * 3 * d * f)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, seq_cap: int = 128) -> ArchConfig:
    """The CPU smoke-test variant: same family/pattern, tiny dims."""
    pat = len(cfg.block_pattern)
    n_layers = max(2, pat)  # at least one full pattern, >= 2 layers
    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(4, cfg.n_heads))
    ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_kv = max(1, n_heads // min(ratio, n_heads))
    head_dim = max(16, d_model // n_heads)
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab=min(cfg.vocab, 512),
        n_experts=min(cfg.n_experts, 4) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        # drop-free capacity so train/serve paths agree exactly in tests
        capacity_factor=(min(cfg.n_experts, 4) / min(cfg.top_k, 2))
        if cfg.is_moe
        else cfg.capacity_factor,
        window=min(cfg.window, seq_cap // 2) if cfg.window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        n_frames=min(cfg.n_frames, 64) if cfg.n_frames else 0,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
        rnn_width=min(cfg.rnn_width, 256),
        scan_layers=False,
        remat=False,
        dtype="float32",
        use_pallas=False,
    )
