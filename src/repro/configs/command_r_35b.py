"""Cohere Command-R 35B — GQA, parallel attn+FFN block, no biases.

[dense] 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    parallel_block=True,    # Cohere parallel residual
    norm="layernorm",
    act="swiglu",
    rope_theta=8e6,
    tie_embeddings=True,    # command-r ties input/output embeddings
)
