"""The paper's own experimental scale: a small decoder used by the
robust-training examples (hierarchical consensus over ~100M params)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paper-sim-100m",
    family="dense",
    source="this paper (Sec. VII simulation scale)",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32000,
    norm="rmsnorm",
    act="swiglu",
    scan_layers=False,
)
