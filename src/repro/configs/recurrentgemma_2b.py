"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 2:1 pattern.

[hybrid] 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000
[arXiv:2402.19427] — pattern: two recurrent blocks then one local-attention
block (window 2048).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rglru", "rglru", "swa"),
    window=2048,
    rnn_width=2560,
    norm="rmsnorm",
    act="geglu",
    tie_embeddings=True,
)
