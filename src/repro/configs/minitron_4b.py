"""Minitron-4B — width-pruned Nemotron-4.

[dense] 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    source="arXiv:2407.14679",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    norm="layernorm",
    act="gelu",             # nemotron uses squared-relu; gelu is our closest
    rope_theta=1e4,
)
