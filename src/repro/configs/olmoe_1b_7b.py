"""OLMoE-1B-7B — 64-expert top-8 MoE, every layer.

[moe] 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304, MoE 64e top-8
[arXiv:2409.02060]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    qk_norm=True,           # OLMoE uses QK-norm
    norm="rmsnorm",
    act="swiglu",
)
