"""InternVL2-26B language backbone (InternLM2-20B-style GQA decoder).

[vlm] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 — InternViT
vision encoder + MLP projector feed patch embeddings (the ViT is a stub per
the assignment carve-out; the projector + LM are real). [arXiv:2404.16821]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    n_patches=256,          # 448x448 image, pixel-shuffle to 256 tokens
    rope_theta=1e6,
    act="swiglu",
    norm="rmsnorm",
)
