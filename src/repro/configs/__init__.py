"""Assigned-architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` with the exact public-literature dimensions
(citation in ``source``). ``repro.configs.base.reduced`` derives the CPU
smoke variant.
"""
from __future__ import annotations

import importlib

from .base import ArchConfig, InputShape, INPUT_SHAPES, reduced

ARCH_IDS = [
    "internvl2_26b",
    "rwkv6_1b6",
    "command_r_35b",
    "recurrentgemma_2b",
    "qwen3_8b",
    "whisper_small",
    "olmoe_1b_7b",
    "qwen3_moe_235b_a22b",
    "llama3_405b",
    "minitron_4b",
    "paper_sim",
]

_ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "command-r-35b": "command_r_35b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-8b": "qwen3_8b",
    "whisper-small": "whisper_small",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama3-405b": "llama3_405b",
    "minitron-4b": "minitron_4b",
}


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "paper_sim"}


__all__ = [
    "ArchConfig", "InputShape", "INPUT_SHAPES", "reduced", "get_config",
    "all_configs", "ARCH_IDS",
]
