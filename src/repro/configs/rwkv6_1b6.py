"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay.

[ssm] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 [arXiv:2404.05892]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,             # 2048 / 64 wkv heads (layout only; attn-free)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    block_pattern=("wkv6",),
    wkv_head_dim=64,
    norm="layernorm",
    act="gelu",             # unused (rwkv channel-mix)
)
